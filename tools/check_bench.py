"""Bench-regression gate: compare freshly emitted BENCH_*.json timings
against the committed baselines in ``benchmarks/baselines/`` and fail on
a >2x slowdown of the compiled-step metrics.

Only CPU-stable metrics are gated — the jitted *compiled* steps, whose
wall time is dominated by the fixed XLA executable rather than Python
lowering or allocator noise. Eager re-lowering timings, raw-kernel
micro-benchmarks, and interpret probes vary too much across runners to
gate on.

Usage (the CI slow lane; ``BENCH_*.json`` emissions are gitignored, the
baselines are committed):

    PYTHONPATH=src python -m benchmarks.run engine_overhead kernel_dispatch
    python tools/check_bench.py

Re-baseline when a change legitimately moves a gated timing:

    cp BENCH_<suite>.json benchmarks/baselines/<suite>.json
"""

from __future__ import annotations

import argparse
import json
import pathlib
import re
import sys
from typing import Dict, List

REPO = pathlib.Path(__file__).resolve().parent.parent

#: default gated suites (the tier1-slow lane): fresh emission
#: BENCH_<name>.json vs baselines/<name>.json. The mesh/streaming suites
#: run in other lanes and are gated there via ``--suites``:
#: tier1-spmd gates coo_scale, tier1-oocore gates oocore_scale,
#: tier1-serving gates serving_load.
SUITES = ("engine_overhead", "kernel_dispatch", "rjp_ablation")
EXTRA_SUITES = ("coo_scale", "oocore_scale", "serving_load")

#: names considered CPU-stable: compiled/jitted steps only (the session
#: variant is the same jitted step behind the Database front door, so
#: gating it bounds the session's per-call overhead too). The rjp lanes
#: gate the §4 join-agg fusion win and the multi-join Σ-pushdown rewrite
#: win; the interpreter-only rjp variants are excluded as unstable.
STABLE = (
    re.compile(r"^engine_overhead/.*/compiled$"),
    re.compile(r"^engine_overhead/.*/session$"),
    re.compile(r"^kernel_dispatch/engine-"),
    re.compile(r"^rjp/all-opts$"),
    re.compile(r"^rjp/no-join-agg-fusion$"),
    re.compile(r"^rjp/pushdown-"),
    # mesh + out-of-core lanes: every row is a jitted step (the streamed
    # rows are the same jitted waves plus host<->device transfers, which
    # on the CI host mesh are memcpys — stable enough for a 2x gate)
    re.compile(r"^coo_scale/.*/(replicated|sharded|oocore)$"),
    re.compile(r"^oocore_scale/.*/(incore|oocore)$"),
    # serving lane: a warmed endpoint's request path is compiled
    # prefill/decode steps plus asyncio scheduling; the open-loop
    # arrival rate sits far below saturation so the percentiles track
    # batch service time, not queueing blow-up
    re.compile(r"^serving_load/open-loop/(p50|p99|us_per_request)$"),
)

DEFAULT_THRESHOLD = 2.0


def _is_stable(name: str) -> bool:
    return any(p.match(name) for p in STABLE)


class BenchFormatError(ValueError):
    """A benchmark emission/baseline file has an unusable shape — wrong
    top-level type, non-object rows, missing or non-numeric metric keys.
    Always names the offending file (and row), never a bare
    KeyError/AttributeError."""


def _rows(path: pathlib.Path, raw) -> List[dict]:
    """Normalize the two accepted baseline schemas to a list of row
    dicts: the emitted ``[{"name": ..., "us_per_call": ...}, ...]`` list,
    or a hand-written ``{"<name>": <us>}`` / ``{"<name>": {...}}``
    mapping. Anything else is a named format error — historically a
    top-level list where a mapping was assumed crashed the gate with
    ``AttributeError: 'list' object has no attribute 'keys'`` and no
    file context."""
    if isinstance(raw, list):
        return raw
    if isinstance(raw, dict):
        return [
            {"name": name, **val}
            if isinstance(val, dict)
            else {"name": name, "us_per_call": val}
            for name, val in raw.items()
        ]
    raise BenchFormatError(
        f"{path}: expected a list of benchmark rows or a name->timing "
        f"mapping, got {type(raw).__name__}"
    )


def _load(path: pathlib.Path) -> Dict[str, float]:
    try:
        raw = json.loads(path.read_text())
    except json.JSONDecodeError as e:
        raise BenchFormatError(f"{path}: not valid JSON ({e})") from None
    out: Dict[str, float] = {}
    for i, r in enumerate(_rows(path, raw)):
        if not isinstance(r, dict):
            raise BenchFormatError(
                f"{path}: row {i} is {type(r).__name__}, expected an "
                f"object with 'name'/'us_per_call' keys"
            )
        missing = [k for k in ("name", "us_per_call") if k not in r]
        if missing:
            raise BenchFormatError(
                f"{path}: row {i} ({r.get('name', '<unnamed>')!r}) is "
                f"missing metric key(s) {missing}; re-emit the suite or "
                f"re-baseline (cp BENCH_<suite>.json benchmarks/baselines/)"
            )
        try:
            out[r["name"]] = float(r["us_per_call"])
        except (TypeError, ValueError):
            raise BenchFormatError(
                f"{path}: row {i} ({r['name']!r}) has non-numeric "
                f"us_per_call {r['us_per_call']!r}"
            ) from None
    return out


def check(
    baseline_dir: pathlib.Path,
    fresh_dir: pathlib.Path,
    threshold: float = DEFAULT_THRESHOLD,
    suites=SUITES,
) -> List[str]:
    """Return a list of failure messages (empty = gate passes)."""
    errors: List[str] = []
    for suite in suites:
        base_path = baseline_dir / f"{suite}.json"
        fresh_path = fresh_dir / f"BENCH_{suite}.json"
        if not base_path.exists():
            errors.append(f"{suite}: baseline missing at {base_path}")
            continue
        if not fresh_path.exists():
            errors.append(f"{suite}: fresh run missing at {fresh_path}")
            continue
        try:
            base = _load(base_path)
            fresh = _load(fresh_path)
        except BenchFormatError as e:
            # a malformed baseline used to surface as a bare KeyError with
            # no file or key context — fail with both instead
            errors.append(str(e))
            continue
        gated = {n for n in base if _is_stable(n)}
        if not gated:
            errors.append(f"{suite}: no gated (compiled-step) metrics in baseline")
            continue
        for name in sorted(gated):
            if name not in fresh:
                errors.append(f"{name}: present in baseline, missing from fresh run")
                continue
            ratio = fresh[name] / base[name] if base[name] > 0 else float("inf")
            status = "FAIL" if ratio > threshold else "ok  "
            print(
                f"{status} {name}: {base[name]:.0f}us -> {fresh[name]:.0f}us "
                f"({ratio:.2f}x, limit {threshold:.1f}x)"
            )
            if ratio > threshold:
                errors.append(
                    f"{name}: {ratio:.2f}x slowdown "
                    f"({base[name]:.0f}us -> {fresh[name]:.0f}us)"
                )
    return errors


def main(argv: List[str]) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--baseline", default=str(REPO / "benchmarks" / "baselines"),
        help="directory holding the committed <suite>.json baselines",
    )
    ap.add_argument(
        "--fresh", default=".",
        help="directory holding the freshly emitted BENCH_<suite>.json files",
    )
    ap.add_argument(
        "--threshold", type=float, default=DEFAULT_THRESHOLD,
        help="max allowed fresh/baseline slowdown ratio (default 2.0)",
    )
    ap.add_argument(
        "--suites", nargs="+", default=list(SUITES),
        choices=sorted(SUITES + EXTRA_SUITES),
        help="which suites to gate (default: the tier1-slow trio)",
    )
    args = ap.parse_args(argv)
    errors = check(
        pathlib.Path(args.baseline),
        pathlib.Path(args.fresh),
        args.threshold,
        suites=tuple(args.suites),
    )
    for e in errors:
        print(f"FAIL {e}", file=sys.stderr)
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
