"""Docs lane: run every docs/*.md as a doctest file and verify that the
cross-references they make — dotted ``repro.*`` module paths, backticked
file paths, relative markdown links — still resolve, so a moved module
fails CI instead of silently rotting the docs.

Usage:  PYTHONPATH=src python tools/check_docs.py [docs/*.md ...]
"""

from __future__ import annotations

import doctest
import importlib
import pathlib
import re
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
DOCS = REPO / "docs"

#: dotted module/attribute references, e.g. ``repro.core.kernels.make_table``
_DOTTED = re.compile(r"\brepro(?:\.[A-Za-z_][A-Za-z0-9_]*)+")
#: backticked path-looking references, e.g. `core/engine.py`, `docs/kernels.md`
_BACKTICK_PATH = re.compile(r"`([A-Za-z0-9_./-]+/[A-Za-z0-9_.-]+\.(?:py|md|json))`")
#: relative markdown links: [text](kernels.md) / [text](../README.md)
_MD_LINK = re.compile(r"\]\((?!https?://|#)([^)#\s]+)\)")

#: roots a backticked path may be relative to.
_PATH_ROOTS = (REPO, REPO / "src" / "repro", REPO / "src", DOCS)


def _check_dotted(ref: str) -> bool:
    """Import the longest importable prefix, then getattr the rest."""
    parts = ref.split(".")
    for cut in range(len(parts), 0, -1):
        try:
            obj = importlib.import_module(".".join(parts[:cut]))
        except ImportError:
            continue
        try:
            for attr in parts[cut:]:
                obj = getattr(obj, attr)
        except AttributeError:
            return False
        return True
    return False


def _check_path(ref: str) -> bool:
    return any((root / ref).exists() for root in _PATH_ROOTS)


def check_file(path: pathlib.Path) -> list[str]:
    errors: list[str] = []
    text = path.read_text()

    # -- doctest the fenced examples -------------------------------------
    results = doctest.testfile(
        str(path),
        module_relative=False,
        optionflags=doctest.ELLIPSIS | doctest.NORMALIZE_WHITESPACE,
        verbose=False,
    )
    if results.failed:
        errors.append(
            f"{path.name}: {results.failed}/{results.attempted} doctests failed"
        )

    # -- cross-references -------------------------------------------------
    for lineno, line in enumerate(text.splitlines(), start=1):
        for ref in _DOTTED.findall(line):
            if not _check_dotted(ref):
                errors.append(
                    f"{path.name}:{lineno}: broken module reference {ref!r}"
                )
        for ref in _BACKTICK_PATH.findall(line):
            if not _check_path(ref):
                errors.append(
                    f"{path.name}:{lineno}: broken path reference {ref!r}"
                )
        for ref in _MD_LINK.findall(line):
            if not (path.parent / ref).exists() and not _check_path(ref):
                errors.append(f"{path.name}:{lineno}: broken link {ref!r}")
    return errors


def main(argv: list[str]) -> int:
    files = [pathlib.Path(a) for a in argv] or sorted(DOCS.glob("*.md"))
    if not files:
        print("check_docs: no docs/*.md files found", file=sys.stderr)
        return 1
    failed = False
    for f in files:
        errs = check_file(f)
        if errs:
            failed = True
            for e in errs:
                print(f"FAIL {e}", file=sys.stderr)
        else:
            print(f"ok   {f.relative_to(REPO) if f.is_absolute() else f}")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
