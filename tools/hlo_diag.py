"""Diagnostic: compile one dry-run combo and histogram the largest tensors
and collectives in the partitioned HLO."""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import collections
import re

import jax

from repro.launch.dryrun import build_step
from repro.launch.mesh import make_production_mesh

BY = {"bf16": 2, "f16": 2, "f32": 4, "s32": 4, "u32": 4, "pred": 1, "s8": 1, "u8": 1, "s64": 8}
PAT = re.compile(r"(bf16|f16|f32|s32|u32|pred|s8|u8|s64)\[([\d,]+)\]")


def bytes_of(dt, dims):
    n = 1
    for d in dims.split(","):
        n *= int(d)
    return n * BY[dt]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--unroll", action="store_true")
    ap.add_argument("--min-gb", type=float, default=0.2)
    ap.add_argument("--set", action="append", default=[], metavar="K=V")
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        try:
            overrides[k] = int(v)
        except ValueError:
            overrides[k] = v

    mesh = make_production_mesh()
    with jax.set_mesh(mesh):
        fn, fargs = build_step(
            args.arch, args.shape, mesh, unroll=args.unroll,
            overrides=overrides or None,
        )
        compiled = fn.lower(*fargs).compile()
    txt = compiled.as_text()

    sizes = collections.Counter()
    colls = collections.Counter()
    for line in txt.splitlines():
        line = line.strip()
        m = PAT.search(line)
        if not m:
            continue
        dt, dims = m.groups()
        b = bytes_of(dt, dims)
        rhs = line.split("=", 1)[1] if "=" in line else line
        mo = re.search(r"\]\}?\s+([a-z][a-z0-9\-]*)", rhs)
        op = mo.group(1) if mo else "?"
        if any(c in line for c in ("all-reduce", "all-gather", "all-to-all", "collective-permute", "reduce-scatter")):
            colls[(dt, dims, op)] += 1
        if b >= args.min_gb * 1e9:
            sizes[(dt, dims, op)] += 1

    print("== largest tensors ==")
    for k, c in sorted(sizes.items(), key=lambda kv: -bytes_of(kv[0][0], kv[0][1]))[:25]:
        print(f"{bytes_of(k[0], k[1])/1e9:8.2f} GB  {k[0]}[{k[1]}] x{c}  {k[2]}")
    print("== collectives ==")
    for k, c in sorted(colls.items(), key=lambda kv: -bytes_of(kv[0][0], kv[0][1]) * kv[1])[:25]:
        print(f"{bytes_of(k[0], k[1])*c/1e9:8.2f} GB total  {k[0]}[{k[1]}] x{c}  {k[2]}")

    print("temp GB:", compiled.memory_analysis().temp_size_in_bytes / 1e9)


if __name__ == "__main__":
    main()
