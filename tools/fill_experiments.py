"""Regenerate the §Dry-run / §Roofline tables inside EXPERIMENTS.md from
experiments/dryrun/*.json (between the <!-- ROOFLINE_TABLE --> and
<!-- DRYRUN_TABLE --> markers).

Usage: python tools/fill_experiments.py
"""

import io
import re
import subprocess
import sys

MD = "EXPERIMENTS.md"


def main() -> None:
    out = subprocess.run(
        [sys.executable, "tools/roofline_table.py"],
        capture_output=True, text=True, check=True,
    ).stdout
    single, multi = out.split("## Multi-pod lowering proof")
    single = single.replace("## Single-pod roofline", "### Single-pod roofline")
    multi = "### Multi-pod lowering proof" + multi

    with open(MD) as f:
        text = f.read()
    text = re.sub(
        r"<!-- DRYRUN_TABLE -->.*?(?=\n## )",
        "<!-- DRYRUN_TABLE -->\n\n" + multi.strip() + "\n\n",
        text, flags=re.S,
    )
    text = re.sub(
        r"<!-- ROOFLINE_TABLE -->.*?(?=\n## )",
        "<!-- ROOFLINE_TABLE -->\n\n" + single.strip() + "\n\n",
        text, flags=re.S,
    )
    with open(MD, "w") as f:
        f.write(text)
    print("EXPERIMENTS.md tables refreshed")


if __name__ == "__main__":
    main()
