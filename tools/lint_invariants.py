#!/usr/bin/env python3
"""Engine-invariant linter: AST checks for repo rules that hold the
compiler/engine contract together but that no unit test can pin down
file-by-file. Stdlib only — runs in the CI ``lint`` lane and from the
command line:

    python tools/lint_invariants.py [--root PATH]

Rules (each prints ``file:line: [rule] message`` and exits non-zero):

  dispatch-pairing   every logical op in ``DISPATCH_OPS`` registers all
                     five tiers (pallas/interpret/sanitizer/ref/jnp) in
                     core/kernels.py, and every Pallas kernel package
                     (src/repro/kernels/*/ with an ops.py) pairs its
                     forward with a ``jax.custom_vjp`` + ``defvjp`` and
                     ships a ``ref.py`` oracle — the dispatch registry's
                     interchangeability contract (docs/kernels.md).
  kernel-contract    every Pallas kernel package's ops.py declares a
                     module-level ``CONTRACT = KernelContract(...)``,
                     and ``_CONTRACT_MODULES`` in core/kernels.py names
                     a contract module for every ``DISPATCH_OPS`` op —
                     the static certifier (repro.analysis.kernelcheck)
                     proves grid/VJP/predicate soundness from these.
  cache-key          the lowering-cache signature builders in
                     core/engine.py (``_rel_signature`` /
                     ``env_signature`` / ``_stats_key``) return hashable
                     shapes: no dict/list/set at the top of a return —
                     an unhashable key silently breaks Lowered reuse.
  jit-scope          ``jax.jit`` in src/repro/core + src/repro/serving
                     appears only in the engine/session/serving-step
                     modules that own executables. A stray jit anywhere
                     else bypasses the session's compile counters and
                     the planner's in_shardings.
  planner-pure       core/planner.py and core/rewrite.py never import
                     ``jax.numpy`` — cost models and algebraic rewrites
                     run at plan time on python numbers; a jnp import
                     would trace (and device-commit) inside planning.
  task-retention     every asyncio ``create_task`` call in serving/ is
                     retained (assigned, not fire-and-forget) and named
                     — an unreferenced task is garbage-collected
                     mid-flight and swallows its exceptions.
"""

from __future__ import annotations

import argparse
import ast
import sys
from pathlib import Path
from typing import List, NamedTuple

DISPATCH_TIERS = ("pallas", "interpret", "sanitizer", "ref", "jnp")

# modules allowed to build jitted executables (rule: jit-scope)
JIT_ALLOWLIST = {
    "core/engine.py",      # the staged executor
    "core/session.py",     # session-owned executables
    "serving/serve.py",    # prefill/decode step builders
    "serving/service.py",  # endpoint fallback jit (mesh-less path)
}

# lowering-cache signature builders (rule: cache-key)
CACHE_KEY_FUNCS = ("_rel_signature", "env_signature", "_stats_key")

UNHASHABLE_NODES = (
    ast.Dict, ast.List, ast.Set, ast.DictComp, ast.ListComp, ast.SetComp,
)


class Violation(NamedTuple):
    path: str
    line: int
    rule: str
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


def _parse(path: Path) -> ast.Module:
    return ast.parse(path.read_text(), filename=str(path))


def _is_jax_jit(node: ast.AST) -> bool:
    return (
        isinstance(node, ast.Attribute)
        and node.attr == "jit"
        and isinstance(node.value, ast.Name)
        and node.value.id == "jax"
    )


# ---------------------------------------------------------------------------
# dispatch-pairing
# ---------------------------------------------------------------------------


def check_dispatch_pairing(src: Path) -> List[Violation]:
    out: List[Violation] = []
    kern = src / "core" / "kernels.py"
    if kern.exists():
        tree = _parse(kern)
        ops: List[str] = []
        ops_line = 1
        registered = set()
        for node in ast.walk(tree):
            if (
                isinstance(node, (ast.Assign, ast.AnnAssign))
                and any(
                    isinstance(t, ast.Name) and t.id == "DISPATCH_OPS"
                    for t in (
                        node.targets
                        if isinstance(node, ast.Assign)
                        else [node.target]
                    )
                )
                and node.value is not None
            ):
                try:
                    ops = list(ast.literal_eval(node.value))
                    ops_line = node.lineno
                except ValueError:
                    out.append(Violation(
                        str(kern), node.lineno, "dispatch-pairing",
                        "DISPATCH_OPS must be a literal tuple of op names",
                    ))
            if (
                isinstance(node, ast.Call)
                and isinstance(node.func, ast.Name)
                and node.func.id == "register_impl"
                and len(node.args) >= 2
                and all(
                    isinstance(a, ast.Constant) for a in node.args[:2]
                )
            ):
                registered.add((node.args[0].value, node.args[1].value))
        for op in ops:
            missing = [
                t for t in DISPATCH_TIERS if (op, t) not in registered
            ]
            if missing:
                out.append(Violation(
                    str(kern), ops_line, "dispatch-pairing",
                    f"op {op!r} has no registered {'/'.join(missing)} "
                    "tier(s); every DISPATCH_OPS entry needs all of "
                    f"{'/'.join(DISPATCH_TIERS)}",
                ))

    kdir = src / "kernels"
    if kdir.is_dir():
        for ops_py in sorted(kdir.glob("*/ops.py")):
            tree = _parse(ops_py)
            names = {
                n.attr if isinstance(n, ast.Attribute) else getattr(n, "id", "")
                for n in ast.walk(tree)
                if isinstance(n, (ast.Attribute, ast.Name))
            }
            if "custom_vjp" not in names:
                out.append(Violation(
                    str(ops_py), 1, "dispatch-pairing",
                    "kernel ops.py has no jax.custom_vjp — the Pallas "
                    "forward must pair with an explicit VJP",
                ))
            if "defvjp" not in names:
                out.append(Violation(
                    str(ops_py), 1, "dispatch-pairing",
                    "kernel ops.py never calls .defvjp(fwd, bwd)",
                ))
            if not (ops_py.parent / "ref.py").exists():
                out.append(Violation(
                    str(ops_py.parent), 1, "dispatch-pairing",
                    "kernel package has no ref.py oracle for the "
                    "ref dispatch tier",
                ))
    return out


# ---------------------------------------------------------------------------
# kernel-contract
# ---------------------------------------------------------------------------


def check_kernel_contract(src: Path) -> List[Violation]:
    out: List[Violation] = []
    kdir = src / "kernels"
    if kdir.is_dir():
        for ops_py in sorted(kdir.glob("*/ops.py")):
            tree = _parse(ops_py)
            has_contract = False
            for node in tree.body:
                targets = (
                    node.targets
                    if isinstance(node, ast.Assign)
                    else [node.target]
                    if isinstance(node, ast.AnnAssign)
                    else []
                )
                if (
                    any(
                        isinstance(t, ast.Name) and t.id == "CONTRACT"
                        for t in targets
                    )
                    and isinstance(getattr(node, "value", None), ast.Call)
                    and (
                        getattr(node.value.func, "id", None)
                        == "KernelContract"
                        or getattr(node.value.func, "attr", None)
                        == "KernelContract"
                    )
                ):
                    has_contract = True
            if not has_contract:
                out.append(Violation(
                    str(ops_py), 1, "kernel-contract",
                    "kernel ops.py declares no module-level CONTRACT = "
                    "KernelContract(...) — the static certifier "
                    "(repro.analysis.kernelcheck) has nothing to prove",
                ))

    kern = src / "core" / "kernels.py"
    if kern.exists():
        tree = _parse(kern)
        ops: List[str] = []
        modules: dict = {}
        line = 1
        for node in ast.walk(tree):
            targets = (
                node.targets
                if isinstance(node, ast.Assign)
                else [node.target]
                if isinstance(node, ast.AnnAssign)
                else []
            )
            names = {
                t.id for t in targets if isinstance(t, ast.Name)
            }
            if not names or getattr(node, "value", None) is None:
                continue
            if "DISPATCH_OPS" in names:
                try:
                    ops = list(ast.literal_eval(node.value))
                except ValueError:
                    pass
            if "_CONTRACT_MODULES" in names:
                line = node.lineno
                try:
                    modules = dict(ast.literal_eval(node.value))
                except ValueError:
                    out.append(Violation(
                        str(kern), node.lineno, "kernel-contract",
                        "_CONTRACT_MODULES must be a literal dict of "
                        "op -> contract module path",
                    ))
        for op in ops:
            if op not in modules:
                out.append(Violation(
                    str(kern), line, "kernel-contract",
                    f"dispatch op {op!r} has no entry in "
                    "_CONTRACT_MODULES — kernelcheck cannot load its "
                    "KernelContract",
                ))
    return out


# ---------------------------------------------------------------------------
# cache-key
# ---------------------------------------------------------------------------


def check_cache_key(src: Path) -> List[Violation]:
    out: List[Violation] = []
    eng = src / "core" / "engine.py"
    if not eng.exists():
        return out
    tree = _parse(eng)
    found = set()
    for node in ast.walk(tree):
        if (
            isinstance(node, ast.FunctionDef)
            and node.name in CACHE_KEY_FUNCS
        ):
            found.add(node.name)
            for ret in ast.walk(node):
                if (
                    isinstance(ret, ast.Return)
                    and isinstance(ret.value, UNHASHABLE_NODES)
                ):
                    out.append(Violation(
                        str(eng), ret.lineno, "cache-key",
                        f"{node.name} returns an unhashable "
                        f"{type(ret.value).__name__.lower()} — the "
                        "lowering cache keys on this value",
                    ))
    for name in CACHE_KEY_FUNCS:
        if name not in found:
            out.append(Violation(
                str(eng), 1, "cache-key",
                f"signature builder {name} not found — if it moved, "
                "update CACHE_KEY_FUNCS in tools/lint_invariants.py",
            ))
    return out


# ---------------------------------------------------------------------------
# jit-scope
# ---------------------------------------------------------------------------


def check_jit_scope(src: Path) -> List[Violation]:
    out: List[Violation] = []
    for sub in ("core", "serving"):
        d = src / sub
        if not d.is_dir():
            continue
        for path in sorted(d.glob("*.py")):
            rel = f"{sub}/{path.name}"
            if rel in JIT_ALLOWLIST:
                continue
            tree = _parse(path)
            for node in ast.walk(tree):
                if _is_jax_jit(node):
                    out.append(Violation(
                        str(path), node.lineno, "jit-scope",
                        "jax.jit outside the executable-owning modules "
                        f"({', '.join(sorted(JIT_ALLOWLIST))}) bypasses "
                        "the session's compile counters and plans",
                    ))
                if (
                    isinstance(node, ast.ImportFrom)
                    and node.module == "jax"
                    and any(a.name == "jit" for a in node.names)
                ):
                    out.append(Violation(
                        str(path), node.lineno, "jit-scope",
                        "from jax import jit outside the "
                        "executable-owning modules",
                    ))
    return out


# ---------------------------------------------------------------------------
# planner-pure
# ---------------------------------------------------------------------------


def check_planner_pure(src: Path) -> List[Violation]:
    out: List[Violation] = []
    for rel in ("core/planner.py", "core/rewrite.py"):
        path = src / rel
        if not path.exists():
            continue
        tree = _parse(path)
        for node in ast.walk(tree):
            bad_line = None
            if isinstance(node, ast.Import) and any(
                a.name == "jax.numpy" for a in node.names
            ):
                bad_line = node.lineno
            if isinstance(node, ast.ImportFrom) and (
                node.module == "jax.numpy"
                or (
                    node.module == "jax"
                    and any(a.name == "numpy" for a in node.names)
                )
            ):
                bad_line = node.lineno
            if bad_line is not None:
                out.append(Violation(
                    str(path), bad_line, "planner-pure",
                    "jax.numpy import in plan-time code — cost models "
                    "and rewrites must stay off the device (python "
                    "numbers only)",
                ))
    return out


# ---------------------------------------------------------------------------
# task-retention
# ---------------------------------------------------------------------------


def check_task_retention(src: Path) -> List[Violation]:
    out: List[Violation] = []
    d = src / "serving"
    if not d.is_dir():
        return out
    for path in sorted(d.glob("*.py")):
        tree = _parse(path)
        for node in ast.walk(tree):
            for child in ast.iter_child_nodes(node):
                call = (
                    child.value
                    if isinstance(child, (ast.Expr, ast.Assign, ast.Return))
                    else child
                )
                if not (
                    isinstance(call, ast.Call)
                    and isinstance(call.func, ast.Attribute)
                    and call.func.attr == "create_task"
                ):
                    continue
                if isinstance(child, ast.Expr):
                    out.append(Violation(
                        str(path), call.lineno, "task-retention",
                        "fire-and-forget create_task: the task can be "
                        "garbage-collected mid-flight and its "
                        "exceptions vanish — assign it",
                    ))
                if not any(k.arg == "name" for k in call.keywords):
                    out.append(Violation(
                        str(path), call.lineno, "task-retention",
                        "create_task without name=: unnamed scheduler "
                        "tasks are undebuggable in asyncio dumps",
                    ))
    return out


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

ALL_CHECKS = (
    check_dispatch_pairing,
    check_kernel_contract,
    check_cache_key,
    check_jit_scope,
    check_planner_pure,
    check_task_retention,
)


def run(root: Path) -> List[Violation]:
    src = root / "src" / "repro"
    violations: List[Violation] = []
    for check in ALL_CHECKS:
        violations.extend(check(src))
    return violations


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--root",
        type=Path,
        default=Path(__file__).resolve().parent.parent,
        help="repo root (contains src/repro); default: this checkout",
    )
    args = ap.parse_args(argv)
    violations = run(args.root)
    for v in violations:
        print(v.render())
    if violations:
        print(f"{len(violations)} invariant violation(s)")
        return 1
    print("engine invariants ok")
    return 0


if __name__ == "__main__":
    sys.exit(main())
