"""Aggregate experiments/dryrun/*.json into the EXPERIMENTS.md roofline
table (single-pod records) + the multi-pod lowering-proof table.

Usage:  python tools/roofline_table.py [--dir experiments/dryrun]
"""

import argparse
import glob
import json
import os


def fmt_s(x):
    if x is None:
        return "—"
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}µs"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    args = ap.parse_args()

    recs = []
    for path in sorted(glob.glob(os.path.join(args.dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))

    shapes = ("train_4k", "prefill_32k", "decode_32k", "long_500k")

    def order(r):
        return (r["arch"], shapes.index(r["shape"]) if r["shape"] in shapes else 9)

    print("## Single-pod roofline (16×16 = 256 chips, per-device terms)\n")
    print("| arch | shape | compute | memory | collective | dominant | "
          "HLO GFLOPs/dev | useful ratio | bytes/dev |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in sorted([r for r in recs if not r.get("multi_pod")], key=order):
        if r["status"] == "skipped":
            print(f"| {r['arch']} | {r['shape']} | — | — | — | "
                  f"skipped: {r['reason']} | — | — | — |")
            continue
        if r["status"] != "ok":
            print(f"| {r['arch']} | {r['shape']} | — | — | — | "
                  f"ERROR | — | — | — |")
            continue
        ro = r["roofline"]
        ur = ro.get("useful_flops_ratio")
        mem = r.get("memory_analysis") or {}
        bytes_dev = (mem.get("argument_size_in_bytes", 0)
                     + mem.get("temp_size_in_bytes", 0))
        print(
            f"| {r['arch']} | {r['shape']} | {fmt_s(ro['compute_s'])} | "
            f"{fmt_s(ro['memory_s'])} | {fmt_s(ro['collective_s'])} | "
            f"**{ro['dominant'].replace('_s','')}** | "
            f"{ro['hlo_flops_per_device']/1e9:,.0f} | "
            f"{ur:.2f}" + (" |" if ur is not None else "— |") +
            f" {bytes_dev/2**30:.1f} GiB |"
        )

    print("\n## Multi-pod lowering proof (2×16×16 = 512 chips)\n")
    print("| arch | shape | status | compile | collective bytes/dev |")
    print("|---|---|---|---|---|")
    for r in sorted([r for r in recs if r.get("multi_pod")], key=order):
        if r["status"] == "skipped":
            print(f"| {r['arch']} | {r['shape']} | skipped ({r['reason']}) | — | — |")
        elif r["status"] != "ok":
            print(f"| {r['arch']} | {r['shape']} | **ERROR** | — | — |")
        else:
            cb = r["roofline"]["collective"]["total_bytes"]
            print(f"| {r['arch']} | {r['shape']} | ok | "
                  f"{r['compile_s']:.0f}s | {cb/2**20:,.0f} MiB |")


if __name__ == "__main__":
    main()
