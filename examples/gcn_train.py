"""End-to-end GCN node-classification training (paper §6, Tables 2–3).

A two-layer graph convolutional network where message passing is the
paper's three-way join (Node ⋈ Edge ⋈ Node) + Σ-by-destination, executed
through the relational ops whose backward passes are RA-autodiff-generated
gradient queries (reversed-edge convolution for ∂h, per-edge dot for ∂w).

Supports full-graph training (the mode only RA-GCN could reach in the
paper) and mini-batch training, mirroring the paper's two rows.

Run:  PYTHONPATH=src python examples/gcn_train.py [--nodes 2048] [--edges 16384]
      [--epochs 30] [--mode full|minibatch]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro
from repro.data import synthetic_graph
from repro.optim import adam_init, adam_update
from repro.relational import gcn_conv, rel_linear


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=2048)
    ap.add_argument("--edges", type=int, default=16384)
    ap.add_argument("--feat", type=int, default=64)
    ap.add_argument("--labels", type=int, default=16)
    ap.add_argument("--hidden", type=int, default=256)   # paper: D=256
    ap.add_argument("--epochs", type=int, default=60)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--mode", choices=("full", "minibatch"), default="full")
    ap.add_argument("--batch", type=int, default=1024)   # paper: B=1024
    ap.add_argument("--mesh", default=None,
                    help='session mesh spec, e.g. "host:2" (default: none)')
    args = ap.parse_args()

    g = synthetic_graph(args.nodes, args.edges, args.feat, args.labels, seed=0)
    keys, w, x = g["edge_keys"], g["edge_w"], g["x"]

    # One session for the whole run: the relational ops (gcn_conv /
    # rel_linear) plan, dispatch and distribute through it. The edge
    # relation is registered so the catalog tracks its key-domain
    # statistics (distinct src/dst counts, nnz, density).
    db = repro.Database(mesh=args.mesh)
    db.put(
        "Edge",
        repro.CooRelation(
            jnp.asarray(keys, jnp.int32), jnp.asarray(w),
            (args.nodes, args.nodes),
        ),
        keys=("src", "dst"),
    )
    print(f"catalog Edge: keys={db.schema('Edge')}  {db.stats('Edge')}")
    # learnable labels (2-hop-smoothed linear function of the features)
    rng = np.random.default_rng(0)
    proj = rng.normal(size=(args.feat, args.labels)).astype(np.float32)
    smooth = np.asarray(gcn_conv(gcn_conv(x, keys, w), keys, w))
    y = jnp.asarray(np.argmax(smooth @ proj, axis=1).astype(np.int32))

    params = {
        "w1": jnp.asarray(
            rng.normal(size=(args.feat, args.hidden)).astype(np.float32)
        ) * (args.feat ** -0.5),
        "w2": jnp.asarray(
            rng.normal(size=(args.hidden, args.labels)).astype(np.float32)
        ) * (args.hidden ** -0.5),
    }
    opt = adam_init(params)

    def forward(params):
        h = gcn_conv(x, keys, w)                  # join-agg message passing
        h = jax.nn.relu(rel_linear(h, params["w1"]))
        h = gcn_conv(h, keys, w)
        return rel_linear(h, params["w2"])

    def loss_fn(params, node_ids):
        logits = forward(params)[node_ids]
        yy = y[node_ids]
        logp = jax.nn.log_softmax(logits)
        loss = -jnp.mean(jnp.take_along_axis(logp, yy[:, None], axis=1))
        acc = jnp.mean((jnp.argmax(logits, axis=1) == yy).astype(jnp.float32))
        return loss, acc

    @jax.jit
    def step(params, opt, node_ids):
        (loss, acc), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, node_ids
        )
        params, opt = adam_update(params, grads, opt, lr=args.lr)
        return params, opt, loss, acc

    all_nodes = jnp.arange(args.nodes)
    print(f"mode={args.mode}  |V|={args.nodes} |E|={keys.shape[0]} "
          f"feat={args.feat} hidden={args.hidden}")
    with db.activate():
        for epoch in range(args.epochs):
            t0 = time.time()
            if args.mode == "full":
                params, opt, loss, acc = step(params, opt, all_nodes)
            else:
                perm = np.random.default_rng(epoch).permutation(args.nodes)
                for i in range(0, args.nodes, args.batch):
                    ids = jnp.asarray(perm[i : i + args.batch])
                    params, opt, loss, acc = step(params, opt, ids)
            dt = time.time() - t0
            if epoch % 5 == 0 or epoch == args.epochs - 1:
                print(f"epoch {epoch:3d}  loss {float(loss):.4f}  "
                      f"acc {float(acc):.3f}  {dt*1e3:.0f} ms")
    assert float(acc) > 0.5, "training failed to learn"
    print("done.")


if __name__ == "__main__":
    main()
