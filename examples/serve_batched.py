"""Serving example: concurrent single-prompt requests through the async
serving front door — ``db.endpoint`` (serving/service.py).

The model is registered in the session catalog (``db.register_model``),
the endpoint is warmed (prefill compiles once per (batch, seq) bucket,
decode once per batch bucket), and then a burst of concurrent requests
is submitted. The endpoint coalesces them into bucketed batches
(continuous batching), decodes them as a slot pool with early release +
compaction, and the unified ``db.counters()`` tree shows what happened.

Run:  PYTHONPATH=src python examples/serve_batched.py [--arch gemma2-9b]
      [--requests 6] [--prompt-len 32] [--gen 16]
"""

import argparse
import asyncio
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro
from repro.configs import ARCH_IDS, get_config
from repro.data import batch_for
from repro.models import build_model


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="gemma2-9b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    seq = args.prompt_len

    # non-token inputs (frames/patches for encoder/vision archs) ride
    # along via the endpoint's make_batch hook; token-only archs skip it
    def make_batch(tokens):
        full = batch_for(cfg, int(tokens.shape[0]), seq, rng)
        full.pop("labels", None)
        full["tokens"] = tokens
        return full

    needs_extra = any(
        k not in ("tokens", "labels") for k in batch_for(cfg, 1, seq, rng)
    )

    db = repro.Database(max_cache_entries=16)
    db.register_model("lm", model, params)              # -> lm@v1
    ep = db.endpoint(
        "lm",
        cache_len=seq + (cfg.vis_seq or 0) + args.gen,
        buckets=[(1, seq), (2, seq), (args.requests, seq)],
        make_batch=make_batch if needs_extra else None,
    )

    t0 = time.time()
    ep.warmup(batch_fn=(lambda b, s: make_batch(
        jnp.zeros((b, s), jnp.int32))) if needs_extra else None)
    print(f"arch={args.arch} (reduced)  warmup {time.time() - t0:.1f}s "
          f"(prefill buckets {ep._prefills and len(next(iter(ep._prefills.values())).buckets)}, "
          f"decode buckets {ep.decode_buckets})")

    prompts = [
        rng.integers(0, cfg.vocab, size=seq) for _ in range(args.requests)
    ]

    async def burst():
        # concurrent submits: the endpoint coalesces whatever is in
        # flight into one bucketed prefill + slot-pooled decode
        return await asyncio.gather(*[
            ep.submit(p, max_new_tokens=args.gen - (i % 3))
            for i, p in enumerate(prompts)
        ])

    t0 = time.time()
    outs = asyncio.run(burst())
    dt = time.time() - t0
    n_tok = sum(len(o.token_ids) for o in outs)
    print(f"served {len(outs)} requests / {n_tok} tokens in {dt*1e3:.0f} ms "
          f"({n_tok / max(dt, 1e-9):,.0f} tok/s)")
    for o in outs[:2]:
        print(f"  {o.model} prompt={o.prompt_len} "
              f"latency={o.latency*1e3:.0f}ms ->",
              np.asarray(o.token_ids).tolist())

    c = db.counters()
    print("serve counters:", json.dumps(c["serve"], indent=1))
    assert c["serve"]["completed"] == args.requests
    assert c["serve"]["batches"] < args.requests    # coalescing happened
    for o in outs:
        ids = np.asarray(o.token_ids)
        assert np.all(ids >= 0) and np.all(ids < cfg.vocab)
    print("ok.")


if __name__ == "__main__":
    main()
