"""Batched serving example: prefill a batch of prompts through the
session-backed ``BatchServer`` (one compiled executable per (batch, seq)
bucket in the ``repro.Database`` cache, warmed up before traffic), then
decode tokens autoregressively from the KV cache — the `serve_step` the
decode dry-run shapes lower (one new token against a seq_len cache).

Run:  PYTHONPATH=src python examples/serve_batched.py [--arch gemma2-9b]
      [--batch 4] [--prompt-len 32] [--gen 16]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro
from repro.configs import ARCH_IDS, get_config
from repro.data import batch_for
from repro.models import build_model
from repro.serving import BatchServer, make_decode_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="gemma2-9b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    cache_len = args.prompt_len + (cfg.vis_seq or 0) + args.gen
    db = repro.Database(max_cache_entries=4)
    server = BatchServer(
        model, cache_len, db=db,
        buckets=[(args.batch, args.prompt_len)],
    )
    server.warmup(
        params,
        batch_fn=lambda b, s: {
            k: (jnp.zeros_like(v) if hasattr(v, "shape") else v)
            for k, v in batch_for(cfg, b, s, np.random.default_rng(1)).items()
            if k != "labels"
        },
    )
    decode = jax.jit(make_decode_step(model, db=db))

    batch = batch_for(cfg, args.batch, args.prompt_len, rng)
    batch.pop("labels", None)

    t0 = time.time()
    logits, caches = server.prefill(params, batch)
    print(f"serving cache after warmup+prefill: {server.cache_stats}")
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)      # (B, 1) greedy
    t_prefill = time.time() - t0
    print(f"arch={args.arch} (reduced)  batch={args.batch}  "
          f"prompt={args.prompt_len}  prefill {t_prefill*1e3:.0f} ms")

    enc_out = None
    if cfg.encoder_layers:
        enc_out = model._encode(params, batch["frames"])

    generated = [tok]
    length = jnp.asarray(args.prompt_len + (cfg.vis_seq or 0), jnp.int32)
    t0 = time.time()
    for i in range(args.gen - 1):
        if enc_out is not None:
            logits, caches = decode(params, tok, caches, length, enc_out)
        else:
            logits, caches = decode(params, tok, caches, length)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        generated.append(tok)
        length = length + 1
    t_decode = time.time() - t0
    out = jnp.concatenate(generated, axis=1)
    print(f"decoded {args.gen} tokens/seq in {t_decode*1e3:.0f} ms "
          f"({args.batch * args.gen / max(t_decode, 1e-9):,.0f} tok/s batched)")
    print("generated token ids (first sequence):", np.asarray(out[0]).tolist())
    assert out.shape == (args.batch, args.gen)
    assert np.all(np.asarray(out) >= 0) and np.all(np.asarray(out) < cfg.vocab)
    print("ok.")


if __name__ == "__main__":
    main()
