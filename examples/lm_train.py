"""End-to-end LM training driver: train a ~100M-parameter OLMoE-family
model (the paper-technique-heavy MoE arch) for a few hundred steps on the
synthetic pipeline, with checkpointing. Every parameter-bearing matmul's
backward is an RA-autodiff-generated gradient query (via the relational
custom_vjp ops inside the model).

Presets:
  --preset smoke  2-layer d=256 model, 20 steps        (seconds, CI)
  --preset 100m   8-layer d=512 16-expert MoE ≈ 100M   (the real driver)

Run:  PYTHONPATH=src python examples/lm_train.py --preset smoke
      PYTHONPATH=src python examples/lm_train.py --preset 100m --steps 300
"""

import argparse
import time

import jax
import numpy as np

import repro
from repro.checkpoint import save_checkpoint
from repro.configs import get_config
from repro.data import synthetic_lm_batches
from repro.models import build_model
from repro.train import make_train_step
from repro.train.trainer import init_train_state


def make_cfg(preset: str):
    base = get_config("olmoe-1b-7b")
    if preset == "smoke":
        return base.reduced()
    # ~100M active-param MoE in the olmoe family
    return base.reduced(
        n_layers=8,
        d_model=512,
        n_heads=8,
        n_kv_heads=8,
        d_ff=1024,
        vocab=8192,
        n_experts=16,
        top_k=4,
        head_dim=64,
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=("smoke", "100m"), default="smoke")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()
    steps = args.steps or (20 if args.preset == "smoke" else 300)

    cfg = make_cfg(args.preset)
    model = build_model(cfg)
    state = init_train_state(model, jax.random.PRNGKey(0))
    n_params = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(state.params))
    print(f"preset={args.preset}  params={n_params/1e6:.1f}M  "
          f"layers={cfg.n_layers} d={cfg.d_model} experts={cfg.n_experts}")

    # One session for the run: the relational custom_vjp ops inside the
    # model plan/dispatch through it (pass mesh="host:2" etc. to shard).
    db = repro.Database()
    step_fn = make_train_step(model, lr=args.lr, database=db)
    batches = synthetic_lm_batches(cfg, args.batch, args.seq, seed=0)
    params, opt_state = state.params, state.opt_state

    t_start = time.time()
    first_loss = None
    for i in range(steps):
        t0 = time.time()
        params, opt_state, metrics = step_fn(params, opt_state, next(batches))
        loss = float(metrics["loss"])
        if first_loss is None:
            first_loss = loss
        if i % 10 == 0 or i == steps - 1:
            tok_s = args.batch * args.seq / (time.time() - t0)
            print(f"step {i:4d}  loss {loss:.4f}  aux {float(metrics['aux']):.4f}"
                  f"  {tok_s:,.0f} tok/s")
        if args.ckpt_every and (i + 1) % args.ckpt_every == 0:
            path = save_checkpoint(args.ckpt_dir, i + 1, params, opt_state)
            print(f"  checkpoint → {path}")
    wall = time.time() - t_start
    print(f"\n{steps} steps in {wall:.0f}s "
          f"({steps * args.batch * args.seq / wall:,.0f} tok/s avg)")
    assert np.isfinite(loss) and loss < first_loss, "loss did not improve"
    print(f"loss {first_loss:.3f} → {loss:.3f}  ok.")


if __name__ == "__main__":
    main()
