"""Quickstart: the paper's §2.3 running example, end to end — "simply load
the data into relational tables, auto-diff the SQL, and begin training".

Compile logistic-regression SQL to a functional-RA query, auto-
differentiate it with Algorithm 2 (relational reverse mode), and run
gradient descent where every gradient is produced by executing the
*generated gradient query* on the chunked compiler. Prints the forward
query plan, the generated gradient plan, and the training curve.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core import compiler, fra
from repro.core.autodiff import ra_autodiff
from repro.core.relation import DenseRelation
from repro.core.sql import compile_sql

LOGREG_SQL = """
mm   := SELECT Rx.row, SUM(multiply(Rx.val, theta.val))
        FROM Rx, theta WHERE Rx.col = theta.col GROUP BY Rx.row;
pred := SELECT mm.row, logistic(mm.val) FROM mm;
SELECT SUM(xent(pred.val, Ry.val)) FROM pred, Ry WHERE pred.row = Ry.row
"""


def logreg_query() -> fra.Query:
    """F_Loss from paper §2.3, compiled from SQL (F_MatMul, F_Predict,
    F_Loss as stacked views)."""
    return compile_sql(
        LOGREG_SQL,
        schema={"Rx": ("row", "col"), "theta": ("col",), "Ry": ("row",)},
        inputs=("theta",),
    )


def main() -> None:
    print("=== SQL input ===")
    print(LOGREG_SQL.strip())
    q = logreg_query()
    print("\n=== compiled forward query (F_Loss, paper §2.3) ===")
    print(q.pretty())

    prog = ra_autodiff(q)   # Algorithm 2 → gradient query per input
    print("\n=== RA-autodiff-generated gradient query (∂Q/∂theta) ===")
    print(prog.grads["theta"].pretty())

    # synthetic separable data
    n, m = 4096, 64
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    X = jax.random.normal(k1, (n, m))
    y = (X @ jax.random.normal(k2, (m,)) > 0).astype(jnp.float32)
    theta = jnp.zeros((m,))

    @jax.jit
    def step(theta):
        env = {
            "Rx": DenseRelation(X, 2),
            "Ry": DenseRelation(y, 1),
            "theta": DenseRelation(theta, 1),
        }
        loss, grads = compiler.grad_eval(prog, env)
        # loss is summed over n tuples — scale the step accordingly
        return theta - (1.0 / n) * grads["theta"].data, loss.data

    print("\n=== training (gradient = executed gradient query) ===")
    for i in range(50):
        theta, loss = step(theta)
        if i % 5 == 0 or i == 49:
            print(f"step {i:3d}   loss {float(loss)/n:.4f}")

    acc = float(jnp.mean(((X @ theta) > 0).astype(jnp.float32) == y))
    print(f"\ntrain accuracy: {acc:.3f}")
    assert acc > 0.9


if __name__ == "__main__":
    main()
