"""Quickstart: the paper's §2.3 running example, end to end — "simply load
the data into relational tables, auto-diff the SQL, and begin training".

Compile logistic-regression SQL to a functional-RA query, auto-
differentiate it with Algorithm 2 (relational reverse mode), and run
gradient descent where every gradient is produced by executing the
*generated gradient query* on the chunked compiler. Prints the forward
query plan, the generated gradient plan, and the training curve.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core import fra
from repro.core.autodiff import ra_autodiff
from repro.core.engine import RAEngine
from repro.core.relation import DenseRelation
from repro.core.sql import compile_sql

LOGREG_SQL = """
mm   := SELECT Rx.row, SUM(multiply(Rx.val, theta.val))
        FROM Rx, theta WHERE Rx.col = theta.col GROUP BY Rx.row;
pred := SELECT mm.row, logistic(mm.val) FROM mm;
SELECT SUM(xent(pred.val, Ry.val)) FROM pred, Ry WHERE pred.row = Ry.row
"""


def logreg_query() -> fra.Query:
    """F_Loss from paper §2.3, compiled from SQL (F_MatMul, F_Predict,
    F_Loss as stacked views)."""
    return compile_sql(
        LOGREG_SQL,
        schema={"Rx": ("row", "col"), "theta": ("col",), "Ry": ("row",)},
        inputs=("theta",),
    )


def main() -> None:
    print("=== SQL input ===")
    print(LOGREG_SQL.strip())
    q = logreg_query()
    print("\n=== compiled forward query (F_Loss, paper §2.3) ===")
    print(q.pretty())

    prog = ra_autodiff(q)   # Algorithm 2 → gradient query per input
    print("\n=== RA-autodiff-generated gradient query (∂Q/∂theta) ===")
    print(prog.grads["theta"].pretty())

    # synthetic separable data
    n, m = 4096, 64
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    X = jax.random.normal(k1, (n, m))
    y = (X @ jax.random.normal(k2, (m,)) > 0).astype(jnp.float32)
    theta = jnp.zeros((m,))

    # Staged pipeline (core/engine.py): the program is lowered once for
    # this environment signature, the planner picks a physical plan per
    # join, and the jitted Compiled step is reused every iteration.
    env = {
        "Rx": DenseRelation(X, 2),
        "Ry": DenseRelation(y, 1),
        "theta": DenseRelation(theta, 1),
    }
    engine = RAEngine(prog)
    compiled = engine.lower(env).compile()
    print("\n=== physical plans (planner.plan_query) ===")
    for nid, plan in compiled.plans.items():
        print(f"join #{nid}: {plan.kind}  costs={ {k: f'{v:.0f}' for k, v in plan.costs.items()} }")

    # Kernel dispatch (docs/kernels.md): each hot op was resolved against
    # the registry at lowering time — pallas on TPU, the jnp lowering by
    # default on CPU; pass dispatch="ref"/"interpret" to engine.lower to
    # route through the kernel packages' CPU tiers instead.
    print(f"\n=== kernel dispatch ({compiled.dispatch.describe()}) ===")
    for site, tier in sorted(compiled.resolutions.items()):
        print(f"{site}  ->  {tier}")

    print("\n=== training (gradient = compiled gradient query) ===")
    for i in range(50):
        loss, grads = compiled(env)
        # loss is summed over n tuples — scale the step accordingly
        theta = env["theta"].data - (1.0 / n) * grads["theta"].data
        env["theta"] = DenseRelation(theta, 1)
        if i % 5 == 0 or i == 49:
            print(f"step {i:3d}   loss {float(loss.data)/n:.4f}")
    print(f"graph lowerings over 50 steps: {engine.trace_count}")

    acc = float(jnp.mean(((X @ theta) > 0).astype(jnp.float32) == y))
    print(f"\ntrain accuracy: {acc:.3f}")
    assert acc > 0.9


if __name__ == "__main__":
    main()
