"""Quickstart: the paper's §2.3 running example, end to end — "simply load
the data into relational tables, auto-diff the SQL, and begin training".

Everything goes through the one front door, ``repro.Database``: load the
relations into the catalog (``db.put`` — schemas + tracked key-domain
statistics), compile the logistic-regression SQL against the catalog
(``db.sql``), and train on the handle's compiled gradient step
(``handle.step()`` — RA-autodiff + the staged engine underneath, plans
sourced from the catalog statistics). Prints the forward query plan, the
generated gradient plan, the planner's physical plans, the kernel
dispatch decisions, and the training curve.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

import repro
from repro.core.autodiff import ra_autodiff
from repro.core.sql import compile_sql

LOGREG_SQL = """
mm   := SELECT Rx.row, SUM(multiply(Rx.val, theta.val))
        FROM Rx, theta WHERE Rx.col = theta.col GROUP BY Rx.row;
pred := SELECT mm.row, logistic(mm.val) FROM mm;
SELECT SUM(xent(pred.val, Ry.val)) FROM pred, Ry WHERE pred.row = Ry.row
"""


def logreg_query():
    """F_Loss from paper §2.3, compiled from SQL (F_MatMul, F_Predict,
    F_Loss as stacked views) — standalone, for callers without a session."""
    return compile_sql(
        LOGREG_SQL,
        schema={"Rx": ("row", "col"), "theta": ("col",), "Ry": ("row",)},
        inputs=("theta",),
    )


def main() -> None:
    print("=== SQL input ===")
    print(LOGREG_SQL.strip())

    # synthetic separable data
    n, m = 4096, 64
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    X = jax.random.normal(k1, (n, m))
    y = (X @ jax.random.normal(k2, (m,)) > 0).astype(jnp.float32)

    # The session: a catalog of named relations with schemas and tracked
    # key-domain statistics, refreshed on every put.
    db = repro.Database()
    db.put("Rx", X, keys=("row", "col"))
    db.put("Ry", y, keys=("row",))
    db.put("theta", jnp.zeros((m,)), keys=("col",))
    print("\n=== catalog ===")
    for name in ("Rx", "Ry", "theta"):
        print(f"{name}: keys={db.schema(name)}  {db.stats(name)}")

    handle = db.sql(LOGREG_SQL, wrt=("theta",))
    print("\n=== compiled forward query (F_Loss, paper §2.3) ===")
    print(handle.query.pretty())
    print("\n=== RA-autodiff-generated gradient query (∂Q/∂theta) ===")
    print(ra_autodiff(handle.query).grads["theta"].pretty())

    # One compiled gradient step — lowered once for this catalog
    # signature, planned from the catalog statistics, jit-cached across
    # iterations (committed layouts auto-threaded: no plan-flapping).
    loss, grads = handle.step()
    print("\n=== physical plans (planner.plan_query, catalog statistics) ===")
    for nid, plan in handle.plans.items():
        print(f"join #{nid}: {plan.kind}  costs={ {k: f'{v:.0f}' for k, v in plan.costs.items()} }")

    # Kernel dispatch (docs/kernels.md): each hot op was resolved against
    # the registry at lowering time — pallas on TPU, the jnp lowering by
    # default on CPU; pass dispatch="ref"/"interpret" to Database() to
    # route through the kernel packages' CPU tiers instead.
    print("\n=== kernel dispatch ===")
    for site, tier in sorted(handle.resolutions.items()):
        print(f"{site}  ->  {tier}")

    print("\n=== training (gradient = compiled gradient query) ===")
    for i in range(50):
        loss, grads = handle.step()
        # loss is summed over n tuples — scale the step accordingly
        theta = db.get("theta").data - (1.0 / n) * grads["theta"].data
        db.put("theta", theta)   # refreshes the catalog entry + stats
        if i % 5 == 0 or i == 49:
            print(f"step {i:3d}   loss {float(loss.data)/n:.4f}")

    acc = float(jnp.mean(((X @ theta) > 0).astype(jnp.float32) == y))
    print(f"\ntrain accuracy: {acc:.3f}")
    assert acc > 0.9


if __name__ == "__main__":
    main()
