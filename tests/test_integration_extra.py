"""Extra integration coverage: SQL → planner pipeline, MoE expert-parallel
flag, serving consistency for sliding-window archs, and optimizer/config
plumbing added during §Perf work."""

from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.core import compiler, fra
from repro.core.planner import input_pspecs, plan_query
from repro.core.relation import DenseRelation
from repro.core.sql import compile_sql
from repro.data import batch_for
from repro.models import build_model
from repro.train import make_train_step
from repro.train.trainer import init_train_state

# whole-stack integration runs: CI's default lane skips these (-m "not slow")
pytestmark = pytest.mark.slow


def test_sql_query_through_planner():
    """The paper's matmul SQL goes through the distribution planner: big
    relations co-partition, the gradient-side joins inherit specs."""
    q = compile_sql(
        "SELECT A.row, B.col, SUM(matrix_multiply(A.mat, B.mat)) "
        "FROM A, B WHERE A.col = B.row GROUP BY A.row, B.col",
        schema={"A": ("row", "col"), "B": ("row", "col")},
        inputs=("A", "B"),
    )
    env = {
        "A": jax.ShapeDtypeStruct((512, 512, 256, 256), jnp.float32),
        "B": jax.ShapeDtypeStruct((512, 512, 256, 256), jnp.float32),
    }
    plans = plan_query(q, env, n_devices=256)
    assert len(plans) == 1
    (plan,) = plans.values()
    assert plan.kind == "copartition"
    specs = input_pspecs(q, plans)
    assert set(specs) == {"A", "B"}


@pytest.mark.parametrize("arch", ["olmoe-1b-7b", "deepseek-v3-671b"])
def test_moe_shard_experts_flag_neutral_on_values(arch):
    """moe_shard_experts only adds sharding constraints — on a single
    device the logits must be bit-identical."""
    cfg = get_config(arch).reduced()
    rng = np.random.default_rng(0)
    batch = batch_for(cfg, 2, 16, rng)

    outs = []
    for flag in (False, True):
        model = build_model(replace(cfg, moe_shard_experts=flag))
        params = model.init(jax.random.PRNGKey(7))
        logits, _ = model.train_logits(params, batch)
        outs.append(np.asarray(logits, dtype=np.float32))
    np.testing.assert_array_equal(outs[0], outs[1])


def test_remat_policy_dots_neutral_on_values():
    cfg = get_config("gemma2-9b").reduced()
    rng = np.random.default_rng(1)
    batch = batch_for(cfg, 2, 16, rng)
    losses = []
    for policy in ("nothing", "dots"):
        model = build_model(replace(cfg, remat=True, remat_policy=policy))
        state = init_train_state(model, jax.random.PRNGKey(8))
        step = make_train_step(model)
        _, _, m = step(state.params, state.opt_state, batch)
        losses.append(float(m["loss"]))
    assert losses[0] == pytest.approx(losses[1], rel=1e-6)


def test_gemma3_prefill_decode_consistency_sliding_window():
    """Sliding-window + global alternation: greedy continuation from the
    cache matches the full-sequence forward."""
    cfg = get_config("gemma3-4b").reduced()
    model = build_model(cfg)
    rng = np.random.default_rng(2)
    batch = batch_for(cfg, 1, 8, rng)
    params = model.init(jax.random.PRNGKey(9))

    logits_full, _ = model.train_logits(params, batch)
    lp, caches = model.prefill(params, batch, cache_len=16)
    np.testing.assert_allclose(
        np.asarray(lp[:, 0], np.float32),
        np.asarray(logits_full[:, -1], np.float32),
        rtol=2e-2, atol=2e-3,
    )


def test_ssm_pallas_flag_close_to_default():
    """The Pallas scan path (interpret mode on CPU) agrees with the XLA
    parallel-prefix path through the full falcon-mamba block stack."""
    cfg = get_config("falcon-mamba-7b").reduced()
    rng = np.random.default_rng(3)
    batch = batch_for(cfg, 1, 32, rng)
    m0 = build_model(replace(cfg, ssm_pallas=False))
    m1 = build_model(replace(cfg, ssm_pallas=True))
    params = m0.init(jax.random.PRNGKey(10))
    l0, _ = m0.train_logits(params, batch)
    l1, _ = m1.train_logits(params, batch)
    np.testing.assert_allclose(
        np.asarray(l0, np.float32), np.asarray(l1, np.float32),
        rtol=1e-3, atol=1e-4,
    )
