"""Pallas selective-scan kernel vs the pure-jnp oracle: shape/dtype sweep
in interpret mode + custom-VJP gradients vs JAX AD of the oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ssm_scan import ssm_scan, ssm_scan_ref


def _rand(shape, dtype, seed=0, decay=False):
    rng = np.random.default_rng(seed)
    if decay:
        x = rng.uniform(0.3, 1.0, size=shape)
    else:
        x = rng.normal(size=shape)
    return jnp.asarray(x, dtype=dtype)


@pytest.mark.parametrize(
    "b,s,c,n", [(1, 32, 8, 4), (2, 128, 16, 16), (3, 64, 24, 8)]
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssm_scan_matches_ref(b, s, c, n, dtype):
    a = _rand((b, s, c, n), dtype, seed=1, decay=True)
    x = _rand((b, s, c, n), dtype, seed=2)
    got = ssm_scan(a, x, 32, 8, True, True)
    ref = ssm_scan_ref(a, x)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(ref, np.float32),
        rtol=tol, atol=tol,
    )


@pytest.mark.parametrize("bt,bc", [(8, 4), (16, 8), (64, 24)])
def test_ssm_scan_tile_shapes(bt, bc):
    a = _rand((2, 64, 24, 4), jnp.float32, seed=3, decay=True)
    x = _rand((2, 64, 24, 4), jnp.float32, seed=4)
    got = ssm_scan(a, x, bt, bc, True, True)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ssm_scan_ref(a, x)), rtol=1e-5, atol=1e-5
    )


def test_ssm_scan_custom_vjp_matches_jax_ad():
    a = _rand((1, 32, 8, 4), jnp.float32, seed=5, decay=True)
    x = _rand((1, 32, 8, 4), jnp.float32, seed=6)

    def loss_k(a, x):
        return jnp.sum(jnp.tanh(ssm_scan(a, x, 16, 8, True, True)))

    def loss_r(a, x):
        return jnp.sum(jnp.tanh(ssm_scan_ref(a, x)))

    gk = jax.grad(loss_k, argnums=(0, 1))(a, x)
    gr = jax.grad(loss_r, argnums=(0, 1))(a, x)
    for k, r in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(k), np.asarray(r), rtol=1e-4, atol=1e-5)


def test_ssm_scan_indivisible_shapes_fall_back():
    """Tile shrinking handles non-power-of-two sequence lengths."""
    a = _rand((1, 48, 6, 4), jnp.float32, seed=7, decay=True)
    x = _rand((1, 48, 6, 4), jnp.float32, seed=8)
    got = ssm_scan(a, x, 32, 8, True, True)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ssm_scan_ref(a, x)), rtol=1e-5, atol=1e-5
    )