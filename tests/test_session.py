"""Database session API: catalog + tracked statistics + QueryHandle as
the one front door. Covers the catalog (schemas, statistics refreshed on
put, donated-buffer guard), SQL/FRA round trips, statistics-driven plan
changes vs the heuristic fallback (the acceptance "skewed key domain
flips the join plan"), the committed-layout plan-stability guarantee
(bit-identical plans, reshard counters flat at zero), the per-(cache entry,
relation) ReshardWarning regression, the serving batch cache, and the
deprecation shims."""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

import repro
from repro.core import compiler, fra, session
from repro.core.autodiff import ra_autodiff
from repro.core.engine import ReshardWarning, engine_for
from repro.core.kernels import ADD, MATMUL, MUL
from repro.core.keys import L, R, eq_pred, identity_key, jproj, project_key
from repro.core.planner import MeshGeometry, RelationStats, plan_query
from repro.core.relation import (
    CooRelation,
    DenseRelation,
    measure_stats,
    owner_partition,
)
from repro.core.sql import compile_sql
from repro.launch.mesh import make_host_mesh

requires8 = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs 8 devices (tier1-spmd lane: "
    "XLA_FLAGS=--xla_force_host_platform_device_count=8)",
)

LOGREG_SQL = """
mm   := SELECT Rx.row, SUM(multiply(Rx.val, theta.val))
        FROM Rx, theta WHERE Rx.col = theta.col GROUP BY Rx.row;
pred := SELECT mm.row, logistic(mm.val) FROM mm;
SELECT SUM(xent(pred.val, Ry.val)) FROM pred, Ry WHERE pred.row = Ry.row
"""


def _logreg_db(n=64, m=8, seed=0):
    rng = np.random.default_rng(seed)
    X = jnp.asarray(rng.normal(size=(n, m)), jnp.float32)
    y = jnp.asarray((rng.uniform(size=n) > 0.5), jnp.float32)
    theta = jnp.asarray(rng.normal(size=m) * 0.1, jnp.float32)
    db = repro.Database()
    db.put("Rx", X, keys=("row", "col"))
    db.put("Ry", y, keys=("row",))
    db.put("theta", theta, keys=("col",))
    return db


# ---------------------------------------------------------------------------
# Catalog: schemas, statistics, guards
# ---------------------------------------------------------------------------


def test_catalog_put_wraps_arrays_and_tracks_schema():
    db = repro.Database()
    db.put("A", jnp.zeros((4, 3)), keys=("i", "j"))
    rel = db.get("A")
    assert isinstance(rel, DenseRelation) and rel.key_arity == 2
    assert db.schema("A") == ("i", "j")
    # chunked: two key dims, the rest chunk
    db.put("B", jnp.zeros((4, 3, 8, 8)), keys=("bi", "bj"))
    assert db.get("B").chunk_shape == (8, 8)
    # update without keys keeps the declared schema
    db.put("A", jnp.ones((4, 3)), key_arity=2)
    assert db.schema("A") == ("i", "j")


def test_catalog_stats_dense_and_coo():
    db = repro.Database()
    db.put("A", jnp.zeros((4, 6)), keys=("i", "j"))
    st = db.stats("A")
    assert (st.distinct, st.extents, st.nnz, st.density) == (
        (4, 6), (4, 6), 24, 1.0
    )
    # per-column equi-width histograms: a dense grid spreads uniformly
    assert st.hist is not None and len(st.hist) == 2
    assert sum(st.hist[0]) == 24 and sum(st.hist[1]) == 24
    # COO: distinct counted over live rows, padding excluded
    keys = jnp.asarray([[0, 1], [0, 1], [1, 1], [2, 1]], jnp.int32)
    coo = CooRelation(keys, jnp.ones((4,), jnp.float32), (8, 8))
    db.put("E", coo, keys=("src", "dst"))
    st = db.stats("E")
    assert st.distinct == (3, 1) and st.nnz == 4
    assert st.density == pytest.approx(4 / 64)
    part = owner_partition(coo, num_shards=3, dim=1)  # pads to 6 rows
    assert measure_stats(part).nnz == 4  # pad rows are not live tuples


def test_catalog_missing_and_donated_guards():
    db = _logreg_db()
    with pytest.raises(repro.CatalogError, match="Zz"):
        db.get("Zz")
    handle = db.query(compile_sql(
        LOGREG_SQL,
        schema={"Rx": ("row", "col"), "theta": ("col",), "Ry": ("row",)},
        inputs=("theta",),
    ))
    loss, grads = handle.step(donate=("theta",))
    with pytest.raises(repro.CatalogError, match="donated"):
        db.get("theta")
    db.put("theta", jnp.zeros((8,)), keys=("col",))  # re-put clears it
    assert db.get("theta").key_arity == 1


# ---------------------------------------------------------------------------
# SQL / FRA round trips through the handle
# ---------------------------------------------------------------------------


def test_db_sql_matches_fra_built_program():
    db = _logreg_db()
    handle = db.sql(LOGREG_SQL, wrt=("theta",))
    loss, grads = handle.step()

    # oracle: the same SQL compiled standalone, run through the eager path
    q = compile_sql(
        LOGREG_SQL,
        schema={"Rx": ("row", "col"), "theta": ("col",), "Ry": ("row",)},
        inputs=("theta",),
    )
    prog = ra_autodiff(q)
    env = {n: db.get(n) for n in ("Rx", "Ry", "theta")}
    out_ref, grads_ref = compiler.grad_eval(prog, env)
    np.testing.assert_allclose(
        np.asarray(loss.data), np.asarray(out_ref.data), rtol=1e-5
    )
    np.testing.assert_allclose(
        np.asarray(grads["theta"].data),
        np.asarray(grads_ref["theta"].data),
        rtol=1e-5,
        atol=1e-6,
    )
    # forward() alone agrees too
    fwd = handle.forward()
    np.testing.assert_allclose(
        np.asarray(fwd.data), np.asarray(out_ref.data), rtol=1e-5
    )


def test_db_query_fra_handle_grad_and_wrt():
    db = repro.Database()
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.normal(size=(2, 2, 4, 4)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(2, 2, 4, 4)), jnp.float32)
    db.put("A", a, keys=("row", "col"))
    db.put("B", b, keys=("row", "col"))
    join = fra.Join(
        eq_pred((1, 0)), jproj(L(0), L(1), R(1)), MATMUL,
        fra.scan("A", 2), fra.scan("B", 2),
    )
    q = fra.Query(fra.Agg(project_key(0, 2), ADD, join), inputs=("A", "B"))
    handle = db.query(q)
    out = handle.forward()
    assert out.key_arity == 2
    seed = DenseRelation(jnp.ones_like(out.data), 2)
    grads = handle.grad(wrt=("A",), seed=seed)
    assert set(grads) == {"A"}
    with pytest.raises(ValueError, match="no gradient for"):
        handle.grad(wrt=("C",), seed=seed)
    with pytest.raises(ValueError, match="cannot donate"):
        handle.step(donate=("C",), seed=seed)


def test_query_handle_lowered_once_across_steps():
    db = _logreg_db()
    handle = db.sql(LOGREG_SQL, wrt=("theta",))
    handle.step()
    eng = engine_for(handle._program(None))
    walks = eng.trace_count
    for _ in range(3):
        loss, grads = handle.step()
        db.put(
            "theta",
            db.get("theta").data - 0.01 * grads["theta"].data,
        )
    assert eng.trace_count == walks  # catalog puts did not re-lower


# ---------------------------------------------------------------------------
# Statistics-driven planning (the acceptance plan flips)
# ---------------------------------------------------------------------------

GEO = MeshGeometry("model", 2, ("data",), 4)


def _skew_query_env():
    """A(i,j) ⋈ B(j) with Σ dropping the batch key i — the heuristic
    assumes the Σ shrinks the output 8×; a skewed (2-wide) i domain
    makes it only 2×, which reprices every psum."""
    join = fra.Join(
        eq_pred((1, 0)), jproj(L(0)), MUL,
        fra.scan("A", 2), fra.scan("B", 1),
    )
    q = fra.Query(fra.Agg(project_key(), ADD, join), inputs=("A", "B"))
    env = {
        "A": DenseRelation(jnp.zeros((2, 64, 512), jnp.float32), 2),
        "B": DenseRelation(jnp.zeros((64, 512), jnp.float32), 1),
    }
    return q, env


def test_skewed_key_domain_flips_the_plan_vs_heuristic():
    """Acceptance: tracked key-domain statistics change the chosen join
    plan relative to the 1/8-per-dropped-key fallback."""
    q, env = _skew_query_env()
    (p_heur,) = plan_query(q, env, 2, geometry=GEO).values()
    stats = {n: measure_stats(r) for n, r in env.items()}
    (p_stat,) = plan_query(q, env, 2, geometry=GEO, stats=stats).values()
    # the measured Σ output (child/2, not child/8) makes the co-partition
    # psum 4× dearer: the model-axis plan flips to broadcasting B
    assert p_heur.kind == "copartition"
    assert p_stat.kind == "broadcast_right"
    assert p_stat.costs["copartition"] > p_heur.costs["copartition"]
    # absent stats entries keep the old plans bit-for-bit
    (p_none,) = plan_query(q, env, 2, geometry=GEO, stats={}).values()
    assert p_none == p_heur


def test_skewed_catalog_flips_plan_through_the_database():
    """The same flip through the front door: two sessions differing only
    in catalog statistics choose different plans."""
    q, env = _skew_query_env()
    db = repro.Database()
    db.put("A", env["A"].data, keys=("i", "j"))
    db.put("B", env["B"].data, keys=("j",))
    handle = db.query(q)
    plans_stat = handle.plan(geometry=GEO)
    plans_heur = handle.plan(geometry=GEO, use_stats=False)
    (p_stat,), (p_heur,) = plans_stat.values(), plans_heur.values()
    assert p_heur.kind == "copartition"
    assert p_stat.kind == "broadcast_right"


def test_skewed_coo_owner_domain_flips_nnz_sharding():
    """A skewed (tiny) dst domain prices the Σ-over-edges scatter near
    the full all-reduce instead of EDGE_CUT_LOCAL, flipping the data-axis
    placement from nnz sharding to replication."""
    nnz = 20_000
    edges = owner_partition(
        CooRelation(
            jnp.zeros((nnz, 2), jnp.int32),
            jnp.zeros((nnz,), jnp.float32),
            (64, 64),
        ),
        num_shards=4,
        dim=1,
    )
    gq = fra.Query(
        fra.Agg(identity_key(1), ADD, fra.Join(
            eq_pred((0, 0)), jproj(L(1)), MUL,
            fra.scan("Edge", 2), fra.scan("Node", 1),
        )),
        inputs=("Edge", "Node"),
    )
    # a wide feature grid: the Σ's segment output is what the scatter
    # moves, so the edge-cut fraction decides the placement
    genv = {"Edge": edges, "Node": DenseRelation(jnp.zeros((64, 4096), jnp.float32), 1)}
    (p_heur,) = plan_query(gq, genv, 2, geometry=GEO).values()
    assert p_heur.data_kind == "data:shard_nnz_left"
    skew = RelationStats(
        distinct=(64, 2), extents=(64, 64), nnz=nnz, density=nnz / 4096
    )
    (p_stat,) = plan_query(
        gq, genv, 2, geometry=GEO, stats={"Edge": skew}
    ).values()
    assert p_stat.data_kind == "data:replicate"
    # a wide owner domain keeps (and re-prices) the nnz sharding
    wide = RelationStats(
        distinct=(64, 64), extents=(64, 64), nnz=nnz, density=nnz / 4096
    )
    (p_wide,) = plan_query(
        gq, genv, 2, geometry=GEO, stats={"Edge": wide}
    ).values()
    assert p_wide.data_kind == "data:shard_nnz_left"
    assert (
        p_wide.costs["data:shard_nnz_left"]
        < p_heur.costs["data:shard_nnz_left"]
    )  # measured cut 3/64 < the 1/8 constant


def test_edge_cut_statistic():
    st = RelationStats(distinct=(64, 16), extents=(64, 64), nnz=1000)
    assert st.edge_cut(1, 1) == 0.0
    assert st.edge_cut(1, 4) == pytest.approx(3 / 16)
    skew = RelationStats(distinct=(64, 2), extents=(64, 64), nnz=1000)
    assert skew.edge_cut(1, 4) == 1.0  # clamped at the full scatter


# ---------------------------------------------------------------------------
# Committed layouts: plan stability (acceptance) + per-relation warnings
# ---------------------------------------------------------------------------


def test_plan_stability_two_calls_bit_identical_no_reshard():
    """Acceptance: two consecutive calls on a committed-layout env
    produce bit-identical plans (the same Compiled, equal JoinPlans) with
    zero resharded bytes on the second call."""
    db = _logreg_db()
    db.use_mesh(make_host_mesh())
    handle = db.sql(LOGREG_SQL, wrt=("theta",))
    loss1, grads1 = handle.step()
    first = handle.last
    plans1 = dict(first.plans)
    # commit the parameter to the layout the plan itself chose — the
    # steady state once step outputs feed the next call
    spec = first.planned_spec("theta")
    theta = jax.device_put(
        db.get("theta").data, NamedSharding(db.mesh, spec)
    )
    db.put("theta", theta)
    with warnings.catch_warnings():
        warnings.simplefilter("error", ReshardWarning)  # no silent reshard
        loss2, grads2 = handle.step()
    second = handle.last
    assert second is first                       # the recorded plan is reused
    assert dict(second.plans) == plans1          # bit-identical plans
    assert second.counters["reshard"]["last_call_bytes"] == 0
    np.testing.assert_allclose(
        np.asarray(loss2.data), np.asarray(loss1.data), rtol=1e-6
    )
    # the catalog records the committed layout
    assert db.layout("theta") == spec


def test_compile_auto_replans_on_foreign_layout():
    """An input committed to a *different* layout than the recorded plan
    triggers exactly one re-plan (the rechunk is charged), after which
    the new record is stable."""
    rng = np.random.default_rng(3)
    env = {
        "A": DenseRelation(jnp.asarray(rng.normal(size=(4, 4, 8, 8)), jnp.float32), 2),
        "B": DenseRelation(jnp.asarray(rng.normal(size=(4, 4, 8, 8)), jnp.float32), 2),
    }
    join = fra.Join(
        eq_pred((1, 0)), jproj(L(0), L(1), R(1)), MATMUL,
        fra.scan("A", 2), fra.scan("B", 2),
    )
    q = fra.Query(fra.Agg(project_key(0, 2), ADD, join), inputs=("A", "B"))
    mesh = make_host_mesh()
    low = engine_for(q).lower(env)
    c1 = low.compile_auto(env, mesh=mesh)
    assert low.compile_auto(env, mesh=mesh) is c1  # uncommitted: stable
    env2 = dict(env)
    foreign = NamedSharding(mesh, P(None, "model"))
    env2["A"] = DenseRelation(jax.device_put(env["A"].data, foreign), 2)
    c2 = low.compile_auto(env2, mesh=mesh)
    if c2 is not c1:  # a 1-device mesh has only one (replicated) layout
        assert low.compile_auto(env2, mesh=mesh) is c2


@pytest.mark.spmd
@requires8
def test_reshard_warning_once_per_cache_entry_and_relation():
    """Regression: a second offending relation warns too — ReshardWarning
    fires once per (cache entry, relation), not once per cache entry."""
    mesh = make_host_mesh(model=2)
    rng = np.random.default_rng(6)
    n, m = 64, 8
    env = {
        "A": DenseRelation(jnp.asarray(rng.normal(size=(n, n, m, m)), jnp.float32), 2),
        "B": DenseRelation(jnp.asarray(rng.normal(size=(n, n, m, m)), jnp.float32), 2),
    }
    join = fra.Join(
        eq_pred((1, 0)), jproj(L(0), L(1), R(1)), MATMUL,
        fra.scan("A", 2), fra.scan("B", 2),
    )
    q = fra.Query(fra.Agg(project_key(0, 2), ADD, join), inputs=("A", "B"))
    comp = engine_for(q).lower(env).compile(mesh=mesh)
    wrong = NamedSharding(mesh, P(None, None, "model", None))
    env_wrong = dict(env)
    env_wrong["A"] = DenseRelation(jax.device_put(env["A"].data, wrong), 2)
    env_wrong["B"] = DenseRelation(jax.device_put(env["B"].data, wrong), 2)
    with pytest.warns(ReshardWarning) as rec:
        comp(env_wrong)
    hits = {w.message.relation for w in rec if isinstance(w.message, ReshardWarning)}
    assert hits == {"A", "B"}          # both offenders named, same entry
    for w in rec:
        if isinstance(w.message, ReshardWarning):
            assert w.message.bytes_moved == int(env["A"].data.nbytes)
    # second call with the same relations: already reported, stays quiet
    with warnings.catch_warnings():
        warnings.simplefilter("error", ReshardWarning)
        comp(env_wrong)
    assert comp.counters["reshard"]["resharded_calls"] == 2


# ---------------------------------------------------------------------------
# Ambient session + deprecation shims
# ---------------------------------------------------------------------------


def test_ambient_session_stack():
    base = session.current()
    db = repro.Database()
    with db.activate():
        assert session.current() is db
        inner = repro.Database()
        with inner.activate():
            assert session.current() is inner
        assert session.current() is db
    assert session.current() is base


def test_relational_ops_run_through_ambient_session():
    from repro.relational import rel_matmul

    x = jnp.asarray(np.random.default_rng(0).normal(size=(4, 3)), jnp.float32)
    w = jnp.asarray(np.random.default_rng(1).normal(size=(3, 2)), jnp.float32)
    ref = np.asarray(x) @ np.asarray(w)
    with repro.Database().activate():
        out = rel_matmul(x, w)
    np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-5)


def test_front_door_shims_are_gone():
    """The deprecated pre-session shims (jit_execute / use_mesh /
    committed_layouts) were removed one release after the session API
    landed; RAEngine remains the warning-free library-level executor."""
    from repro.core import engine

    for shim in ("jit_execute", "use_mesh", "committed_layouts"):
        assert not hasattr(engine, shim), shim

    q = fra.Query(
        fra.Join(eq_pred(), jproj(), MATMUL, fra.scan("X", 0), fra.scan("W", 0)),
        inputs=("X", "W"),
    )
    env = {
        "X": DenseRelation(jnp.ones((2, 3)), 0),
        "W": DenseRelation(jnp.ones((3, 2)), 0),
    }
    # direct construction and the session path are both warning-free now
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        eng = engine.RAEngine(q)
        out = eng.lower(env).compile()(env)
        eng2 = engine.engine_for(q)
        repro.Database().execute(q, env)
    assert out.data.shape == (2, 2)
    assert eng2.source is q


# ---------------------------------------------------------------------------
# Serving batch cache (satellite)
# ---------------------------------------------------------------------------


class _StubModel:
    """Minimal Model stand-in: prefill returns per-token logits."""

    cfg = None

    def prefill(self, params, batch, cache_len):
        t = batch["tokens"]
        return t[..., None].astype(jnp.float32) * params, {"len": cache_len}


def test_bucketed_prefill_buckets_hits_and_evictions():
    from repro.serving import BucketedPrefill

    srv = BucketedPrefill(
        _StubModel(), cache_len=64,
        buckets=[(2, 16), (4, 32), (8, 64)], max_entries=2,
    )
    p = jnp.asarray(2.0)
    srv.warmup(p, buckets=[(2, 16), (4, 32)])
    assert srv.db.counters()["cache"] == {"hits": 0, "misses": 2, "evictions": 0}

    # smaller batch at a bucketed seq: a cache hit, batch-padded + sliced
    logits, _ = srv.prefill(p, {"tokens": jnp.ones((1, 16), jnp.int32)})
    assert logits.shape == (1, 16, 1)
    assert srv.db.counters()["cache"]["hits"] == 1
    np.testing.assert_allclose(np.asarray(logits), 2.0)

    # request needing the third bucket: a miss that evicts the LRU entry
    logits, _ = srv.prefill(p, {"tokens": jnp.ones((5, 64), jnp.int32)})
    assert logits.shape == (5, 64, 1)
    assert srv.db.counters()["cache"] == {"hits": 1, "misses": 3, "evictions": 1}

    # the evicted (4, 32) bucket misses again and evicts the next LRU
    srv.prefill(p, {"tokens": jnp.ones((4, 32), jnp.int32)})
    assert srv.db.counters()["cache"]["misses"] == 4
    assert srv.db.counters()["cache"]["evictions"] == 2

    with pytest.raises(ValueError, match="no bucket fits"):
        srv.prefill(p, {"tokens": jnp.ones((16, 64), jnp.int32)})
    # the sequence dim is never padded (last-position logits would score
    # the pad token): an unbucketed seq is refused, not rounded up
    with pytest.raises(ValueError, match="seq must match exactly"):
        srv.prefill(p, {"tokens": jnp.ones((2, 10), jnp.int32)})


def test_bucketed_prefill_shares_session_cache():
    from repro.serving import BucketedPrefill

    db = repro.Database(max_cache_entries=8)
    srv = BucketedPrefill(_StubModel(), cache_len=8, db=db)
    srv.prefill(jnp.asarray(1.0), {"tokens": jnp.zeros((1, 4), jnp.int32)})
    assert db.counters()["cache"]["misses"] == 1  # lives in the session's cache


@pytest.mark.spmd
@requires8
def test_plan_stability_on_2d_mesh():
    """Acceptance on the real 4×2 (data × model) host mesh: consecutive
    committed-layout steps reuse the recorded plan — bit-identical plans,
    zero resharded bytes, matching results."""
    db = _logreg_db(n=64, m=8, seed=9)
    db.use_mesh(make_host_mesh(model=2))
    handle = db.sql(LOGREG_SQL, wrt=("theta",))
    loss1, grads1 = handle.step()
    first = handle.last
    assert first.placements["Rx"] == {"data": 0, "model": 1}
    # commit every relation to the plan's own placement (steady state):
    # the catalog recorded each plan-committed layout
    from repro.launch.sharding import catalog_shardings

    placed = catalog_shardings(db)
    assert set(placed) == {"Rx", "Ry", "theta"}
    for name, sh in placed.items():
        db.put(name, jax.device_put(db.get(name).data, sh))
    with warnings.catch_warnings():
        warnings.simplefilter("error", ReshardWarning)
        loss2, grads2 = handle.step()
    assert handle.last is first
    assert dict(handle.last.plans) == dict(first.plans)
    assert handle.last.counters["reshard"]["last_call_bytes"] == 0
    np.testing.assert_allclose(
        np.asarray(loss2.data), np.asarray(loss1.data), atol=1e-5
    )


def test_bucketed_prefill_slices_cache_batch_for_sub_bucket_requests():
    """Regression: a request smaller than its bucket gets caches sliced
    back to the request batch (scan subtrees slice axis 1 — axis 0 is
    the stacked layer axis — everything else axis 0), so decode
    continues at the request batch instead of crashing on bucket-sized
    caches."""
    from repro.serving import BucketedPrefill

    class CacheStub:
        cfg = None

        def prefill(self, params, batch, cache_len):
            b = batch["tokens"].shape[0]
            caches = [{
                "scan": {"kv": {"k": jnp.zeros((3, b, cache_len, 2))}},
                "tail": [{"kv": {"v": jnp.zeros((b, cache_len, 2))}}],
            }]
            return batch["tokens"][..., None].astype(jnp.float32), caches

    srv = BucketedPrefill(CacheStub(), cache_len=8, buckets=[(4, 16)])
    logits, caches = srv.prefill(
        jnp.asarray(1.0), {"tokens": jnp.ones((2, 16), jnp.int32)}
    )
    assert logits.shape == (2, 16, 1)
    assert caches[0]["scan"]["kv"]["k"].shape == (3, 2, 8, 2)   # axis 1 cut
    assert caches[0]["tail"][0]["kv"]["v"].shape == (2, 8, 2)   # axis 0 cut
