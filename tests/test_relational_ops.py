"""The relational/ layer: custom_vjp ops whose backward is RA-generated.
Asserted against jax.grad of plain-JAX references, under jit."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.relational import gcn_conv, rel_embed, rel_linear, rel_matmul
from repro.relational.linear import rel_matmul_blocked

jax.config.update("jax_enable_x64", True)


def test_rel_matmul_forward_and_grads():
    rng = np.random.default_rng(0)
    x = jnp.array(rng.normal(size=(7, 5)))
    w = jnp.array(rng.normal(size=(5, 3)))
    np.testing.assert_allclose(np.asarray(rel_matmul(x, w)), np.asarray(x @ w), rtol=1e-12)

    def loss_rel(x, w):
        return jnp.sum(jnp.tanh(rel_matmul(x, w)) ** 2)

    def loss_ref(x, w):
        return jnp.sum(jnp.tanh(x @ w) ** 2)

    gx, gw = jax.grad(loss_rel, argnums=(0, 1))(x, w)
    rx, rw = jax.grad(loss_ref, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(rx), rtol=1e-10)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(rw), rtol=1e-10)


def test_rel_linear_batched_jit():
    rng = np.random.default_rng(1)
    x = jnp.array(rng.normal(size=(2, 9, 5)))
    w = jnp.array(rng.normal(size=(5, 4)))

    @jax.jit
    def f(x, w):
        return jax.grad(lambda w: jnp.sum(rel_linear(x, w) ** 2))(w)

    got = f(x, w)
    ref = jax.grad(lambda w: jnp.sum((x @ w) ** 2))(w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-10)


def test_rel_matmul_blocked_grads():
    rng = np.random.default_rng(2)
    x = jnp.array(rng.normal(size=(2, 3, 8, 4)))   # (BI,BK,bm,bk)
    w = jnp.array(rng.normal(size=(3, 2, 4, 16)))  # (BK,BJ,bk,bn)

    def loss_rel(x, w):
        return jnp.sum(rel_matmul_blocked(x, w) ** 2)

    def dense(x):
        return jnp.concatenate(
            [jnp.concatenate(list(r), axis=1) for r in x], axis=0
        )

    def loss_ref(x, w):
        return jnp.sum((dense(x) @ dense(w)) ** 2)

    g = jax.grad(loss_rel, argnums=(0, 1))(x, w)
    r = jax.grad(loss_ref, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(np.asarray(g[0]), np.asarray(r[0]), rtol=1e-9)
    np.testing.assert_allclose(np.asarray(g[1]), np.asarray(r[1]), rtol=1e-9)


def test_gcn_conv_grads_h_and_w():
    rng = np.random.default_rng(3)
    n, e, d = 12, 40, 6
    h = jnp.array(rng.normal(size=(n, d)))
    keys = jnp.array(
        np.stack([rng.integers(0, n, e), rng.integers(0, n, e)], axis=1),
        dtype=jnp.int32,
    )
    w = jnp.array(rng.normal(size=(e,)))
    src, dst = np.asarray(keys[:, 0]), np.asarray(keys[:, 1])

    def loss_rel(h, w):
        return jnp.sum(gcn_conv(h, keys, w) ** 2)

    def ref_conv(h, w):
        msg = w[:, None] * h[src]
        return jnp.zeros_like(h).at[dst].add(msg)

    def loss_ref(h, w):
        return jnp.sum(ref_conv(h, w) ** 2)

    gh, gw = jax.grad(loss_rel, argnums=(0, 1))(h, w)
    rh, rw = jax.grad(loss_ref, argnums=(0, 1))(h, w)
    np.testing.assert_allclose(np.asarray(gh), np.asarray(rh), rtol=1e-9)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(rw), rtol=1e-9)


def test_gcn_conv_jits():
    rng = np.random.default_rng(4)
    n, e, d = 8, 20, 4
    h = jnp.array(rng.normal(size=(n, d)))
    keys = jnp.array(
        np.stack([rng.integers(0, n, e), rng.integers(0, n, e)], axis=1),
        dtype=jnp.int32,
    )
    w = jnp.array(rng.normal(size=(e,)))
    out = jax.jit(gcn_conv)(h, keys, w)
    assert out.shape == (n, d)
    assert np.all(np.isfinite(np.asarray(out)))


def test_rel_embed_forward_and_grad():
    rng = np.random.default_rng(5)
    v, d, b = 11, 6, 9
    table = jnp.array(rng.normal(size=(v, d)))
    ids = jnp.array(rng.integers(0, v, size=b), dtype=jnp.int32)
    np.testing.assert_allclose(
        np.asarray(rel_embed(table, ids)), np.asarray(table[ids]), rtol=1e-12
    )

    def loss_rel(t):
        return jnp.sum(rel_embed(t, ids) ** 2)

    def loss_ref(t):
        return jnp.sum(t[ids] ** 2)

    g = jax.grad(loss_rel)(table)
    r = jax.grad(loss_ref)(table)
    np.testing.assert_allclose(np.asarray(g), np.asarray(r), rtol=1e-10)
