"""The engine-invariant linter (tools/lint_invariants.py): the real
repo lints clean, and each rule actually fires on a seeded violation in
a synthetic tree — a linter that never fails is indistinguishable from
one that checks nothing."""

import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "tools"))

import lint_invariants  # noqa: E402


def test_repo_lints_clean():
    violations = lint_invariants.run(REPO)
    assert violations == [], "\n".join(v.render() for v in violations)


def test_cli_exit_codes(tmp_path):
    proc = subprocess.run(
        [sys.executable, str(REPO / "tools" / "lint_invariants.py")],
        capture_output=True,
        text=True,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "ok" in proc.stdout
    # an empty tree has no files to lint — and no violations
    (tmp_path / "src" / "repro").mkdir(parents=True)
    proc = subprocess.run(
        [
            sys.executable,
            str(REPO / "tools" / "lint_invariants.py"),
            "--root",
            str(tmp_path),
        ],
        capture_output=True,
        text=True,
    )
    assert proc.returncode == 0


def _tree(tmp_path, rel, text):
    path = tmp_path / "src" / "repro" / rel
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(text)
    return tmp_path


def _rules(violations):
    return {v.rule for v in violations}


def test_seeded_stray_jit_detected(tmp_path):
    root = _tree(
        tmp_path,
        "core/compiler.py",
        "import jax\n\ndef f(x):\n    return jax.jit(lambda y: y)(x)\n",
    )
    assert "jit-scope" in _rules(lint_invariants.run(root))
    # the same code in an allowlisted module is fine
    root2 = _tree(
        tmp_path / "ok",
        "core/engine.py",
        "import jax\n\ndef f(x):\n    return jax.jit(lambda y: y)(x)\n",
    )
    assert "jit-scope" not in _rules(lint_invariants.run(root2))


def test_seeded_jnp_in_planner_detected(tmp_path):
    root = _tree(
        tmp_path,
        "core/planner.py",
        "import jax.numpy as jnp\n\ndef cost(x):\n    return jnp.sum(x)\n",
    )
    assert "planner-pure" in _rules(lint_invariants.run(root))
    root2 = _tree(
        tmp_path / "ok",
        "core/rewrite.py",
        "from jax.sharding import PartitionSpec\n",
    )
    assert "planner-pure" not in _rules(lint_invariants.run(root2))


def test_seeded_unhashable_cache_key_detected(tmp_path):
    root = _tree(
        tmp_path,
        "core/engine.py",
        "def _rel_signature(name, rel):\n"
        "    return {name: rel.key_arity}\n"
        "def env_signature(env, seed=None):\n"
        "    return tuple(sorted(env))\n"
        "def _stats_key(stats):\n"
        "    return None\n",
    )
    vs = lint_invariants.run(root)
    assert any(
        v.rule == "cache-key" and "_rel_signature" in v.message for v in vs
    )


def test_seeded_missing_tier_detected(tmp_path):
    root = _tree(
        tmp_path,
        "core/kernels.py",
        'DISPATCH_OPS = ("segment_sum",)\n'
        "def register_impl(op, tier, fn, **kw):\n"
        "    pass\n"
        'register_impl("segment_sum", "jnp", None)\n',
    )
    vs = lint_invariants.run(root)
    assert any(
        v.rule == "dispatch-pairing" and "pallas" in v.message for v in vs
    )


def test_seeded_missing_contract_detected(tmp_path):
    # an ops.py without CONTRACT = KernelContract(...) fires the rule
    root = _tree(
        tmp_path,
        "kernels/badkern/ops.py",
        "import jax\n\n"
        "@jax.custom_vjp\n"
        "def forward(x):\n    return x\n"
        "forward.defvjp(lambda x: (x, None), lambda r, g: (g,))\n",
    )
    (tmp_path / "src" / "repro" / "kernels" / "badkern" / "ref.py").write_text(
        "def forward(x):\n    return x\n"
    )
    vs = [v for v in lint_invariants.run(root) if v.rule == "kernel-contract"]
    assert any("CONTRACT" in v.message for v in vs)
    # declaring one silences it
    root2 = _tree(
        tmp_path / "ok",
        "kernels/goodkern/ops.py",
        "import jax\n"
        "from repro.core.kernels import KernelContract\n\n"
        "@jax.custom_vjp\n"
        "def forward(x):\n    return x\n"
        "forward.defvjp(lambda x: (x, None), lambda r, g: (g,))\n"
        'CONTRACT = KernelContract(op="goodkern", dtypes="floating")\n',
    )
    ok_dir = tmp_path / "ok" / "src" / "repro" / "kernels" / "goodkern"
    (ok_dir / "ref.py").write_text("def forward(x):\n    return x\n")
    assert "kernel-contract" not in _rules(lint_invariants.run(root2))


def test_seeded_contract_module_gap_detected(tmp_path):
    # a DISPATCH_OPS op absent from _CONTRACT_MODULES fires the rule
    root = _tree(
        tmp_path,
        "core/kernels.py",
        'DISPATCH_OPS = ("segment_sum", "blocked_matmul")\n'
        '_CONTRACT_MODULES = {"segment_sum": "repro.kernels.segsum.ops"}\n',
    )
    vs = [v for v in lint_invariants.run(root) if v.rule == "kernel-contract"]
    assert any("blocked_matmul" in v.message for v in vs)


def test_seeded_unpaired_kernel_forward_detected(tmp_path):
    root = _tree(
        tmp_path,
        "kernels/badkern/ops.py",
        "def forward(x):\n    return x\n",
    )
    vs = [v for v in lint_invariants.run(root) if v.rule == "dispatch-pairing"]
    msgs = " ".join(v.message for v in vs)
    assert "custom_vjp" in msgs and "defvjp" in msgs and "ref.py" in msgs


def test_seeded_fire_and_forget_task_detected(tmp_path):
    root = _tree(
        tmp_path,
        "serving/service.py",
        "import asyncio\n\n"
        "async def go(loop, coro):\n"
        "    loop.create_task(coro)\n",
    )
    vs = [v for v in lint_invariants.run(root) if v.rule == "task-retention"]
    msgs = " ".join(v.message for v in vs)
    assert "fire-and-forget" in msgs and "name=" in msgs
    # retained + named passes
    root2 = _tree(
        tmp_path / "ok",
        "serving/service.py",
        "import asyncio\n\n"
        "async def go(loop, coro):\n"
        '    t = loop.create_task(coro, name="x")\n'
        "    return t\n",
    )
    assert "task-retention" not in _rules(lint_invariants.run(root2))
