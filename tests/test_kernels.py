"""Per-kernel shape/dtype sweeps, interpret=True, allclose vs ref.py."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.matmul.ops import blocked_matmul
from repro.kernels.matmul.ref import matmul_ref
from repro.kernels.segsum.ops import segment_sum
from repro.kernels.segsum.ref import segment_sum_ref


@pytest.mark.parametrize(
    "m,k,n",
    [
        (128, 128, 128),
        (256, 128, 384),
        (128, 512, 128),
        (100, 70, 30),    # ragged -> exercises padding
        (1, 128, 128),
        (33, 257, 65),
    ],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_blocked_matmul_matches_ref(m, k, n, dtype):
    rng = np.random.default_rng(hash((m, k, n)) % 2**31)
    x = jnp.asarray(rng.normal(size=(m, k)), dtype=dtype)
    y = jnp.asarray(rng.normal(size=(k, n)), dtype=dtype)
    got = blocked_matmul(x, y, interpret=True)
    ref = matmul_ref(x, y)
    # f32 tolerance covers tiled-vs-monolithic accumulation-order drift.
    tol = 5e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(ref, np.float32), rtol=tol, atol=tol
    )


@pytest.mark.parametrize("bm,bn,bk", [(128, 128, 128), (256, 128, 128)])
def test_blocked_matmul_tile_shapes(bm, bn, bk):
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(size=(256, 256)), dtype=jnp.float32)
    y = jnp.asarray(rng.normal(size=(256, 256)), dtype=jnp.float32)
    got = blocked_matmul(x, y, bm=bm, bn=bn, bk=bk, interpret=True)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(matmul_ref(x, y)), rtol=5e-4, atol=5e-4
    )


@pytest.mark.parametrize(
    "e,d,s",
    [
        (512, 128, 128),
        (1000, 64, 100),   # ragged
        (512, 256, 256),
        (37, 16, 9),
    ],
)
def test_segment_sum_matches_ref(e, d, s):
    rng = np.random.default_rng(hash((e, d, s)) % 2**31)
    msg = jnp.asarray(rng.normal(size=(e, d)), dtype=jnp.float32)
    seg = jnp.asarray(rng.integers(0, s, size=e), dtype=jnp.int32)
    got = segment_sum(msg, seg, s, interpret=True)
    ref = segment_sum_ref(msg, seg, s)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-5, atol=1e-5)


def test_segment_sum_empty_segments():
    msg = jnp.ones((8, 4), dtype=jnp.float32)
    seg = jnp.zeros((8,), dtype=jnp.int32)  # all into segment 0
    got = segment_sum(msg, seg, 4, interpret=True)
    assert np.allclose(np.asarray(got)[0], 8.0)
    assert np.allclose(np.asarray(got)[1:], 0.0)
