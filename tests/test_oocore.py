"""Out-of-core chunked execution: the differential harness.

Every test here runs the same step twice — once in core (the oracle) and
once through a ``Database(memory_budget=...)`` session small enough to
force chunk-wave streaming — and asserts the results agree to 1e-5:

  * dense logistic regression (the paper's §2.3 SQL program): the data
    matrix streams, the labels co-stream with the same row boundaries,
    the parameters stay resident (gradient Σ-accumulated across waves);
  * a GCN conv step over an owner-partitioned COO edge relation: edge
    waves touch O(1) segment blocks, the padded last chunk rides the
    pad-and-mask contract;
  * a KGE-style bilinear score (two joins against the entity table).

Plus the control surfaces: budgets forcing 1/2/8-wave execution,
budget-too-small and unstreamable-query error paths, bit-identity with
an unconstrained budget, and the serving batch cache over a budgeted
session.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro
from repro.core import fra
from repro.core.chunkstore import ChunkStore, OutOfCoreError
from repro.core.engine import StreamedCompiled
from repro.core.kernels import (
    ADD, EXP, MUL, SQERR, SQUARE, SUM_CHUNK, scale_kernel,
)
from repro.core.keys import (
    EMPTY_KEY, TRUE, L, eq_pred, identity_key, jproj,
)
from repro.core.planner import plan_waves, _rel_bytes
from repro.core.relation import COO_PAD_KEY, CooRelation, DenseRelation
from repro.relational.gcn import partitioned_edges

ATOL = 1e-5

requires8 = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs 8 devices (tier1-oocore lane: "
    "XLA_FLAGS=--xla_force_host_platform_device_count=8)",
)

LOGREG_SQL = """
mm   := SELECT Rx.row, SUM(multiply(Rx.val, theta.val))
        FROM Rx, theta WHERE Rx.col = theta.col GROUP BY Rx.row;
pred := SELECT mm.row, logistic(mm.val) FROM mm;
SELECT SUM(xent(pred.val, Ry.val)) FROM pred, Ry WHERE pred.row = Ry.row
"""


# ---------------------------------------------------------------------------
# model builders: (db-filler, query, wrt) triples shared by all sweeps
# ---------------------------------------------------------------------------


def _logreg_fill(db, n=64, m=8, seed=0):
    rng = np.random.default_rng(seed)
    X = jnp.asarray(rng.normal(size=(n, m)), jnp.float32)
    y = jnp.asarray(
        (rng.uniform(size=n) > 0.5).astype(np.float32) * 0.98 + 0.01
    )
    theta = jnp.asarray(rng.normal(size=m) * 0.1, jnp.float32)
    db.put("Rx", X, keys=("row", "col"))
    db.put("Ry", y, keys=("row",))
    db.put("theta", theta, keys=("col",))
    return db


def _logreg_handle(db):
    return db.sql(LOGREG_SQL, wrt=("theta", "Rx", "Ry"))


def _logreg_bytes(n=64, m=8):
    return n * m * 4 + n * 4 + m * 4


def _gcn_query(n):
    conv = fra.Agg(
        identity_key(1), ADD,
        fra.Join(
            eq_pred((0, 0)), jproj(L(1)), MUL,
            fra.scan("Edge", 2), fra.scan("Node", 1),
        ),
    )
    sq = fra.Select(TRUE, identity_key(1), SQUARE, conv)
    loss = fra.Agg(
        EMPTY_KEY, ADD, fra.Select(TRUE, identity_key(1), SUM_CHUNK, sq)
    )
    mean = fra.Select(TRUE, identity_key(0), scale_kernel(1.0 / n), loss)
    return fra.Query(mean, inputs=("Edge", "Node"))


def _gcn_fill(db, n=60, e=500, d=8, seed=1, shards=4):
    rng = np.random.default_rng(seed)
    edge = partitioned_edges(
        np.stack([rng.integers(0, n, e), rng.integers(0, n, e)], 1),
        rng.normal(size=e).astype(np.float32),
        n,
        shards,
    )
    node = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    db.put("Edge", edge)
    db.put("Node", node, keys=("node",))
    return db


def _kge_query():
    # DistMult-flavoured bilinear score over triples (h, t) with weight w:
    #   loss = Σ_t Σ_d [ (Σ_h w_ht · Ent[h]) ⊙ Ent[t] ]_d
    conv = fra.Agg(
        identity_key(1), ADD,
        fra.Join(
            eq_pred((0, 0)), jproj(L(1)), MUL,
            fra.scan("Triple", 2), fra.scan("Ent", 1),
        ),
    )
    pair = fra.Join(
        eq_pred((0, 0)), jproj(L(0)), MUL, conv, fra.scan("Ent", 1)
    )
    sc = fra.Select(TRUE, identity_key(1), SUM_CHUNK, pair)
    return fra.Query(
        fra.Agg(EMPTY_KEY, ADD, sc), inputs=("Triple", "Ent")
    )


def _kge_fill(db, n=40, e=300, d=6, seed=3, partition=True):
    rng = np.random.default_rng(seed)
    keys = np.stack([rng.integers(0, n, e), rng.integers(0, n, e)], 1)
    vals = (rng.normal(size=e) * 0.3).astype(np.float32)
    if partition:
        triple = partitioned_edges(keys, vals, n, 4)
    else:
        triple = CooRelation(
            jnp.asarray(keys, jnp.int32), jnp.asarray(vals), (n, n)
        )
    db.put("Triple", triple)
    db.put("Ent", jnp.asarray(rng.normal(size=(n, d)), jnp.float32),
           keys=("ent",))
    return db


def _grad_close(g0, g1):
    assert set(g0) == set(g1)
    for name in g0:
        a, b = g0[name], g1[name]
        if isinstance(a, CooRelation):
            np.testing.assert_array_equal(
                np.asarray(a.keys), np.asarray(b.keys)
            )
            np.testing.assert_allclose(
                np.asarray(a.values), np.asarray(b.values), atol=ATOL
            )
        else:
            np.testing.assert_allclose(
                np.asarray(a.data), np.asarray(b.data), atol=ATOL
            )


# ---------------------------------------------------------------------------
# the differential harness: chunked ≡ in-core across models and budgets
# ---------------------------------------------------------------------------


def test_logreg_chunked_matches_incore_across_wave_counts():
    l0, g0 = _logreg_handle(_logreg_fill(repro.Database())).step()
    total = _logreg_bytes()
    # budgets sized so resident θ + the moving set needs 2 / 8 waves
    for budget, want_waves in [(total * 0.7, 2), (total * 0.15, 8)]:
        db = _logreg_fill(repro.Database(memory_budget=budget))
        h = _logreg_handle(db)
        l1, g1 = h.step()
        assert isinstance(h.last, StreamedCompiled)
        assert h.last.num_waves == want_waves
        np.testing.assert_allclose(
            np.asarray(l0.data), np.asarray(l1.data), atol=ATOL
        )
        _grad_close(g0, g1)
        # the data matrix streamed and the labels co-streamed with it
        assert h.last.plan.stream == "Rx"
        assert h.last.plan.co_streams == ("Ry",)
        st = db.counters()["spill"]
        assert st["spilled_relations"] == 2
        assert st["fetched_chunks"] == 2 * want_waves


def test_gcn_chunked_matches_incore():
    n = 60
    db0 = _gcn_fill(repro.Database(), n=n)
    l0, g0 = db0.query(_gcn_query(n)).step(wrt=("Edge", "Node"))
    total = _rel_bytes(db0.get("Edge")) + _rel_bytes(db0.get("Node"))
    db = _gcn_fill(repro.Database(memory_budget=total / 3), n=n)
    h = db.query(_gcn_query(n))
    l1, g1 = h.step(wrt=("Edge", "Node"))
    assert isinstance(h.last, StreamedCompiled)
    assert h.last.num_waves >= 2
    assert h.last.plan.owner_aligned  # owner-partitioned edge waves
    np.testing.assert_allclose(
        np.asarray(l0.data), np.asarray(l1.data), atol=ATOL
    )
    _grad_close(g0, g1)


@pytest.mark.parametrize("partition", [True, False])
def test_kge_chunked_matches_incore(partition):
    db0 = _kge_fill(repro.Database(), partition=partition)
    l0, g0 = db0.query(_kge_query()).step(wrt=("Triple", "Ent"))
    total = _rel_bytes(db0.get("Triple")) + _rel_bytes(db0.get("Ent"))
    db = _kge_fill(
        repro.Database(memory_budget=total / 2.5), partition=partition
    )
    h = db.query(_kge_query())
    l1, g1 = h.step(wrt=("Triple", "Ent"))
    assert isinstance(h.last, StreamedCompiled)
    assert h.last.num_waves >= 2
    np.testing.assert_allclose(
        np.asarray(l0.data), np.asarray(l1.data), atol=ATOL
    )
    _grad_close(g0, g1)


def test_forward_only_query_streams_too():
    n = 60
    db0 = _gcn_fill(repro.Database(), n=n)
    out0 = db0.query(_gcn_query(n)).forward()
    total = _rel_bytes(db0.get("Edge")) + _rel_bytes(db0.get("Node"))
    db = _gcn_fill(repro.Database(memory_budget=total / 3), n=n)
    h = db.query(_gcn_query(n))
    out1 = h.forward()
    assert isinstance(h.last, StreamedCompiled)
    np.testing.assert_allclose(
        np.asarray(out0.data), np.asarray(out1.data), atol=ATOL
    )


# ---------------------------------------------------------------------------
# bit-identity with no / unconstraining budget (the in-core fast path)
# ---------------------------------------------------------------------------


def test_unconstrained_budget_is_bit_identical_to_no_budget():
    db0 = _logreg_fill(repro.Database())
    h0 = _logreg_handle(db0)
    l0, g0 = h0.step()
    # a budget everything fits under: plan_waves returns None, the
    # session takes the exact pre-existing path (same plans, same bits)
    db1 = _logreg_fill(repro.Database(memory_budget=1 << 30))
    h1 = _logreg_handle(db1)
    l1, g1 = h1.step()
    assert not isinstance(h1.last, StreamedCompiled)
    # node ids differ between independently-built handles; the chosen
    # physical plans must not
    assert sorted(p.kind for p in h1.last.plans.values()) == sorted(
        p.kind for p in h0.last.plans.values()
    )
    np.testing.assert_array_equal(np.asarray(l0.data), np.asarray(l1.data))
    for name in g0:
        np.testing.assert_array_equal(
            np.asarray(g0[name].data), np.asarray(g1[name].data)
        )
    assert db1.counters()["spill"] == {
        "spilled_relations": 0, "spilled_bytes": 0,
        "fetched_chunks": 0, "fetched_bytes": 0,
    }


# ---------------------------------------------------------------------------
# error paths: too-small budgets and unstreamable queries
# ---------------------------------------------------------------------------


def test_budget_smaller_than_resident_raises():
    db = _gcn_fill(repro.Database(memory_budget=64.0))
    # resident Node alone exceeds 64 bytes: no wave count can help
    with pytest.raises(OutOfCoreError, match="too small"):
        db.query(_gcn_query(60)).step(wrt=("Node",))


def test_budget_needing_more_waves_than_rows_raises():
    # resident θ holds 8 of the 18 bytes: 10 B of headroom needs more
    # waves than Rx has rows
    db = _logreg_fill(repro.Database(memory_budget=18.0), n=16, m=2)
    with pytest.raises(OutOfCoreError, match="waves|too small"):
        _logreg_handle(db).step()


def test_donation_under_streaming_raises():
    total = _logreg_bytes()
    db = _logreg_fill(repro.Database(memory_budget=total * 0.5))
    with pytest.raises(OutOfCoreError, match="donate"):
        _logreg_handle(db).step(donate=("theta",))


def test_unstreamable_query_names_the_offending_node():
    # exp is neither linear nor zero-preserving: a Σ-partial passing
    # through it cannot merge additively across waves
    n = 32
    sq = fra.Agg(
        EMPTY_KEY, ADD,
        fra.Select(TRUE, identity_key(1), SUM_CHUNK, fra.scan("X", 1)),
    )
    bad = fra.Select(TRUE, identity_key(0), EXP, sq)
    q = fra.Query(bad, inputs=("X",))
    rng = np.random.default_rng(0)
    db = repro.Database(memory_budget=n * 4 * 8 * 0.5)
    db.put("X", jnp.asarray(rng.normal(size=(n, 8)), jnp.float32),
           keys=("i",))
    with pytest.raises(OutOfCoreError, match="exp"):
        db.query(q).forward()


# ---------------------------------------------------------------------------
# chunk store mechanics
# ---------------------------------------------------------------------------


def test_chunkstore_spill_fetch_counters_and_idempotence():
    rng = np.random.default_rng(5)
    rel = DenseRelation(jnp.asarray(rng.normal(size=(12, 3)), jnp.float32), 1)
    store = ChunkStore()
    mani = store.spill("A", rel, 3)
    assert mani.num_chunks == 3 and "A" in store
    assert store.stats["spilled_relations"] == 1
    spilled = store.stats["spilled_bytes"]
    assert spilled == 12 * 3 * 4
    # same manifest again: a no-op, counters unchanged
    store.spill("A", rel, mani)
    assert store.stats["spilled_bytes"] == spilled
    parts = [store.fetch("A", w) for w in range(3)]
    assert store.stats["fetched_chunks"] == 3
    assert store.stats["fetched_bytes"] == spilled
    np.testing.assert_array_equal(
        np.concatenate([np.asarray(p.data) for p in parts]),
        np.asarray(rel.data),
    )
    store.drop("A")
    assert "A" not in store and store.stats["spilled_bytes"] == 0


def test_plan_waves_none_without_budget_or_pressure():
    db = _logreg_fill(repro.Database())
    env = {n: db.get(n) for n in ("Rx", "Ry", "theta")}
    q = _logreg_handle(db).query
    assert plan_waves(q, env, None) is None
    assert plan_waves(q, env, 1e12) is None
    wp = plan_waves(q, env, _logreg_bytes() * 0.5)
    assert wp is not None and wp.num_waves >= 2
    assert wp.streamed_names == ("Rx", "Ry")


# ---------------------------------------------------------------------------
# serving over a budgeted session
# ---------------------------------------------------------------------------


class _StubModel:
    cfg = None

    def prefill(self, params, batch, cache_len):
        t = batch["tokens"]
        return t[..., None].astype(jnp.float32) * params, {"len": cache_len}


def test_bucketed_prefill_warmup_with_spilled_relations():
    from repro.serving import BucketedPrefill

    total = _logreg_bytes()
    db = _logreg_fill(repro.Database(memory_budget=total * 0.5))
    # a training step spills + streams through the same session…
    _logreg_handle(db).step()
    assert db.counters()["spill"]["spilled_relations"] == 2
    # …and the serving cache on top of it behaves exactly as unbudgeted:
    # warmup compiles per bucket, repeats hit, the counters match
    srv = BucketedPrefill(
        _StubModel(), cache_len=16, db=db, buckets=[(2, 8), (4, 16)]
    )
    srv.warmup(jnp.asarray(2.0))
    assert db.counters()["cache"] == {"hits": 0, "misses": 2, "evictions": 0}
    logits, _ = srv.prefill(
        jnp.asarray(2.0), {"tokens": jnp.ones((1, 8), jnp.int32)}
    )
    assert logits.shape == (1, 8, 1)
    c = db.counters()  # one tree: serving cache next to spill stats
    assert c["cache"] == {"hits": 1, "misses": 2, "evictions": 0}
    assert c["spill"]["spilled_relations"] == 2


@pytest.mark.spmd
@requires8
def test_budgeted_session_never_silently_replicates():
    """Regression: with committed layouts on the 4×2 host mesh, a
    budgeted (but fitting) session reuses the recorded plan with zero
    silently-moved bytes, exactly like an unbudgeted one."""
    import warnings

    from repro.core.engine import ReshardWarning
    from repro.launch.mesh import make_host_mesh
    from repro.launch.sharding import catalog_shardings

    db = _logreg_fill(repro.Database(memory_budget=1 << 30), n=64, m=8)
    db.use_mesh(make_host_mesh(model=2))
    handle = db.sql(LOGREG_SQL, wrt=("theta",))
    loss1, _ = handle.step()
    placed = catalog_shardings(db)
    for name, sh in placed.items():
        db.put(name, jax.device_put(db.get(name).data, sh))
    with warnings.catch_warnings():
        warnings.simplefilter("error", ReshardWarning)
        loss2, _ = handle.step()
    assert handle.last.counters["reshard"]["last_call_bytes"] == 0
    assert handle.last.counters["reshard"]["bytes_moved"] == 0
    np.testing.assert_allclose(
        np.asarray(loss1.data), np.asarray(loss2.data), atol=ATOL
    )


@pytest.mark.spmd
@requires8
def test_gcn_4x_budget_waves_on_host_mesh():
    """The acceptance gate: a GCN grad step whose COO edge relation is
    ≥4× the device-memory budget completes via chunk waves on the 4×2
    host mesh and matches the in-core oracle."""
    from repro.launch.mesh import make_host_mesh

    n, e, d = 200, 4000, 16
    db0 = _gcn_fill(repro.Database(), n=n, e=e, d=d, shards=8)
    l0, g0 = db0.query(_gcn_query(n)).step(wrt=("Edge", "Node"))

    edge_bytes = _rel_bytes(db0.get("Edge"))
    node_bytes = _rel_bytes(db0.get("Node"))
    budget = node_bytes + edge_bytes / 4  # edge ≥ 4× its headroom
    assert edge_bytes >= 4 * (budget - node_bytes)
    db = _gcn_fill(
        repro.Database(mesh=make_host_mesh(model=2), memory_budget=budget),
        n=n, e=e, d=d, shards=8,
    )
    h = db.query(_gcn_query(n))
    l1, g1 = h.step(wrt=("Edge", "Node"))
    assert isinstance(h.last, StreamedCompiled)
    assert h.last.num_waves >= 4
    np.testing.assert_allclose(
        np.asarray(l0.data), np.asarray(l1.data), atol=ATOL
    )
    _grad_close(g0, g1)


def test_const_data_relations_stream_when_only_params_are_wrt():
    """The SQL front door lowers non-``wrt`` relations to Const leaves;
    the wave planner must still stream them — differentiating only the
    params while streaming the constant design matrix is the canonical
    budgeted workload."""
    db0 = _logreg_fill(repro.Database())
    h0 = db0.sql(LOGREG_SQL, wrt=("theta",))
    l0, g0 = h0.step()
    db = _logreg_fill(repro.Database(memory_budget=_logreg_bytes() * 0.5))
    h = db.sql(LOGREG_SQL, wrt=("theta",))
    l1, g1 = h.step()
    assert isinstance(h.last, StreamedCompiled)
    assert h.last.plan.stream == "Rx"      # a Const leaf, not a TableScan
    assert h.last.plan.co_streams == ("Ry",)
    np.testing.assert_allclose(
        np.asarray(l0.data), np.asarray(l1.data), atol=ATOL
    )
    _grad_close(g0, g1)


# ---------------------------------------------------------------------------
# static wave certification (repro.analysis.certify) — the oocore lane
# asserts the certifier's independent re-derivation of plan_waves
# ---------------------------------------------------------------------------


def test_streamed_logreg_plan_certifies():
    """The certifier re-derives wave soundness for a streamed plan:
    boundary coverage, budget sizing, and grad derivability — proven off
    the plan record, not observed from an execution."""
    from repro.analysis import certify

    db = _logreg_fill(repro.Database(memory_budget=_logreg_bytes() * 0.7))
    env = {n: db.get(n) for n in ("Rx", "Ry", "theta")}  # before spill
    h = _logreg_handle(db)
    h.step()
    assert isinstance(h.last, StreamedCompiled)
    cert = certify(h.last, env, query=h.query, wrt=("theta",))
    assert cert.kind == "streamed"
    assert cert.waves["boundaries_ok"] and cert.waves["budget_ok"]
    assert cert.waves["num_waves"] == h.last.num_waves == 2
    assert cert.waves["max_wave_bytes"] <= cert.waves["budget"]
    assert cert.ok
    assert cert.grad is not None and cert.grad["full_rjp"]
    assert "waves: ok" in cert.render()


def test_streamed_gcn_plan_certifies_owner_alignment():
    """Owner-partitioned COO streams certify end to end: the wave cuts
    never straddle an owner run, and the edge relation's shard offsets
    are consistent with its owner column."""
    from repro.analysis import certify

    n = 60
    db0 = _gcn_fill(repro.Database(), n=n)
    total = _rel_bytes(db0.get("Edge")) + _rel_bytes(db0.get("Node"))
    db = _gcn_fill(repro.Database(memory_budget=total / 3), n=n)
    env = {"Edge": db.get("Edge"), "Node": db.get("Node")}
    h = db.query(_gcn_query(n))
    h.step(wrt=("Edge", "Node"))
    assert h.last.plan.owner_aligned
    cert = certify(h.last, env)
    assert cert.ok
    assert cert.waves["owner_aligned_ok"]
    assert cert.coo["relations"]["Edge"]["ok"]


def test_wave_certifier_rejects_tampered_plans():
    """Negative control: the certifier is an independent checker, so a
    corrupted plan record must fail it — non-covering boundaries, a cut
    through an owner run, and an over-budget wave count all flag."""
    import dataclasses
    from types import SimpleNamespace

    from repro.analysis.certify import _certify_waves

    db = _logreg_fill(repro.Database(memory_budget=_logreg_bytes() * 0.7))
    env = {n: db.get(n) for n in ("Rx", "Ry", "theta")}
    h = _logreg_handle(db)
    h.step()
    plan = h.last.plan
    assert _certify_waves(h.last, env)["ok"]  # sanity: genuine plan passes

    short = dataclasses.replace(plan, boundaries=plan.boundaries[:-1] + (63,))
    assert not _certify_waves(SimpleNamespace(plan=short), env)["boundaries_ok"]

    crowded = dataclasses.replace(plan, num_waves=1, boundaries=(0, 64))
    assert not _certify_waves(SimpleNamespace(plan=crowded), env)["budget_ok"]

    # owner-run straddle: a GCN edge plan with a cut moved off its
    # owner-aligned snap point
    n = 60
    db0 = _gcn_fill(repro.Database(), n=n)
    total = _rel_bytes(db0.get("Edge")) + _rel_bytes(db0.get("Node"))
    db2 = _gcn_fill(repro.Database(memory_budget=total / 3), n=n)
    env2 = {"Edge": db2.get("Edge"), "Node": db2.get("Node")}
    h2 = db2.query(_gcn_query(n))
    h2.step(wrt=("Edge", "Node"))
    plan2 = h2.last.plan
    owners = np.asarray(env2["Edge"].keys)[:, env2["Edge"].owner_dim]
    cut = None
    for c in range(1, owners.shape[0] - 1):
        if owners[c - 1] == owners[c] != COO_PAD_KEY:
            cut = c
            break
    assert cut is not None
    bad = dataclasses.replace(
        plan2, boundaries=(0, cut, int(owners.shape[0])), num_waves=2
    )
    res = _certify_waves(SimpleNamespace(plan=bad), env2)
    assert not res["owner_aligned_ok"]
