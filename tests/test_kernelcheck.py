"""Kernel contract certification (``repro.analysis.kernelcheck``) and
the sanitizer dispatch tier.

Three layers: golden-file diagnostics for seeded contract violations
(racy grid, OOB index map, unpaired VJP, dtype-domain — stable rendered
reports, reviewed like any behavior change; regenerate with
``REGEN_GOLDEN=1``), the acceptance bar (the real registry certifies
clean; a seeded racy BlockSpec / OOB index map is *rejected* through
``certify_kernels`` with node-path diagnostics at the plan's actual
dispatch sites; a stateful predicate is caught by the resolution
replay), and the dynamic twin (the sanitizer tier raises
``SanitizerError`` whose ``kind`` matches the static verdict, and agrees
with the jnp tier end-to-end through the engine, forward and gradient).
"""

import dataclasses
import os
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import certify_kernels, certify_registry
from repro.analysis import kernelcheck
from repro.analysis.diagnostics import CheckReport
from repro.core import fra
from repro.core import kernels as K
from repro.core.autodiff import ra_autodiff
from repro.core.engine import RAEngine
from repro.core.kernels import (
    ADD,
    MUL,
    SQUARE,
    SUM_CHUNK,
    AccumModel,
    BlockModel,
    GridModel,
    KernelContract,
    SanitizerError,
    VjpPair,
)
from repro.core.keys import EMPTY_KEY, TRUE, L, eq_pred, identity_key, jproj
from repro.core.relation import CooRelation, DenseRelation

GOLDEN = Path(__file__).parent / "golden" / "kernelcheck"

F32 = jnp.dtype("float32")
I32 = jnp.dtype("int32")


# ---------------------------------------------------------------------------
# Seeded contract violations (shared by goldens, acceptance, sanitizer)
# ---------------------------------------------------------------------------

SEG_INFO = {"nnz": 512, "dim": 128, "num_segments": 128, "dtype": F32}


def _racy_grid_model(info, **concrete):
    """Output map ignores a non-reduction axis and there is no
    accumulator: every output block is stored grid[1] times."""
    return GridModel(
        grid=(2, 2),
        inputs=(BlockModel("msg", (256, 128), (128, 128), lambda i, j: (j, 0)),),
        output=BlockModel("out", (256, 128), (128, 128), lambda i, j: (i, 0)),
        accumulator=None,
    )


def _oob_grid_model(info, **concrete):
    """Input index map walks one block past the (padded) array."""
    return GridModel(
        grid=(2,),
        inputs=(BlockModel("msg", (256, 128), (128, 128), lambda i: (i + 1, 0)),),
        output=BlockModel("out", (256, 128), (128, 128), lambda i: (i, 0)),
        accumulator=None,
    )


def _contract_with(grid_model, **overrides):
    base = K.kernel_contract("segment_sum")
    return dataclasses.replace(base, grid_model=grid_model, **overrides)


# ---------------------------------------------------------------------------
# Golden-file diagnostics
# ---------------------------------------------------------------------------


def case_racy_grid():
    diags = kernelcheck.check_contract_grid(
        "segment_sum", _contract_with(_racy_grid_model), [SEG_INFO]
    )
    return CheckReport(tuple(diags))


def case_oob_index_map():
    diags = kernelcheck.check_contract_grid(
        "segment_sum", _contract_with(_oob_grid_model), [SEG_INFO]
    )
    return CheckReport(tuple(diags))


def case_unpaired_vjp():
    impl = K.KernelImpl(
        "segment_sum", "pallas", lambda *a: None, ("tpu",), 0, K._is_float
    )
    contract = _contract_with(
        K.kernel_contract("segment_sum").grid_model,
        vjp_pairs=(VjpPair("scatter_add", lambda info: dict(info)),),
    )
    return CheckReport(tuple(kernelcheck.check_impl(impl, contract, [SEG_INFO])))


def case_dtype_domain():
    # a hardware-tier impl with no floating predicate admits int32
    impl = K.KernelImpl(
        "segment_sum", "interpret", lambda *a: None, (), 0, None
    )
    info = {"nnz": 1024, "dim": 64, "num_segments": 256, "dtype": I32}
    contract = K.kernel_contract("segment_sum")
    return CheckReport(tuple(kernelcheck.check_impl(impl, contract, [info])))


CASES = {
    name[len("case_"):]: fn
    for name, fn in sorted(globals().items())
    if name.startswith("case_")
}


@pytest.mark.parametrize("name", sorted(CASES))
def test_golden(name):
    report = CASES[name]()
    got = report.render() + "\n"
    path = GOLDEN / f"{name}.txt"
    if os.environ.get("REGEN_GOLDEN"):
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(got)
    assert path.exists(), f"golden file missing; REGEN_GOLDEN=1 to create: {path}"
    assert got == path.read_text()


def test_every_seeded_case_is_an_error_with_a_node_path():
    for name, fn in CASES.items():
        report = fn()
        assert report.errors, name
        assert all(d.node_path for d in report.diagnostics), name


# ---------------------------------------------------------------------------
# The acceptance bar: real registry clean, seeded violations rejected
# ---------------------------------------------------------------------------


def test_registry_certifies_clean():
    report = certify_registry()
    assert report.ok, report.render()
    assert report.render() == "ok (no diagnostics)"


def test_cli_exits_clean():
    import subprocess
    import sys

    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis.kernelcheck"],
        capture_output=True,
        text=True,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "kernelcheck:" in proc.stdout and "ok" in proc.stdout


def _gcn_prog_env():
    """COO conv: exercises gather_join + segment_sum sites, fwd + grad."""
    join = fra.Join(
        eq_pred((0, 0)), jproj(L(1)), MUL,
        fra.const("Edge", 2), fra.scan("Node", 1),
    )
    q = fra.Query(fra.Agg(identity_key(1), ADD, join), inputs=("Node",))
    sq = fra.Select(TRUE, identity_key(1), SQUARE, q.root)
    loss = fra.Agg(
        EMPTY_KEY, ADD,
        fra.Select(TRUE, identity_key(1), SUM_CHUNK, sq),
    )
    prog = ra_autodiff(fra.Query(loss, inputs=("Node",)))
    rng = np.random.default_rng(7)
    n, nnz, d = 16, 40, 8
    env = {
        "Edge": CooRelation(
            jnp.asarray(
                np.stack(
                    [rng.integers(0, n, nnz), rng.integers(0, n, nnz)], 1
                ),
                jnp.int32,
            ),
            jnp.asarray(rng.normal(size=nnz), jnp.float32),
            (n, n),
        ),
        "Node": DenseRelation(
            jnp.asarray(rng.normal(size=(n, d)), jnp.float32), 1
        ),
    }
    return prog, env


def test_certified_plan_reports_clean_kernels():
    prog, env = _gcn_prog_env()
    low = RAEngine(prog).lower(env)
    report = certify_kernels(low)
    assert getattr(low.resolutions, "sites", ()), "no dispatch site recorded"
    assert report.ok, report.render()
    # cached on the Lowered: the second call is the same object
    assert certify_kernels(low) is report


@pytest.mark.parametrize(
    "bad_model,code",
    [(_racy_grid_model, "grid-race"), (_oob_grid_model, "grid-oob-index")],
)
def test_seeded_bad_blockspec_rejected_at_dispatch_sites(
    monkeypatch, bad_model, code
):
    """A racy / out-of-bounds BlockSpec in the segsum contract is
    statically rejected at the plan's actual dispatch sites."""
    import repro.kernels.segsum.ops as segsum_ops

    prog, env = _gcn_prog_env()
    low = RAEngine(prog).lower(env)
    monkeypatch.setattr(
        segsum_ops, "CONTRACT", _contract_with(bad_model)
    )
    report = certify_kernels(low, recheck=True)
    assert not report.ok
    hits = [d for d in report.errors if d.code == code]
    assert hits, report.render()
    assert all(d.node_path.startswith("dispatch:segment_sum[") for d in hits)


def test_stateful_predicate_rejected(monkeypatch):
    """The retrace-desync hazard, now a named diagnostic: a predicate
    that answers differently on replay flips the resolved tier between
    lowering and retrace — certify_kernels replays every recorded site
    and reports ``flappy-predicate``."""
    state = {"accept": True}

    def stateful(info):
        return state["accept"]  # reads mutable state, not the site info

    # on cpu the real pallas impl is backend-gated out, so this is the
    # only eligible pallas entry: rejecting on replay falls to jnp
    impl = K.register_impl(
        "segment_sum", "pallas", K._IMPLS[("segment_sum", "ref")][0].fn,
        priority=10, predicate=stateful,
    )
    try:
        prog, env = _gcn_prog_env()
        low = RAEngine(prog).lower(env, dispatch=("pallas", "jnp"))
        state["accept"] = False  # the state drifts before the retrace
        report = certify_kernels(low, recheck=True)
    finally:
        K._IMPLS[("segment_sum", "pallas")].remove(impl)
    flappy = [d for d in report.errors if d.code == "flappy-predicate"]
    assert flappy, report.render()
    assert any(d.node_path.startswith("dispatch:") for d in flappy)


# ---------------------------------------------------------------------------
# Sanitizer tier: dynamic twin of the static certifier
# ---------------------------------------------------------------------------


def test_sanitizer_agrees_with_static_verdict(monkeypatch):
    """On the same seeded-bad contract, the sanitizer raises the exact
    code the static certifier reports."""
    import repro.kernels.segsum.ops as segsum_ops

    rng = np.random.default_rng(0)
    msg = jnp.asarray(rng.normal(size=(512, 128)), jnp.float32)
    seg = jnp.asarray(rng.integers(0, 128, 512), jnp.int32)
    for bad_model in (_racy_grid_model, _oob_grid_model):
        contract = _contract_with(bad_model)
        monkeypatch.setattr(segsum_ops, "CONTRACT", contract)
        static = kernelcheck.check_contract_grid(
            "segment_sum", contract, [SEG_INFO]
        )
        with pytest.raises(SanitizerError) as exc:
            K._segsum_sanitizer(msg, seg, 128)
        assert exc.value.kind == static[0].code
    monkeypatch.undo()
    # dtype-domain dynamically (direct call bypasses the float predicate)
    with pytest.raises(SanitizerError) as exc:
        K._segsum_sanitizer(jnp.ones((8, 4), jnp.int32), seg[:8], 5)
    assert exc.value.kind == "dtype-domain"


def test_sanitizer_clean_sites_match_ref_oracle():
    from repro.kernels.gather.ref import gather_rows_ref
    from repro.kernels.segsum.ref import segment_sum_ref

    rng = np.random.default_rng(1)
    msg = jnp.asarray(rng.normal(size=(100, 24)), jnp.float32)
    seg = jnp.asarray(rng.integers(-1, 30, 100), jnp.int32)  # pad ids too
    np.testing.assert_allclose(
        np.asarray(K._segsum_sanitizer(msg, seg, 30)),
        np.asarray(segment_sum_ref(msg, seg, 30)),
        atol=1e-5,
    )
    table = jnp.asarray(rng.normal(size=(30, 24)), jnp.float32)
    rows = jnp.asarray(rng.integers(-1, 31, 64), jnp.int32)  # invalid rows
    np.testing.assert_allclose(
        np.asarray(K._gather_sanitizer(table, rows)),
        np.asarray(gather_rows_ref(table, rows)),
        atol=1e-5,
    )


def test_sanitizer_tier_smoke_segsum_gather_fwd_grad():
    """The fast-lane smoke: segsum + gather_join forward/grad through the
    engine under the sanitizer tier agree with the jnp tier."""
    prog, env = _gcn_prog_env()
    eng = RAEngine(prog)
    out_j, grads_j = eng.lower(env, dispatch="jnp").compile()(env)
    out_s, grads_s = eng.lower(env, dispatch="sanitizer").compile()(env)
    np.testing.assert_allclose(
        np.asarray(out_s.data), np.asarray(out_j.data), rtol=1e-5, atol=1e-5
    )
    for name in grads_j:
        gj, gs = grads_j[name], grads_s[name]
        lj = gj.values if isinstance(gj, CooRelation) else gj.data
        ls = gs.values if isinstance(gs, CooRelation) else gs.data
        np.testing.assert_allclose(
            np.asarray(ls), np.asarray(lj), rtol=1e-5, atol=1e-5
        )
    low = eng.lower(env, dispatch="sanitizer")
    assert certify_kernels(low).ok
    recorded = {rec.tier for rec in low.resolutions.sites}
    assert recorded == {"sanitizer"}


# ---------------------------------------------------------------------------
# Property: certified-clean shape classes agree with the ref oracle
# ---------------------------------------------------------------------------


def _certify_and_run(nnz, dim, num_segments, seed):
    info = {"nnz": nnz, "dim": dim, "num_segments": num_segments, "dtype": F32}
    diags = kernelcheck.check_contract_grid(
        "segment_sum", K.kernel_contract("segment_sum"), [info]
    )
    assert diags == [], [d.render() for d in diags]
    from repro.kernels.segsum.ref import segment_sum_ref

    rng = np.random.default_rng(seed)
    msg = jnp.asarray(rng.normal(size=(nnz, dim)), jnp.float32)
    seg = jnp.asarray(rng.integers(-1, num_segments, nnz), jnp.int32)
    np.testing.assert_allclose(
        np.asarray(K._segsum_sanitizer(msg, seg, num_segments)),
        np.asarray(segment_sum_ref(msg, seg, num_segments)),
        atol=1e-5,
    )


def test_random_shape_classes_certify_clean_and_match_oracle():
    """Seeded-random fallback for environments without hypothesis."""
    rng = np.random.default_rng(42)
    for trial in range(20):
        nnz = int(rng.integers(1, 1500))
        dim = int(rng.integers(1, 160))
        num_segments = int(rng.integers(1, 400))
        _certify_and_run(nnz, dim, num_segments, seed=trial)


def test_hypothesis_shape_classes_certify_clean_and_match_oracle():
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=30, deadline=None)
    @given(
        nnz=st.integers(1, 2000),
        dim=st.integers(1, 200),
        num_segments=st.integers(1, 500),
    )
    def prop(nnz, dim, num_segments):
        _certify_and_run(nnz, dim, num_segments, seed=nnz * 31 + dim)

    prop()
