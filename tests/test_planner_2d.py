"""2-D (data × model) query plans: MeshGeometry, per-relation batch-dim
placement, 1-axis bit-for-bit compatibility, the make_host_mesh fixes,
and — under the tier1-spmd lane's 8 virtual devices — the end-to-end
oracle: a compiled logreg grad step on a real 4×2 host mesh matches the
single-device result."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import fra
from repro.core.autodiff import ra_autodiff
from repro.core.engine import RAEngine
from repro.core.kernels import ADD, LOGISTIC, MATMUL, MUL, XENT
from repro.core.keys import (
    EMPTY_KEY,
    TRUE,
    L,
    R,
    eq_pred,
    identity_key,
    jproj,
    project_key,
)
from repro.core.planner import (
    MeshGeometry,
    input_pspecs,
    plan_query,
)
from repro.core.relation import DenseRelation
from repro.launch.mesh import batch_axes, make_host_mesh, resolve_mesh

requires8 = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs 8 devices (tier1-spmd lane: "
    "XLA_FLAGS=--xla_force_host_platform_device_count=8)",
)


def _sds(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def logreg_forward_query():
    """Rx (batch × feature) ⋈ theta (feature) → Σ by batch row."""
    join = fra.Join(
        eq_pred((1, 0)), jproj(L(0), L(1)), MUL,
        fra.scan("Rx", 2), fra.scan("theta", 1),
    )
    return fra.Query(
        fra.Agg(project_key(0), ADD, join), inputs=("Rx", "theta")
    )


def logreg_loss_query():
    f_matmul = fra.Agg(
        project_key(0), ADD,
        fra.Join(
            eq_pred((1, 0)), jproj(L(0), L(1)), MUL,
            fra.const("Rx", 2), fra.scan("theta", 1),
        ),
    )
    f_predict = fra.Select(TRUE, identity_key(1), LOGISTIC, f_matmul)
    f_loss = fra.Agg(
        EMPTY_KEY, ADD,
        fra.Join(eq_pred((0, 0)), jproj(L(0)), XENT, f_predict, fra.const("Ry", 1)),
    )
    return fra.Query(f_loss, inputs=("theta",))


def matmul_query():
    join = fra.Join(
        eq_pred((1, 0)), jproj(L(0), L(1), R(1)), MATMUL,
        fra.scan("A", 2), fra.scan("B", 2),
    )
    return fra.Query(fra.Agg(project_key(0, 2), ADD, join), inputs=("A", "B"))


# ---------------------------------------------------------------------------
# 2-D cost model (device-free unit tests)
# ---------------------------------------------------------------------------


def test_2d_data_shards_batch_relation_model_shards_params():
    """The acceptance layout: the batch-keyed relation lands on the data
    axis, the parameter relation on the model axis (classic 2-D logreg)."""
    q = logreg_forward_query()
    env = {"Rx": _sds((4096, 64)), "theta": _sds((64,))}
    geo = MeshGeometry("model", 2, ("data",), 4)
    plans = plan_query(q, env, 2, geometry=geo)
    (plan,) = plans.values()

    # data axis: shard Rx's surviving batch dim (row), replicate theta
    assert plan.data_kind == "data:shard_left"
    assert plan.left_batch_dim == 0 and plan.right_batch_dim is None
    # batch key survives the Σ-by-row: no data-axis all-reduce
    assert not plan.needs_data_psum
    # model axis: co-partition on the feature key (theta on "model") —
    # a broadcast would leave the model axis idle (Rx's only surviving
    # dim is taken by "data") and is costed as full replication
    assert plan.kind == "copartition"
    assert plan.left_shard_dim == 1 and plan.right_shard_dim == 0
    assert plan.costs["copartition"] < plan.costs["broadcast_right"]

    specs = input_pspecs(q, plans)
    assert specs["Rx"] == P("data", "model")
    assert specs["theta"] == P("model")


def test_2d_data_replicates_when_nothing_has_a_batch_dim():
    """Neither side of the loss join keeps a non-contraction dim — the
    data axes have nothing to shard and fall back to replication."""
    q = logreg_loss_query()
    env = {
        "Rx": _sds((4096, 64)),
        "Ry": _sds((4096,)),
        "theta": _sds((64,)),
    }
    geo = MeshGeometry("model", 2, ("data",), 4)
    plans = plan_query(q, env, 2, geometry=geo)
    loss_plans = [
        p for p in plans.values() if p.data_kind == "data:replicate"
    ]
    assert loss_plans, "xent join should have no batch dim to shard"
    (loss_plan,) = loss_plans
    assert loss_plan.left_batch_dim is None
    assert loss_plan.right_batch_dim is None


def test_2d_data_axis_respects_memory_budget():
    """Candidates that would replicate an over-budget relation over the
    data axes are infeasible; with nothing feasible the planner falls
    back to sharding a batch dim (never an error on the data axes)."""
    q = logreg_forward_query()
    env = {"Rx": _sds((4096, 64)), "theta": _sds((64,))}
    geo = MeshGeometry("model", 2, ("data",), 4)
    plans = plan_query(q, env, 2, mem_budget=1.0, geometry=geo)
    (plan,) = plans.values()
    # theta (256 B) exceeds the 1-byte budget: replicate is infeasible,
    # best-effort still shards Rx's batch dim
    assert plan.data_kind == "data:shard_left"
    assert "data:replicate" not in plan.costs


def test_from_mesh_rejects_absent_axis_override():
    mesh = jax.sharding.Mesh(
        np.array(jax.devices()[:1]).reshape(1), ("model",)
    )
    with pytest.raises(ValueError, match="not on the mesh"):
        MeshGeometry.from_mesh(mesh, axis="tp")


def test_resolve_mesh_rejects_unknown_production_variant():
    with pytest.raises(ValueError, match="production mesh variant"):
        resolve_mesh("production:multipods")


def test_one_axis_geometry_reproduces_1d_plans_bit_for_bit():
    """A 1-axis mesh is the legacy planner: identical JoinPlans (kind,
    dims, every cost-table entry) and identical PartitionSpecs."""
    q = matmul_query()
    for env in (
        {"A": _sds((512, 512, 256, 256)), "B": _sds((512, 1, 256, 64))},
        {"A": _sds((512, 512, 256, 256)), "B": _sds((512, 512, 256, 256))},
    ):
        legacy = plan_query(q, env, 16)
        one_axis = plan_query(
            q, env, 16, geometry=MeshGeometry.single(16)
        )
        assert legacy == one_axis
        assert input_pspecs(q, legacy) == input_pspecs(q, one_axis)
        for plan in one_axis.values():
            assert plan.data_kind == "none"
            assert plan.left_batch_dim is None
            assert plan.right_batch_dim is None
            assert not any(k.startswith("data:") for k in plan.costs)


def test_multipod_folds_pod_and_data_axes():
    """On the multi-pod geometry the batch dim carries the folded
    ("pod", "data") pair, matching launch/mesh.batch_axes."""
    q = logreg_forward_query()
    env = {"Rx": _sds((4096, 64)), "theta": _sds((64,))}
    geo = MeshGeometry("model", 16, ("pod", "data"), 32)
    plans = plan_query(q, env, 16, geometry=geo)
    specs = input_pspecs(q, plans)
    assert tuple(specs["Rx"])[0] == ("pod", "data")


def test_geometry_from_one_axis_mesh_degrades_to_1d():
    mesh = jax.sharding.Mesh(
        np.array(jax.devices()[:1]).reshape(1), ("model",)
    )
    geo = MeshGeometry.from_mesh(mesh)
    assert geo.model_axis == "model"
    assert geo.data_axes == () and geo.data_size == 1
    assert geo.data_spec is None


# ---------------------------------------------------------------------------
# make_host_mesh fixes
# ---------------------------------------------------------------------------


def test_make_host_mesh_raises_value_error_with_device_count(monkeypatch):
    monkeypatch.setattr(jax, "devices", lambda *a, **k: [object()] * 3)
    with pytest.raises(ValueError, match="3 visible device"):
        make_host_mesh(model=2)


def test_make_host_mesh_single_device_falls_back_to_1_axis(monkeypatch):
    real = jax.devices()
    monkeypatch.setattr(jax, "devices", lambda *a, **k: real[:1])
    mesh = make_host_mesh()
    assert tuple(mesh.axis_names) == ("model",)
    assert dict(mesh.shape) == {"model": 1}
    # the 1-axis fallback reproduces the legacy planner geometry
    geo = MeshGeometry.from_mesh(mesh)
    assert geo == MeshGeometry.single(1)


def test_resolve_mesh_specs(monkeypatch):
    real = jax.devices()
    monkeypatch.setattr(jax, "devices", lambda *a, **k: real[:1])
    assert resolve_mesh(None) is None
    mesh = resolve_mesh("host")
    assert resolve_mesh(mesh) is mesh
    with pytest.raises(ValueError, match="unknown mesh spec"):
        resolve_mesh("nope")


# ---------------------------------------------------------------------------
# SPMD: the 4×2 host mesh (tier1-spmd lane, 8 virtual CPU devices)
# ---------------------------------------------------------------------------


def _logreg_env(rng, n=64, m=8):
    return {
        "Rx": DenseRelation(jnp.asarray(rng.normal(size=(n, m)), jnp.float32), 2),
        "Ry": DenseRelation(
            jnp.asarray(rng.integers(0, 2, size=n), jnp.float32), 1
        ),
        "theta": DenseRelation(
            jnp.asarray(rng.normal(size=m) * 0.1, jnp.float32), 1
        ),
    }


@pytest.mark.spmd
@requires8
def test_logreg_grad_step_2d_matches_single_device_oracle():
    """Acceptance: on the 4×2 (data × model) host mesh a compiled logreg
    grad step plans 2-D shardings — batch relation on "data", parameter
    relation on "model" — and matches the unsharded result to 1e-5."""
    mesh = make_host_mesh(model=2)
    assert dict(mesh.shape) == {"data": 4, "model": 2}
    assert batch_axes(mesh) == ("data",)
    geo = MeshGeometry.from_mesh(mesh)
    assert geo == MeshGeometry("model", 2, ("data",), 4)

    prog = ra_autodiff(logreg_loss_query())
    env = _logreg_env(np.random.default_rng(0))
    eng = RAEngine(prog)
    low = eng.lower(env)

    comp2d = low.compile(mesh=mesh)
    assert comp2d.placements["Rx"] == {"data": 0, "model": 1}
    assert comp2d.placements["theta"] == {"data": None, "model": 0}
    out2, grads2 = comp2d(env)
    walks = eng.trace_count
    comp2d(env)                          # jit cache hit: no re-lowering
    assert eng.trace_count == walks

    comp1 = low.compile()                # single-device oracle
    out1, grads1 = comp1(env)
    np.testing.assert_allclose(
        np.asarray(out2.data), np.asarray(out1.data), atol=1e-5
    )
    np.testing.assert_allclose(
        np.asarray(grads2["theta"].data),
        np.asarray(grads1["theta"].data),
        atol=1e-5,
    )
    # the co-partitioned feature key must have produced a psum
    hlo = comp2d.lower_text()
    assert "all-reduce" in hlo or "reduce-scatter" in hlo


@pytest.mark.spmd
@requires8
def test_compile_cache_distinguishes_mesh_geometries():
    prog = ra_autodiff(logreg_loss_query())
    env = _logreg_env(np.random.default_rng(1))
    low = RAEngine(prog).lower(env)
    m22 = make_host_mesh(model=2)
    m81 = make_host_mesh(model=1)
    c22 = low.compile(mesh=m22)
    c81 = low.compile(mesh=m81)
    assert c22 is not c81
    assert c22.geometry != c81.geometry
    assert low.compile(mesh=m22) is c22   # same mesh: cache hit


@pytest.mark.spmd
@requires8
def test_relational_wrappers_under_session_mesh():
    """The relational operator layer threads the canonical host mesh via
    an activated session — forward and backward match the mesh-less
    result (the custom_vjp boundary takes no new arguments)."""
    import repro
    from repro.relational.linear import rel_matmul_blocked

    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(4, 2, 8, 8)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(2, 4, 8, 8)), jnp.float32)

    def loss(x, w):
        return jnp.sum(rel_matmul_blocked(x, w) ** 2)

    ref = rel_matmul_blocked(x, w)
    gref = jax.grad(loss, argnums=(0, 1))(x, w)
    with repro.Database(mesh="host:2").activate():
        out = rel_matmul_blocked(x, w)
        g = jax.grad(loss, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)
    np.testing.assert_allclose(np.asarray(g[0]), np.asarray(gref[0]), atol=1e-4)
    np.testing.assert_allclose(np.asarray(g[1]), np.asarray(gref[1]), atol=1e-4)
