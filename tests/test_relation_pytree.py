"""DenseRelation/CooRelation as JAX pytrees: schema (key arity, extents)
is static aux data, array payloads are leaves — the property that lets a
whole relation environment cross the jit/sharding boundary as one pytree
argument (core/engine.py)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.relation import CooRelation, DenseRelation


def test_dense_flatten_roundtrip():
    rel = DenseRelation(jnp.arange(24.0).reshape(2, 3, 4), key_arity=2)
    leaves, treedef = jax.tree_util.tree_flatten(rel)
    assert len(leaves) == 1                      # data is the only leaf
    back = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(back, DenseRelation)
    assert back.key_arity == 2
    assert back.extents == (2, 3)
    assert back.chunk_shape == (4,)
    np.testing.assert_array_equal(back.data, rel.data)


def test_coo_flatten_roundtrip():
    rel = CooRelation(
        keys=jnp.array([[0, 1], [2, 3]], dtype=jnp.int32),
        values=jnp.array([[1.0, 2.0], [3.0, 4.0]]),
        extents=(4, 4),
    )
    leaves, treedef = jax.tree_util.tree_flatten(rel)
    assert len(leaves) == 2                      # keys + values
    back = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(back, CooRelation)
    assert back.extents == (4, 4)                # static aux survives
    assert back.key_arity == 2 and back.nnz == 2
    np.testing.assert_array_equal(back.keys, rel.keys)
    np.testing.assert_array_equal(back.values, rel.values)


def test_key_arity_is_static_not_a_leaf():
    a = DenseRelation(jnp.zeros((2, 2)), key_arity=1)
    b = DenseRelation(jnp.zeros((2, 2)), key_arity=2)
    ta = jax.tree_util.tree_structure(a)
    tb = jax.tree_util.tree_structure(b)
    assert ta != tb                              # arity distinguishes treedefs


def test_relations_cross_jit_boundary():
    env = {
        "D": DenseRelation(jnp.ones((2, 3)), key_arity=1),
        "C": CooRelation(
            jnp.zeros((3, 2), jnp.int32), jnp.ones((3,)), (2, 2)
        ),
    }

    @jax.jit
    def double(e):
        return jax.tree_util.tree_map(lambda x: x * 2, e)

    out = double(env)
    assert isinstance(out["D"], DenseRelation) and out["D"].key_arity == 1
    assert isinstance(out["C"], CooRelation) and out["C"].extents == (2, 2)
    np.testing.assert_allclose(out["D"].data, 2.0)
    # int32 keys double too under tree_map — jit preserved the container
    np.testing.assert_array_equal(np.asarray(out["C"].keys), 0)
    np.testing.assert_allclose(out["C"].values, 2.0)


def test_grad_through_relation_pytree():
    rel = DenseRelation(jnp.array([1.0, 2.0, 3.0]), key_arity=1)

    def loss(r):
        return jnp.sum(r.data ** 2)

    g = jax.grad(loss)(rel)
    assert isinstance(g, DenseRelation) and g.key_arity == 1
    np.testing.assert_allclose(g.data, 2.0 * rel.data)
