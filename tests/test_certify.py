"""The static plan certifier (``repro.analysis.certify``): certificates
*prove* plan properties off the compile records — zero-unplanned-reshard
execution, sharded-extent divisibility, COO owner-partition soundness,
and RJP grad-derivability — before any execution pays for them. The
spmd-marked test cross-checks the proof against the runtime reshard
counters on the 8-device lane."""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

import repro
from repro.analysis import Certificate, certify
from repro.analysis.certify import certify_grad
from repro.core import fra
from repro.core.engine import ReshardWarning, engine_for
from repro.core.kernels import ADD, MATMUL, MUL
from repro.core.keys import L, R, eq_pred, identity_key, jproj, project_key
from repro.core.relation import CooRelation, DenseRelation
from repro.launch.mesh import make_host_mesh

requires8 = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs 8 devices (tier1-spmd lane: "
    "XLA_FLAGS=--xla_force_host_platform_device_count=8)",
)


def _matmul_query():
    join = fra.Join(
        eq_pred((1, 0)), jproj(L(0), L(1), R(1)), MATMUL,
        fra.scan("A", 2), fra.scan("B", 2),
    )
    return fra.Query(fra.Agg(project_key(0, 2), ADD, join), inputs=("A", "B"))


def _matmul_env(n=4, m=8, seed=0):
    rng = np.random.default_rng(seed)
    return {
        "A": DenseRelation(
            jnp.asarray(rng.normal(size=(n, n, m, m)), jnp.float32), 2
        ),
        "B": DenseRelation(
            jnp.asarray(rng.normal(size=(n, n, m, m)), jnp.float32), 2
        ),
    }


# ---------------------------------------------------------------------------
# mesh-less certificates: trivially proven, still structured
# ---------------------------------------------------------------------------


def test_meshless_plan_certifies_trivially():
    q = _matmul_query()
    env = _matmul_env()
    comp = engine_for(q).lower(env).compile()
    cert = certify(comp, env, query=q)
    assert isinstance(cert, Certificate)
    assert cert.kind == "in-core"
    assert cert.ok and cert.zero_unplanned_reshard
    assert "mesh-less" in cert.reshard["reason"]
    assert cert.grad is not None and cert.grad["full_rjp"]
    d = cert.to_dict()
    assert d["ok"] and d["kind"] == "in-core"
    assert "OK" in cert.render()


def test_certify_rejects_non_compiled():
    with pytest.raises(TypeError, match="cannot certify"):
        certify(object(), {})


# ---------------------------------------------------------------------------
# grad derivability, pre-compile
# ---------------------------------------------------------------------------


def test_certify_grad_full_vs_partial():
    # matmul: both input keys solvable from the Σ∘⋈ output → full RJP
    g = certify_grad(_matmul_query(), ("A", "B"))
    assert g["full_rjp"]
    assert set(g["joins"]) == {"Σ/⋈"}
    assert g["joins"]["Σ/⋈"] == {"left": "solvable", "right": "solvable"}

    # a ⋈ whose output keeps only B's free key: A's key is unsolvable
    join = fra.Join(
        eq_pred((1, 0)), jproj(R(1)), MUL,
        fra.scan("A", 2), fra.scan("B", 2),
    )
    q = fra.Query(
        fra.Agg(identity_key(1), ADD, join), inputs=("A", "B")
    )
    g = certify_grad(q, ("A",))
    assert not g["full_rjp"]
    assert g["joins"]["Σ/⋈"]["left"] == "partial"
    assert g["joins"]["Σ/⋈"]["right"] == "n/a"  # B is not a wrt input


# ---------------------------------------------------------------------------
# COO owner-partition soundness (no mesh needed)
# ---------------------------------------------------------------------------


def _owner_coo(offsets, owners, extent=8):
    keys = np.stack([np.asarray(owners, np.int32),
                     np.zeros(len(owners), np.int32)], axis=1)
    return CooRelation(
        keys, np.ones((len(owners),), np.float32), (extent, extent),
        owner_dim=0, shard_offsets=tuple(offsets),
    )


def test_coo_owner_partition_soundness_proof():
    q = _matmul_query()
    env = _matmul_env()
    comp = engine_for(q).lower(env).compile()
    # sound: 2 shards of 2 rows each, owner-sorted, offsets = first keys
    sound = dict(env, E=_owner_coo((0, 4), (0, 2, 4, 6)))
    assert certify(comp, sound).coo["relations"]["E"]["ok"]
    # broken offsets: shard 1 claims first owner 3 but holds 4
    broken = dict(env, E=_owner_coo((0, 3), (0, 2, 4, 6)))
    cert = certify(comp, broken)
    assert not cert.coo["relations"]["E"]["offsets_consistent"]
    assert not cert.ok
    # unsorted owners: monotone offsets but rows out of owner order
    unsorted = dict(env, E=_owner_coo((0, 1), (0, 5, 1, 6)))
    assert not certify(comp, unsorted).coo["relations"]["E"]["ok"]


# ---------------------------------------------------------------------------
# spmd lane: the proof agrees with the runtime counters
# ---------------------------------------------------------------------------


@pytest.mark.spmd
@requires8
def test_certificate_proves_zero_unplanned_reshard_on_mesh():
    mesh = make_host_mesh(model=2)
    q = _matmul_query()
    env = _matmul_env()
    low = engine_for(q).lower(env)
    comp = low.compile_auto(env, mesh=mesh)

    # uncommitted inputs place for free: proven before any call
    cert = certify(comp, env)
    assert cert.kind == "in-core"
    assert cert.zero_unplanned_reshard and cert.ok
    assert cert.divisibility["ok"]
    statuses = {r["status"] for r in cert.reshard["relations"].values()}
    assert statuses <= {"uncommitted", "aligned"}

    # commit every input to its planned layout: proof says aligned, and
    # the runtime reshard counters agree (zero bytes moved)
    committed_env = {}
    for name, rel in env.items():
        spec = comp.planned_spec(name)
        arr = (
            jax.device_put(rel.data, NamedSharding(mesh, spec))
            if spec is not None
            else rel.data
        )
        committed_env[name] = DenseRelation(arr, rel.key_arity)
    comp2 = low.compile_auto(committed_env, mesh=mesh)
    cert2 = certify(comp2, committed_env)
    assert cert2.zero_unplanned_reshard and cert2.ok
    with warnings.catch_warnings():
        warnings.filterwarnings("error", category=ReshardWarning)
        comp2(committed_env)
    assert comp2.counters["reshard"]["last_call_bytes"] == 0

    # adversarial: an input committed against the plan (and not in the
    # plan's rechunk stage) breaks the proof
    wrong = NamedSharding(mesh, P(None, None, "model", None))
    bad_env = dict(committed_env)
    bad_env["A"] = DenseRelation(
        jax.device_put(env["A"].data, wrong), 2
    )
    bad_committed = {
        n: (comp2.planned_spec(n) if n != "A" else wrong.spec)
        for n in bad_env
    }
    cert3 = certify(comp2, bad_env, committed=bad_committed)
    if cert3.reshard["relations"]["A"]["status"] == "unplanned":
        assert not cert3.zero_unplanned_reshard and not cert3.ok


@pytest.mark.spmd
@requires8
def test_session_step_certifies_clean_end_to_end():
    """Database front door: after a step, the recorded executable and the
    catalog's committed layouts certify zero-unplanned-reshard."""
    rng = np.random.default_rng(0)
    db = repro.Database()
    db.use_mesh(make_host_mesh(model=2))
    db.put("Rx", jnp.asarray(rng.normal(size=(64, 8)), jnp.float32),
           keys=("row", "col"))
    db.put("Ry", jnp.asarray((rng.uniform(size=64) > 0.5), jnp.float32),
           keys=("row",))
    db.put("theta", jnp.asarray(rng.normal(size=8) * 0.1, jnp.float32),
           keys=("col",))
    h = db.sql(
        """
        mm   := SELECT Rx.row, SUM(multiply(Rx.val, theta.val))
                FROM Rx, theta WHERE Rx.col = theta.col GROUP BY Rx.row;
        pred := SELECT mm.row, logistic(mm.val) FROM mm;
        SELECT SUM(xent(pred.val, Ry.val)) FROM pred, Ry
        WHERE pred.row = Ry.row
        """,
        wrt=("theta",),
    )
    h.step()
    env = {n: db.get(n) for n in ("Rx", "Ry", "theta")}
    cert = certify(h.last, env, query=h.query, wrt=("theta",))
    assert cert.zero_unplanned_reshard and cert.ok
    assert cert.grad is not None
