"""Golden-file tests for the typed FRA checker (``repro.analysis``):
each malformed query renders a stable, reviewed diagnostic report —
severity, rule code, node path, provenance labels, fix hint. Regenerate
after an intentional renderer change with::

    REGEN_GOLDEN=1 PYTHONPATH=src python -m pytest tests/test_check.py

and review the diff like any other behavior change."""

import os
from pathlib import Path
from types import SimpleNamespace

import numpy as np
import pytest

import repro
from repro.analysis import ValidationError, check_query
from repro.core import fra
from repro.core.kernels import ADD, IDENT, MATMUL, MAX, MUL
from repro.core.keys import (
    TRUE,
    In,
    KeyFn,
    L,
    R,
    SelPred,
    eq_pred,
    identity_key,
    jproj,
    project_key,
)
from repro.core.planner import MeshGeometry
from repro.core.relation import CooRelation, DenseRelation

GOLDEN = Path(__file__).parent / "golden" / "check"


def _dense(*extents, dtype=np.float32):
    return DenseRelation(np.zeros(extents, dtype=dtype), len(extents))


def _coo(nnz, *extents):
    return CooRelation(
        np.zeros((nnz, len(extents)), np.int32),
        np.zeros((nnz,), np.float32),
        tuple(extents),
    )


SCHEMA = {"A": ("row", "col"), "B": ("row", "col"), "E": ("src", "dst")}


def case_unknown_relation():
    return check_query(fra.scan("Ghost", 2), env={"A": _dense(3, 4)})


def case_arity_mismatch():
    return check_query(fra.scan("A", 3), env={"A": _dense(3, 4)})


def case_join_extent_mismatch():
    join = fra.Join(
        eq_pred((1, 0)), jproj(L(0), L(1), R(1)), MATMUL,
        fra.scan("A", 2), fra.scan("B", 2),
    )
    q = fra.Query(fra.Agg(project_key(0, 2), ADD, join), inputs=("A", "B"))
    return check_query(
        q,
        env={"A": _dense(3, 4), "B": _dense(5, 6)},
        schema=SCHEMA,
    )


def case_dtype_promotion():
    join = fra.Join(
        eq_pred((1, 0)), jproj(L(0), L(1), R(1)), MATMUL,
        fra.scan("A", 2), fra.scan("B", 2),
    )
    q = fra.Query(fra.Agg(project_key(0, 2), ADD, join), inputs=("A", "B"))
    return check_query(
        q,
        env={"A": _dense(3, 4), "B": _dense(4, 6, dtype=np.float64)},
        schema=SCHEMA,
    )


def case_non_permutation_select():
    node = fra.Select(TRUE, KeyFn((In(0),)), IDENT, fra.scan("A", 2))
    return check_query(node, env={"A": _dense(3, 4)})


def case_projects_fixed():
    node = fra.Select(
        SelPred(((0, 1),)), identity_key(2), IDENT,
        fra.scan("A", 2),
    )
    return check_query(node, env={"A": _dense(3, 4)}, schema=SCHEMA)


def case_duplicate_group():
    node = fra.Agg(KeyFn((In(0), In(0))), ADD, fra.scan("A", 2))
    return check_query(node, env={"A": _dense(3, 4)})


def case_non_additive_agg():
    node = fra.Agg(project_key(0), MAX, fra.scan("A", 2))
    return check_query(node, env={"A": _dense(3, 4)})


def case_coo_coo_join():
    node = fra.Join(
        eq_pred((0, 0)), jproj(L(0), L(1)), MUL,
        fra.scan("E", 2), fra.scan("F", 2),
    )
    return check_query(
        fra.Agg(identity_key(2), ADD, node),
        env={"E": _coo(8, 5, 5), "F": _coo(8, 5, 5)},
    )


def case_coo_predicate():
    node = fra.Select(
        SelPred(((0, 1),)), identity_key(2), IDENT,
        fra.scan("E", 2),
    )
    return check_query(node, env={"E": _coo(8, 5, 5)}, schema=SCHEMA)


def case_join_drops_class():
    node = fra.Join(
        eq_pred((1, 0)), jproj(L(0)), MUL,
        fra.scan("A", 2), fra.scan("B", 2),
    )
    return check_query(
        node, env={"A": _dense(3, 4), "B": _dense(4, 6)}, schema=SCHEMA
    )


def case_partial_rjp():
    # the Σ∘⋈ output keeps only B's second key: A's key is not solvable
    # from the output, so grads for A take the general partial-RJP path
    join = fra.Join(
        eq_pred((1, 0)), jproj(R(1)), MUL,
        fra.scan("A", 2), fra.scan("B", 2),
    )
    node = fra.Agg(identity_key(1), ADD, join)
    return check_query(
        node,
        env={"A": _dense(3, 4), "B": _dense(4, 6)},
        schema=SCHEMA,
        wrt=("A",),
    )


def case_empty_selection():
    node = fra.Select(
        SelPred(((0, 99),)), KeyFn((In(1),)), IDENT,
        fra.scan("A", 2),
    )
    return check_query(node, env={"A": _dense(3, 4)}, schema=SCHEMA)


def case_stale_stats():
    return check_query(
        fra.scan("A", 2),
        env={"A": _dense(3, 4)},
        stats={"A": SimpleNamespace(extents=(9, 9))},
    )


def case_non_divisible_shard():
    q = fra.Query(
        fra.Agg(KeyFn(()), ADD, fra.scan("A", 2)), inputs=("A",)
    )
    return check_query(
        q, env={"A": _dense(5, 7)}, geometry=MeshGeometry.single(4)
    )


CASES = {
    name[len("case_"):]: fn
    for name, fn in sorted(globals().items())
    if name.startswith("case_")
}


@pytest.mark.parametrize("name", sorted(CASES))
def test_golden(name):
    report = CASES[name]()
    got = report.render() + "\n"
    path = GOLDEN / f"{name}.txt"
    if os.environ.get("REGEN_GOLDEN"):
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(got)
    assert path.exists(), f"golden file missing; REGEN_GOLDEN=1 to create: {path}"
    assert got == path.read_text()


def test_every_malformed_case_is_caught_with_a_node_path():
    """The acceptance bar: every malformed golden query produces at least
    one error diagnostic, and every diagnostic carries a node path."""
    warning_only = {
        "dtype_promotion", "partial_rjp", "empty_selection",
        "stale_stats", "non_divisible_shard",
    }
    for name, fn in CASES.items():
        report = fn()
        assert report.diagnostics, name
        assert all(d.node_path for d in report.diagnostics), name
        if name in warning_only:
            assert report.ok, name
        else:
            assert not report.ok, name


def test_db_check_and_validation_error_round_trip():
    """db.check surfaces the same report the validate stage raises."""
    import jax.numpy as jnp

    db = repro.Database()
    db.put("A", jnp.zeros((3, 4)), keys=("row", "col"))
    db.put("B", jnp.zeros((5, 6)), keys=("row", "col"))
    join = fra.Join(
        eq_pred((1, 0)), jproj(L(0), L(1), R(1)), MATMUL,
        fra.scan("A", 2), fra.scan("B", 2),
    )
    q = fra.Query(fra.Agg(project_key(0, 2), ADD, join), inputs=("A", "B"))
    report = db.check(q)
    assert not report.ok
    assert report.codes() == ("join-extent-mismatch",)
    # catalog key names flow into the provenance labels
    (d,) = report.errors
    assert "A.col" in d.message and "B.row" in d.message
    with pytest.raises(ValidationError) as ei:
        db.query(q).forward()
    assert ei.value.report.codes() == report.codes()
    # explain renders the diagnostics without raising
    assert "join-extent-mismatch" in db.explain(q)
