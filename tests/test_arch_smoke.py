"""Per-architecture smoke tests: REDUCED same-family variants (≤2
superblocks, d_model≤256, ≤4 experts), one forward + one train step on CPU,
asserting output shapes and finiteness; decode-capable archs also run a
prefill→decode round trip."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.data import batch_for
from repro.models import build_model
from repro.serving import init_cache
from repro.train import make_train_step
from repro.train.trainer import init_train_state

B, S = 2, 32


def reduced(name):
    return get_config(name).reduced()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step(arch):
    cfg = reduced(arch)
    model = build_model(cfg)
    rng = np.random.default_rng(0)
    batch = batch_for(cfg, B, S, rng)
    state = init_train_state(model, jax.random.PRNGKey(0))
    step = make_train_step(model)
    params, opt_state, metrics = step(state.params, state.opt_state, batch)
    assert np.isfinite(float(metrics["loss"])), arch
    # one more step must also be finite and change the loss
    _, _, m2 = step(params, opt_state, batch)
    assert np.isfinite(float(m2["loss"]))
    assert float(m2["loss"]) != float(metrics["loss"])


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes(arch):
    cfg = reduced(arch)
    model = build_model(cfg)
    rng = np.random.default_rng(1)
    batch = batch_for(cfg, B, S, rng)
    params = model.init(jax.random.PRNGKey(1))
    logits, aux = model.train_logits(params, batch)
    assert logits.shape == (B, S, cfg.vocab), arch
    assert np.all(np.isfinite(np.asarray(logits, dtype=np.float32)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode(arch):
    cfg = reduced(arch)
    model = build_model(cfg)
    rng = np.random.default_rng(2)
    cache_len = S + (cfg.vis_seq or 0) + 4
    batch = batch_for(cfg, B, S, rng)
    params = model.init(jax.random.PRNGKey(2))

    logits, caches = model.prefill(params, batch, cache_len)
    assert logits.shape == (B, 1, cfg.vocab)

    enc_out = None
    if cfg.encoder_layers:
        enc_out = model._encode(params, batch["frames"])
    length = jnp.asarray(S + (cfg.vis_seq if cfg.vis_seq else 0), jnp.int32)
    tok = jnp.zeros((B, 1), jnp.int32)
    logits2, caches = model.decode_step(params, tok, caches, length, enc_out)
    assert logits2.shape == (B, 1, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits2, dtype=np.float32))), arch


@pytest.mark.parametrize("arch", ["llama3-405b", "falcon-mamba-7b", "zamba2-7b"])
def test_decode_from_zero_cache(arch):
    """Decode against a zero-initialized cache (the dry-run serve_step
    contract: cache arrives as an input)."""
    cfg = reduced(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(3))
    caches = init_cache(cfg, B, S)
    tok = jnp.zeros((B, 1), jnp.int32)
    logits, caches = model.decode_step(params, tok, caches, jnp.asarray(S - 1, jnp.int32))
    assert logits.shape == (B, 1, cfg.vocab)


def test_prefill_decode_consistency_dense():
    """Greedy next-token from (prefill then decode) == from train_logits
    over the concatenated sequence — validates cache semantics."""
    cfg = reduced("deepseek-coder-33b")
    model = build_model(cfg)
    rng = np.random.default_rng(4)
    batch = batch_for(cfg, 1, 8, rng)
    params = model.init(jax.random.PRNGKey(4))

    logits_full, _ = model.train_logits(params, batch)
    lp, caches = model.prefill(params, batch, cache_len=16)
    np.testing.assert_allclose(
        np.asarray(lp[:, 0]), np.asarray(logits_full[:, -1]), rtol=2e-2, atol=2e-3
    )
