"""The async serving front door (serving/service.py): continuous
batching over the Database session. Covers admission + coalescing
(concurrent single-row submits served in one prefill batch, chunked at
the bucket cap), decode bucketing (compiled once per bucket — trace
counters flat under traffic after warmup, the cold path compiles on
demand), slot reuse (early finishers release mid-group, the group
compacts to a smaller bucket), correctness against a solo-served
oracle, per-tenant model versions + hot swap through the catalog, load
shedding (queue-full and deadline), the serving edge cases (oversized /
unbucketed / zero-length requests), the unified ``db.counters()`` tree,
EOS early stop, the removal of the pre-unification telemetry shims,
and the ``_PlacedParamsCache`` fix."""

import asyncio
import gc

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.serving import (
    BucketedPrefill,
    DeadlineExceeded,
    Endpoint,
    EndpointClosed,
    Overloaded,
)

V = 11  # toy vocab


class _TinyLM:
    """Deterministic per-row toy LM: each row's next token is a pure
    function of its own running token sum — batched serving must match
    solo serving bit-for-bit, and any cross-slot leak (bad pad /
    compaction of the cache pytree) changes the output. The cache
    carries both layouts the repo uses: a stacked ``scan`` subtree
    (batch on axis 1) and a flat leaf (batch on axis 0)."""

    cfg = None

    def prefill(self, params, batch, cache_len):
        t = batch["tokens"]                                   # (B, S)
        s = jnp.sum(t, axis=1, keepdims=True)                 # (B, 1)
        nxt = (s * params).astype(jnp.int32) % V
        caches = {
            "scan": {"h": jnp.tile(s.astype(jnp.float32)[None], (2, 1, 1))},
            "state": s.astype(jnp.float32),
        }
        return jax.nn.one_hot(nxt, V), caches                 # (B, 1, V)

    def decode_step(self, params, token, caches, length, enc_out=None):
        tok = token.astype(jnp.float32)
        state = caches["state"] + tok
        scan = caches["scan"]["h"] + tok[None]
        # read the state through BOTH cache layouts: a compaction bug in
        # either batch axis corrupts the generated tokens
        s = (state + scan[0]) / 2.0
        nxt = (s.astype(jnp.int32) * params.astype(jnp.int32) + length) % V
        return (
            jax.nn.one_hot(nxt, V),
            {"scan": {"h": scan}, "state": state},
        )


def _oracle(tokens, p, n_new, seq):
    """What _TinyLM greedily generates for one row, in plain numpy."""
    s = int(np.sum(tokens))
    out = [(s * p) % V]
    length = seq
    for _ in range(n_new - 1):
        s += out[-1]
        out.append((s * p + length) % V)
        length += 1
    return out


def _endpoint(db=None, **kw):
    db = db or repro.Database()
    db.register_model("lm", _TinyLM(), jnp.asarray(3.0))
    kw.setdefault("cache_len", 16)
    kw.setdefault("buckets", [(1, 8), (2, 8), (4, 8)])
    return db, db.endpoint("lm", **kw)


def _prompts(n, seq=8, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, V, size=seq).astype(np.int64) for _ in range(n)]


# ---------------------------------------------------------------------------
# coalescing + correctness
# ---------------------------------------------------------------------------


def test_concurrent_requests_coalesce_and_match_solo_oracle():
    db, ep = _endpoint()
    prompts = _prompts(4)
    budgets = [3, 5, 2, 4]  # mixed budgets: early finishers release slots

    async def burst():
        return await asyncio.gather(*[
            ep.submit(p, max_new_tokens=n)
            for p, n in zip(prompts, budgets)
        ])

    outs = asyncio.run(burst())
    c = db.counters()["serve"]
    assert c["batches"] == 1                       # one coalesced batch
    assert c["batched_requests"] == 4
    assert c["prefill"]["steps"] == 1
    assert c["completed"] == 4 and c["failed"] == 0
    # early finishers released their slots and the group compacted down
    assert c["decode"]["slot_releases"] == 4
    assert c["decode"]["rebuckets"] >= 1
    for out, p, n in zip(outs, prompts, budgets):
        assert out.model == "lm@v1" and out.prompt_len == 8
        np.testing.assert_array_equal(
            out.token_ids, _oracle(p, 3, n, seq=8)
        )


def test_group_larger_than_max_bucket_chunks():
    db, ep = _endpoint()

    async def burst():
        return await asyncio.gather(*[
            ep.submit(p, max_new_tokens=2) for p in _prompts(6)
        ])

    outs = asyncio.run(burst())
    assert len(outs) == 6
    c = db.counters()["serve"]
    # max bucket batch is 4: six coalesced requests serve as 4 + 2
    assert c["batches"] == 2
    assert c["batched_requests"] == 6


def test_endpoint_survives_consecutive_event_loops():
    db, ep = _endpoint()
    a = asyncio.run(ep.submit(_prompts(1)[0], max_new_tokens=2))
    b = asyncio.run(ep.submit(_prompts(1)[0], max_new_tokens=2))
    np.testing.assert_array_equal(a.token_ids, b.token_ids)
    assert db.counters()["serve"]["completed"] == 2


def test_repro_serve_is_the_endpoint_front_door():
    db = repro.Database()
    db.register_model("lm", _TinyLM(), jnp.asarray(2.0))
    ep = repro.serve(db, "lm", cache_len=16, buckets=[(2, 8)])
    assert isinstance(ep, Endpoint)
    out = asyncio.run(ep.submit(_prompts(1)[0], max_new_tokens=2))
    assert out.token_ids.shape == (2,)


# ---------------------------------------------------------------------------
# decode bucketing: warm vs cold compile counts, reuse across requests
# ---------------------------------------------------------------------------


def test_warmup_compiles_every_bucket_and_traffic_adds_none():
    db, ep = _endpoint()
    assert ep.decode_buckets == [1, 2, 4]
    ep.warmup()
    c = db.counters()["serve"]
    assert c["prefill"]["compiles"] == 3           # one per (batch, seq)
    assert c["decode"]["compiles"] == 3            # one per decode bucket
    warm = (c["prefill"]["compiles"], c["decode"]["compiles"],
            c["decode"]["traces"])

    async def traffic():
        for n in (3, 2, 4, 1):                     # every bucket, twice over
            await asyncio.gather(*[
                ep.submit(p, max_new_tokens=3) for p in _prompts(n, seed=n)
            ])

    asyncio.run(traffic())
    c = db.counters()["serve"]
    # a warmed endpoint never compiles (or even retraces) on the
    # request path: decode compiled once per bucket, not per batch
    assert (c["prefill"]["compiles"], c["decode"]["compiles"],
            c["decode"]["traces"]) == warm
    assert c["decode"]["steps"] > 0


def test_cold_endpoint_compiles_on_request_path_once_per_bucket():
    db, ep = _endpoint()

    async def one(n, seed):
        return await asyncio.gather(*[
            ep.submit(p, max_new_tokens=2) for p in _prompts(n, seed=seed)
        ])

    asyncio.run(one(2, 1))
    c = db.counters()["serve"]
    assert c["prefill"]["compiles"] == 1
    assert c["decode"]["compiles"] == 1            # cold: compiled on demand
    asyncio.run(one(2, 2))                         # same bucket: reused
    c = db.counters()["serve"]
    assert c["prefill"]["compiles"] == 1
    assert c["decode"]["compiles"] == 1
    assert c["decode"]["traces"] == 1


# ---------------------------------------------------------------------------
# load shedding + lifecycle
# ---------------------------------------------------------------------------


def test_queue_full_sheds_with_overloaded():
    db, ep = _endpoint(max_queue=2)

    async def burst():
        return await asyncio.gather(
            *[ep.submit(p, max_new_tokens=2) for p in _prompts(6)],
            return_exceptions=True,
        )

    outs = asyncio.run(burst())
    shed = [o for o in outs if isinstance(o, Overloaded)]
    served = [o for o in outs if not isinstance(o, Exception)]
    # all six submits land before the scheduler first runs: two fit the
    # queue, four shed synchronously at admission
    assert len(shed) == 4 and len(served) == 2
    c = db.counters()["serve"]
    assert c["shed_queue_full"] == 4
    assert c["admitted"] == 2 and c["completed"] == 2
    assert c["queue_peak"] == 2


def test_expired_deadline_sheds_at_batch_formation():
    db, ep = _endpoint()

    async def burst():
        return await asyncio.gather(
            ep.submit(_prompts(1)[0], max_new_tokens=2),
            ep.submit(_prompts(1, seed=1)[0], max_new_tokens=2, deadline=0.0),
            return_exceptions=True,
        )

    ok, dead = asyncio.run(burst())
    assert not isinstance(ok, Exception)
    assert isinstance(dead, DeadlineExceeded)
    c = db.counters()["serve"]
    assert c["shed_deadline"] == 1
    assert c["completed"] == 1


def test_closed_endpoint_rejects_submits():
    db, ep = _endpoint()

    async def run():
        async with ep:
            await ep.submit(_prompts(1)[0], max_new_tokens=1)
        with pytest.raises(EndpointClosed):
            await ep.submit(_prompts(1)[0])

    asyncio.run(run())


# ---------------------------------------------------------------------------
# serving edge cases
# ---------------------------------------------------------------------------


def test_unservable_requests_rejected_at_submit():
    db, ep = _endpoint()

    async def run():
        with pytest.raises(ValueError, match="no bucket fits"):
            await ep.submit(np.zeros(9, np.int64))  # unbucketed seq
        with pytest.raises(ValueError, match="zero-length prompt"):
            await ep.submit(np.zeros(0, np.int64))
        with pytest.raises(ValueError, match="1-D token ids"):
            await ep.submit(np.zeros((2, 8), np.int64))
        with pytest.raises(ValueError, match="max_new_tokens"):
            await ep.submit(np.zeros(8, np.int64), max_new_tokens=0)

    asyncio.run(run())
    c = db.counters()["serve"]
    assert c["admitted"] == 0 and c["batches"] == 0


def test_oversized_batch_never_forms():
    """Submit-side bucket validation means a single row always fits, so
    the 'request larger than the largest bucket' failure mode of the old
    BatchServer surface is now a per-request ValueError (above) and a
    chunked group (test_group_larger_than_max_bucket_chunks) — the
    bucketing engine itself still refuses oversized exact batches."""
    pre = BucketedPrefill(
        _TinyLM(), cache_len=16, buckets=[(2, 8), (4, 8)]
    )
    with pytest.raises(ValueError, match="no bucket fits"):
        pre.prefill(
            jnp.asarray(1.0), {"tokens": jnp.zeros((8, 8), jnp.int32)}
        )
    assert pre.max_batch(8) == 4
    assert pre.max_batch(5) == 0


# ---------------------------------------------------------------------------
# per-tenant model versions through the catalog
# ---------------------------------------------------------------------------


def test_tenants_pin_model_versions_and_bare_names_hot_swap():
    db = repro.Database()
    db.register_model("lm", _TinyLM(), jnp.asarray(3.0))   # lm@v1
    db.register_model("lm", _TinyLM(), jnp.asarray(5.0))   # lm@v2 (latest)
    ep = db.endpoint(
        cache_len=16, buckets=[(2, 8)],
        tenants={"pinned": "lm@v1", "latest": "lm"},
    )
    p = _prompts(1)[0]

    async def pair():
        return await asyncio.gather(
            ep.submit(p, tenant="pinned", max_new_tokens=3),
            ep.submit(p, tenant="latest", max_new_tokens=3),
        )

    a, b = asyncio.run(pair())
    assert a.model == "lm@v1" and b.model == "lm@v2"
    np.testing.assert_array_equal(a.token_ids, _oracle(p, 3, 3, 8))
    np.testing.assert_array_equal(b.token_ids, _oracle(p, 5, 3, 8))
    # different versions never share a batch
    assert db.counters()["serve"]["batches"] == 2

    # a new registration hot-swaps every unpinned resolution
    db.register_model("lm", _TinyLM(), jnp.asarray(7.0))   # lm@v3
    c = asyncio.run(ep.submit(p, tenant="latest", max_new_tokens=3))
    assert c.model == "lm@v3"
    np.testing.assert_array_equal(c.token_ids, _oracle(p, 7, 3, 8))

    async def unknown():
        await ep.submit(p, tenant="nobody")

    with pytest.raises(ValueError, match="no model mapping"):
        asyncio.run(unknown())


def test_model_registry_errors():
    db = repro.Database()
    with pytest.raises(repro.CatalogError):
        db.model("ghost")
    db.register_model("lm", _TinyLM(), jnp.asarray(1.0))
    with pytest.raises(repro.CatalogError):
        db.model("lm@v9")
    with pytest.raises(ValueError, match="params="):
        db.endpoint(_TinyLM(), cache_len=8)
    with pytest.raises(ValueError, match="no default model"):
        ep = db.endpoint(cache_len=16, buckets=[(1, 8)])
        asyncio.run(ep.submit(np.zeros(8, np.int64)))


# ---------------------------------------------------------------------------
# unified telemetry tree
# ---------------------------------------------------------------------------


def test_counters_tree_shape_and_snapshot_semantics():
    db, ep = _endpoint()
    c = db.counters()
    assert set(c) == {"cache", "reshard", "spill", "serve"}
    assert set(c["cache"]) == {"hits", "misses", "evictions"}
    assert set(c["reshard"]) == {
        "calls", "resharded_calls", "bytes_moved",
        "last_call_bytes", "planned_bytes",
    }
    assert set(c["serve"]) >= {
        "requests", "admitted", "completed", "failed",
        "shed_queue_full", "shed_deadline", "batches",
        "batched_requests", "queue_peak", "prefill", "decode",
    }
    c["serve"]["requests"] = 999   # a snapshot, not the live tree
    c["cache"]["hits"] = 999
    assert db.counters()["serve"]["requests"] == 0
    assert db.counters()["cache"]["hits"] == 0
    asyncio.run(ep.submit(_prompts(1)[0], max_new_tokens=1))
    c = db.counters()
    assert c["serve"]["completed"] == 1
    assert c["cache"]["misses"] >= 1   # serving shares the session cache


# ---------------------------------------------------------------------------
# EOS early stop
# ---------------------------------------------------------------------------


def test_eos_token_releases_slot_early_with_identical_prefix():
    budget = 8
    p = _prompts(1)[0]
    db0, ep0 = _endpoint()
    base = asyncio.run(ep0.submit(p, max_new_tokens=budget))
    base_steps = db0.counters()["serve"]["decode"]["steps"]
    # pick a mid-sequence token as EOS so the stop is genuinely early
    eos = int(base.token_ids[2])
    k = list(base.token_ids).index(eos)  # first occurrence

    db, ep = _endpoint(eos_token=eos)
    out = asyncio.run(ep.submit(p, max_new_tokens=budget))
    # identical prefix up to and including the EOS token, then stop
    np.testing.assert_array_equal(out.token_ids, base.token_ids[: k + 1])
    assert len(out.token_ids) < budget
    c = db.counters()["serve"]["decode"]
    assert c["steps"] < base_steps
    assert c["eos_stops"] == 1
    assert c["slot_releases"] == 1


def test_eos_absent_decodes_full_budget():
    p = _prompts(1)[0]
    db0, ep0 = _endpoint()
    base = asyncio.run(ep0.submit(p, max_new_tokens=4))
    db, ep = _endpoint(eos_token=V + 1)  # never emitted
    out = asyncio.run(ep.submit(p, max_new_tokens=4))
    np.testing.assert_array_equal(out.token_ids, base.token_ids)
    assert db.counters()["serve"]["decode"]["eos_stops"] == 0


# ---------------------------------------------------------------------------
# the pre-unification telemetry shims are gone
# ---------------------------------------------------------------------------


def test_pre_unification_shims_are_gone():
    db = repro.Database()
    assert not hasattr(db, "cache_stats")
    assert not hasattr(db, "spill_stats")
    with pytest.raises(AttributeError):
        repro.BatchServer
    from repro.core.engine import Compiled, StreamedCompiled

    assert not hasattr(Compiled, "reshard_stats")
    assert not hasattr(StreamedCompiled, "reshard_stats")


# ---------------------------------------------------------------------------
# the params-placement cache fix (serve.py satellite)
# ---------------------------------------------------------------------------


def test_placed_params_cache_hits_evicts_and_bounds():
    from repro.serving.serve import _PlacedParamsCache

    cache = _PlacedParamsCache(capacity=2)
    # float64 numpy leaves: device_put must convert (x64 is off), so the
    # placed copy cannot zero-copy-alias the source buffer and the cache
    # entry holds no reference back to the source params
    p1 = {"w": np.ones((4,), np.float64)}
    placed = cache.place(p1, None)
    assert cache.place(p1, None) is placed         # identity hit
    assert len(cache) == 1

    # the historical leak: params released by the trainer stayed pinned
    # forever under their id. Now the weakref death callback evicts.
    del p1
    gc.collect()
    assert len(cache) == 0

    # LRU capacity bound with live params
    keep = [{"w": np.full((2,), i, np.float64)} for i in range(3)]
    for p in keep:
        cache.place(p, None)
    assert len(cache) == 2

    # id-recycling guard: a stale entry whose anchor died is not
    # returned for a new params object that happens to reuse the id
    p = keep[-1]
    ref, val = cache._entries[id(p)]
    cache._entries[id(p)] = ((lambda: object()), val)  # stale anchor
    fresh = cache.place(p, None)                       # miss, re-placed
    assert cache._entries[id(p)][1] is fresh
    assert cache._entries[id(p)][0]() is p["w"]

    cache.clear()
    assert len(cache) == 0
