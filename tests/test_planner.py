"""Distribution planner: broadcast-vs-copartition decisions from relation
sizes + per-node memory budget (the paper's §1 optimizer claim) + an
8-device SPMD execution test run in a subprocess (device count must be set
before JAX initializes)."""

import pathlib
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fra
from repro.core.kernels import ADD, MATMUL
from repro.core.keys import L, R, eq_pred, jproj, project_key
from repro.core.planner import input_pspecs, plan_join, plan_query
from repro.core.relation import DenseRelation


def matmul_join(left_leaf, right_leaf):
    return fra.Join(
        eq_pred((1, 0)),                  # A.col == B.row
        jproj(L(0), L(1), R(1)),          # paper: ⟨keyL[0], keyL[1], keyR[1]⟩
        MATMUL,
        left_leaf,
        right_leaf,
    )


def matmul_query(left="A", right="B"):
    join = matmul_join(fra.scan(left, 2), fra.scan(right, 2))
    return fra.Query(
        fra.Agg(project_key(0, 2), ADD, join), inputs=(left, right)
    )


def _sds(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def test_broadcast_small_side_chosen():
    """A small model matrix joined against a huge data matrix — the paper's
    data-parallel plan: broadcast the small side."""
    q = matmul_query()
    env = {
        "A": _sds((512, 512, 256, 256)),   # ~64 GB: must stay partitioned
        "B": _sds((512, 1, 256, 64)),      # ~32 MB: broadcastable
    }
    plans = plan_query(q, env, n_devices=16)
    (plan,) = plans.values()
    assert plan.kind == "broadcast_right"
    assert not plan.needs_psum
    # big side stays sharded on its non-contraction output dim (row),
    # small side replicated
    assert plan.left_shard_dim == 0
    assert plan.right_shard_dim is None
    assert "broadcast_left" not in plan.costs  # A exceeds the budget


def test_copartition_chosen_when_nothing_fits():
    """Two huge matrices, neither replicable within the per-node memory
    budget — the paper's tensor-parallel plan: co-partition on the join
    key, pay the output all-reduce."""
    q = matmul_query()
    env = {
        "A": _sds((512, 512, 256, 256)),   # ~64 GB each
        "B": _sds((512, 512, 256, 256)),
    }
    plans = plan_query(q, env, n_devices=16)
    (plan,) = plans.values()
    assert plan.kind == "copartition"
    assert plan.needs_psum
    # sharded on the contraction dims: A.col (dim 1), B.row (dim 0)
    assert plan.left_shard_dim == 1
    assert plan.right_shard_dim == 0
    assert set(plan.costs) == {"copartition"}


def test_cheapest_bytes_moved_wins_when_all_feasible():
    """When everything fits, the decision is by bytes moved — broadcasting
    the smaller side beats the 2×output all-reduce."""
    join = matmul_join(fra.scan("A", 2), fra.scan("B", 2))
    p = plan_join(join, 1e6, 4e6, 4e6, 16)
    assert p.kind == "broadcast_left"
    # co-partition was considered but costs 2·out > left gather
    assert p.costs["copartition"] > p.costs["broadcast_left"]


def test_memory_budget_flips_plan():
    """Exactly the paper's story: same relations, smaller nodes →
    the optimizer switches from broadcast to co-partition."""
    join = matmul_join(fra.scan("A", 2), fra.scan("B", 2))
    roomy = plan_join(join, 1e8, 1e9, 1e9, 16, mem_budget=8e9)
    tight = plan_join(join, 1e8, 1e9, 1e9, 16, mem_budget=1e7)
    assert roomy.kind == "broadcast_left"
    assert tight.kind == "copartition"


def test_plan_pspecs():
    q = matmul_query()
    env = {"A": _sds((512, 512, 256, 256)), "B": _sds((512, 512, 256, 256))}
    plans = plan_query(q, env, n_devices=16)
    specs = input_pspecs(q, plans)
    from jax.sharding import PartitionSpec as P

    assert specs["A"] == P(None, "model")
    assert specs["B"] == P("model", None)


_SPMD_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core import compiler, fra
    from repro.core.kernels import ADD, MATMUL
    from repro.core.keys import L, R, eq_pred, jproj, project_key
    from repro.core.planner import input_pspecs, plan_query
    from repro.core.relation import DenseRelation

    join = fra.Join(eq_pred((1, 0)), jproj(L(0), L(1), R(1)), MATMUL,
                    fra.scan("A", 2), fra.scan("B", 2))
    q = fra.Query(fra.Agg(project_key(0, 2), ADD, join), inputs=("A", "B"))

    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(8, 8, 16, 16)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(8, 8, 16, 16)).astype(np.float32))
    env = {"A": DenseRelation(a, 2), "B": DenseRelation(b, 2)}

    # tiny budget forces the co-partition (tensor-parallel) plan
    plans = plan_query(q, env, n_devices=8, mem_budget=1.0)
    (plan,) = plans.values()
    assert plan.kind == "copartition", plan.kind

    mesh = jax.make_mesh((8,), ("model",))
    specs = input_pspecs(q, plans)
    a_sh = jax.device_put(a, NamedSharding(mesh, specs["A"]))
    b_sh = jax.device_put(b, NamedSharding(mesh, specs["B"]))

    @jax.jit
    def run(a, b):
        return compiler.execute(
            q.root, {"A": DenseRelation(a, 2), "B": DenseRelation(b, 2)}
        ).data

    # NamedShardings carry the mesh; no global mesh context needed
    # (jax.set_mesh does not exist on this jax version).
    out = run(a_sh, b_sh)
    hlo = jax.jit(run).lower(a_sh, b_sh).compile().as_text()

    ref = run(a, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
    # the co-partition plan must have produced a contraction all-reduce
    assert "all-reduce" in hlo or "reduce-scatter" in hlo, "no psum emitted"
    print("SPMD-OK")
    """
)


@pytest.mark.spmd
def test_copartition_executes_under_spmd():
    repo = pathlib.Path(__file__).resolve().parent.parent
    r = subprocess.run(
        [sys.executable, "-c", _SPMD_SCRIPT],
        capture_output=True,
        text=True,
        env={
            "PYTHONPATH": str(repo / "src"),
            "PATH": "/usr/bin:/bin",
            "JAX_PLATFORMS": "cpu",
        },
        cwd=str(repo),
        timeout=600,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    assert "SPMD-OK" in r.stdout
