"""Relational auto-diff (Algorithms 1-2 + §4 RJPs) vs. jax.grad and finite
differences, executed through the sparse interpreter oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fra
from repro.core.autodiff import ra_autodiff
from repro.core.interpreter import run_query
from repro.core.kernels import (
    ADD,
    LOGISTIC,
    MATMUL,
    MUL,
    SQERR,
    XENT,
)
from repro.core.keys import (
    EMPTY_KEY,
    TRUE,
    L,
    R,
    eq_pred,
    identity_key,
    jproj,
    project_key,
)

jax.config.update("jax_enable_x64", True)


def dense_to_rel(x):
    x = np.asarray(x)
    if x.ndim == 1:
        return {(i,): float(x[i]) for i in range(x.shape[0])}
    return {(i, j): float(x[i, j]) for i in range(x.shape[0]) for j in range(x.shape[1])}


def rel_to_dense(rel, shape):
    out = np.zeros(shape)
    for k, v in rel.items():
        out[k] = v
    return out


# ---------------------------------------------------------------------------
# Logistic regression — the paper's running example (§2.3 / Fig 5)
# ---------------------------------------------------------------------------


def logreg_query():
    """F_Loss ≡ Σ(grp, ⊕, ⋈_const(pred, proj, ⊗_loss, F_Predict, R_y))."""
    f_matmul = fra.Agg(
        project_key(0),  # grp -> ⟨key[0]⟩
        ADD,
        fra.Join(
            eq_pred((1, 0)),               # keyL[1] == keyR[0]
            jproj(L(0), L(1)),             # ⟨keyL[0], keyL[1]⟩
            MUL,
            fra.const("Rx", 2),            # ⋈_const: data is constant
            fra.scan("theta", 1),
        ),
    )
    f_predict = fra.Select(TRUE, identity_key(1), LOGISTIC, f_matmul)
    f_loss = fra.Agg(
        EMPTY_KEY,
        ADD,
        fra.Join(
            eq_pred((0, 0)),
            jproj(L(0)),
            XENT,
            f_predict,
            fra.const("Ry", 1),
        ),
    )
    return fra.Query(f_loss, inputs=("theta",))


def logreg_loss_jax(theta, X, y):
    yhat = jax.nn.sigmoid(X @ theta)
    return jnp.sum(-y * jnp.log(yhat) + (y - 1.0) * jnp.log1p(-yhat))


def test_logreg_forward_matches_jax():
    rng = np.random.default_rng(0)
    X = rng.normal(size=(6, 4))
    y = rng.integers(0, 2, size=6).astype(float)
    theta = rng.normal(size=4) * 0.1
    env = {"Rx": dense_to_rel(X), "Ry": dense_to_rel(y), "theta": dense_to_rel(theta)}
    out = run_query(logreg_query(), env)
    ref = logreg_loss_jax(jnp.array(theta), jnp.array(X), jnp.array(y))
    assert out[()] == pytest.approx(float(ref), rel=1e-10)


def test_logreg_gradient_matches_jax():
    rng = np.random.default_rng(1)
    X = rng.normal(size=(6, 4))
    y = rng.integers(0, 2, size=6).astype(float)
    theta = rng.normal(size=4) * 0.1
    env = {"Rx": dense_to_rel(X), "Ry": dense_to_rel(y), "theta": dense_to_rel(theta)}
    prog = ra_autodiff(logreg_query())
    out, grads = prog.eval(env)
    got = rel_to_dense(grads["theta"], (4,))
    ref = jax.grad(logreg_loss_jax)(jnp.array(theta), jnp.array(X), jnp.array(y))
    np.testing.assert_allclose(got, np.asarray(ref), rtol=1e-8)


# ---------------------------------------------------------------------------
# MatMul → loss: gradient w.r.t. both operands (paper Fig 4)
# ---------------------------------------------------------------------------


def matmul_loss_query(kernel=MUL):
    from repro.core.kernels import SQUARE

    join = fra.Join(
        eq_pred((1, 0)),
        jproj(L(0), L(1), R(1)),
        kernel,
        fra.scan("A", 2),
        fra.scan("B", 2),
    )
    prod = fra.Agg(project_key(0, 2), ADD, join)
    # loss = sum of squared entries: σ(square) then Σ to one tuple
    sq = fra.Select(TRUE, identity_key(2), SQUARE, prod)
    loss = fra.Agg(EMPTY_KEY, ADD, sq)
    return fra.Query(loss, inputs=("A", "B"))


def test_matmul_grads_both_sides():
    rng = np.random.default_rng(2)
    A = rng.normal(size=(3, 4))
    B = rng.normal(size=(4, 2))
    env = {"A": dense_to_rel(A), "B": dense_to_rel(B)}
    prog = ra_autodiff(matmul_loss_query())
    out, grads = prog.eval(env)

    def loss(a, b):
        return jnp.sum((a @ b) ** 2)

    ga, gb = jax.grad(loss, argnums=(0, 1))(jnp.array(A), jnp.array(B))
    np.testing.assert_allclose(rel_to_dense(grads["A"], (3, 4)), np.asarray(ga), rtol=1e-8)
    np.testing.assert_allclose(rel_to_dense(grads["B"], (4, 2)), np.asarray(gb), rtol=1e-8)
    assert out[()] == pytest.approx(float(loss(jnp.array(A), jnp.array(B))), rel=1e-10)


def test_matmul_grads_chunked():
    # Chunked MatMul kernel (Appendix A): relational grads == dense grads.
    rng = np.random.default_rng(3)
    A = rng.normal(size=(2, 3, 4, 8))
    B = rng.normal(size=(3, 2, 8, 4))
    relA = {(i, j): jnp.array(A[i, j]) for i in range(2) for j in range(3)}
    relB = {(i, j): jnp.array(B[i, j]) for i in range(3) for j in range(2)}
    from repro.core.kernels import SQUARE

    join = fra.Join(
        eq_pred((1, 0)), jproj(L(0), L(1), R(1)), MATMUL, fra.scan("A", 2), fra.scan("B", 2)
    )
    prod = fra.Agg(project_key(0, 2), ADD, join)
    sq = fra.Select(TRUE, identity_key(2), SQUARE, prod)
    loss = fra.Agg(EMPTY_KEY, ADD, sq)
    q = fra.Query(loss, inputs=("A", "B"))
    prog = ra_autodiff(q)
    out, grads = prog.eval({"A": relA, "B": relB})

    def to_dense(x):
        return np.concatenate([np.concatenate(list(r), axis=1) for r in x], axis=0)

    dA, dB = to_dense(A), to_dense(B)

    def loss_fn(a, b):
        return jnp.sum((a @ b) ** 2)

    ga, gb = jax.grad(loss_fn, argnums=(0, 1))(jnp.array(dA), jnp.array(dB))
    gotA = to_dense(
        np.array([[np.asarray(grads["A"][(i, j)]) for j in range(3)] for i in range(2)])
    )
    gotB = to_dense(
        np.array([[np.asarray(grads["B"][(i, j)]) for j in range(2)] for i in range(3)])
    )
    np.testing.assert_allclose(gotA, np.asarray(ga), rtol=1e-8)
    np.testing.assert_allclose(gotB, np.asarray(gb), rtol=1e-8)


# ---------------------------------------------------------------------------
# Finite differences on a randomized query (selection + agg + join)
# ---------------------------------------------------------------------------


def test_grad_matches_finite_differences():
    rng = np.random.default_rng(4)
    W = rng.normal(size=(3, 3)) * 0.5
    env = {"W": dense_to_rel(W)}
    from repro.core.kernels import SQUARE

    # loss = sum_i (sum_j square(W_ij))  via σ then Σ twice
    sq = fra.Select(TRUE, identity_key(2), SQUARE, fra.scan("W", 2))
    rowsum = fra.Agg(project_key(0), ADD, sq)
    sig = fra.Select(TRUE, identity_key(1), LOGISTIC, rowsum)
    loss = fra.Agg(EMPTY_KEY, ADD, sig)
    q = fra.Query(loss, inputs=("W",))
    prog = ra_autodiff(q)
    out, grads = prog.eval(env)

    eps = 1e-6
    for i in range(3):
        for j in range(3):
            envp = {"W": dict(env["W"])}
            envp["W"][(i, j)] += eps
            envm = {"W": dict(env["W"])}
            envm["W"][(i, j)] -= eps
            fd = (run_query(q, envp)[()] - run_query(q, envm)[()]) / (2 * eps)
            assert grads["W"][(i, j)] == pytest.approx(fd, rel=1e-5), (i, j)


def test_fanout_total_derivative_add():
    # Same relation used twice: d(sum(x*x))/dx = 2x via the add rule (§5).
    rng = np.random.default_rng(5)
    x = rng.normal(size=4)
    env = {"X": dense_to_rel(x)}
    xs = fra.scan("X", 1)
    join = fra.Join(eq_pred((0, 0)), jproj(L(0)), MUL, xs, xs)
    loss = fra.Agg(EMPTY_KEY, ADD, join)
    q = fra.Query(loss, inputs=("X",))
    prog = ra_autodiff(q)
    out, grads = prog.eval(env)
    np.testing.assert_allclose(rel_to_dense(grads["X"], (4,)), 2 * x, rtol=1e-10)
