"""Relational auto-diff (Algorithms 1-2 + §4 RJPs) vs. jax.grad and finite
differences, executed through the sparse interpreter oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fra
from repro.core.autodiff import ra_autodiff
from repro.core.interpreter import run_query
from repro.core.kernels import (
    ADD,
    LOGISTIC,
    MATMUL,
    MUL,
    SQERR,
    XENT,
)
from repro.core.keys import (
    EMPTY_KEY,
    TRUE,
    L,
    R,
    eq_pred,
    identity_key,
    jproj,
    project_key,
)

jax.config.update("jax_enable_x64", True)


def dense_to_rel(x):
    x = np.asarray(x)
    if x.ndim == 1:
        return {(i,): float(x[i]) for i in range(x.shape[0])}
    return {(i, j): float(x[i, j]) for i in range(x.shape[0]) for j in range(x.shape[1])}


def rel_to_dense(rel, shape):
    out = np.zeros(shape)
    for k, v in rel.items():
        out[k] = v
    return out


# ---------------------------------------------------------------------------
# Logistic regression — the paper's running example (§2.3 / Fig 5)
# ---------------------------------------------------------------------------


def logreg_query():
    """F_Loss ≡ Σ(grp, ⊕, ⋈_const(pred, proj, ⊗_loss, F_Predict, R_y))."""
    f_matmul = fra.Agg(
        project_key(0),  # grp -> ⟨key[0]⟩
        ADD,
        fra.Join(
            eq_pred((1, 0)),               # keyL[1] == keyR[0]
            jproj(L(0), L(1)),             # ⟨keyL[0], keyL[1]⟩
            MUL,
            fra.const("Rx", 2),            # ⋈_const: data is constant
            fra.scan("theta", 1),
        ),
    )
    f_predict = fra.Select(TRUE, identity_key(1), LOGISTIC, f_matmul)
    f_loss = fra.Agg(
        EMPTY_KEY,
        ADD,
        fra.Join(
            eq_pred((0, 0)),
            jproj(L(0)),
            XENT,
            f_predict,
            fra.const("Ry", 1),
        ),
    )
    return fra.Query(f_loss, inputs=("theta",))


def logreg_loss_jax(theta, X, y):
    yhat = jax.nn.sigmoid(X @ theta)
    return jnp.sum(-y * jnp.log(yhat) + (y - 1.0) * jnp.log1p(-yhat))


def test_logreg_forward_matches_jax():
    rng = np.random.default_rng(0)
    X = rng.normal(size=(6, 4))
    y = rng.integers(0, 2, size=6).astype(float)
    theta = rng.normal(size=4) * 0.1
    env = {"Rx": dense_to_rel(X), "Ry": dense_to_rel(y), "theta": dense_to_rel(theta)}
    out = run_query(logreg_query(), env)
    ref = logreg_loss_jax(jnp.array(theta), jnp.array(X), jnp.array(y))
    assert out[()] == pytest.approx(float(ref), rel=1e-10)


def test_logreg_gradient_matches_jax():
    rng = np.random.default_rng(1)
    X = rng.normal(size=(6, 4))
    y = rng.integers(0, 2, size=6).astype(float)
    theta = rng.normal(size=4) * 0.1
    env = {"Rx": dense_to_rel(X), "Ry": dense_to_rel(y), "theta": dense_to_rel(theta)}
    prog = ra_autodiff(logreg_query())
    out, grads = prog.eval(env)
    got = rel_to_dense(grads["theta"], (4,))
    ref = jax.grad(logreg_loss_jax)(jnp.array(theta), jnp.array(X), jnp.array(y))
    np.testing.assert_allclose(got, np.asarray(ref), rtol=1e-8)


# ---------------------------------------------------------------------------
# MatMul → loss: gradient w.r.t. both operands (paper Fig 4)
# ---------------------------------------------------------------------------


def matmul_loss_query(kernel=MUL):
    from repro.core.kernels import SQUARE

    join = fra.Join(
        eq_pred((1, 0)),
        jproj(L(0), L(1), R(1)),
        kernel,
        fra.scan("A", 2),
        fra.scan("B", 2),
    )
    prod = fra.Agg(project_key(0, 2), ADD, join)
    # loss = sum of squared entries: σ(square) then Σ to one tuple
    sq = fra.Select(TRUE, identity_key(2), SQUARE, prod)
    loss = fra.Agg(EMPTY_KEY, ADD, sq)
    return fra.Query(loss, inputs=("A", "B"))


def test_matmul_grads_both_sides():
    rng = np.random.default_rng(2)
    A = rng.normal(size=(3, 4))
    B = rng.normal(size=(4, 2))
    env = {"A": dense_to_rel(A), "B": dense_to_rel(B)}
    prog = ra_autodiff(matmul_loss_query())
    out, grads = prog.eval(env)

    def loss(a, b):
        return jnp.sum((a @ b) ** 2)

    ga, gb = jax.grad(loss, argnums=(0, 1))(jnp.array(A), jnp.array(B))
    np.testing.assert_allclose(rel_to_dense(grads["A"], (3, 4)), np.asarray(ga), rtol=1e-8)
    np.testing.assert_allclose(rel_to_dense(grads["B"], (4, 2)), np.asarray(gb), rtol=1e-8)
    assert out[()] == pytest.approx(float(loss(jnp.array(A), jnp.array(B))), rel=1e-10)


def test_matmul_grads_chunked():
    # Chunked MatMul kernel (Appendix A): relational grads == dense grads.
    rng = np.random.default_rng(3)
    A = rng.normal(size=(2, 3, 4, 8))
    B = rng.normal(size=(3, 2, 8, 4))
    relA = {(i, j): jnp.array(A[i, j]) for i in range(2) for j in range(3)}
    relB = {(i, j): jnp.array(B[i, j]) for i in range(3) for j in range(2)}
    from repro.core.kernels import SQUARE

    join = fra.Join(
        eq_pred((1, 0)), jproj(L(0), L(1), R(1)), MATMUL, fra.scan("A", 2), fra.scan("B", 2)
    )
    prod = fra.Agg(project_key(0, 2), ADD, join)
    sq = fra.Select(TRUE, identity_key(2), SQUARE, prod)
    loss = fra.Agg(EMPTY_KEY, ADD, sq)
    q = fra.Query(loss, inputs=("A", "B"))
    prog = ra_autodiff(q)
    out, grads = prog.eval({"A": relA, "B": relB})

    def to_dense(x):
        return np.concatenate([np.concatenate(list(r), axis=1) for r in x], axis=0)

    dA, dB = to_dense(A), to_dense(B)

    def loss_fn(a, b):
        return jnp.sum((a @ b) ** 2)

    ga, gb = jax.grad(loss_fn, argnums=(0, 1))(jnp.array(dA), jnp.array(dB))
    gotA = to_dense(
        np.array([[np.asarray(grads["A"][(i, j)]) for j in range(3)] for i in range(2)])
    )
    gotB = to_dense(
        np.array([[np.asarray(grads["B"][(i, j)]) for j in range(2)] for i in range(3)])
    )
    np.testing.assert_allclose(gotA, np.asarray(ga), rtol=1e-8)
    np.testing.assert_allclose(gotB, np.asarray(gb), rtol=1e-8)


# ---------------------------------------------------------------------------
# Finite differences on a randomized query (selection + agg + join)
# ---------------------------------------------------------------------------


def test_grad_matches_finite_differences():
    rng = np.random.default_rng(4)
    W = rng.normal(size=(3, 3)) * 0.5
    env = {"W": dense_to_rel(W)}
    from repro.core.kernels import SQUARE

    # loss = sum_i (sum_j square(W_ij))  via σ then Σ twice
    sq = fra.Select(TRUE, identity_key(2), SQUARE, fra.scan("W", 2))
    rowsum = fra.Agg(project_key(0), ADD, sq)
    sig = fra.Select(TRUE, identity_key(1), LOGISTIC, rowsum)
    loss = fra.Agg(EMPTY_KEY, ADD, sig)
    q = fra.Query(loss, inputs=("W",))
    prog = ra_autodiff(q)
    out, grads = prog.eval(env)

    eps = 1e-6
    for i in range(3):
        for j in range(3):
            envp = {"W": dict(env["W"])}
            envp["W"][(i, j)] += eps
            envm = {"W": dict(env["W"])}
            envm["W"][(i, j)] -= eps
            fd = (run_query(q, envp)[()] - run_query(q, envm)[()]) / (2 * eps)
            assert grads["W"][(i, j)] == pytest.approx(fd, rel=1e-5), (i, j)


def test_fanout_total_derivative_add():
    # Same relation used twice: d(sum(x*x))/dx = 2x via the add rule (§5).
    rng = np.random.default_rng(5)
    x = rng.normal(size=4)
    env = {"X": dense_to_rel(x)}
    xs = fra.scan("X", 1)
    join = fra.Join(eq_pred((0, 0)), jproj(L(0)), MUL, xs, xs)
    loss = fra.Agg(EMPTY_KEY, ADD, join)
    q = fra.Query(loss, inputs=("X",))
    prog = ra_autodiff(q)
    out, grads = prog.eval(env)
    np.testing.assert_allclose(rel_to_dense(grads["X"], (4,)), 2 * x, rtol=1e-10)


# ---------------------------------------------------------------------------
# General partial-RJP fallback (the unoptimized RJP_join) on chains whose
# Σ drops the join key: these derivations used to produce bare joins with
# duplicate keys that neither interpreted nor lowered, and were only
# reachable by disabling the Σ-pushdown rewrite. They now run end to end
# through both the interpreter and the compiled engine.
# ---------------------------------------------------------------------------


def _run_both_ways(q, arrays, wrt):
    """(interpreter grads, compiled grads) for a scalar-loss query over
    dense env arrays."""
    from repro.core.engine import engine_for
    from repro.core.relation import DenseRelation

    prog = ra_autodiff(q)
    ienv = {k: dense_to_rel(v) for k, v in arrays.items()}
    _, igrads = prog.eval(ienv)
    cenv = {
        k: DenseRelation(jnp.asarray(v), np.asarray(v).ndim)
        for k, v in arrays.items()
    }
    eng = engine_for(prog)
    _, cgrads = eng.lower(cenv).compile()(cenv)
    return (
        {n: rel_to_dense(igrads[n], arrays[n].shape) for n in wrt},
        {n: np.asarray(cgrads[n].data) for n in wrt},
    )


def test_general_partial_rjp_sqerr_sigma_drops_join_key():
    # loss = Σ_{i,j,k} sqerr(R[i,j], S[j,k]): ∂⊗/∂side is non-multiplicative,
    # so RJP_join takes the general fallback; the Σ above the join drops
    # the join key j (and k), the regression this path used to fail on.
    rng = np.random.default_rng(11)
    Rm = rng.normal(size=(3, 4))
    Sm = rng.normal(size=(4, 2))
    join = fra.Join(
        eq_pred((1, 0)), jproj(L(0), L(1), R(1)), SQERR,
        fra.scan("R", 2), fra.scan("S", 2),
    )
    per_i = fra.Agg(project_key(0), ADD, join)
    q = fra.Query(fra.Agg(EMPTY_KEY, ADD, per_i), inputs=("R", "S"))

    def loss(Ra, Sa):
        return jnp.sum(0.5 * (Ra[:, :, None] - Sa[None, :, :]) ** 2)

    dR, dS = jax.grad(loss, argnums=(0, 1))(jnp.asarray(Rm), jnp.asarray(Sm))
    igrads, cgrads = _run_both_ways(q, {"R": Rm, "S": Sm}, ("R", "S"))
    for got in (igrads, cgrads):
        np.testing.assert_allclose(got["R"], np.asarray(dR), atol=1e-8)
        np.testing.assert_allclose(got["S"], np.asarray(dS), atol=1e-8)


def test_general_partial_rjp_without_fusion_or_rewrites():
    # NO_OPTS: every §4 optimization off, so even × takes the general
    # path. The join keeps all key classes (i, j, k) — a valid relation
    # without fusion — and the Σ drops j and k.
    from repro.core.autodiff import NO_OPTS

    rng = np.random.default_rng(12)
    A = rng.normal(size=(3, 4))
    B = rng.normal(size=(4, 2))
    join = fra.Join(
        eq_pred((1, 0)), jproj(L(0), L(1), R(1)), MUL,
        fra.scan("A", 2), fra.scan("B", 2),
    )
    q = fra.Query(fra.Agg(EMPTY_KEY, ADD, join), inputs=("A", "B"))
    prog = ra_autodiff(q, opts=NO_OPTS)

    ienv = {"A": dense_to_rel(A), "B": dense_to_rel(B)}
    _, igrads = prog.eval(ienv)
    np.testing.assert_allclose(
        rel_to_dense(igrads["A"], A.shape),
        B.sum(1)[None, :].repeat(3, 0),
        atol=1e-8,
    )
    np.testing.assert_allclose(
        rel_to_dense(igrads["B"], B.shape),
        A.sum(0)[:, None].repeat(2, 1),
        atol=1e-8,
    )

    from repro.core.engine import engine_for
    from repro.core.relation import DenseRelation

    cenv = {
        "A": DenseRelation(jnp.asarray(A), 2),
        "B": DenseRelation(jnp.asarray(B), 2),
    }
    # NO_OPTS grads consume the raw join intermediates, so the forward
    # must materialize them (the rjp_ablation contract).
    eng = engine_for(prog, fuse_join_agg=False)
    _, cgrads = eng.lower(cenv).compile()(cenv)
    np.testing.assert_allclose(
        np.asarray(cgrads["A"].data), B.sum(1)[None, :].repeat(3, 0), atol=1e-8
    )
    np.testing.assert_allclose(
        np.asarray(cgrads["B"].data), A.sum(0)[:, None].repeat(2, 1), atol=1e-8
    )


def test_general_partial_rjp_coo_join():
    # COO edge relation ⋈ dense nodes under a non-multiplicative ⊗:
    # the fallback derivation must produce the sparse edge gradient and
    # the scatter-added dense node gradient.
    from repro.core.engine import engine_for
    from repro.core.relation import CooRelation, DenseRelation

    rng = np.random.default_rng(13)
    n, e = 5, 12
    flat = rng.choice(n * n, size=e, replace=False)
    keys = np.stack([flat // n, flat % n], 1)
    w = rng.normal(size=e)
    x = rng.normal(size=n)
    join = fra.Join(
        eq_pred((0, 0)), jproj(L(1)), SQERR,
        fra.scan("Edge", 2), fra.scan("Node", 1),
    )
    per_dst = fra.Agg(identity_key(1), ADD, join)
    q = fra.Query(fra.Agg(EMPTY_KEY, ADD, per_dst), inputs=("Edge", "Node"))
    prog = ra_autodiff(q)

    # oracle: loss = Σ_e 0.5(w_e − x[src_e])²
    want_edge = w - x[keys[:, 0]]
    want_node = np.zeros(n)
    np.add.at(want_node, keys[:, 0], x[keys[:, 0]] - w)

    ienv = {
        "Edge": {(int(s), int(d)): float(v) for (s, d), v in zip(keys, w)},
        "Node": dense_to_rel(x),
    }
    _, igrads = prog.eval(ienv)
    for (src, dst), want in zip(keys, want_edge):
        np.testing.assert_allclose(
            igrads["Edge"][(int(src), int(dst))], want, atol=1e-8
        )
    np.testing.assert_allclose(
        rel_to_dense(igrads["Node"], x.shape), want_node, atol=1e-8
    )

    cenv = {
        "Edge": CooRelation(
            jnp.asarray(keys, jnp.int32), jnp.asarray(w), (n, n)
        ),
        "Node": DenseRelation(jnp.asarray(x), 1),
    }
    eng = engine_for(prog)
    _, cgrads = eng.lower(cenv).compile()(cenv)
    assert isinstance(cgrads["Edge"], CooRelation)
    np.testing.assert_array_equal(
        np.asarray(cgrads["Edge"].keys), keys
    )
    np.testing.assert_allclose(
        np.asarray(cgrads["Edge"].values), want_edge, atol=1e-8
    )
    np.testing.assert_allclose(
        np.asarray(cgrads["Node"].data), want_node, atol=1e-8
    )
