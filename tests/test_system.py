"""End-to-end system behaviour tests: full training loops whose backward
pass is the RA-autodiff-generated gradient query, checkpoint round-trips,
data-pipeline determinism, and serving consistency. These exercise the
whole stack (paper technique → compiled gradient queries → optimizer →
trainer/serving), not individual operators."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import compiler, fra
from repro.core.autodiff import ra_autodiff
from repro.core.kernels import ADD, LOGISTIC, MUL, XENT
from repro.core.keys import EMPTY_KEY, TRUE, L, eq_pred, identity_key, jproj, project_key
from repro.core.relation import DenseRelation
from repro.checkpoint import save_checkpoint, restore_checkpoint
from repro.data import batch_for, synthetic_graph, synthetic_lm_batches
from repro.models import build_model
from repro.optim import adam_init, adam_update
from repro.relational import gcn_conv, rel_linear, rel_matmul
from repro.train import make_train_step
from repro.train.trainer import init_train_state

# end-to-end training loops: CI's default lane skips these (-m "not slow")
pytestmark = pytest.mark.slow


# ---------------------------------------------------------------------------
# Logistic regression (paper §2.3 running example), trained end-to-end with
# the RA-generated gradient query.
# ---------------------------------------------------------------------------


def _logreg_query():
    f_matmul = fra.Agg(
        project_key(0), ADD,
        fra.Join(
            eq_pred((1, 0)), jproj(L(0), L(1)), MUL,
            fra.const("Rx", 2), fra.scan("theta", 1),
        ),
    )
    f_predict = fra.Select(TRUE, identity_key(1), LOGISTIC, f_matmul)
    f_loss = fra.Agg(
        EMPTY_KEY, ADD,
        fra.Join(eq_pred((0, 0)), jproj(L(0)), XENT, f_predict, fra.const("Ry", 1)),
    )
    return fra.Query(f_loss, inputs=("theta",))


def test_logreg_ra_training_converges_and_matches_jax():
    n, m = 512, 16
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    X = jax.random.normal(k1, (n, m))
    true_theta = jax.random.normal(k2, (m,))
    y = (X @ true_theta > 0).astype(jnp.float32)
    theta0 = jnp.zeros((m,))

    prog = ra_autodiff(_logreg_query())

    @jax.jit
    def ra_step(theta):
        env = {
            "Rx": DenseRelation(X, 2),
            "Ry": DenseRelation(y, 1),
            "theta": DenseRelation(theta, 1),
        }
        loss, grads = compiler.grad_eval(prog, env)
        return theta - 0.01 * grads["theta"].data, loss.data

    def jax_loss(theta):
        yhat = jax.nn.sigmoid(X @ theta)
        return jnp.sum(-y * jnp.log(yhat) + (y - 1.0) * jnp.log1p(-yhat))

    @jax.jit
    def jax_step(theta):
        loss, g = jax.value_and_grad(jax_loss)(theta)
        return theta - 0.01 * g, loss

    tha, thj = theta0, theta0
    losses_a, losses_j = [], []
    for _ in range(20):
        tha, la = ra_step(tha)
        thj, lj = jax_step(thj)
        losses_a.append(float(la))
        losses_j.append(float(lj))

    # converges
    assert losses_a[-1] < 0.5 * losses_a[0]
    # trajectory identical to jax.grad training (same arithmetic, Fig. 4)
    np.testing.assert_allclose(losses_a, losses_j, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(tha), np.asarray(thj), rtol=1e-3, atol=1e-5)


# ---------------------------------------------------------------------------
# GCN node classification end-to-end (paper §6 main experiment, reduced)
# ---------------------------------------------------------------------------


def test_gcn_training_improves_accuracy():
    g = synthetic_graph(n_nodes=128, n_edges=512, n_feat=16, n_labels=4, seed=0)
    keys, w, x = g["edge_keys"], g["edge_w"], g["x"]
    # learnable labels: a linear function of features so the model *can* fit
    proj = np.random.default_rng(0).normal(size=(16, 4)).astype(np.float32)
    y = jnp.asarray(np.argmax(np.asarray(x) @ proj, axis=1).astype(np.int32))

    hidden = 32
    rng = np.random.default_rng(1)
    params = {
        "w1": jnp.asarray(rng.normal(size=(16, hidden)).astype(np.float32)) * 0.1,
        "w2": jnp.asarray(rng.normal(size=(hidden, 4)).astype(np.float32)) * 0.1,
    }
    opt = adam_init(params)

    def loss_fn(params):
        h = gcn_conv(x, keys, w)
        h = jax.nn.relu(rel_linear(h, params["w1"]))
        h = gcn_conv(h, keys, w)
        logits = rel_linear(h, params["w2"])
        logp = jax.nn.log_softmax(logits)
        loss = -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))
        acc = jnp.mean((jnp.argmax(logits, axis=1) == y).astype(jnp.float32))
        return loss, acc

    @jax.jit
    def step(params, opt):
        (loss, acc), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, opt = adam_update(params, grads, opt, lr=0.05)
        return params, opt, loss, acc

    _, acc0 = loss_fn(params)
    loss_first = None
    for _ in range(30):
        params, opt, loss, acc = step(params, opt)
        if loss_first is None:
            loss_first = float(loss)
    assert float(loss) < 0.7 * loss_first
    assert float(acc) > float(acc0) + 0.1


# ---------------------------------------------------------------------------
# NNMF (paper Appendix B) via relational matmul gradients
# ---------------------------------------------------------------------------


def test_nnmf_relational_factorization_converges():
    n, d, r = 64, 48, 8
    rng = np.random.default_rng(2)
    wt = np.abs(rng.normal(size=(n, r))).astype(np.float32)
    ht = np.abs(rng.normal(size=(r, d))).astype(np.float32)
    A = jnp.asarray(wt @ ht)
    W = jnp.asarray(np.abs(rng.normal(size=(n, r))).astype(np.float32))
    H = jnp.asarray(np.abs(rng.normal(size=(r, d))).astype(np.float32))

    def loss_fn(W, H):
        return jnp.mean((rel_matmul(W, H) - A) ** 2)

    @jax.jit
    def step(W, H):
        loss, (gW, gH) = jax.value_and_grad(loss_fn, argnums=(0, 1))(W, H)
        W = jnp.maximum(W - 0.5 * gW, 0.0)   # projected GD keeps W,H ≥ 0
        H = jnp.maximum(H - 0.5 * gH, 0.0)
        return W, H, loss

    losses = []
    for _ in range(60):
        W, H, loss = step(W, H)
        losses.append(float(loss))
    assert losses[-1] < 0.2 * losses[0]
    assert bool(jnp.all(W >= 0)) and bool(jnp.all(H >= 0))


# ---------------------------------------------------------------------------
# LM trainer: reduced dense arch, loss decreases on a fixed batch
# ---------------------------------------------------------------------------


def test_lm_trainer_loss_decreases():
    cfg = get_config("deepseek-coder-33b").reduced()
    model = build_model(cfg)
    rng = np.random.default_rng(3)
    batch = batch_for(cfg, 2, 16, rng)
    state = init_train_state(model, jax.random.PRNGKey(5))
    step = make_train_step(model, lr=1e-3)
    params, opt_state = state.params, state.opt_state
    losses = []
    for _ in range(8):
        params, opt_state, metrics = step(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0]


# ---------------------------------------------------------------------------
# Checkpoint round trip: restore reproduces the exact training trajectory
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_config("olmoe-1b-7b").reduced()
    model = build_model(cfg)
    rng = np.random.default_rng(4)
    batch = batch_for(cfg, 2, 16, rng)
    state = init_train_state(model, jax.random.PRNGKey(6))
    step = make_train_step(model)

    params, opt_state, _ = step(state.params, state.opt_state, batch)
    path = save_checkpoint(str(tmp_path), 1, params, opt_state)
    assert os.path.exists(path)

    p2, o2 = restore_checkpoint(path, params, opt_state)
    # continuation from (params, opt) and (restored params, opt) is identical
    pa, oa, ma = step(params, opt_state, batch)
    pb, ob, mb = step(p2, o2, batch)
    np.testing.assert_allclose(float(ma["loss"]), float(mb["loss"]), rtol=1e-6)
    for la, lb in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb), rtol=1e-6)


# ---------------------------------------------------------------------------
# Data pipeline: deterministic by seed, different across seeds
# ---------------------------------------------------------------------------


def test_data_pipeline_determinism():
    cfg = get_config("gemma2-9b").reduced()
    it1 = synthetic_lm_batches(cfg, 2, 16, seed=7)
    it2 = synthetic_lm_batches(cfg, 2, 16, seed=7)
    it3 = synthetic_lm_batches(cfg, 2, 16, seed=8)
    b1, b2, b3 = next(it1), next(it2), next(it3)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    assert not np.array_equal(np.asarray(b1["tokens"]), np.asarray(b3["tokens"]))
    assert b1["tokens"].dtype == jnp.int32
    assert int(b1["tokens"].max()) < cfg.vocab


# ---------------------------------------------------------------------------
# RA-generated backward == native-JAX backward inside a full model step
# ---------------------------------------------------------------------------


def test_rel_backward_matches_native_in_model():
    """A 2-layer MLP built on rel_linear has gradients identical to the
    same MLP built on jnp.matmul — i.e. the RA-autodiff query compiles to
    exactly the Fig.-4 arithmetic inside a composite model."""
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(32, 24)).astype(np.float32))
    y = jnp.asarray(rng.normal(size=(32, 8)).astype(np.float32))
    params = {
        "w1": jnp.asarray(rng.normal(size=(24, 64)).astype(np.float32)) * 0.1,
        "w2": jnp.asarray(rng.normal(size=(64, 8)).astype(np.float32)) * 0.1,
    }

    def loss_rel(p):
        h = jax.nn.gelu(rel_linear(x, p["w1"]))
        return jnp.mean((rel_linear(h, p["w2"]) - y) ** 2)

    def loss_nat(p):
        h = jax.nn.gelu(x @ p["w1"])
        return jnp.mean((h @ p["w2"] - y) ** 2)

    la, ga = jax.value_and_grad(loss_rel)(params)
    lb, gb = jax.value_and_grad(loss_nat)(params)
    np.testing.assert_allclose(float(la), float(lb), rtol=1e-6)
    for k in params:
        np.testing.assert_allclose(
            np.asarray(ga[k]), np.asarray(gb[k]), rtol=1e-4, atol=1e-6
        )
