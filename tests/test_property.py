"""Property-based tests (hypothesis) on the system's invariants:

  * the chunked compiler agrees with the tuple-at-a-time interpreter
    (the paper-semantics oracle) on randomized query graphs;
  * relational auto-diff is linear in the seed cotangent (RJPs are
    linear maps);
  * the §4 RJP optimizations are semantics-preserving (all RJPOptions
    settings produce the same gradients on the oracle);
  * gradient of add = add of gradients (§5 total derivative);
  * the Pallas blocked-matmul kernel matches its jnp oracle over
    randomized shapes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import compiler, fra
from repro.core.autodiff import NO_OPTS, RJPOptions, ra_autodiff
from repro.core.interpreter import evaluate
from repro.core.kernels import (
    ADD, IDENT, MUL, NEG, RELU, SQUARE, UnaryKernel, unary,
)
from repro.core.keys import (
    EMPTY_KEY, TRUE, KeyFn, In, L, R, eq_pred, identity_key, jproj,
    project_key,
)
from repro.core.relation import DenseRelation

# ---------------------------------------------------------------------------
# Random query graphs: interpreter (oracle) == compiler
# ---------------------------------------------------------------------------

_UNARIES = ("ident", "neg", "relu", "square")


@st.composite
def query_and_env(draw):
    """A random single-input query graph + a full-grid environment."""
    arity = draw(st.integers(1, 2))
    extents = tuple(draw(st.integers(1, 3)) for _ in range(arity))
    rng = np.random.default_rng(draw(st.integers(0, 2**31 - 1)))

    def full_grid(extents):
        return {
            k: float(v)
            for k, v in np.ndenumerate(
                rng.normal(size=extents).astype(np.float32)
            )
        }

    env = {"T0": full_grid(extents)}
    node: fra.Node = fra.scan("T0", arity)
    cur_extents = list(extents)
    n_leaves = 1

    for _ in range(draw(st.integers(1, 3))):
        op = draw(st.sampled_from(("select", "agg", "join")))
        a = node.key_arity
        if a == 0:
            break  # aggregated to a scalar — nothing left to do
        if op == "select":
            kern = unary(draw(st.sampled_from(_UNARIES)))
            perm = draw(st.permutations(range(a)))
            node = fra.Select(TRUE, KeyFn(tuple(In(i) for i in perm)), kern, node)
            cur_extents = [cur_extents[i] for i in perm]
        elif op == "agg":
            keep = draw(
                st.lists(st.integers(0, a - 1), unique=True, max_size=a)
            )
            node = fra.Agg(KeyFn(tuple(In(i) for i in keep)), ADD, node)
            cur_extents = [cur_extents[i] for i in keep]
        else:  # join against a fresh leaf on one matching-extent dim
            if a == 0:
                continue
            li = draw(st.integers(0, a - 1))
            r_arity = draw(st.integers(1, 2))
            rj = draw(st.integers(0, r_arity - 1))
            r_extents = tuple(
                cur_extents[li] if j == rj else draw(st.integers(1, 3))
                for j in range(r_arity)
            )
            name = f"T{n_leaves}"
            n_leaves += 1
            env[name] = full_grid(r_extents)
            leaf = fra.scan(name, r_arity)
            # proj: all left comps + right comps except the joined one
            proj = tuple(L(i) for i in range(a)) + tuple(
                R(j) for j in range(r_arity) if j != rj
            )
            node = fra.Join(eq_pred((li, rj)), jproj(*proj), MUL, node, leaf)
            cur_extents = cur_extents + [
                r_extents[j] for j in range(r_arity) if j != rj
            ]

    q = fra.Query(node, inputs=tuple(sorted(env)))
    return q, env, tuple(cur_extents)


@settings(max_examples=40, deadline=None)
@given(query_and_env())
def test_compiler_matches_interpreter(qe):
    q, env, out_extents = qe
    oracle = evaluate(q.root, env)

    dense_env = {}
    for node in q.root.topo():
        if isinstance(node, fra.TableScan):
            rel = env[node.name]
            ext = tuple(
                max(k[i] for k in rel) + 1 for i in range(node.key_arity)
            ) if rel else ()
            data = np.zeros(ext, dtype=np.float32)
            for k, v in rel.items():
                data[k] = v
            dense_env[node.name] = DenseRelation(jnp.asarray(data), node.key_arity)

    got = compiler.execute(q.root, dense_env)
    dense = np.asarray(got.data)
    assert got.key_arity == len(out_extents)
    for key, val in oracle.items():
        np.testing.assert_allclose(dense[key], val, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# Auto-diff properties (on the §2.2 matmul-loss query)
# ---------------------------------------------------------------------------


def _mm_loss_query():
    join = fra.Join(
        eq_pred((1, 0)), jproj(L(0), L(1), R(1)), MUL,
        fra.scan("A", 2), fra.scan("B", 2),
    )
    mm = fra.Agg(project_key(0, 2), ADD, join)
    return fra.Query(fra.Agg(EMPTY_KEY, ADD, mm), inputs=("A", "B"))


def _rand_env(seed, n=3):
    rng = np.random.default_rng(seed)
    return {
        "A": DenseRelation(
            jnp.asarray(rng.normal(size=(n, n)).astype(np.float32)), 2
        ),
        "B": DenseRelation(
            jnp.asarray(rng.normal(size=(n, n)).astype(np.float32)), 2
        ),
    }


@settings(max_examples=20, deadline=None)
@given(
    st.integers(0, 2**31 - 1),
    st.floats(-3, 3, allow_nan=False),
    st.floats(-3, 3, allow_nan=False),
)
def test_rjp_linear_in_seed(seed, a, b):
    """RJPs are linear maps: grad(a·s1 + b·s2) == a·grad(s1) + b·grad(s2)."""
    prog = ra_autodiff(_mm_loss_query())
    env = _rand_env(seed)

    def grad_with_seed(sval):
        s = DenseRelation(jnp.asarray(sval, jnp.float32), 0)
        _, g = compiler.grad_eval(prog, env, seed=s)
        return np.asarray(g["A"].data)

    g1 = grad_with_seed(1.0)
    g2 = grad_with_seed(2.0)
    gc = grad_with_seed(a * 1.0 + b * 2.0)
    np.testing.assert_allclose(gc, a * g1 + b * g2, rtol=1e-4, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_rjp_opts_semantics_preserving(seed):
    """All §4 optimization settings yield identical gradients (oracle)."""
    rng = np.random.default_rng(seed)
    n = 2
    env = {
        "A": {(i, j): float(rng.normal()) for i in range(n) for j in range(n)},
        "B": {(i, j): float(rng.normal()) for i in range(n) for j in range(n)},
    }
    q = _mm_loss_query()
    ref = None
    for opts in (
        RJPOptions(True, True, True),
        RJPOptions(False, True, True),
        RJPOptions(True, False, True),
        RJPOptions(True, True, False),
        NO_OPTS,
    ):
        prog = ra_autodiff(q, opts=opts)
        _, grads = prog.eval(env)
        got = {k: dict(v) for k, v in grads.items()}
        if ref is None:
            ref = got
        else:
            assert got.keys() == ref.keys()
            for name in ref:
                assert got[name].keys() == ref[name].keys()
                for key in ref[name]:
                    assert got[name][key] == pytest.approx(
                        ref[name][key], rel=1e-8, abs=1e-10
                    )


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_grad_of_fanout_is_sum(seed):
    """§5 total derivative: if a relation feeds the loss twice, its
    gradient is the sum of both paths' contributions."""
    # loss = Σ (A ⊗mul A) over the diagonal join: d/dA = 2A
    join = fra.Join(
        eq_pred((0, 0), (1, 1)), jproj(L(0), L(1)), MUL,
        fra.scan("A", 2), fra.scan("A", 2),
    )
    q = fra.Query(fra.Agg(EMPTY_KEY, ADD, join), inputs=("A",))
    prog = ra_autodiff(q)
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.normal(size=(3, 3)).astype(np.float32))
    _, grads = compiler.grad_eval(prog, {"A": DenseRelation(a, 2)})
    np.testing.assert_allclose(
        np.asarray(grads["A"].data), 2.0 * np.asarray(a), rtol=1e-5
    )


# ---------------------------------------------------------------------------
# Pallas blocked matmul vs oracle over randomized shapes
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(
    st.integers(1, 4),
    st.integers(1, 4),
    st.integers(1, 4),
    st.sampled_from((jnp.float32, jnp.bfloat16)),
    st.integers(0, 2**31 - 1),
)
def test_pallas_matmul_random_shapes(mi, ki, ni, dtype, seed):
    from repro.kernels.matmul import ops as mm_ops
    from repro.kernels.matmul import ref as mm_ref

    m, k, n = 8 * mi, 8 * ki, 8 * ni
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.normal(size=(m, k)), dtype=dtype)
    b = jnp.asarray(rng.normal(size=(k, n)), dtype=dtype)
    got = mm_ops.blocked_matmul(a, b, interpret=True)
    want = mm_ref.matmul_ref(a, b)
    np.testing.assert_allclose(
        np.asarray(got, np.float32),
        np.asarray(want, np.float32),
        rtol=5e-2 if dtype == jnp.bfloat16 else 1e-4,
        atol=1e-2 if dtype == jnp.bfloat16 else 1e-5,
    )


# ---------------------------------------------------------------------------
# Out-of-core chunking invariants (rechunk / wave order / pad-and-mask)
# ---------------------------------------------------------------------------


@st.composite
def _chunked_relation(draw):
    """A random dense or owner-partitioned COO relation plus two valid
    chunk counts for its leading axis."""
    rng = np.random.default_rng(draw(st.integers(0, 2**31 - 1)))
    if draw(st.booleans()):
        rows = draw(st.integers(4, 40))
        width = draw(st.integers(1, 5))
        rel = DenseRelation(
            jnp.asarray(rng.normal(size=(rows, width)), jnp.float32), 1
        )
    else:
        from repro.core.relation import CooRelation, owner_partition

        n = draw(st.integers(3, 10))
        nnz = draw(st.integers(4, 60))
        keys = np.stack(
            [rng.integers(0, n, nnz), rng.integers(0, n, nnz)], 1
        )
        vals = rng.normal(size=nnz).astype(np.float32)
        rel = owner_partition(
            CooRelation(
                jnp.asarray(keys, jnp.int32), jnp.asarray(vals), (n, n)
            ),
            num_shards=draw(st.integers(1, 3)),
            dim=1,
        )
        rows = int(rel.nnz)
    a = draw(st.integers(1, max(1, rows // 2)))
    b = draw(st.integers(1, max(1, rows // 2)))
    return rel, a, b


@settings(max_examples=40, deadline=None)
@given(_chunked_relation())
def test_rechunk_round_trip_is_bit_stable(case):
    """rechunk A→B→A reproduces the original chunks bit for bit (and
    assemble ∘ split is the identity on the relation)."""
    from repro.core.relation import (
        assemble_chunks, make_manifest, rechunk, split_chunks,
    )

    rel, a, b = case
    ma = make_manifest(rel, a)
    mb = make_manifest(rel, b)
    ca = split_chunks(rel, ma)
    cb = rechunk(ca, ma, mb)
    ca2 = rechunk(cb, mb, ma)
    for x, y in zip(ca, ca2):
        for lx, ly in zip(jax.tree_util.tree_leaves(x),
                          jax.tree_util.tree_leaves(y)):
            np.testing.assert_array_equal(np.asarray(lx), np.asarray(ly))
    back = assemble_chunks(ca2, ma)
    for lx, ly in zip(jax.tree_util.tree_leaves(rel),
                      jax.tree_util.tree_leaves(back)):
        np.testing.assert_array_equal(np.asarray(lx), np.asarray(ly))


@settings(max_examples=30, deadline=None)
@given(
    st.integers(4, 48),
    st.integers(1, 4),
    st.integers(2, 6),
    st.integers(0, 2**31 - 1),
)
def test_chunked_sum_is_wave_order_invariant(rows, width, chunks, seed):
    """Σ accumulated over chunk waves agrees with the in-core Σ for any
    wave processing order (floating-point tolerance, not bit equality:
    + is commutative but not associative)."""
    from repro.core.relation import make_manifest, split_chunks

    chunks = min(chunks, rows)
    rng = np.random.default_rng(seed)
    rel = DenseRelation(
        jnp.asarray(rng.normal(size=(rows, width)), jnp.float32), 1
    )
    mani = make_manifest(rel, chunks)
    parts = [
        jnp.sum(c.data, axis=0) for c in split_chunks(rel, mani)
    ]
    want = np.asarray(jnp.sum(rel.data, axis=0))
    order = rng.permutation(len(parts))
    acc = jnp.zeros_like(parts[0])
    for w in order:
        acc = acc + parts[w]
    np.testing.assert_allclose(np.asarray(acc), want, atol=1e-5)


@settings(max_examples=30, deadline=None)
@given(
    st.integers(2, 8),
    st.integers(3, 40),
    st.integers(0, 16),
    st.integers(0, 2**31 - 1),
)
def test_pad_and_mask_never_leaks_pad_rows(n, nnz, extra, seed):
    """A padded COO Σ equals the unpadded one: COO_PAD_KEY rows are
    masked out of every aggregate, and the pad keys never appear in a
    gradient's key column."""
    from repro.core.engine import RAEngine
    from repro.core.relation import COO_PAD_KEY, CooRelation, pad_coo_nnz

    rng = np.random.default_rng(seed)
    keys = np.stack([rng.integers(0, n, nnz), rng.integers(0, n, nnz)], 1)
    vals = rng.normal(size=nnz).astype(np.float32)
    coo = CooRelation(jnp.asarray(keys, jnp.int32), jnp.asarray(vals), (n, n))
    padded = pad_coo_nnz(coo, nnz + extra)
    q = fra.Query(
        fra.Agg(identity_key(1), ADD,
                fra.Select(TRUE, project_key(1), IDENT, fra.scan("E", 2))),
        inputs=("E",),
    )
    eng = RAEngine(q)
    want = eng.lower({"E": coo}).compile()({"E": coo})
    got = eng.lower({"E": padded}).compile()({"E": padded})
    np.testing.assert_allclose(
        np.asarray(got.data), np.asarray(want.data), atol=1e-5
    )
    if extra:
        assert np.all(np.asarray(padded.keys)[nnz:] == COO_PAD_KEY)
