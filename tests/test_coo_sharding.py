"""COO nnz-dimension sharding: the planner's scatter-vs-replicate
decision (with the owner-partition edge-cut estimate and the
committed-layout rechunk fold), the owner-partitioned relation layout,
the gather_join dispatch op, the zero-nnz Σ guard, pad-and-mask for
non-divisible nnz, reshard accounting — and, under the tier1-spmd lane's
8 virtual devices, the acceptance path: a GCN grad step over an
nnz-sharded edge relation on the 4×2 host mesh matches the single-device
oracle to 1e-5."""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import fra
from repro.core.autodiff import ra_autodiff
from repro.core.engine import (
    RAEngine,
    ReshardWarning,
    ShardFallbackWarning,
    _committed_layouts,
)
from repro.core.kernels import ADD, MATMUL, MUL, SQUARE, SUM_CHUNK
from repro.core.keys import (
    EMPTY_KEY,
    TRUE,
    L,
    R,
    eq_pred,
    identity_key,
    jproj,
    project_key,
)
from repro.core.planner import (
    EDGE_CUT_LOCAL,
    MeshGeometry,
    input_pspecs,
    plan_join,
    plan_query,
)
from repro.core.relation import (
    COO_PAD_KEY,
    CooRelation,
    DenseRelation,
    owner_partition,
    pad_coo_nnz,
)
from repro.launch.mesh import make_host_mesh

requires8 = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs 8 devices (tier1-spmd lane: "
    "XLA_FLAGS=--xla_force_host_platform_device_count=8)",
)

GEO = MeshGeometry("model", 2, ("data",), 4)


def gcn_query(edge_input: bool = True):
    join = fra.Join(
        eq_pred((0, 0)), jproj(L(1)), MUL,
        fra.scan("Edge", 2), fra.scan("Node", 1),
    )
    inputs = ("Edge", "Node") if edge_input else ("Node",)
    return fra.Query(fra.Agg(identity_key(1), ADD, join), inputs=inputs)


def gcn_grad_prog():
    q = gcn_query()
    sq = fra.Select(TRUE, identity_key(1), SQUARE, q.root)
    loss = fra.Agg(
        EMPTY_KEY, ADD, fra.Select(TRUE, identity_key(1), SUM_CHUNK, sq)
    )
    return ra_autodiff(fra.Query(loss, inputs=("Edge", "Node")))


def gcn_env(rng, n, nnz, d, *, shards=None):
    src = rng.integers(0, n, size=nnz)
    dst = rng.integers(0, n, size=nnz)
    # weights scaled by 1/sqrt(mean degree) keep gradient magnitudes O(1),
    # so the atol-1e-5 oracle checks measure agreement, not summation scale
    w = rng.normal(size=nnz) / np.sqrt(max(nnz / n, 1.0))
    edge = CooRelation(
        jnp.asarray(np.stack([src, dst], 1), jnp.int32),
        jnp.asarray(w, jnp.float32),
        (n, n),
    )
    if shards is not None:
        edge = owner_partition(edge, shards, dim=1)
    return {
        "Edge": edge,
        "Node": DenseRelation(
            jnp.asarray(rng.normal(size=(n, d)), jnp.float32), 1
        ),
    }


def _coo(nnz, n=64, chunk=()):
    return CooRelation(
        jnp.zeros((nnz, 2), jnp.int32),
        jnp.zeros((nnz,) + chunk, jnp.float32),
        (n, n),
    )


# ---------------------------------------------------------------------------
# Planner: scatter-vs-replicate crossover, edge cut, rechunk fold
# ---------------------------------------------------------------------------


def test_planner_shards_nnz_when_edges_dominate():
    """A big edge list against small node features: sharding the nnz rows
    (psum_scatter of the segment grid) beats replicating the COO."""
    env = {"Edge": _coo(100_000), "Node": DenseRelation(jnp.zeros((64, 8), jnp.float32), 1)}
    q = gcn_query()
    plans = plan_query(q, env, 2, geometry=GEO)
    (plan,) = plans.values()
    assert plan.coo_sides == (True, False)
    assert plan.data_kind == "data:shard_nnz_left"
    assert plan.nnz_sharded("left") and not plan.nnz_sharded("right")
    assert plan.needs_data_psum          # the planned scatter collective
    assert plan.costs["data:shard_nnz_left"] < plan.costs["data:replicate"]
    specs = input_pspecs(q, plans)
    assert specs["Edge"] == P("data")    # nnz rows on the data axes
    # a COO side never carries the model axis / a key-dim spec
    assert plan.left_shard_dim is None


def test_planner_replicates_small_edge_lists():
    """The crossover: few edges against a big node grid — replicating the
    COO is cheaper than paying the Σ's scatter."""
    env = {"Edge": _coo(16, n=2048), "Node": DenseRelation(jnp.zeros((2048, 64), jnp.float32), 1)}
    q = gcn_query()
    plans = plan_query(q, env, 2, geometry=GEO)
    (plan,) = plans.values()
    assert plan.data_kind == "data:replicate"
    assert input_pspecs(q, plans)["Edge"] == P()


def test_coo_side_is_never_key_sharded():
    """nnz rows are not key-sharded: when both sides bust the memory
    budget (the copartition trigger) only the *dense* side co-partitions
    on the contraction key — the COO side's shard dim stays None and its
    nnz rows still land on the data axes."""
    env = {"Edge": _coo(100_000), "Node": DenseRelation(jnp.zeros((64, 8), jnp.float32), 1)}
    q = gcn_query()
    plans = plan_query(q, env, 2, mem_budget=1.0, geometry=GEO)
    (plan,) = plans.values()
    assert plan.kind == "copartition"        # the memory-feasible 1-D plan
    assert plan.left_shard_dim is None       # COO side: no key dims
    assert plan.right_shard_dim == 0         # dense side: contraction key
    assert plan.data_kind == "data:shard_nnz_left"
    assert input_pspecs(q, plans)["Edge"] == P("data")


def test_owner_partition_discounts_the_scatter():
    """An edge relation owner-partitioned on the Σ's segment key (dst)
    prices the scatter at the EDGE_CUT_LOCAL fraction."""
    q = gcn_query()
    plain = {"Edge": _coo(100_000), "Node": DenseRelation(jnp.zeros((64, 8), jnp.float32), 1)}
    part = dict(plain)
    part["Edge"] = owner_partition(plain["Edge"], GEO.data_size, dim=1)
    (p_plain,) = plan_query(q, plain, 2, geometry=GEO).values()
    (p_part,) = plan_query(q, part, 2, geometry=GEO).values()
    c_plain = p_plain.costs["data:shard_nnz_left"]
    c_part = p_part.costs["data:shard_nnz_left"]
    assert c_part < c_plain
    # the difference is exactly the (1 - EDGE_CUT_LOCAL) scatter discount
    frac_d = (GEO.data_size - 1) / GEO.data_size
    dense_bytes = 64 * 8 * 4.0
    scatter_full = dense_bytes * frac_d     # min(sum_out, dense) = dense
    np.testing.assert_allclose(
        c_plain - c_part, scatter_full * (1.0 - EDGE_CUT_LOCAL), rtol=1e-6
    )
    # partitioned on src (not the segment key): no discount
    wrong = dict(plain)
    wrong["Edge"] = owner_partition(plain["Edge"], GEO.data_size, dim=0)
    (p_wrong,) = plan_query(q, wrong, 2, geometry=GEO).values()
    np.testing.assert_allclose(
        p_wrong.costs["data:shard_nnz_left"], c_plain, rtol=1e-6
    )


def matmul_join():
    return fra.Join(
        eq_pred((1, 0)), jproj(L(0), L(1), R(1)), MATMUL,
        fra.scan("A", 2), fra.scan("B", 2),
    )


def test_committed_layout_fold_flips_the_plan():
    """The device-layout rechunk cost (ROADMAP follow-up): a side
    committed to the wrong layout charges the all-to-all, flipping a
    copartition win into a broadcast."""
    join = matmul_join()
    free = plan_join(join, 1e6, 1e6, 1e5, 16)
    assert free.kind == "copartition"
    # A is committed with the model axis on dim 0; copartition needs its
    # contraction dim 1 — the fold charges A's all-to-all
    committed = ({"model": 0, "data": None}, None)
    folded = plan_join(join, 1e6, 1e6, 1e5, 16, committed_dims=committed)
    assert folded.kind == "broadcast_left"
    frac = 15 / 16
    np.testing.assert_allclose(
        folded.costs["copartition"] - free.costs["copartition"],
        1e6 * frac,
        rtol=1e-6,
    )
    # a matching committed layout charges nothing
    aligned = plan_join(
        join, 1e6, 1e6, 1e5, 16,
        committed_dims=({"model": 1, "data": None}, None),
    )
    assert aligned.costs["copartition"] == free.costs["copartition"]


def test_plan_query_threads_committed_specs():
    q = fra.Query(
        fra.Agg(project_key(0, 2), ADD, matmul_join()), inputs=("A", "B")
    )
    env = {
        "A": jax.ShapeDtypeStruct((512, 512, 16, 16), jnp.float32),
        "B": jax.ShapeDtypeStruct((512, 512, 16, 16), jnp.float32),
    }
    free = plan_query(q, env, 16)
    folded = plan_query(
        q, env, 16, committed={"A": P("model", None), "B": P(None, "model")}
    )
    (pf,), (pc,) = free.values(), folded.values()
    assert pc.costs["copartition"] > pf.costs["copartition"]


# ---------------------------------------------------------------------------
# Relation layer: owner partition + pad-and-mask
# ---------------------------------------------------------------------------


def test_owner_partition_sorts_pads_and_records_offsets():
    keys = jnp.asarray([[0, 3], [1, 0], [2, 2], [3, 1], [4, 3]], jnp.int32)
    vals = jnp.asarray([3.0, 0.0, 2.0, 1.0, 3.5], jnp.float32)
    rel = owner_partition(CooRelation(keys, vals, (5, 4)), 4, dim=1)
    assert rel.owner_dim == 1
    assert rel.nnz == 8                     # padded 5 -> multiple of 4
    dst = np.asarray(rel.keys[:, 1])
    assert list(dst[:5]) == sorted(dst[:5])  # sorted by owner key
    assert (dst[5:] == COO_PAD_KEY).all()    # inert padding rows
    np.testing.assert_array_equal(np.asarray(rel.values[5:]), 0.0)
    assert rel.shard_offsets == (0, 2, 3, 4)
    # a shard whose rows are all padding owns no segments: it records the
    # one-past-the-end owner extent
    tiny = owner_partition(
        CooRelation(keys[:2], vals[:2], (5, 4)), 4, dim=1
    )
    assert tiny.shard_offsets == (0, 3, 4, 4)
    # aux data (layout metadata) survives the pytree roundtrip
    leaves, treedef = jax.tree_util.tree_flatten(rel)
    back = jax.tree_util.tree_unflatten(treedef, leaves)
    assert back.owner_dim == 1 and back.shard_offsets == rel.shard_offsets


def test_pad_coo_nnz_is_numerically_inert():
    rng = np.random.default_rng(0)
    env = gcn_env(rng, n=16, nnz=30, d=4)
    padded = dict(env)
    padded["Edge"] = pad_coo_nnz(env["Edge"], 37)
    q = gcn_query()
    out = RAEngine(q).lower(env).compile()(env)
    outp = RAEngine(q).lower(padded).compile()(padded)
    np.testing.assert_allclose(
        np.asarray(outp.data), np.asarray(out.data), rtol=1e-6
    )


# ---------------------------------------------------------------------------
# gather_join dispatch + the zero-nnz Σ guard
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("tier", ("jnp", "ref", "interpret"))
def test_gather_join_resolves_and_is_recorded(tier):
    rng = np.random.default_rng(1)
    env = gcn_env(rng, n=16, nnz=40, d=8)
    prog = gcn_grad_prog()
    comp = RAEngine(prog).lower(env, dispatch=tier).compile()
    gathers = [k for k in comp.resolutions if k.startswith("gather_join[")]
    assert gathers, "no gather_join site recorded"
    assert {comp.resolutions[k] for k in gathers} == {tier}


@pytest.mark.parametrize("tier", ("ref", "interpret"))
def test_gather_join_tiers_match_jnp(tier):
    """Forward + relational gradients agree across gather tiers — the
    edge gradient exercises the restricted-join gather, the node gradient
    the reversed-edge gather."""
    rng = np.random.default_rng(2)
    env = gcn_env(rng, n=16, nnz=40, d=8)
    prog = gcn_grad_prog()
    eng = RAEngine(prog)
    out_j, grads_j = eng.lower(env, dispatch="jnp").compile()(env)
    out_t, grads_t = eng.lower(env, dispatch=tier).compile()(env)
    np.testing.assert_allclose(
        np.asarray(out_t.data), np.asarray(out_j.data), atol=1e-5
    )
    np.testing.assert_allclose(
        np.asarray(grads_t["Node"].data),
        np.asarray(grads_j["Node"].data),
        atol=1e-5,
    )
    np.testing.assert_allclose(
        np.asarray(grads_t["Edge"].values),
        np.asarray(grads_j["Edge"].values),
        atol=1e-5,
    )


@pytest.mark.parametrize("tier", ("jnp", "ref", "interpret"))
def test_zero_nnz_aggregate_is_guarded_across_tiers(tier):
    """Σ over an empty CooRelation: every registered tier produces the
    same zero grid with the values' dtype — the lowering never reaches a
    tier-specific empty segment_sum."""
    env = {
        "Edge": CooRelation(
            jnp.zeros((0, 2), jnp.int32), jnp.zeros((0,), jnp.float32), (8, 8)
        ),
        "Node": DenseRelation(jnp.ones((8, 4), jnp.float32), 1),
    }
    q = gcn_query()
    comp = RAEngine(q).lower(env, dispatch=tier).compile()
    out = comp(env)
    assert isinstance(out, DenseRelation)
    assert out.data.shape == (8, 4) and out.data.dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(out.data), 0.0)


def test_select_over_padded_coo_keeps_pad_rows_inert():
    """A σ kernel with f(0) != 0 (exp) must not resurrect padded rows:
    they are re-masked before a full-reduce Σ can sum them."""
    from repro.core.kernels import EXP

    keys = jnp.asarray([[0, 1], [1, 2], [2, 0]], jnp.int32)
    vals = jnp.asarray([0.5, -1.0, 2.0], jnp.float32)
    edge = owner_partition(CooRelation(keys, vals, (4, 4)), 4, dim=1)
    assert edge.nnz == 4                       # one padded row
    q = fra.Query(
        fra.Agg(
            EMPTY_KEY, ADD,
            fra.Select(TRUE, identity_key(2), EXP, fra.scan("Edge", 2)),
        ),
        inputs=("Edge",),
    )
    out = RAEngine(q).lower({"Edge": edge}).compile()({"Edge": edge})
    np.testing.assert_allclose(
        float(out.data), float(np.sum(np.exp(np.asarray(vals)))), rtol=1e-6
    )


def test_zero_nnz_gradients_are_guarded():
    env = {
        "Edge": CooRelation(
            jnp.zeros((0, 2), jnp.int32), jnp.zeros((0,), jnp.float32), (8, 8)
        ),
        "Node": DenseRelation(jnp.ones((8, 4), jnp.float32), 1),
    }
    prog = gcn_grad_prog()
    out, grads = RAEngine(prog).lower(env).compile()(env)
    np.testing.assert_array_equal(np.asarray(out.data), 0.0)
    assert grads["Edge"].values.shape == (0,)
    np.testing.assert_array_equal(np.asarray(grads["Node"].data), 0.0)


# ---------------------------------------------------------------------------
# SPMD acceptance: the 4×2 host mesh (tier1-spmd lane)
# ---------------------------------------------------------------------------


@pytest.mark.spmd
@requires8
def test_gcn_grad_step_nnz_sharded_matches_oracle():
    """Acceptance: on the 4×2 (data × model) host mesh the compiled GCN
    grad step shards the edge relation's nnz rows over "data"
    (Compiled.placements reports it), routes the gather join through the
    dispatch registry, emits the Σ's scatter collective, and matches the
    single-device oracle to 1e-5."""
    mesh = make_host_mesh(model=2)
    rng = np.random.default_rng(3)
    env = gcn_env(rng, n=64, nnz=8192, d=8, shards=4)
    prog = gcn_grad_prog()
    eng = RAEngine(prog)
    low = eng.lower(env)

    comp = low.compile(mesh=mesh)
    assert comp.placements["Edge"] == {"data": 0, "model": None}
    assert any(k.startswith("gather_join[") for k in comp.resolutions)
    (plan,) = comp.plans.values()
    assert plan.data_kind == "data:shard_nnz_left"

    out_s, grads_s = comp(env)
    walks = eng.trace_count
    comp(env)                                # jit cache hit: no re-walk
    assert eng.trace_count == walks

    out_1, grads_1 = low.compile()(env)      # single-device oracle
    np.testing.assert_allclose(
        np.asarray(out_s.data), np.asarray(out_1.data), atol=1e-5, rtol=1e-5
    )
    np.testing.assert_allclose(
        np.asarray(grads_s["Node"].data),
        np.asarray(grads_1["Node"].data),
        atol=1e-5,
        rtol=1e-5,
    )
    np.testing.assert_allclose(
        np.asarray(grads_s["Edge"].values),
        np.asarray(grads_1["Edge"].values),
        atol=1e-5,
        rtol=1e-5,
    )
    # the sharded Σ-over-edges must have produced its scatter collective
    hlo = comp.lower_text()
    assert "all-reduce" in hlo or "reduce-scatter" in hlo


@pytest.mark.spmd
@requires8
def test_non_divisible_nnz_is_padded_not_replicated():
    """8191 edges on 4 data shards: the engine pads the nnz axis
    (pad-and-mask) instead of silently replicating, results still match
    the oracle, and outputs come back unpadded."""
    mesh = make_host_mesh(model=2)
    rng = np.random.default_rng(4)
    env = gcn_env(rng, n=64, nnz=8191, d=8)
    prog = gcn_grad_prog()
    low = RAEngine(prog).lower(env)
    comp = low.compile(mesh=mesh)
    assert comp.pad_nnz == {"Edge": 8192}
    assert comp.placements["Edge"] == {"data": 0, "model": None}
    out_s, grads_s = comp(env)
    assert grads_s["Edge"].values.shape == (8191,)
    out_1, grads_1 = low.compile()(env)
    np.testing.assert_allclose(
        np.asarray(out_s.data), np.asarray(out_1.data), atol=1e-5, rtol=1e-5
    )
    np.testing.assert_allclose(
        np.asarray(grads_s["Edge"].values),
        np.asarray(grads_1["Edge"].values),
        atol=1e-5,
        rtol=1e-5,
    )


@pytest.mark.spmd
@requires8
def test_coo_pspecs_place_the_nnz_rows():
    """launch/sharding.coo_pspecs: the manual device_put layout matches
    the planner's nnz-row fold — each data-shard holds nnz/4 rows."""
    from repro.launch.sharding import coo_pspecs, to_shardings

    mesh = make_host_mesh(model=2)
    edge = _coo(8192)
    placed = jax.device_put(edge, to_shardings(coo_pspecs(edge, mesh), mesh))
    rows = {s.data.shape[0] for s in placed.values.addressable_shards}
    assert rows == {8192 // 4}
    assert {s.data.shape for s in placed.keys.addressable_shards} == {(2048, 2)}
    assert placed.extents == edge.extents


@pytest.mark.spmd
@requires8
def test_dense_fallback_emits_structured_warning():
    """A dense extent the mesh axes do not divide falls back to
    replication with a ShardFallbackWarning naming relation and extents."""
    from repro.core.kernels import LOGISTIC, XENT

    f_matmul = fra.Agg(
        project_key(0), ADD,
        fra.Join(
            eq_pred((1, 0)), jproj(L(0), L(1)), MUL,
            fra.const("Rx", 2), fra.scan("theta", 1),
        ),
    )
    f_predict = fra.Select(TRUE, identity_key(1), LOGISTIC, f_matmul)
    f_loss = fra.Agg(
        EMPTY_KEY, ADD,
        fra.Join(eq_pred((0, 0)), jproj(L(0)), XENT, f_predict, fra.const("Ry", 1)),
    )
    prog = ra_autodiff(fra.Query(f_loss, inputs=("theta",)))
    rng = np.random.default_rng(5)
    env = {
        "Rx": DenseRelation(jnp.asarray(rng.normal(size=(65, 8)), jnp.float32), 2),
        "Ry": DenseRelation(jnp.ones((65,), jnp.float32), 1),
        "theta": DenseRelation(jnp.zeros((8,), jnp.float32), 1),
    }
    mesh = make_host_mesh(model=2)
    with pytest.warns(ShardFallbackWarning) as rec:
        RAEngine(prog).lower(env).compile(mesh=mesh)
    falls = {
        r.message.relation: r.message
        for r in rec
        if isinstance(r.message, ShardFallbackWarning)
    }
    w = falls["Rx"]
    assert w.extent == 65 and w.divisor == 4


@pytest.mark.spmd
@requires8
def test_reshard_stats_count_committed_moves_and_warn_once():
    """The silent-reshard fix: committed inputs arriving in a different
    layout are counted on Compiled.counters["reshard"], warned about once per
    cache entry, and foldable into the plan via _committed_layouts."""
    mesh = make_host_mesh(model=2)
    rng = np.random.default_rng(6)
    n, m = 64, 8
    env = {
        "A": DenseRelation(jnp.asarray(rng.normal(size=(n, n, m, m)), jnp.float32), 2),
        "B": DenseRelation(jnp.asarray(rng.normal(size=(n, n, m, m)), jnp.float32), 2),
    }
    q = fra.Query(
        fra.Agg(project_key(0, 2), ADD, matmul_join()), inputs=("A", "B")
    )
    low = RAEngine(q).lower(env)
    comp = low.compile(mesh=mesh)
    # commit A against the planned layout
    wrong = NamedSharding(mesh, P(None, None, "model", None))
    env_wrong = dict(env)
    env_wrong["A"] = DenseRelation(jax.device_put(env["A"].data, wrong), 2)
    assert set(_committed_layouts(env_wrong)) == {"A"}
    with pytest.warns(ReshardWarning):
        comp(env_wrong)
    nbytes = int(env["A"].data.nbytes)
    assert comp.counters["reshard"]["resharded_calls"] == 1
    assert comp.counters["reshard"]["last_call_bytes"] == nbytes
    with warnings.catch_warnings():
        warnings.simplefilter("error", ReshardWarning)  # once per entry
        comp(env_wrong)
    assert comp.counters["reshard"]["bytes_moved"] == 2 * nbytes
    assert comp.counters["reshard"]["calls"] == comp.counters["reshard"]["resharded_calls"] + 0
    # matching layouts move nothing
    comp2 = low.compile(mesh=mesh, committed=_committed_layouts(env))
    comp2(env)
    assert comp2.counters["reshard"]["last_call_bytes"] == 0
    # committed *replicated* inputs shard by a local slice — zero bytes
    # moved, no warning (and plan_join's _move fold charges them nothing)
    env_rep = dict(env)
    env_rep["A"] = DenseRelation(
        jax.device_put(env["A"].data, NamedSharding(mesh, P())), 2
    )
    comp3 = low.compile(mesh=mesh, donate=("B",))  # fresh cache entry
    with warnings.catch_warnings():
        warnings.simplefilter("error", ReshardWarning)
        comp3(env_rep)
    assert comp3.counters["reshard"]["last_call_bytes"] == 0
