"""Kernel dispatch (core/kernels.py registry + engine threading).

Covers the registry contract — tier resolution order, backend gating,
predicate fall-through — the numerical agreement of the CPU tiers
(Pallas interpret-mode vs the ref.py oracles, forward *and* gradient),
and the staging contract: the DispatchTable is part of the lowering
signature, so switching tiers invalidates the engine's lowering cache
while re-using a tier hits it.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compiler, fra
from repro.core import kernels as K
from repro.core.autodiff import ra_autodiff
from repro.core.engine import RAEngine
from repro.core.kernels import ADD, LOGISTIC, MUL, XENT
from repro.core.keys import (
    EMPTY_KEY,
    TRUE,
    L,
    eq_pred,
    identity_key,
    jproj,
    project_key,
)
from repro.core.relation import CooRelation, DenseRelation

CPU_TIERS = ("jnp", "ref", "interpret")


# ---------------------------------------------------------------------------
# Registry resolution order
# ---------------------------------------------------------------------------


def test_default_table_is_jnp_on_cpu():
    t = K.default_table("cpu")
    for op in K.DISPATCH_OPS:
        assert t.tiers(op) == ("jnp",)
        assert K.resolve_impl(op, {"dtype": jnp.float32}, t).tier == "jnp"


def test_default_table_prefers_pallas_on_tpu():
    t = K.default_table("tpu")
    for op in K.DISPATCH_OPS:
        assert t.tiers(op) == ("pallas", "jnp")
        # resolution honours the table's pinned backend, not the host's
        assert K.resolve_impl(op, {"dtype": jnp.float32}, t).tier == "pallas"


@pytest.mark.parametrize("tier", CPU_TIERS)
def test_forced_tier_resolves_that_tier(tier):
    t = K.make_table(tier, backend="cpu")
    for op in K.DISPATCH_OPS:
        assert K.resolve_impl(op, {"dtype": jnp.float32}, t).tier == tier


def test_tier_order_walked_in_sequence():
    t = K.make_table(("interpret", "ref", "jnp"), backend="cpu")
    impl = K.resolve_impl("segment_sum", {"dtype": jnp.float32}, t)
    assert impl.tier == "interpret"
    # int dtype fails the interpret predicate → falls through to ref
    impl = K.resolve_impl("segment_sum", {"dtype": jnp.int32}, t)
    assert impl.tier == "ref"


def test_pallas_tier_is_tpu_only():
    t = K.make_table("pallas", backend="cpu")
    with pytest.raises(K.KernelDispatchError):
        K.resolve_impl("blocked_matmul", {"dtype": jnp.float32}, t)


def test_make_table_validates():
    with pytest.raises(ValueError, match="unknown tier"):
        K.make_table("mxu")
    with pytest.raises(ValueError, match="unknown op"):
        K.make_table({"softmax": "jnp"})
    with pytest.raises(TypeError):
        K.make_table(3.14)


def test_make_table_rejects_cross_backend_reinterpretation():
    tpu_table = K.default_table("tpu")
    assert K.make_table(tpu_table) is tpu_table          # passthrough
    assert K.make_table(tpu_table, backend="tpu") is tpu_table
    with pytest.raises(ValueError, match="pinned to backend"):
        K.make_table(tpu_table, backend="cpu")


def test_make_table_dict_keeps_defaults_for_unmentioned_ops():
    t = K.make_table({"segment_sum": "ref"}, backend="cpu")
    assert t.tiers("segment_sum") == ("ref",)
    assert t.tiers("blocked_matmul") == ("jnp",)


def test_tables_are_hashable_and_compare_by_value():
    a = K.make_table("ref", backend="cpu")
    b = K.make_table("ref", backend="cpu")
    assert a == b and hash(a) == hash(b)
    assert a != K.make_table("jnp", backend="cpu")


# ---------------------------------------------------------------------------
# CPU tiers: interpret-mode vs ref.py, forward + gradient
# ---------------------------------------------------------------------------


def test_segment_sum_interpret_matches_ref_fwd_and_grad():
    from repro.kernels.segsum.ops import segment_sum
    from repro.kernels.segsum.ref import segment_sum_ref

    rng = np.random.default_rng(0)
    e, d, s = 75, 12, 17
    msg = jnp.asarray(rng.normal(size=(e, d)), jnp.float32)
    seg = jnp.asarray(rng.integers(0, s, size=e), jnp.int32)

    got = segment_sum(msg, seg, s, interpret=True)
    ref = segment_sum_ref(msg, seg, s)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-5)

    def loss_pallas(m):
        return jnp.sum(segment_sum(m, seg, s, interpret=True) ** 2)

    def loss_ref(m):
        return jnp.sum(segment_sum_ref(m, seg, s) ** 2)

    np.testing.assert_allclose(
        np.asarray(jax.grad(loss_pallas)(msg)),
        np.asarray(jax.grad(loss_ref)(msg)),
        rtol=1e-5,
        atol=1e-5,
    )


def test_blocked_matmul_interpret_matches_ref_fwd_and_grad():
    from repro.kernels.matmul.ops import blocked_matmul
    from repro.kernels.matmul.ref import matmul_ref

    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(33, 20)), jnp.float32)
    y = jnp.asarray(rng.normal(size=(20, 17)), jnp.float32)

    got = blocked_matmul(x, y, interpret=True)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(matmul_ref(x, y)), rtol=1e-5, atol=1e-5
    )

    def loss_pallas(a, b):
        return jnp.sum(blocked_matmul(a, b, interpret=True) ** 2)

    def loss_ref(a, b):
        return jnp.sum(matmul_ref(a, b) ** 2)

    for argnum in (0, 1):
        np.testing.assert_allclose(
            np.asarray(jax.grad(loss_pallas, argnum)(x, y)),
            np.asarray(jax.grad(loss_ref, argnum)(x, y)),
            rtol=1e-4,
            atol=1e-4,
        )


# ---------------------------------------------------------------------------
# Engine-level agreement: compiled programs under every CPU tier
# ---------------------------------------------------------------------------


def _logreg_prog_env():
    f_matmul = fra.Agg(
        project_key(0), ADD,
        fra.Join(
            eq_pred((1, 0)), jproj(L(0), L(1)), MUL,
            fra.const("Rx", 2), fra.scan("theta", 1),
        ),
    )
    f_predict = fra.Select(TRUE, identity_key(1), LOGISTIC, f_matmul)
    f_loss = fra.Agg(
        EMPTY_KEY, ADD,
        fra.Join(
            eq_pred((0, 0)), jproj(L(0)), XENT, f_predict, fra.const("Ry", 1)
        ),
    )
    prog = ra_autodiff(fra.Query(f_loss, inputs=("theta",)))
    rng = np.random.default_rng(2)
    n, m = 48, 12
    env = {
        "Rx": DenseRelation(jnp.asarray(rng.normal(size=(n, m)), jnp.float32), 2),
        "Ry": DenseRelation(
            jnp.asarray(rng.integers(0, 2, size=n), jnp.float32), 1
        ),
        "theta": DenseRelation(
            jnp.asarray(rng.normal(size=m) * 0.1, jnp.float32), 1
        ),
    }
    return prog, env


def _gcn_prog_env():
    join = fra.Join(
        eq_pred((0, 0)), jproj(L(1)), MUL,
        fra.const("Edge", 2), fra.scan("Node", 1),
    )
    q = fra.Query(fra.Agg(identity_key(1), ADD, join), inputs=("Node",))
    from repro.core.kernels import SQUARE, SUM_CHUNK

    sq = fra.Select(TRUE, identity_key(1), SQUARE, q.root)
    loss = fra.Agg(
        EMPTY_KEY, ADD, fra.Select(TRUE, identity_key(1), SUM_CHUNK, sq)
    )
    prog = ra_autodiff(fra.Query(loss, inputs=("Node",)))
    rng = np.random.default_rng(3)
    n, nnz, d = 16, 40, 8
    src = rng.integers(0, n, size=nnz)
    dst = rng.integers(0, n, size=nnz)
    env = {
        "Edge": CooRelation(
            jnp.asarray(np.stack([src, dst], 1), jnp.int32),
            jnp.asarray(rng.normal(size=nnz), jnp.float32),
            (n, n),
        ),
        "Node": DenseRelation(
            jnp.asarray(rng.normal(size=(n, d)), jnp.float32), 1
        ),
    }
    return prog, env


@pytest.mark.parametrize("make", [_logreg_prog_env, _gcn_prog_env])
@pytest.mark.parametrize("tier", ("ref", "interpret"))
def test_compiled_grad_step_matches_jnp_tier(make, tier):
    prog, env = make()
    eng = RAEngine(prog)
    out_j, grads_j = eng.lower(env, dispatch="jnp").compile()(env)
    out_t, grads_t = eng.lower(env, dispatch=tier).compile()(env)
    np.testing.assert_allclose(
        np.asarray(out_t.data), np.asarray(out_j.data), rtol=1e-5, atol=1e-5
    )
    for name in grads_j:
        gj, gt = grads_j[name], grads_t[name]
        lj = gj.values if isinstance(gj, CooRelation) else gj.data
        lt = gt.values if isinstance(gt, CooRelation) else gt.data
        np.testing.assert_allclose(
            np.asarray(lt), np.asarray(lj), rtol=1e-5, atol=1e-5
        )


def test_resolutions_record_the_forced_tier():
    prog, env = _gcn_prog_env()
    comp = RAEngine(prog).lower(env, dispatch="ref").compile()
    res = comp.resolutions
    assert res, "no dispatch site recorded for the GCN program"
    segsums = [k for k in res if k.startswith("segment_sum[")]
    # the forward conv and the reverse-edge gradient conv share a shape
    # signature but are distinct sites: both must be recorded (#2 suffix)
    assert len(segsums) >= 2
    assert set(res.values()) == {"ref"}
    assert comp.dispatch == K.make_table("ref")


def test_grad_eval_accepts_dispatch():
    prog, env = _logreg_prog_env()
    out_j, grads_j = compiler.grad_eval(prog, env)
    out_r, grads_r = compiler.grad_eval(prog, env, dispatch="ref")
    np.testing.assert_allclose(
        np.asarray(out_r.data), np.asarray(out_j.data), rtol=1e-5, atol=1e-5
    )
    np.testing.assert_allclose(
        np.asarray(grads_r["theta"].data),
        np.asarray(grads_j["theta"].data),
        rtol=1e-5,
        atol=1e-5,
    )


# ---------------------------------------------------------------------------
# Staging contract: dispatch is part of the lowering signature
# ---------------------------------------------------------------------------


def test_switching_tiers_invalidates_lowering_cache():
    prog, env = _logreg_prog_env()
    eng = RAEngine(prog)

    low_jnp = eng.lower(env, dispatch="jnp")
    assert eng.trace_count == 1
    assert eng.lower(env, dispatch="jnp") is low_jnp    # same tier: hit
    assert eng.trace_count == 1

    low_ref = eng.lower(env, dispatch="ref")            # tier switch: miss
    assert low_ref is not low_jnp
    assert eng.trace_count == 2

    assert eng.lower(env, dispatch="ref") is low_ref    # and re-hit
    assert eng.trace_count == 2


def test_compiled_steps_per_tier_are_independent_and_cached():
    prog, env = _logreg_prog_env()
    eng = RAEngine(prog)
    comp_jnp = eng.lower(env, dispatch="jnp").compile()
    comp_ref = eng.lower(env, dispatch="ref").compile()
    assert comp_jnp is not comp_ref

    comp_jnp(env)
    comp_ref(env)
    walks = eng.trace_count
    for _ in range(2):                       # steady state: zero re-walks
        comp_jnp(env)
        comp_ref(env)
    assert eng.trace_count == walks
    assert eng.lower(env, dispatch="ref").compile() is comp_ref
