"""Chunked compiler vs. the sparse interpreter oracle and jax.grad."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fra
from repro.core.autodiff import ra_autodiff
from repro.core import compiler, interpreter
from repro.core.kernels import ADD, LOGISTIC, MATMUL, MUL, SQUARE, SUM_CHUNK, XENT
from repro.core.keys import (
    EMPTY_KEY,
    TRUE,
    L,
    R,
    eq_pred,
    identity_key,
    jproj,
    project_key,
)
from repro.core.relation import (
    CooRelation,
    DenseRelation,
    from_blocked,
    to_blocked,
)

jax.config.update("jax_enable_x64", True)


def matmul_query(kernel=MATMUL):
    join = fra.Join(
        eq_pred((1, 0)),
        jproj(L(0), L(1), R(1)),
        kernel,
        fra.scan("A", 2),
        fra.scan("B", 2),
    )
    return fra.Query(fra.Agg(project_key(0, 2), ADD, join), inputs=("A", "B"))


def test_blocked_matmul_forward():
    rng = np.random.default_rng(0)
    A = rng.normal(size=(6, 8))
    B = rng.normal(size=(8, 4))
    env = {"A": from_blocked(A, (3, 4)), "B": from_blocked(B, (4, 2))}
    out = compiler.run_query(matmul_query(), env)
    np.testing.assert_allclose(to_blocked(out), A @ B, rtol=1e-10)


def test_compiler_matches_interpreter_scalar():
    rng = np.random.default_rng(1)
    A = rng.normal(size=(3, 4))
    B = rng.normal(size=(4, 2))
    q = matmul_query(kernel=MUL)
    denv = {
        "A": DenseRelation(jnp.array(A), 2),
        "B": DenseRelation(jnp.array(B), 2),
    }
    senv = {"A": denv["A"].to_sparse(), "B": denv["B"].to_sparse()}
    dout = compiler.run_query(q, denv)
    sout = interpreter.run_query(q, senv)
    for k, v in sout.items():
        assert float(dout.data[k]) == pytest.approx(v, rel=1e-10)


def test_compiled_gradients_blocked_matmul():
    rng = np.random.default_rng(2)
    A = rng.normal(size=(6, 8))
    B = rng.normal(size=(8, 4))
    join = fra.Join(
        eq_pred((1, 0)), jproj(L(0), L(1), R(1)), MATMUL, fra.scan("A", 2), fra.scan("B", 2)
    )
    prod = fra.Agg(project_key(0, 2), ADD, join)
    sq = fra.Select(TRUE, identity_key(2), SQUARE, prod)
    chunksum = fra.Select(TRUE, identity_key(2), SUM_CHUNK, sq)
    loss = fra.Agg(EMPTY_KEY, ADD, chunksum)
    q = fra.Query(loss, inputs=("A", "B"))
    prog = ra_autodiff(q)
    env = {"A": from_blocked(A, (3, 4)), "B": from_blocked(B, (4, 2))}
    out, grads = compiler.grad_eval(prog, env)

    def f(a, b):
        return jnp.sum((a @ b) ** 2)

    ga, gb = jax.grad(f, argnums=(0, 1))(jnp.array(A), jnp.array(B))
    assert float(out.data) == pytest.approx(float(f(jnp.array(A), jnp.array(B))), rel=1e-10)
    np.testing.assert_allclose(to_blocked(grads["A"]), np.asarray(ga), rtol=1e-8)
    np.testing.assert_allclose(to_blocked(grads["B"]), np.asarray(gb), rtol=1e-8)


def logreg_query():
    f_matmul = fra.Agg(
        project_key(0),
        ADD,
        fra.Join(
            eq_pred((1, 0)),
            jproj(L(0), L(1)),
            MUL,
            fra.const("Rx", 2),
            fra.scan("theta", 1),
        ),
    )
    f_predict = fra.Select(TRUE, identity_key(1), LOGISTIC, f_matmul)
    f_loss = fra.Agg(
        EMPTY_KEY,
        ADD,
        fra.Join(eq_pred((0, 0)), jproj(L(0)), XENT, f_predict, fra.const("Ry", 1)),
    )
    return fra.Query(f_loss, inputs=("theta",))


def test_compiled_logreg_grad_matches_jax():
    rng = np.random.default_rng(3)
    X = rng.normal(size=(16, 5))
    y = rng.integers(0, 2, size=16).astype(float)
    theta = rng.normal(size=5) * 0.1
    env = {
        "Rx": DenseRelation(jnp.array(X), 2),
        "Ry": DenseRelation(jnp.array(y), 1),
        "theta": DenseRelation(jnp.array(theta), 1),
    }
    prog = ra_autodiff(logreg_query())
    out, grads = compiler.grad_eval(prog, env)

    def loss(t):
        yhat = jax.nn.sigmoid(X @ t)
        return jnp.sum(-y * jnp.log(yhat) + (y - 1.0) * jnp.log1p(-yhat))

    ref = jax.grad(loss)(jnp.array(theta))
    np.testing.assert_allclose(np.asarray(grads["theta"].data), np.asarray(ref), rtol=1e-8)


def test_compiled_logreg_jits():
    rng = np.random.default_rng(4)
    X = rng.normal(size=(8, 3))
    y = rng.integers(0, 2, size=8).astype(float)
    theta = rng.normal(size=3) * 0.1
    prog = ra_autodiff(logreg_query())

    @jax.jit
    def step(tdata, xdata, ydata):
        env = {
            "Rx": DenseRelation(xdata, 2),
            "Ry": DenseRelation(ydata, 1),
            "theta": DenseRelation(tdata, 1),
        }
        out, grads = compiler.grad_eval(prog, env)
        return out.data, grads["theta"].data

    loss, g = step(jnp.array(theta), jnp.array(X), jnp.array(y))
    assert np.isfinite(loss)
    assert g.shape == (3,)


# ---------------------------------------------------------------------------
# GCN message passing: COO edges ⋈ dense node embeddings (paper §1)
# ---------------------------------------------------------------------------


def gcn_query():
    """h'_dst = Σ_src w(src,dst)·h_src — a join Edge⋈Node + Σ by dst."""
    join = fra.Join(
        eq_pred((0, 0)),            # edge.src == node.id
        jproj(L(1)),                # key -> dst
        MUL,                        # w * h_src (scalar × vector chunk)
        fra.const("Edge", 2),
        fra.scan("Node", 1),
    )
    return fra.Query(fra.Agg(identity_key(1), ADD, join), inputs=("Node",))


def make_graph(rng, n=10, nnz=30, d=4):
    src = rng.integers(0, n, size=nnz)
    dst = rng.integers(0, n, size=nnz)
    w = rng.normal(size=nnz)
    H = rng.normal(size=(n, d))
    edges = CooRelation(
        keys=jnp.array(np.stack([src, dst], axis=1), dtype=jnp.int32),
        values=jnp.array(w),
        extents=(n, n),
    )
    return edges, H, src, dst, w


def gcn_ref(H, src, dst, w, n):
    out = np.zeros_like(H)
    for s, t, ww in zip(src, dst, w):
        out[t] += ww * H[s]
    return out


def test_gcn_forward_coo():
    rng = np.random.default_rng(5)
    edges, H, src, dst, w = make_graph(rng)
    env = {"Edge": edges, "Node": DenseRelation(jnp.array(H), 1)}
    out = compiler.run_query(gcn_query(), env)
    np.testing.assert_allclose(np.asarray(out.data), gcn_ref(H, src, dst, w, 10), rtol=1e-8)


def test_gcn_backward_coo():
    # dL/dH for L = sum(square(gcn(H))) — RA-autodiff against jax.grad.
    rng = np.random.default_rng(6)
    edges, H, src, dst, w = make_graph(rng)
    conv = gcn_query().root
    sq = fra.Select(TRUE, identity_key(1), SQUARE, conv)
    loss = fra.Agg(EMPTY_KEY, ADD, sq)
    q = fra.Query(loss, inputs=("Node",))
    prog = ra_autodiff(q)
    env = {"Edge": edges, "Node": DenseRelation(jnp.array(H), 1)}
    out, grads = compiler.grad_eval(prog, env)

    def f(h):
        msg = w[:, None] * h[src]
        agg = jnp.zeros_like(h).at[dst].add(jnp.array(msg))
        return jnp.sum(agg**2)

    ref = jax.grad(f)(jnp.array(H))
    np.testing.assert_allclose(np.asarray(grads["Node"].data), np.asarray(ref), rtol=1e-8)
