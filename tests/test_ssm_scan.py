"""Chunked selective scan (§Perf iteration): equivalence with the plain
parallel prefix, in both scan dtypes, and through a full mamba block."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.ssm import (
    _assoc_scan,
    mamba1_apply,
    mamba1_init,
    selective_scan,
)


@pytest.mark.parametrize("chunk", [0, 8, 16, 64, 100])
def test_selective_scan_matches_prefix(chunk):
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.uniform(0.3, 1.0, size=(2, 64, 3, 4)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(2, 64, 3, 4)).astype(np.float32))
    ref = _assoc_scan(a, b)[1]
    got = selective_scan(a, b, chunk)   # chunk=100 does not divide 64 → plain
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-5, atol=1e-5)


def test_selective_scan_broadcast_decay():
    """mamba2-style broadcast: a has trailing singleton state dims."""
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.uniform(0.3, 1.0, size=(2, 32, 3, 1, 1)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(2, 32, 3, 4, 5)).astype(np.float32))
    ref = _assoc_scan(a, b)[1]
    got = selective_scan(a, b, 8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-5, atol=1e-5)


def test_mamba1_chunked_matches_unchunked():
    key = jax.random.PRNGKey(0)
    p = mamba1_init(key, d_model=32, state=4)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 32))
    y0, _ = mamba1_apply(p, x, chunk=0)
    y1, _ = mamba1_apply(p, x, chunk=16)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), rtol=1e-4, atol=1e-5)


def test_mamba1_bf16_scan_close_to_f32():
    key = jax.random.PRNGKey(2)
    p = mamba1_init(key, d_model=32, state=4)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 128, 32))
    y0, _ = mamba1_apply(p, x, chunk=0, scan_dtype=jnp.float32)
    y1, _ = mamba1_apply(p, x, chunk=32, scan_dtype=jnp.bfloat16)
    err = np.max(np.abs(np.asarray(y0) - np.asarray(y1)))
    scale = np.max(np.abs(np.asarray(y0)))
    assert err < 0.05 * scale, (err, scale)


def test_mamba1_chunked_gradients_match():
    key = jax.random.PRNGKey(4)
    p = mamba1_init(key, d_model=16, state=4)
    x = jax.random.normal(jax.random.PRNGKey(5), (1, 32, 16))

    def loss(p, chunk):
        y, _ = mamba1_apply(p, x, chunk=chunk)
        return jnp.mean(y * y)

    g0 = jax.grad(lambda p: loss(p, 0))(p)
    g1 = jax.grad(lambda p: loss(p, 8))(p)
    for k in g0:
        np.testing.assert_allclose(
            np.asarray(g0[k]), np.asarray(g1[k]), rtol=2e-3, atol=1e-5
        )
