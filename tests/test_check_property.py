"""Property-based test (hypothesis) for the typed checker's soundness
direction: on randomized query graphs **with fault injection** (free
join extents that may mismatch, arbitrary σ projections that may drop
keys, mixed dtypes), a check-clean report means the chunked compiler
and the tuple-at-a-time interpreter both accept the query. The checker
may be conservative the other way (an error report for a query some
fallback happens to execute), but it must never wave through a query
the engine then rejects."""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.analysis import check_query  # noqa: E402
from repro.core import compiler, fra  # noqa: E402
from repro.core.interpreter import evaluate  # noqa: E402
from repro.core.kernels import ADD, IDENT, MAX, MUL, NEG  # noqa: E402
from repro.core.keys import (  # noqa: E402
    TRUE,
    In,
    KeyFn,
    L,
    R,
    SelPred,
    eq_pred,
    jproj,
)
from repro.core.relation import DenseRelation  # noqa: E402


@st.composite
def faulty_query_and_env(draw):
    """A random query graph whose construction deliberately allows the
    malformations the checker flags: non-permutation σ projections, σ
    literals outside the key domain, join extents drawn independently
    per side, non-additive Σ kernels, duplicate groupings."""
    rng = np.random.default_rng(draw(st.integers(0, 2**31 - 1)))

    def leaf(name, env, arity=None):
        arity = arity or draw(st.integers(1, 2))
        extents = tuple(draw(st.integers(1, 3)) for _ in range(arity))
        env[name] = DenseRelation(
            jnp.asarray(rng.normal(size=extents).astype(np.float32)),
            arity,
        )
        return fra.scan(name, arity)

    env = {}
    node = leaf("T0", env)
    n_leaves = 1

    for _ in range(draw(st.integers(1, 3))):
        a = node.key_arity
        if a == 0:
            break
        op = draw(st.sampled_from(("select", "agg", "join")))
        if op == "select":
            # fault injection: arbitrary projection indices (may drop or
            # duplicate keys) and a predicate literal that may be out of
            # the key domain
            comps = tuple(
                In(draw(st.integers(0, a - 1)))
                for _ in range(draw(st.integers(1, a)))
            )
            eqs = ()
            if draw(st.booleans()):
                eqs = ((draw(st.integers(0, a - 1)), draw(st.integers(0, 4))),)
            kern = draw(st.sampled_from((IDENT, NEG)))
            node = fra.Select(SelPred(eqs), KeyFn(comps), kern, node)
        elif op == "agg":
            # fault injection: groupings may duplicate a component, and
            # the kernel may be non-additive
            idxs = draw(
                st.lists(st.integers(0, a - 1), max_size=a)
            )
            kern = draw(st.sampled_from((ADD, ADD, MAX)))
            node = fra.Agg(KeyFn(tuple(In(i) for i in idxs)), kern, node)
        else:
            # fault injection: the fresh leaf's extents are drawn freely,
            # so the joined dimension may mismatch
            li = draw(st.integers(0, a - 1))
            r_arity = draw(st.integers(1, 2))
            rj = draw(st.integers(0, r_arity - 1))
            name = f"T{n_leaves}"
            n_leaves += 1
            right = leaf(name, env, r_arity)
            proj = tuple(L(i) for i in range(a)) + tuple(
                R(j) for j in range(r_arity) if j != rj
            )
            join = fra.Join(
                eq_pred((li, rj)), jproj(*proj), MUL, node, right
            )
            node = fra.Agg(
                KeyFn(tuple(In(i) for i in range(len(proj)))), ADD, join
            )

    return fra.Query(node, inputs=tuple(sorted(env))), env


@settings(max_examples=60, deadline=None)
@given(faulty_query_and_env())
def test_check_clean_implies_engine_accepts(qe):
    q, env = qe
    report = check_query(q, env)
    if not report.ok:
        return  # rejected statically — nothing to prove here
    # clean bill of health: both execution paths must accept the query
    out = compiler.execute(q.root, env)
    assert out is not None
    sparse_env = {name: rel.to_sparse() for name, rel in env.items()}
    evaluate(q.root, sparse_env)


@settings(max_examples=60, deadline=None)
@given(faulty_query_and_env())
def test_report_rendering_is_total(qe):
    """Rendering a report never crashes, whatever the draw produced, and
    every diagnostic carries a node path and a severity."""
    q, env = qe
    report = check_query(q, env)
    assert isinstance(report.render(), str)
    for d in report.diagnostics:
        assert d.node_path and d.severity in ("error", "warning", "info")


def test_generator_actually_injects_faults():
    """Anti-vacuity check on the harness: across a fixed sample of draws
    the generator must produce both clean and error reports — otherwise
    the implication property above proves nothing."""
    from hypothesis import find

    find(faulty_query_and_env(), lambda qe: not check_query(qe[0], qe[1]).ok)
    find(faulty_query_and_env(), lambda qe: check_query(qe[0], qe[1]).ok)
