"""Staged engine (core/engine.py): lower → plan → jit-compile.

Covers the staging contract — same-shape re-execution hits the lowering
cache (trace-counter stays flat), changed shapes re-lower — and the
numerics: Compiled output matches the sparse interpreter oracle on the
logreg and GCN queries. The SPMD subprocess test is the acceptance path:
plan_query's PartitionSpecs become jax.jit in_shardings and the chosen
co-partition plan's all-reduce shows up in the HLO.
"""

import pathlib
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compiler, fra, interpreter
from repro.core.autodiff import ra_autodiff
from repro.core.engine import RAEngine, _staged_execute, engine_for
from repro.core.kernels import ADD, LOGISTIC, MATMUL, MUL, XENT
from repro.core.keys import (
    EMPTY_KEY,
    TRUE,
    L,
    R,
    eq_pred,
    identity_key,
    jproj,
    project_key,
)
from repro.core.relation import (
    CooRelation,
    DenseRelation,
    from_blocked,
    to_blocked,
)

jax.config.update("jax_enable_x64", True)


def matmul_query():
    join = fra.Join(
        eq_pred((1, 0)),
        jproj(L(0), L(1), R(1)),
        MATMUL,
        fra.scan("A", 2),
        fra.scan("B", 2),
    )
    return fra.Query(fra.Agg(project_key(0, 2), ADD, join), inputs=("A", "B"))


def logreg_query():
    f_matmul = fra.Agg(
        project_key(0), ADD,
        fra.Join(
            eq_pred((1, 0)), jproj(L(0), L(1)), MUL,
            fra.const("Rx", 2), fra.scan("theta", 1),
        ),
    )
    f_predict = fra.Select(TRUE, identity_key(1), LOGISTIC, f_matmul)
    f_loss = fra.Agg(
        EMPTY_KEY, ADD,
        fra.Join(eq_pred((0, 0)), jproj(L(0)), XENT, f_predict, fra.const("Ry", 1)),
    )
    return fra.Query(f_loss, inputs=("theta",))


def gcn_query():
    join = fra.Join(
        eq_pred((0, 0)),
        jproj(L(1)),
        MUL,
        fra.const("Edge", 2),
        fra.scan("Node", 1),
    )
    return fra.Query(fra.Agg(identity_key(1), ADD, join), inputs=("Node",))


def _matmul_env(rng, bi=2, bk=2, bj=2, c=3):
    A = rng.normal(size=(bi * c, bk * c))
    B = rng.normal(size=(bk * c, bj * c))
    return A, B, {"A": from_blocked(A, (c, c)), "B": from_blocked(B, (c, c))}


# ---------------------------------------------------------------------------
# Staging contract: the lowering cache and the trace counter
# ---------------------------------------------------------------------------


def test_same_shape_reexecution_hits_lowering_cache():
    rng = np.random.default_rng(0)
    _, _, env = _matmul_env(rng)
    eng = RAEngine(matmul_query())

    low = eng.lower(env)
    assert eng.trace_count == 1          # the abstract-shape lowering walk
    assert eng.lower(env) is low         # cache hit: no re-walk
    assert eng.trace_count == 1

    comp = low.compile()
    comp(env)                            # first call: one jit trace
    walks = eng.trace_count
    for _ in range(3):
        comp(env)                        # same signature: zero re-lowering
    assert eng.trace_count == walks
    assert low.compile() is comp         # Compiled is cached too


def test_changed_shapes_relower():
    rng = np.random.default_rng(1)
    _, _, env_small = _matmul_env(rng, c=3)
    _, _, env_big = _matmul_env(rng, c=4)
    eng = RAEngine(matmul_query())

    low_small = eng.lower(env_small)
    low_big = eng.lower(env_big)
    assert low_small is not low_big
    assert eng.trace_count == 2          # one walk per signature

    out = low_big.compile()(env_big)
    assert out.chunk_shape == (4, 4)


def test_compiled_rejects_mismatched_signature():
    rng = np.random.default_rng(2)
    _, _, env = _matmul_env(rng, c=3)
    _, _, other = _matmul_env(rng, c=4)
    comp = RAEngine(matmul_query()).lower(env).compile()
    with pytest.raises(ValueError, match="signature"):
        comp(other)


def test_staged_execute_caches_engines():
    q = matmul_query()
    assert engine_for(q) is engine_for(q)
    rng = np.random.default_rng(3)
    A, B, env = _matmul_env(rng)
    out = _staged_execute(q, env)
    np.testing.assert_allclose(to_blocked(out), A @ B, rtol=1e-8)


# ---------------------------------------------------------------------------
# Numerics: Compiled vs the sparse interpreter oracle
# ---------------------------------------------------------------------------


def test_compiled_logreg_matches_interpreter_oracle():
    rng = np.random.default_rng(4)
    X = rng.normal(size=(6, 3))
    y = rng.integers(0, 2, size=6).astype(float)
    theta = rng.normal(size=3) * 0.1
    env = {
        "Rx": DenseRelation(jnp.array(X), 2),
        "Ry": DenseRelation(jnp.array(y), 1),
        "theta": DenseRelation(jnp.array(theta), 1),
    }
    prog = ra_autodiff(logreg_query())

    eng = RAEngine(prog)
    out, grads = eng.lower(env).compile()(env)

    senv = {k: v.to_sparse() for k, v in env.items()}
    sout, sgrads = prog.eval(senv)       # tuple-at-a-time oracle

    assert float(out.data) == pytest.approx(sout[()], rel=1e-8)
    for (j,), v in sgrads["theta"].items():
        assert float(grads["theta"].data[j]) == pytest.approx(v, rel=1e-7)


def test_compiled_gcn_matches_interpreter_oracle():
    rng = np.random.default_rng(5)
    n, nnz, d = 8, 20, 4
    # unique (src, dst) pairs: the dict-backed oracle collapses duplicate
    # keys, whereas COO treats them as separate tuples to be aggregated
    flat = rng.choice(n * n, size=nnz, replace=False)
    src, dst = flat // n, flat % n
    w = rng.normal(size=nnz)
    H = rng.normal(size=(n, d))
    env = {
        "Edge": CooRelation(
            jnp.array(np.stack([src, dst], 1), dtype=jnp.int32),
            jnp.array(w),
            (n, n),
        ),
        "Node": DenseRelation(jnp.array(H), 1),
    }
    q = gcn_query()
    out = RAEngine(q).lower(env).compile()(env)

    senv = {k: v.to_sparse() for k, v in env.items()}
    sout = interpreter.run_query(q, senv)
    for (i,), vec in sout.items():
        np.testing.assert_allclose(
            np.asarray(out.data[i]), np.asarray(vec), rtol=1e-8
        )


def test_compiled_grad_program_matches_eager_wrapper():
    rng = np.random.default_rng(6)
    A, B, env = _matmul_env(rng)
    join = fra.Join(
        eq_pred((1, 0)), jproj(L(0), L(1), R(1)), MATMUL,
        fra.scan("A", 2), fra.scan("B", 2),
    )
    from repro.core.kernels import SQUARE, SUM_CHUNK

    prod = fra.Agg(project_key(0, 2), ADD, join)
    sq = fra.Select(TRUE, identity_key(2), SQUARE, prod)
    chunksum = fra.Select(TRUE, identity_key(2), SUM_CHUNK, sq)
    loss = fra.Agg(EMPTY_KEY, ADD, chunksum)
    prog = ra_autodiff(fra.Query(loss, inputs=("A", "B")))

    out_c, grads_c = RAEngine(prog).lower(env).compile()(env)
    out_e, grads_e = compiler.grad_eval(prog, env)

    np.testing.assert_allclose(float(out_c.data), float(out_e.data), rtol=1e-10)
    for name in ("A", "B"):
        np.testing.assert_allclose(
            to_blocked(grads_c[name]), to_blocked(grads_e[name]), rtol=1e-10
        )


def test_plans_are_populated_on_compile():
    """plan_query runs on the hot path: every Join in the forward query
    gets a physical plan, and the planner's specs are exposed."""
    rng = np.random.default_rng(7)
    _, _, env = _matmul_env(rng)
    comp = RAEngine(matmul_query()).lower(env).compile()
    assert len(comp.plans) == 1
    (plan,) = comp.plans.values()
    assert plan.kind in ("broadcast_left", "broadcast_right", "copartition")
    assert set(comp.input_specs) == {"A", "B"}


def test_compile_with_donation_runs():
    rng = np.random.default_rng(8)
    A, B, env = _matmul_env(rng)
    comp = RAEngine(matmul_query()).lower(env).compile(donate=("A",))
    out = comp(env)
    np.testing.assert_allclose(to_blocked(out), A @ B, rtol=1e-8)
    assert comp.donate_names == ("A",)


# ---------------------------------------------------------------------------
# Acceptance: planner-emitted in_shardings under SPMD (8 fake CPU devices;
# subprocess because the device count must be set before JAX initializes)
# ---------------------------------------------------------------------------

_SPMD_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import fra
    from repro.core.autodiff import ra_autodiff
    from repro.core.engine import RAEngine
    from repro.core.kernels import ADD, MATMUL, MUL
    from repro.core.keys import L, R, eq_pred, identity_key, jproj, project_key
    from repro.core.relation import CooRelation, DenseRelation

    mesh = jax.make_mesh((8,), ("model",))
    rng = np.random.default_rng(0)

    # ---- blocked matmul: tiny budget forces the co-partition plan ----
    join = fra.Join(eq_pred((1, 0)), jproj(L(0), L(1), R(1)), MATMUL,
                    fra.scan("A", 2), fra.scan("B", 2))
    q = fra.Query(fra.Agg(project_key(0, 2), ADD, join), inputs=("A", "B"))
    a = jnp.asarray(rng.normal(size=(8, 8, 8, 8)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(8, 8, 8, 8)).astype(np.float32))
    env = {"A": DenseRelation(a, 2), "B": DenseRelation(b, 2)}

    eng = RAEngine(q)
    low = eng.lower(env)
    comp = low.compile(mesh=mesh, mem_budget=1.0)
    (plan,) = comp.plans.values()
    assert plan.kind == "copartition", plan.kind
    # planner-emitted in_shardings: contraction axes carry the mesh axis
    assert tuple(comp.input_specs["A"]) == (None, "model"), comp.input_specs
    assert tuple(comp.input_specs["B"]) == ("model", None), comp.input_specs

    out = comp(env)
    walks = eng.trace_count
    out2 = comp(env)
    assert eng.trace_count == walks, "re-lowered on second call"
    hlo = comp.lower_text()
    ref = low.eager(env)
    np.testing.assert_allclose(np.asarray(out.data), np.asarray(ref.data),
                               rtol=1e-4, atol=1e-4)
    assert "all-reduce" in hlo or "reduce-scatter" in hlo, "no psum emitted"

    # ---- GCN gradient program under the same pipeline ----
    gjoin = fra.Join(eq_pred((0, 0)), jproj(L(1)), MUL,
                     fra.const("Edge", 2), fra.scan("Node", 1))
    gq = fra.Query(fra.Agg(identity_key(1), ADD, gjoin), inputs=("Node",))
    from repro.core.kernels import SQUARE, SUM_CHUNK
    from repro.core.keys import EMPTY_KEY, TRUE
    sq = fra.Select(TRUE, identity_key(1), SQUARE, gq.root)
    loss = fra.Agg(EMPTY_KEY, ADD,
                   fra.Select(TRUE, identity_key(1), SUM_CHUNK, sq))
    prog = ra_autodiff(fra.Query(loss, inputs=("Node",)))

    n, nnz, d = 16, 64, 8
    src = rng.integers(0, n, size=nnz); dst = rng.integers(0, n, size=nnz)
    genv = {
        "Edge": CooRelation(
            jnp.asarray(np.stack([src, dst], 1), jnp.int32),
            jnp.asarray(rng.normal(size=nnz).astype(np.float32)), (n, n)),
        "Node": DenseRelation(
            jnp.asarray(rng.normal(size=(n, d)).astype(np.float32)), 1),
    }
    geng = RAEngine(prog)
    glow = geng.lower(genv)
    gcomp = glow.compile(mesh=mesh, mem_budget=1.0)
    assert gcomp.plans, "GCN join got no physical plan"
    out_s, grads_s = gcomp(genv)
    out_e, grads_e = glow.eager(genv)
    np.testing.assert_allclose(np.asarray(out_s.data), np.asarray(out_e.data),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(grads_s["Node"].data),
                               np.asarray(grads_e["Node"].data),
                               rtol=1e-4, atol=1e-4)
    print("ENGINE-SPMD-OK")
    """
)


@pytest.mark.spmd
def test_compiled_spmd_in_shardings():
    repo = pathlib.Path(__file__).resolve().parent.parent
    r = subprocess.run(
        [sys.executable, "-c", _SPMD_SCRIPT],
        capture_output=True,
        text=True,
        env={
            "PYTHONPATH": str(repo / "src"),
            "PATH": "/usr/bin:/bin",
            "JAX_PLATFORMS": "cpu",
        },
        cwd=str(repo),
        timeout=600,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    assert "ENGINE-SPMD-OK" in r.stdout
