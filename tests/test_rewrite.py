"""core/rewrite.py: the cost-gated factorized-evaluation stage.

Properties under test:

  * rewritten ≡ unrewritten oracle — forward *and* gradients — across
    randomized multi-join Σ∘⋈ chains (hypothesis), whatever the gate
    decides;
  * skewed statistics flip the gate both ways: a wide middle key domain
    fires the Σ-pushdown, a collapsed (distinct=1) one declines it;
  * a declined gate is bit-identical: the engine lowers the *original*
    program object and produces the same plans as rewrite-off;
  * dedup merges structurally identical subplans without changing
    results;
  * ``Database.explain`` reports the decisions.

The unrewritten gradient oracle for chains whose Σ drops a middle join
key must run without join-agg fusion (``RJPOptions(False, True, True)``,
``fuse_join_agg=False``): the fused derivation of those chains has no
multiplicative RJP solution and does not lower — which is precisely the
shape the rewrite exists to fix.
"""

import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.core import compiler, engine, fra, rewrite
from repro.core.autodiff import RJPOptions, ra_autodiff
from repro.core.kernels import ADD, MUL
from repro.core.keys import (
    EMPTY_KEY, In, KeyFn, L, R, eq_pred, jproj, project_key,
)
from repro.core.planner import RelationStats
from repro.core.relation import DenseRelation, measure_stats

NO_FUSION = RJPOptions(False, True, True)


def _dense(rng, *extents):
    scale = 1.0 / np.sqrt(max(extents))
    return DenseRelation(
        jnp.asarray(rng.normal(size=extents).astype(np.float32) * scale),
        len(extents),
    )


def _chain3(inner_keep=(0, 3)):
    """loss = Σ_{()} Σ_{inner_keep} ((A ⋈ B) ⋈ C) — the 3-relation MUL
    chain whose default inner Σ drops both middle join keys."""
    j1 = fra.Join(
        eq_pred((1, 0)), jproj(L(0), L(1), R(1)), MUL,
        fra.scan("A", 2), fra.scan("B", 2),
    )
    j2 = fra.Join(
        eq_pred((2, 0)), jproj(L(0), L(1), L(2), R(1)), MUL,
        j1, fra.scan("C", 2),
    )
    loss = fra.Agg(EMPTY_KEY, ADD, fra.Agg(project_key(*inner_keep), ADD, j2))
    return fra.Query(loss, inputs=("A", "B", "C"))


def _chain3_env(n=6, seed=0):
    rng = np.random.default_rng(seed)
    env = {k: _dense(rng, n, n) for k in ("A", "B", "C")}
    stats = {k: measure_stats(v) for k, v in env.items()}
    return env, stats


# ---------------------------------------------------------------------------
# Randomized multi-join Σ∘⋈ chains: rewritten ≡ unrewritten oracle
# ---------------------------------------------------------------------------


def sigma_join_chain(seed):
    """A seed-driven random k-join MUL chain capped by Σ(random keep)
    then Σ→scalar, plus a dense env and its measured stats. Extents of 1
    make the gate decline; extents of 3-4 with min_shrink 1.0 make it
    fire — both paths are exercised across the seed sweep."""
    rng = np.random.default_rng(seed)
    n_joins = int(rng.integers(1, 4))
    extents = [int(rng.integers(1, 5)) for _ in range(n_joins + 2)]

    env = {"T0": _dense(rng, extents[0], extents[1])}
    node: fra.Node = fra.scan("T0", 2)
    for j in range(1, n_joins + 1):
        name = f"T{j}"
        env[name] = _dense(rng, extents[j], extents[j + 1])
        a = node.key_arity
        proj = tuple(L(i) for i in range(a)) + (R(1),)
        node = fra.Join(
            eq_pred((a - 1, 0)), jproj(*proj), MUL, node, fra.scan(name, 2)
        )
    n_keep = int(rng.integers(0, node.key_arity + 1))
    keep = tuple(
        int(i)
        for i in rng.permutation(node.key_arity)[:n_keep]
    )
    node = fra.Agg(KeyFn(tuple(In(i) for i in keep)), ADD, node)
    loss = fra.Agg(EMPTY_KEY, ADD, node)
    q = fra.Query(loss, inputs=tuple(sorted(env)))
    stats = {k: measure_stats(v) for k, v in env.items()}
    min_shrink = float(rng.choice((1.0, 2.0, 4.0)))
    return q, env, stats, rewrite.RuleSet(min_shrink=min_shrink)


@pytest.mark.parametrize("seed", range(30))
def test_rewritten_forward_matches_oracle(seed):
    q, env, stats, rules = sigma_join_chain(seed)
    rw, report = rewrite.rewrite_query(q, env, stats=stats, rules=rules)
    want = compiler.execute(q.root, env)
    got = compiler.execute(rw.root, env)
    assert got.key_arity == want.key_arity
    np.testing.assert_allclose(
        np.asarray(got.data), np.asarray(want.data), rtol=1e-4, atol=1e-5
    )
    if not report.changed:
        assert rw is q  # decline path returns the original object


def _dict_env(env):
    return {
        name: {
            k: float(v) for k, v in np.ndenumerate(np.asarray(rel.data))
        }
        for name, rel in env.items()
    }


@pytest.mark.parametrize("seed", range(30, 45))
def test_rewritten_grad_matches_oracle(seed):
    """Semantics preservation through autodiff, on the tuple-at-a-time
    interpreter (the paper-semantics oracle, which evaluates any FRA
    graph): gradients of the rewritten program equal gradients of the
    unrewritten one. The compiled gradient path is covered by the
    deterministic chain-3 / session tests below and the
    ``rjp/pushdown-*`` benchmark lanes, on the shapes whose rewritten
    derivation lowers."""
    q, env, stats, rules = sigma_join_chain(seed)
    denv = _dict_env(env)
    # NO_FUSION is the only derivation valid for every unrewritten chain:
    # the fused derivation of a Σ that drops a join key falls back to
    # partial-RJP joins that not even the interpreter can merge.
    oracle = ra_autodiff(q, opts=NO_FUSION)
    loss_ref, g_ref = oracle.eval(denv)

    prog = ra_autodiff(q)  # the production (default-opts) program
    rw, report = rewrite.rewrite_program(prog, env, stats=stats, rules=rules)
    if not report.changed:
        assert rw is prog  # nothing fired/reverted: same program object
        return
    loss_rw, g_rw = rw.eval(denv)
    assert loss_rw.get((), 0.0) == pytest.approx(
        loss_ref.get((), 0.0), rel=1e-4, abs=1e-5
    )
    assert g_rw.keys() == g_ref.keys()
    for name in g_ref:
        ref, got = dict(g_ref[name]), dict(g_rw[name])
        for key in set(ref) | set(got):
            assert got.get(key, 0.0) == pytest.approx(
                ref.get(key, 0.0), rel=1e-4, abs=1e-5
            )


# ---------------------------------------------------------------------------
# The cost gate: skewed stats flip it both ways
# ---------------------------------------------------------------------------


def test_gate_fires_on_wide_middle_keys():
    q = _chain3()
    env, stats = _chain3_env(n=6)
    rw, report = rewrite.rewrite_query(q, env, stats=stats)
    assert report.changed and report.fired
    assert "FIRED" in report.render()
    # the join output is never materialized at full arity: every Σ sits
    # directly on its join, and the 4-key intermediate is gone
    arities = [n.key_arity for n in rw.root.topo()]
    assert max(arities) < 4
    want = compiler.execute(q.root, env)
    got = compiler.execute(rw.root, env)
    np.testing.assert_allclose(
        np.asarray(got.data), np.asarray(want.data), rtol=1e-4, atol=1e-5
    )


def test_gate_declines_on_collapsed_middle_keys():
    """Same graph, skewed stats: every middle key column claims a single
    distinct value, so pushing Σ down cannot shrink anything."""
    q = _chain3()
    env, _ = _chain3_env(n=6)
    n = 6
    skewed = {
        name: RelationStats(
            distinct=(1, 1), extents=(n, n), nnz=n * n, density=1.0
        )
        for name in ("A", "B", "C")
    }
    rw, report = rewrite.rewrite_query(q, env, stats=skewed)
    assert not report.changed
    assert rw is q
    assert report.decisions, "gate should record its declined candidates"
    assert all(not d.fired for d in report.decisions)
    assert "declined" in report.render()


def test_measured_stats_flip_gate_vs_skew():
    """The *same* query and env rewrite differently purely on stats."""
    q = _chain3()
    env, measured = _chain3_env(n=6)
    _, rep_wide = rewrite.rewrite_query(q, env, stats=measured)
    skewed = {
        k: RelationStats((1, 1), (6, 6), 36, 1.0) for k in ("A", "B", "C")
    }
    _, rep_skew = rewrite.rewrite_query(q, env, stats=skewed)
    assert rep_wide.changed and not rep_skew.changed


def test_declined_gate_is_bit_identical_through_the_engine():
    """A declined rewrite lowers the engine's own program object and
    produces the same physical plans as rewrite-off."""
    # forward-only query: Σ drops a middle key of extent 1 → shrink 1×
    j = fra.Join(
        eq_pred((1, 0)), jproj(L(0), L(1), R(1)), MUL,
        fra.scan("A", 2), fra.scan("B", 2),
    )
    q = fra.Query(fra.Agg(project_key(0, 2), ADD, j), inputs=("A", "B"))
    rng = np.random.default_rng(0)
    env = {"A": _dense(rng, 4, 1), "B": _dense(rng, 1, 4)}
    stats = {k: measure_stats(v) for k, v in env.items()}

    eng = engine.RAEngine(q)
    low_on = eng.lower(env, stats=stats, rewrite=True)
    low_off = eng.lower(env, rewrite=None)
    assert low_on.program is eng.program  # decline → original object
    assert low_on.rewrite_report is not None
    assert not low_on.rewrite_report.changed
    c_on, c_off = low_on.compile(), low_off.compile()
    assert c_on.plans == c_off.plans
    np.testing.assert_allclose(
        np.asarray(c_on(env).data), np.asarray(c_off(env).data), rtol=1e-6
    )


def test_lower_cache_keys_on_rules_and_stats():
    q = _chain3()
    env, stats = _chain3_env(n=4)
    eng = engine.RAEngine(q)
    a = eng.lower(env, stats=stats, rewrite=True)
    b = eng.lower(env, stats=stats, rewrite=True)
    assert a is b  # same (sig, table, rules, stats snapshot) → cache hit
    c = eng.lower(env, rewrite=None)
    assert c is not a  # rewrite-off is a different cache entry
    loose = rewrite.RuleSet(min_shrink=1e9)
    d = eng.lower(env, stats=stats, rewrite=loose)
    assert d is not a  # different gate threshold → different entry


# ---------------------------------------------------------------------------
# Dedup: common-subplan elimination
# ---------------------------------------------------------------------------


def _twin_branch_query():
    def branch():
        j = fra.Join(
            eq_pred((1, 0)), jproj(L(0), L(1), R(1)), MUL,
            fra.scan("A", 2), fra.scan("B", 2),
        )
        return fra.Agg(project_key(0), ADD, j)

    return fra.Query(
        fra.Agg(EMPTY_KEY, ADD, fra.AddOp(branch(), branch())),
        inputs=("A", "B"),
    )


def test_dedup_merges_identical_subplans():
    q = _twin_branch_query()
    rng = np.random.default_rng(3)
    env = {"A": _dense(rng, 3, 3), "B": _dense(rng, 3, 3)}
    rw, report = rewrite.rewrite_query(
        q, env, rules=rewrite.RuleSet(rules=("dedup",))
    )
    assert report.changed
    assert any(d.rule == "dedup" and d.fired for d in report.decisions)
    assert len(rw.root.topo()) < len(q.root.topo())
    add = next(n for n in rw.root.topo() if isinstance(n, fra.AddOp))
    assert add.left is add.right  # one shared subplan, memoized once
    want = compiler.execute(q.root, env)
    got = compiler.execute(rw.root, env)
    np.testing.assert_allclose(
        np.asarray(got.data), np.asarray(want.data), rtol=1e-5
    )


def test_no_candidates_returns_original():
    q = fra.Query(
        fra.Agg(EMPTY_KEY, ADD, fra.scan("A", 2)), inputs=("A",)
    )
    rng = np.random.default_rng(0)
    env = {"A": _dense(rng, 3, 3)}
    rw, report = rewrite.rewrite_query(q, env)
    assert rw is q and not report.changed
    assert "no rewrite candidates" in report.render()


# ---------------------------------------------------------------------------
# Session surface: Database.explain and the rewrite toggle
# ---------------------------------------------------------------------------


def test_database_explain_reports_decisions():
    db = repro.Database()
    n = 6
    for name in ("A", "B", "C"):
        db.put(name, jnp.ones((n, n)), keys=("i", "j"))
    text = db.explain(_chain3())
    assert "before:" in text and "rewrite decisions:" in text
    assert "FIRED" in text and "after:" in text
    off = repro.Database(rewrite=False)
    for name in ("A", "B", "C"):
        off.put(name, jnp.ones((n, n)), keys=("i", "j"))
    off_text = off.explain(_chain3())
    assert "OFF" in off_text and "(unchanged)" in off_text


def test_session_step_matches_oracle_with_rewrite_on():
    q = _chain3()
    env, _ = _chain3_env(n=6, seed=7)
    oracle = ra_autodiff(q, opts=NO_FUSION)
    loss_ref, g_ref = compiler.grad_eval(oracle, env, fuse_join_agg=False)

    db = repro.Database()
    for name in ("A", "B", "C"):
        db.put(name, env[name].data, keys=("i", "j"))
    loss, grads = db.query(q, wrt=("A", "B", "C")).step()
    np.testing.assert_allclose(
        np.asarray(loss.data), np.asarray(loss_ref.data), rtol=1e-4, atol=1e-5
    )
    for name in g_ref:
        np.testing.assert_allclose(
            np.asarray(grads[name].data), np.asarray(g_ref[name].data),
            rtol=1e-4, atol=1e-5,
        )
