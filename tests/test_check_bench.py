"""tools/check_bench.py: a malformed baseline (missing metric key) must
fail with the named key and file, not a bare KeyError."""

import importlib.util
import json
import pathlib

REPO = pathlib.Path(__file__).resolve().parent.parent


def _load_module():
    spec = importlib.util.spec_from_file_location(
        "check_bench", REPO / "tools" / "check_bench.py"
    )
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_missing_metric_key_is_named(tmp_path):
    cb = _load_module()
    baselines = tmp_path / "baselines"
    baselines.mkdir()
    good = [{"name": "engine_overhead/x/compiled", "us_per_call": 1.0}]
    bad = [{"name": "engine_overhead/x/compiled"}]        # no us_per_call
    (baselines / "engine_overhead.json").write_text(json.dumps(bad))
    (baselines / "kernel_dispatch.json").write_text(json.dumps(good))
    (tmp_path / "BENCH_engine_overhead.json").write_text(json.dumps(good))
    (tmp_path / "BENCH_kernel_dispatch.json").write_text(json.dumps(good))

    errors = cb.check(baselines, tmp_path)
    joined = "\n".join(errors)
    assert "us_per_call" in joined                 # the missing key, named
    assert "engine_overhead.json" in joined        # the offending file
    # the well-formed suite is still checked, not aborted by the bad one
    assert any("kernel_dispatch" in e or "no gated" in e for e in errors) or (
        len(errors) == 1
    )


def test_well_formed_baselines_pass(tmp_path):
    cb = _load_module()
    baselines = tmp_path / "baselines"
    baselines.mkdir()
    rows = [
        {"name": "engine_overhead/x/compiled", "us_per_call": 100.0},
        {"name": "kernel_dispatch/engine-x/jnp", "us_per_call": 50.0},
    ]
    for suite in cb.SUITES:
        (baselines / f"{suite}.json").write_text(json.dumps(rows))
        (tmp_path / f"BENCH_{suite}.json").write_text(json.dumps(rows))
    assert cb.check(baselines, tmp_path) == []
