"""tools/check_bench.py: malformed baselines (missing metric key, wrong
top-level shape, list-valued metrics) must fail with the named file and
row, not a bare KeyError/AttributeError."""

import importlib.util
import json
import pathlib

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent

#: one gated (CPU-stable) row per suite, so a well-formed fixture passes
#: the "no gated metrics" guard for every suite in cb.SUITES.
GATED_ROWS = {
    "engine_overhead": [
        {"name": "engine_overhead/x/compiled", "us_per_call": 100.0},
        {"name": "engine_overhead/x/session", "us_per_call": 110.0},
    ],
    "kernel_dispatch": [
        {"name": "kernel_dispatch/engine-x/jnp", "us_per_call": 50.0},
    ],
    "rjp_ablation": [
        {"name": "rjp/all-opts", "us_per_call": 1200.0},
        {"name": "rjp/no-join-agg-fusion", "us_per_call": 4500.0},
        {"name": "rjp/pushdown-on", "us_per_call": 300.0},
        {"name": "rjp/pushdown-off", "us_per_call": 900.0},
    ],
}


def _load_module():
    spec = importlib.util.spec_from_file_location(
        "check_bench", REPO / "tools" / "check_bench.py"
    )
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _write_all(cb, baselines, fresh, override=None):
    baselines.mkdir(exist_ok=True)
    for suite in cb.SUITES:
        rows = (override or {}).get(suite, GATED_ROWS[suite])
        (baselines / f"{suite}.json").write_text(json.dumps(rows))
        (fresh / f"BENCH_{suite}.json").write_text(
            json.dumps(GATED_ROWS[suite])
        )


def test_missing_metric_key_is_named(tmp_path):
    cb = _load_module()
    baselines = tmp_path / "baselines"
    bad = [{"name": "engine_overhead/x/compiled"}]        # no us_per_call
    _write_all(cb, baselines, tmp_path, override={"engine_overhead": bad})

    errors = cb.check(baselines, tmp_path)
    joined = "\n".join(errors)
    assert "us_per_call" in joined                 # the missing key, named
    assert "engine_overhead.json" in joined        # the offending file
    # the well-formed suites are still checked, not aborted by the bad one
    assert len(errors) == 1


def test_well_formed_baselines_pass(tmp_path):
    cb = _load_module()
    baselines = tmp_path / "baselines"
    _write_all(cb, baselines, tmp_path)
    assert cb.check(baselines, tmp_path) == []


def test_every_suite_has_gated_fixture_rows():
    """Keep GATED_ROWS in sync with cb.SUITES: each suite needs at least
    one STABLE-matching name or the gate errors with 'no gated'."""
    cb = _load_module()
    for suite in cb.SUITES:
        assert suite in GATED_ROWS
        assert any(cb._is_stable(r["name"]) for r in GATED_ROWS[suite])


def test_mapping_baselines_are_normalized(tmp_path):
    """A hand-written {name: us} mapping baseline is accepted — this
    shape used to crash the loader instead of being normalized."""
    cb = _load_module()
    baselines = tmp_path / "baselines"
    mapping = {
        "engine_overhead/x/compiled": 100.0,
        "engine_overhead/x/session": {"us_per_call": 110.0},
    }
    _write_all(cb, baselines, tmp_path, override={"engine_overhead": mapping})
    assert cb.check(baselines, tmp_path) == []


@pytest.mark.parametrize(
    "payload, fragment",
    [
        ("42", "expected a list"),                      # scalar top level
        ('[["rjp/all-opts", 1.0]]', "row 0 is list"),   # non-object row
        (
            '[{"name": "rjp/all-opts", "us_per_call": [1.0]}]',
            "non-numeric us_per_call",                   # list-valued metric
        ),
        ("{not json", "not valid JSON"),
    ],
)
def test_malformed_baseline_shapes_name_the_file(tmp_path, payload, fragment):
    cb = _load_module()
    baselines = tmp_path / "baselines"
    _write_all(cb, baselines, tmp_path)
    (baselines / "rjp_ablation.json").write_text(payload)
    errors = cb.check(baselines, tmp_path)
    assert len(errors) == 1
    assert "rjp_ablation.json" in errors[0]
    assert fragment in errors[0]
