"""Semantics tests for the sparse interpreter (paper §2 examples)."""

import numpy as np
import pytest

from repro.core import fra
from repro.core.interpreter import run_query
from repro.core.kernels import ADD, MATADD, MATMUL, MUL, LOGISTIC, IDENT
from repro.core.keys import (
    EMPTY_KEY,
    KeyFn,
    In,
    JoinProj,
    L,
    R,
    SelPred,
    TRUE,
    eq_pred,
    identity_key,
    jproj,
    project_key,
)


def dense_to_rel(x):
    """Matrix -> relation keyed by (row, col) of scalars."""
    return {(i, j): float(x[i, j]) for i in range(x.shape[0]) for j in range(x.shape[1])}


def rel_to_dense(rel, shape):
    out = np.zeros(shape)
    for k, v in rel.items():
        out[k] = v
    return out


def test_figure1_aggregation_to_single_tuple():
    # Paper §2.2: aggregate a 4x4 matrix stored as 2x2 chunks down to one 2x2.
    X = {
        (0, 0): np.array([[1.0, 4.0], [1.0, 2.0]]),
        (0, 1): np.array([[1.0, 2.0], [4.0, 3.0]]),
        (1, 0): np.array([[3.0, 1.0], [2.0, 2.0]]),
        (1, 1): np.array([[2.0, 1.0], [2.0, 2.0]]),
    }
    q = fra.Query(
        fra.Agg(EMPTY_KEY, MATADD, fra.scan("X", 2)),
        inputs=("X",),
    )
    out = run_query(q, {"X": X})
    assert set(out) == {()}
    np.testing.assert_allclose(out[()], np.array([[7.0, 8.0], [9.0, 9.0]]))


def matmul_query(a_name="A", b_name="B", kernel=MUL):
    """F_MatMul ≡ Σ(grp, ⊕, ⋈(pred, proj, ⊗, τ(K), τ(K))) — paper §2.2."""
    join = fra.Join(
        eq_pred((1, 0)),                     # keyL[1] == keyR[0]
        jproj(L(0), L(1), R(1)),             # ⟨keyL[0], keyL[1], keyR[1]⟩
        kernel,
        fra.scan(a_name, 2),
        fra.scan(b_name, 2),
    )
    agg = fra.Agg(project_key(0, 2), ADD, join)  # grp: ⟨key[0], key[2]⟩
    return fra.Query(agg, inputs=(a_name, b_name))


def test_matmul_scalar_relations():
    rng = np.random.default_rng(0)
    A = rng.normal(size=(3, 4))
    B = rng.normal(size=(4, 5))
    q = matmul_query()
    out = run_query(q, {"A": dense_to_rel(A), "B": dense_to_rel(B)})
    np.testing.assert_allclose(rel_to_dense(out, (3, 5)), A @ B, rtol=1e-12)


def test_matmul_chunked_relations():
    # Appendix A: the same query over chunk values with the MatMul kernel.
    rng = np.random.default_rng(1)
    A = rng.normal(size=(2, 3, 8, 16))  # 2x3 grid of 8x16 chunks
    B = rng.normal(size=(3, 2, 16, 4))
    relA = {(i, j): A[i, j] for i in range(2) for j in range(3)}
    relB = {(i, j): B[i, j] for i in range(3) for j in range(2)}
    q = matmul_query(kernel=MATMUL)
    out = run_query(q, {"A": relA, "B": relB})
    dense_a = np.concatenate([np.concatenate(list(A[i]), axis=1) for i in range(2)], axis=0)
    dense_b = np.concatenate([np.concatenate(list(B[i]), axis=1) for i in range(3)], axis=0)
    ref = dense_a @ dense_b
    got = np.concatenate(
        [np.concatenate([out[(i, j)] for j in range(2)], axis=1) for i in range(2)],
        axis=0,
    )
    np.testing.assert_allclose(got, ref, rtol=1e-10)


def test_selection_modifies_values_and_keys():
    rel = {(0,): 1.0, (1,): -2.0, (2,): 3.0}
    q = fra.Query(
        fra.Select(SelPred(eqs=((0, 1),), custom=None), project_key(0), LOGISTIC, fra.scan("X", 1)),
        inputs=("X",),
    )
    out = run_query(q, {"X": rel})
    assert set(out) == {(1,)}
    np.testing.assert_allclose(out[(1,)], 1.0 / (1.0 + np.exp(2.0)))


def test_join_duplicate_keys_requires_agg():
    rel = {(0,): 1.0, (1,): 2.0}
    join = fra.Join(
        eq_pred(),                        # cross join (no predicate)
        jproj(L(0)),                      # non-injective: drops right key
        MUL,
        fra.scan("A", 1),
        fra.scan("B", 1),
    )
    q = fra.Query(join, inputs=("A", "B"))
    with pytest.raises(ValueError, match="duplicate key"):
        run_query(q, {"A": rel, "B": rel})
    # Wrapped in Σ with identity grp, duplicates merge.
    q2 = fra.Query(fra.Agg(identity_key(1), ADD, join), inputs=("A", "B"))
    out = run_query(q2, {"A": rel, "B": rel})
    assert out[(0,)] == pytest.approx(1.0 * 1.0 + 1.0 * 2.0)
    assert out[(1,)] == pytest.approx(2.0 * 1.0 + 2.0 * 2.0)


def test_add_total_derivative_semantics():
    a = {(0,): 1.0, (1,): 2.0}
    b = {(1,): 10.0, (2,): 20.0}
    q = fra.Query(
        fra.AddOp(fra.scan("A", 1), fra.scan("B", 1)),
        inputs=("A", "B"),
    )
    out = run_query(q, {"A": a, "B": b})
    assert out == {(0,): 1.0, (1,): 12.0, (2,): 20.0}
