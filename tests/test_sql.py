"""SQL frontend: paper SQL fragments → FRA → (autodiff) → compiled
execution, validated against the interpreter oracle and jax.grad."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compiler, fra
from repro.core.autodiff import ra_autodiff
from repro.core.interpreter import run_query
from repro.core.relation import DenseRelation
from repro.core.sql import SQLError, compile_sql, sql_autodiff


# ---------------------------------------------------------------------------
# The paper's §1 blocked matrix multiply SQL
# ---------------------------------------------------------------------------

MATMUL_SQL = """
SELECT A.row, B.col, SUM(matrix_multiply(A.mat, B.mat))
FROM A, B WHERE A.col = B.row
GROUP BY A.row, B.col
"""


def test_paper_matmul_sql_compiles_and_runs():
    q = compile_sql(
        MATMUL_SQL,
        schema={"A": ("row", "col"), "B": ("row", "col")},
        inputs=("A", "B"),
    )
    assert isinstance(q.root, fra.Agg)
    assert isinstance(q.root.child, fra.Join)

    # 2×2 grid of 2×2 chunks, checked against jnp.matmul
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(2, 2, 2, 2)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(2, 2, 2, 2)).astype(np.float32))
    out = compiler.execute(
        q.root, {"A": DenseRelation(a, 2), "B": DenseRelation(b, 2)}
    )
    full_a = np.block([[np.asarray(a[i, j]) for j in range(2)] for i in range(2)])
    full_b = np.block([[np.asarray(b[i, j]) for j in range(2)] for i in range(2)])
    full_o = np.block([[np.asarray(out.data[i, j]) for j in range(2)] for i in range(2)])
    np.testing.assert_allclose(full_o, full_a @ full_b, rtol=1e-5)


def test_paper_matmul_sql_gradients():
    q = compile_sql(
        MATMUL_SQL,
        schema={"A": ("row", "col"), "B": ("row", "col")},
        inputs=("A", "B"),
    )
    # loss = sum of all output entries: seed with ones over the output grid
    prog = ra_autodiff(q)
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.normal(size=(2, 2, 2, 2)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(2, 2, 2, 2)).astype(np.float32))
    env = {"A": DenseRelation(a, 2), "B": DenseRelation(b, 2)}
    seed = DenseRelation(jnp.ones((2, 2, 2, 2), jnp.float32), 2)
    out, grads = compiler.grad_eval(prog, env, seed=seed)

    def loss(a, b):
        fa = jnp.concatenate([jnp.concatenate([a[i, j] for j in range(2)], 1)
                              for i in range(2)], 0)
        fb = jnp.concatenate([jnp.concatenate([b[i, j] for j in range(2)], 1)
                              for i in range(2)], 0)
        return jnp.sum(fa @ fb)

    ga, gb = jax.grad(loss, argnums=(0, 1))(a, b)
    np.testing.assert_allclose(np.asarray(grads["A"].data), np.asarray(ga), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(grads["B"].data), np.asarray(gb), rtol=1e-5)


# ---------------------------------------------------------------------------
# §2.3 logistic regression pipeline via views
# ---------------------------------------------------------------------------

LOGREG_SQL = """
mm   := SELECT Rx.row, SUM(multiply(Rx.val, theta.val))
        FROM Rx, theta WHERE Rx.col = theta.col GROUP BY Rx.row;
pred := SELECT mm.row, logistic(mm.val) FROM mm;
SELECT SUM(xent(pred.val, Ry.val)) FROM pred, Ry WHERE pred.row = Ry.row
"""

SCHEMA = {"Rx": ("row", "col"), "theta": ("col",), "Ry": ("row",)}


def test_logreg_sql_matches_jax():
    prog = sql_autodiff(LOGREG_SQL, SCHEMA, inputs=("theta",))
    n, m = 64, 8
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    X = jax.random.normal(k1, (n, m))
    y = (jax.random.uniform(k2, (n,)) > 0.5).astype(jnp.float32)
    theta = jax.random.normal(k3, (m,)) * 0.1

    env = {
        "Rx": DenseRelation(X, 2),
        "Ry": DenseRelation(y, 1),
        "theta": DenseRelation(theta, 1),
    }
    loss, grads = compiler.grad_eval(prog, env)

    def jax_loss(theta):
        yhat = jax.nn.sigmoid(X @ theta)
        return jnp.sum(-y * jnp.log(yhat) + (y - 1.0) * jnp.log1p(-yhat))

    lj, gj = jax.value_and_grad(jax_loss)(theta)
    np.testing.assert_allclose(float(loss.data), float(lj), rtol=1e-5)
    np.testing.assert_allclose(
        np.asarray(grads["theta"].data), np.asarray(gj), rtol=1e-4, atol=1e-6
    )


def test_logreg_sql_interpreter_oracle():
    """The SQL-compiled query agrees with the tuple-at-a-time interpreter."""
    q = compile_sql(LOGREG_SQL, SCHEMA, inputs=("theta",))
    rng = np.random.default_rng(2)
    n, m = 6, 3
    X = rng.normal(size=(n, m)).astype(np.float32)
    y = (rng.uniform(size=n) > 0.5).astype(np.float32)
    theta = rng.normal(size=m).astype(np.float32) * 0.1

    sparse_env = {
        "Rx": {(i, j): float(X[i, j]) for i in range(n) for j in range(m)},
        "Ry": {(i,): float(y[i]) for i in range(n)},
        "theta": {(j,): float(theta[j]) for j in range(m)},
    }
    out = run_query(q, sparse_env)
    dense_env = {
        "Rx": DenseRelation(jnp.asarray(X), 2),
        "Ry": DenseRelation(jnp.asarray(y), 1),
        "theta": DenseRelation(jnp.asarray(theta), 1),
    }
    dense_out = compiler.execute(q.root, dense_env)
    np.testing.assert_allclose(out[()], float(dense_out.data), rtol=1e-4)


# ---------------------------------------------------------------------------
# Grammar / error cases
# ---------------------------------------------------------------------------


def test_single_table_selection_with_literal_pred():
    q = compile_sql(
        "SELECT T.i, relu(T.v) FROM T WHERE T.i = 1",
        schema={"T": ("i",)},
        inputs=("T",),
    )
    out = run_query(q, {"T": {(0,): -5.0, (1,): -3.0, (2,): 7.0}})
    assert out == {(1,): 0.0}


def test_bad_kernel_name_raises():
    with pytest.raises(SQLError, match="unknown kernel"):
        compile_sql("SELECT frobnicate(T.v) FROM T", {"T": ("i",)}, ("T",))


def test_three_way_join_rejected_with_hint():
    with pytest.raises(SQLError, match="use views"):
        compile_sql(
            "SELECT SUM(multiply(A.v, B.v)) FROM A, B, C",
            {"A": ("i",), "B": ("i",), "C": ("i",)},
            ("A",),
        )


def test_key_used_as_value_rejected():
    with pytest.raises(SQLError, match="is a key"):
        compile_sql(
            "SELECT logistic(T.i) FROM T", {"T": ("i",)}, ("T",)
        )


def test_group_by_mismatch_rejected():
    with pytest.raises(SQLError, match="GROUP BY"):
        compile_sql(
            "SELECT A.row, SUM(multiply(A.v, B.v)) FROM A, B "
            "WHERE A.col = B.col GROUP BY A.col",
            {"A": ("row", "col"), "B": ("col",)},
            ("A",),
        )


# ---------------------------------------------------------------------------
# Error paths: SQLError messages must name the offending token
# ---------------------------------------------------------------------------

_SCHEMA = {"A": ("row", "col"), "B": ("row", "col")}


def _compile(script, schema=_SCHEMA, inputs=("A",)):
    return compile_sql(script, schema=schema, inputs=inputs)


def test_sql_error_unknown_table_names_token():
    with pytest.raises(SQLError, match=r"unknown relation 'Foo'"):
        _compile("SELECT Foo.row, SUM(Foo.val) FROM Foo GROUP BY Foo.row",
                 inputs=())


def test_sql_error_unknown_key_column_names_token():
    with pytest.raises(SQLError, match=r"A\.bogus is not a key attribute"):
        _compile("SELECT A.bogus, SUM(A.val) FROM A GROUP BY A.bogus")


def test_sql_error_unknown_table_in_value_expr_names_token():
    with pytest.raises(SQLError, match=r"unknown table 'C'"):
        _compile("SELECT A.row, SUM(multiply(A.val, C.val)) FROM A, B "
                 "WHERE A.col = B.row GROUP BY A.row")


def test_sql_error_bad_aggregate_names_token():
    with pytest.raises(SQLError, match=r"unsupported aggregate 'AVG'"):
        _compile("SELECT A.row, AVG(A.val) FROM A GROUP BY A.row")
    with pytest.raises(SQLError, match=r"unknown kernel function 'frobnicate'"):
        _compile("SELECT A.row, frobnicate(A.val) FROM A")


def test_sql_error_join_on_value_attr_names_token():
    with pytest.raises(SQLError, match=r"A\.val is not a key attribute"):
        _compile("SELECT A.row, B.col, SUM(multiply(A.val, B.val)) "
                 "FROM A, B WHERE A.val = B.row GROUP BY A.row, B.col")


def test_sql_error_key_used_as_value_names_token():
    with pytest.raises(SQLError, match=r"A\.row is a key, not a value"):
        _compile("SELECT A.col, SUM(multiply(A.row, B.val)) FROM A, B "
                 "WHERE A.col = B.row GROUP BY A.col")


def test_sql_error_group_by_mismatch_names_columns():
    with pytest.raises(SQLError, match=r"\['col'\].*\['row'\]"):
        _compile("SELECT A.row, SUM(A.val) FROM A GROUP BY A.col")


def test_sql_error_duplicate_alias_names_token():
    with pytest.raises(SQLError, match=r"duplicate table alias 'x'"):
        _compile("SELECT x.row, SUM(multiply(x.val, x.val)) FROM A x, B x "
                 "WHERE x.col = x.row GROUP BY x.row")


# ---------------------------------------------------------------------------
# db.sql round trip against the FRA-built equivalent
# ---------------------------------------------------------------------------


def test_db_sql_matmul_matches_fra_equivalent():
    import repro
    from repro.core.kernels import ADD, MATMUL
    from repro.core.keys import L, R, eq_pred, jproj, project_key

    rng = np.random.default_rng(7)
    a = jnp.asarray(rng.normal(size=(2, 2, 4, 4)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(2, 2, 4, 4)).astype(np.float32))

    db = repro.Database()
    db.put("A", a, keys=("row", "col"))
    db.put("B", b, keys=("row", "col"))
    handle = db.sql(MATMUL_SQL, wrt=("A", "B"))
    out_sql = handle.forward()

    join = fra.Join(
        eq_pred((1, 0)), jproj(L(0), L(1), R(1)), MATMUL,
        fra.scan("A", 2), fra.scan("B", 2),
    )
    q = fra.Query(fra.Agg(project_key(0, 2), ADD, join), inputs=("A", "B"))
    out_fra = db.query(q).forward()
    np.testing.assert_allclose(
        np.asarray(out_sql.data), np.asarray(out_fra.data), rtol=1e-5
    )

    # and the gradient round trip
    seed = jnp.ones_like(out_fra.data)
    g_sql = handle.grad(seed=seed)
    g_fra = db.query(q).grad(seed=seed)
    for name in ("A", "B"):
        np.testing.assert_allclose(
            np.asarray(g_sql[name].data),
            np.asarray(g_fra[name].data),
            rtol=1e-5,
        )


# ---------------------------------------------------------------------------
# structured diagnostics on the error paths
# ---------------------------------------------------------------------------


def test_sql_errors_carry_structured_diagnostics():
    from repro.analysis.diagnostics import Diagnostic

    with pytest.raises(SQLError, match="unknown relation") as ei:
        compile_sql("SELECT SUM(Ghost.val) FROM Ghost", SCHEMA)
    d = ei.value.diagnostic
    assert isinstance(d, Diagnostic)
    assert d.severity == "error" and d.code == "unknown-relation"
    assert "stmt[0]" in d.node_path
    assert "Rx" in d.hint  # hint lists the known relations
    # str(err) renders the node path and hint for except-and-print callers
    assert "stmt[0]" in str(ei.value) and "hint" in str(ei.value)


def test_sql_diagnostic_names_the_offending_view_statement():
    bad = """
    mm := SELECT Rx.row, SUM(multiply(Rx.val, theta.val))
          FROM Rx, theta WHERE Rx.col = theta.col GROUP BY Rx.row;
    SELECT SUM(mm.val) FROM mm GROUP BY mm.nope
    """
    with pytest.raises(SQLError) as ei:
        compile_sql(bad, SCHEMA)
    d = ei.value.diagnostic
    assert d.node_path == "stmt[1]"       # the failing SELECT, not the view
    assert d.code == "group-by-mismatch"
    assert d.hint


def test_sql_key_as_value_diagnostic():
    with pytest.raises(SQLError, match="is a key, not a value") as ei:
        compile_sql("SELECT Rx.row, SUM(Rx.col) FROM Rx GROUP BY Rx.row",
                    SCHEMA)
    assert ei.value.diagnostic.code == "key-as-value"
    assert ei.value.diagnostic.node_path == "stmt[0]"
