"""Out-of-core streaming lane: the GCN grad step with the edge relation
oversubscribed ≥4× past a simulated device-memory budget.

Two lanes per graph, both through the ``Database`` front door so the
streamed path is the one users actually hit:

  incore — ``Database()`` with no budget: one jitted step over the whole
           graph (the oracle, and the pre-PR behaviour)
  oocore — ``Database(memory_budget=...)`` with the budget set to
           node-bytes + edge-bytes/4: the planner streams the
           owner-partitioned edge relation through ≥4 double-buffered
           chunk waves, Σ accumulating across waves

Results are asserted to agree to atol 1e-5 before anything is recorded,
so a silently-wrong streamed step can never post a timing. ``derived``
carries the wave count, the oversubscription ratio (edge bytes over the
budget headroom left after resident relations), and the spill counters.

Runs on any device count — streaming is a host↔device tier decision,
not a mesh one. The tier1-oocore CI lane runs it on the 4×2 host mesh
and gates the emitted BENCH_oocore_scale.json against the committed
baseline via ``tools/check_bench.py --suites oocore_scale``.
"""

from __future__ import annotations

import numpy as np

import repro
from repro.core import fra
from repro.core.engine import StreamedCompiled
from repro.core.kernels import ADD, MUL, SQUARE, SUM_CHUNK, scale_kernel
from repro.core.keys import EMPTY_KEY, TRUE, L, eq_pred, identity_key, jproj
from repro.core.planner import _rel_bytes

from .common import record, timeit

ATOL = 1e-5

#: name, nodes, edges, feature dim — sized so the budgeted lane streams
#: ≥4 waves while staying inside the CI time box
GRAPHS = [
    ("pubmed-mini", 500, 20_000, 16),
    ("arxiv-mini", 1_000, 80_000, 32),
]


def _gcn_query(n: int) -> fra.Query:
    conv = fra.Agg(
        identity_key(1), ADD,
        fra.Join(
            eq_pred((0, 0)), jproj(L(1)), MUL,
            fra.scan("Edge", 2), fra.scan("Node", 1),
        ),
    )
    sq = fra.Select(TRUE, identity_key(1), SQUARE, conv)
    loss = fra.Agg(
        EMPTY_KEY, ADD, fra.Select(TRUE, identity_key(1), SUM_CHUNK, sq)
    )
    mean = fra.Select(TRUE, identity_key(0), scale_kernel(1.0 / n), loss)
    return fra.Query(mean, inputs=("Edge", "Node"))


def _fill(db, rng, n: int, e: int, d: int):
    import jax.numpy as jnp

    from repro.relational.gcn import partitioned_edges

    edge = partitioned_edges(
        np.stack([rng.integers(0, n, e), rng.integers(0, n, e)], 1),
        (rng.normal(size=e) / np.sqrt(e / n)).astype(np.float32),
        n,
        8,
    )
    db.put("Edge", edge)
    db.put(
        "Node",
        jnp.asarray(rng.normal(size=(n, d)), jnp.float32),
        keys=("node",),
    )
    return db


def _leaves(loss, grads):
    out = [np.asarray(loss.data)]
    for _, g in sorted(grads.items()):
        out.append(np.asarray(g.values if hasattr(g, "values") else g.data))
    return out


def run() -> None:
    for seed, (name, n, e, d) in enumerate(GRAPHS, start=17):
        q = _gcn_query(n)
        wrt = ("Edge", "Node")

        db0 = _fill(repro.Database(), np.random.default_rng(seed), n, e, d)
        h0 = db0.query(q)
        l0, g0 = h0.step(wrt=wrt)
        base = _leaves(l0, g0)
        us = timeit(lambda: h0.step(wrt=wrt), iters=5, warmup=2)
        edge_bytes = _rel_bytes(db0.get("Edge"))
        node_bytes = _rel_bytes(db0.get("Node"))
        record(
            f"oocore_scale/{name}/incore", us,
            f"edge_bytes={edge_bytes};E={e};n={n};d={d}",
        )

        # edge relation ≥4× the headroom the budget leaves after the
        # resident (node) relation -> the planner must stream ≥4 waves
        budget = node_bytes + edge_bytes / 4
        headroom = budget - node_bytes
        assert edge_bytes >= 4 * headroom
        db = _fill(
            repro.Database(memory_budget=budget),
            np.random.default_rng(seed), n, e, d,
        )
        h = db.query(q)
        l1, g1 = h.step(wrt=wrt)
        assert isinstance(h.last, StreamedCompiled), "budget did not stream"
        waves = h.last.num_waves
        assert waves >= 4, f"expected >=4 waves, planned {waves}"
        for got, want in zip(_leaves(l1, g1), base):
            np.testing.assert_allclose(got, want, atol=ATOL, rtol=1e-5)
        us = timeit(lambda: h.step(wrt=wrt), iters=5, warmup=2)
        st = db.counters()["spill"]
        record(
            f"oocore_scale/{name}/oocore", us,
            f"waves={waves};oversub={edge_bytes / headroom:.1f}"
            f";spilled_bytes={st['spilled_bytes']}"
            f";fetched_chunks={st['fetched_chunks']}",
        )


if __name__ == "__main__":
    from .common import ROWS, emit_header, emit_json

    emit_header()
    run()
    emit_json("BENCH_oocore_scale.json", ROWS)
