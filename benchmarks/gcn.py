"""GCN training per-epoch (paper Tables 2–3, scaled to this container).

Three systems on the same synthetic graphs:
  ra-gcn        — 2-layer GCN whose message passing + projections run
                  through the relational engine (RA-autodiff backward)
  ra-gcn(full)  — full-graph training (the paper's headline capability)
  jax-gcn       — hand-written pure-JAX GCN via jax.grad (the DistDGL
                  stand-in: special-purpose baseline)

Graphs scale as (nodes, edges) ∝ the paper's ogbn ladder, shrunk to CPU.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import synthetic_graph
from repro.optim import adam_init, adam_update
from repro.relational import gcn_conv, rel_linear

from .common import record, timeit

GRAPHS = [
    ("arxiv-mini", 4_000, 22_000, 64, 16),
    ("products-mini", 2_000, 80_000, 64, 16),
    ("papers-mini", 20_000, 320_000, 64, 32),
]


def init_params(key, n_feat, hidden, n_labels):
    k1, k2 = jax.random.split(key)
    s1 = (n_feat ** -0.5)
    s2 = (hidden ** -0.5)
    return {
        "w1": jax.random.normal(k1, (n_feat, hidden)) * s1,
        "w2": jax.random.normal(k2, (hidden, n_labels)) * s2,
    }


def make_ra_step(g, hidden, n_labels, batch_nodes=None):
    keys, w, x, y = g["edge_keys"], g["edge_w"], g["x"], g["y"]
    n = g["n_nodes"]

    def loss_fn(params):
        h = gcn_conv(x, keys, w)
        h = jax.nn.relu(rel_linear(h, params["w1"]))
        h = gcn_conv(h, keys, w)
        logits = rel_linear(h, params["w2"])
        if batch_nodes is not None:
            logits = logits[:batch_nodes]
            yy = y[:batch_nodes]
        else:
            yy = y
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(logp, yy[:, None], axis=1))

    @jax.jit
    def step(params, opt):
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt = adam_update(params, grads, opt, lr=0.1)
        return params, opt, loss

    return step


def make_jax_step(g, hidden, n_labels, batch_nodes=None):
    keys, w, x, y = g["edge_keys"], g["edge_w"], g["x"], g["y"]
    src, dst = keys[:, 0], keys[:, 1]
    n = g["n_nodes"]

    def conv(h):
        msg = w[:, None] * h[src]
        return jnp.zeros_like(h).at[dst].add(msg)

    def loss_fn(params):
        h = conv(x)
        h = jax.nn.relu(h @ params["w1"])
        h = conv(h)
        logits = h @ params["w2"]
        if batch_nodes is not None:
            logits = logits[:batch_nodes]
            yy = y[:batch_nodes]
        else:
            yy = y
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(logp, yy[:, None], axis=1))

    @jax.jit
    def step(params, opt):
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt = adam_update(params, grads, opt, lr=0.1)
        return params, opt, loss

    return step


def run() -> None:
    hidden = 32
    for name, n, e, f, c in GRAPHS:
        g = synthetic_graph(n, e, f, c, seed=1)
        params = init_params(jax.random.PRNGKey(0), f, hidden, c)
        opt = adam_init(params)
        batch = max(256, n // 8)

        for tag, step in (
            (f"gcn/{name}/ra-minibatch", make_ra_step(g, hidden, c, batch)),
            (f"gcn/{name}/ra-full", make_ra_step(g, hidden, c, None)),
            (f"gcn/{name}/jax-full", make_jax_step(g, hidden, c, None)),
        ):
            us = timeit(step, params, opt, iters=3, warmup=1)
            record(tag, us, f"n={n};e={e}")

        # correctness cross-check: RA loss == JAX loss after one step
        ra = make_ra_step(g, hidden, c, None)
        jx = make_jax_step(g, hidden, c, None)
        _, _, l1 = ra(params, opt)
        _, _, l2 = jx(params, opt)
        assert abs(float(l1) - float(l2)) < 1e-4 * max(1.0, abs(float(l2))), (
            float(l1), float(l2),
        )
