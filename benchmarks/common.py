"""Benchmark harness utilities: timing + CSV/JSON emission."""

from __future__ import annotations

import json
import time
from typing import Callable, List, Tuple

import jax

ROWS: List[Tuple[str, float, str]] = []


def timeit(fn: Callable, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall time in microseconds; blocks on jax outputs."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def record(name: str, us: float, derived: str = "") -> None:
    ROWS.append((name, us, derived))
    print(f"{name},{us:.1f},{derived}")


def emit_header() -> None:
    print("name,us_per_call,derived")


def emit_json(path: str, rows=None) -> None:
    """Dump rows (default: everything recorded so far) as a BENCH_*.json
    artifact so wins are machine-readable across PRs."""
    rows = ROWS if rows is None else rows
    payload = [
        {"name": n, "us_per_call": us, "derived": d} for n, us, d in rows
    ]
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {path} ({len(payload)} rows)")
