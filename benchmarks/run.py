"""Benchmark driver: one benchmark per paper table/figure.

  logreg           §2.3 running example — RA-autodiff overhead vs jax.grad
  gcn              Tables 2–3 — GCN per-epoch, mini-batch + full-graph
  nnmf             Figure 2 — non-negative matrix factorization per-epoch
  kge              Figure 3 — TransE/TransR 100-iteration time
  rjp_ablation     §4 — RJP optimizations on/off
  engine_overhead  staged engine: eager re-lowering vs cached Compiled
  kernel_dispatch  dispatch tiers: jnp vs ref (vs pallas on TPU), raw
                   kernels + compiled logreg/GCN grad steps
  coo_scale        COO nnz sharding: replicated vs nnz-sharded GCN grad
                   step, per-device edge-relation bytes (needs >=2
                   devices for the sharded lane to differ)
  oocore_scale     out-of-core streaming: GCN grad step with the edge
                   relation >=4x past the simulated device-memory
                   budget, chunk waves vs the in-core oracle
  serving_load     async serving front door: open-loop concurrent
                   single-row requests through db.endpoint, sustained
                   QPS + p50/p99 latency (continuous batching on)

Each suite's rows are also written to BENCH_<suite>.json.

Usage:  PYTHONPATH=src python -m benchmarks.run [name ...]
"""

import sys

from .common import ROWS, emit_header, emit_json


def main() -> None:
    from . import (
        coo_scale,
        engine_overhead,
        gcn,
        kernel_dispatch,
        kge,
        logreg,
        nnmf,
        oocore_scale,
        rjp_ablation,
        serving_load,
    )

    suites = {
        "logreg": logreg.run,
        "gcn": gcn.run,
        "nnmf": nnmf.run,
        "kge": kge.run,
        "rjp_ablation": rjp_ablation.run,
        "engine_overhead": engine_overhead.run,
        "kernel_dispatch": kernel_dispatch.run,
        "coo_scale": coo_scale.run,
        "oocore_scale": oocore_scale.run,
        "serving_load": serving_load.run,
    }
    names = sys.argv[1:] or list(suites)
    unknown = [n for n in names if n not in suites]
    if unknown:
        raise SystemExit(f"unknown benchmark(s) {unknown}; have {list(suites)}")
    emit_header()
    for n in names:
        print(f"# --- {n} ---")
        start = len(ROWS)
        suites[n]()
        emit_json(f"BENCH_{n}.json", ROWS[start:])


if __name__ == "__main__":
    main()
