"""Kernel dispatch: the routed segment-sum / blocked-matmul tiers head to
head, raw and under compiled engine steps.

Three sections:

  segsum-raw /     the two dispatch ops at GCN- and logreg-representative
  matmul-raw       shapes, per tier — ``jnp`` (the compiler's default
                   lowering) vs ``ref`` (the kernel packages' jnp oracle)
                   vs ``pallas`` where a TPU is attached
  engine-*-grad    compiled logreg and GCN gradient steps per tier; the
                   jnp-tier result is the correctness oracle, asserted to
                   atol 1e-5
  interpret-probe  Pallas interpreter-mode at small shapes: the CPU
                   stand-in proving the TPU kernels' logic inside a
                   compiled step, also asserted against jnp

On CPU the jnp-vs-ref delta is the headline number (ref is the oracle the
Pallas kernels are tested against, so the delta isolates dispatch-layer
overhead — it should be ≈1.0x); on TPU the pallas rows report the actual
kernel speedup over the jnp tier.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fra
from repro.core.autodiff import ra_autodiff
from repro.core.engine import engine_for
from repro.core.kernels import (
    ADD,
    MUL,
    make_table,
    resolve_impl,
    scale_kernel,
)
from repro.core.keys import EMPTY_KEY, TRUE, L, eq_pred, identity_key, jproj
from repro.core.relation import CooRelation, DenseRelation

from .common import record, timeit
from .logreg import logreg_query

ATOL = 1e-5


def _tiers():
    if jax.default_backend() == "tpu":
        return ("jnp", "ref", "pallas")
    return ("jnp", "ref")


def _logreg_prog(n: int):
    # mean (not sum) loss keeps gradient magnitudes O(1), so the atol-1e-5
    # cross-tier check measures kernel agreement, not summation scale
    q = logreg_query()
    mean = fra.Select(TRUE, identity_key(0), scale_kernel(1.0 / n), q.root)
    return ra_autodiff(fra.Query(mean, inputs=q.inputs))


def _logreg_env(rng, n: int, m: int):
    return {
        "Rx": DenseRelation(jnp.asarray(rng.normal(size=(n, m)), jnp.float32), 2),
        "Ry": DenseRelation(
            jnp.asarray(rng.integers(0, 2, size=n), jnp.float32), 1
        ),
        "theta": DenseRelation(
            jnp.asarray(rng.normal(size=m) * 0.01, jnp.float32), 1
        ),
    }


def _gcn_prog(n: int):
    from repro.core.kernels import SQUARE, SUM_CHUNK

    conv = fra.Agg(
        identity_key(1), ADD,
        fra.Join(
            eq_pred((0, 0)), jproj(L(1)), MUL,
            fra.const("Edge", 2), fra.scan("Node", 1),
        ),
    )
    sq = fra.Select(TRUE, identity_key(1), SQUARE, conv)
    loss = fra.Agg(
        EMPTY_KEY, ADD, fra.Select(TRUE, identity_key(1), SUM_CHUNK, sq)
    )
    mean = fra.Select(TRUE, identity_key(0), scale_kernel(1.0 / n), loss)
    return ra_autodiff(fra.Query(mean, inputs=("Node",)))


def _gcn_env(rng, n: int, e: int, d: int):
    src = rng.integers(0, n, size=e)
    dst = rng.integers(0, n, size=e)
    return {
        "Edge": CooRelation(
            jnp.asarray(np.stack([src, dst], 1), jnp.int32),
            jnp.asarray(rng.normal(size=e) / np.sqrt(e / n), jnp.float32),
            (n, n),
        ),
        "Node": DenseRelation(
            jnp.asarray(rng.normal(size=(n, d)), jnp.float32), 1
        ),
    }


def _grad_leaves(out, grads):
    leaves = [np.asarray(out.data)]
    for name in sorted(grads):
        g = grads[name]
        leaves.append(np.asarray(g.values if isinstance(g, CooRelation) else g.data))
    return leaves


def _bench_raw_segsum() -> None:
    rng = np.random.default_rng(0)
    for e, d, s in ((320_000, 32, 20_000), (22_000, 64, 4_000)):
        msg = jnp.asarray(rng.normal(size=(e, d)), jnp.float32)
        seg = jnp.asarray(rng.integers(0, s, size=e), jnp.int32)
        info = {"nnz": e, "dim": d, "num_segments": s, "dtype": msg.dtype}
        base_us, base_out = None, None
        for tier in _tiers():
            impl = resolve_impl("segment_sum", info, make_table(tier))
            fn = jax.jit(lambda m, sg, _f=impl.fn, _s=s: _f(m, sg, _s))
            us = timeit(fn, msg, seg, iters=5, warmup=2)
            out = np.asarray(fn(msg, seg))
            if tier == "jnp":
                base_us, base_out = us, out
                derived = f"E={e};D={d};S={s}"
            else:
                np.testing.assert_allclose(out, base_out, rtol=1e-4, atol=1e-4)
                derived = f"vs_jnp={base_us / us:.2f}x"
            record(f"kernel_dispatch/segsum-raw/E{e}-D{d}-S{s}/{tier}", us, derived)


def _bench_raw_matmul() -> None:
    rng = np.random.default_rng(1)
    for m, k, n in ((4096, 256, 256), (20_000, 64, 32)):
        x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
        y = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
        info = {"m": m, "k": k, "n": n, "dtype": x.dtype}
        base_us, base_out = None, None
        for tier in _tiers():
            impl = resolve_impl("blocked_matmul", info, make_table(tier))
            fn = jax.jit(impl.fn)
            us = timeit(fn, x, y, iters=5, warmup=2)
            out = np.asarray(fn(x, y))
            if tier == "jnp":
                base_us, base_out = us, out
                derived = f"m={m};k={k};n={n}"
            else:
                np.testing.assert_allclose(
                    out, base_out, rtol=1e-4, atol=1e-3 * np.sqrt(k)
                )
                derived = f"vs_jnp={base_us / us:.2f}x"
            record(f"kernel_dispatch/matmul-raw/{m}x{k}x{n}/{tier}", us, derived)


def _bench_engine(tag: str, prog, env, tiers, iters: int = 10) -> None:
    eng = engine_for(prog)
    base_us, base_leaves = None, None
    for tier in tiers:
        comp = eng.lower(env, dispatch=tier).compile()
        out, grads = comp(env)                       # trace once
        leaves = _grad_leaves(out, grads)
        t0 = eng.trace_count
        us = timeit(lambda: comp(env), iters=iters, warmup=2)
        retraces = eng.trace_count - t0
        assert retraces == 0, f"{tag}/{tier} re-lowered on a fixed signature"
        sites = ",".join(
            f"{k}={v}" for k, v in sorted(comp.resolutions.items())
        )
        if tier == "jnp":
            base_us, base_leaves = us, leaves
            derived = sites
        else:
            for got, want in zip(leaves, base_leaves):
                np.testing.assert_allclose(got, want, rtol=1e-5, atol=ATOL)
            derived = f"vs_jnp={base_us / us:.2f}x;{sites}"
        record(f"kernel_dispatch/{tag}/{tier}", us, derived)


def _bench_interpret_probe() -> None:
    """Pallas interpreter mode inside compiled steps, small shapes: the
    CPU correctness probe for the TPU kernel logic (timed for visibility,
    not for speed — interpret mode is slow by construction)."""
    rng = np.random.default_rng(2)
    for tag, prog, env in (
        ("logreg", _logreg_prog(48), _logreg_env(rng, 48, 12)),
        ("gcn", _gcn_prog(16), _gcn_env(rng, 16, 40, 8)),
    ):
        eng = engine_for(prog)
        out_j, grads_j = eng.lower(env, dispatch="jnp").compile()(env)
        comp = eng.lower(env, dispatch="interpret").compile()
        out_i, grads_i = comp(env)
        for got, want in zip(
            _grad_leaves(out_i, grads_i), _grad_leaves(out_j, grads_j)
        ):
            np.testing.assert_allclose(got, want, rtol=1e-5, atol=ATOL)
        us = timeit(lambda: comp(env), iters=2, warmup=1)
        record(
            f"kernel_dispatch/interpret-probe/{tag}", us,
            "matches_jnp_atol=1e-5",
        )


def run() -> None:
    tiers = _tiers()
    _bench_raw_segsum()
    _bench_raw_matmul()
    rng = np.random.default_rng(3)
    _bench_engine(
        "engine-logreg-grad", _logreg_prog(8192), _logreg_env(rng, 8192, 256), tiers
    )
    _bench_engine(
        "engine-gcn-grad", _gcn_prog(4000), _gcn_env(rng, 4000, 22_000, 64), tiers
    )
    _bench_interpret_probe()


if __name__ == "__main__":
    from .common import ROWS, emit_header, emit_json

    emit_header()
    run()
    emit_json("BENCH_kernel_dispatch.json", ROWS)
