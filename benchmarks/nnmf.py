"""Non-negative matrix factorization per-epoch (paper Appendix B, Fig 2).

A ≈ relu(W) · relu(H) under squared loss, SGD η=0.1 (as the paper). The
RA path uses the *blocked* relational matmul (chunked relations, Fig 1);
the baseline is hand-written jnp via jax.grad (the Dask/MPI stand-in).
Cases mirror the paper's (N, D) ladder, shrunk to CPU scale.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim import sgd_update
from repro.relational.linear import rel_matmul_blocked

from .common import record, timeit

CASES = [
    ("n1024-d1024", 1024, 1024, 32),
    ("n1280-d1024", 1280, 1024, 32),
    ("n1536-d256", 1536, 256, 32),
    ("n256-d1536", 256, 1536, 32),
]

BLOCK = 256


def _to_blocks(x):
    m, n = x.shape
    return (
        x.reshape(m // BLOCK, BLOCK, n // BLOCK, BLOCK).transpose(0, 2, 1, 3)
    )


def run() -> None:
    rank = 32
    for name, n, d, _ in CASES:
        key = jax.random.PRNGKey(0)
        k1, k2, k3 = jax.random.split(key, 3)
        a = jax.random.uniform(k1, (n, d))
        w0 = jax.random.uniform(k2, (n, rank)) * 0.1
        h0 = jax.random.uniform(k3, (rank, d)) * 0.1
        ab = _to_blocks(a)

        def ra_loss(params):
            wb = _to_blocks(jax.nn.relu(params["w"]))
            hb = _to_blocks(jax.nn.relu(params["h"]))
            pred = rel_matmul_blocked(wb, hb)
            return 0.5 * jnp.sum((pred - ab) ** 2)

        def jax_loss(params):
            pred = jax.nn.relu(params["w"]) @ jax.nn.relu(params["h"])
            return 0.5 * jnp.sum((pred - a) ** 2)

        def make(lossfn):
            @jax.jit
            def step(params):
                loss, g = jax.value_and_grad(lossfn)(params)
                params, _ = sgd_update(params, g, {}, lr=0.1 / (n * d))
                return params, loss

            return step

        params = {"w": w0, "h": h0}
        pad = BLOCK - rank  # rank dim must tile; pad factor matrices
        params = {
            "w": jnp.pad(w0, ((0, 0), (0, pad))),
            "h": jnp.pad(h0, ((0, pad), (0, 0))),
        }
        ra = make(ra_loss)
        jx = make(jax_loss)
        record(f"nnmf/{name}/ra", timeit(ra, params, iters=3, warmup=1), f"n={n};d={d}")
        record(f"nnmf/{name}/jax", timeit(jx, params, iters=3, warmup=1), f"n={n};d={d}")
        _, l1 = ra(params)
        _, l2 = jx(params)
        assert abs(float(l1) - float(l2)) < 1e-3 * max(1.0, abs(float(l2)))
