"""Engine staging micro-benchmark: eager ``compiler.execute`` re-walks the
FRA graph (Python lowering) on every call; a staged ``Compiled`` walks it
once at trace time and then steps through the jit cache. This measures
both regimes on the logreg gradient program (paper §2.3) and on the
blocked matmul, plus the ``repro.Database`` session path (catalog-sourced
env + statistics + committed-layout record per step) against the raw
``Compiled`` step — the session's front-door overhead — and reports
steps/sec plus the engine's retrace count — the number of actual graph
walks over the whole timed run.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

import repro
from repro.core import compiler, fra
from repro.core.autodiff import ra_autodiff
from repro.core.engine import engine_for
from repro.core.kernels import ADD, MATMUL
from repro.core.keys import L, R, eq_pred, jproj, project_key
from repro.core.relation import DenseRelation

from .common import record, timeit
from .logreg import logreg_query


def _matmul_query():
    join = fra.Join(
        eq_pred((1, 0)), jproj(L(0), L(1), R(1)), MATMUL,
        fra.scan("A", 2), fra.scan("B", 2),
    )
    return fra.Query(fra.Agg(project_key(0, 2), ADD, join), inputs=("A", "B"))


def run() -> None:
    key = jax.random.PRNGKey(0)

    # ---- logreg gradient program: eager grad_eval vs staged Compiled ----
    n, m = 4096, 64
    k1, k2, k3 = jax.random.split(key, 3)
    env = {
        "Rx": DenseRelation(jax.random.normal(k1, (n, m)), 2),
        "Ry": DenseRelation(
            (jax.random.uniform(k2, (n,)) > 0.5).astype(jnp.float32), 1
        ),
        "theta": DenseRelation(jax.random.normal(k3, (m,)) * 0.01, 1),
    }
    prog = ra_autodiff(logreg_query())
    iters = 20

    us_eager = timeit(
        lambda: compiler.grad_eval(prog, env), iters=iters, warmup=2
    )

    eng = engine_for(prog)
    compiled = eng.lower(env).compile()
    compiled(env)                       # trace once
    t0 = eng.trace_count
    us_staged = timeit(lambda: compiled(env), iters=iters, warmup=2)
    retraces = eng.trace_count - t0

    record("engine_overhead/logreg-grad/eager", us_eager,
           f"n={n};m={m};steps_per_s={1e6/us_eager:.1f}")
    record("engine_overhead/logreg-grad/compiled", us_staged,
           f"retraces={retraces};steps_per_s={1e6/us_staged:.1f};"
           f"speedup={us_eager/us_staged:.2f}x")
    assert retraces == 0, "Compiled re-lowered on a fixed signature"

    # ---- Database session path: catalog env + stats + layout record ----
    # Same gradient step through the one front door; the delta vs the raw
    # Compiled step is the session's per-call overhead (env assembly,
    # stats snapshot, compile_auto record check).
    db = repro.Database()
    db.put("Rx", env["Rx"].data, keys=("row", "col"))
    db.put("Ry", env["Ry"].data, keys=("row",))
    db.put("theta", env["theta"].data, keys=("col",))
    handle = db.query(logreg_query())
    handle.step()                       # trace once
    us_session = timeit(lambda: handle.step(), iters=iters, warmup=2)
    record("engine_overhead/logreg-grad/session", us_session,
           f"steps_per_s={1e6/us_session:.1f};"
           f"overhead_vs_compiled={us_session/us_staged:.2f}x")

    # ---- blocked matmul forward: eager execute vs staged Compiled -------
    k4, k5 = jax.random.split(key)
    menv = {
        "A": DenseRelation(jax.random.normal(k4, (8, 8, 32, 32)), 2),
        "B": DenseRelation(jax.random.normal(k5, (8, 8, 32, 32)), 2),
    }
    mq = _matmul_query()
    us_eager_mm = timeit(
        lambda: compiler.execute(mq.root, menv), iters=iters, warmup=2
    )
    meng = engine_for(mq)
    mcomp = meng.lower(menv).compile()
    mcomp(menv)                         # trace once
    t0 = meng.trace_count
    us_staged_mm = timeit(lambda: mcomp(menv), iters=iters, warmup=2)
    retraces = meng.trace_count - t0

    record("engine_overhead/blocked-matmul/eager", us_eager_mm, "grid=8x8;chunk=32")
    record("engine_overhead/blocked-matmul/compiled", us_staged_mm,
           f"retraces={retraces};speedup={us_eager_mm/us_staged_mm:.2f}x")
    assert retraces == 0, "Compiled re-lowered on a fixed signature"
