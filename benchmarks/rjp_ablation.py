"""RJP optimization ablation (paper §4).

The paper lists three optimizations applied when constructing RJPs:
  1. ⋈_const elimination when ⊗ is multiplicative (mul/MatMul) — join the
     upstream gradient directly against the saved forward operand with the
     VJP kernel (Fig. 4), instead of materializing ∂⊗/∂val tuples.
  2. Σ elimination by join cardinality (1-1 joins need no re-aggregation).
  3. join-agg fusion — differentiate Σ∘⋈ as one operator.

This benchmark builds the same blocked-matmul-loss query, runs relational
auto-diff with each optimization toggled off, and measures (a) gradient
query *size* (operator count — plan complexity) and (b) compiled
execution time of one gradient evaluation. Correctness is asserted
against the fully-optimized plan.

The ``rjp/pushdown-*`` lanes measure the cost-gated Σ-through-⋈ rewrite
(core/rewrite.py) on a 3-relation multi-join Σ∘⋈ chain whose top Σ
drops the middle join key: rewrite-enabled vs rewrite-disabled compiled
gradient steps, both asserted against the jnp-tier unrewritten oracle.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compiler, fra
from repro.core.autodiff import RJPOptions, ra_autodiff
from repro.core.kernels import ADD, MATMUL, SUM_CHUNK
from repro.core.keys import (
    EMPTY_KEY, TRUE, L, R, eq_pred, identity_key, jproj, project_key,
)
from repro.core.relation import DenseRelation

from .common import record, timeit


def _matmul_loss_query() -> fra.Query:
    """loss = Σ_all sum_chunk(X ⋈ W) — blocked matmul + scalar loss."""
    join = fra.Join(
        eq_pred((1, 0)),
        jproj(L(0), L(1), R(1)),
        MATMUL,
        fra.scan("X", 2),
        fra.scan("W", 2),
    )
    mm = fra.Agg(project_key(0, 2), ADD, join)
    summed = fra.Select(TRUE, identity_key(2), SUM_CHUNK, mm)
    loss = fra.Agg(EMPTY_KEY, ADD, summed)
    return fra.Query(loss, inputs=("X", "W"))


def _plan_size(node: fra.Node) -> int:
    return len(node.topo())


def _interpreter_time(opts: RJPOptions) -> float:
    """Median time of one interpreter-path gradient evaluation on a tiny
    scalar-relation instance of the same query."""
    import time

    from repro.core.kernels import MUL

    join = fra.Join(
        eq_pred((1, 0)), jproj(L(0), L(1), R(1)), MUL,
        fra.scan("X", 2), fra.scan("W", 2),
    )
    mm = fra.Agg(project_key(0, 2), ADD, join)
    loss = fra.Agg(EMPTY_KEY, ADD, mm)
    q = fra.Query(loss, inputs=("X", "W"))
    prog = ra_autodiff(q, opts=opts)
    rng = np.random.default_rng(0)
    env = {
        "X": {(i, j): float(rng.normal()) for i in range(2) for j in range(2)},
        "W": {(i, j): float(rng.normal()) for i in range(2) for j in range(2)},
    }
    times = []
    for _ in range(5):
        t0 = time.perf_counter()
        prog.eval(env)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def _chain_query() -> fra.Query:
    """3-relation Σ∘⋈∘⋈ chain: loss = Σ_{()} Σ_{(a,d)} (A ⋈ B ⋈ C).

    The inner Σ keeps only the chain's endpoint keys, so the unrewritten
    plan materializes the full 3-key join output before aggregating —
    the shape the Σ-pushdown rewrite factorizes into per-join partial
    aggregates."""
    from repro.core.kernels import MUL

    j1 = fra.Join(
        eq_pred((1, 0)), jproj(L(0), L(1), R(1)), MUL,
        fra.scan("A", 2), fra.scan("B", 2),
    )
    j2 = fra.Join(
        eq_pred((2, 0)), jproj(L(0), L(1), L(2), R(1)), MUL,
        j1, fra.scan("C", 2),
    )
    loss = fra.Agg(EMPTY_KEY, ADD, fra.Agg(project_key(0, 3), ADD, j2))
    return fra.Query(loss, inputs=("A", "B", "C"))


def _pushdown_lane() -> None:
    """rjp/pushdown-on vs rjp/pushdown-off: compiled grad step of the
    3-relation chain with the rewrite stage enabled vs disabled."""
    from repro.core import rewrite
    from repro.core.relation import measure_stats

    q = _chain_query()
    n = 96
    rng = np.random.default_rng(1)
    scale = 1.0 / np.sqrt(n)
    arrs = {
        k: jnp.asarray(rng.normal(size=(n, n)).astype(np.float32) * scale)
        for k in ("A", "B", "C")
    }
    env = {k: DenseRelation(a, 2) for k, a in arrs.items()}
    stats = {k: measure_stats(v) for k, v in env.items()}

    # rewrite ON: the cost-gated stage factorizes the chain, and the
    # factorized program differentiates under the default RJP options.
    prog_on, report = rewrite.rewrite_program(
        ra_autodiff(q), env, stats=stats
    )
    assert report.changed, "pushdown gate unexpectedly declined"
    # rewrite OFF: the unrewritten chain's *fused* gradient has no
    # multiplicative RJP solution (the Σ drops the middle join key, so
    # the VJP w.r.t. the nested join cannot reconstruct it) — its best
    # lowerable derivation disables join-agg fusion.
    prog_off = ra_autodiff(q, opts=RJPOptions(False, True, True))

    # jnp-tier unrewritten oracle: eager jnp-table lowering of prog_off
    _, oracle = compiler.grad_eval(
        prog_off, env, fuse_join_agg=False, dispatch="jnp"
    )

    lanes = (
        ("pushdown-on", prog_on, True),
        ("pushdown-off", prog_off, False),
    )
    for name, prog, fuse in lanes:
        size = sum(_plan_size(g) for g in prog.grads.values())

        def step(A, B, C, _prog=prog, _fuse=fuse):
            e = {
                "A": DenseRelation(A, 2),
                "B": DenseRelation(B, 2),
                "C": DenseRelation(C, 2),
            }
            loss, grads = compiler.grad_eval(_prog, e, fuse_join_agg=_fuse)
            return grads["A"].data, grads["B"].data, grads["C"].data

        jstep = jax.jit(step)
        outs = jstep(arrs["A"], arrs["B"], arrs["C"])
        for g, k in zip(outs, ("A", "B", "C")):
            np.testing.assert_allclose(
                np.asarray(g), np.asarray(oracle[k].data),
                rtol=2e-4, atol=1e-5,
            )
        us = timeit(jstep, arrs["A"], arrs["B"], arrs["C"], iters=10, warmup=2)
        record(f"rjp/{name}", us, f"plan_ops={size};n={n}")


def run() -> None:
    q = _matmul_loss_query()
    gb, gk, gn = 8, 8, 8     # block grid
    cm, ck, cn = 32, 32, 32  # chunk dims
    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.normal(size=(gb, gk, cm, ck)).astype(np.float32))
    W = jnp.asarray(rng.normal(size=(gk, gn, ck, cn)).astype(np.float32))
    env = {"X": DenseRelation(X, 2), "W": DenseRelation(W, 2)}

    variants = {
        "all-opts": RJPOptions(True, True, True),
        "no-join-agg-fusion": RJPOptions(False, True, True),
        "no-sigma-elim": RJPOptions(True, False, True),
        "no-mult-path": RJPOptions(True, True, False),
        "none": RJPOptions(False, False, False),
    }

    ref_grads = None
    for name, opts in variants.items():
        prog = ra_autodiff(q, opts=opts)
        size = sum(_plan_size(g) for g in prog.grads.values())

        def step(X, W, _prog=prog, _fuse=opts.fuse_join_agg):
            e = {"X": DenseRelation(X, 2), "W": DenseRelation(W, 2)}
            loss, grads = compiler.grad_eval(_prog, e, fuse_join_agg=_fuse)
            return grads["X"].data, grads["W"].data

        jstep = jax.jit(step)
        try:
            gx, gw = jstep(X, W)
        except Exception:
            # Without the multiplicative optimization the gradient query
            # materializes ∂⊗/∂val tuples that the dense compiler cannot
            # fuse — exactly why the paper applies opt 1. Time the plan on
            # the tuple-at-a-time interpreter (tiny grid) instead.
            us = _interpreter_time(opts)
            record(f"rjp/{name}", us,
                   f"plan_ops={size};interpreter-only(2x2 grid, scalar)")
            continue
        if ref_grads is None:
            ref_grads = (np.asarray(gx), np.asarray(gw))
        else:
            np.testing.assert_allclose(np.asarray(gx), ref_grads[0], rtol=2e-4, atol=1e-5)
            np.testing.assert_allclose(np.asarray(gw), ref_grads[1], rtol=2e-4, atol=1e-5)
        us = timeit(jstep, X, W, iters=10, warmup=2)
        record(f"rjp/{name}", us, f"plan_ops={size}")

    _pushdown_lane()


if __name__ == "__main__":
    run()
