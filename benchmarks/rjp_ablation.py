"""RJP optimization ablation (paper §4).

The paper lists three optimizations applied when constructing RJPs:
  1. ⋈_const elimination when ⊗ is multiplicative (mul/MatMul) — join the
     upstream gradient directly against the saved forward operand with the
     VJP kernel (Fig. 4), instead of materializing ∂⊗/∂val tuples.
  2. Σ elimination by join cardinality (1-1 joins need no re-aggregation).
  3. join-agg fusion — differentiate Σ∘⋈ as one operator.

This benchmark builds the same blocked-matmul-loss query, runs relational
auto-diff with each optimization toggled off, and measures (a) gradient
query *size* (operator count — plan complexity) and (b) compiled
execution time of one gradient evaluation. Correctness is asserted
against the fully-optimized plan.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compiler, fra
from repro.core.autodiff import RJPOptions, ra_autodiff
from repro.core.kernels import ADD, MATMUL, SUM_CHUNK
from repro.core.keys import (
    EMPTY_KEY, TRUE, L, R, eq_pred, identity_key, jproj, project_key,
)
from repro.core.relation import DenseRelation

from .common import record, timeit


def _matmul_loss_query() -> fra.Query:
    """loss = Σ_all sum_chunk(X ⋈ W) — blocked matmul + scalar loss."""
    join = fra.Join(
        eq_pred((1, 0)),
        jproj(L(0), L(1), R(1)),
        MATMUL,
        fra.scan("X", 2),
        fra.scan("W", 2),
    )
    mm = fra.Agg(project_key(0, 2), ADD, join)
    summed = fra.Select(TRUE, identity_key(2), SUM_CHUNK, mm)
    loss = fra.Agg(EMPTY_KEY, ADD, summed)
    return fra.Query(loss, inputs=("X", "W"))


def _plan_size(node: fra.Node) -> int:
    return len(node.topo())


def _interpreter_time(opts: RJPOptions) -> float:
    """Median time of one interpreter-path gradient evaluation on a tiny
    scalar-relation instance of the same query."""
    import time

    from repro.core.kernels import MUL

    join = fra.Join(
        eq_pred((1, 0)), jproj(L(0), L(1), R(1)), MUL,
        fra.scan("X", 2), fra.scan("W", 2),
    )
    mm = fra.Agg(project_key(0, 2), ADD, join)
    loss = fra.Agg(EMPTY_KEY, ADD, mm)
    q = fra.Query(loss, inputs=("X", "W"))
    prog = ra_autodiff(q, opts=opts)
    rng = np.random.default_rng(0)
    env = {
        "X": {(i, j): float(rng.normal()) for i in range(2) for j in range(2)},
        "W": {(i, j): float(rng.normal()) for i in range(2) for j in range(2)},
    }
    times = []
    for _ in range(5):
        t0 = time.perf_counter()
        prog.eval(env)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def run() -> None:
    q = _matmul_loss_query()
    gb, gk, gn = 8, 8, 8     # block grid
    cm, ck, cn = 32, 32, 32  # chunk dims
    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.normal(size=(gb, gk, cm, ck)).astype(np.float32))
    W = jnp.asarray(rng.normal(size=(gk, gn, ck, cn)).astype(np.float32))
    env = {"X": DenseRelation(X, 2), "W": DenseRelation(W, 2)}

    variants = {
        "all-opts": RJPOptions(True, True, True),
        "no-join-agg-fusion": RJPOptions(False, True, True),
        "no-sigma-elim": RJPOptions(True, False, True),
        "no-mult-path": RJPOptions(True, True, False),
        "none": RJPOptions(False, False, False),
    }

    ref_grads = None
    for name, opts in variants.items():
        prog = ra_autodiff(q, opts=opts)
        size = sum(_plan_size(g) for g in prog.grads.values())

        def step(X, W, _prog=prog, _fuse=opts.fuse_join_agg):
            e = {"X": DenseRelation(X, 2), "W": DenseRelation(W, 2)}
            loss, grads = compiler.grad_eval(_prog, e, fuse_join_agg=_fuse)
            return grads["X"].data, grads["W"].data

        jstep = jax.jit(step)
        try:
            gx, gw = jstep(X, W)
        except Exception:
            # Without the multiplicative optimization the gradient query
            # materializes ∂⊗/∂val tuples that the dense compiler cannot
            # fuse — exactly why the paper applies opt 1. Time the plan on
            # the tuple-at-a-time interpreter (tiny grid) instead.
            us = _interpreter_time(opts)
            record(f"rjp/{name}", us,
                   f"plan_ops={size};interpreter-only(2x2 grid, scalar)")
            continue
        if ref_grads is None:
            ref_grads = (np.asarray(gx), np.asarray(gw))
        else:
            np.testing.assert_allclose(np.asarray(gx), ref_grads[0], rtol=2e-4, atol=1e-5)
            np.testing.assert_allclose(np.asarray(gw), ref_grads[1], rtol=2e-4, atol=1e-5)
        us = timeit(jstep, X, W, iters=10, warmup=2)
        record(f"rjp/{name}", us, f"plan_ops={size}")


if __name__ == "__main__":
    run()
