"""Logistic regression (paper §2.3 running example): the RA-autodiff'ed
gradient query (interpreter-free, compiled path) vs jax.grad on the same
model — measures end-to-end overhead of the relational machinery."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import compiler, fra
from repro.core.autodiff import ra_autodiff
from repro.core.kernels import ADD, LOGISTIC, MUL, XENT
from repro.core.keys import EMPTY_KEY, TRUE, L, eq_pred, identity_key, jproj, project_key
from repro.core.relation import DenseRelation

from .common import record, timeit


def logreg_query():
    f_matmul = fra.Agg(
        project_key(0), ADD,
        fra.Join(
            eq_pred((1, 0)), jproj(L(0), L(1)), MUL,
            fra.const("Rx", 2), fra.scan("theta", 1),
        ),
    )
    f_predict = fra.Select(TRUE, identity_key(1), LOGISTIC, f_matmul)
    f_loss = fra.Agg(
        EMPTY_KEY, ADD,
        fra.Join(eq_pred((0, 0)), jproj(L(0)), XENT, f_predict, fra.const("Ry", 1)),
    )
    return fra.Query(f_loss, inputs=("theta",))


def run() -> None:
    n, m = 50_000, 256
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    X = jax.random.normal(k1, (n, m))
    y = (jax.random.uniform(k2, (n,)) > 0.5).astype(jnp.float32)
    theta = jax.random.normal(k3, (m,)) * 0.01

    prog = ra_autodiff(logreg_query())

    @jax.jit
    def ra_step(theta):
        env = {
            "Rx": DenseRelation(X, 2),
            "Ry": DenseRelation(y, 1),
            "theta": DenseRelation(theta, 1),
        }
        out, grads = compiler.grad_eval(prog, env)
        return theta - 0.1 * grads["theta"].data, out.data

    def jax_loss(theta):
        yhat = jax.nn.sigmoid(X @ theta)
        return jnp.sum(-y * jnp.log(yhat) + (y - 1.0) * jnp.log1p(-yhat))

    @jax.jit
    def jax_step(theta):
        loss, g = jax.value_and_grad(jax_loss)(theta)
        return theta - 0.1 * g, loss

    us_ra = timeit(ra_step, theta, iters=10, warmup=2)
    us_jx = timeit(jax_step, theta, iters=10, warmup=2)
    record("logreg/ra-autodiff", us_ra, f"n={n};m={m}")
    record("logreg/jax-grad", us_jx, f"overhead={us_ra/us_jx:.3f}x")
    _, l1 = ra_step(theta)
    _, l2 = jax_step(theta)
    assert abs(float(l1) - float(l2)) < 1e-3 * abs(float(l2))
