"""Knowledge-graph embedding: TransE-L2 and TransR (paper Appendix C,
Fig 3): time for 100 forward+backprop iterations, batch 1k, negatives
per positive, SGD η=0.5 — embeddings gathered/scattered through the
relational engine (rel_embed) vs hand-written jnp.take baseline."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import sgd_update
from repro.relational import rel_embed

from .common import record, timeit

N_ENT = 20_000
N_REL = 200
BATCH = 1024
NEG = 8          # paper uses 200 on a 16-node cluster; scaled to CPU
ITERS = 10       # timed iters; derived column reports the ×100 projection


def _batch(rng):
    h = rng.integers(0, N_ENT, BATCH)
    r = rng.integers(0, N_REL, BATCH)
    t = rng.integers(0, N_ENT, BATCH)
    tneg = rng.integers(0, N_ENT, (BATCH, NEG))
    return (
        jnp.asarray(h, jnp.int32),
        jnp.asarray(r, jnp.int32),
        jnp.asarray(t, jnp.int32),
        jnp.asarray(tneg, jnp.int32),
    )


def _transe_loss(embed_fn):
    def loss(params, h, r, t, tneg):
        eh = embed_fn(params["ent"], h)
        er = embed_fn(params["rel"], r)
        et = embed_fn(params["ent"], t)
        etn = embed_fn(params["ent"], tneg.reshape(-1)).reshape(BATCH, NEG, -1)
        pos = jnp.sum((eh + er - et) ** 2, axis=-1)
        neg = jnp.sum((eh + er)[:, None, :] - etn, axis=-1) ** 2
        return jnp.mean(jax.nn.relu(1.0 + pos[:, None] - neg))

    return loss


def _transr_loss(embed_fn):
    def loss(params, h, r, t, tneg):
        eh = embed_fn(params["ent"], h)
        er = embed_fn(params["rel"], r)
        et = embed_fn(params["ent"], t)
        mr = params["proj"][r]                      # (B, D, Dr)
        ph = jnp.einsum("bd,bdr->br", eh, mr)
        pt = jnp.einsum("bd,bdr->br", et, mr)
        etn = embed_fn(params["ent"], tneg.reshape(-1)).reshape(BATCH, NEG, -1)
        ptn = jnp.einsum("bnd,bdr->bnr", etn, mr)
        pos = jnp.sum((ph + er - pt) ** 2, axis=-1)
        neg = jnp.sum(((ph + er)[:, None, :] - ptn) ** 2, axis=-1)
        return jnp.mean(jax.nn.relu(1.0 + pos[:, None] - neg))

    return loss


def run() -> None:
    for dim in (50, 100):
        key = jax.random.PRNGKey(0)
        ks = jax.random.split(key, 3)
        rng = np.random.default_rng(0)
        batch = _batch(rng)

        for algo, lossmk, extra in (
            ("transe", _transe_loss, {}),
            (
                "transr",
                _transr_loss,
                {"proj": jax.random.normal(ks[2], (N_REL, dim, dim)) * 0.05},
            ),
        ):
            params = {
                "ent": jax.random.normal(ks[0], (N_ENT, dim)) * 0.05,
                "rel": jax.random.normal(ks[1], (N_REL, dim if algo == "transe" else dim)) * 0.05,
                **extra,
            }

            def make(embed_fn):
                lf = lossmk(embed_fn)

                @jax.jit
                def step(params, h, r, t, tneg):
                    loss, g = jax.value_and_grad(lf)(params, h, r, t, tneg)
                    params, _ = sgd_update(params, g, {}, lr=0.5)
                    return params, loss

                return step

            ra = make(rel_embed)
            jx = make(lambda tbl, ids: tbl[ids])
            us_ra = timeit(ra, params, *batch, iters=ITERS, warmup=2)
            us_jx = timeit(jx, params, *batch, iters=ITERS, warmup=2)
            record(f"kge/{algo}-d{dim}/ra", us_ra, f"100it={us_ra*100/1e6:.2f}s")
            record(f"kge/{algo}-d{dim}/jax", us_jx, f"100it={us_jx*100/1e6:.2f}s")
            _, l1 = ra(params, *batch)
            _, l2 = jx(params, *batch)
            assert abs(float(l1) - float(l2)) < 1e-4 * max(1.0, abs(float(l2)))
