"""COO nnz-sharding scale lane: the same GCN grad step with the edge
relation replicated vs nnz-sharded, on the host mesh.

The paper's scaling claim needs the *edge list* — the largest array in a
graph program — distributed. This lane measures exactly that on the
8-virtual-device CI mesh:

  replicated — a 1×N (model-only) host mesh: the planner has no data
               axes, the CooRelation is replicated on every device (the
               pre-COO-sharding behaviour)
  sharded    — an N×1 (data-only) host mesh: the planner places the nnz
               rows on the data axis (``data:shard_nnz_left``) and the
               Σ-by-dst runs as per-shard segment-sum + scatter collective

Per row we record the jitted step time and, in ``derived``, the measured
**per-device peak bytes of the edge relation** (max over devices of the
keys+values shard bytes actually placed by the compiled in_shardings) —
the sharded lane must show the ~N× reduction. Results are asserted to
agree to atol 1e-5 across lanes.

A third ``oocore`` lane extends the scaling axis past what fits at
all: the same step through ``Database(memory_budget=...)`` with the
budget set so E is 4× past the simulated device memory — the edge
relation spills to the host chunk store and streams back through
owner-partitioned chunk waves on the sharded mesh, matching the in-core
lanes to atol 1e-5. ``tools/check_bench.py --suites coo_scale`` gates
all three lanes against the committed baseline.

Runs meaningfully under the tier1-spmd lane's
``XLA_FLAGS=--xla_force_host_platform_device_count=8``; on a single
device both mesh lanes degenerate to the same placement and the rows
say so.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

import repro
from repro.core import fra
from repro.core.autodiff import ra_autodiff
from repro.core.engine import StreamedCompiled, engine_for
from repro.core.kernels import ADD, MUL, SQUARE, SUM_CHUNK, scale_kernel
from repro.core.keys import EMPTY_KEY, TRUE, L, eq_pred, identity_key, jproj
from repro.core.relation import DenseRelation
from repro.launch.mesh import make_host_mesh
from repro.relational.gcn import partitioned_edges

from .common import record, timeit

ATOL = 1e-5

GRAPHS = [
    ("arxiv-mini", 2_000, 160_000, 32),
    ("pubmed-mini", 500, 20_000, 16),
]


def _gcn_prog(n: int):
    conv = fra.Agg(
        identity_key(1), ADD,
        fra.Join(
            eq_pred((0, 0)), jproj(L(1)), MUL,
            fra.scan("Edge", 2), fra.scan("Node", 1),
        ),
    )
    sq = fra.Select(TRUE, identity_key(1), SQUARE, conv)
    loss = fra.Agg(
        EMPTY_KEY, ADD, fra.Select(TRUE, identity_key(1), SUM_CHUNK, sq)
    )
    mean = fra.Select(TRUE, identity_key(0), scale_kernel(1.0 / n), loss)
    return ra_autodiff(fra.Query(mean, inputs=("Edge", "Node")))


def _env(rng, n: int, e: int, d: int, num_shards: int):
    src = rng.integers(0, n, size=e)
    dst = rng.integers(0, n, size=e)
    w = rng.normal(size=e) / np.sqrt(e / n)
    edge = partitioned_edges(
        np.stack([src, dst], 1), w.astype(np.float32), n, num_shards
    )
    return {
        "Edge": edge,
        "Node": DenseRelation(
            jnp.asarray(rng.normal(size=(n, d)), jnp.float32), 1
        ),
    }


def _edge_bytes_per_device(comp, env) -> int:
    """Max over devices of the edge relation's placed shard bytes (keys +
    values), read off the compiled step's actual in_shardings."""
    sh_don, sh_kept = comp.in_shardings
    target = {**sh_kept, **sh_don}["Edge"]
    placed = jax.device_put(comp._padded(env)["Edge"], target)
    per_device: dict = {}
    for arr in (placed.keys, placed.values):
        for s in arr.addressable_shards:
            per_device[s.device.id] = per_device.get(s.device.id, 0) + int(
                np.prod(s.data.shape) * s.data.dtype.itemsize
            )
    return max(per_device.values())


def run() -> None:
    n_dev = jax.device_count()
    rng = np.random.default_rng(7)
    for name, n, e, d in GRAPHS:
        if n_dev < 2:
            record(
                f"coo_scale/{name}/replicated", 0.0,
                f"skipped=single_device;devices={n_dev}",
            )
            continue
        env = _env(rng, n, e, d, n_dev)
        prog = _gcn_prog(n)
        eng = engine_for(prog)
        low = eng.lower(env)

        lanes = {
            # model-only mesh: no data axes -> the COO is replicated
            "replicated": make_host_mesh(model=n_dev),
            # data-only mesh: nnz rows sharded n_dev ways
            "sharded": make_host_mesh(model=1),
        }
        base = None
        for lane, mesh in lanes.items():
            comp = low.compile(mesh=mesh)
            out, grads = comp(env)
            leaves = [np.asarray(out.data)] + [
                np.asarray(
                    g.values if hasattr(g, "values") else g.data
                )
                for _, g in sorted(grads.items())
            ]
            if base is None:
                base = leaves
            else:
                for got, want in zip(leaves, base):
                    np.testing.assert_allclose(got, want, atol=ATOL, rtol=1e-5)
            ebytes = _edge_bytes_per_device(comp, env)
            placement = comp.placements["Edge"]
            us = timeit(lambda: comp(env), iters=5, warmup=2)
            record(
                f"coo_scale/{name}/{lane}", us,
                f"edge_bytes_per_device={ebytes};nnz_data_dim="
                f"{placement['data']};E={e};n={n};d={d}",
            )

        # oocore lane: E extended past the simulated device budget — the
        # edge relation is 4x the headroom the budget leaves after the
        # node features, so the same step must stream chunk waves
        from repro.core.planner import _rel_bytes

        edge_bytes = _rel_bytes(env["Edge"])
        node_bytes = _rel_bytes(env["Node"])
        budget = node_bytes + edge_bytes / 4
        db = repro.Database(
            mesh=lanes["sharded"], memory_budget=budget
        )
        db.put("Edge", env["Edge"])
        db.put("Node", env["Node"].data, keys=("node",))
        q = fra.Query(prog.forward.root, inputs=("Edge", "Node"))
        h = db.query(q)
        out, grads = h.step(wrt=("Edge", "Node"))
        assert isinstance(h.last, StreamedCompiled), "budget did not stream"
        leaves = [np.asarray(out.data)] + [
            np.asarray(g.values if hasattr(g, "values") else g.data)
            for _, g in sorted(grads.items())
        ]
        for got, want in zip(leaves, base):
            np.testing.assert_allclose(got, want, atol=ATOL, rtol=1e-5)
        us = timeit(lambda: h.step(wrt=("Edge", "Node")), iters=5, warmup=2)
        record(
            f"coo_scale/{name}/oocore", us,
            f"waves={h.last.num_waves};budget={budget:.0f};"
            f"edge_bytes={edge_bytes};E={e};n={n};d={d}",
        )


if __name__ == "__main__":
    from .common import ROWS, emit_header, emit_json

    emit_header()
    run()
    emit_json("BENCH_coo_scale.json", ROWS)
