"""serving_load: open-loop synthetic load on the async serving front
door (``db.endpoint`` — serving/service.py).

Concurrent single-row requests arrive at a fixed interval (open loop:
arrivals do not wait for completions) against a REDUCED dense model
served through a warmed endpoint. The run asserts the two serving
invariants the PR is gated on — cross-request batching actually happens
(coalesced batches < requests) and decode compiles at most once per
bucket — then records sustained QPS and the p50/p99 request latency.

Gated rows (check_bench, 2x):

  serving_load/open-loop/p50             p50 request latency (us)
  serving_load/open-loop/p99             p99 request latency (us)
  serving_load/open-loop/us_per_request  wall time per request (1/QPS)

The arrival rate is set well below saturation so the percentiles track
the (compiled) batch service time, not a queueing blow-up — that keeps
the 2x gate meaningful on shared CI hosts.
"""

import asyncio
import time

import jax
import numpy as np

import repro
from repro.configs import get_config
from repro.models import build_model

from .common import record

N_REQUESTS = 64
SEQ = 16
MAX_NEW = 8
# ~20 req/s offered vs ~40 req/s measured CPU capacity (~50%
# utilization): arrivals coalesce with in-flight decode groups but the
# queue never builds, so p50/p99 track compiled batch service time
INTERVAL_S = 0.050


def _percentile(sorted_us, q):
    return sorted_us[min(len(sorted_us) - 1, int(len(sorted_us) * q))]


def run() -> None:
    cfg = get_config("gemma3-4b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    db = repro.Database(max_cache_entries=32)
    db.register_model("lm", model, params)
    ep = db.endpoint(
        "lm",
        cache_len=SEQ + MAX_NEW + 4,
        buckets=[(1, SEQ), (2, SEQ), (4, SEQ), (8, SEQ)],
        max_queue=2 * N_REQUESTS,
    )
    ep.warmup()  # the measured path never compiles

    rng = np.random.default_rng(0)
    prompts = [
        rng.integers(0, cfg.vocab, size=SEQ) for _ in range(N_REQUESTS)
    ]

    async def load():
        async def client(i):
            await asyncio.sleep(i * INTERVAL_S)
            out = await ep.submit(prompts[i], max_new_tokens=MAX_NEW)
            return out.latency

        t0 = time.perf_counter()
        lats = await asyncio.gather(
            *[client(i) for i in range(N_REQUESTS)]
        )
        return list(lats), time.perf_counter() - t0

    asyncio.run(load())  # warm pass: stabilize allocator + dispatch
    lat, wall = asyncio.run(load())

    c = db.counters()["serve"]
    assert c["completed"] == 2 * N_REQUESTS and c["failed"] == 0
    # the acceptance invariants: coalescing happened, decode stayed
    # bucketed (compiled once per bucket, flat across both passes)
    assert c["batches"] < c["requests"], (
        f"no cross-request batching: {c['batches']} batches for "
        f"{c['requests']} requests"
    )
    assert c["decode"]["compiles"] <= len(ep.decode_buckets), (
        f"decode compiled {c['decode']['compiles']}x for "
        f"{len(ep.decode_buckets)} buckets"
    )

    lat_us = sorted(s * 1e6 for s in lat)
    record(
        "serving_load/open-loop/p50",
        _percentile(lat_us, 0.50),
        f"n={N_REQUESTS} seq={SEQ} max_new={MAX_NEW}",
    )
    record("serving_load/open-loop/p99", _percentile(lat_us, 0.99))
    record(
        "serving_load/open-loop/us_per_request",
        wall / N_REQUESTS * 1e6,
        f"qps={N_REQUESTS / wall:.1f} batches={c['batches']}",
    )
