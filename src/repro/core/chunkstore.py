"""Host-resident chunked backing store for out-of-core execution.

A ``ChunkStore`` holds relations that do not fit the session's device
memory budget as **host numpy chunks** under a ``relation.ChunkManifest``
(the "different tier" generalization of plan-aware rechunking: spilling
to host is the same split/assemble all-to-all as re-blocking to another
grid, with a transfer instead of a shuffle as its cost). The streaming
executor (``core/engine.StreamedCompiled``) fetches one chunk *wave* at a
time; ``fetch`` returns device arrays via ``jax.device_put``, which
dispatches the host→device copy asynchronously — issuing the fetch of
wave ``w+1`` before consuming wave ``w`` is what double-buffers the
transfer behind compute.

Counters (the session's spill counters, exposed as
``Database.counters()["spill"]``):

    spilled_relations — relations currently backed by the store
    spilled_bytes     — host bytes across all stored chunks
    fetched_chunks    — chunk fetches issued (host→device transfers)
    fetched_bytes     — bytes moved host→device by those fetches
"""

from __future__ import annotations

from typing import Dict, List, Optional

import jax
import numpy as np

from .relation import (
    ChunkManifest,
    CooRelation,
    DenseRelation,
    make_manifest,
    split_chunks,
)


class OutOfCoreError(RuntimeError):
    """A memory-budgeted plan cannot be executed: the budget is too small
    for the resident relations, or the query's shape cannot stream (the
    reason names the offending node/relation)."""


def _host_bytes(rel) -> int:
    if isinstance(rel, DenseRelation):
        return int(np.asarray(rel.data).nbytes)
    return int(np.asarray(rel.keys).nbytes + np.asarray(rel.values).nbytes)


class ChunkStore:
    """Named host-resident chunked relations + spill/fetch counters."""

    def __init__(self) -> None:
        self._chunks: Dict[str, List] = {}
        self._manifests: Dict[str, ChunkManifest] = {}
        self.stats: Dict[str, int] = {
            "spilled_relations": 0,
            "spilled_bytes": 0,
            "fetched_chunks": 0,
            "fetched_bytes": 0,
        }

    def __contains__(self, name: str) -> bool:
        return name in self._chunks

    def manifest(self, name: str) -> ChunkManifest:
        return self._manifests[name]

    def spill(self, name: str, rel, chunking, axis: int = 0) -> ChunkManifest:
        """Split ``rel`` into host chunks. ``chunking`` is either a chunk
        count (a fresh even manifest is built) or a ``ChunkManifest`` to
        reuse — co-streamed relations share the stream's cut boundaries on
        their own axis. Re-spilling a name under the same manifest is a
        no-op; a different manifest replaces its chunks (the catalog's
        ``put`` semantics)."""
        if isinstance(chunking, ChunkManifest):
            manifest = chunking
        else:
            manifest = make_manifest(rel, int(chunking), axis=axis)
        if name in self._chunks and self._manifests[name] == manifest:
            return manifest
        chunks = split_chunks(rel, manifest)
        if name in self._chunks:
            self.drop(name)
        self._chunks[name] = chunks
        self._manifests[name] = manifest
        self.stats["spilled_relations"] += 1
        self.stats["spilled_bytes"] += sum(_host_bytes(c) for c in chunks)
        return manifest

    def fetch(self, name: str, w: int):
        """Device-resident copy of chunk ``w`` (async host→device copy —
        call ahead of use to overlap the transfer with compute)."""
        chunk = self._chunks[name][w]
        self.stats["fetched_chunks"] += 1
        self.stats["fetched_bytes"] += _host_bytes(chunk)
        if isinstance(chunk, DenseRelation):
            return DenseRelation(jax.device_put(chunk.data), chunk.key_arity)
        return CooRelation(
            jax.device_put(chunk.keys),
            jax.device_put(chunk.values),
            chunk.extents,
            chunk.owner_dim,
            chunk.shard_offsets,
        )

    def host_chunk(self, name: str, w: int):
        """The raw host chunk (no transfer, no counter)."""
        return self._chunks[name][w]

    def drop(self, name: str) -> None:
        chunks = self._chunks.pop(name, None)
        self._manifests.pop(name, None)
        if chunks is not None:
            self.stats["spilled_relations"] -= 1
            self.stats["spilled_bytes"] -= sum(_host_bytes(c) for c in chunks)
