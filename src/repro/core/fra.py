"""Functional relational algebra IR (paper §2).

A *query* is a DAG of operator nodes. Leaves are ``TableScan`` (variable
inputs — relations we may differentiate with respect to) and ``Const``
(constant relations — the paper's ⋈_const inputs, training data, cached
forward intermediates). Interior nodes are Selection σ, Aggregation Σ,
Join ⋈ (with the const variant folded in via Const leaves), and the
``add`` operation of §5 used for total derivatives.

Every node carries its output key arity; kernel functions are registry
entries (see kernels.py); key functions are symbolic (see keys.py). Both
the sparse interpreter (interpreter.py — the semantics oracle) and the
chunked compiler (compiler.py — the fast jit path) execute this IR, and the
relational auto-diff (autodiff.py) transforms it.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple, Union

from .kernels import AggKernel, BinKernel, UnaryKernel
from .keys import JoinPred, JoinProj, KeyFn, SelPred

_ids = itertools.count()


class Node:
    """Base class. Subclasses set ``children`` and ``key_arity``."""

    children: Tuple["Node", ...]
    key_arity: int

    def __post_init__(self):  # dataclasses call this
        self.id = next(_ids)

    # -- graph utilities ----------------------------------------------------
    def topo(self) -> List["Node"]:
        """Topological order, leaves first, root last."""
        seen: Dict[int, Node] = {}
        order: List[Node] = []

        def visit(n: Node) -> None:
            if n.id in seen:
                return
            seen[n.id] = n
            for c in n.children:
                visit(c)
            order.append(n)

        visit(self)
        return order

    def table_scans(self) -> List["TableScan"]:
        return [n for n in self.topo() if isinstance(n, TableScan)]

    def pretty(self, indent: int = 0) -> str:
        pad = "  " * indent
        head = f"{pad}{self.describe()}"
        return "\n".join([head] + [c.pretty(indent + 1) for c in self.children])

    def describe(self) -> str:
        return type(self).__name__


@dataclass(eq=False)
class TableScan(Node):
    """τ(K): a named variable input relation."""

    name: str
    key_arity: int

    def __post_init__(self):
        super().__post_init__()
        self.children = ()

    def describe(self) -> str:
        return f"τ({self.name}, arity={self.key_arity})"


@dataclass(eq=False)
class Const(Node):
    """A constant relation embedded in the query (⋈_const operands, data,
    cached forward intermediates in gradient queries). ``ref`` names the
    relation in the environment at execution time."""

    ref: str
    key_arity: int

    def __post_init__(self):
        super().__post_init__()
        self.children = ()

    def describe(self) -> str:
        return f"const({self.ref}, arity={self.key_arity})"


@dataclass(eq=False)
class Select(Node):
    """σ(pred, proj, ⊙, child)."""

    pred: SelPred
    proj: KeyFn
    kernel: UnaryKernel
    child: Node

    def __post_init__(self):
        super().__post_init__()
        self.children = (self.child,)
        self.key_arity = self.proj.arity_out

    def describe(self) -> str:
        return f"σ(pred={self.pred!r}, proj={self.proj!r}, {self.kernel!r})"


@dataclass(eq=False)
class Agg(Node):
    """Σ(grp, ⊕, child)."""

    grp: KeyFn
    kernel: AggKernel
    child: Node

    def __post_init__(self):
        super().__post_init__()
        self.children = (self.child,)
        self.key_arity = self.grp.arity_out

    def describe(self) -> str:
        return f"Σ(grp={self.grp!r}, {self.kernel!r})"


@dataclass(eq=False)
class Join(Node):
    """⋈(pred, proj, ⊗, left, right).

    ⋈_const is represented as a Join whose left/right child is a Const leaf.
    A Join may produce duplicate output keys when ``proj`` is non-injective
    over matches; such a Join is only well-formed under an Agg parent which
    merges duplicates (the paper's join-agg trees). The executors enforce
    this.
    """

    pred: JoinPred
    proj: JoinProj
    kernel: BinKernel
    left: Node
    right: Node

    def __post_init__(self):
        super().__post_init__()
        self.children = (self.left, self.right)
        self.key_arity = self.proj.arity_out

    def describe(self) -> str:
        return f"⋈(pred={self.pred!r}, proj={self.proj!r}, {self.kernel!r})"


@dataclass(eq=False)
class AddOp(Node):
    """add(l, r): pointwise sum of two relations on the same key set (§5)."""

    left: Node
    right: Node

    def __post_init__(self):
        super().__post_init__()
        assert self.left.key_arity == self.right.key_arity, (
            self.left.key_arity,
            self.right.key_arity,
        )
        self.children = (self.left, self.right)
        self.key_arity = self.left.key_arity

    def describe(self) -> str:
        return "add"


@dataclass(eq=False)
class Restrict(Node):
    """Restrict ``child`` to the key set of relation ``ref``.

    The paper defines partial derivatives only for keys *in* the input
    relation's key set (§3.1); gradient queries therefore restrict each
    RJP-join output to the differentiated relation's keys. For dense
    (full-grid) relations this is the identity; for sparse (COO) relations
    it keeps the gradient sparse and lets the compiler fuse the enclosing
    RJP join into a per-tuple gather instead of a dense cross product.
    """

    child: Node
    ref: Node

    def __post_init__(self):
        super().__post_init__()
        assert self.child.key_arity == self.ref.key_arity, (
            self.child.key_arity,
            self.ref.key_arity,
        )
        self.children = (self.child, self.ref)
        self.key_arity = self.child.key_arity

    def describe(self) -> str:
        return "restrict"


@dataclass(eq=False)
class Query:
    """A compiled-ready query: root node + ordered variable-input names."""

    root: Node
    inputs: Tuple[str, ...]

    def __post_init__(self):
        scans = {s.name for s in self.root.table_scans()}
        missing = scans - set(self.inputs)
        if missing:
            raise ValueError(f"table scans not declared as inputs: {missing}")

    def pretty(self) -> str:
        return self.root.pretty()


def scan(name: str, key_arity: int) -> TableScan:
    return TableScan(name, key_arity)


def const(ref: str, key_arity: int) -> Const:
    return Const(ref, key_arity)
