"""Relational reverse-mode auto-differentiation (paper §3–5).

Algorithms 1 (ChainRule) and 2 (RAAutoDiff), implemented as a *symbolic*
transformation: given a forward ``Query``, we construct for every
differentiable input relation a new FRA query graph that evaluates
∂Q/∂R_input. The gradient graphs reference

  * ``__seed``            — the output cotangent relation (for a one-tuple
                            loss, ``{(): 1.0}``; Algorithm 2 line 7), and
  * ``__fwd_<node_id>``   — forward intermediate relations cached during the
                            forward execution (Algorithm 2 line 6),

as Const leaves resolved from the environment at execution time. Because the
gradient is itself an FRA query, it can be executed by the sparse
interpreter, compiled by the chunked compiler, optimized, sharded, and even
differentiated again.

The §4 RJP optimizations are applied during construction:

  1. ⋈_const elimination for multiplicative ⊗ (mul/MatMul): the RJP joins
     the upstream gradient *directly* against the saved forward operand with
     the VJP kernel (paper Fig. 4) instead of materializing ∂⊗/∂val.
  2. Σ elimination by join cardinality: the trailing Σ of an RJP join is
     emitted only when the (output, other-operand) pair under-determines the
     differentiated operand's key (see ``_needs_agg``).
  3. Join-agg fusion: Σ(grp, +, ⋈(...)) is differentiated as a single fused
     operator by composing grp into the join projection — the Σ is never
     differentiated separately.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple


@dataclass(frozen=True)
class RJPOptions:
    """§4 optimization toggles (benchmarks/rjp_ablation.py measures each)."""

    fuse_join_agg: bool = True       # differentiate Σ∘⋈ as one operator
    eliminate_sigma: bool = True     # drop the RJP's trailing Σ when 1-1
    multiplicative: bool = True      # ⋈_const-eliminated VJP-kernel path


DEFAULT_OPTS = RJPOptions()
NO_OPTS = RJPOptions(False, False, False)

from . import fra, interpreter
from .kernels import (
    ADD,
    BinKernel,
    MUL,
    UnaryKernel,
    register_bin,
)
from .keys import (
    In,
    JoinPred,
    JoinProj,
    KeyFn,
    L,
    Lit,
    R,
    SelPred,
    identity_key,
    join_equiv_classes,
    solve_left_key,
)

SEED = "__seed"


def fwd_ref(node: fra.Node) -> fra.Const:
    """Const leaf referring to ``node``'s cached forward value."""
    return fra.const(f"__fwd_{node.id}", node.key_arity)


# ---------------------------------------------------------------------------
# Derived kernels (memoized so graph nodes share registry entries)
# ---------------------------------------------------------------------------

_DERIVED: Dict[str, BinKernel] = {}


def _vjp_unary(k: UnaryKernel) -> BinKernel:
    """⊗'(g, x) = vjp of unary kernel ⊙ — the RJP-for-σ kernel."""
    name = f"vjp1[{k.name}]"
    if name not in _DERIVED:
        _DERIVED[name] = register_bin(
            name,
            lambda g, x, _k=k: _k.vjp(g, x),
            vjp_l=None,
            vjp_r=None,
        )
    return _DERIVED[name]


def _take_left() -> BinKernel:
    """⊗(g, x) = g — broadcast join used by RJP-for-Σ with ⊕ = add."""
    name = "take_l"
    if name not in _DERIVED:
        _DERIVED[name] = register_bin(
            name,
            lambda g, x: g,
            vjp_l=lambda gg, g, x: gg,
            vjp_r=lambda gg, g, x: (g - g) if hasattr(g, "shape") else 0.0,
        )
    return _DERIVED[name]


def _vjp_bin(k: BinKernel, side: str) -> BinKernel:
    """Optimized RJP kernel for multiplicative ⊗: joins (g, other) directly.

    side='l': fn(g, r) = vjp_l(g, ·, r);  side='r': fn(g, l) = vjp_r(g, l, ·).
    Only valid for multiplicative kernels, whose vjp w.r.t. one operand does
    not reference that operand (paper §4, first optimization).
    """
    assert k.multiplicative, k
    name = f"vjp2{side}[{k.name}]"
    if name not in _DERIVED:
        # Derived einsum lowering hints: the VJP contracts the cotangent
        # (chunk letters = output letters of ⊗) against the other operand.
        spec = None
        if k.chunk_spec is not None:
            lc, rc, oc = k.chunk_spec
            spec = (oc, rc, lc) if side == "l" else (oc, lc, rc)
        if side == "l":
            fn = lambda g, r, _k=k: _k.vjp_l(g, None, r)
        else:
            fn = lambda g, l, _k=k: _k.vjp_r(g, l, None)
        _DERIVED[name] = register_bin(
            name, fn, elementwise=k.elementwise, chunk_spec=spec
        )
    return _DERIVED[name]


def _partial_bin(k: BinKernel, side: str) -> BinKernel:
    """General-path partial-derivative kernel ∂⊗/∂side as a value (paper's
    ⊗₂). Valid for elementwise scalar/chunk kernels where
    vjp_side(g,l,r) = g * ∂⊗/∂side(l,r).

    The RJP's inner join always places the *differentiated* operand on its
    left, so for side='r' the incoming (wrt, other) pair must be swapped
    back into the original kernel's (l, r) order."""
    name = f"partial{side}[{k.name}]"
    if name not in _DERIVED:
        if side == "l":
            fn = lambda wrt, other, _k=k: _k.vjp_l(1.0, wrt, other)
        else:
            fn = lambda wrt, other, _k=k: _k.vjp_r(1.0, other, wrt)
        # No einsum hints: ``elementwise`` promises product semantics to the
        # compiler's einsum path, which a general ∂⊗/∂side does not have —
        # the inner join lowers through the aligned/broadcast dense paths.
        _DERIVED[name] = register_bin(name, fn)
    return _DERIVED[name]


# ---------------------------------------------------------------------------
# RJP constructors (paper §4), one per operator
# ---------------------------------------------------------------------------


def _rjp_select(g: fra.Node, node: fra.Select) -> fra.Node:
    """RJP_σ: ⋈(pred', proj', ⊗', τ(K_o), τ(K_i)) — paper §4.

    pred'(keyO, keyIn) = (keyO == proj(keyIn)) ∧ pred(keyIn)
    proj'            -> keyIn
    ⊗'(g, x)         = ⊙.vjp(g, x)
    """
    child = node.child
    eqs: List[Tuple] = []
    for o, c in enumerate(node.proj.comps):
        rc = R(c.idx) if isinstance(c, In) else Lit(c.val)
        eqs.append((L(o), rc))
    if node.pred.custom is not None:
        raise NotImplementedError("cannot differentiate custom selection predicates")
    for i, v in node.pred.eqs:
        eqs.append((R(i), Lit(v)))
    pred = JoinPred(tuple(eqs))
    proj = JoinProj(tuple(R(i) for i in range(child.key_arity)))
    return fra.Join(pred, proj, _vjp_unary(node.kernel), g, fwd_ref(child))


def _rjp_agg(g: fra.Node, node: fra.Agg) -> fra.Node:
    """RJP_Σ: ⋈(pred, proj, ⊗, τ(K_o), τ(K_i)) with
    pred(keyO, keyIn) = keyO == grp(keyIn), proj -> keyIn,
    ⊗(g, x) = ∂⊕/∂x · g (= g for ⊕ = add: broadcast join)."""
    if not node.kernel.is_add:
        raise NotImplementedError(
            f"RJP for non-additive ⊕ {node.kernel.name} not supported"
        )
    child = node.child
    eqs = []
    for o, c in enumerate(node.grp.comps):
        rc = R(c.idx) if isinstance(c, In) else Lit(c.val)
        eqs.append((L(o), rc))
    pred = JoinPred(tuple(eqs))
    proj = JoinProj(tuple(R(i) for i in range(child.key_arity)))
    return fra.Join(pred, proj, _take_left(), g, fwd_ref(child))


def _mirror(pred: JoinPred, proj: JoinProj) -> Tuple[JoinPred, JoinProj]:
    """Swap L and R roles so the right-operand RJP reuses the left solver."""
    def sw(c):
        if isinstance(c, L):
            return R(c.idx)
        if isinstance(c, R):
            return L(c.idx)
        return c

    return (
        JoinPred(tuple((sw(a), sw(b)) for a, b in pred.eqs)),
        JoinProj(tuple(sw(c) for c in proj.comps)),
    )


def _needs_agg(
    pred: JoinPred, proj: JoinProj, wrt_arity: int, other_arity: int
) -> bool:
    """Σ-elimination analysis (paper §4, second optimization).

    The RJP join pairs (output key O, other key R). Duplicate
    differentiated-operand keys — requiring a trailing Σ — arise iff some
    join equivalence class visible in (O, R) is *not* pinned by the
    reconstructed key. This is exactly the n side of a 1-n join.
    """
    solved = solve_left_key(pred, proj, wrt_arity, other_arity)
    if solved is None:
        return True
    exprs, _ = solved
    uf = join_equiv_classes(pred, wrt_arity, other_arity)
    pinned = set()
    for i in range(wrt_arity):
        pinned.add(uf.find(L(i)))
    visible = set()
    for j in range(other_arity):
        visible.add(uf.find(R(j)))
    for c in proj.comps:
        if not isinstance(c, Lit):
            visible.add(uf.find(c))
    return not visible <= pinned


def _rjp_join_one_side(
    g: fra.Node,
    pred: JoinPred,
    proj: JoinProj,
    kernel: BinKernel,
    wrt_child: fra.Node,
    other_child: fra.Node,
    side: str,
    opts: RJPOptions = DEFAULT_OPTS,
) -> fra.Node:
    """RJP_⋈ for one operand, with all three §4 optimizations.

    ``pred``/``proj`` must already be oriented so the differentiated operand
    is on the *left* (use _mirror for the right operand). ``side`` tags which
    VJP kernel to use ('l' or 'r' of the *original* kernel).
    """
    wa, oa = wrt_child.key_arity, other_child.key_arity
    solved = solve_left_key(pred, proj, wa, oa)

    if kernel.multiplicative and solved is not None and opts.multiplicative:
        exprs, consistency = solved
        out = fra.Join(
            consistency,
            JoinProj(tuple(exprs)),
            _vjp_bin(kernel, side),
            g,
            fwd_ref(other_child),
        )
        if _needs_agg(pred, proj, wa, oa) or not opts.eliminate_sigma:
            out = fra.Agg(identity_key(wa), ADD, out)
        # §3.1: the gradient is defined on the differentiated relation's key
        # set — restrict (identity for full-grid relations, keeps sparse
        # relations' gradients sparse).
        return fra.Restrict(out, fwd_ref(wrt_child))

    # General path (paper's unoptimized RJP_⋈): re-derive the forward join
    # matches with the partial-derivative kernel, keyed ⟨keyL, keyO'⟩ where
    # keyO' keeps only the output comps whose equivalence class is not
    # already carried by keyL (a duplicated class would be an einsum output
    # subscript repeated — unlowerable — and is redundant: the outer join
    # reads the class off its keyL position instead). Then join the
    # upstream gradient against ⟨keyL, keyO'⟩ on keyO and contract with ×,
    # then Σ over the surviving other-side classes.
    uf = join_equiv_classes(pred, wa, oa)
    pos_of: Dict[object, int] = {}
    for i in range(wa):
        pos_of.setdefault(uf.find(L(i)), i)
    extra: List = []
    outer_eqs: List[Tuple] = []
    for o, c in enumerate(proj.comps):
        if isinstance(c, Lit):
            # constant output comp: the upstream gradient contributes only
            # where its key carries that constant
            outer_eqs.append((L(o), Lit(c.val)))
            continue
        root = uf.find(c)
        if root not in pos_of:
            pos_of[root] = wa + len(extra)
            extra.append(c)
        outer_eqs.append((L(o), R(pos_of[root])))
    inner_proj = JoinProj(tuple(L(i) for i in range(wa)) + tuple(extra))
    inner = fra.Join(
        pred, inner_proj, _partial_bin(kernel, side),
        fwd_ref(wrt_child), fwd_ref(other_child),
    )
    # When the forward Σ drops a join key, some equivalence class of the
    # inner join is determined by neither ⟨keyL⟩ nor ⟨keyO'⟩, so the inner
    # join emits duplicate ⟨keyL, keyO'⟩ rows — a multiset no executor
    # accepts as a relation. All duplicates of one ⟨keyL, keyO'⟩ meet the
    # same g[keyO] in the outer join, so merging them with the Σ's ⊕ first
    # is exact (distributivity of × over +) and makes the derivation both
    # interpretable (Agg merges the pair list) and lowerable (the fused
    # Agg-over-Join contracts the dropped class).
    determined = set(pos_of)
    for a, b in pred.eqs:
        for c in (a, b):
            if isinstance(c, Lit):
                determined.add(uf.find(c))
    if any(uf.find(R(j)) not in determined for j in range(oa)):
        inner = fra.Agg(identity_key(wa + len(extra)), ADD, inner)
    outer_proj = JoinProj(tuple(R(i) for i in range(wa)))
    outer = fra.Join(JoinPred(tuple(outer_eqs)), outer_proj, MUL, g, inner)
    out = fra.Agg(identity_key(wa), ADD, outer)
    return fra.Restrict(out, fwd_ref(wrt_child))


def _rjp_join(
    g: fra.Node, node: fra.Join, opts: RJPOptions = DEFAULT_OPTS
) -> List[Tuple[int, fra.Node]]:
    """Gradient contributions of a Join to each non-Const child. Returned as
    (child_id, contribution) pairs — a self-join (same node on both sides)
    yields two contributions to the same child, summed by the caller (the
    total-derivative ``add`` of §5)."""
    out: List[Tuple[int, fra.Node]] = []
    if not isinstance(node.left, fra.Const):
        out.append(
            (
                node.left.id,
                _rjp_join_one_side(
                    g, node.pred, node.proj, node.kernel,
                    node.left, node.right, "l", opts,
                ),
            )
        )
    if not isinstance(node.right, fra.Const):
        mp, mj = _mirror(node.pred, node.proj)
        out.append(
            (
                node.right.id,
                _rjp_join_one_side(
                    g, mp, mj, node.kernel, node.right, node.left, "r", opts
                ),
            )
        )
    return out


def _compose_grp_into_proj(grp: KeyFn, proj: JoinProj) -> JoinProj:
    """proj_eff = grp ∘ proj — join-agg fusion (§4 third optimization)."""
    comps = []
    for c in grp.comps:
        if isinstance(c, Lit):
            comps.append(c)
        else:
            comps.append(proj.comps[c.idx])
    return JoinProj(tuple(comps))


# ---------------------------------------------------------------------------
# Algorithm 2: RAAutoDiff
# ---------------------------------------------------------------------------


@dataclass
class GradientProgram:
    """The output of relational auto-diff.

    ``grads[name]`` is the root of an FRA graph computing ∂Q/∂R_name. Its
    environment must contain: the original inputs, ``__seed`` (output
    cotangent), and the ``__fwd_*`` cached intermediates produced by
    ``forward_with_cache``.

    ``opts`` records the RJPOptions the program was derived under, so a
    structural rewrite of the forward query (core/rewrite.py) can
    re-derive the gradient graphs under identical settings.
    """

    forward: fra.Query
    grads: Dict[str, fra.Node]
    wrt: Tuple[str, ...]
    opts: RJPOptions = DEFAULT_OPTS

    def grad_query(self, name: str) -> fra.Query:
        scans = tuple(
            sorted({s.name for s in self.grads[name].table_scans()})
        )
        return fra.Query(self.grads[name], scans)

    # -- execution via the sparse interpreter (oracle path) ----------------
    def forward_with_cache(self, env: interpreter.Env):
        cache: Dict[int, object] = {}
        out = interpreter.run_query(self.forward, env, cache)
        fwd_env = {f"__fwd_{nid}": rel for nid, rel in cache.items()}
        return out, fwd_env

    def eval(
        self,
        env: interpreter.Env,
        seed: Optional[interpreter.SparseRelation] = None,
    ):
        out, fwd_env = self.forward_with_cache(env)
        if seed is None:
            if len(out) != 1:
                raise ValueError(
                    "default seed requires a one-tuple loss output; pass a "
                    "cotangent relation explicitly"
                )
            seed = {k: 1.0 for k in out}
        genv = dict(env)
        genv.update(fwd_env)
        genv[SEED] = seed
        gout = {
            name: interpreter.evaluate(root, genv)
            for name, root in self.grads.items()
        }
        return out, gout


def ra_autodiff(
    query: fra.Query,
    wrt: Optional[Tuple[str, ...]] = None,
    opts: RJPOptions = DEFAULT_OPTS,
) -> GradientProgram:
    """Algorithm 2 (RAAutoDiff), symbolically.

    Walks the operator DAG in reverse topological order, applies ChainRule
    (Algorithm 1) via the RJP constructors, and accumulates fan-out
    contributions with ``add`` (the total derivative, §5).
    """
    if wrt is None:
        wrt = query.inputs
    order = query.root.topo()
    # Accumulated gradient graph per node id.
    acc: Dict[int, fra.Node] = {query.root.id: fra.const(SEED, query.root.key_arity)}

    # Count consumers to know when a node's gradient is complete. For our
    # DAGs (each node knows its children), process in reverse topo order —
    # every parent appears after its children in `order`, so by the time we
    # reach a node all its parents' contributions have been accumulated.
    fused_joins: set = set()

    for node in reversed(order):
        g = acc.get(node.id)
        if g is None or isinstance(node, (fra.TableScan, fra.Const)):
            continue
        if node.id in fused_joins:
            continue

        def accumulate(child_id: int, contrib: fra.Node) -> None:
            if child_id in acc:
                acc[child_id] = fra.AddOp(acc[child_id], contrib)
            else:
                acc[child_id] = contrib

        if isinstance(node, fra.AddOp):
            # d add / d child = identity on both sides (twice if self-add).
            accumulate(node.left.id, g)
            accumulate(node.right.id, g)
        elif isinstance(node, fra.Select):
            accumulate(node.child.id, _rjp_select(g, node))
        elif isinstance(node, fra.Agg):
            child = node.child
            if (
                isinstance(child, fra.Join)
                and node.kernel.is_add
                and opts.fuse_join_agg
                and _single_parent(child, order)
            ):
                # Join-agg fusion: differentiate Σ∘⋈ as one operator.
                proj_eff = _compose_grp_into_proj(node.grp, child.proj)
                fused = fra.Join(
                    child.pred, proj_eff, child.kernel, child.left, child.right
                )
                fused.id = child.id  # same forward intermediates
                for cid, contrib in _rjp_join(g, fused, opts):
                    accumulate(cid, contrib)
                fused_joins.add(child.id)
            else:
                accumulate(child.id, _rjp_agg(g, node))
        elif isinstance(node, fra.Join):
            for cid, contrib in _rjp_join(g, node, opts):
                accumulate(cid, contrib)
        else:
            raise TypeError(f"cannot differentiate node {node}")

    grads: Dict[str, fra.Node] = {}
    for s in query.root.table_scans():
        if s.name in wrt:
            if s.id not in acc:
                raise ValueError(f"input {s.name} does not reach the output")
            if s.name in grads:
                # Distinct τ nodes naming the same input relation: the
                # total derivative (§5) sums their contributions.
                grads[s.name] = fra.AddOp(grads[s.name], acc[s.id])
            else:
                grads[s.name] = acc[s.id]
    missing = set(wrt) - set(grads)
    if missing:
        raise ValueError(f"wrt inputs not found in query: {missing}")
    return GradientProgram(query, grads, tuple(wrt), opts)


def _single_parent(node: fra.Node, order: List[fra.Node]) -> bool:
    n = 0
    for p in order:
        for c in p.children:
            if c.id == node.id:
                n += 1
    return n == 1
