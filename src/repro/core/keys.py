"""Symbolic key-function language for the functional relational algebra.

The paper's RA operations are parameterized by key functions:

  grp  : K_i -> K_o                  (Aggregation)
  pred : K_l x K_r -> bool           (Join)
  proj : K_l x K_r -> K_o            (Join)
  pred : K_i -> bool                 (Selection)
  proj : K_i -> K_o                  (Selection)

Keys are tuples of integers. We represent key functions *symbolically* so
that (1) the RJP construction (autodiff) can derive the paper's transformed
key functions (e.g. ``pred'(keyL, keyR) = keyL == proj(keyR)``) in closed
form, and (2) the chunked compiler can pattern-match joins/aggregations into
einsum / gather / segment-sum lowerings.

Component references:
  In(i)   -- i-th component of the (single) input key
  L(i)    -- i-th component of the left join key
  R(i)    -- i-th component of the right join key
  Lit(v)  -- integer literal
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional, Sequence, Tuple, Union


@dataclass(frozen=True)
class In:
    idx: int

    def __repr__(self) -> str:
        return f"k[{self.idx}]"


@dataclass(frozen=True)
class L:
    idx: int

    def __repr__(self) -> str:
        return f"l[{self.idx}]"


@dataclass(frozen=True)
class R:
    idx: int

    def __repr__(self) -> str:
        return f"r[{self.idx}]"


@dataclass(frozen=True)
class Lit:
    val: int

    def __repr__(self) -> str:
        return str(self.val)


Comp = Union[In, Lit]
JoinComp = Union[L, R, Lit]


def _eval_comp(c, key) -> int:
    if isinstance(c, In):
        return key[c.idx]
    if isinstance(c, Lit):
        return c.val
    raise TypeError(f"not a unary component: {c}")


def _eval_join_comp(c, kl, kr) -> int:
    if isinstance(c, L):
        return kl[c.idx]
    if isinstance(c, R):
        return kr[c.idx]
    if isinstance(c, Lit):
        return c.val
    raise TypeError(f"not a join component: {c}")


# ---------------------------------------------------------------------------
# Unary key map:  K_i -> K_o   (used by grp and selection proj)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class KeyFn:
    """Key map returning a tuple of components drawn from the input key."""

    comps: Tuple[Comp, ...]

    def __call__(self, key: Tuple[int, ...]) -> Tuple[int, ...]:
        return tuple(_eval_comp(c, key) for c in self.comps)

    @property
    def arity_out(self) -> int:
        return len(self.comps)

    def is_identity(self, arity_in: int) -> bool:
        return self.comps == tuple(In(i) for i in range(arity_in))

    def is_permutation(self, arity_in: int) -> bool:
        idxs = [c.idx for c in self.comps if isinstance(c, In)]
        return (
            len(idxs) == len(self.comps) == arity_in
            and sorted(idxs) == list(range(arity_in))
        )

    def __repr__(self) -> str:
        return "key->(" + ",".join(map(repr, self.comps)) + ")"


def identity_key(arity: int) -> KeyFn:
    return KeyFn(tuple(In(i) for i in range(arity)))


def project_key(*idxs: int) -> KeyFn:
    return KeyFn(tuple(In(i) for i in idxs))


def const_key(*vals: int) -> KeyFn:
    """Constant grouping function (aggregate everything to one tuple)."""
    return KeyFn(tuple(Lit(v) for v in vals))


EMPTY_KEY = KeyFn(())  # grp(key) -> <>


# ---------------------------------------------------------------------------
# Unary predicate:  K_i -> bool   (selection)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class SelPred:
    """Conjunction of equality constraints ``key[idx] == val``.

    ``eqs == ()`` is the always-true predicate. A ``custom`` callable escape
    hatch is provided for tests of general semantics; the compiler rejects
    custom predicates (interpreter-only).
    """

    eqs: Tuple[Tuple[int, int], ...] = ()
    custom: Optional[Callable[[Tuple[int, ...]], bool]] = None

    def __call__(self, key: Tuple[int, ...]) -> bool:
        if self.custom is not None:
            return bool(self.custom(key))
        return all(key[i] == v for i, v in self.eqs)

    @property
    def always_true(self) -> bool:
        return self.custom is None and not self.eqs

    def __repr__(self) -> str:
        if self.custom is not None:
            return "pred<custom>"
        if not self.eqs:
            return "true"
        return " & ".join(f"k[{i}]=={v}" for i, v in self.eqs)


TRUE = SelPred()


# ---------------------------------------------------------------------------
# Join predicate:  K_l x K_r -> bool
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class JoinPred:
    """Conjunction of equalities between join components.

    Each pair ``(a, b)`` asserts ``eval(a) == eval(b)`` where a, b are
    L(i)/R(j)/Lit(v). The typical matmul predicate ``keyL[1] == keyR[0]`` is
    ``JoinPred(((L(1), R(0)),))``.
    """

    eqs: Tuple[Tuple[JoinComp, JoinComp], ...] = ()

    def __call__(self, kl: Tuple[int, ...], kr: Tuple[int, ...]) -> bool:
        return all(
            _eval_join_comp(a, kl, kr) == _eval_join_comp(b, kl, kr)
            for a, b in self.eqs
        )

    def __repr__(self) -> str:
        if not self.eqs:
            return "true"
        return " & ".join(f"{a!r}=={b!r}" for a, b in self.eqs)


JTRUE = JoinPred()


def eq_pred(*pairs: Tuple[int, int]) -> JoinPred:
    """Equality join predicate from (left_idx, right_idx) pairs."""
    return JoinPred(tuple((L(i), R(j)) for i, j in pairs))


# ---------------------------------------------------------------------------
# Join projection:  K_l x K_r -> K_o
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class JoinProj:
    comps: Tuple[JoinComp, ...]

    def __call__(self, kl: Tuple[int, ...], kr: Tuple[int, ...]) -> Tuple[int, ...]:
        return tuple(_eval_join_comp(c, kl, kr) for c in self.comps)

    @property
    def arity_out(self) -> int:
        return len(self.comps)

    def __repr__(self) -> str:
        return "(l,r)->(" + ",".join(map(repr, self.comps)) + ")"


def jproj(*comps: JoinComp) -> JoinProj:
    return JoinProj(tuple(comps))


# ---------------------------------------------------------------------------
# Equivalence classes over join components
# ---------------------------------------------------------------------------


class UnionFind:
    def __init__(self) -> None:
        self.parent: dict = {}

    def find(self, x):
        self.parent.setdefault(x, x)
        while self.parent[x] != x:
            self.parent[x] = self.parent[self.parent[x]]
            x = self.parent[x]
        return x

    def union(self, a, b) -> None:
        ra, rb = self.find(a), self.find(b)
        if ra != rb:
            self.parent[ra] = rb

    def classes(self) -> dict:
        out: dict = {}
        for x in list(self.parent):
            out.setdefault(self.find(x), []).append(x)
        return out


def join_equiv_classes(
    pred: JoinPred,
    left_arity: int,
    right_arity: int,
) -> UnionFind:
    """Union-find over {L(i)}, {R(j)}, literals implied by ``pred``."""
    uf = UnionFind()
    for i in range(left_arity):
        uf.find(L(i))
    for j in range(right_arity):
        uf.find(R(j))
    for a, b in pred.eqs:
        uf.union(a, b)
    return uf


def solve_left_key(
    pred: JoinPred,
    proj: JoinProj,
    left_arity: int,
    right_arity: int,
):
    """Derive, for the RJP of a join, expressions reconstructing each left-key
    component from (output key, right key).

    Returns ``(exprs, consistency)`` where ``exprs[i]`` is a component over
    the *RJP join* inputs — L(o) referring to output-key component ``o`` or
    R(j) referring to right-key component ``j`` (or Lit) — such that
    ``keyL[i] = eval(exprs[i], keyO, keyR)``; and ``consistency`` is a
    JoinPred over (keyO, keyR) expressing the residual match condition.

    Returns ``None`` if some left component is not derivable (the compiler
    then falls back to the general/unoptimized RJP).
    """
    uf = join_equiv_classes(pred, left_arity, right_arity)

    # Where does each equivalence class surface in (O, R)?
    # O components: proj.comps[o] is L(i)/R(j)/Lit -> class visible at L(o)
    # R components: R(j) visible at R(j). Lit classes are visible as Lit.
    class_expr: dict = {}
    for j in range(right_arity):
        class_expr.setdefault(uf.find(R(j)), R(j))
    for o, c in enumerate(proj.comps):
        if isinstance(c, Lit):
            continue
        class_expr.setdefault(uf.find(c), L(o))  # L(o) == output comp o
    for a, b in pred.eqs:
        for c in (a, b):
            if isinstance(c, Lit):
                root = uf.find(c)
                class_expr.setdefault(root, c)

    exprs = []
    for i in range(left_arity):
        root = uf.find(L(i))
        e = class_expr.get(root)
        if e is None:
            return None
        exprs.append(e)

    # Residual consistency: every *other* appearance of a class in (O, R)
    # must equal the representative expression.
    cons = []
    seen: dict = {}
    for j in range(right_arity):
        root = uf.find(R(j))
        rep = class_expr[root]
        if rep != R(j):
            cons.append((rep, R(j)))
        seen[root] = True
    for o, c in enumerate(proj.comps):
        if isinstance(c, Lit):
            cons.append((L(o), Lit(c.val)))
            continue
        root = uf.find(c)
        rep = class_expr[root]
        if rep != L(o):
            cons.append((rep, L(o)))
    # Deduplicate (a,b) pairs regardless of order.
    uniq = []
    for a, b in cons:
        if (a, b) not in uniq and (b, a) not in uniq:
            uniq.append((a, b))
    return tuple(exprs), JoinPred(tuple(uniq))
