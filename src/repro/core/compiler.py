"""Chunked compiler: lowers FRA query graphs to jit-able JAX computations.

This is the fast path of the engine. Where the sparse interpreter executes
tuple-at-a-time (the oracle), this executor lowers whole operators to XLA
ops over chunked relations:

  Σ(grp, +, ⋈(eq-pred, proj, mul/matmul, ·, ·)) over DenseRelations
      → one ``jnp.einsum`` (block axes from the join's key-equivalence
        classes, chunk axes from the kernel's chunk_spec);
  joins against a CooRelation (graph edges)   → gather (``take``);
  Σ over a CooRelation                        → ``segment_sum`` (scatter-add);
  RJP broadcast/aligned joins (from Σ/σ differentiation)
      → transpose + broadcast + elementwise kernel;
  σ                                           → slice/transpose/elementwise.

Everything here traces under ``jax.jit``; the paper's "database query
optimizer distributes the computation" role is then played by the sharding
planner (planner.py — 2-D (data × model) plans on a launch/mesh mesh) +
the XLA SPMD partitioner, which inserts the chosen plan's model-axis
psum and data-axis batch collectives around the lowerings emitted here.

The three hardware hot-spots — the Σ over a CooRelation, the matmul-shaped
Σ∘⋈ einsum, and the COO gather join (edge ⋈ node, plus the restricted-join
sparse-gradient gather) — are not called directly: each lowering site is
resolved against the kernel dispatch registry (kernels.py), which routes
it to the Pallas TPU kernels (kernels/segsum, kernels/matmul,
kernels/gather), their interpret/ref CPU tiers, or the default jnp path,
according to the ``DispatchTable`` the engine threads through
``_execute_graph``. Resolved tiers are recorded into the caller's
``resolutions`` dict (the engine exposes them on ``Compiled.resolutions``).
All gather/scatter sites honour the COO pad-and-mask contract: negative
(padding) key components gather zero rows and are dropped by segment sums,
so an nnz axis padded up to a shard multiple stays numerically inert.

Dense gradients of *absent* tuples: a relational gradient relation simply
lacks tuples that received no contribution; a dense array cannot express
absence, so the compiled gradient stores explicit zeros there. Under the
additive aggregation semantics this is exact.
"""

from __future__ import annotations

import math
import string
from typing import Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from . import fra, kernels
from .kernels import BinKernel
from .keys import In, JoinPred, JoinProj, KeyFn, L, Lit, R, join_equiv_classes
from .relation import CooRelation, DenseRelation

AnyRel = Union[DenseRelation, CooRelation]
Env = Dict[str, AnyRel]

_BLOCK_LETTERS = string.ascii_uppercase


def _vmapped(fn, times: int):
    """Kernel functions have chunk-local semantics (they see one tuple's
    value). Lift them over leading block-key / nnz axes with vmap so
    shape-dependent kernels (e.g. sum_chunk, per-chunk softmax) stay
    correct; XLA fuses the trivial elementwise cases back to one op."""
    for _ in range(times):
        fn = jax.vmap(fn)
    return fn


class LoweringError(NotImplementedError):
    pass


def _norm_pairs(pred: JoinPred):
    """Normalize eq pairs into (L, R), (L, Lit), (R, Lit) canonical forms."""
    lr, llit, rlit = [], [], []
    for a, b in pred.eqs:
        pair = (a, b)
        if isinstance(b, L) or (isinstance(b, R) and isinstance(a, Lit)):
            pair = (b, a)
        a, b = pair
        if isinstance(a, L) and isinstance(b, R):
            lr.append((a.idx, b.idx))
        elif isinstance(a, R) and isinstance(b, L):
            lr.append((b.idx, a.idx))
        elif isinstance(a, L) and isinstance(b, Lit):
            llit.append((a.idx, b.val))
        elif isinstance(a, R) and isinstance(b, Lit):
            rlit.append((a.idx, b.val))
        elif isinstance(a, L) and isinstance(b, L):
            raise LoweringError(f"L-L equality {a}=={b} not lowerable")
        elif isinstance(a, R) and isinstance(b, R):
            raise LoweringError(f"R-R equality {a}=={b} not lowerable")
        else:
            raise LoweringError(f"cannot normalize predicate pair {a}=={b}")
    return lr, llit, rlit


# ---------------------------------------------------------------------------
# Join lowering: einsum path (dense ⋈ dense, multiplicative kernel)
# ---------------------------------------------------------------------------


def _note(
    resolutions: Optional[Dict], op: str, site: str, impl, info: Optional[Dict] = None
) -> None:
    """Record a dispatch decision for diagnostics (Compiled.resolutions).
    Distinct sites that share a shape signature get ordinal suffixes
    (``op[site]#2`` …) so the record counts every decision, not every
    distinct shape. When ``resolutions`` is a ``kernels.ResolutionLog``
    (the engine's lowering walk) the site-info dict is snapshotted too,
    so ``analysis.kernelcheck`` can replay the resolution and prove it
    stable across retraces."""
    if resolutions is None:
        return
    key = f"{op}[{site}]"
    if key in resolutions:
        i = 2
        while f"{key}#{i}" in resolutions:
            i += 1
        key = f"{key}#{i}"
    resolutions[key] = impl.tier
    if info is not None and hasattr(resolutions, "record"):
        resolutions.record(key, op, site, impl.tier, dict(info))


def _dispatched_matmul_join(
    lspec: str,
    rspec: str,
    ospec: str,
    kernel: BinKernel,
    lrel: DenseRelation,
    rrel: DenseRelation,
    dispatch,
    resolutions: Optional[Dict],
) -> Optional[DenseRelation]:
    """Route a matmul-shaped Σ∘⋈ einsum through the ``blocked_matmul``
    dispatch op: contractions expressible as ONE 2-D matmul after
    flattening block axes — the MatMul chunk kernel ('mk','kn'→'mn') or a
    chunkless elementwise ⊗ — with every contracted block class shared by
    both sides and no batch class. Returns None to fall back to
    ``jnp.einsum`` (including when the table resolves this site to the
    jnp tier, which *is* the einsum path)."""
    if kernel.chunk_spec is not None:
        if kernel.chunk_spec != ("mk", "kn", "mn"):
            return None
        chunked = True
    elif kernel.elementwise and lrel.chunk_rank == 0 and rrel.chunk_rank == 0:
        chunked = False
    else:
        return None
    sl, sr, so = set(lspec), set(rspec), set(ospec)
    if len(sl) != len(lspec) or len(sr) != len(rspec) or len(so) != len(ospec):
        return None                  # repeated block class within one spec
    con = [c for c in lspec if c in sr and c not in so]
    if not con and not chunked:
        return None                  # outer product: nothing to win
    if (sl & sr) - set(con):
        return None                  # batch class (in both inputs + output)
    if (sl - set(con)) - so or (sr - set(con)) - so or so - (sl | sr):
        return None                  # unilateral sum / phantom output class

    l_keep = [c for c in lspec if c in so]
    r_keep = [c for c in rspec if c in so]
    la, ra = len(lspec), len(rspec)
    lext = {c: lrel.data.shape[lspec.index(c)] for c in lspec}
    rext = {c: rrel.data.shape[rspec.index(c)] for c in rspec}

    m, kk, n = (
        (lrel.data.shape[la], lrel.data.shape[la + 1], rrel.data.shape[ra + 1])
        if chunked
        else (1, 1, 1)
    )
    rows = math.prod(lext[c] for c in l_keep) * m
    inner = math.prod(lext[c] for c in con) * kk
    cols = math.prod(rext[c] for c in r_keep) * n

    ct = jnp.result_type(lrel.data, rrel.data)
    info = {"m": rows, "k": inner, "n": cols, "dtype": ct}
    impl = kernels.resolve_impl("blocked_matmul", info, dispatch)
    _note(resolutions, "blocked_matmul", f"m={rows},k={inner},n={cols}", impl, info)
    if impl.tier == "jnp":
        return None                  # the einsum below IS the jnp tier

    lk_ax = [lspec.index(c) for c in l_keep]
    lc_ax = [lspec.index(c) for c in con]
    rk_ax = [rspec.index(c) for c in r_keep]
    rc_ax = [rspec.index(c) for c in con]
    if chunked:
        lperm = lk_ax + [la] + lc_ax + [la + 1]      # (keep.., m, con.., k)
        rperm = rc_ax + [ra] + rk_ax + [ra + 1]      # (con.., k, keep.., n)
    else:
        lperm = lk_ax + lc_ax
        rperm = rc_ax + rk_ax
    l2 = jnp.transpose(lrel.data.astype(ct), lperm).reshape(rows, inner)
    r2 = jnp.transpose(rrel.data.astype(ct), rperm).reshape(inner, cols)
    out2 = impl.fn(l2, r2)

    shp = tuple(lext[c] for c in l_keep) + ((m,) if chunked else ())
    shp += tuple(rext[c] for c in r_keep) + ((n,) if chunked else ())
    out = out2.reshape(shp)
    # natural axis order: l_keep.., [m], r_keep.., [n] → ospec order + chunks
    ax_of = {c: i for i, c in enumerate(l_keep)}
    off = len(l_keep) + (1 if chunked else 0)
    for j, c in enumerate(r_keep):
        ax_of[c] = off + j
    perm = [ax_of[c] for c in ospec]
    if chunked:
        perm += [len(l_keep), off + len(r_keep)]
    out = jnp.transpose(out, perm)
    return DenseRelation(out, key_arity=len(ospec))


def _einsum_join(
    join: fra.Join,
    grp: Optional[KeyFn],
    lrel: DenseRelation,
    rrel: DenseRelation,
    dispatch=None,
    resolutions: Optional[Dict] = None,
) -> DenseRelation:
    la, ra = join.left.key_arity, join.right.key_arity
    uf = join_equiv_classes(join.pred, la, ra)
    for a, b in join.pred.eqs:
        if isinstance(a, Lit) or isinstance(b, Lit):
            raise LoweringError("literal in einsum-join predicate")

    letters: Dict[object, str] = {}

    def letter(comp) -> str:
        root = uf.find(comp)
        if root not in letters:
            letters[root] = _BLOCK_LETTERS[len(letters)]
        return letters[root]

    lspec = "".join(letter(L(i)) for i in range(la))
    rspec = "".join(letter(R(j)) for j in range(ra))

    out_comps: List = list(join.proj.comps)
    if grp is not None:
        composed = []
        for c in grp.comps:
            if isinstance(c, Lit):
                raise LoweringError("Lit in grp over einsum join")
            composed.append(join.proj.comps[c.idx])
        out_comps = composed
    if any(isinstance(c, Lit) for c in out_comps):
        raise LoweringError("Lit in einsum join projection")
    ospec = "".join(letter(c) for c in out_comps)

    if grp is None:
        # A bare join must not implicitly aggregate: every block class must
        # survive into the output key.
        if not set(lspec + rspec) <= set(ospec):
            raise LoweringError(
                "bare join drops a key class (duplicate keys); wrap in Σ"
            )

    k = join.kernel
    if k.chunk_spec is not None:
        lc, rc, oc = k.chunk_spec
        if len(lc) != lrel.chunk_rank or len(rc) != rrel.chunk_rank:
            raise LoweringError(
                f"chunk rank mismatch for {k.name}: {lrel.chunk_rank},{rrel.chunk_rank}"
            )
    elif k.elementwise:
        cr = max(lrel.chunk_rank, rrel.chunk_rank)
        oc = string.ascii_lowercase[:cr]
        lc = oc[cr - lrel.chunk_rank:]
        rc = oc[cr - rrel.chunk_rank:]
    else:
        raise LoweringError(f"kernel {k.name} is not einsum-lowerable")

    routed = _dispatched_matmul_join(
        lspec, rspec, ospec, k, lrel, rrel, dispatch, resolutions
    )
    if routed is not None:
        return routed

    spec = f"{lspec}{lc},{rspec}{rc}->{ospec}{oc}"
    data = jnp.einsum(spec, lrel.data, rrel.data)
    return DenseRelation(data, key_arity=len(out_comps))


# ---------------------------------------------------------------------------
# Join lowering: aligned/broadcast path (RJPs of σ and Σ, pointwise losses)
# ---------------------------------------------------------------------------


def _aligned_join(
    join: fra.Join, lrel: DenseRelation, rrel: DenseRelation
) -> Optional[DenseRelation]:
    """Joins whose projection is the identity on one side: the other side is
    permuted/broadcast into that side's grid and the kernel applied
    pointwise. Covers the RJP-of-Σ broadcast join, the RJP-of-σ join, and
    pointwise losses (⊗ against labels with proj → keyL)."""
    la, ra = join.left.key_arity, join.right.key_arity
    lr, llit, rlit = _norm_pairs(join.pred)

    id_over_R = join.proj.comps == tuple(R(j) for j in range(ra))
    id_over_L = join.proj.comps == tuple(L(i) for i in range(la))
    if id_over_R:
        base_rel, base_arity = rrel, ra
        mapped_rel, mapped_arity = lrel, la
        pairs = [(i, j) for i, j in lr]          # mapped comp i ↔ base comp j
        mapped_lit, base_lit = llit, rlit
        order = "lr"
    elif id_over_L:
        base_rel, base_arity = lrel, la
        mapped_rel, mapped_arity = rrel, ra
        pairs = [(j, i) for i, j in lr]
        mapped_lit, base_lit = rlit, llit
        order = "rl"
    else:
        return None

    m2b = dict(pairs)
    if len(m2b) != len(pairs) or len(set(m2b.values())) != len(m2b):
        return None
    if len(m2b) != mapped_arity or mapped_lit:
        return None  # a mapped axis is unconstrained -> would need summation

    # Permute mapped block axes into base-axis order, insert broadcast axes.
    src = mapped_rel.data
    perm = sorted(range(mapped_arity), key=lambda i: m2b[i])
    src = jnp.transpose(
        src, tuple(perm) + tuple(range(mapped_arity, src.ndim))
    )
    matched_base = set(m2b.values())
    for j in range(base_arity):
        if j not in matched_base:
            src = jnp.expand_dims(src, axis=j)
    # src now has base_arity block axes (some size-1) + mapped chunk dims;
    # broadcast explicitly so pointwise kernels that ignore one operand
    # (e.g. the Σ-RJP's take_l) still produce full-grid outputs.
    src = jnp.broadcast_to(
        src, base_rel.extents + tuple(src.shape[base_arity:])
    )

    bb = base_rel.data
    kfn = _vmapped(join.kernel.fn, base_arity)
    if order == "lr":
        val = kfn(src, bb)
    else:
        val = kfn(bb, src)

    out_arity = base_arity
    if base_lit:
        idx = jnp.ones(base_rel.extents, dtype=bool)
        for j, v in base_lit:
            ax_shape = [1] * base_arity
            ax_shape[j] = base_rel.extents[j]
            m = (jnp.arange(base_rel.extents[j]) == v).reshape(ax_shape)
            idx = idx & m
        mask = idx.reshape(idx.shape + (1,) * (val.ndim - out_arity))
        val = jnp.where(mask, val, jnp.zeros((), dtype=val.dtype))
    return DenseRelation(val, key_arity=out_arity)


def _broadcast_join(
    join: fra.Join,
    grp: Optional[KeyFn],
    lrel: DenseRelation,
    rrel: DenseRelation,
) -> Optional[DenseRelation]:
    """Last-resort dense ⋈ dense lowering for kernels with no einsum hints
    and non-aligned projections (e.g. the autodiff general path's inner
    join under a merging Σ): materialize the joint key-class grid,
    broadcast both operands into it, apply the kernel pointwise, and sum
    out the classes the (grp-composed) output key drops. Cost is the full
    class-grid product — the paper's *unoptimized* RJP — so the einsum and
    aligned paths are always tried first."""
    la, ra = join.left.key_arity, join.right.key_arity
    for a, b in join.pred.eqs:
        if isinstance(a, Lit) or isinstance(b, Lit):
            return None
    uf = join_equiv_classes(join.pred, la, ra)

    out_comps: List = list(join.proj.comps)
    if grp is not None:
        composed = []
        for c in grp.comps:
            if isinstance(c, Lit):
                return None
            composed.append(join.proj.comps[c.idx])
        out_comps = composed
    if any(isinstance(c, Lit) for c in out_comps):
        return None

    # one grid axis per join equivalence class, first-appearance order
    ax_of: Dict[object, int] = {}
    extents: List[int] = []
    lcomps = tuple(L(i) for i in range(la))
    rcomps = tuple(R(j) for j in range(ra))
    for comps, rel in ((lcomps, lrel), (rcomps, rrel)):
        for k, c in enumerate(comps):
            root = uf.find(c)
            if root not in ax_of:
                ax_of[root] = len(extents)
                extents.append(rel.extents[k])
    out_ax: List[int] = []
    for c in out_comps:
        ax = ax_of[uf.find(c)]
        if ax in out_ax:
            return None          # repeated class in output key (diagonal)
        out_ax.append(ax)
    if grp is None and len(out_ax) != len(extents):
        # a bare join dropping a class would emit duplicate keys
        return None

    def into_grid(rel: DenseRelation, comps) -> jnp.ndarray:
        axes = [ax_of[uf.find(c)] for c in comps]
        if len(set(axes)) != len(axes):
            return None          # intra-side equality (diagonal operand)
        perm = sorted(range(len(axes)), key=lambda i: axes[i])
        data = jnp.transpose(
            rel.data, tuple(perm) + tuple(range(len(axes), rel.data.ndim))
        )
        present = set(axes)
        for ax in range(len(extents)):
            if ax not in present:
                data = jnp.expand_dims(data, axis=ax)
        return jnp.broadcast_to(data, tuple(extents) + rel.chunk_shape)

    lb = into_grid(lrel, lcomps)
    rb = into_grid(rrel, rcomps)
    if lb is None or rb is None:
        return None
    val = _vmapped(join.kernel.fn, len(extents))(lb, rb)
    drop = tuple(ax for ax in range(len(extents)) if ax not in out_ax)
    if drop:
        val = jnp.sum(val, axis=drop)
    remaining = [ax for ax in range(len(extents)) if ax not in drop]
    perm = [remaining.index(ax) for ax in out_ax]
    val = jnp.transpose(
        val, tuple(perm) + tuple(range(len(out_ax), val.ndim))
    )
    return DenseRelation(val, key_arity=len(out_comps))


# ---------------------------------------------------------------------------
# Join lowering: gather path (one side COO)
# ---------------------------------------------------------------------------


def _dispatched_gather(
    dense: DenseRelation,
    idx_cols: Tuple[jnp.ndarray, ...],
    dispatch,
    resolutions: Optional[Dict],
) -> jnp.ndarray:
    """Gather rows of ``dense`` at per-key-dim index columns through the
    ``gather_join`` dispatch op: the key grid is flattened to one row axis
    and the chunk to one feature axis, matching the op contract
    ``fn(table2d, rows) → table2d[rows]`` (out-of-range / negative ids —
    the COO nnz padding — yield zero rows). Returns (E, *chunk)."""
    assert len(idx_cols) == dense.key_arity and dense.key_arity > 0
    e = idx_cols[0].shape[0]
    chunk = dense.chunk_shape
    if e == 0:
        # zero-nnz COO guard: every tier agrees on the empty gather
        return jnp.zeros((0,) + chunk, dtype=dense.data.dtype)
    # flat row ids; any out-of-range component poisons the row to -1 so
    # the kernel's mask drops it
    valid = None
    flat = jnp.zeros((e,), dtype=jnp.int32)
    for ext, col in zip(dense.extents, idx_cols):
        col = col.astype(jnp.int32)
        ok = (col >= 0) & (col < ext)
        valid = ok if valid is None else (valid & ok)
        flat = flat * ext + jnp.clip(col, 0, max(ext - 1, 0))
    rows = jnp.where(valid, flat, jnp.int32(-1))
    n = math.prod(dense.extents)
    d = math.prod(chunk)
    info = {"rows": e, "num_rows": n, "dim": d, "dtype": dense.data.dtype}
    impl = kernels.resolve_impl("gather_join", info, dispatch)
    _note(resolutions, "gather_join", f"E={e},N={n},D={d}", impl, info)
    table2 = dense.data.reshape(n, d)
    return impl.fn(table2, rows).reshape((e,) + chunk)


def _mask_padded_rows(keys: jnp.ndarray, vals: jnp.ndarray) -> jnp.ndarray:
    """Zero value rows whose key carries a negative (padding) component, so
    padded nnz rows stay inert through non-multiplicative kernels too."""
    valid = jnp.all(keys >= 0, axis=1)
    return jnp.where(
        valid.reshape((-1,) + (1,) * (vals.ndim - 1)),
        vals,
        jnp.zeros((), dtype=vals.dtype),
    )


def _coo_join(
    join: fra.Join, lrel: AnyRel, rrel: AnyRel, dispatch, resolutions
) -> CooRelation:
    coo_is_left = isinstance(lrel, CooRelation)
    coo = lrel if coo_is_left else rrel
    dense = rrel if coo_is_left else lrel
    assert isinstance(dense, DenseRelation)
    lr, llit, rlit = _norm_pairs(join.pred)
    if llit or rlit:
        raise LoweringError("literal predicates on COO joins not supported")
    # (coo column ↔ dense comp) pairs
    if coo_is_left:
        pairs = [(i, j) for i, j in lr]
    else:
        pairs = [(j, i) for i, j in lr]
    d2c = {j: i for i, j in pairs}
    if len(d2c) != dense.key_arity:
        raise LoweringError(
            "COO join requires every dense key component matched (gather)"
        )
    idx = tuple(coo.keys[:, d2c[j]] for j in range(dense.key_arity))
    gathered = _dispatched_gather(dense, idx, dispatch, resolutions)
    kfn = _vmapped(join.kernel.fn, 1)
    if coo_is_left:
        vals = kfn(coo.values, gathered)
    else:
        vals = kfn(gathered, coo.values)
    vals = _mask_padded_rows(coo.keys, vals)

    cols = []
    extents = []
    for c in join.proj.comps:
        if isinstance(c, Lit):
            cols.append(jnp.full((coo.nnz,), c.val, dtype=coo.keys.dtype))
            extents.append(c.val + 1)
            continue
        if coo_is_left:
            col = c.idx if isinstance(c, L) else d2c[c.idx]
            ext = coo.extents[c.idx] if isinstance(c, L) else dense.extents[c.idx]
        else:
            col = c.idx if isinstance(c, R) else d2c[c.idx]
            ext = coo.extents[c.idx] if isinstance(c, R) else dense.extents[c.idx]
        cols.append(coo.keys[:, col])
        extents.append(ext)
    keys = jnp.stack(cols, axis=1) if cols else jnp.zeros((coo.nnz, 0), coo.keys.dtype)
    return CooRelation(keys, vals, tuple(extents))


# ---------------------------------------------------------------------------
# Restrict lowering: fused per-tuple gather for sparse gradients
# ---------------------------------------------------------------------------


def _solve_side_from_output(
    pred: JoinPred, proj: JoinProj, la: int, ra: int
):
    """For Restrict(Join(...), coo): reconstruct each input key component of
    the join from the *output* key columns (+ pred equalities). Returns
    (left_exprs, right_exprs) where each expr is an output column index or
    a Lit, or None if some component is underdetermined."""
    uf = join_equiv_classes(pred, la, ra)
    col_of: Dict[object, object] = {}
    for p, c in enumerate(proj.comps):
        if isinstance(c, Lit):
            continue
        col_of.setdefault(uf.find(c), p)
    for a, b in pred.eqs:
        for c in (a, b):
            if isinstance(c, Lit):
                col_of.setdefault(uf.find(c), Lit(c.val))

    def solve(comps):
        out = []
        for c in comps:
            e = col_of.get(uf.find(c))
            if e is None:
                return None
            out.append(e)
        return out

    lex = solve([L(i) for i in range(la)])
    rex = solve([R(j) for j in range(ra)])
    if lex is None or rex is None:
        return None
    return lex, rex


def _restricted_join(
    join: fra.Join,
    ref: CooRelation,
    lrel: AnyRel,
    rrel: AnyRel,
    dispatch=None,
    resolutions: Optional[Dict] = None,
) -> CooRelation:
    """Evaluate a dense⋈dense join only at the key set of ``ref``: gather
    both operands per ref-tuple and apply the kernel pointwise. This is the
    sparse-gradient fast path (e.g. ∂loss/∂edge_weights = g[dst]·h[src]);
    the per-tuple gathers route through the ``gather_join`` dispatch op."""
    if not (isinstance(lrel, DenseRelation) and isinstance(rrel, DenseRelation)):
        raise LoweringError("restricted join requires dense operands")
    la, ra = join.left.key_arity, join.right.key_arity
    solved = _solve_side_from_output(join.pred, join.proj, la, ra)
    if solved is None:
        raise LoweringError("restricted join underdetermined (needs Σ)")
    lex, rex = solved

    def gather(rel: DenseRelation, exprs):
        idx = []
        for e in exprs:
            if isinstance(e, Lit):
                idx.append(jnp.full((ref.nnz,), e.val, dtype=ref.keys.dtype))
            else:
                idx.append(ref.keys[:, e])
        return (
            _dispatched_gather(rel, tuple(idx), dispatch, resolutions)
            if idx
            else jnp.broadcast_to(rel.data, (ref.nnz,) + rel.chunk_shape)
        )

    lv = gather(lrel, lex)
    rv = gather(rrel, rex)
    vals = _vmapped(join.kernel.fn, 1)(lv, rv)
    vals = _mask_padded_rows(ref.keys, vals)
    # Chunk-level broadcasting in the forward kernel (e.g. scalar edge
    # weight × embedding chunk) dualizes to a reduction in the backward:
    # sum the VJP chunk down to the target relation's chunk shape.
    tgt = ref.chunk_shape
    extra = (vals.ndim - 1) - len(tgt)
    if extra > 0:
        vals = jnp.sum(vals, axis=tuple(range(1, 1 + extra)))
    for ax, (got, want) in enumerate(zip(vals.shape[1:], tgt)):
        if got != want:
            assert want == 1, (vals.shape, tgt)
            vals = jnp.sum(vals, axis=1 + ax, keepdims=True)
    return CooRelation(
        ref.keys, vals, ref.extents, ref.owner_dim, ref.shard_offsets
    )


# ---------------------------------------------------------------------------
# The executor
# ---------------------------------------------------------------------------


def _execute_graph(
    root: fra.Node,
    env: Env,
    cache: Optional[Env] = None,
    *,
    fuse_join_agg: bool = True,
    dispatch=None,
    resolutions: Optional[Dict] = None,
) -> AnyRel:
    """Walk a query graph over chunked relations, lowering each node to XLA
    ops. This is the engine's *lowering primitive*: it runs once per trace
    on the staged path (core/engine.py) and once per call on the eager
    path.

    ``fuse_join_agg=False`` materializes every Join's output individually
    instead of fusing Σ∘⋈ into one einsum — needed when a gradient program
    built *without* the §4 join-agg-fusion optimization will consume the
    join intermediates (benchmarks/rjp_ablation.py).

    ``dispatch`` is a kernels.DispatchTable (None → backend default)
    steering the segment-sum / blocked-matmul hot-spots to a physical
    tier; ``resolutions`` (optional dict) collects ``op[site] → tier``
    records of every dispatch decision made during the walk."""
    memo: Dict[int, AnyRel] = {}

    def ex(n: fra.Node) -> AnyRel:
        if n.id in memo:
            return memo[n.id]
        out = _ex(n)
        memo[n.id] = out
        if cache is not None:
            cache[f"__fwd_{n.id}"] = out
        return out

    def _join(n: fra.Join, grp: Optional[KeyFn]) -> AnyRel:
        lrel, rrel = ex(n.left), ex(n.right)
        if isinstance(lrel, CooRelation) or isinstance(rrel, CooRelation):
            if isinstance(lrel, CooRelation) and isinstance(rrel, CooRelation):
                raise LoweringError("COO ⋈ COO not supported")
            out = _coo_join(n, lrel, rrel, dispatch, resolutions)
            if grp is not None:
                out = _agg_coo(grp, out)
            return out
        # dense ⋈ dense
        k = n.kernel
        if k.elementwise or k.chunk_spec is not None:
            try:
                return _einsum_join(
                    n, grp, lrel, rrel, dispatch=dispatch, resolutions=resolutions
                )
            except LoweringError:
                pass
        al = _aligned_join(n, lrel, rrel)
        if al is not None:
            if grp is not None:
                al = _agg_dense(grp, al)
            return al
        bc = _broadcast_join(n, grp, lrel, rrel)
        if bc is not None:
            return bc
        raise LoweringError(f"cannot lower join {n.describe()}")

    def _agg_dense(grp: KeyFn, rel: DenseRelation) -> DenseRelation:
        arity = rel.key_arity
        if all(isinstance(c, Lit) for c in grp.comps) and grp.arity_out == 0:
            data = jnp.sum(
                rel.data, axis=tuple(range(arity))
            ) if arity else rel.data
            return DenseRelation(data, key_arity=0)
        if any(isinstance(c, Lit) for c in grp.comps):
            raise LoweringError("mixed Lit grp over dense not supported")
        keep = [c.idx for c in grp.comps]
        if len(set(keep)) != len(keep):
            raise LoweringError("duplicate grp components over dense")
        drop = tuple(i for i in range(arity) if i not in keep)
        data = jnp.sum(rel.data, axis=drop) if drop else rel.data
        # axes now ordered by ascending original idx; permute to grp order
        remaining = [i for i in range(arity) if i not in drop]
        perm = [remaining.index(i) for i in keep]
        data = jnp.transpose(
            data, tuple(perm) + tuple(range(len(keep), data.ndim))
        )
        return DenseRelation(data, key_arity=len(keep))

    def _agg_coo(grp: KeyFn, rel: CooRelation) -> DenseRelation:
        if any(isinstance(c, Lit) for c in grp.comps):
            raise LoweringError("Lit grp over COO not supported")
        keep = [c.idx for c in grp.comps]
        extents = tuple(rel.extents[i] for i in keep)
        if rel.nnz == 0:
            # zero-nnz guard: the registered tiers can disagree on the
            # dtype/shape of a segment_sum over empty arrays — the Σ of
            # no tuples is the ⊕-unit grid, emitted without dispatching
            return DenseRelation(
                jnp.zeros(extents + rel.chunk_shape, dtype=rel.values.dtype),
                key_arity=len(extents),
            )
        if not extents:
            return DenseRelation(jnp.sum(rel.values, axis=0), key_arity=0)
        flat = jnp.zeros((rel.nnz,), dtype=jnp.int32)
        stride = 1
        for i in reversed(range(len(keep))):
            flat = flat + rel.keys[:, keep[i]].astype(jnp.int32) * stride
            stride *= extents[i]
        num = math.prod(extents)
        chunk = rel.chunk_shape
        d = math.prod(chunk)
        info = {
            "nnz": rel.nnz, "dim": d, "num_segments": num,
            "dtype": rel.values.dtype,
        }
        impl = kernels.resolve_impl("segment_sum", info, dispatch)
        _note(resolutions, "segment_sum", f"E={rel.nnz},D={d},S={num}", impl, info)
        if impl.tier == "jnp":
            summed = jax.ops.segment_sum(rel.values, flat, num_segments=num)
        else:
            msg = rel.values.reshape((rel.nnz, d))
            summed = impl.fn(msg, flat, num)          # (num, d)
        return DenseRelation(
            summed.reshape(extents + chunk), key_arity=len(extents)
        )

    def _ex(n: fra.Node) -> AnyRel:
        if isinstance(n, fra.TableScan):
            return env[n.name]
        if isinstance(n, fra.Const):
            return env[n.ref]
        if isinstance(n, fra.Select):
            rel = ex(n.child)
            if isinstance(rel, CooRelation):
                if not n.pred.always_true:
                    raise LoweringError("predicated σ over COO not supported")
                cols = []
                extents = []
                for c in n.proj.comps:
                    if isinstance(c, Lit):
                        raise LoweringError("Lit proj over COO")
                    cols.append(rel.keys[:, c.idx])
                    extents.append(rel.extents[c.idx])
                keys = jnp.stack(cols, axis=1)
                vals = _vmapped(n.kernel.fn, 1)(rel.values)
                # σ kernels with f(0) != 0 would resurrect padded rows;
                # re-mask so they stay inert through full-reduce Σs
                vals = _mask_padded_rows(rel.keys, vals)
                return CooRelation(keys, vals, tuple(extents))
            if n.pred.custom is not None:
                raise LoweringError("custom σ predicate not compilable")
            fixed = dict(n.pred.eqs)
            data = rel.data
            # slice fixed components (descending so axes stay valid)
            for i in sorted(fixed, reverse=True):
                data = jnp.take(data, fixed[i], axis=i)
            remaining = [i for i in range(n.child.key_arity) if i not in fixed]
            proj_idx = []
            for c in n.proj.comps:
                if isinstance(c, Lit):
                    raise LoweringError("Lit σ projection over dense")
                if c.idx in fixed:
                    raise LoweringError("σ projects a predicate-fixed component")
                proj_idx.append(remaining.index(c.idx))
            if sorted(proj_idx) != list(range(len(remaining))):
                raise LoweringError("σ projection must permute surviving comps")
            chunk_axes = tuple(range(len(remaining), data.ndim))
            data = jnp.transpose(data, tuple(proj_idx) + chunk_axes)
            data = _vmapped(n.kernel.fn, len(proj_idx))(data)
            return DenseRelation(data, key_arity=len(proj_idx))
        if isinstance(n, fra.Agg):
            if isinstance(n.child, fra.Join) and fuse_join_agg:
                if not n.kernel.is_add:
                    raise LoweringError("non-additive Σ over ⋈ not supported")
                return _join(n.child, n.grp)
            rel = ex(n.child)
            if not n.kernel.is_add:
                raise LoweringError("non-additive Σ not supported in compiler")
            if isinstance(rel, CooRelation):
                return _agg_coo(n.grp, rel)
            return _agg_dense(n.grp, rel)
        if isinstance(n, fra.Join):
            return _join(n, None)
        if isinstance(n, fra.Restrict):
            ref = ex(n.ref)
            if isinstance(ref, DenseRelation):
                # Full-grid key set: the restriction is the identity.
                return ex(n.child)
            assert isinstance(ref, CooRelation)
            if isinstance(n.child, fra.Join):
                lrel, rrel = ex(n.child.left), ex(n.child.right)
                if isinstance(lrel, DenseRelation) and isinstance(rrel, DenseRelation):
                    return _restricted_join(
                        n.child, ref, lrel, rrel, dispatch, resolutions
                    )
            child = ex(n.child)
            if isinstance(child, CooRelation):
                # By construction RJP outputs over a sparse target reuse the
                # target's key order.
                return child
            # Dense child: gather at ref keys (padding rows gather zeros).
            idx = tuple(ref.keys[:, i] for i in range(ref.key_arity))
            vals = _dispatched_gather(child, idx, dispatch, resolutions)
            return CooRelation(
                ref.keys, vals, ref.extents, ref.owner_dim, ref.shard_offsets
            )
        if isinstance(n, fra.AddOp):
            a, b = ex(n.left), ex(n.right)
            if isinstance(a, DenseRelation) and isinstance(b, DenseRelation):
                return DenseRelation(a.data + b.data, a.key_arity)
            if isinstance(a, DenseRelation) and isinstance(b, CooRelation):
                a, b = b, a
            if isinstance(a, CooRelation) and isinstance(b, DenseRelation):
                idx = tuple(a.keys[:, i] for i in range(a.key_arity))
                return DenseRelation(b.data.at[idx].add(a.values), b.key_arity)
            raise LoweringError("COO + COO add not supported")
        raise TypeError(f"unknown node {n}")

    return ex(root)


def execute(
    root: fra.Node,
    env: Env,
    cache: Optional[Env] = None,
    *,
    fuse_join_agg: bool = True,
    dispatch=None,
) -> AnyRel:
    """Eager execution: the engine's eager mode on an anonymous graph —
    re-walks the graph on every call, no engine registered (callers often
    build throwaway graphs; interning them would only pin memory). Use
    the ``repro.Database`` session (``db.query(...)`` /
    ``db.execute(...)``) for the cached jit path.

    ``dispatch`` accepts anything ``kernels.make_table`` does (a tier
    name, a {op: tier} dict, a DispatchTable); None keeps the backend
    default (jnp lowerings on CPU, Pallas kernels on TPU)."""
    table = kernels.make_table(dispatch)
    return _execute_graph(
        root, env, cache, fuse_join_agg=fuse_join_agg, dispatch=table
    )


def run_query(q: fra.Query, env: Env, *, dispatch=None) -> AnyRel:
    """Eager execution of a whole Query (see ``execute``)."""
    table = kernels.make_table(dispatch)
    return _execute_graph(q.root, env, dispatch=table)


def execute_with_cache(
    root: fra.Node, env: Env, *, fuse_join_agg: bool = True, dispatch=None
) -> Tuple[AnyRel, Env]:
    """Forward pass caching every evaluated node's chunked relation, for the
    compiled gradient path (Algorithm 2 line 6). Joins consumed by a fusing
    Agg are evaluated as part of the fused einsum and are not individually
    cached — the §4-optimized RJPs never consume them, only their children
    (which are cached). Pass ``fuse_join_agg=False`` when the gradient
    program was built without join-agg fusion and needs the join
    intermediates."""
    fwd: Env = {}
    table = kernels.make_table(dispatch)
    out = _execute_graph(
        root, env, cache=fwd, fuse_join_agg=fuse_join_agg, dispatch=table
    )
    return out, fwd


def grad_eval(
    prog,
    env: Env,
    seed: Optional[AnyRel] = None,
    *,
    fuse_join_agg: bool = True,
    dispatch=None,
) -> Tuple[AnyRel, Dict[str, AnyRel]]:
    """Execute a GradientProgram (autodiff.py) on the compiled path:
    chunked forward with cache, then each gradient query graph. Thin
    wrapper over the engine's eager mode; the staged equivalent is a
    ``repro.Database`` handle's ``step()``. ``dispatch`` steers the
    kernel tier of both the forward and every gradient graph, so the
    gradient queries differentiate *through* whatever physical forward
    (Pallas included) the table selects."""
    from .engine import engine_for

    return engine_for(prog, fuse_join_agg=fuse_join_agg).eager(
        env, seed, dispatch=dispatch
    )
