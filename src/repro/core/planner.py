"""Distribution planner: the JAX analogue of the paper's claim that "the
database query optimizer will automatically distribute the computation,
taking into account the sizes of the two matrices" (§1).

For every Join in an FRA query the planner chooses, from relation sizes
and the mesh, between the paper's two physical plans:

  * BROADCAST the small side (the paper's data-parallel plan): the small
    relation is replicated (XLA: all-gather once), the big side stays
    partitioned on a non-contraction block axis; no output collective.
  * CO-PARTITION both sides on the join key (the paper's mixed
    data/model-parallel or tensor-parallel plan): both relations are
    sharded on the contraction block axis; the join-aggregate's Σ then
    requires an all-reduce (psum) of the output.

On a 2-D (data × model) mesh — ``launch/mesh.make_host_mesh`` /
``make_production_mesh`` — the planner additionally chooses, per
relation, a *data-axis batch dimension*: the surviving non-contraction
block axis of (usually) the batch-keyed relation is sharded over the
mesh's batch axes (``("pod", "data")`` folded on the multi-pod mesh),
the other side is replicated over them, and the Σ of the join-aggregate
pays a data-axis all-reduce whenever the grouping drops the batch key.
Both placements use the same bytes-moved cost model; a 1-axis mesh
degrades to exactly the historical 1-D plans.

The decision is made statically (relation chunk-grid shapes are static at
trace time) with the same bytes-moved cost model a database optimizer
uses, and is *executed* by emitting PartitionSpecs for the relations'
block axes — the XLA SPMD partitioner then plays the role of the
database execution engine, inserting exactly the all-gather or
all-reduce the chosen plan implies.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from jax.sharding import PartitionSpec as P

from . import fra
from .keys import In, L, R, join_equiv_classes
from .relation import CooRelation, DenseRelation

#: mesh axes treated as data-parallel (batch) axes, in fold order — the
#: multi-pod production mesh folds ("pod", "data") onto one relation dim.
DATA_AXIS_NAMES = ("pod", "data")


def fold_axes(axes: Tuple[str, ...]):
    """PartitionSpec entry for a dim carrying ``axes``: the folded tuple,
    a single axis name, or None — the one place the fold rule lives."""
    if not axes:
        return None
    return axes if len(axes) > 1 else axes[0]


@dataclass(frozen=True)
class MeshGeometry:
    """Static description of the mesh the planner plans for: one
    tensor-parallel (model) axis plus zero or more folded data axes.

    ``from_mesh`` derives it from a real ``jax.sharding.Mesh``;
    ``single`` is the legacy 1-D geometry (model axis only) used when the
    caller only knows a device count."""

    model_axis: str
    model_size: int
    data_axes: Tuple[str, ...] = ()
    data_size: int = 1

    @classmethod
    def single(cls, n_devices: int, axis: str = "model") -> "MeshGeometry":
        return cls(axis, max(1, int(n_devices or 1)))

    @classmethod
    def from_mesh(cls, mesh, axis: Optional[str] = None) -> "MeshGeometry":
        """Read the (data × model) geometry off a jax Mesh: ``axis`` (or
        ``"model"``) is the tensor-parallel axis — on a 1-axis mesh the
        sole axis plays that role, reproducing the 1-D plans — and every
        ``DATA_AXIS_NAMES`` axis present is folded into the batch pair."""
        names = tuple(mesh.axis_names)
        sizes = dict(mesh.shape)
        if axis is not None:
            if axis not in names:
                raise ValueError(
                    f"model axis {axis!r} is not on the mesh (axes: {names})"
                )
            model = axis
        elif "model" in names:
            model = "model"
        elif len(names) == 1:
            model = names[0]
        else:
            raise ValueError(
                f"cannot infer the model axis of a multi-axis mesh with no "
                f"'model' axis (axes: {names}); pass axis= explicitly"
            )
        data_axes = tuple(
            a for a in DATA_AXIS_NAMES if a in names and a != model
        )
        data_size = 1
        for a in data_axes:
            data_size *= int(sizes[a])
        return cls(model, int(sizes[model]), data_axes, data_size)

    @property
    def data_spec(self):
        """PartitionSpec entry for a data-sharded dim: the folded axis
        tuple, or the single axis name."""
        return fold_axes(self.data_axes)


@dataclass(frozen=True)
class JoinPlan:
    """Physical plan for one Join node."""

    kind: str                      # broadcast_left | broadcast_right | copartition
    node_id: int
    # estimated bytes moved per device for each candidate (the cost table;
    # 2-D plans add the data-axis candidates under "data:*" keys)
    costs: Dict[str, float]
    # block-axis index carrying the model axis, per side (None = replicated)
    left_shard_dim: Optional[int]
    right_shard_dim: Optional[int]
    # does the plan end in a model-axis all-reduce of the join-agg output?
    needs_psum: bool
    # block-axis index carrying the data (batch) axes, per side
    left_batch_dim: Optional[int] = None
    right_batch_dim: Optional[int] = None
    # the mesh axes the dims above refer to
    model_axis: str = "model"
    data_axes: Tuple[str, ...] = ()
    # chosen data-axis placement: none | data:shard_left | data:shard_right
    #                             | data:replicate
    data_kind: str = "none"
    # does the Σ reduce the data-sharded batch key (data-axis all-reduce)?
    needs_data_psum: bool = False

    def pspec(self, side: str, arity: int, axis: Optional[str] = None) -> P:
        dim = self.left_shard_dim if side == "left" else self.right_shard_dim
        bdim = (
            self.left_batch_dim if side == "left" else self.right_batch_dim
        )
        spec: list = [None] * arity
        if dim is not None and dim < arity:
            spec[dim] = axis or self.model_axis
        if bdim is not None and bdim < arity and self.data_axes:
            spec[bdim] = fold_axes(self.data_axes)
        return P(*spec)

def _rel_bytes(rel) -> float:
    if isinstance(rel, DenseRelation):
        return float(rel.data.size * rel.data.dtype.itemsize)
    if isinstance(rel, CooRelation):
        return float(rel.values.size * rel.values.dtype.itemsize)
    # ShapeDtypeStruct-like estimate
    size = 1
    for d in rel.shape:
        size *= d
    return float(size * rel.dtype.itemsize)


def _contraction_dims(join: fra.Join) -> Tuple[Optional[int], Optional[int]]:
    """Joined-on block-key dims (left, right) — the contraction axes a
    co-partition plan shards. (The join-agg tree's Σ typically drops this
    key from the final output; whether it survives the join's own proj is
    irrelevant to the physical plan.)"""
    al = join.left.key_arity
    ar = join.right.key_arity
    uf = join_equiv_classes(join.pred, al, ar)
    for i in range(al):
        root = uf.find(L(i))
        for j in range(ar):
            if uf.find(R(j)) == root:
                return i, j
    return None, None


def _output_dims(join: fra.Join) -> Tuple[Optional[int], Optional[int]]:
    """First *non-contraction* block dim per side that survives into the
    output (for the broadcast plans: the kept side stays sharded on a dim
    requiring no collective — sharding the contraction dim would still
    force a psum). On a 2-D mesh this is also each side's candidate batch
    dim for the data axes."""
    lc, rc = _contraction_dims(join)
    ldim = rdim = None
    for c in join.proj.comps:
        if isinstance(c, L) and ldim is None and c.idx != lc:
            ldim = c.idx
        if isinstance(c, R) and rdim is None and c.idx != rc:
            rdim = c.idx
    return ldim, rdim


DEFAULT_MEM_BUDGET = 8e9  # half a v5e chip's 16 GB HBM for one relation


def plan_join(
    join: fra.Join,
    left_bytes: float,
    right_bytes: float,
    out_bytes: float,
    n_devices: int,
    mem_budget: float = DEFAULT_MEM_BUDGET,
    *,
    geometry: Optional[MeshGeometry] = None,
    sum_out_bytes: Optional[float] = None,
    batch_survives: Tuple[bool, bool] = (True, True),
) -> JoinPlan:
    """Pick the cheapest *feasible* physical plan by bytes moved per
    device, exactly the way the paper describes the database optimizer
    (§1): broadcast requires the broadcast relation to be replicated on
    every node, so it is only feasible within the per-node memory budget;
    otherwise the relations are co-partitioned on the join key.

    all-gather of X over N devices moves ~X·(N-1)/N per device;
    a ring all-reduce of the output moves ~2·out·(N-1)/N.

    ``geometry`` extends the decision to a 2-D (data × model) mesh: the
    data axes are placed first — shard one side's surviving batch dim
    (replicating the other side over the data axes) or replicate both —
    and the model axis then avoids the batch dim. ``sum_out_bytes`` is
    the post-Σ output estimate the all-reduce costs use on the 2-D path;
    ``batch_survives`` says, per side, whether the batch dim survives the
    enclosing grouping (a dropped batch key costs a data-axis all-reduce
    of the Σ output). A 1-axis geometry reproduces the historical 1-D
    plans bit-for-bit.
    """
    geo = geometry or MeshGeometry.single(n_devices)
    n_model = max(1, geo.model_size)
    frac_m = (n_model - 1) / n_model
    two_d = geo.data_size > 1
    lc, rc = _contraction_dims(join)
    lo, ro = _output_dims(join)

    costs: Dict[str, float] = {}

    # --- data axes: shard a batch dim, or replicate over them ------------
    left_batch = right_batch = None
    data_kind = "none"
    needs_data_psum = False
    if two_d:
        frac_d = (geo.data_size - 1) / geo.data_size
        sum_out = out_bytes if sum_out_bytes is None else sum_out_bytes
        # feasibility mirrors the model axis: a candidate must fit every
        # relation it replicates within the per-device budget
        dcosts: Dict[str, float] = {}
        if left_bytes <= mem_budget and right_bytes <= mem_budget:
            # no batch parallelism: both inputs replicated over the axes
            dcosts["data:replicate"] = (left_bytes + right_bytes) * frac_d
        if lo is not None and right_bytes <= mem_budget:
            dcosts["data:shard_left"] = right_bytes * frac_d + (
                0.0 if batch_survives[0] else 2.0 * sum_out * frac_d
            )
        if ro is not None and left_bytes <= mem_budget:
            dcosts["data:shard_right"] = left_bytes * frac_d + (
                0.0 if batch_survives[1] else 2.0 * sum_out * frac_d
            )
        if not dcosts:
            # nothing feasible (e.g. both sides over budget with no batch
            # dim): best effort — shard a batch dim if one exists so at
            # least the sharded side stays partitioned, else replicate
            if lo is not None:
                dcosts["data:shard_left"] = right_bytes * frac_d
            elif ro is not None:
                dcosts["data:shard_right"] = left_bytes * frac_d
            else:
                dcosts["data:replicate"] = (left_bytes + right_bytes) * frac_d
        data_kind = min(dcosts, key=dcosts.get)
        costs.update(dcosts)
        if data_kind == "data:shard_left":
            left_batch = lo
            needs_data_psum = not batch_survives[0]
        elif data_kind == "data:shard_right":
            right_batch = ro
            needs_data_psum = not batch_survives[1]

    # --- model axis: broadcast vs co-partition, avoiding the batch dims --
    # The kept side of a broadcast plan stays sharded on a surviving dim;
    # if the data axes already took that dim, the model axis would sit
    # idle and the "broadcast" degenerates to replicating *both* sides —
    # charge it as such (2-D path only; 1-D keeps the historical costs).
    lo_m = None if (lo is not None and lo == left_batch) else lo
    ro_m = None if (ro is not None and ro == right_batch) else ro
    mcosts: Dict[str, float] = {}
    if left_bytes <= mem_budget:
        c = left_bytes * frac_m
        if two_d and ro_m is None:
            c += right_bytes * frac_m
        mcosts["broadcast_left"] = c
    if right_bytes <= mem_budget:
        c = right_bytes * frac_m
        if two_d and lo_m is None:
            c += left_bytes * frac_m
        mcosts["broadcast_right"] = c
    if lc is not None and rc is not None:
        # co-partition on the contraction key: inputs land pre-sharded
        # (no repartition cost for our static plans — parameters/data are
        # *created* in the planned layout), output needs the psum. The
        # 2-D path prices the psum at the post-Σ output size.
        psum_out = sum_out if two_d and sum_out_bytes is not None else out_bytes
        mcosts["copartition"] = 2.0 * psum_out * frac_m
    if not mcosts:
        raise ValueError(
            "no feasible plan: both sides exceed the memory budget and the "
            "join has no contraction key to co-partition on"
        )
    kind = min(mcosts, key=mcosts.get)
    costs.update(mcosts)

    common = dict(
        left_batch_dim=left_batch,
        right_batch_dim=right_batch,
        model_axis=geo.model_axis,
        data_axes=geo.data_axes,
        data_kind=data_kind,
        needs_data_psum=needs_data_psum,
    )
    if kind == "copartition":
        return JoinPlan(kind, join.id, costs, lc, rc, needs_psum=True, **common)
    if kind == "broadcast_left":
        return JoinPlan(kind, join.id, costs, None, ro_m, needs_psum=False, **common)
    return JoinPlan(kind, join.id, costs, lo_m, None, needs_psum=False, **common)


def _batch_survival(
    join: fra.Join, agg: Optional[fra.Agg]
) -> Tuple[bool, bool]:
    """Does each side's batch dim survive the enclosing Σ's grouping?
    Dropped batch keys cost a data-axis all-reduce of the Σ output."""
    lo, ro = _output_dims(join)

    def survives(comp) -> bool:
        if comp is None or agg is None:
            return True
        try:
            pos = join.proj.comps.index(comp)
        except ValueError:
            return True
        return any(
            isinstance(c, In) and c.idx == pos for c in agg.grp.comps
        )

    return (
        survives(None if lo is None else L(lo)),
        survives(None if ro is None else R(ro)),
    )


def plan_query(
    query: fra.Query,
    env: Dict[str, object],
    n_devices: int,
    mem_budget: float = DEFAULT_MEM_BUDGET,
    *,
    geometry: Optional[MeshGeometry] = None,
) -> Dict[int, JoinPlan]:
    """Walk the query graph, estimate relation sizes bottom-up, and emit a
    JoinPlan per Join node (keyed by node id). ``geometry`` plans for a
    2-D (data × model) mesh (see ``MeshGeometry.from_mesh``); omitted, it
    is the legacy 1-D model-axis-only geometry over ``n_devices``."""
    geo = geometry or MeshGeometry.single(n_devices)
    sizes: Dict[int, float] = {}
    agg_of: Dict[int, fra.Agg] = {}
    joins: List[fra.Join] = []

    for node in query.root.topo():
        if isinstance(node, (fra.TableScan, fra.Const)):
            ref = node.name if isinstance(node, fra.TableScan) else node.ref
            if ref in env:
                sizes[node.id] = _rel_bytes(env[ref])
            else:  # unresolved (__seed/__fwd): assume small
                sizes[node.id] = 0.0
        elif isinstance(node, fra.Select):
            sizes[node.id] = sizes[node.child.id]
        elif isinstance(node, fra.Agg):
            # grouping reduces size by the dropped-key fraction; without
            # key-domain statistics assume a 1/8 reduction per dropped key
            child = sizes[node.child.id]
            dropped = max(0, node.child.key_arity - node.key_arity)
            sizes[node.id] = child / (8.0 ** dropped)
            if isinstance(node.child, fra.Join):
                agg_of[node.child.id] = node
        elif isinstance(node, fra.Join):
            joins.append(node)
            sizes[node.id] = max(
                sizes[node.left.id], sizes[node.right.id]
            )  # join-agg output is at most the big side
        elif isinstance(node, (fra.AddOp, fra.Restrict)):
            sizes[node.id] = sizes[node.children[0].id]

    plans: Dict[int, JoinPlan] = {}
    for node in joins:
        lb = sizes[node.left.id]
        rb = sizes[node.right.id]
        ob = sizes[node.id]
        agg = agg_of.get(node.id)
        plans[node.id] = plan_join(
            node,
            lb,
            rb,
            ob,
            geo.model_size,
            mem_budget,
            geometry=geo,
            sum_out_bytes=sizes[agg.id] if agg is not None else None,
            batch_survives=_batch_survival(node, agg),
        )
    return plans


def input_pspecs(
    query: fra.Query,
    plans: Dict[int, JoinPlan],
    axis: Optional[str] = None,
) -> Dict[str, P]:
    """PartitionSpecs for the query's base relations implied by the plans
    — 2-D on a (data × model) geometry: the model axis on the shard dim,
    the (folded) data axes on the batch dim. ``axis`` overrides the model
    axis name (legacy callers); default is each plan's own.

    When a relation feeds multiple joins with conflicting specs the first
    (bottom-most) join wins — XLA resharding handles the rest."""
    specs: Dict[str, P] = {}

    def leaf_name(n) -> Optional[str]:
        if isinstance(n, fra.TableScan):
            return n.name
        if isinstance(n, fra.Const):
            return n.ref
        return None

    for node in query.root.topo():
        if not isinstance(node, fra.Join) or node.id not in plans:
            continue
        plan = plans[node.id]
        for side, child in (("left", node.left), ("right", node.right)):
            name = leaf_name(child)
            if name is None or name in specs:
                continue
            specs[name] = plan.pspec(side, child.key_arity, axis)
    return specs
