"""Distribution planner: the JAX analogue of the paper's claim that "the
database query optimizer will automatically distribute the computation,
taking into account the sizes of the two matrices" (§1).

For every Join in an FRA query the planner chooses, from relation sizes
and the mesh, between the paper's two physical plans:

  * BROADCAST the small side (the paper's data-parallel plan): the small
    relation is replicated (XLA: all-gather once), the big side stays
    partitioned on a non-contraction block axis; no output collective.
  * CO-PARTITION both sides on the join key (the paper's mixed
    data/model-parallel or tensor-parallel plan): both relations are
    sharded on the contraction block axis; the join-aggregate's Σ then
    requires an all-reduce (psum) of the output.

The decision is made statically (relation chunk-grid shapes are static at
trace time) with the same bytes-moved cost model a database optimizer
uses, and is *executed* by emitting PartitionSpecs for the relations'
block axes — the XLA SPMD partitioner then plays the role of the
database execution engine, inserting exactly the all-gather or
all-reduce the chosen plan implies.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from jax.sharding import PartitionSpec as P

from . import fra
from .keys import L, R, join_equiv_classes
from .relation import CooRelation, DenseRelation


@dataclass(frozen=True)
class JoinPlan:
    """Physical plan for one Join node."""

    kind: str                      # broadcast_left | broadcast_right | copartition
    node_id: int
    # estimated bytes moved per device for each candidate (the cost table)
    costs: Dict[str, float]
    # block-axis index carrying the mesh axis, per side (None = replicated)
    left_shard_dim: Optional[int]
    right_shard_dim: Optional[int]
    # does the plan end in an all-reduce of the join-agg output?
    needs_psum: bool

    def pspec(self, side: str, arity: int, axis: str = "model") -> P:
        dim = self.left_shard_dim if side == "left" else self.right_shard_dim
        spec = [None] * arity
        if dim is not None and dim < arity:
            spec[dim] = axis
        return P(*spec)


def _rel_bytes(rel) -> float:
    if isinstance(rel, DenseRelation):
        return float(rel.data.size * rel.data.dtype.itemsize)
    if isinstance(rel, CooRelation):
        return float(rel.values.size * rel.values.dtype.itemsize)
    # ShapeDtypeStruct-like estimate
    size = 1
    for d in rel.shape:
        size *= d
    return float(size * rel.dtype.itemsize)


def _contraction_dims(join: fra.Join) -> Tuple[Optional[int], Optional[int]]:
    """Joined-on block-key dims (left, right) — the contraction axes a
    co-partition plan shards. (The join-agg tree's Σ typically drops this
    key from the final output; whether it survives the join's own proj is
    irrelevant to the physical plan.)"""
    al = join.left.key_arity
    ar = join.right.key_arity
    uf = join_equiv_classes(join.pred, al, ar)
    for i in range(al):
        root = uf.find(L(i))
        for j in range(ar):
            if uf.find(R(j)) == root:
                return i, j
    return None, None


def _output_dims(join: fra.Join) -> Tuple[Optional[int], Optional[int]]:
    """First *non-contraction* block dim per side that survives into the
    output (for the broadcast plans: the kept side stays sharded on a dim
    requiring no collective — sharding the contraction dim would still
    force a psum)."""
    lc, rc = _contraction_dims(join)
    ldim = rdim = None
    for c in join.proj.comps:
        if isinstance(c, L) and ldim is None and c.idx != lc:
            ldim = c.idx
        if isinstance(c, R) and rdim is None and c.idx != rc:
            rdim = c.idx
    return ldim, rdim


DEFAULT_MEM_BUDGET = 8e9  # half a v5e chip's 16 GB HBM for one relation


def plan_join(
    join: fra.Join,
    left_bytes: float,
    right_bytes: float,
    out_bytes: float,
    n_devices: int,
    mem_budget: float = DEFAULT_MEM_BUDGET,
) -> JoinPlan:
    """Pick the cheapest *feasible* physical plan by bytes moved per
    device, exactly the way the paper describes the database optimizer
    (§1): broadcast requires the broadcast relation to be replicated on
    every node, so it is only feasible within the per-node memory budget;
    otherwise the relations are co-partitioned on the join key.

    all-gather of X over N devices moves ~X·(N-1)/N per device;
    a ring all-reduce of the output moves ~2·out·(N-1)/N.
    """
    frac = (n_devices - 1) / n_devices
    lc, rc = _contraction_dims(join)
    lo, ro = _output_dims(join)

    costs: Dict[str, float] = {}
    if left_bytes <= mem_budget:
        costs["broadcast_left"] = left_bytes * frac
    if right_bytes <= mem_budget:
        costs["broadcast_right"] = right_bytes * frac
    if lc is not None and rc is not None:
        # co-partition on the contraction key: inputs land pre-sharded
        # (no repartition cost for our static plans — parameters/data are
        # *created* in the planned layout), output needs the psum.
        costs["copartition"] = 2.0 * out_bytes * frac
    if not costs:
        raise ValueError(
            "no feasible plan: both sides exceed the memory budget and the "
            "join has no contraction key to co-partition on"
        )
    kind = min(costs, key=costs.get)

    if kind == "copartition":
        return JoinPlan(kind, join.id, costs, lc, rc, needs_psum=True)
    if kind == "broadcast_left":
        return JoinPlan(kind, join.id, costs, None, ro, needs_psum=False)
    return JoinPlan(kind, join.id, costs, lo, None, needs_psum=False)


def plan_query(
    query: fra.Query,
    env: Dict[str, object],
    n_devices: int,
    mem_budget: float = DEFAULT_MEM_BUDGET,
) -> Dict[int, JoinPlan]:
    """Walk the query graph, estimate relation sizes bottom-up, and emit a
    JoinPlan per Join node (keyed by node id)."""
    sizes: Dict[int, float] = {}
    plans: Dict[int, JoinPlan] = {}

    for node in query.root.topo():
        if isinstance(node, (fra.TableScan, fra.Const)):
            ref = node.name if isinstance(node, fra.TableScan) else node.ref
            if ref in env:
                sizes[node.id] = _rel_bytes(env[ref])
            else:  # unresolved (__seed/__fwd): assume small
                sizes[node.id] = 0.0
        elif isinstance(node, fra.Select):
            sizes[node.id] = sizes[node.child.id]
        elif isinstance(node, fra.Agg):
            # grouping reduces size by the dropped-key fraction; without
            # key-domain statistics assume a 1/8 reduction per dropped key
            child = sizes[node.child.id]
            dropped = max(0, node.child.key_arity - node.key_arity)
            sizes[node.id] = child / (8.0 ** dropped)
        elif isinstance(node, fra.Join):
            lb = sizes[node.left.id]
            rb = sizes[node.right.id]
            ob = max(lb, rb)  # join-agg output is at most the big side
            plans[node.id] = plan_join(node, lb, rb, ob, n_devices, mem_budget)
            sizes[node.id] = ob
        elif isinstance(node, (fra.AddOp, fra.Restrict)):
            sizes[node.id] = sizes[node.children[0].id]
    return plans


def input_pspecs(
    query: fra.Query,
    plans: Dict[int, JoinPlan],
    axis: str = "model",
) -> Dict[str, P]:
    """PartitionSpecs for the query's base relations implied by the plans.

    When a relation feeds multiple joins with conflicting specs the first
    (bottom-most) join wins — XLA resharding handles the rest."""
    specs: Dict[str, P] = {}

    def leaf_name(n) -> Optional[str]:
        if isinstance(n, fra.TableScan):
            return n.name
        if isinstance(n, fra.Const):
            return n.ref
        return None

    for node in query.root.topo():
        if not isinstance(node, fra.Join) or node.id not in plans:
            continue
        plan = plans[node.id]
        for side, child in (("left", node.left), ("right", node.right)):
            name = leaf_name(child)
            if name is None or name in specs:
                continue
            specs[name] = plan.pspec(side, child.key_arity, axis)
    return specs
