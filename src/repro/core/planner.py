"""Distribution planner: the JAX analogue of the paper's claim that "the
database query optimizer will automatically distribute the computation,
taking into account the sizes of the two matrices" (§1).

For every Join in an FRA query the planner chooses, from relation sizes
and the mesh, between the paper's two physical plans:

  * BROADCAST the small side (the paper's data-parallel plan): the small
    relation is replicated (XLA: all-gather once), the big side stays
    partitioned on a non-contraction block axis; no output collective.
  * CO-PARTITION both sides on the join key (the paper's mixed
    data/model-parallel or tensor-parallel plan): both relations are
    sharded on the contraction block axis; the join-aggregate's Σ then
    requires an all-reduce (psum) of the output.

On a 2-D (data × model) mesh — ``launch/mesh.make_host_mesh`` /
``make_production_mesh`` — the planner additionally chooses, per
relation, a *data-axis batch dimension*: the surviving non-contraction
block axis of (usually) the batch-keyed relation is sharded over the
mesh's batch axes (``("pod", "data")`` folded on the multi-pod mesh),
the other side is replicated over them, and the Σ of the join-aggregate
pays a data-axis all-reduce whenever the grouping drops the batch key.
Both placements use the same bytes-moved cost model; a 1-axis mesh
degrades to exactly the historical 1-D plans.

The decision is made statically (relation chunk-grid shapes are static at
trace time) with the same bytes-moved cost model a database optimizer
uses, and is *executed* by emitting PartitionSpecs for the relations'
block axes — the XLA SPMD partitioner then plays the role of the
database execution engine, inserting exactly the all-gather or
all-reduce the chosen plan implies.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from jax.sharding import PartitionSpec as P

from . import fra
from .keys import In, L, R, join_equiv_classes
from .relation import CooRelation, DenseRelation

#: mesh axes treated as data-parallel (batch) axes, in fold order — the
#: multi-pod production mesh folds ("pod", "data") onto one relation dim.
DATA_AXIS_NAMES = ("pod", "data")

#: fallback edge-cut estimate for the Σ-over-COO scatter when the edge
#: relation is owner-partitioned on the Σ's segment key
#: (relation.owner_partition) but no tracked statistics are available:
#: each shard then owns a contiguous segment range, so only boundary-
#: crossing contributions move. With a catalog (core/session.py) the
#: planner replaces this constant by a measured fraction derived from the
#: relation's distinct-owner-key count; 1/8 mirrors the legacy
#: per-dropped-key Agg heuristic and is kept as the stats-less fallback.
EDGE_CUT_LOCAL = 0.125

#: equi-width buckets per key column in ``RelationStats.hist`` (see
#: ``relation.measure_stats``) — coarse on purpose: the histograms only
#: feed the rewrite stage's join output-size estimate, and a snapshot of
#: them rides in the lowering cache key.
HIST_BUCKETS = 8


@dataclass(frozen=True)
class RelationStats:
    """Tracked key-domain statistics for one relation — what a database
    catalog stores and the optimizer consults per query. Produced by
    ``relation.measure_stats`` (refreshed on ``Database.put``), consumed
    by ``plan_query(stats=...)``:

    * ``distinct`` — distinct key values per key column. Replaces the
      1/8-per-dropped-key Agg output estimate (a Σ dropping key column
      ``i`` reduces the child by ``distinct[i]``) and prices the
      Σ-over-COO scatter's edge cut from the owner column's real domain.
    * ``extents`` — declared key-domain extents per key column (the
      dense grid shape / COO extents).
    * ``nnz`` — live (non-padded) tuple count; for a DenseRelation this
      is the full grid size.
    * ``density`` — ``nnz / prod(extents)``; 1.0 for dense grids.
    * ``hist`` — optional per-key-column equi-width histograms
      (``HIST_BUCKETS`` tuple counts over ``[0, extents[i])``), refreshed
      on ``Database.put``. The rewrite stage's cost gate overlaps two
      columns' histograms to sharpen the join output-size estimate that
      decides a Σ-pushdown; ``None`` falls back to the extent/distinct
      heuristics, bit-identically to a stats-less plan.

    Frozen and tuple-valued so a stats snapshot is hashable — it is part
    of the ``Lowered.compile`` cache key."""

    distinct: Tuple[int, ...]
    extents: Tuple[int, ...]
    nnz: int
    density: float = 1.0
    hist: Optional[Tuple[Tuple[int, ...], ...]] = None

    def quantized(self) -> "RelationStats":
        """Counts bucketed to powers of two (extents kept exact) — the
        form compile cache *keys* use, so per-batch statistics jitter
        (e.g. a re-sampled edge set whose distinct counts wobble a few
        percent) does not re-plan and re-jit every step. Planning itself
        always uses the raw statistics; only key identity is coarse."""

        def q(x: int) -> int:
            x = int(x)
            return x if x <= 1 else 1 << (x - 1).bit_length()

        nnz = q(self.nnz)
        size = 1
        for e in self.extents:
            size *= int(e)
        return RelationStats(
            distinct=tuple(q(d) for d in self.distinct),
            extents=self.extents,
            nnz=nnz,
            density=(nnz / size) if size else 0.0,
            hist=(
                tuple(tuple(q(c) for c in col) for col in self.hist)
                if self.hist is not None
                else None
            ),
        )

    def edge_cut(self, owner_dim: int, num_shards: int) -> float:
        """Estimated non-local fraction of an owner-partitioned Σ-scatter
        over ``num_shards`` data shards: each shard owns a contiguous
        range of the ``distinct[owner_dim]`` segment keys, so only the
        ≤ ``num_shards - 1`` boundary-straddling segments move. A skewed
        (small) owner domain pushes this toward the full scatter."""
        if num_shards <= 1:
            return 0.0
        owners = max(1, int(self.distinct[owner_dim]))
        return min(1.0, float(num_shards - 1) / float(owners))


def fold_axes(axes: Tuple[str, ...]):
    """PartitionSpec entry for a dim carrying ``axes``: the folded tuple,
    a single axis name, or None — the one place the fold rule lives."""
    if not axes:
        return None
    return axes if len(axes) > 1 else axes[0]


@dataclass(frozen=True)
class MeshGeometry:
    """Static description of the mesh the planner plans for: one
    tensor-parallel (model) axis plus zero or more folded data axes.

    ``from_mesh`` derives it from a real ``jax.sharding.Mesh``;
    ``single`` is the legacy 1-D geometry (model axis only) used when the
    caller only knows a device count."""

    model_axis: str
    model_size: int
    data_axes: Tuple[str, ...] = ()
    data_size: int = 1

    @classmethod
    def single(cls, n_devices: int, axis: str = "model") -> "MeshGeometry":
        return cls(axis, max(1, int(n_devices or 1)))

    @classmethod
    def from_mesh(cls, mesh, axis: Optional[str] = None) -> "MeshGeometry":
        """Read the (data × model) geometry off a jax Mesh: ``axis`` (or
        ``"model"``) is the tensor-parallel axis — on a 1-axis mesh the
        sole axis plays that role, reproducing the 1-D plans — and every
        ``DATA_AXIS_NAMES`` axis present is folded into the batch pair."""
        names = tuple(mesh.axis_names)
        sizes = dict(mesh.shape)
        if axis is not None:
            if axis not in names:
                raise ValueError(
                    f"model axis {axis!r} is not on the mesh (axes: {names})"
                )
            model = axis
        elif "model" in names:
            model = "model"
        elif len(names) == 1:
            model = names[0]
        else:
            raise ValueError(
                f"cannot infer the model axis of a multi-axis mesh with no "
                f"'model' axis (axes: {names}); pass axis= explicitly"
            )
        data_axes = tuple(
            a for a in DATA_AXIS_NAMES if a in names and a != model
        )
        data_size = 1
        for a in data_axes:
            data_size *= int(sizes[a])
        return cls(model, int(sizes[model]), data_axes, data_size)

    @property
    def data_spec(self):
        """PartitionSpec entry for a data-sharded dim: the folded axis
        tuple, or the single axis name."""
        return fold_axes(self.data_axes)


@dataclass(frozen=True)
class JoinPlan:
    """Physical plan for one Join node."""

    kind: str                      # broadcast_left | broadcast_right | copartition
    node_id: int
    # estimated bytes moved per device for each candidate (the cost table;
    # 2-D plans add the data-axis candidates under "data:*" keys)
    costs: Dict[str, float]
    # block-axis index carrying the model axis, per side (None = replicated)
    left_shard_dim: Optional[int]
    right_shard_dim: Optional[int]
    # does the plan end in a model-axis all-reduce of the join-agg output?
    needs_psum: bool
    # block-axis index carrying the data (batch) axes, per side
    left_batch_dim: Optional[int] = None
    right_batch_dim: Optional[int] = None
    # the mesh axes the dims above refer to
    model_axis: str = "model"
    data_axes: Tuple[str, ...] = ()
    # chosen data-axis placement: none | data:shard_left | data:shard_right
    #            | data:replicate | data:shard_nnz_left | data:shard_nnz_right
    data_kind: str = "none"
    # does the Σ reduce the data-sharded batch key (data-axis all-reduce),
    # or scatter a data-sharded nnz axis into segments (psum_scatter)?
    needs_data_psum: bool = False
    # which side is a CooRelation (nnz-row layout, no shardable key dims)
    coo_sides: Tuple[bool, bool] = (False, False)

    def nnz_sharded(self, side: str) -> bool:
        """Did the data axes land on ``side``'s COO nnz row dimension?"""
        return self.data_kind == f"data:shard_nnz_{side}"

    def pspec(self, side: str, arity: int, axis: Optional[str] = None) -> P:
        if self.coo_sides[0 if side == "left" else 1]:
            # COO payloads have one shardable axis: the nnz row dim.
            if self.nnz_sharded(side) and self.data_axes:
                return P(fold_axes(self.data_axes))
            return P()
        dim = self.left_shard_dim if side == "left" else self.right_shard_dim
        bdim = (
            self.left_batch_dim if side == "left" else self.right_batch_dim
        )
        spec: list = [None] * arity
        if dim is not None and dim < arity:
            spec[dim] = axis or self.model_axis
        if bdim is not None and bdim < arity and self.data_axes:
            spec[bdim] = fold_axes(self.data_axes)
        return P(*spec)

def _rel_bytes(rel) -> float:
    if isinstance(rel, DenseRelation):
        return float(rel.data.size * rel.data.dtype.itemsize)
    if isinstance(rel, CooRelation):
        # keys move with the values under every placement of the nnz axis
        return float(
            rel.values.size * rel.values.dtype.itemsize
            + rel.keys.size * rel.keys.dtype.itemsize
        )
    # ShapeDtypeStruct-like estimate
    size = 1
    for d in rel.shape:
        size *= d
    return float(size * rel.dtype.itemsize)


def _contraction_dims(join: fra.Join) -> Tuple[Optional[int], Optional[int]]:
    """Joined-on block-key dims (left, right) — the contraction axes a
    co-partition plan shards. (The join-agg tree's Σ typically drops this
    key from the final output; whether it survives the join's own proj is
    irrelevant to the physical plan.)"""
    al = join.left.key_arity
    ar = join.right.key_arity
    uf = join_equiv_classes(join.pred, al, ar)
    for i in range(al):
        root = uf.find(L(i))
        for j in range(ar):
            if uf.find(R(j)) == root:
                return i, j
    return None, None


def _output_dims(join: fra.Join) -> Tuple[Optional[int], Optional[int]]:
    """First *non-contraction* block dim per side that survives into the
    output (for the broadcast plans: the kept side stays sharded on a dim
    requiring no collective — sharding the contraction dim would still
    force a psum). On a 2-D mesh this is also each side's candidate batch
    dim for the data axes."""
    lc, rc = _contraction_dims(join)
    ldim = rdim = None
    for c in join.proj.comps:
        if isinstance(c, L) and ldim is None and c.idx != lc:
            ldim = c.idx
        if isinstance(c, R) and rdim is None and c.idx != rc:
            rdim = c.idx
    return ldim, rdim


DEFAULT_MEM_BUDGET = 8e9  # half a v5e chip's 16 GB HBM for one relation


def plan_join(
    join: fra.Join,
    left_bytes: float,
    right_bytes: float,
    out_bytes: float,
    n_devices: int,
    mem_budget: float = DEFAULT_MEM_BUDGET,
    *,
    geometry: Optional[MeshGeometry] = None,
    sum_out_bytes: Optional[float] = None,
    batch_survives: Tuple[bool, bool] = (True, True),
    coo_sides: Tuple[bool, bool] = (False, False),
    coo_local: Tuple[bool, bool] = (False, False),
    committed_dims: Tuple[Optional[Dict], Optional[Dict]] = (None, None),
    coo_edge_cut: Tuple[Optional[float], Optional[float]] = (None, None),
    sum_out_stat: bool = False,
) -> JoinPlan:
    """Pick the cheapest *feasible* physical plan by bytes moved per
    device, exactly the way the paper describes the database optimizer
    (§1): broadcast requires the broadcast relation to be replicated on
    every node, so it is only feasible within the per-node memory budget;
    otherwise the relations are co-partitioned on the join key.

    all-gather of X over N devices moves ~X·(N-1)/N per device;
    a ring all-reduce of the output moves ~2·out·(N-1)/N.

    ``geometry`` extends the decision to a 2-D (data × model) mesh: the
    data axes are placed first — shard one side's surviving batch dim
    (replicating the other side over the data axes) or replicate both —
    and the model axis then avoids the batch dim. ``sum_out_bytes`` is
    the post-Σ output estimate the all-reduce costs use on the 2-D path;
    ``batch_survives`` says, per side, whether the batch dim survives the
    enclosing grouping (a dropped batch key costs a data-axis all-reduce
    of the Σ output). A 1-axis geometry reproduces the historical 1-D
    plans bit-for-bit.

    ``coo_sides`` marks CooRelation sides. A COO side has no block axes —
    its one shardable axis is the physical nnz row dim, which only the
    data axes may take (``data:shard_nnz_*``): the dense side is
    replicated over them and the enclosing Σ pays a **psum_scatter** of
    the segment grid, priced at the edge-cut estimate — ``EDGE_CUT_LOCAL``
    when ``coo_local`` says the relation is owner-partitioned on the Σ's
    segment key, the full scatter otherwise. The model axis never takes
    nnz rows: a COO side is replicated over it, and a co-partition plan
    key-shards only the dense side (the one model-axis plan that keeps an
    over-budget dense grid partitioned, matching the 1-D planner).

    ``committed_dims`` folds the device-layout rechunk cost in: per side,
    the ``{"data": dim, "model": dim}`` placement the input is *known* to
    be committed to (None = unknown). A candidate that wants a side
    pre-sharded on a different dim pays that side's all-to-all, instead
    of ``Compiled.__call__`` paying it silently per step.

    ``coo_edge_cut`` overrides the scatter's edge-cut *fraction* per COO
    side with a catalog-derived estimate (``RelationStats.edge_cut``);
    ``None`` falls back to the stats-less heuristic (``EDGE_CUT_LOCAL``
    when ``coo_local``, the full scatter otherwise). ``sum_out_stat``
    marks ``sum_out_bytes`` as catalog-backed: the defensive dense-side
    cap on the segment-grid estimate is then skipped — the statistics
    already bound the Σ output by the real key domain.
    """
    geo = geometry or MeshGeometry.single(n_devices)
    n_model = max(1, geo.model_size)
    frac_m = (n_model - 1) / n_model
    two_d = geo.data_size > 1
    lc, rc = _contraction_dims(join)
    lo, ro = _output_dims(join)
    coo_l, coo_r = coo_sides
    cdim_l, cdim_r = committed_dims

    def _move(cdims, axis_kind, required, bytes_, frac):
        """Rechunk fold: a candidate expecting a side pre-sharded on
        ``required`` while it is committed sharded on a *different* dim
        pays the all-to-all. Replication candidates charge their
        all-gather in the base cost already (``required=None`` never
        adds), and an input committed replicated on this axis shards by a
        zero-communication local slice (``committed None`` never adds)."""
        if cdims is None or required is None or frac <= 0.0:
            return 0.0
        cur = cdims.get(axis_kind)
        if cur is None:
            return 0.0
        return bytes_ * frac if cur != required else 0.0

    costs: Dict[str, float] = {}

    # --- data axes: shard a batch dim / the COO nnz dim, or replicate ----
    left_batch = right_batch = None
    data_kind = "none"
    needs_data_psum = False
    if two_d:
        frac_d = (geo.data_size - 1) / geo.data_size
        sum_out = out_bytes if sum_out_bytes is None else sum_out_bytes

        def _scatter(dense_bytes: float, local: bool, cut: Optional[float]) -> float:
            """psum_scatter of the Σ-over-COO segment grid. Without an
            enclosing Σ the output stays nnz-aligned (no collective). A
            stats-backed ``sum_out`` is trusted as-is; the heuristic one
            is bounded by the gathered dense side, which caps the
            post-Agg guess. ``cut`` is the catalog edge-cut fraction,
            falling back to the EDGE_CUT_LOCAL constant."""
            if sum_out_bytes is None:
                return 0.0
            if sum_out_stat:
                est = sum_out
            else:
                est = min(sum_out, dense_bytes) if dense_bytes > 0 else sum_out
            if cut is None:
                cut = EDGE_CUT_LOCAL if local else 1.0
            return est * frac_d * cut

        # feasibility mirrors the model axis: a candidate must fit every
        # relation it replicates within the per-device budget
        dcosts: Dict[str, float] = {}
        if left_bytes <= mem_budget and right_bytes <= mem_budget:
            # no batch parallelism: both inputs replicated over the axes
            dcosts["data:replicate"] = (left_bytes + right_bytes) * frac_d
        if coo_l:
            if right_bytes <= mem_budget:
                dcosts["data:shard_nnz_left"] = (
                    right_bytes * frac_d
                    + _scatter(right_bytes, coo_local[0], coo_edge_cut[0])
                    + _move(cdim_l, "data", 0, left_bytes, frac_d)
                )
        elif lo is not None and right_bytes <= mem_budget:
            dcosts["data:shard_left"] = (
                right_bytes * frac_d
                + (0.0 if batch_survives[0] else 2.0 * sum_out * frac_d)
                + _move(cdim_l, "data", lo, left_bytes, frac_d)
            )
        if coo_r:
            if left_bytes <= mem_budget:
                dcosts["data:shard_nnz_right"] = (
                    left_bytes * frac_d
                    + _scatter(left_bytes, coo_local[1], coo_edge_cut[1])
                    + _move(cdim_r, "data", 0, right_bytes, frac_d)
                )
        elif ro is not None and left_bytes <= mem_budget:
            dcosts["data:shard_right"] = (
                left_bytes * frac_d
                + (0.0 if batch_survives[1] else 2.0 * sum_out * frac_d)
                + _move(cdim_r, "data", ro, right_bytes, frac_d)
            )
        if not dcosts:
            # nothing feasible (e.g. both sides over budget): best effort —
            # keep the partitionable side partitioned (a COO's nnz rows
            # beat a dense batch dim: that is the only placement that can
            # ever fit a beyond-memory edge relation), else replicate
            if coo_l:
                dcosts["data:shard_nnz_left"] = (
                    right_bytes * frac_d + _scatter(right_bytes, coo_local[0], coo_edge_cut[0])
                )
            elif coo_r:
                dcosts["data:shard_nnz_right"] = (
                    left_bytes * frac_d + _scatter(left_bytes, coo_local[1], coo_edge_cut[1])
                )
            elif lo is not None:
                dcosts["data:shard_left"] = right_bytes * frac_d
            elif ro is not None:
                dcosts["data:shard_right"] = left_bytes * frac_d
            else:
                dcosts["data:replicate"] = (left_bytes + right_bytes) * frac_d
        data_kind = min(dcosts, key=dcosts.get)
        costs.update(dcosts)
        if data_kind == "data:shard_left":
            left_batch = lo
            needs_data_psum = not batch_survives[0]
        elif data_kind == "data:shard_right":
            right_batch = ro
            needs_data_psum = not batch_survives[1]
        elif data_kind.startswith("data:shard_nnz"):
            # the Σ over the sharded nnz rows always scatters into the
            # (replicated) segment grid: that IS the planned collective
            needs_data_psum = sum_out_bytes is not None

    # --- model axis: broadcast vs co-partition, avoiding the batch dims --
    # The kept side of a broadcast plan stays sharded on a surviving dim;
    # if the data axes already took that dim, the model axis would sit
    # idle and the "broadcast" degenerates to replicating *both* sides —
    # charge it as such (2-D path only; 1-D keeps the historical costs).
    # A COO side has no key dims at all: it behaves like a dim-less side.
    lo_m = None if coo_l or (lo is not None and lo == left_batch) else lo
    ro_m = None if coo_r or (ro is not None and ro == right_batch) else ro
    mcosts: Dict[str, float] = {}
    if left_bytes <= mem_budget:
        c = left_bytes * frac_m
        if two_d and ro_m is None:
            c += right_bytes * frac_m
        c += _move(cdim_r, "model", ro_m, right_bytes, frac_m)
        mcosts["broadcast_left"] = c
    if right_bytes <= mem_budget:
        c = right_bytes * frac_m
        if two_d and lo_m is None:
            c += left_bytes * frac_m
        c += _move(cdim_l, "model", lo_m, left_bytes, frac_m)
        mcosts["broadcast_right"] = c
    if lc is not None and rc is not None and not (coo_l and coo_r):
        # co-partition on the contraction key: inputs land pre-sharded
        # (no repartition cost for our static plans — parameters/data are
        # *created* in the planned layout, and committed_dims charges the
        # all-to-all when the caller knows otherwise), output needs the
        # psum. The 2-D path prices the psum at the post-Σ output size.
        # With one COO side only the dense side is key-sharded (nnz rows
        # carry no key dims; the gather against the sharded grid pays its
        # collective via XLA) — still the one model-axis plan that keeps
        # an over-budget dense side partitioned, as in the 1-D planner.
        psum_out = sum_out if two_d and sum_out_bytes is not None else out_bytes
        mcosts["copartition"] = (
            2.0 * psum_out * frac_m
            + _move(cdim_l, "model", None if coo_l else lc, left_bytes, frac_m)
            + _move(cdim_r, "model", None if coo_r else rc, right_bytes, frac_m)
        )
    if not mcosts:
        if coo_l or coo_r:
            # COO ⋈ COO has no key-shardable side at all; best effort:
            # replicate both over the model axis
            kind = "broadcast_left" if coo_l else "broadcast_right"
            mcosts[kind] = (left_bytes + right_bytes) * frac_m
        else:
            raise ValueError(
                "no feasible plan: both sides exceed the memory budget and "
                "the join has no contraction key to co-partition on"
            )
    kind = min(mcosts, key=mcosts.get)
    costs.update(mcosts)

    common = dict(
        left_batch_dim=left_batch,
        right_batch_dim=right_batch,
        model_axis=geo.model_axis,
        data_axes=geo.data_axes,
        data_kind=data_kind,
        needs_data_psum=needs_data_psum,
        coo_sides=coo_sides,
    )
    if kind == "copartition":
        return JoinPlan(
            kind,
            join.id,
            costs,
            None if coo_l else lc,
            None if coo_r else rc,
            needs_psum=True,
            **common,
        )
    if kind == "broadcast_left":
        return JoinPlan(kind, join.id, costs, None, ro_m, needs_psum=False, **common)
    return JoinPlan(kind, join.id, costs, lo_m, None, needs_psum=False, **common)


def _batch_survival(
    join: fra.Join, agg: Optional[fra.Agg]
) -> Tuple[bool, bool]:
    """Does each side's batch dim survive the enclosing Σ's grouping?
    Dropped batch keys cost a data-axis all-reduce of the Σ output."""
    lo, ro = _output_dims(join)

    def survives(comp) -> bool:
        if comp is None or agg is None:
            return True
        try:
            pos = join.proj.comps.index(comp)
        except ValueError:
            return True
        return any(
            isinstance(c, In) and c.idx == pos for c in agg.grp.comps
        )

    return (
        survives(None if lo is None else L(lo)),
        survives(None if ro is None else R(ro)),
    )


def _coo_owner_survives(
    join: fra.Join, agg: Optional[fra.Agg], side: str, owner_dim: Optional[int]
) -> bool:
    """Is the COO side's owner-partition column the enclosing Σ's segment
    key? Then the scatter is local except at shard-boundary segments and
    the planner prices it at ``EDGE_CUT_LOCAL``."""
    if agg is None or owner_dim is None:
        return False
    comp = L(owner_dim) if side == "left" else R(owner_dim)
    try:
        pos = join.proj.comps.index(comp)
    except ValueError:
        return False
    return any(isinstance(c, In) and c.idx == pos for c in agg.grp.comps)


def _leaf_name(n) -> Optional[str]:
    """Base-relation name of a leaf node (TableScan/Const), else None."""
    if isinstance(n, fra.TableScan):
        return n.name
    if isinstance(n, fra.Const):
        return n.ref
    return None


def _spec_dims(spec, geo: MeshGeometry) -> Optional[Dict[str, Optional[int]]]:
    """Parse a committed PartitionSpec into the ``{"data": dim, "model":
    dim}`` placement the rechunk fold compares against."""
    if spec is None:
        return None
    model = data = None
    for d, entry in enumerate(tuple(spec)):
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        if geo.model_axis in axes:
            model = d
        if any(a in geo.data_axes for a in axes):
            data = d
    return {"model": model, "data": data}


@dataclass
class GraphEstimate:
    """Bottom-up size/statistics estimates over one FRA graph — the walk
    ``plan_query`` prices joins with, extracted so the rewrite stage
    (``core/rewrite.py``) gates its rules on the *same* numbers the
    planner would later see. All maps are keyed by node id.

    * ``sizes`` — estimated bytes per node (join-agg semantics: a Join is
      at most its big side, a Σ divides its child by the dropped keys'
      measured domains or the 1/8-per-key fallback).
    * ``is_coo`` — whether the node's subtree is COO-keyed.
    * ``dist`` — per key position, estimated distinct values (None = no
      statistics reached this node / position).
    * ``hists`` — per key position, the equi-width histogram propagated
      from ``RelationStats.hist`` (None wherever unavailable); only the
      rewrite gate consumes these.
    * ``stat_aggs`` — Agg node ids whose size came from statistics.
    * ``agg_of`` — Join id → the Agg sitting directly above it.
    * ``joins`` — Join nodes in topo (leaves-first) order.
    """

    sizes: Dict[int, float]
    is_coo: Dict[int, bool]
    dist: Dict[int, Optional[Tuple[Optional[float], ...]]]
    hists: Dict[int, Optional[Tuple[Optional[Tuple[int, ...]], ...]]]
    stat_aggs: set
    agg_of: Dict[int, "fra.Agg"]
    joins: List["fra.Join"]


def agg_shrink(
    child_arity: int,
    grp,
    child_dist: Optional[Tuple[Optional[float], ...]],
) -> Tuple[float, bool]:
    """The Σ output-size rule shared by ``estimate_graph`` and the
    rewrite cost gate: ``(shrink_factor, from_stats)`` such that the Agg
    output is ``child_bytes / shrink_factor``. With statistics covering
    every dropped key position the factor is the product of their
    measured domains; otherwise the flat 1/8-per-dropped-key fallback."""
    kept = {c.idx for c in grp.comps if isinstance(c, In)}
    dropped_pos = [i for i in range(child_arity) if i not in kept]
    if (
        child_dist is not None
        and dropped_pos
        and all(child_dist[i] is not None for i in dropped_pos)
    ):
        factor = 1.0
        for i in dropped_pos:
            factor *= max(1.0, float(child_dist[i]))
        return factor, True
    return 8.0 ** len(dropped_pos), False


def estimate_graph(
    root: fra.Node,
    env: Dict[str, object],
    stats: Optional[Dict[str, RelationStats]] = None,
) -> GraphEstimate:
    """Walk ``root`` leaves-first and estimate per-node sizes, COO-ness,
    and (with a catalog snapshot) distinct counts and histograms. This is
    the cost model both ``plan_query`` and the rewrite stage's gate read;
    stats-less calls reproduce the legacy heuristics bit-for-bit."""
    sizes: Dict[int, float] = {}
    is_coo: Dict[int, bool] = {}
    agg_of: Dict[int, fra.Agg] = {}
    joins: List[fra.Join] = []
    dist: Dict[int, Optional[Tuple[Optional[float], ...]]] = {}
    hists: Dict[int, Optional[Tuple[Optional[Tuple[int, ...]], ...]]] = {}
    stat_aggs: set = set()

    for node in root.topo():
        hists[node.id] = None
        if isinstance(node, (fra.TableScan, fra.Const)):
            ref = node.name if isinstance(node, fra.TableScan) else node.ref
            if ref in env:
                sizes[node.id] = _rel_bytes(env[ref])
                is_coo[node.id] = isinstance(env[ref], CooRelation)
            else:  # unresolved (__seed/__fwd): assume small
                sizes[node.id] = 0.0
                is_coo[node.id] = False
            st = stats.get(ref) if stats else None
            dist[node.id] = (
                tuple(float(d) for d in st.distinct) if st is not None else None
            )
            if st is not None and st.hist is not None:
                hists[node.id] = tuple(st.hist)
        elif isinstance(node, fra.Select):
            sizes[node.id] = sizes[node.child.id]
            is_coo[node.id] = is_coo[node.child.id]
            cd = dist.get(node.child.id)
            dist[node.id] = (
                tuple(
                    cd[c.idx] if isinstance(c, In) else None
                    for c in node.proj.comps
                )
                if cd is not None
                else None
            )
            ch = hists.get(node.child.id)
            if ch is not None:
                hists[node.id] = tuple(
                    ch[c.idx] if isinstance(c, In) else None
                    for c in node.proj.comps
                )
        elif isinstance(node, fra.Agg):
            child = sizes[node.child.id]
            cd = dist.get(node.child.id)
            factor, from_stats = agg_shrink(node.child.key_arity, node.grp, cd)
            if from_stats:
                # catalog statistics: a Σ dropping key position i merges
                # its distinct[i] values into one group — the measured
                # replacement for the flat 1/8-per-dropped-key guess
                sizes[node.id] = child / factor
                stat_aggs.add(node.id)
                dist[node.id] = tuple(
                    cd[c.idx] if isinstance(c, In) else None
                    for c in node.grp.comps
                )
            else:
                # no statistics: assume a 1/8 reduction per dropped key
                sizes[node.id] = child / factor
                dist[node.id] = None
            # grouping rescales bucket counts unpredictably: drop hists
            is_coo[node.id] = False  # Σ over COO materializes the grid
            if isinstance(node.child, fra.Join):
                agg_of[node.child.id] = node
        elif isinstance(node, fra.Join):
            joins.append(node)
            sizes[node.id] = max(
                sizes[node.left.id], sizes[node.right.id]
            )  # join-agg output is at most the big side
            is_coo[node.id] = (
                is_coo[node.left.id] or is_coo[node.right.id]
            )  # the gather join keeps the COO key set
            ld, rd = dist.get(node.left.id), dist.get(node.right.id)
            comps_dist: List[Optional[float]] = []
            for c in node.proj.comps:
                if isinstance(c, L) and ld is not None:
                    comps_dist.append(ld[c.idx])
                elif isinstance(c, R) and rd is not None:
                    comps_dist.append(rd[c.idx])
                else:
                    comps_dist.append(None)
            dist[node.id] = tuple(comps_dist)
            lh, rh = hists.get(node.left.id), hists.get(node.right.id)
            if lh is not None or rh is not None:
                hists[node.id] = tuple(
                    lh[c.idx] if isinstance(c, L) and lh is not None
                    else rh[c.idx] if isinstance(c, R) and rh is not None
                    else None
                    for c in node.proj.comps
                )
        elif isinstance(node, fra.Restrict):
            sizes[node.id] = sizes[node.children[0].id]
            is_coo[node.id] = is_coo[node.ref.id]
            # restricted to the ref's key set: its statistics apply
            dist[node.id] = dist.get(node.ref.id) or dist.get(node.child.id)
        elif isinstance(node, fra.AddOp):
            sizes[node.id] = sizes[node.children[0].id]
            is_coo[node.id] = is_coo[node.left.id] and is_coo[node.right.id]
            dist[node.id] = dist.get(node.left.id) or dist.get(node.right.id)

    return GraphEstimate(sizes, is_coo, dist, hists, stat_aggs, agg_of, joins)


def plan_query(
    query: fra.Query,
    env: Dict[str, object],
    n_devices: int,
    mem_budget: float = DEFAULT_MEM_BUDGET,
    *,
    geometry: Optional[MeshGeometry] = None,
    committed: Optional[Dict[str, P]] = None,
    stats: Optional[Dict[str, RelationStats]] = None,
) -> Dict[int, JoinPlan]:
    """Walk the query graph, estimate relation sizes bottom-up, and emit a
    JoinPlan per Join node (keyed by node id). ``geometry`` plans for a
    2-D (data × model) mesh (see ``MeshGeometry.from_mesh``); omitted, it
    is the legacy 1-D model-axis-only geometry over ``n_devices``.

    CooRelation leaves are planned for real: the walk tracks which
    subtrees are COO-keyed, and ``plan_join`` may place a join's COO nnz
    rows on the data axes (``data:shard_nnz_*``), costing the Σ's
    psum_scatter at the owner-partition edge-cut estimate.

    ``committed`` maps base-relation names to the PartitionSpec their
    arrays are already committed to (see ``engine._committed_layouts``);
    candidates that would force a device-layout rechunk then pay the
    all-to-all in the cost table instead of hiding it in
    ``Compiled.__call__``'s device_put.

    ``stats`` maps base-relation names to tracked ``RelationStats`` (the
    catalog snapshot — ``Database.catalog.snapshot()``). When present,
    per-key distinct counts are propagated through the graph and replace
    three heuristics: a Σ's output size divides the child by the dropped
    keys' *measured* domains (not a flat 1/8 per key), the Σ-over-COO
    scatter's edge cut is priced from the owner column's distinct count
    (not the ``EDGE_CUT_LOCAL`` constant), and the stats-backed Σ output
    estimate is trusted without the defensive dense-side cap. Relations
    missing from ``stats`` fall back to the old heuristics, so a
    stats-less call plans bit-identically to earlier releases."""
    geo = geometry or MeshGeometry.single(n_devices)
    est = estimate_graph(query.root, env, stats)
    sizes = est.sizes
    is_coo = est.is_coo
    agg_of = est.agg_of
    joins = est.joins
    stat_aggs = est.stat_aggs

    def owner_dim_of(n) -> Optional[int]:
        name = _leaf_name(n)
        rel = env.get(name) if name is not None else None
        return rel.owner_dim if isinstance(rel, CooRelation) else None

    def edge_cut_of(n, side: str, join: fra.Join, agg) -> Optional[float]:
        """Catalog edge-cut fraction for a COO side's Σ-scatter, or None
        to fall back to the EDGE_CUT_LOCAL/full-scatter heuristic."""
        name = _leaf_name(n)
        st = stats.get(name) if stats and name is not None else None
        rel = env.get(name) if name is not None else None
        if st is None or not isinstance(rel, CooRelation):
            return None
        od = rel.owner_dim
        if od is None or not _coo_owner_survives(join, agg, side, od):
            return None
        return st.edge_cut(od, geo.data_size)

    def committed_of(n) -> Optional[Dict[str, Optional[int]]]:
        if not committed:
            return None
        name = _leaf_name(n)
        if name is None or name not in committed:
            return None
        return _spec_dims(committed[name], geo)

    plans: Dict[int, JoinPlan] = {}
    for node in joins:
        lb = sizes[node.left.id]
        rb = sizes[node.right.id]
        ob = sizes[node.id]
        agg = agg_of.get(node.id)
        coo_sides = (is_coo[node.left.id], is_coo[node.right.id])
        plans[node.id] = plan_join(
            node,
            lb,
            rb,
            ob,
            geo.model_size,
            mem_budget,
            geometry=geo,
            sum_out_bytes=sizes[agg.id] if agg is not None else None,
            batch_survives=_batch_survival(node, agg),
            coo_sides=coo_sides,
            coo_local=(
                _coo_owner_survives(node, agg, "left", owner_dim_of(node.left)),
                _coo_owner_survives(node, agg, "right", owner_dim_of(node.right)),
            ),
            committed_dims=(committed_of(node.left), committed_of(node.right)),
            coo_edge_cut=(
                edge_cut_of(node.left, "left", node, agg),
                edge_cut_of(node.right, "right", node, agg),
            ),
            sum_out_stat=agg is not None and agg.id in stat_aggs,
        )
    return plans


def input_pspecs(
    query: fra.Query,
    plans: Dict[int, JoinPlan],
    axis: Optional[str] = None,
) -> Dict[str, P]:
    """PartitionSpecs for the query's base relations implied by the plans
    — 2-D on a (data × model) geometry: the model axis on the shard dim,
    the (folded) data axes on the batch dim. ``axis`` overrides the model
    axis name (legacy callers); default is each plan's own.

    When a relation feeds multiple joins with conflicting specs the first
    (bottom-most) join wins — XLA resharding handles the rest."""
    specs: Dict[str, P] = {}

    for node in query.root.topo():
        if not isinstance(node, fra.Join) or node.id not in plans:
            continue
        plan = plans[node.id]
        for side, child in (("left", node.left), ("right", node.right)):
            name = _leaf_name(child)
            if name is None or name in specs:
                continue
            specs[name] = plan.pspec(side, child.key_arity, axis)
    return specs


# ---------------------------------------------------------------------------
# Out-of-core wave planning: stream one relation through the step in chunks
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class WavePlan:
    """Decision record of ``plan_waves``: stream ``stream`` (and slice the
    dense ``co_streams`` with the same row boundaries) through the compiled
    step in ``num_waves`` host→device waves under ``budget`` bytes of
    device memory. ``axis_of`` maps each streamed dense relation to the
    key dim being sliced; the primary stream's manifest carries the cut
    vector (owner-aligned for owner-partitioned COO streams)."""

    stream: str
    co_streams: Tuple[str, ...]
    num_waves: int
    boundaries: Tuple[int, ...]
    axis_of: Tuple[Tuple[str, int], ...]
    owner_aligned: bool
    budget: float

    @property
    def streamed_names(self) -> Tuple[str, ...]:
        return (self.stream,) + self.co_streams


# Stream-analysis states (see ``_stream_states``):
#   ("untainted",)   — value identical in every wave
#   ("rows", p)      — dense stream rows at key position p, wave-local ids
#   ("coo", p)       — streamed COO rows; global keys; owner column at p
#                      (p is None once the owner column is projected away)
#   ("owner", p)     — dense grid over the Σ segment key at position p:
#                      complete on wave-owned segments, ⊕-unit elsewhere
#   ("merged",)      — additive partial: full value = Σ over waves
_UNTAINTED = ("untainted",)
_MERGED = ("merged",)


class _Restart(Exception):
    """A new co-stream was discovered; re-run the analysis with it."""


def _stream_states(
    root: fra.Node,
    env: Dict[str, object],
    stream: str,
    co: Dict[str, int],
    owner_aligned: bool,
):
    """Walk the forward graph classifying every node's wave behaviour.

    Raises OutOfCoreError when some node combines wave-partial values in a
    way that is not additive across waves (the differential harness's
    budget-too-small/unstreamable error path); mutates ``co`` and raises
    ``_Restart`` when a join demands that another dense base relation be
    sliced with the stream's boundaries."""
    from .chunkstore import OutOfCoreError

    memo: Dict[int, tuple] = {}

    def die(n: fra.Node, why: str):
        raise OutOfCoreError(
            f"cannot stream '{stream}' through {n.describe()}: {why}"
        )

    def new_pos(comps, pos, of=None):
        """Position of source comp index ``pos`` among projection comps."""
        for o, c in enumerate(comps):
            if not _is_lit(c) and c.idx == pos and (of is None or isinstance(c, of)):
                return o
        return None

    def _is_lit(c) -> bool:
        return type(c).__name__ == "Lit"

    def visit(n: fra.Node):
        if n.id in memo:
            return memo[n.id]
        s = _visit(n)
        memo[n.id] = s
        return s

    def _scan_state(name: str, n: fra.Node):
        if name == stream:
            rel = env[name]
            if isinstance(rel, CooRelation):
                return ("coo", rel.owner_dim)
            return ("rows", 0)
        if name in co:
            return ("rows", co[name])
        return _UNTAINTED

    def _select(n: fra.Select):
        s = visit(n.child)
        if s == _UNTAINTED:
            return _UNTAINTED
        kind = s[0]
        if kind == "coo":
            # σ over COO: no predicate (compiler contract), proj permutes
            # key columns; any per-row kernel is wave-local
            p = s[1]
            return ("coo", new_pos(n.proj.comps, p) if p is not None else None)
        if kind == "rows":
            p = s[1]
            if any(i == p for i, _ in n.pred.eqs):
                die(n, "a σ predicate fixes a literal row of the wave-local "
                       "streamed axis")
            q = new_pos(n.proj.comps, p)
            if q is None:
                die(n, "σ projects away the streamed row axis")
            return ("rows", q)
        if kind == "owner":
            if not n.kernel.zero_preserving:
                die(n, f"⊙{n.kernel.name} is not zero-preserving over "
                       "segments untouched by this wave")
            p = s[1]
            if any(i == p for i, _ in n.pred.eqs):
                return _MERGED
            q = new_pos(n.proj.comps, p)
            return ("owner", q) if q is not None else _MERGED
        # merged
        if not n.kernel.linear:
            die(n, f"⊙{n.kernel.name} is not linear over partially "
                   "accumulated Σ values")
        return _MERGED

    def _agg(n: fra.Agg, s):
        if s == _UNTAINTED:
            return _UNTAINTED
        if not n.kernel.is_add:
            die(n, f"⊕{n.kernel.name} cannot merge wave partials (not +)")
        kind = s[0]
        if kind == "rows":
            q = new_pos(n.grp.comps, s[1])
            return ("rows", q) if q is not None else _MERGED
        if kind == "coo":
            p = s[1]
            q = new_pos(n.grp.comps, p) if p is not None else None
            if q is not None and owner_aligned:
                return ("owner", q)
            return _MERGED
        if kind == "owner":
            q = new_pos(n.grp.comps, s[1])
            return ("owner", q) if q is not None else _MERGED
        return _MERGED

    def _join(n: fra.Join):
        sl, sr = visit(n.left), visit(n.right)
        if sl == _UNTAINTED and sr == _UNTAINTED:
            return _UNTAINTED
        la, ra = n.left.key_arity, n.right.key_arity
        uf = join_equiv_classes(n.pred, la, ra)

        def out_pos(cls):
            for o, c in enumerate(n.proj.comps):
                if not _is_lit(c) and uf.find(c) == cls:
                    return o
            return None

        if sl[0] == "rows" and sr[0] == "rows":
            # both sides wave-local rows (stream + co-stream): valid only
            # when the join aligns them row-for-row
            if uf.find(L(sl[1])) != uf.find(R(sr[1])):
                die(n, "two wave-local row sets join on different keys")
            q = out_pos(uf.find(L(sl[1])))
            return ("rows", q) if q is not None else _MERGED
        if sl != _UNTAINTED and sr != _UNTAINTED:
            die(n, "both sides depend on the streamed relation")
        tainted_left = sl != _UNTAINTED
        s, other = (sl, n.right) if tainted_left else (sr, n.left)
        kind = s[0]
        if kind == "coo":
            # streamed COO keys are global: gathers against resident dense
            # relations are wave-exact under any kernel
            p = s[1]
            if p is None:
                return ("coo", None)
            cls = uf.find(L(p) if tainted_left else R(p))
            return ("coo", out_pos(cls))
        if kind == "rows":
            cls = uf.find(L(s[1]) if tainted_left else R(s[1]))
            opp = [R(j) for j in range(ra)] if tainted_left else [
                L(i) for i in range(la)
            ]
            hit = [c for c in opp if uf.find(c) == cls]
            if hit:
                # the other side joins ON the wave-local row ids: it must
                # be co-streamed with the same boundaries
                name = _leaf_name(other)
                rel = env.get(name) if name else None
                if name is None or not isinstance(rel, DenseRelation):
                    die(n, "the other side joins on the streamed row axis "
                           "but is not a sliceable dense base relation")
                if name == stream or name in co:
                    die(n, "the streamed row axis joins a relation that is "
                           "already streamed on a different axis")
                co[name] = hit[0].idx
                raise _Restart()
            q = out_pos(cls)
            return ("rows", q) if q is not None else _MERGED
        # owner / merged operands pass through a join only when the kernel
        # is linear in that operand (0 stays 0, partials distribute)
        if not n.kernel.multiplicative:
            die(n, f"⊗{n.kernel.name} is not multiplicative: wave partials "
                   "do not distribute through it")
        if kind == "owner":
            cls = uf.find(L(s[1]) if tainted_left else R(s[1]))
            q = out_pos(cls)
            return ("owner", q) if q is not None else _MERGED
        return _MERGED

    def _visit(n: fra.Node):
        if isinstance(n, fra.TableScan):
            return _scan_state(n.name, n)
        if isinstance(n, fra.Const):
            return _scan_state(n.ref, n) if n.ref in env else _UNTAINTED
        if isinstance(n, fra.Select):
            return _select(n)
        if isinstance(n, fra.Agg):
            return _agg(n, visit(n.child))
        if isinstance(n, fra.Join):
            return _join(n)
        if isinstance(n, fra.Restrict):
            if visit(n.ref) != _UNTAINTED:
                die(n, "restriction reference depends on the stream")
            s = visit(n.child)
            if s[0] == "rows":
                die(n, "restricting wave-local rows against global keys")
            return s
        if isinstance(n, fra.AddOp):
            sl, sr = visit(n.left), visit(n.right)
            if sl == sr:
                return sl
            die(n, f"summands have incompatible wave states {sl} vs {sr}")
        raise TypeError(f"unknown node {n}")

    return visit(root)


def plan_waves(
    query: fra.Query,
    env: Dict[str, object],
    memory_budget: Optional[float],
    *,
    stats: Optional[Dict[str, RelationStats]] = None,
) -> Optional[WavePlan]:
    """Decide whether (and how) to stream this query's environment through
    the device in chunk waves under ``memory_budget`` bytes.

    Returns None when everything fits (or no budget is set) — the
    bit-identity gate: the in-core path then runs with zero new code.
    Otherwise picks the largest base relation as the stream, verifies via
    ``_stream_states`` that per-wave results merge exactly (raising
    ``chunkstore.OutOfCoreError`` with the offending node otherwise), and
    sizes the wave count so resident relations plus one wave fit the
    budget."""
    from .chunkstore import OutOfCoreError
    from .relation import make_manifest

    if memory_budget is None:
        return None
    sizes = {name: _rel_bytes(rel) for name, rel in env.items()}
    total = sum(sizes.values())
    if total <= memory_budget:
        return None
    # streamable leaves: TableScans plus Const refs resolving to env
    # relations — the SQL front door lowers every non-``wrt`` relation to
    # a Const, and those are exactly the big constant data relations
    # (design matrix, labels) a budgeted step most needs to stream
    base = {s.name for s in query.root.table_scans()}
    base.update(
        c.ref
        for c in query.root.topo()
        if isinstance(c, fra.Const) and c.ref in env
    )
    candidates = [n for n in sizes if n in base]
    if not candidates:
        raise OutOfCoreError(
            f"environment ({total:.0f} B) exceeds the memory budget "
            f"({memory_budget:.0f} B) but the query has no streamable "
            "base relation"
        )
    stream = max(candidates, key=lambda n: sizes[n])
    srel = env[stream]
    owner_aligned = (
        isinstance(srel, CooRelation) and srel.owner_dim is not None
    )

    co: Dict[str, int] = {}
    for _ in range(len(env) + 1):
        try:
            _stream_states(query.root, env, stream, co, owner_aligned)
            break
        except _Restart:
            continue
    else:
        raise OutOfCoreError("co-stream discovery did not converge")

    moving = sizes[stream] + sum(sizes[n] for n in co)
    resident = total - moving
    headroom = memory_budget - resident
    if headroom <= 0:
        raise OutOfCoreError(
            f"memory budget too small: resident relations alone hold "
            f"{resident:.0f} B of the {memory_budget:.0f} B budget"
        )
    num_waves = max(2, -int(-moving // headroom))
    rows = (
        srel.nnz if isinstance(srel, CooRelation) else int(srel.extents[0])
    )
    if num_waves > rows:
        raise OutOfCoreError(
            f"memory budget too small: '{stream}' needs {num_waves} waves "
            f"but has only {rows} rows"
        )
    # co-streamed relations are sliced with the stream's boundaries along
    # their joined dim — their row extents must agree
    for name, dim in co.items():
        ext = int(env[name].extents[dim])
        if ext != rows and not isinstance(srel, CooRelation):
            raise OutOfCoreError(
                f"co-streamed '{name}' dim {dim} extent {ext} != streamed "
                f"'{stream}' rows {rows}"
            )
    manifest = make_manifest(srel, num_waves, axis=0)
    axis_of = tuple(sorted([(stream, 0)] + list(co.items())))
    return WavePlan(
        stream=stream,
        co_streams=tuple(sorted(co)),
        num_waves=manifest.num_chunks,
        boundaries=manifest.boundaries,
        axis_of=axis_of,
        owner_aligned=manifest.owner_aligned,
        budget=float(memory_budget),
    )
