"""Mini SQL frontend: compiles the paper's SQL style into functional-RA
query graphs (fra.py) ready for ``ra_autodiff``.

The paper's §6 implementation "accepts SQL input"; this is that layer.
Supported grammar (enough for every SQL fragment the paper shows):

  script   := stmt (";" stmt)* [";"]
  stmt     := NAME ":=" select | select          -- named views; last = root
  select   := SELECT item ("," item)*
              FROM tbl [alias] ("," tbl [alias])*
              [WHERE cond (AND cond)*]
              [GROUP BY colref ("," colref)*]
  item     := colref [AS NAME]                   -- key column
            | [SUM|MAX] "(" call | colref ")" [AS NAME]   -- value column
  call     := NAME "(" valarg ("," valarg)* ")"  -- kernel from the registry
  cond     := colref "=" colref | colref "=" INT

One kernel call per SELECT (the paper builds multi-operator pipelines as
stacked queries — use views, e.g. the §2.3 logistic regression below).
Key columns are the relation's declared key attributes; any other
attribute (``val``, ``mat``, ``vec``...) refers to the tuple's value.

  SQL function         FRA kernel
  matrix_multiply   →  matmul        multiply → mul      add → add2
  (any registered kernel name works verbatim: logistic, xent, sqerr, ...)

Example (paper §2.3):

  compile_sql('''
    mm   := SELECT Rx.row, SUM(multiply(Rx.val, theta.val))
            FROM Rx, theta WHERE Rx.col = theta.col GROUP BY Rx.row;
    pred := SELECT mm.row, logistic(mm.val) FROM mm;
    SELECT SUM(xent(pred.val, Ry.val)) FROM pred, Ry
    WHERE pred.row = Ry.row
  ''', schema={"Rx": ("row", "col"), "theta": ("col",), "Ry": ("row",)},
       inputs=("theta",))
"""

from __future__ import annotations

import re
from contextvars import ContextVar
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from . import fra
from .kernels import _AGG, _BIN, _UNARY, IDENT, agg, bin_kernel, unary
from .keys import (
    In,
    JoinPred,
    JoinProj,
    KeyFn,
    L,
    Lit,
    R,
    SelPred,
    jproj,
)

_FN_ALIASES = {
    "matrix_multiply": "matmul",
    "matmul": "matmul",
    "multiply": "mul",
    "mul": "mul",
    "add": "add2",
    "matrix_add": "add2",
    "subtract": "sub",
}

_AGG_NAMES = {"SUM": "add", "MAX": "max"}


# the statement being compiled, used as the Diagnostic node path so an
# error in a multi-statement script names the offending stmt/view
_CURRENT_STMT: ContextVar[str] = ContextVar("_CURRENT_STMT", default="script")


class SQLError(ValueError):
    """A SQL frontend error carrying a structured ``Diagnostic``.

    ``str(err)`` renders as ``<node_path>: <message> (hint: ...)`` so
    existing ``except SQLError`` / message-matching callers keep
    working; ``err.diagnostic`` exposes the severity/code/node-path/hint
    record for programmatic consumers (same type the FRA checker
    emits — see ``repro.analysis.diagnostics``)."""

    def __init__(self, message: str = "", *, code: str = "sql",
                 hint: str = "", diagnostic=None):
        from ..analysis.diagnostics import Diagnostic

        if diagnostic is None:
            diagnostic = Diagnostic(
                severity="error",
                code=code,
                node_path=_CURRENT_STMT.get(),
                message=str(message),
                hint=hint,
            )
        self.diagnostic = diagnostic
        super().__init__(diagnostic.render_inline())


# ---------------------------------------------------------------------------
# Tokenizer
# ---------------------------------------------------------------------------

_TOKEN_RE = re.compile(
    r"\s*(?:(?P<assign>:=)|(?P<punct>[(),;.=])|(?P<int>\d+)|(?P<name>[A-Za-z_]\w*)|(?P<comment>--[^\n]*))"
)

_KEYWORDS = {"SELECT", "FROM", "WHERE", "GROUP", "BY", "AND", "AS"}


def _tokenize(text: str) -> List[Tuple[str, str]]:
    toks, pos = [], 0
    while pos < len(text):
        m = _TOKEN_RE.match(text, pos)
        if not m:
            if text[pos:].strip() == "":
                break
            raise SQLError(f"cannot tokenize at: {text[pos:pos+30]!r}")
        pos = m.end()
        if m.lastgroup == "comment":
            continue
        val = m.group(m.lastgroup)
        if m.lastgroup == "name" and val.upper() in _KEYWORDS:
            toks.append(("kw", val.upper()))
        else:
            toks.append((m.lastgroup, val))
    toks.append(("eof", ""))
    return toks


# ---------------------------------------------------------------------------
# AST
# ---------------------------------------------------------------------------


@dataclass
class ColRef:
    table: str
    attr: str


@dataclass
class Call:
    fn: str
    args: List[ColRef]


@dataclass
class ValItem:
    aggfn: Optional[str]          # "add"/"max" or None
    call: Optional[Call]          # kernel call, or None for bare colref
    col: Optional[ColRef]
    alias: Optional[str]


@dataclass
class SelectStmt:
    key_items: List[Tuple[ColRef, Optional[str]]]
    val_item: ValItem
    tables: List[Tuple[str, str]]               # (name, alias)
    conds: List[Tuple[ColRef, object]]          # rhs: ColRef | int
    group_by: List[ColRef]


class _Parser:
    def __init__(self, toks: List[Tuple[str, str]]):
        self.toks = toks
        self.i = 0

    def peek(self) -> Tuple[str, str]:
        return self.toks[self.i]

    def next(self) -> Tuple[str, str]:
        t = self.toks[self.i]
        self.i += 1
        return t

    def expect(self, kind: str, val: Optional[str] = None) -> str:
        k, v = self.next()
        if k != kind or (val is not None and v != val):
            raise SQLError(f"expected {val or kind}, got {v!r}")
        return v

    def at_kw(self, kw: str) -> bool:
        k, v = self.peek()
        return k == "kw" and v == kw

    # -- grammar ------------------------------------------------------------

    def script(self) -> List[Tuple[Optional[str], SelectStmt]]:
        stmts = []
        while not self.peek()[0] == "eof":
            name = None
            if self.peek()[0] == "name" and self.toks[self.i + 1][0] == "assign":
                name = self.next()[1]
                self.next()  # :=
            stmts.append((name, self.select()))
            if self.peek() == ("punct", ";"):
                self.next()
        if not stmts:
            raise SQLError("empty script")
        return stmts

    def select(self) -> SelectStmt:
        self.expect("kw", "SELECT")
        key_items: List[Tuple[ColRef, Optional[str]]] = []
        val_item: Optional[ValItem] = None
        while True:
            item = self.sel_item()
            if isinstance(item, tuple):
                key_items.append(item)
            else:
                if val_item is not None:
                    raise SQLError("only one value expression per SELECT")
                val_item = item
            if self.peek() == ("punct", ","):
                self.next()
                continue
            break
        if val_item is None:
            raise SQLError("SELECT needs a value expression "
                           "(bare key projection is not a query)")
        self.expect("kw", "FROM")
        tables = [self.table_ref()]
        while self.peek() == ("punct", ","):
            self.next()
            tables.append(self.table_ref())
        conds: List[Tuple[ColRef, object]] = []
        if self.at_kw("WHERE"):
            self.next()
            conds.append(self.cond())
            while self.at_kw("AND"):
                self.next()
                conds.append(self.cond())
        group_by: List[ColRef] = []
        if self.at_kw("GROUP"):
            self.next()
            self.expect("kw", "BY")
            group_by.append(self.colref())
            while self.peek() == ("punct", ","):
                self.next()
                group_by.append(self.colref())
        return SelectStmt(key_items, val_item, tables, conds, group_by)

    def sel_item(self):
        k, v = self.peek()
        # aggregate or kernel call?
        if k == "name" and self.toks[self.i + 1] == ("punct", "("):
            fname = self.next()[1]
            self.next()  # (
            if fname.upper() in _AGG_NAMES:
                inner_k, _ = self.peek()
                if inner_k == "name" and self.toks[self.i + 1] == ("punct", "("):
                    call = self.call()
                    col = None
                else:
                    col = self.colref()
                    call = None
                self.expect("punct", ")")
                alias = self.opt_alias()
                return ValItem(_AGG_NAMES[fname.upper()], call, col, alias)
            call = self.call_body(fname)
            alias = self.opt_alias()
            return ValItem(None, call, None, alias)
        col = self.colref()
        alias = self.opt_alias()
        return (col, alias)  # may get reclassified by the compiler

    def call(self) -> Call:
        fname = self.expect("name")
        self.expect("punct", "(")
        return self.call_body(fname)

    def call_body(self, fname: str) -> Call:
        args = [self.colref()]
        while self.peek() == ("punct", ","):
            self.next()
            args.append(self.colref())
        self.expect("punct", ")")
        return Call(fname, args)

    def colref(self) -> ColRef:
        t = self.expect("name")
        self.expect("punct", ".")
        a = self.expect("name")
        return ColRef(t, a)

    def opt_alias(self) -> Optional[str]:
        if self.at_kw("AS"):
            self.next()
            return self.expect("name")
        return None

    def table_ref(self) -> Tuple[str, str]:
        name = self.expect("name")
        k, v = self.peek()
        if k == "name":  # alias
            self.next()
            return (name, v)
        return (name, name)

    def cond(self) -> Tuple[ColRef, object]:
        lhs = self.colref()
        self.expect("punct", "=")
        k, v = self.peek()
        if k == "int":
            self.next()
            return (lhs, int(v))
        return (lhs, self.colref())


# ---------------------------------------------------------------------------
# Compiler: AST → FRA
# ---------------------------------------------------------------------------


@dataclass
class _Rel:
    """A compiled relation: FRA node + output key attribute names."""

    node: fra.Node
    key_attrs: Tuple[str, ...]


def _kernel_name(fn: str) -> str:
    name = _FN_ALIASES.get(fn.lower(), fn.lower())
    if name in _BIN or name in _UNARY:
        return name
    if fn.upper() in ("AVG", "MIN", "COUNT", "STDDEV", "MEDIAN", "VAR"):
        raise SQLError(
            f"unsupported aggregate {fn!r} "
            f"(supported aggregates: {sorted(_AGG_NAMES)})",
            code="unsupported-aggregate",
            hint="only additive-monoid aggregates differentiate; "
                 "rewrite AVG as SUM over a pre-scaled value",
        )
    raise SQLError(f"unknown kernel function {fn!r} "
                   f"(registered: {sorted(set(_BIN) | set(_UNARY))})",
                   code="unknown-kernel",
                   hint="register the kernel in core/kernels.py or use a "
                        "registered alias (matrix_multiply, multiply, add)")


def _key_pos(rel: _Rel, attr: str, table: str) -> int:
    try:
        return rel.key_attrs.index(attr)
    except ValueError:
        raise SQLError(
            f"{table}.{attr} is not a key attribute of {table} "
            f"(keys: {rel.key_attrs})",
            code="unknown-column",
            hint=f"key columns of {table} are {list(rel.key_attrs)}; "
                 "any other attribute refers to the tuple's value",
        ) from None


def _is_value_attr(rel: _Rel, attr: str) -> bool:
    return attr not in rel.key_attrs


def _compile_select(
    stmt: SelectStmt,
    env: Dict[str, _Rel],
) -> _Rel:
    # resolve FROM tables
    rels: Dict[str, _Rel] = {}
    order: List[str] = []
    for name, alias in stmt.tables:
        if name not in env:
            raise SQLError(
                f"unknown relation {name!r}",
                code="unknown-relation",
                hint=f"known relations and views: {sorted(env)}",
            )
        if alias in rels:
            raise SQLError(f"duplicate table alias {alias!r}",
                           code="duplicate-alias")
        rels[alias] = env[name]
        order.append(alias)
    if len(order) > 2:
        raise SQLError(
            "at most two tables per SELECT (use views to chain)",
            code="too-many-tables",
            hint="chain joins through named views: "
                 "v := SELECT ... FROM a, b ...; SELECT ... FROM v, c ...",
        )

    val = stmt.val_item
    # value argument tables, in call order
    if val.call is not None:
        vargs = val.call.args
    else:
        vargs = [val.col] if val.col is not None else []
    for a in vargs:
        if a.table not in rels:
            raise SQLError(f"unknown table {a.table!r} in value expression",
                           code="unknown-table",
                           hint=f"tables in scope: {sorted(rels)}")
        if not _is_value_attr(rels[a.table], a.attr):
            raise SQLError(
                f"{a.table}.{a.attr} is a key, not a value",
                code="key-as-value",
                hint="kernel arguments must be value attributes; key "
                     "columns only join, select, and group",
            )

    if len(order) == 1:
        return _compile_single(stmt, rels, order[0], vargs)
    return _compile_join(stmt, rels, order, vargs)


def _compile_single(stmt, rels, t, vargs) -> _Rel:
    rel = rels[t]
    arity = rel.node.key_arity
    val = stmt.val_item

    # σ predicate from WHERE (key = literal only, single table)
    eqs = []
    for lhs, rhs in stmt.conds:
        if not isinstance(rhs, int):
            raise SQLError("single-table WHERE must compare a key to an integer")
        eqs.append((_key_pos(rel, lhs.attr, t), rhs))
    pred = SelPred(tuple(eqs))

    # kernel
    if val.call is not None:
        kname = _kernel_name(val.call.fn)
        if kname not in _UNARY:
            raise SQLError(f"{val.call.fn} is binary; single-table SELECT "
                           "needs a unary kernel")
        kern = unary(kname)
    else:
        kern = IDENT

    # projection from the key items
    comps = tuple(In(_key_pos(rel, c.attr, t)) for c, _ in stmt.key_items)
    out_attrs = tuple(
        alias or c.attr for c, alias in stmt.key_items
    )

    if val.aggfn is None:
        if not stmt.key_items:   # keep all keys
            comps = tuple(In(i) for i in range(arity))
            out_attrs = rel.key_attrs
        node = fra.Select(pred, KeyFn(comps), kern, rel.node)
        return _Rel(node, out_attrs)

    # aggregation: optional σ first (for kernel/pred), then Σ
    child = rel.node
    if not pred.always_true or kern is not IDENT:
        child = fra.Select(pred, KeyFn(tuple(In(i) for i in range(arity))),
                           kern, child)
    grp_cols = stmt.group_by or []
    if [c.attr for c in grp_cols] != [c.attr for c, _ in stmt.key_items]:
        raise SQLError(
            f"GROUP BY columns {[c.attr for c in grp_cols]} must match the "
            f"SELECT key columns {[c.attr for c, _ in stmt.key_items]}",
            code="group-by-mismatch",
            hint="list the same key columns, in the same order, in both "
                 "the SELECT items and the GROUP BY clause",
        )
    grp = KeyFn(tuple(In(_key_pos(rel, c.attr, t)) for c in grp_cols))
    node = fra.Agg(grp, agg(val.aggfn), child)
    return _Rel(node, out_attrs)


def _compile_join(stmt, rels, order, vargs) -> _Rel:
    val = stmt.val_item
    if val.call is None or len(vargs) != 2:
        raise SQLError("two-table SELECT needs a binary kernel call")
    kname = _kernel_name(val.call.fn)
    if kname not in _BIN:
        raise SQLError(f"{val.call.fn} is not a binary kernel")
    kern = bin_kernel(kname)

    # left = table of the first kernel argument (paper: ⊗(valL, valR))
    lt = vargs[0].table
    rt = vargs[1].table
    if {lt, rt} != set(order):
        raise SQLError("value expression must use both joined tables")
    lrel, rrel = rels[lt], rels[rt]

    def side_comp(c: ColRef):
        if c.table == lt:
            return L(_key_pos(lrel, c.attr, lt))
        if c.table == rt:
            return R(_key_pos(rrel, c.attr, rt))
        raise SQLError(f"unknown table {c.table!r}")

    eqs = []
    for lhs, rhs in stmt.conds:
        if isinstance(rhs, int):
            eqs.append((side_comp(lhs), Lit(rhs)))
        else:
            eqs.append((side_comp(lhs), side_comp(rhs)))
    pred = JoinPred(tuple(eqs))

    out_attrs = tuple(alias or c.attr for c, alias in stmt.key_items)

    if val.aggfn is None:
        comps = tuple(side_comp(c) for c, _ in stmt.key_items)
        node: fra.Node = fra.Join(pred, JoinProj(comps), kern,
                                  lrel.node, rrel.node)
        return _Rel(node, out_attrs)

    # Aggregated join — compile exactly as the paper does for its matmul
    # SQL: the join proj keeps the full keyL plus every keyR component not
    # already determined by keyL through the join predicate, and the Σ grp
    # projects the SELECT keys out of that composite key.
    grp_cols = stmt.group_by or []
    if [c.attr for c in grp_cols] != [c.attr for c, _ in stmt.key_items]:
        raise SQLError(
            f"GROUP BY columns {[c.attr for c in grp_cols]} must match the "
            f"SELECT key columns {[c.attr for c, _ in stmt.key_items]}",
            code="group-by-mismatch",
            hint="list the same key columns, in the same order, in both "
                 "the SELECT items and the GROUP BY clause",
        )

    from .keys import join_equiv_classes

    al, ar = lrel.node.key_arity, rrel.node.key_arity
    uf = join_equiv_classes(pred, al, ar)
    left_roots = {uf.find(L(i)) for i in range(al)}
    proj_comps: List[object] = [L(i) for i in range(al)]
    pos_of: Dict[object, int] = {L(i): i for i in range(al)}
    for j in range(ar):
        if uf.find(R(j)) in left_roots:
            # equivalent to some left component — record that position
            for i in range(al):
                if uf.find(L(i)) == uf.find(R(j)):
                    pos_of[R(j)] = i
                    break
        else:
            pos_of[R(j)] = len(proj_comps)
            proj_comps.append(R(j))

    grp_comps = tuple(In(pos_of[side_comp(c)]) for c, _ in stmt.key_items)
    node = fra.Join(pred, JoinProj(tuple(proj_comps)), kern,
                    lrel.node, rrel.node)
    node = fra.Agg(KeyFn(grp_comps), agg(val.aggfn), node)
    return _Rel(node, out_attrs)


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------


def compile_sql(
    script: str,
    schema: Dict[str, Sequence[str]],
    inputs: Sequence[str] = (),
) -> fra.Query:
    """Compile a SQL script to an FRA ``Query``.

    ``schema`` maps base-relation names to their key attribute names.
    ``inputs`` names the relations to treat as differentiable variable
    inputs (TableScan leaves); all other relations are constants
    (⋈_const operands / training data).
    """
    stmts = _Parser(_tokenize(script)).script()
    env: Dict[str, _Rel] = {}
    for name, attrs in schema.items():
        arity = len(attrs)
        leaf = (
            fra.scan(name, arity) if name in inputs else fra.const(name, arity)
        )
        env[name] = _Rel(leaf, tuple(attrs))

    last: Optional[_Rel] = None
    for i, (name, stmt) in enumerate(stmts):
        label = f"stmt[{i}]" if name is None else f"stmt[{i}]:{name}"
        token = _CURRENT_STMT.set(label)
        try:
            rel = _compile_select(stmt, env)
            if name is not None:
                if name in env:
                    raise SQLError(
                        f"view {name!r} shadows an existing relation",
                        code="view-shadows-relation",
                        hint="pick a view name outside the schema: "
                             f"{sorted(schema)}",
                    )
                env[name] = rel
        finally:
            _CURRENT_STMT.reset(token)
        last = rel
    assert last is not None
    missing = set(inputs) - {s.name for s in last.node.table_scans()}
    if missing:
        raise SQLError(
            f"declared inputs never scanned: {missing}",
            code="unused-input",
            hint="every wrt= input must appear in a FROM clause that "
                 "reaches the final statement",
        )
    return fra.Query(last.node, inputs=tuple(inputs))


def sql_autodiff(script: str, schema, inputs):
    """compile_sql + ra_autodiff in one call."""
    from .autodiff import ra_autodiff

    return ra_autodiff(compile_sql(script, schema, inputs))
