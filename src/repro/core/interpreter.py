"""Sparse reference interpreter — the semantics oracle.

Executes the FRA IR literally per the paper's definitions (§2.2), over
relations represented as ``dict[key_tuple, value]``. Values may be python
floats, numpy arrays, or jnp arrays (chunks). This executor is
tuple-at-a-time and deliberately naive: it exists to pin down semantics for
tests; the chunked compiler (compiler.py) is the fast path and is tested
against this one.

A bare Join may produce duplicate output keys (non-injective proj over
matches); per the paper such joins appear only under an Agg ("join-agg
tree"). Internally Join evaluates to a *list* of (key, value) pairs; Agg
consumes either a list or a dict; any other consumer requires uniqueness
and raises otherwise.
"""

from __future__ import annotations

from typing import Dict, List, Tuple, Union

from . import fra

SparseRelation = Dict[tuple, object]
Env = Dict[str, SparseRelation]


def _as_relation(pairs: Union[SparseRelation, List[tuple]], ctx: str) -> SparseRelation:
    if isinstance(pairs, dict):
        return pairs
    rel: SparseRelation = {}
    for k, v in pairs:
        if k in rel:
            raise ValueError(
                f"join under {ctx} produced duplicate key {k}; wrap it in an "
                f"Agg (join-agg tree) to merge duplicates"
            )
        rel[k] = v
    return rel


def _items(pairs: Union[SparseRelation, List[tuple]]):
    return pairs.items() if isinstance(pairs, dict) else pairs


def evaluate(
    node: fra.Node,
    env: Env,
    cache: Dict[int, object] | None = None,
) -> SparseRelation:
    """Evaluate ``node`` under ``env``. If ``cache`` is given, every node's
    intermediate relation is stored there by node id (needed by the
    auto-diff forward pass, Algorithm 2 line 6)."""
    memo: Dict[int, object] = {}

    def ev(n: fra.Node):
        if n.id in memo:
            return memo[n.id]
        out = _ev(n)
        memo[n.id] = out
        if cache is not None:
            # Joins cache their raw multiset; with the join-agg fusion of §4
            # the bare-join intermediate is never consumed as a relation.
            cache[n.id] = out
        return out

    def _ev(n: fra.Node):
        if isinstance(n, fra.TableScan):
            return env[n.name]
        if isinstance(n, fra.Const):
            return env[n.ref]
        if isinstance(n, fra.Select):
            child = _as_relation(ev(n.child), "σ")
            out: SparseRelation = {}
            for k, v in child.items():
                if n.pred(k):
                    nk = n.proj(k)
                    if nk in out:
                        raise ValueError(f"σ proj produced duplicate key {nk}")
                    out[nk] = n.kernel.fn(v)
            return out
        if isinstance(n, fra.Agg):
            child = ev(n.child)
            out: SparseRelation = {}
            for k, v in _items(child):
                nk = n.grp(k)
                out[nk] = n.kernel.fn(out[nk], v) if nk in out else v
            return out
        if isinstance(n, fra.Join):
            left = _as_relation(ev(n.left), "⋈.left")
            right = _as_relation(ev(n.right), "⋈.right")
            pairs: List[tuple] = []
            for kl, vl in left.items():
                for kr, vr in right.items():
                    if n.pred(kl, kr):
                        pairs.append((n.proj(kl, kr), n.kernel.fn(vl, vr)))
            return pairs
        if isinstance(n, fra.Restrict):
            child = _as_relation(ev(n.child), "restrict")
            ref = _as_relation(ev(n.ref), "restrict.ref")
            return {k: v for k, v in child.items() if k in ref}
        if isinstance(n, fra.AddOp):
            left = _as_relation(ev(n.left), "add.left")
            right = _as_relation(ev(n.right), "add.right")
            out = dict(left)
            for k, v in right.items():
                out[k] = out[k] + v if k in out else v
            return out
        raise TypeError(f"unknown node {n}")

    return _as_relation(ev(node), "root")


def run_query(q: fra.Query, env: Env, cache: Dict[int, object] | None = None) -> SparseRelation:
    return evaluate(q.root, env, cache)
