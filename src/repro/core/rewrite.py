"""Cost-gated algebraic rewrite stage: factorized evaluation of Σ∘⋈.

The planner (plan_query) decides *where* a query runs; this stage decides
*what* is computed. It sits between ``RAEngine.lower`` and ``plan_query``
and applies a small rule registry bottom-up over the FRA graph:

* ``sigma_pushdown`` — Σ-through-⋈: when the Σ above a join drops key
  columns that one side contributes and the join predicate never reads,
  a partial Σ over those columns is pushed below the join (the
  factorized-learning rewrite: partial aggregates instead of the
  materialized join output). Σ_g(l ⊗ r) with ⊗ multiplicative (linear
  per argument) distributes over the dropped columns:
  ``Σ_{g}(L ⋈ R) = Σ_{g'}((Σ_{kept} L) ⋈ R)``.
* ``sigma_split`` — the same pushdown applied to *both* join sides at
  once, when each contributes droppable columns (independent branches).
* ``dedup`` — common-subplan elimination: structurally identical
  subtrees (same operator, key functions, kernels, and — recursively —
  children) are merged to one node, so the executor's per-node memo
  computes them once.

Every structural rule is **cost-gated** on the same bottom-up byte
estimates ``plan_query`` prices joins with (``planner.estimate_graph``),
sharpened by ``RelationStats`` catalog snapshots when available: a
pushdown fires only when the estimated post-Agg size beats the
unrewritten join output by ``RuleSet.min_shrink``. Per-column histograms
(``RelationStats.hist``) refine the join output-size estimate via bucket
overlap of the joined columns; without stats the gate falls back to the
planner's 1/8-per-dropped-key heuristic, and a declined gate returns the
*original* graph object — bit-identical plans, cache keys and all.

The rewritten graph must differentiate correctly: ``rewrite_program``
rewrites a GradientProgram's forward query and re-derives the gradient
graphs with ``ra_autodiff`` (same wrt tuple, same RJPOptions), so the
partial-aggregate VJPs ride the existing segment-sum / gather dispatch
ops and the ``__fwd_*`` cache refs stay consistent with the rewritten
forward. The engine keys its lowering cache on (rule set, stats
snapshot) — see ``RAEngine.lower``.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from . import fra
from .keys import In, JoinPred, JoinProj, KeyFn, L, R
from .planner import GraphEstimate, RelationStats, agg_shrink, estimate_graph

#: rule names, in application order
ALL_RULES = ("dedup", "sigma_pushdown", "sigma_split")


@dataclass(frozen=True)
class RuleSet:
    """The enabled rewrite rules plus the cost gate's firing threshold.

    Frozen and hashable — a RuleSet is part of the ``Lowered`` cache key,
    so two lowerings under different rule sets (or thresholds) can never
    alias one cached plan.

    ``min_shrink``: a pushdown fires only when the estimated post-Agg
    bytes are at least this factor below the unrewritten join-side
    bytes; 2.0 means "don't restructure the program for less than a 2×
    smaller intermediate".
    """

    rules: Tuple[str, ...] = ALL_RULES
    min_shrink: float = 2.0

    def __post_init__(self):
        unknown = set(self.rules) - set(ALL_RULES)
        if unknown:
            raise ValueError(
                f"unknown rewrite rules {sorted(unknown)}; known: {ALL_RULES}"
            )

    def __contains__(self, rule: str) -> bool:
        return rule in self.rules


DEFAULT_RULES = RuleSet()


def make_rules(spec) -> Optional[RuleSet]:
    """Normalize a rewrite spec: None/False → off, True → the default
    rule set, a RuleSet → itself, an iterable of rule names → a RuleSet
    over exactly those rules."""
    if spec is None or spec is False:
        return None
    if spec is True:
        return DEFAULT_RULES
    if isinstance(spec, RuleSet):
        return spec
    return RuleSet(tuple(spec))


@dataclass
class Decision:
    """One cost-gate verdict, for ``Database.explain``."""

    rule: str
    site: str            # describe() of the node the rule looked at
    fired: bool
    est_before: float    # bytes the unrewritten plan materializes here
    est_after: float     # bytes after the rewrite (== est_before if declined)
    detail: str = ""

    def render(self) -> str:
        verdict = "FIRED" if self.fired else "declined"
        line = (
            f"{self.rule} @ {self.site}: {verdict} "
            f"(est {_fmt_bytes(self.est_before)} -> "
            f"{_fmt_bytes(self.est_after)}"
        )
        if self.detail:
            line += f"; {self.detail}"
        return line + ")"


@dataclass
class RewriteReport:
    """What the rewrite stage did to one query: every gate decision (in
    bottom-up application order) plus the changed flag ``RAEngine.lower``
    caches alongside the rewritten program."""

    decisions: List[Decision] = field(default_factory=list)
    changed: bool = False

    @property
    def fired(self) -> List[Decision]:
        return [d for d in self.decisions if d.fired]

    def render(self) -> str:
        if not self.decisions:
            return "no rewrite candidates"
        return "\n".join(d.render() for d in self.decisions)


def _fmt_bytes(b: float) -> str:
    """Deterministic short byte count for explain output."""
    for unit in ("B", "KiB", "MiB", "GiB"):
        if abs(b) < 1024.0 or unit == "GiB":
            return f"{b:.1f}{unit}" if unit != "B" else f"{b:.0f}B"
        b /= 1024.0
    return f"{b:.1f}GiB"  # pragma: no cover — loop always returns


# ---------------------------------------------------------------------------
# Common-subplan deduplication (structural hashing)
# ---------------------------------------------------------------------------


def _structural_key(node: fra.Node, child_ids: Tuple[int, ...]) -> Tuple:
    """Hashable identity of one node *given* canonical children: operator
    type + its key functions / kernel + the children's canonical ids.
    Key functions are frozen dataclasses, so equality is structural;
    kernels compare by registry name."""
    if isinstance(node, fra.TableScan):
        return ("scan", node.name, node.key_arity)
    if isinstance(node, fra.Const):
        return ("const", node.ref, node.key_arity)
    if isinstance(node, fra.Select):
        return ("select", node.pred, node.proj, node.kernel.name, child_ids)
    if isinstance(node, fra.Agg):
        return ("agg", node.grp, node.kernel.name, child_ids)
    if isinstance(node, fra.Join):
        return ("join", node.pred, node.proj, node.kernel.name, child_ids)
    if isinstance(node, fra.AddOp):
        return ("add", child_ids)
    if isinstance(node, fra.Restrict):
        return ("restrict", child_ids)
    raise TypeError(f"cannot hash node {node}")


def _rebuild(node: fra.Node, children: Tuple[fra.Node, ...]) -> fra.Node:
    if children == node.children:
        return node
    if isinstance(node, fra.Select):
        return fra.Select(node.pred, node.proj, node.kernel, children[0])
    if isinstance(node, fra.Agg):
        return fra.Agg(node.grp, node.kernel, children[0])
    if isinstance(node, fra.Join):
        return fra.Join(node.pred, node.proj, node.kernel, *children)
    if isinstance(node, fra.AddOp):
        return fra.AddOp(*children)
    if isinstance(node, fra.Restrict):
        return fra.Restrict(*children)
    raise TypeError(f"cannot rebuild node {node}")  # pragma: no cover


def dedup(root: fra.Node) -> Tuple[fra.Node, int]:
    """Merge structurally identical subtrees bottom-up. Returns the
    (possibly rebuilt) root and the number of nodes eliminated. Safe for
    gradients: the executors memoize per node id and ``ra_autodiff``
    accumulates fan-out contributions, so a merged node simply becomes a
    shared DAG child."""
    canon: Dict[Tuple, fra.Node] = {}
    memo: Dict[int, fra.Node] = {}
    merged = 0
    for node in root.topo():
        children = tuple(memo[c.id] for c in node.children)
        key = _structural_key(node, tuple(c.id for c in children))
        hit = canon.get(key)
        if hit is not None:
            if hit is not node:
                merged += 1
            memo[node.id] = hit
        else:
            rebuilt = _rebuild(node, children)
            canon[key] = rebuilt
            memo[node.id] = rebuilt
    return memo[root.id], merged


# ---------------------------------------------------------------------------
# Σ-through-⋈ pushdown, cost-gated
# ---------------------------------------------------------------------------


class _Estimator:
    """A ``planner.estimate_graph`` result that can be extended with the
    nodes the rewriter creates, using the same size rules — so a cascaded
    pushdown (a multi-join chain) gates every step on consistent numbers."""

    def __init__(self, base: GraphEstimate):
        self.sizes = dict(base.sizes)
        self.is_coo = dict(base.is_coo)
        self.dist = dict(base.dist)
        self.hists = dict(base.hists)

    def note(self, node: fra.Node) -> None:
        """Record estimates for one freshly created node (children known)."""
        if node.id in self.sizes:
            return
        if isinstance(node, fra.Agg):
            cd = self.dist.get(node.child.id)
            factor, _ = agg_shrink(node.child.key_arity, node.grp, cd)
            self.sizes[node.id] = self.sizes[node.child.id] / factor
            self.dist[node.id] = (
                tuple(
                    cd[c.idx] if isinstance(c, In) else None
                    for c in node.grp.comps
                )
                if cd is not None
                else None
            )
            self.is_coo[node.id] = False
            self.hists[node.id] = None
        elif isinstance(node, fra.Join):
            self.sizes[node.id] = max(
                self.sizes[node.left.id], self.sizes[node.right.id]
            )
            self.is_coo[node.id] = (
                self.is_coo[node.left.id] or self.is_coo[node.right.id]
            )
            ld = self.dist.get(node.left.id)
            rd = self.dist.get(node.right.id)
            self.dist[node.id] = tuple(
                ld[c.idx] if isinstance(c, L) and ld is not None
                else rd[c.idx] if isinstance(c, R) and rd is not None
                else None
                for c in node.proj.comps
            )
            lh = self.hists.get(node.left.id)
            rh = self.hists.get(node.right.id)
            self.hists[node.id] = (
                tuple(
                    lh[c.idx] if isinstance(c, L) and lh is not None
                    else rh[c.idx] if isinstance(c, R) and rh is not None
                    else None
                    for c in node.proj.comps
                )
                if lh is not None or rh is not None
                else None
            )
        else:  # pragma: no cover — the rewriter only creates Agg/Join
            raise TypeError(f"cannot note node {node}")


def _match_fraction(join: fra.Join, est: "_Estimator") -> float:
    """Histogram-sharpened join selectivity: the estimated fraction of a
    side's tuples whose join-column value finds matching mass on the
    other side, from the joined columns' equi-width histograms (columns
    joined by equality are assumed to share a key domain, so buckets
    align). 1.0 — the dense-grid assumption — wherever histograms are
    unavailable, keeping the stats-less gate bit-identical to the
    heuristic path."""
    lh, rh = est.hists.get(join.left.id), est.hists.get(join.right.id)
    if lh is None or rh is None:
        return 1.0
    frac = 1.0
    for a, b in join.pred.eqs:
        if isinstance(a, R) and isinstance(b, L):
            a, b = b, a
        if not (isinstance(a, L) and isinstance(b, R)):
            continue
        hl = lh[a.idx] if a.idx < len(lh) else None
        hr = rh[b.idx] if b.idx < len(rh) else None
        if hl is None or hr is None:
            continue
        tot = float(sum(hl))
        if tot <= 0.0 or not any(hr):
            continue
        matched = float(sum(l for l, r in zip(hl, hr) if r > 0))
        frac *= matched / tot
    return frac


def _side_needed(
    join: fra.Join, proj_eff: JoinProj, side_cls: type
) -> Optional[set]:
    """Key positions of one join side (``side_cls`` is L or R) that the
    predicate or the effective projection reads; None when a literal
    component blocks the analysis (the compiler rejects Lit keys in
    einsum lowerings anyway)."""
    needed: set = set()
    for a, b in join.pred.eqs:
        for c in (a, b):
            if isinstance(c, side_cls):
                needed.add(c.idx)
            elif not isinstance(c, (L, R)):
                return None  # Lit in the predicate: leave the join alone
    for c in proj_eff.comps:
        if isinstance(c, side_cls):
            needed.add(c.idx)
        elif not isinstance(c, (L, R)):
            return None  # Lit in the projection
    return needed


def _remap_side(comp, side_cls, new_idx):
    """Remap one join component's ``side_cls`` index after that side's
    key was compacted to its kept columns."""
    if isinstance(comp, side_cls):
        return side_cls(new_idx[comp.idx])
    return comp


class _Rewriter:
    """One bottom-up pass over a (deduplicated) graph: rebuilds nodes
    whose children changed and attempts the gated Σ-pushdown at every
    Agg-over-Join. Nodes it leaves alone are returned as-is (object
    identity preserved), so a fully declined pass yields the original
    root and the engine's decline path stays bit-identical."""

    def __init__(
        self,
        est: _Estimator,
        parents: Dict[int, int],
        rules: RuleSet,
        report: RewriteReport,
    ):
        self.est = est
        self.parents = parents
        self.rules = rules
        self.report = report
        self.memo: Dict[int, fra.Node] = {}

    def rewrite(self, root: fra.Node) -> fra.Node:
        for node in root.topo():
            children = tuple(self.memo[c.id] for c in node.children)
            out: Optional[fra.Node] = None
            if (
                isinstance(node, fra.Agg)
                and isinstance(children[0], fra.Join)
                and "sigma_pushdown" in self.rules
                # sharing check on the *original* child id: a join output
                # consumed elsewhere too must stay one subplan — splitting
                # it into a per-consumer partial-agg form would double
                # the work dedup just saved
                and self.parents.get(node.children[0].id, 1) <= 1
            ):
                out = self._try_pushdown(node.grp, node.kernel, children[0])
            if out is None:
                out = _rebuild(node, children)
                if out is not node:
                    if isinstance(out, (fra.Agg, fra.Join)):
                        self.est.note(out)
                    else:
                        self._copy_est(node, out)
            self.memo[node.id] = out
        return self.memo[root.id]

    def _copy_est(self, old: fra.Node, new: fra.Node) -> None:
        self.est.sizes[new.id] = self.est.sizes.get(old.id, 0.0)
        self.est.is_coo[new.id] = self.est.is_coo.get(old.id, False)
        self.est.dist[new.id] = self.est.dist.get(old.id)
        self.est.hists[new.id] = self.est.hists.get(old.id)

    # -- the Σ-through-⋈ rule ---------------------------------------------
    def _try_pushdown(
        self, grp: KeyFn, kernel, join: fra.Join
    ) -> Optional[fra.Node]:
        """Push a partial Σ below ``join`` if legal and worth it; returns
        the replacement subtree, or None to keep the plain Agg."""
        est = self.est
        if not kernel.is_add or not join.kernel.multiplicative:
            return None
        if not all(isinstance(c, In) for c in grp.comps):
            return None
        proj_eff = JoinProj(tuple(join.proj.comps[c.idx] for c in grp.comps))
        plans = []  # (side, dropped, kept, decision)
        for side_name, side_cls, side in (
            ("left", L, join.left),
            ("right", R, join.right),
        ):
            needed = _side_needed(join, proj_eff, side_cls)
            if needed is None:
                return None  # literal component: leave the join alone
            dropped = [
                i for i in range(side.key_arity) if i not in needed
            ]
            if not dropped or est.is_coo.get(side.id, False):
                continue
            side_bytes = est.sizes.get(side.id, 0.0)
            sd = est.dist.get(side.id)
            factor, from_stats = agg_shrink(
                side.key_arity,
                KeyFn(tuple(In(i) for i in sorted(needed))),
                sd,
            )
            post = side_bytes / factor
            sel = _match_fraction(join, est)
            fired = (
                side_bytes > 0.0
                and post * self.rules.min_shrink <= side_bytes * sel
            )
            detail = (
                f"drop {side_name}[{','.join(map(str, dropped))}], "
                f"shrink {factor:g}x"
                + (" (stats)" if from_stats else " (heuristic)")
                + (f", join match {sel:.2f}" if sel < 1.0 else "")
            )
            decision = Decision(
                rule="sigma_pushdown",
                site=join.describe(),
                fired=fired,
                est_before=side_bytes * sel,
                est_after=post if fired else side_bytes * sel,
                detail=detail,
            )
            self.report.decisions.append(decision)
            if fired:
                plans.append((side_name, side_cls, side, sorted(needed)))
        if not plans:
            return None
        if len(plans) == 2 and "sigma_split" not in self.rules:
            # split disabled: push only the side with the bigger win
            plans.sort(
                key=lambda p: est.sizes.get(p[2].id, 0.0), reverse=True
            )
            plans = plans[:1]
        if len(plans) == 2:
            self.report.decisions.append(
                Decision(
                    rule="sigma_split",
                    site=join.describe(),
                    fired=True,
                    est_before=est.sizes.get(join.id, 0.0),
                    est_after=est.sizes.get(join.id, 0.0),
                    detail="partial Σ pushed into both branches",
                )
            )

        new_left, new_right = join.left, join.right
        pred_eqs = join.pred.eqs
        proj_comps = proj_eff.comps
        for side_name, side_cls, side, kept in plans:
            new_idx = {old: new for new, old in enumerate(kept)}
            inner_grp = KeyFn(tuple(In(i) for i in kept))
            # cascade: the partial Σ may push further down a join chain
            inner = self._make_agg(inner_grp, kernel, side)
            if side_name == "left":
                new_left = inner
            else:
                new_right = inner
            pred_eqs = tuple(
                (
                    _remap_side(a, side_cls, new_idx),
                    _remap_side(b, side_cls, new_idx),
                )
                for a, b in pred_eqs
            )
            proj_comps = tuple(
                _remap_side(c, side_cls, new_idx) for c in proj_comps
            )
        new_join = fra.Join(
            JoinPred(pred_eqs),
            JoinProj(proj_comps),
            join.kernel,
            new_left,
            new_right,
        )
        est.note(new_join)
        # the join can still merge output keys (e.g. the contracted join
        # class is dropped from proj_eff): keep an outer Σ over the fused
        # projection — the compiler fuses it into the join's einsum
        outer = fra.Agg(
            KeyFn(tuple(In(i) for i in range(len(proj_comps)))),
            kernel,
            new_join,
        )
        est.note(outer)
        return outer

    def _make_agg(self, grp: KeyFn, kernel, child: fra.Node) -> fra.Node:
        """Build Σ(grp, child), recursively attempting pushdown when the
        child is itself a (non-shared) join — the cascade down multi-join
        chains. Nodes the rewriter created are never shared, so missing
        parent counts default to 1."""
        if (
            isinstance(child, fra.Join)
            and self.parents.get(child.id, 1) <= 1
        ):
            pushed = self._try_pushdown(grp, kernel, child)
            if pushed is not None:
                return pushed
        out = fra.Agg(grp, kernel, child)
        self.est.note(out)
        return out


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------


def rewrite_query(
    query: fra.Query,
    env: Dict[str, object],
    *,
    stats: Optional[Dict[str, RelationStats]] = None,
    rules: Optional[RuleSet] = DEFAULT_RULES,
) -> Tuple[fra.Query, RewriteReport]:
    """Apply the enabled rules to ``query`` bottom-up. Returns the
    rewritten query and the gate report; when nothing fires the
    *original* query object is returned (``report.changed`` False), so
    downstream plan/lowering caches see a bit-identical program."""
    report = RewriteReport()
    if rules is None:
        return query, report
    root = query.root
    if "dedup" in rules:
        root, merged = dedup(root)
        if merged:
            report.decisions.append(
                Decision(
                    rule="dedup",
                    site=query.root.describe(),
                    fired=True,
                    est_before=float(merged),
                    est_after=0.0,
                    detail=f"{merged} duplicate subplan(s) merged",
                )
            )
    est = _Estimator(estimate_graph(root, env, stats))
    parents: Dict[int, int] = {}
    for node in root.topo():
        for c in node.children:
            parents[c.id] = parents.get(c.id, 0) + 1
    rw = _Rewriter(est, parents, rules, report)
    new_root = rw.rewrite(root)
    if new_root is query.root:
        return query, report
    report.changed = True
    return fra.Query(new_root, query.inputs), report


def _partial_rjp_sites(program) -> int:
    """Count general-path partial-RJP joins (autodiff._partial_bin
    kernels, named ``partial{l,r}[...]``) across a program's gradient
    graphs — the fallback taken when an RJP has no multiplicative
    solution."""
    count = 0
    for g in program.grads.values():
        for n in g.topo():
            if isinstance(n, fra.Join) and n.kernel.name.startswith("partial"):
                count += 1
    return count


def rewrite_program(
    program,
    env: Dict[str, object],
    *,
    stats: Optional[Dict[str, RelationStats]] = None,
    rules: Optional[RuleSet] = DEFAULT_RULES,
):
    """Rewrite a ``GradientProgram``'s forward query and re-derive the
    gradient graphs from the rewritten forward (same ``wrt``, same
    ``RJPOptions``) — gradients are taken *of the rewritten program*, so
    its ``__fwd_*`` cache refs and partial-aggregate VJPs line up with
    what the forward pass actually computes. A plain ``fra.Query`` is
    rewritten directly. Unchanged programs come back as the original
    object (bit-identical decline path).

    The rewrite must leave gradients no harder to derive than they
    were: a pushed-down Σ∘⋈ pair whose RJP loses its multiplicative
    solution would force the general partial-RJP fallback — a strictly
    larger gradient plan the chunked compiler cannot always lower. When
    re-derivation introduces partial-RJP sites the original derivation
    did not have, the whole rewrite is reverted (original program
    object, bit-identical plans) and the reversion is recorded in the
    report."""
    from .autodiff import GradientProgram, ra_autodiff

    if isinstance(program, fra.Query):
        return rewrite_query(program, env, stats=stats, rules=rules)
    if not isinstance(program, GradientProgram):
        raise TypeError(f"cannot rewrite program of type {type(program)}")
    fwd, report = rewrite_query(
        program.forward, env, stats=stats, rules=rules
    )
    if not report.changed:
        return program, report
    rewritten = ra_autodiff(fwd, wrt=program.wrt, opts=program.opts)
    if _partial_rjp_sites(rewritten) > _partial_rjp_sites(program):
        report.changed = False
        report.decisions.append(
            Decision(
                rule="grad_check",
                site=program.forward.root.describe(),
                fired=False,
                est_before=0.0,
                est_after=0.0,
                detail=(
                    "rewrite reverted: the factorized forward forces the "
                    "general partial-RJP fallback on a gradient"
                ),
            )
        )
        return program, report
    return rewritten, report
