"""Database session: the one front door from SQL to compiled gradient step.

The paper's pitch is that ML *is* a relational computation run by a
database engine — so the user-facing surface should look like a database,
not like a bag of engine internals. ``Database`` (re-exported as
``repro.Database``) is that surface: a session object owning the
**catalog** a real relational system keeps —

  * named relations with schemas (key attribute names),
  * **tracked key-domain statistics** per relation
    (``planner.RelationStats``: distinct key counts, key-domain extents,
    nnz/density for COO layouts), refreshed on ``db.put`` and cheap to
    snapshot,
  * the physical layout each compiled plan committed a relation to,
  * the active mesh and the kernel dispatch table

— and one coherent query path::

    db = repro.Database(mesh="host:2")
    db.put("Rx", X, keys=("row", "col"))
    db.put("Ry", y, keys=("row",))
    db.put("theta", theta, keys=("col",))
    handle = db.sql(LOGREG_SQL, wrt=("theta",))   # or db.query(fra_query)
    loss = handle.forward()
    grads = handle.grad()                         # RA-autodiff, compiled
    loss, grads = handle.step(donate=("theta",))  # the training hot path

``forward`` / ``grad`` / ``step`` all lower → plan → compile through the
staged engine (core/engine.py), but source *everything the planner
needs* from the catalog: relation environments by name, the statistics
snapshot that replaces the planner's Agg-size / edge-cut heuristics, the
session mesh, the dispatch table, and the committed-layout record that
guarantees plan stability across calls (``Lowered.compile_auto``).

Sessions also run the cost-gated algebraic rewrite stage
(core/rewrite.py) ahead of planning — Σ-through-⋈ pushdown, Σ-split,
common-subplan dedup, each priced against the catalog's tracked
statistics — and ``db.explain(query)`` shows the before/after trees with
every gate verdict.
"""

from __future__ import annotations

import contextlib
import contextvars
import copy
import warnings
import weakref
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterator, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from . import chunkstore as _chunkstore
from . import engine as _engine
from . import fra, kernels, planner
from . import rewrite as _rewrite
from . import sql as _sql
from .autodiff import GradientProgram, ra_autodiff
from .relation import CooRelation, DenseRelation, measure_stats

AnyRel = Union[DenseRelation, CooRelation]


class CatalogError(KeyError):
    """A query referenced a relation the session's catalog does not hold
    (or holds in an unusable state, e.g. donated to a compiled step)."""

    def __str__(self) -> str:  # KeyError repr()s its args; keep prose
        return self.args[0] if self.args else ""


@dataclass
class TableEntry:
    """One catalog row: a named relation plus everything the optimizer
    and the SQL frontend know about it."""

    name: str
    relation: AnyRel
    #: key attribute names (the SQL schema; positional order = key dims).
    key_attrs: Tuple[str, ...]
    #: tracked key-domain statistics (refreshed on ``Database.put``).
    stats: planner.RelationStats
    #: the PartitionSpec the last compiled plan committed this relation
    #: to (None until a mesh-compiled step placed it).
    layout: Optional[Any] = None
    #: True once the relation's buffers were donated to a compiled step —
    #: the entry must be re-``put`` before it can be read again.
    donated: bool = False


@dataclass
class ModelEntry:
    """One row of the catalog's model registry: a served model under a
    ``name@version`` coordinate. The serving front door
    (``Database.endpoint``) resolves every request — including per-tenant
    aliases — through these entries, so re-registering a version swaps
    the served parameters without touching the endpoint."""

    name: str
    version: str
    model: Any
    params: Any

    @property
    def key(self) -> Tuple[str, str]:
        return (self.name, self.version)

    def __str__(self) -> str:
        return f"{self.name}@{self.version}"


class Catalog:
    """Named relations + schemas + statistics + committed layouts — the
    structure a database optimizer consults on every query — plus the
    model registry the serving front door resolves requests through."""

    def __init__(self) -> None:
        self._tables: "OrderedDict[str, TableEntry]" = OrderedDict()
        #: name → version → ModelEntry (insertion order; last = latest).
        self._models: "OrderedDict[str, OrderedDict[str, ModelEntry]]" = (
            OrderedDict()
        )

    def __contains__(self, name: str) -> bool:
        return name in self._tables

    def __iter__(self) -> Iterator[str]:
        return iter(self._tables)

    def __len__(self) -> int:
        return len(self._tables)

    def entry(self, name: str) -> TableEntry:
        try:
            return self._tables[name]
        except KeyError:
            raise CatalogError(
                f"relation {name!r} is not in the catalog "
                f"(tables: {sorted(self._tables)}); db.put(...) it first"
            ) from None

    def items(self):
        return self._tables.items()

    def put(
        self,
        name: str,
        relation: AnyRel,
        key_attrs: Optional[Sequence[str]] = None,
        *,
        refresh_stats: bool = True,
    ) -> TableEntry:
        prev = self._tables.get(name)
        if key_attrs is None:
            if prev is not None and len(prev.key_attrs) == relation.key_arity:
                key_attrs = prev.key_attrs  # keep the declared schema
            else:
                key_attrs = tuple(f"k{i}" for i in range(relation.key_arity))
        key_attrs = tuple(key_attrs)
        if len(key_attrs) != relation.key_arity:
            raise ValueError(
                f"relation {name!r}: {len(key_attrs)} key attribute name(s) "
                f"{key_attrs} for key arity {relation.key_arity}"
            )
        if refresh_stats or prev is None:
            stats = measure_stats(relation)
        else:
            stats = prev.stats
        entry = TableEntry(name, relation, key_attrs, stats)
        if prev is not None:
            entry.layout = prev.layout
        self._tables[name] = entry
        return entry

    def drop(self, name: str) -> None:
        self._tables.pop(name, None)

    def schema(self) -> Dict[str, Tuple[str, ...]]:
        """{relation: key attribute names} — what ``compile_sql`` takes."""
        return {n: e.key_attrs for n, e in self._tables.items()}

    def snapshot(
        self, names: Optional[Sequence[str]] = None
    ) -> Dict[str, planner.RelationStats]:
        """Cheap, hashable statistics snapshot for the planner (the
        ``stats=`` argument of ``plan_query`` / ``Lowered.compile``).
        ``names`` restricts the snapshot to the given relations — query
        handles pass their own base relations so that the snapshot (a
        compile cache key component) is insensitive to updates of
        unrelated catalog tables."""
        if names is None:
            return {n: e.stats for n, e in self._tables.items()}
        return {
            n: self._tables[n].stats for n in names if n in self._tables
        }

    def record_layout(self, name: str, spec) -> None:
        e = self._tables.get(name)
        if e is not None:
            e.layout = spec

    # -- model registry (the serving front door resolves through this) -----

    def put_model(
        self, name: str, model, params, version: Optional[str] = None
    ) -> ModelEntry:
        """Register (or update) a served model version. ``version``
        defaults to ``v<n+1>``; re-registering an existing version swaps
        its model/params in place (live endpoints pick the new parameters
        up on the next batch they form)."""
        versions = self._models.setdefault(name, OrderedDict())
        if version is None:
            version = f"v{len(versions) + 1}"
        entry = ModelEntry(name, str(version), model, params)
        versions[entry.version] = entry
        versions.move_to_end(entry.version)
        return entry

    def model(self, name: str, version: Optional[str] = None) -> ModelEntry:
        """Resolve ``name[@version]`` to a registered ModelEntry (latest
        registered version when ``version`` is None)."""
        if version is None and "@" in name:
            name, _, version = name.partition("@")
        try:
            versions = self._models[name]
        except KeyError:
            raise CatalogError(
                f"model {name!r} is not registered (models: "
                f"{sorted(self._models)}); db.register_model(...) it first"
            ) from None
        if version is None:
            return next(reversed(versions.values()))
        try:
            return versions[str(version)]
        except KeyError:
            raise CatalogError(
                f"model {name!r} has no version {version!r} "
                f"(versions: {list(versions)})"
            ) from None

    def models(self) -> Dict[str, Tuple[str, ...]]:
        """{model name: registered versions, oldest→latest}."""
        return {n: tuple(v) for n, v in self._models.items()}


# ---------------------------------------------------------------------------
# The session
# ---------------------------------------------------------------------------

#: ambient session stack (ContextVar: concurrent threads/tasks see only
#: their own ``Database.activate`` nesting), plus one lazily created
#: process-default session for the relational operator layer.
_SESSION_STACK: "contextvars.ContextVar[Tuple[Database, ...]]" = (
    contextvars.ContextVar("repro_session_stack", default=())
)
_PROCESS_DEFAULT: Optional["Database"] = None


def current() -> "Database":
    """The ambient session: the innermost ``Database.activate`` block's
    session, else a process-wide default ``Database()``. The relational
    operator layer (``rel_matmul``, ``gcn_conv``, ``rel_embed``) steps
    through this, so activating a session distributes those ops on its
    mesh without new arguments crossing the ``custom_vjp`` boundary."""
    stack = _SESSION_STACK.get()
    if stack:
        return stack[-1]
    global _PROCESS_DEFAULT
    if _PROCESS_DEFAULT is None:
        _PROCESS_DEFAULT = Database()
    return _PROCESS_DEFAULT


#: field layout of the per-executable reshard counters (engine.Compiled).
_RESHARD_KEYS = (
    "calls", "resharded_calls", "bytes_moved", "last_call_bytes",
    "planned_bytes",
)


def _serve_counters() -> Dict[str, Any]:
    """Zeroed ``serve/`` subtree of the unified counter tree — the async
    serving front door (serving/service.py) increments these."""
    return {
        "requests": 0,        # submitted to an endpoint on this session
        "admitted": 0,        # passed the bounded admission queue
        "completed": 0,       # futures resolved with a Completion
        "failed": 0,          # futures resolved with an error
        "shed_queue_full": 0,  # rejected: admission queue at max_queue
        "shed_deadline": 0,    # rejected: deadline passed before service
        "batches": 0,          # coalesced prefill batches executed
        "batched_requests": 0,  # requests that shared a batch (size > 1)
        "queue_peak": 0,       # high-water admission queue depth
        "prefill": {"compiles": 0, "steps": 0},
        "decode": {
            "compiles": 0,      # decode executables built (per bucket)
            "traces": 0,        # decode retraces (≤ one per bucket)
            "steps": 0,         # decode steps executed
            "rebuckets": 0,     # mid-decode compactions to a smaller bucket
            "slot_releases": 0,  # slots freed by finished requests
            "eos_stops": 0,      # slots released early on an EOS token
        },
    }


class Database:
    """A session: catalog + statistics + active mesh + dispatch table,
    and the one query path from SQL (or FRA) to a compiled gradient step.

    ``mesh`` is a jax Mesh, a ``launch/mesh.resolve_mesh`` spec string
    (``"host"``, ``"host:<model>"``, ``"production"``,
    ``"production:multipod"``), or None (single-device; an ambient
    session mesh still applies). ``dispatch`` takes anything
    ``kernels.make_table`` accepts and pins the kernel tier for every
    query compiled in this session. ``rewrite`` configures the
    cost-gated algebraic rewrite stage run ahead of planning (anything
    ``rewrite.make_rules`` accepts: True — the default — enables the
    full rule set, False disables the stage, a ``rewrite.RuleSet`` or an
    iterable of rule names selects rules). ``max_cache_entries`` bounds
    the session's executable cache (LRU) — the serving batch cache rides
    on it; None = unbounded.
    """

    def __init__(
        self,
        mesh=None,
        *,
        dispatch=None,
        mem_budget: Optional[float] = None,
        memory_budget: Optional[float] = None,
        fuse_join_agg: bool = True,
        rewrite=True,
        max_cache_entries: Optional[int] = None,
    ) -> None:
        self.catalog = Catalog()
        #: the session's enabled rewrite rules (None = stage off).
        self.rewrite_rules = _rewrite.make_rules(rewrite)
        self._mesh_spec = mesh
        self._mesh_resolved = mesh is None or not isinstance(mesh, str)
        self._mesh = None if isinstance(mesh, str) else mesh
        self.dispatch = kernels.make_table(dispatch)
        self.mem_budget = (
            planner.DEFAULT_MEM_BUDGET if mem_budget is None else mem_budget
        )
        #: out-of-core *device-memory* budget in bytes (distinct from
        #: ``mem_budget``, the planner's per-device plan-feasibility
        #: budget): when a step's environment exceeds it, the largest
        #: base relation is spilled to the host-resident ChunkStore and
        #: streamed through the step in chunk waves. None (default)
        #: disables spilling entirely — plans and results are
        #: bit-identical to an unbudgeted session.
        self.memory_budget = memory_budget
        self._chunkstore = _chunkstore.ChunkStore()
        self.fuse_join_agg = fuse_join_agg
        self.max_cache_entries = max_cache_entries
        self._exec_cache: "OrderedDict[Any, Any]" = OrderedDict()
        #: the session's unified telemetry tree (``db.counters()``); the
        #: ``cache`` and ``serve`` subtrees live here, ``reshard`` is
        #: aggregated over compiled executables and ``spill`` read off the
        #: ChunkStore at snapshot time.
        self._counters: Dict[str, Any] = {
            "cache": {"hits": 0, "misses": 0, "evictions": 0},
            "serve": _serve_counters(),
        }
        #: every executable this session compiled (weak — engine caches
        #: keep live ones alive), for the reshard counter aggregate.
        self._compiled_refs: "weakref.WeakSet" = weakref.WeakSet()

    # -- catalog front door ------------------------------------------------

    def put(
        self,
        name: str,
        value,
        *,
        keys: Optional[Sequence[str]] = None,
        key_arity: Optional[int] = None,
        refresh_stats: bool = True,
    ) -> "Database":
        """Register (or update) a named relation and refresh its tracked
        statistics. ``value`` is a relation, or a raw array made into a
        ``DenseRelation`` whose key arity is ``len(keys)`` (or
        ``key_arity``) — the leading dims are the key grid, the rest the
        tuple chunk::

            db.put("Rx", X, keys=("row", "col"))     # (n, m) array
            db.put("Edge", coo_relation)             # relation as-is

        ``refresh_stats=False`` keeps the previous statistics (skip the
        COO distinct-count pass when only values changed). Returns the
        session for chaining."""
        if not isinstance(value, (DenseRelation, CooRelation)):
            arr = jnp.asarray(value)
            if keys is not None:
                arity = len(tuple(keys))
            elif key_arity is not None:
                arity = key_arity
            else:
                arity = arr.ndim
            value = DenseRelation(arr, arity)
        if (
            self.memory_budget is not None
            and planner._rel_bytes(value) > self.memory_budget
        ):
            # host tier: a relation bigger than the device budget is kept
            # as host numpy — statistics, signatures and abstract lowering
            # all work on numpy payloads, and the wave executor splits
            # host-side anyway, so nothing forces it onto the device
            if isinstance(value, DenseRelation):
                value = DenseRelation(np.asarray(value.data), value.key_arity)
            else:
                value = CooRelation(
                    np.asarray(value.keys),
                    np.asarray(value.values),
                    value.extents,
                    value.owner_dim,
                    value.shard_offsets,
                )
        self.catalog.put(name, value, keys, refresh_stats=refresh_stats)
        return self

    def get(self, name: str) -> AnyRel:
        """The named relation (raises ``CatalogError`` when absent or
        when its buffers were donated to a compiled step)."""
        e = self.catalog.entry(name)
        if e.donated:
            raise CatalogError(
                f"relation {name!r} was donated to a compiled step; "
                f"db.put(...) its updated value before reading it again"
            )
        return e.relation

    def __contains__(self, name: str) -> bool:
        return name in self.catalog

    def drop(self, name: str) -> None:
        self.catalog.drop(name)

    def stats(self, name: str) -> planner.RelationStats:
        """The tracked key-domain statistics of one relation."""
        return self.catalog.entry(name).stats

    def schema(self, name: str) -> Tuple[str, ...]:
        """The key attribute names of one relation."""
        return self.catalog.entry(name).key_attrs

    def layout(self, name: str):
        """The PartitionSpec the last compiled plan committed the
        relation to (None before any mesh-compiled step)."""
        return self.catalog.entry(name).layout

    # -- model registry + the serving front door ---------------------------

    def register_model(
        self, name: str, model, params, *, version: Optional[str] = None
    ) -> ModelEntry:
        """Register a model version in the catalog's model registry —
        what the serving front door (``db.endpoint``) resolves request
        model/tenant coordinates through. ``version`` defaults to
        ``v<n+1>``; re-registering a version hot-swaps its parameters
        (live endpoints serve the new ones from the next batch on)."""
        return self.catalog.put_model(name, model, params, version)

    def model(self, name: str, version: Optional[str] = None) -> ModelEntry:
        """Resolve ``name`` (or ``"name@version"``) from the model
        registry — latest registered version when unversioned."""
        return self.catalog.model(name, version)

    def endpoint(self, model=None, **kwargs) -> "Any":
        """The serving front door: an async ``Endpoint`` over this
        session — continuous batching of concurrent requests into the
        session's (batch, seq) bucketed executables, decode-step
        bucketing, per-tenant model versions resolved through the
        catalog's model registry, and bounded-queue/deadline load
        shedding counted under ``db.counters()["serve"]``.

        ``model`` is a registered model name (``"lm"`` / ``"lm@v2"``) or
        a Model instance (auto-registered; pass ``params=``). See
        ``repro.serving.service.Endpoint`` for the keyword surface
        (``cache_len``, ``buckets``, ``decode_buckets``, ``max_queue``,
        ``gather_window``, ...)."""
        from repro.serving.service import Endpoint

        return Endpoint(self, model, **kwargs)

    # -- unified telemetry -------------------------------------------------

    def counters(self) -> Dict[str, Any]:
        """The session's telemetry tree — **one** structured surface for
        every counter the stack keeps, snapshotted (mutating the returned
        dict never touches live state)::

            {"cache":   {hits, misses, evictions},          # exec cache
             "reshard": {calls, resharded_calls, bytes_moved,
                         last_call_bytes, planned_bytes},   # aggregated
                                                            # over every
                                                            # compiled step
             "spill":   {spilled_relations, spilled_bytes,
                         fetched_chunks, fetched_bytes},    # out-of-core
             "serve":   {requests, admitted, completed, failed,
                         shed_queue_full, shed_deadline, batches,
                         batched_requests, queue_peak,
                         prefill: {compiles, steps},
                         decode:  {compiles, traces, steps, rebuckets,
                                   slot_releases, eos_stops}}}

        ``reshard`` sums the per-executable counters of every step this
        session compiled (``Compiled.counters["reshard"]``);
        ``last_call_bytes`` sums each live executable's most recent
        call. This is the single telemetry surface — the pre-unification
        accessors (``cache_stats``/``spill_stats``/``reshard_stats``)
        are gone."""
        reshard = dict.fromkeys(_RESHARD_KEYS, 0)
        for comp in list(self._compiled_refs):
            for k, v in comp.counters["reshard"].items():
                reshard[k] = reshard.get(k, 0) + v
        return {
            "cache": dict(self._counters["cache"]),
            "reshard": reshard,
            "spill": dict(self._chunkstore.stats),
            "serve": copy.deepcopy(self._counters["serve"]),
        }

    # -- the active mesh ---------------------------------------------------

    @property
    def mesh(self):
        """The session's active mesh (spec strings resolved lazily, so
        constructing a Database never touches jax device state)."""
        if not self._mesh_resolved:
            from repro.launch.mesh import resolve_mesh

            self._mesh = resolve_mesh(self._mesh_spec)
            self._mesh_resolved = True
        return self._mesh

    def use_mesh(self, mesh) -> "Database":
        """Re-point the session at a different mesh (spec string or jax
        Mesh). Compiled plans are cached per mesh, so switching back is
        cheap."""
        self._mesh_spec = mesh
        self._mesh_resolved = mesh is None or not isinstance(mesh, str)
        self._mesh = None if isinstance(mesh, str) else mesh
        return self

    def _step_mesh(self):
        """Mesh a step should compile against: the session mesh — or the
        ambient mesh of an enclosing activated session — outside traces;
        None under an active trace (the engine's ``_trace_clean`` probe
        is the single source of that rule)."""
        if self.mesh is not None:
            return self.mesh if _engine._trace_clean() else None
        return _engine._ambient_mesh()

    @contextlib.contextmanager
    def activate(self):
        """Make this the ambient session of the block: the relational
        operator layer (and any code calling ``session.current()``)
        plans, dispatches and distributes through it."""
        token = _SESSION_STACK.set(_SESSION_STACK.get() + (self,))
        try:
            yield self
        finally:
            _SESSION_STACK.reset(token)

    # -- query front door --------------------------------------------------

    def sql(self, script: str, *, wrt: Sequence[str] = ()) -> "QueryHandle":
        """Compile a SQL script against the catalog's schemas and return
        a differentiable ``QueryHandle``. ``wrt`` names the relations to
        treat as differentiable inputs (everything else is constant
        data); table and column references resolve against the key
        attribute names declared via ``db.put(..., keys=...)``."""
        query = _sql.compile_sql(
            script, schema=self.catalog.schema(), inputs=tuple(wrt)
        )
        return QueryHandle(self, query)

    def query(
        self, q: Union[fra.Query, fra.Node], *, wrt: Optional[Sequence[str]] = None
    ) -> "QueryHandle":
        """Wrap an FRA query (or bare graph root) built in code. ``wrt``
        defaults to the query's declared inputs (for a bare node: its
        table scans)."""
        if isinstance(q, fra.Node):
            inputs = tuple(sorted({s.name for s in q.table_scans()}))
            q = fra.Query(q, inputs)
        if wrt is not None:
            missing = set(wrt) - set(q.inputs)
            if missing:
                raise ValueError(
                    f"wrt relations {sorted(missing)} are not inputs of the "
                    f"query (inputs: {q.inputs})"
                )
        return QueryHandle(self, q, default_wrt=None if wrt is None else tuple(wrt))

    def check(self, q: Union[fra.Query, fra.Node], *, wrt: Sequence[str] = ()):
        """Statically check an FRA query (or bare graph root) against the
        catalog: the typed checker (``repro.analysis.typecheck``) infers
        schemas/shapes/dtypes bottom-up and returns a ``CheckReport`` of
        node-path diagnostics — compiler-guaranteed failures as errors
        (bad join keys, non-permutation σ, non-additive Σ, COO ⋈ COO...),
        hazards as warnings (f32→f64 promotion, statically empty
        selections, stale statistics, non-divisible sharded extents,
        partial-RJP gradients for ``wrt`` inputs). Relations, statistics,
        key-attribute names and the mesh geometry are sourced from the
        catalog exactly as a compiled step would source them. Purely
        observational — nothing is lowered or cached; the same checker
        runs as the engine's mandatory validate stage, which *raises* on
        the error-severity findings reported here."""
        from repro.analysis.typecheck import check_query

        if isinstance(q, fra.Node):
            q = fra.Query(
                q, tuple(sorted({s.name for s in q.table_scans()}))
            )
        names = _base_names([q.root])
        env = {
            n: self.catalog.entry(n).relation
            for n in names
            if n in self.catalog
        }
        stats = self.catalog.snapshot(names)
        schema = self.catalog.schema()
        mesh = self.mesh
        geometry = (
            planner.MeshGeometry.from_mesh(mesh) if mesh is not None else None
        )
        return check_query(
            q,
            env,
            stats=stats,
            schema=schema,
            geometry=geometry,
            wrt=tuple(wrt),
            fuse_join_agg=self.fuse_join_agg,
        )

    def explain(self, q: Union[fra.Query, fra.Node]) -> str:
        """What the rewrite stage would do to ``q`` against the current
        catalog: the query tree before, every cost-gate verdict (with the
        byte estimates the gate compared), and the tree after. Relations
        and their tracked statistics are sourced from the catalog exactly
        as ``forward``/``grad``/``step`` would source them, so the
        verdicts shown are the ones a compiled step takes. Observational
        with one exception: when the typed check is clean the query is
        *lowered* (never planned or executed) so the kernel certifier
        (``repro.analysis.kernelcheck``) can prove the exact dispatch
        sites the plan resolved — the Lowered and its certification
        report land in the engine's ordinary caches, which a later
        ``forward``/``grad``/``step`` reuses."""
        if isinstance(q, fra.Node):
            q = fra.Query(
                q, tuple(sorted({s.name for s in q.table_scans()}))
            )
        names = _base_names([q.root])
        env = {n: self.get(n) for n in names}
        stats = self.catalog.snapshot(names)
        rules = (
            self.rewrite_rules
            if self.rewrite_rules is not None
            else _rewrite.DEFAULT_RULES
        )
        rewritten, report = _rewrite.rewrite_query(
            q, env, stats=stats, rules=rules
        )
        lines = ["before:"]
        lines += ["  " + ln for ln in q.root.pretty().splitlines()]
        lines.append("rewrite decisions:")
        lines += ["  " + ln for ln in report.render().splitlines()]
        if self.rewrite_rules is None:
            lines.append("  (session rewrite stage is OFF: plan unchanged)")
            lines.append("after: (unchanged)")
        elif not report.changed:
            lines.append("after: (unchanged)")
        else:
            lines.append("after:")
            lines += [
                "  " + ln for ln in rewritten.root.pretty().splitlines()
            ]
        lines.append("diagnostics:")
        report = self.check(q)
        if report.diagnostics:
            lines += ["  " + ln for ln in report.render().splitlines()]
        else:
            lines.append("  (none)")
        lines.append("kernel certification:")
        if report.errors:
            lines.append("  (skipped: typed check failed)")
        else:
            from repro.analysis import kernelcheck as _kernelcheck

            eng = _engine.engine_for(q, fuse_join_agg=self.fuse_join_agg)
            low = eng.lower(
                env,
                dispatch=self.dispatch,
                stats=stats,
                rewrite=self.rewrite_rules,
            )
            kreport = _kernelcheck.certify_kernels(low)
            sites = len(getattr(low.resolutions, "sites", ()))
            lines.append(
                f"  {sites} dispatch site(s): " + kreport.render().splitlines()[0]
            )
            if kreport.diagnostics:
                lines += [
                    "  " + ln for ln in kreport.render().splitlines()[1:]
                ]
        return "\n".join(lines)

    # -- staged execution (the engine underneath) --------------------------

    def _compiled_for(
        self,
        program,
        env: Dict[str, AnyRel],
        seed: Optional[AnyRel] = None,
        *,
        donate: Tuple[str, ...] = (),
        stats: Optional[Dict[str, planner.RelationStats]] = None,
    ):
        eng = _engine.engine_for(program, fuse_join_agg=self.fuse_join_agg)
        if self.memory_budget is not None:
            fwd = eng.forward_query
            wave_plan = planner.plan_waves(fwd, env, self.memory_budget)
            if wave_plan is not None:
                if donate:
                    raise _chunkstore.OutOfCoreError(
                        f"cannot donate {sorted(donate)} while streaming "
                        "chunk waves: the buffers are reused across waves"
                    )

                def compile_wave(wave_env, wave_seed):
                    wstats = self._catalog_stats_for(wave_env)
                    wlow = eng.lower(
                        wave_env,
                        wave_seed,
                        dispatch=self.dispatch,
                        stats=wstats,
                        rewrite=self.rewrite_rules,
                    )
                    return wlow.compile_auto(
                        wave_env,
                        mesh=self._step_mesh(),
                        stats=wstats,
                        mem_budget=self.mem_budget,
                    )

                def lower_full(full_env, full_seed):
                    return eng.lower(
                        full_env,
                        full_seed,
                        dispatch=self.dispatch,
                        stats=stats,
                        rewrite=self.rewrite_rules,
                    )

                streamed = _engine.StreamedCompiled(
                    wave_plan, self._chunkstore, compile_wave, lower_full
                )
                self._compiled_refs.add(streamed)
                return streamed
        low = eng.lower(
            env,
            seed,
            dispatch=self.dispatch,
            stats=stats,
            rewrite=self.rewrite_rules,
        )
        compiled = low.compile_auto(
            env,
            mesh=self._step_mesh(),
            donate=donate,
            stats=stats,
            mem_budget=self.mem_budget,
        )
        self._compiled_refs.add(compiled)
        return compiled

    def _catalog_stats_for(
        self, env: Dict[str, AnyRel]
    ) -> Optional[Dict[str, planner.RelationStats]]:
        """Tracked statistics for the env relations that match a catalog
        table of the same name, layout class and key-domain extents — the
        guard that lets anonymous wrapper environments (whose names are
        program-local, e.g. the GCN's ``Edge``/``Node``) pick up catalog
        statistics without a same-named but unrelated table leaking in."""
        out: Dict[str, planner.RelationStats] = {}
        for name, rel in env.items():
            if name not in self.catalog:
                continue
            e = self.catalog.entry(name)
            if (
                type(rel) is type(e.relation)
                and rel.key_arity == len(e.stats.distinct)
                and tuple(int(x) for x in rel.extents) == e.stats.extents
            ):
                out[name] = e.stats
        return out or None

    def execute(
        self,
        program,
        env: Dict[str, AnyRel],
        seed: Optional[AnyRel] = None,
        *,
        donate: Tuple[str, ...] = (),
        stats: Optional[Dict[str, planner.RelationStats]] = None,
    ):
        """Staged execution of a program over an *anonymous* environment
        (relations passed directly rather than named in the catalog) —
        the path the relational operator layer steps through. Uses the
        session's mesh, dispatch table and memory budget, auto-threads
        committed layouts (``Lowered.compile_auto``) so repeated calls
        neither re-plan nor silently reshard, and — when an env relation
        matches a registered catalog table by name, layout class and
        extents — sources that relation's tracked statistics for the
        planner (register e.g. a GCN edge relation with ``db.put`` to get
        statistics-priced scatter plans out of the wrapper ops)."""
        if stats is None:
            stats = self._catalog_stats_for(env)
        compiled = self._compiled_for(
            program, env, seed, donate=donate, stats=stats
        )
        return compiled(env, seed)

    # -- session executable cache (serving batch buckets etc.) -------------

    def cached_executable(self, key, build: Callable[[], Any]):
        """One compiled executable per ``key`` in the session's LRU
        cache: returns the cached value (a hit), or ``build()``'s result
        after inserting it (a miss), evicting least-recently-used entries
        beyond ``max_cache_entries``. ``db.counters()["cache"]`` counts
        hits, misses and evictions — the serving front door asserts on
        them."""
        cache = self._counters["cache"]
        hit = self._exec_cache.get(key)
        if hit is not None:
            self._exec_cache.move_to_end(key)
            cache["hits"] += 1
            return hit
        cache["misses"] += 1
        val = build()
        self._exec_cache[key] = val
        if self.max_cache_entries is not None:
            while len(self._exec_cache) > self.max_cache_entries:
                self._exec_cache.popitem(last=False)
                cache["evictions"] += 1
        return val


# ---------------------------------------------------------------------------
# QueryHandle: a differentiable, compiled query over the catalog
# ---------------------------------------------------------------------------


def _base_names(roots) -> Tuple[str, ...]:
    """Base-relation names a set of graph roots read from the catalog:
    TableScan names plus Const refs, excluding the engine-internal
    ``__seed`` / ``__fwd_*`` references."""
    names = set()
    for root in roots:
        for node in root.topo():
            if isinstance(node, fra.TableScan):
                names.add(node.name)
            elif isinstance(node, fra.Const) and not node.ref.startswith("__"):
                names.add(node.ref)
    return tuple(sorted(names))


class QueryHandle:
    """A differentiable query bound to a session's catalog.

    ``forward()`` runs the query; ``grad(wrt=...)`` runs the
    RA-autodiff-generated gradient queries; ``step(donate=...)`` is the
    training hot path — forward + all gradients in one compiled
    executable, optionally donating parameter buffers. All three source
    relations, statistics, mesh, dispatch table and committed layouts
    from the catalog, and cache their compiled executables across calls
    (``trace_count`` stays flat; plans are bit-stable under
    ``compile_auto``)."""

    def __init__(
        self,
        db: Database,
        query: fra.Query,
        *,
        default_wrt: Optional[Tuple[str, ...]] = None,
    ):
        self.db = db
        self.query = query
        #: default gradient targets when grad/step get no ``wrt``.
        self.default_wrt = default_wrt
        self._grad_progs: Dict[Tuple[str, ...], GradientProgram] = {}
        self._full_prog: Optional[GradientProgram] = None
        #: the most recently used Compiled (plans/placements/resolutions).
        self.last: Optional[Any] = None

    def check(self, *, wrt: Optional[Sequence[str]] = None):
        """``db.check`` on this handle's query (see ``Database.check``);
        ``wrt`` defaults to the handle's gradient targets, so partial-RJP
        warnings cover exactly the inputs ``grad``/``step`` would
        differentiate."""
        if wrt is None:
            wrt = self.default_wrt or self.query.inputs
        return self.db.check(self.query, wrt=tuple(wrt))

    # -- environments off the catalog -------------------------------------

    def _env(self, names: Sequence[str]) -> Dict[str, AnyRel]:
        return {n: self.db.get(n) for n in names}

    def _record(self, compiled, names: Sequence[str]) -> None:
        self.last = compiled
        if compiled.mesh is not None:
            for n in names:
                spec = compiled.planned_spec(n)
                if spec is not None and n in self.db.catalog:
                    self.db.catalog.record_layout(n, spec)

    # -- the three entry points --------------------------------------------

    def forward(self):
        """Execute the (forward) query; returns its output relation."""
        names = _base_names([self.query.root])
        env = self._env(names)
        compiled = self.db._compiled_for(
            self.query, env, stats=self.db.catalog.snapshot(names)
        )
        self._record(compiled, names)
        return compiled(env)

    def _program(self, wrt: Optional[Sequence[str]]) -> GradientProgram:
        if wrt is None:
            wrt = self.default_wrt
        if self._full_prog is None:
            if not self.query.inputs:
                raise ValueError(
                    "query has no differentiable inputs; pass wrt= to "
                    "db.sql(...) / declare inputs on the fra.Query"
                )
            self._full_prog = ra_autodiff(self.query)
        if wrt is None:
            return self._full_prog
        wrt = tuple(wrt)
        missing = set(wrt) - set(self._full_prog.grads)
        if missing:
            raise ValueError(
                f"no gradient for {sorted(missing)}; differentiable inputs "
                f"are {sorted(self._full_prog.grads)}"
            )
        prog = self._grad_progs.get(wrt)
        if prog is None:
            prog = GradientProgram(
                self._full_prog.forward,
                {n: self._full_prog.grads[n] for n in wrt},
                wrt,
            )
            self._grad_progs[wrt] = prog
        return prog

    def _seed_rel(self, seed) -> Optional[AnyRel]:
        if seed is None or isinstance(seed, (DenseRelation, CooRelation)):
            return seed
        return DenseRelation(jnp.asarray(seed), self.query.root.key_arity)

    def _run_grad(
        self,
        wrt: Optional[Sequence[str]],
        seed,
        donate: Tuple[str, ...],
    ):
        prog = self._program(wrt)
        names = _base_names(
            [prog.forward.root, *prog.grads.values()]
        )
        env = self._env(names)
        bad = set(donate) - set(env)
        if bad:
            raise ValueError(
                f"cannot donate {sorted(bad)}: not relations of this query "
                f"(env: {sorted(env)})"
            )
        seed_rel = self._seed_rel(seed)
        compiled = self.db._compiled_for(
            prog, env, seed_rel,
            donate=tuple(sorted(donate)),
            stats=self.db.catalog.snapshot(names),
        )
        self._record(compiled, names)
        out, grads = compiled(env, seed_rel)
        for n in donate:
            self.db.catalog.entry(n).donated = True
        return out, grads

    def grad(self, *, wrt: Optional[Sequence[str]] = None, seed=None):
        """Gradients of the query output w.r.t. the (``wrt``-selected)
        differentiable inputs: ``{name: relation}``. ``seed`` is the
        output cotangent (default: ones — requires a scalar-loss
        output); arrays are wrapped at the output's key arity."""
        _, grads = self._run_grad(wrt, seed, ())
        return grads

    def step(
        self,
        *,
        wrt: Optional[Sequence[str]] = None,
        seed=None,
        donate: Tuple[str, ...] = (),
    ):
        """One compiled training step: ``(output, gradients)`` from a
        single jitted executable. ``donate`` names catalog relations
        whose buffers the step may reuse (parameters on the hot path) —
        a donated relation must be re-``put`` before its next read, and
        the catalog enforces that."""
        return self._run_grad(wrt, seed, tuple(donate))

    # -- introspection -----------------------------------------------------

    def plan(
        self,
        *,
        geometry: Optional[planner.MeshGeometry] = None,
        n_devices: Optional[int] = None,
        use_stats: bool = True,
    ) -> Dict[int, planner.JoinPlan]:
        """Planning-only inspection: the physical ``JoinPlan`` per join
        the optimizer would choose for this query on a mesh of the given
        geometry, sourced from the catalog (set ``use_stats=False`` for
        the stats-less heuristic baseline — comparing the two shows what
        the tracked statistics changed)."""
        names = _base_names([self.query.root])
        env = self._env(names)
        if n_devices is None:
            n_devices = geometry.model_size if geometry is not None else 1
        return planner.plan_query(
            self.query,
            env,
            n_devices,
            mem_budget=self.db.mem_budget,
            geometry=geometry,
            stats=self.db.catalog.snapshot(names) if use_stats else None,
        )

    @property
    def plans(self) -> Dict[int, planner.JoinPlan]:
        """The physical plans of the most recent compiled executable."""
        if self.last is None:
            raise ValueError("no compiled step yet: call forward/grad/step")
        return self.last.plans

    @property
    def placements(self):
        """Per-relation {"data": dim, "model": dim} placements of the
        most recent compiled executable."""
        if self.last is None:
            raise ValueError("no compiled step yet: call forward/grad/step")
        return self.last.placements

    @property
    def resolutions(self) -> Dict[str, str]:
        """Kernel-dispatch decisions of the most recent executable."""
        if self.last is None:
            raise ValueError("no compiled step yet: call forward/grad/step")
        return self.last.resolutions
