"""Staged query engine: lower → plan → jit-compile.

The paper's systems claim (§1) is that a relational engine *automatically
distributes* differentiated queries: the optimizer picks a physical plan
per join, the execution engine inserts the implied collectives, and the
whole thing is compiled once and reused across training iterations. This
module is that pipeline, staged explicitly in the jax.stages idiom
(wrapped → lowered → compiled):

    RAEngine(program)             # FRA query / gradient program (wrapped)
        .lower(env)               # → Lowered: abstract-shape trace of the
                                  #   chunked lowering, cached per
                                  #   (graph, shapes/dtypes, dispatch
                                  #   table) signature
        .compile(mesh=...)        # → Compiled: planner.plan_query picks a
                                  #   JoinPlan per join — 2-D (data ×
                                  #   model) on a launch/mesh mesh — its
                                  #   PartitionSpecs become jax.jit
                                  #   in_shardings, XLA SPMD inserts the
                                  #   plan's collectives
    compiled(env)                 # jit-cached step: zero re-lowering

Kernel dispatch is part of the lowering: ``lower(env, dispatch=...)``
pins a kernels.DispatchTable (Pallas / interpret / ref / jnp tier per hot
op) into the lowering signature, so switching tiers re-lowers and jits a
distinct step — kernel choice can never alias a stale jit cache entry.
The decisions actually taken are recorded on ``Compiled.resolutions``.

``RAEngine.trace_count`` counts actual FRA-graph walks (lowerings). A
``Compiled`` step re-walks the graph only when jit retraces — i.e. never,
for a fixed environment signature; the engine-stage tests assert this.

Relations cross the jit boundary as pytrees (relation.py registers
``DenseRelation``/``CooRelation`` with key arity / extents as static aux
data), so a whole relation environment is one argument and every
relation's block axes can carry a planner-emitted sharding.

Eager mode (``RAEngine.eager`` / ``compiler.execute``) walks the graph on
every call — it is the un-staged path kept for debugging and for the
oracle cross-checks.
"""

from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import functools
import warnings
from collections import OrderedDict
from typing import Any, Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from . import fra, kernels, planner
from . import rewrite as _rewrite
from .autodiff import GradientProgram
from .relation import CooRelation, DenseRelation, pad_coo_nnz

AnyRel = Union[DenseRelation, CooRelation]
Env = Dict[str, AnyRel]
Program = Union[fra.Query, fra.Node, GradientProgram]

#: per-Lowered bound on retained Compiled executables (LRU): generous for
#: real mesh/donate/stats-bucket churn, small enough that key-churning
#: callers cannot accrete XLA executables without bound.
_MAX_COMPILED = 64


class ShardFallbackWarning(UserWarning):
    """A planned sharding could not be emitted and the relation fell back
    to replication. Structured: carries the relation name, the offending
    dim/extent, and the divisor, so callers can grep/assert on them."""

    def __init__(self, relation: str, dim: int, extent: int, divisor: int):
        self.relation = relation
        self.dim = dim
        self.extent = extent
        self.divisor = divisor
        super().__init__(
            f"relation {relation!r}: planned sharding of block dim {dim} "
            f"(extent {extent}) dropped — not divisible by the mesh axes' "
            f"product {divisor}; the dim is replicated instead"
        )


class ReshardWarning(UserWarning):
    """``Compiled.__call__`` moved committed input bytes to the planned
    layout via device_put — an all-to-all the plan did not account for.
    Structured (carries the relation name and the bytes moved) and
    emitted once per *(cache entry, relation)*, so a second offending
    relation is reported too instead of being swallowed by the first.
    See ``Compiled.counters["reshard"]``; fold the cost into planning with
    ``compile(committed=...)`` or let ``compile_auto`` / the ``Database``
    session thread it automatically."""

    def __init__(self, relation: str, bytes_moved: int):
        self.relation = relation
        self.bytes_moved = bytes_moved
        super().__init__(
            f"relation {relation!r}: Compiled step resharded {bytes_moved} "
            f"committed input bytes to the planned layout (an all-to-all "
            f"the plan did not cost); pass committed= layouts to compile() "
            f"— or step through repro.Database, which auto-threads them — "
            f"to fold it into the plan. See Compiled.counters['reshard']."
        )


# ---------------------------------------------------------------------------
# Environment signatures: the lowering-cache key
# ---------------------------------------------------------------------------


def _rel_signature(name: str, rel: AnyRel) -> Tuple:
    if isinstance(rel, DenseRelation):
        return (
            name,
            "dense",
            rel.key_arity,
            tuple(rel.data.shape),
            str(rel.data.dtype),
        )
    if isinstance(rel, CooRelation):
        return (
            name,
            "coo",
            tuple(rel.extents),
            tuple(rel.keys.shape),
            str(rel.keys.dtype),
            tuple(rel.values.shape),
            str(rel.values.dtype),
            rel.owner_dim,
            rel.shard_offsets,
        )
    raise TypeError(f"env entry {name!r} is not a relation: {type(rel)}")


def env_signature(env: Env, seed: Optional[AnyRel] = None) -> Tuple:
    """Hashable (graph-independent) structure+shape+dtype key for an
    environment — the lowering cache is keyed on this per engine."""
    sig = tuple(_rel_signature(n, env[n]) for n in sorted(env))
    if seed is not None:
        sig += (_rel_signature("__seed_arg", seed),)
    return sig


def _stats_key(stats) -> Optional[Tuple]:
    """Hashable snapshot key for a {name: RelationStats} dict (the stats
    part of a Compiled cache key). Counts are quantized to powers of two
    (``RelationStats.quantized``): statistics jitter across refreshes of
    the same-shaped relation lands on the same key — and therefore the
    same cached plan — while an order-of-magnitude shift re-plans."""
    if not stats:
        return None
    return tuple(sorted((n, st.quantized()) for n, st in stats.items()))


def _norm_spec(spec) -> Tuple:
    """PartitionSpec normalized for layout comparison: trailing
    replicated dims dropped, so ``P('data')`` and ``P('data', None)``
    describe the same placement."""
    t = tuple(spec) if spec is not None else ()
    while t and t[-1] is None:
        t = t[:-1]
    return t


def _abstract(rel):
    """Replace array leaves with ShapeDtypeStructs (relation containers and
    their static aux data survive — relations are pytrees)."""
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(tuple(x.shape), x.dtype), rel
    )


# ---------------------------------------------------------------------------
# Compiled: the jitted executable with planner-emitted shardings
# ---------------------------------------------------------------------------


class Compiled:
    """A jit-compiled, plan-annotated executable for one environment
    signature. Calling it with a same-signature environment hits the jit
    cache: the FRA graph is never re-walked.

    Cache-key semantics: a Compiled is cached on its parent ``Lowered``
    under ``(mesh, axis, donate, mem_budget, n_devices, geometry)`` where
    ``geometry`` is the planner's ``MeshGeometry`` read off the mesh; the
    Lowered itself is cached on the engine under ``(env signature,
    dispatch table)``. Everything that changes the traced computation —
    shapes, dtypes, relation layouts, kernel tiers, mesh shape — is
    therefore part of some cache key, and a Compiled can only ever be
    replayed on environments whose signature matches the one it was
    lowered for (``__call__`` re-checks and raises otherwise)."""

    def __init__(
        self,
        lowered: "Lowered",
        jitted,
        donate_names: Tuple[str, ...],
        plans: Dict[int, planner.JoinPlan],
        input_specs: Dict[str, P],
        mesh,
        geometry: Optional[planner.MeshGeometry] = None,
        in_shardings: Optional[Tuple[Dict, Dict]] = None,
        pad_nnz: Optional[Dict[str, int]] = None,
        rechunks: Optional[Dict[str, int]] = None,
    ):
        self.lowered = lowered
        self._jitted = jitted
        self.donate_names = donate_names
        #: planner.JoinPlan per Join node id — the chosen physical plans.
        self.plans = plans
        #: planner-emitted PartitionSpec per base relation (pre-padding).
        self.input_specs = input_specs
        self.mesh = mesh
        #: the (data × model) MeshGeometry this executable was planned for.
        self.geometry = geometry
        #: (donated, kept) relation-shaped sharding pytrees when a mesh
        #: was given; __call__ reshards inputs to the planned layout.
        self.in_shardings = in_shardings
        #: COO relations whose nnz axis is padded to a shard multiple
        #: (pad-and-mask): relation name → padded row count. __call__ pads
        #: inputs and slices nnz-shaped outputs back.
        self.pad_nnz = dict(pad_nnz or {})
        #: the planner's *rechunk stage*: relations whose committed layout
        #: differed from the plan's chosen grid at compile time, so the
        #: re-blocking all-to-all was costed into the plan (name → bytes).
        #: __call__ counts these moves under ``planned_bytes`` and does
        #: not warn — only unplanned moves are "silent" reshards.
        self.rechunks: Dict[str, int] = dict(rechunks or {})
        #: device-layout rechunk accounting for the silent-reshard path:
        #: calls, calls that moved committed bytes, cumulative and
        #: last-call bytes moved by __call__'s device_put; plus the
        #: cumulative bytes of plan-aware (costed, warning-free) rechunks.
        #: Read it as ``Compiled.counters["reshard"]`` (or aggregated over
        #: a whole session as ``db.counters()["reshard"]``).
        self._reshard: Dict[str, int] = {
            "calls": 0,
            "resharded_calls": 0,
            "bytes_moved": 0,
            "last_call_bytes": 0,
            "planned_bytes": 0,
        }
        #: relations already warned about — ReshardWarning fires once per
        #: (cache entry, relation), not once per cache entry.
        self._reshard_warned: set = set()
        # flattened target leaves per relation, precomputed so the per-call
        # accounting never re-walks the sharding pytrees
        self._reshard_targets = (
            {
                name: jax.tree_util.tree_leaves(target)
                for shards in in_shardings
                for name, target in shards.items()
            }
            if in_shardings is not None
            else {}
        )

    @property
    def dispatch(self) -> kernels.DispatchTable:
        """The kernel DispatchTable this executable was lowered under."""
        return self.lowered.dispatch

    @property
    def counters(self) -> Dict[str, Dict[str, int]]:
        """This executable's slice of the unified telemetry tree —
        currently ``{"reshard": {...}}`` (calls / resharded_calls /
        bytes_moved / last_call_bytes / planned_bytes, all live dicts).
        Sessions aggregate the same keys over every executable they
        compiled as ``db.counters()["reshard"]``."""
        return {"reshard": self._reshard}

    @property
    def resolutions(self) -> Dict[str, str]:
        """``op[site] → tier`` record of every kernel-dispatch decision
        taken while lowering (e.g. ``segment_sum[E=320000,D=32,S=20000]``
        → ``'pallas'``)."""
        return dict(self.lowered.resolutions)

    @property
    def placements(self) -> Dict[str, Dict[str, Optional[int]]]:
        """``relation → {"data": dim, "model": dim}`` record of the 2-D
        placement of every base relation: which axis carries the mesh's
        (folded) data axes and which carries the model axis (``None`` =
        replicated on that mesh axis). For a CooRelation, dim 0 is the
        physical nnz row axis — ``{"data": 0}`` is the nnz-sharded
        layout. The distribution analogue of ``resolutions``. Compiled
        against a mesh, this reads the *effective* in_shardings (after
        non-divisible dense axes were dropped and non-divisible nnz axes
        padded); without a mesh it reports the planner's intent from
        ``input_specs``."""
        geo = self.geometry
        model_axis = geo.model_axis if geo is not None else "model"
        data_axes = set(geo.data_axes) if geo is not None else set()

        def dims_of(spec) -> Dict[str, Optional[int]]:
            data_dim = model_dim = None
            for d, entry in enumerate(tuple(spec)):
                if entry is None:
                    continue
                axes = entry if isinstance(entry, tuple) else (entry,)
                if any(a in data_axes for a in axes):
                    data_dim = d
                if model_axis in axes:
                    model_dim = d
            return {"data": data_dim, "model": model_dim}

        if self.in_shardings is None:
            return {n: dims_of(s) for n, s in self.input_specs.items()}
        out: Dict[str, Dict[str, Optional[int]]] = {}
        for shards in self.in_shardings:
            for name, rel in shards.items():
                if isinstance(rel, DenseRelation):
                    out[name] = dims_of(rel.data.spec)
                else:  # CooRelation: values sharding covers (nnz, *chunk)
                    out[name] = dims_of(rel.values.spec)
        return out

    def planned_spec(self, name: str) -> Optional[P]:
        """The PartitionSpec this executable places relation ``name``'s
        payload array at (a DenseRelation's ``data`` / a CooRelation's
        ``values``) — the layout a ``_committed_layouts``-style probe of
        this step's *inputs after placement* would report. ``compile_auto``
        compares it against an env's committed layouts to decide whether a
        recorded plan still applies without any rechunk."""
        if self.in_shardings is None:
            return self.input_specs.get(name)
        for shards in self.in_shardings:
            rel = shards.get(name)
            if rel is not None:
                sh = rel.data if isinstance(rel, DenseRelation) else rel.values
                return sh.spec
        return None

    def _count_reshard_bytes(self, env: Env) -> Dict[str, int]:
        """Per-relation bytes of *committed* input arrays whose layout
        differs from the planned in_sharding — the silent all-to-all
        device_put pays. Uncommitted arrays place for free and cost only
        an attribute probe; the target leaves are precomputed at compile
        time."""
        moved: Dict[str, int] = {}
        for name, targets in self._reshard_targets.items():
            rel = env.get(name)
            if rel is None:
                continue
            for arr, sh in zip(jax.tree_util.tree_leaves(rel), targets):
                if not getattr(arr, "committed", False):
                    continue  # uncommitted inputs place for free
                cur = getattr(arr, "sharding", None)
                if getattr(cur, "is_fully_replicated", False):
                    continue  # slicing a replicated array moves nothing
                try:
                    same = cur is not None and cur.is_equivalent_to(sh, arr.ndim)
                except Exception:
                    same = cur == sh
                if not same:
                    moved[name] = moved.get(name, 0) + int(arr.nbytes)
        return moved

    def _padded(self, env: Env) -> Env:
        if not self.pad_nnz:
            return env
        out = dict(env)
        for name, target in self.pad_nnz.items():
            if name in out:
                out[name] = pad_coo_nnz(out[name], target)
        return out

    def _unpad(self, out):
        """Slice padded nnz axes out of the results: any output leaf whose
        leading dim exceeds the unpadded lowering's expectation (all other
        dims equal) is a row-aligned COO payload and is cut back."""
        def cut(got, want):
            wshape = tuple(want.shape)
            if (
                hasattr(got, "shape")
                and tuple(got.shape) != wshape
                and len(got.shape) == len(wshape)
                and wshape
                and got.shape[0] > wshape[0]
                and tuple(got.shape[1:]) == wshape[1:]
            ):
                return got[: wshape[0]]
            return got

        return jax.tree_util.tree_map(cut, out, self.lowered.out_shape)

    def __call__(self, env: Env, seed: Optional[AnyRel] = None):
        sig = env_signature(env, seed)
        if sig != self.lowered.sig:
            raise ValueError(
                "environment signature does not match this Compiled's "
                "lowering; call RAEngine.lower(env) again for the new "
                f"shapes.\n  lowered: {self.lowered.sig}\n  got:     {sig}"
            )
        if self.in_shardings is not None:
            # Reshard accounting runs on the *pre-pad* env: padding makes
            # fresh (uncommitted) arrays, which would hide a committed
            # input's layout mismatch from the stats.
            moved_by_rel = self._count_reshard_bytes(env)
            # split plan-aware rechunks (costed at plan time, no warning)
            # from silent reshards the planner did not anticipate
            planned_by_rel = {
                n: b for n, b in moved_by_rel.items() if n in self.rechunks
            }
            moved_by_rel = {
                n: b for n, b in moved_by_rel.items() if n not in self.rechunks
            }
            moved = sum(moved_by_rel.values())
        env = self._padded(env)
        donated = {k: env[k] for k in self.donate_names}
        kept = {k: v for k, v in env.items() if k not in self.donate_names}
        if self.in_shardings is not None:
            # Reshard to the planned layout: inputs produced by an earlier
            # step may be committed to a different placement (e.g. a
            # gradient seed laid out by the forward's compiled output);
            # device_put inserts the re-blocking collective and is a
            # no-op when the layout already matches. The bytes moved are
            # counted on counters["reshard"] and warned about once — fold them
            # into the plan via compile(committed=...).
            sh_don, sh_kept = self.in_shardings
            stats = self._reshard
            stats["calls"] += 1
            stats["last_call_bytes"] = moved
            stats["planned_bytes"] += sum(planned_by_rel.values())
            if moved:
                stats["resharded_calls"] += 1
                stats["bytes_moved"] += moved
                for name, nbytes in moved_by_rel.items():
                    if name in self._reshard_warned:
                        continue  # already reported for this cache entry
                    self._reshard_warned.add(name)
                    warnings.warn(ReshardWarning(name, nbytes), stacklevel=2)
            donated = jax.device_put(donated, sh_don)
            kept = jax.device_put(kept, sh_kept)
        out = self._jitted(donated, kept, seed)
        return self._unpad(out) if self.pad_nnz else out

    def lower_text(self, *, compiled: bool = True) -> str:
        """HLO of the jitted step (diagnostics). ``compiled=True`` returns
        post-SPMD-partitioning HLO — the text in which the plan's
        collectives (all-reduce/all-gather) are visible; ``compiled=False``
        returns the pre-partitioning StableHLO."""
        abstract = dict(self.lowered.abstract_env)
        for name, target in self.pad_nnz.items():
            rel = abstract[name]
            abstract[name] = CooRelation(
                jax.ShapeDtypeStruct(
                    (target,) + tuple(rel.keys.shape[1:]), rel.keys.dtype
                ),
                jax.ShapeDtypeStruct(
                    (target,) + tuple(rel.values.shape[1:]), rel.values.dtype
                ),
                rel.extents,
                rel.owner_dim,
                rel.shard_offsets,
            )
        don = {k: abstract[k] for k in self.donate_names}
        kept = {
            k: v for k, v in abstract.items() if k not in self.donate_names
        }
        lowered = self._jitted.lower(don, kept, self.lowered.abstract_seed)
        if compiled:
            return lowered.compile().as_text()
        return lowered.as_text()


# ---------------------------------------------------------------------------
# Lowered: the shape-specialized lowering, pre-plan
# ---------------------------------------------------------------------------


class Lowered:
    """Abstract-shape lowering of an engine's program for one environment
    signature and one kernel DispatchTable. ``compile`` attaches a
    physical plan + jit.

    Cache-key semantics: the engine caches Lowereds under ``(sig,
    dispatch, rewrite-key)`` where ``sig`` is ``env_signature(env, seed)``
    — relation structure, key arities, shapes, dtypes — ``dispatch`` is
    the (hashable) DispatchTable, and the rewrite key is the enabled
    ``rewrite.RuleSet`` plus the quantized statistics snapshot the cost
    gate read (None when the rewrite stage is off). Two environments with
    equal signatures share a Lowered; a different tier table — or a
    statistics shift large enough to cross a quantization bucket and flip
    a gate — never does, so rewrite decisions are bit-stable like
    committed layouts."""

    def __init__(
        self,
        engine: "RAEngine",
        sig: Tuple,
        dispatch: kernels.DispatchTable,
        abstract_env: Env,
        abstract_seed,
        out_shape,
        resolutions: Dict[str, str],
        program: Optional[Program] = None,
        rewrite_report: Optional[_rewrite.RewriteReport] = None,
        check_report=None,
    ):
        self.engine = engine
        self.sig = sig
        #: validate-stage report (analysis.typecheck.CheckReport): the
        #: typed checker's diagnostics for the forward graph at this
        #: signature — error-free by construction (errors raise before a
        #: Lowered is built), warnings retained for db.check/explain.
        self.check_report = check_report
        #: the kernel tier table this lowering resolved against.
        self.dispatch = dispatch
        #: the program this lowering executes: the engine's program as
        #: rewritten by the cost-gated rewrite stage (core/rewrite.py),
        #: or the engine's own program when the stage was off/declined.
        self.program: Program = engine.program if program is None else program
        #: gate decisions of the rewrite stage (None when it was off).
        self.rewrite_report = rewrite_report
        self.abstract_env = abstract_env
        self.abstract_seed = abstract_seed
        #: pytree of ShapeDtypeStruct-leaved relations: the program output.
        self.out_shape = out_shape
        #: op[site] → tier decisions recorded during the lowering walk
        #: (a kernels.ResolutionLog: the dict plus per-site SiteRecords).
        self.resolutions = resolutions
        #: analysis.kernelcheck.certify_kernels caches its CheckReport
        #: here — the Lowered is already cached per (sig, dispatch,
        #: rewrite) key, so kernel certification is computed at most once
        #: per lowering and never on the execution hot path.
        self._kernel_report = None
        #: LRU-bounded: a Compiled holds an XLA executable, and callers
        #: that churn cache keys (committed layouts, stats buckets) must
        #: not accrete executables forever. Evicted entries simply
        #: recompile on next use; callers keep their own references.
        self._compiled: "OrderedDict[Tuple, Compiled]" = OrderedDict()
        #: compile_auto's plan record: per (mesh, donate, …) base key the
        #: Compiled whose committed-layout plan the catalog stands by.
        self._auto: "OrderedDict[Tuple, Compiled]" = OrderedDict()

    def eager(self, env: Env, seed: Optional[AnyRel] = None):
        """Un-jitted execution (re-walks the graph; debugging only)."""
        return self.engine._execute(
            env, seed, dispatch=self.dispatch, program=self.program
        )

    def compile(
        self,
        mesh=None,
        *,
        axis: Optional[str] = None,
        donate: Tuple[str, ...] = (),
        mem_budget: float = planner.DEFAULT_MEM_BUDGET,
        n_devices: Optional[int] = None,
        committed: Optional[Dict[str, P]] = None,
        stats: Optional[Dict[str, planner.RelationStats]] = None,
    ) -> Compiled:
        """plan_query → in_shardings → jax.jit.

        ``mesh``: a jax Mesh — ``launch/mesh.make_host_mesh`` and
        ``make_production_mesh`` are the canonical constructors. The
        planner reads the real (data × model) geometry off it
        (``planner.MeshGeometry.from_mesh``): a 1-axis mesh reproduces
        the historical 1-D model-axis plans, a 2-D mesh adds per-relation
        batch-dim sharding over the (folded) data axes and may shard a
        CooRelation's nnz rows over them (padding non-divisible row
        counts — pad-and-mask — instead of falling back to replication).
        None compiles for the default (single-device) placement but still
        runs the planner (the plans are inspectable either way).
        ``axis`` overrides the name of the model axis (default: the
        mesh's ``"model"`` axis, or its sole axis).
        ``donate`` names env entries whose buffers jit may reuse
        (parameters / optimizer state on the training hot path). Note:
        a donated COO relation whose nnz is padded per call donates the
        padded *copy*, not the caller's buffer — pre-pad to the shard
        multiple (``relation.owner_partition`` / ``pad_coo_nnz``) so
        ``pad_nnz`` stays empty and donation reaches the real buffers.
        ``committed`` maps relation names to the PartitionSpec their
        arrays are already committed to (``_committed_layouts(env)``
        derives it): the planner then charges candidates that would force
        a device-layout rechunk, instead of ``Compiled.__call__`` paying
        the all-to-all silently (it still counts such moves on
        ``Compiled.counters["reshard"]``).
        ``stats`` maps relation names to tracked ``planner.RelationStats``
        (a ``Database`` catalog snapshot): the planner then replaces its
        Agg-size / edge-cut heuristics with measured key-domain
        statistics. The snapshot is part of the compile cache key —
        refreshed statistics re-plan, identical ones hit the cache.
        """
        donate = tuple(sorted(donate))
        geo = (
            planner.MeshGeometry.from_mesh(mesh, axis=axis)
            if mesh is not None
            else None
        )
        if n_devices is None:
            n_devices = geo.model_size if geo is not None else jax.device_count()
        elif geo is not None and n_devices != geo.model_size:
            # an explicit n_devices overrides the mesh-derived model-axis
            # size in the cost model (legacy contract)
            geo = dataclasses.replace(geo, model_size=n_devices)
        committed_key = (
            tuple(sorted((k, v) for k, v in committed.items()))
            if committed
            else None
        )
        stats_key = _stats_key(stats)
        key = (
            mesh, axis, donate, mem_budget, n_devices, geo, committed_key,
            stats_key,
        )
        hit = self._compiled.get(key)
        if hit is not None:
            self._compiled.move_to_end(key)
            return hit

        # --- plan: the distribution planner picks a JoinPlan per join ----
        # (planner._rel_bytes reads sizes off relations whose payloads are
        # ShapeDtypeStructs, so the abstract env is a valid stats source)
        fwd_query = (
            self.program.forward
            if isinstance(self.program, GradientProgram)
            else self.program
        )
        plans = planner.plan_query(
            fwd_query,
            self.abstract_env,
            n_devices,
            mem_budget=mem_budget,
            geometry=geo,
            committed=committed,
            stats=stats,
        )
        input_specs = planner.input_pspecs(fwd_query, plans)

        # --- rechunk stage: relations whose committed layout is not the -
        # plan's grid get an explicit, costed re-blocking (the all-to-all
        # the bytes-moved model already charged via committed=): record
        # them so __call__ books the move as planned, not silent
        rechunks: Dict[str, int] = {}
        if committed and mesh is not None:
            for name, spec in committed.items():
                planned = input_specs.get(name)
                if _norm_spec(spec) != _norm_spec(planned):
                    rel = self.abstract_env.get(name)
                    rechunks[name] = (
                        int(planner._rel_bytes(rel)) if rel is not None else 0
                    )

        # --- jit: plans become in_shardings, XLA inserts the collectives -
        engine = self.engine
        table = self.dispatch
        program = self.program

        jit_kwargs: Dict[str, Any] = {"donate_argnums": (0,)} if donate else {}
        shardings = None
        pad_nnz: Dict[str, int] = {}
        coo_pins: Dict[str, CooRelation] = {}
        if mesh is not None:
            sh_don: Dict[str, AnyRel] = {}
            sh_kept: Dict[str, AnyRel] = {}
            for k, rel in self.abstract_env.items():
                sharding, pad = self._rel_sharding(
                    k, rel, input_specs.get(k), mesh
                )
                (sh_don if k in donate else sh_kept)[k] = sharding
                if pad is not None:
                    pad_nnz[k] = pad
                if isinstance(sharding, CooRelation) and tuple(
                    sharding.values.spec
                ):
                    # nnz-sharded COO: pin the layout inside the jitted
                    # step too, so the traced segment-sum + scatter-add
                    # stays partitioned over the planned data axes (the
                    # per-shard local segsum + psum the plan costed)
                    # regardless of how XLA would re-place the operands.
                    coo_pins[k] = sharding
            jit_kwargs["in_shardings"] = (sh_don, sh_kept, None)
            shardings = (sh_don, sh_kept)

        def step(donated_env: Env, kept_env: Env, seed):
            env = dict(kept_env)
            env.update(donated_env)
            for name, sh in coo_pins.items():
                rel = env[name]
                env[name] = CooRelation(
                    jax.lax.with_sharding_constraint(rel.keys, sh.keys),
                    jax.lax.with_sharding_constraint(rel.values, sh.values),
                    rel.extents,
                    rel.owner_dim,
                    rel.shard_offsets,
                )
            return engine._execute(env, seed, dispatch=table, program=program)

        compiled = Compiled(
            self,
            jax.jit(step, **jit_kwargs),
            donate,
            plans,
            input_specs,
            mesh,
            geo,
            shardings,
            pad_nnz,
            rechunks,
        )
        self._compiled[key] = compiled
        while len(self._compiled) > _MAX_COMPILED:
            self._compiled.popitem(last=False)
        return compiled

    def compile_auto(
        self,
        env: Env,
        *,
        mesh=None,
        axis: Optional[str] = None,
        donate: Tuple[str, ...] = (),
        mem_budget: float = planner.DEFAULT_MEM_BUDGET,
        stats: Optional[Dict[str, planner.RelationStats]] = None,
    ) -> Compiled:
        """``compile`` with committed layouts auto-threaded and a
        **plan-stability guarantee** — the PR-4 follow-up ("auto-thread
        committed layouts through the staged path without plan-flapping").

        The committed layouts of ``env``'s arrays are derived per call
        (``_committed_layouts``) and folded into planning, but the record
        of the plan last committed to is kept here: when every committed
        input already sits at that plan's own placement — the steady
        state once a step's outputs feed the next call — the recorded
        ``Compiled`` is returned as-is. First and later calls therefore
        produce the identical plan (bit-identical ``Compiled.plans``, the
        same executable, ``counters["reshard"]`` flat at zero moved bytes)
        instead of flapping between a no-committed and an all-committed
        plan. Only inputs committed to a genuinely *different* layout —
        an upstream producer changed its placement — trigger a re-plan,
        which then charges the rechunk and becomes the new record.

        This is the compile entry the ``Database`` session and the
        relational operator layer step through."""
        donate = tuple(sorted(donate))
        base = (mesh, axis, donate, mem_budget, _stats_key(stats))
        committed = _committed_layouts(env) if mesh is not None else {}
        prev = self._auto.get(base)
        if prev is not None and all(
            _norm_spec(prev.planned_spec(name)) == _norm_spec(spec)
            for name, spec in committed.items()
        ):
            self._auto.move_to_end(base)
            return prev
        compiled = self.compile(
            mesh=mesh,
            axis=axis,
            donate=donate,
            mem_budget=mem_budget,
            committed=committed or None,
            stats=stats,
        )
        self._auto[base] = compiled
        while len(self._auto) > _MAX_COMPILED:
            self._auto.popitem(last=False)
        return compiled

    @staticmethod
    def _rel_sharding(
        name: str, rel: AnyRel, spec: Optional[P], mesh
    ) -> Tuple[AnyRel, Optional[int]]:
        """Relation-shaped sharding pytree for one relation, plus the
        padded nnz row count when a COO's planned nnz sharding does not
        divide (pad-and-mask; ``None`` = no padding needed).

        Dense: the planner's block-axis spec, padded over chunk axes; a
        2-D plan's folded data-axis tuples (("pod", "data")) divide by
        the axes' product, and non-divisible extents fall back to
        replicating that dim with a structured ``ShardFallbackWarning``.

        COO: the planner's nnz spec (entry 0) lands on the keys/values
        row axis; a non-divisible row count is padded up to the next
        shard multiple rather than silently replicated."""
        sizes = dict(mesh.shape)

        def axes_total(ax) -> Optional[int]:
            axes = ax if isinstance(ax, tuple) else (ax,)
            if any(a not in sizes for a in axes):
                return None
            total = 1
            for a in axes:
                total *= int(sizes[a])
            return total

        if isinstance(rel, CooRelation):
            rep = NamedSharding(mesh, P())
            row_ax = tuple(spec)[0] if spec is not None and tuple(spec) else None
            total = axes_total(row_ax) if row_ax is not None else None
            if row_ax is None or total is None or total <= 1:
                return CooRelation(
                    rep, rep, rel.extents, rel.owner_dim, rel.shard_offsets
                ), None
            nnz = int(rel.keys.shape[0])
            pad = ((nnz + total - 1) // total) * total if nnz % total else None
            keys_sh = NamedSharding(mesh, P(row_ax, None))
            vals_sh = NamedSharding(
                mesh, P(row_ax, *([None] * (rel.values.ndim - 1)))
            )
            return CooRelation(
                keys_sh, vals_sh, rel.extents, rel.owner_dim, rel.shard_offsets
            ), pad

        full = [None] * len(rel.data.shape)
        if spec is not None:
            for d, ax in enumerate(tuple(spec)):
                if ax is None or d >= rel.key_arity:
                    continue
                total = axes_total(ax)
                if total is None:
                    continue
                if rel.data.shape[d] % total == 0:
                    full[d] = ax
                elif total > 1:
                    warnings.warn(
                        ShardFallbackWarning(
                            name, d, int(rel.data.shape[d]), total
                        ),
                        stacklevel=3,
                    )
        return DenseRelation(NamedSharding(mesh, P(*full)), rel.key_arity), None


# ---------------------------------------------------------------------------
# StreamedCompiled: out-of-core chunk-wave execution
# ---------------------------------------------------------------------------


class StreamedCompiled:
    """Chunk-wave executor for a ``planner.WavePlan``: the session's
    memory budget did not fit the environment, so the streamed relation
    (and its co-streams) live host-side in the ``ChunkStore`` and each
    call runs the normally-compiled step once per wave over ``resident +
    one chunk``, double-buffering the host→device transfer of wave
    ``w+1`` behind wave ``w``'s compute.

    Wave results merge by the plan's soundness analysis
    (``planner._stream_states``): an output leaf whose shape equals the
    full in-core lowering's expectation is an additive partial (Σ across
    waves — the loss, gradients of resident relations); a leaf whose
    shape differs along exactly one axis is wave-local rows of the
    streamed axis (gradients of the streamed relation itself) and is
    sliced to the wave's live rows — dropping the COO pad rows of the
    padded last chunk — and concatenated in row order. Either way the
    merged result equals the in-core step's.

    Duck-types ``Compiled`` for the session's introspection surface
    (``mesh``/``plans``/``placements``/``resolutions``/``counters``/
    ``planned_spec``) by delegating to the per-wave inner ``Compiled``
    (identical across waves of equal signature); ``planned_spec`` is None
    for streamed relations — they have no single device placement, so
    the catalog never commits a layout for them."""

    def __init__(self, plan, store, compile_wave, lower_full):
        from .chunkstore import OutOfCoreError  # noqa: F401  (re-raised)

        self.plan = plan
        self.store = store
        #: wave env → Compiled (the session's normal staged path; the
        #: engine's Lowered/Compiled caches make wave 2..n cache hits).
        self._compile_wave = compile_wave
        #: full env → Lowered (abstract shapes only — never executed):
        #: its out_shape is the merge oracle for ADD-vs-CONCAT leaves.
        self._lower_full = lower_full
        self._inner: Optional[Compiled] = None

    # -- Compiled surface ---------------------------------------------------

    @property
    def num_waves(self) -> int:
        return self.plan.num_waves

    @property
    def mesh(self):
        return self._inner.mesh if self._inner is not None else None

    @property
    def plans(self):
        return self._inner.plans if self._inner is not None else {}

    @property
    def placements(self):
        return self._inner.placements if self._inner is not None else {}

    @property
    def resolutions(self):
        return self._inner.resolutions if self._inner is not None else {}

    @property
    def counters(self) -> Dict[str, Dict[str, int]]:
        if self._inner is None:
            return {"reshard": {
                "calls": 0, "resharded_calls": 0, "bytes_moved": 0,
                "last_call_bytes": 0, "planned_bytes": 0,
            }}
        return self._inner.counters

    def planned_spec(self, name: str):
        if name in self.plan.streamed_names or self._inner is None:
            return None
        return self._inner.planned_spec(name)

    # -- execution ----------------------------------------------------------

    def _fetch_wave(self, resident: Env, w: int, max_rows: int) -> Env:
        """Resident relations + wave ``w``'s chunks, device-put issued
        (async) — calling this one wave ahead is the double buffer."""
        wave = dict(resident)
        for name in self.plan.streamed_names:
            rel = self.store.fetch(name, w)
            if isinstance(rel, CooRelation):
                # pad every COO wave to the largest chunk so all waves
                # share one env signature (one lowering, one executable);
                # pad rows carry COO_PAD_KEY and are sliced off on merge
                rel = pad_coo_nnz(rel, max_rows)
            wave[name] = rel
        return wave

    def _merge(self, wave_outs, want_shape):
        from .chunkstore import OutOfCoreError

        want_leaves, want_def = jax.tree_util.tree_flatten(want_shape)
        per_wave = [jax.tree_util.tree_leaves(o) for o in wave_outs]
        if any(len(p) != len(want_leaves) for p in per_wave):
            raise OutOfCoreError(
                "wave output structure does not match the in-core lowering"
            )
        bnd = self.plan.boundaries
        merged = []
        for i, want in enumerate(want_leaves):
            leaves = [p[i] for p in per_wave]
            wshape = tuple(want.shape)
            if all(tuple(g.shape) == wshape for g in leaves):
                out = leaves[0]
                for g in leaves[1:]:
                    out = out + g
                merged.append(out)
                continue
            shapes = {tuple(g.shape) for g in leaves}
            diff_axes = {
                ax
                for s in shapes
                if len(s) == len(wshape)
                for ax in range(len(s))
                if s[ax] != wshape[ax]
            }
            if len(diff_axes) != 1 or any(
                len(s) != len(wshape) for s in shapes
            ):
                raise OutOfCoreError(
                    f"cannot merge wave output leaf of shapes {shapes} "
                    f"into expected {wshape}: not an additive partial and "
                    "not single-axis wave rows"
                )
            ax = diff_axes.pop()
            cut = []
            for w, g in enumerate(leaves):
                rows = bnd[w + 1] - bnd[w]
                idx = [slice(None)] * g.ndim
                idx[ax] = slice(0, rows)  # drop COO pad rows of the wave
                # host-side assembly: the full-size streamed-axis result
                # is host-tier data by definition (it did not fit the
                # device budget), and np.asarray also canonicalizes
                # mesh-sharded wave leaves before the concat
                cut.append(np.asarray(jax.device_get(g[tuple(idx)])))
            merged.append(np.concatenate(cut, axis=ax))
        return jax.tree_util.tree_unflatten(want_def, merged)

    def __call__(self, env: Env, seed: Optional[AnyRel] = None):
        from .relation import ChunkManifest

        plan = self.plan
        streamed = set(plan.streamed_names)
        axis_of = dict(plan.axis_of)
        smani = ChunkManifest(
            axis=0,
            boundaries=plan.boundaries,
            owner_aligned=plan.owner_aligned,
        )
        self.store.spill(plan.stream, env[plan.stream], smani)
        for name in plan.co_streams:
            # co-streams share the stream's cut vector on their own axis:
            # wave w of the stream joins wave w of every co-stream
            self.store.spill(
                name,
                env[name],
                ChunkManifest(axis=axis_of[name], boundaries=plan.boundaries),
            )
        resident = {k: v for k, v in env.items() if k not in streamed}
        max_rows = smani.max_rows
        want_shape = self._lower_full(env, seed).out_shape

        outs = []
        wave = self._fetch_wave(resident, 0, max_rows)
        for w in range(plan.num_waves):
            if w + 1 < plan.num_waves:
                nxt = self._fetch_wave(resident, w + 1, max_rows)
            compiled = self._compile_wave(wave, seed)
            self._inner = compiled
            outs.append(compiled(wave, seed))
            if w + 1 < plan.num_waves:
                wave = nxt
        return self._merge(outs, want_shape)


# ---------------------------------------------------------------------------
# RAEngine: the wrapped program
# ---------------------------------------------------------------------------


class RAEngine:
    """Staged executor for an FRA query, bare gradient-graph root, or
    GradientProgram. Holds the lowering cache and the trace counter.

    This is the library-level staged executor; the ``repro.Database``
    session API (``db.query(...)`` / ``db.sql(...)``) layers the catalog
    — tracked statistics, committed layouts, the active mesh — on top of
    it and is the recommended front door for catalog-backed work."""

    def __init__(self, program: Program, *, fuse_join_agg: bool = True):
        self.source = program
        self.fuse_join_agg = fuse_join_agg
        #: number of actual FRA-graph walks (eager calls + jit traces).
        self.trace_count = 0
        self._lowered: Dict[Tuple, Lowered] = {}

        if isinstance(program, GradientProgram):
            self.kind = "grad"
            self.program = program
        elif isinstance(program, fra.Query):
            self.kind = "query"
            self.program = program
        elif isinstance(program, fra.Node):
            self.kind = "query"
            inputs = tuple(sorted({s.name for s in program.table_scans()}))
            self.program = fra.Query(program, inputs)
        else:
            raise TypeError(f"cannot wrap program of type {type(program)}")

    @property
    def forward_query(self) -> fra.Query:
        return (
            self.program.forward if self.kind == "grad" else self.program
        )

    # -- execution body (runs eagerly or under trace) ----------------------
    def _execute(
        self,
        env: Env,
        seed: Optional[AnyRel] = None,
        dispatch: Optional[kernels.DispatchTable] = None,
        resolutions: Optional[Dict[str, str]] = None,
        program: Optional[Program] = None,
    ):
        """Walk the program's FRA graph(s) over ``env`` (eagerly or under
        a jax trace). ``program`` overrides the engine's own program —
        the handle a ``Lowered`` uses to execute the *rewritten* program
        its cache entry lowered (core/rewrite.py) while sharing this
        engine's trace counter and fuse flag."""
        from . import compiler

        self.trace_count += 1
        prog = self.program if program is None else program
        if not isinstance(prog, GradientProgram):
            if seed is not None:
                raise ValueError("seed is only meaningful for GradientPrograms")
            return compiler._execute_graph(
                prog.root,
                env,
                fuse_join_agg=self.fuse_join_agg,
                dispatch=dispatch,
                resolutions=resolutions,
            )

        fwd_cache: Env = {}
        out = compiler._execute_graph(
            prog.forward.root,
            env,
            cache=fwd_cache,
            fuse_join_agg=self.fuse_join_agg,
            dispatch=dispatch,
            resolutions=resolutions,
        )
        if seed is None:
            if not (isinstance(out, DenseRelation) and out.key_arity == 0):
                raise ValueError("default seed requires a scalar-loss output")
            seed = DenseRelation(jnp.ones_like(out.data), key_arity=0)
        genv = dict(env)
        genv.update(fwd_cache)
        genv["__seed"] = seed
        # Gradient graphs fuse their own join-aggs regardless of how the
        # forward was executed (matches the historical grad_eval contract;
        # rjp_ablation relies on it).
        grads = {
            name: compiler._execute_graph(
                rootn, genv, dispatch=dispatch, resolutions=resolutions
            )
            for name, rootn in prog.grads.items()
        }
        return out, grads

    # -- the staged pipeline ----------------------------------------------
    def eager(
        self, env: Env, seed: Optional[AnyRel] = None, *, dispatch=None
    ):
        """Un-staged execution: walk the graph now, every call.
        ``dispatch`` takes anything ``kernels.make_table`` accepts."""
        table = kernels.make_table(dispatch)
        return self._execute(env, seed, dispatch=table)

    def lower(
        self,
        env: Env,
        seed: Optional[AnyRel] = None,
        *,
        dispatch=None,
        stats: Optional[Dict[str, planner.RelationStats]] = None,
        rewrite=None,
    ) -> Lowered:
        """Trace the chunked lowering at ``env``'s shapes under a kernel
        DispatchTable (``dispatch`` accepts anything ``kernels.make_table``
        does; None → backend default). Cached: a second call with an
        identical (signature, table, rewrite-key) triple returns the same
        Lowered without re-walking the graph; switching tiers is a cache
        miss and re-lowers.

        ``rewrite`` enables the cost-gated algebraic rewrite stage
        (core/rewrite.py) ahead of planning: anything
        ``rewrite.make_rules`` accepts — True for the default rule set, a
        ``RuleSet``, an iterable of rule names; None/False (default)
        skips the stage. ``stats`` is the catalog statistics snapshot the
        cost gate prices pushdowns with (also what sharpens the planner's
        estimates at compile time); its quantized form joins the enabled
        RuleSet in the cache key, so a statistics shift that could flip a
        gate re-lowers while same-bucket refreshes hit the cache. The
        rewritten program (and the gate report) live on the returned
        ``Lowered`` — declined rewrites keep the engine's original
        program object, bit-identical to a rewrite-off lowering."""
        table = kernels.make_table(dispatch)
        rules = _rewrite.make_rules(rewrite)
        rw_key = None if rules is None else (rules, _stats_key(stats))
        sig = env_signature(env, seed)
        key = (sig, table, rw_key)
        hit = self._lowered.get(key)
        if hit is not None:
            return hit
        # mandatory validate stage (repro.analysis.typecheck): schema/
        # shape/dtype-check the forward graph at this env's shapes before
        # the rewrite/plan/jit stages run — a malformed query fails here
        # with node-path diagnostics instead of a trace-time error from
        # deep inside the chunked lowering. Raises ValidationError on
        # error-severity findings; the full report (warnings included)
        # rides on the returned Lowered as ``check_report``.
        from ..analysis.typecheck import ValidationError, check_query

        check_report = check_query(
            self.forward_query, env, fuse_join_agg=self.fuse_join_agg
        )
        if not check_report.ok:
            raise ValidationError(check_report)
        abstract_env = {k: _abstract(v) for k, v in env.items()}
        abstract_seed = None if seed is None else _abstract(seed)
        program = None
        report = None
        if rules is not None:
            program, report = _rewrite.rewrite_program(
                self.program, abstract_env, stats=stats, rules=rules
            )
        # a ResolutionLog (not a plain dict) so each dispatch decision
        # carries its site-info snapshot — analysis.kernelcheck replays
        # resolve_impl on the snapshots to certify the decisions stable
        resolutions: Dict[str, str] = kernels.ResolutionLog()
        out_shape = jax.eval_shape(
            functools.partial(
                self._execute,
                dispatch=table,
                resolutions=resolutions,
                program=program,
            ),
            abstract_env,
            abstract_seed,
        )
        low = Lowered(
            self,
            sig,
            table,
            abstract_env,
            abstract_seed,
            out_shape,
            resolutions,
            program=program,
            rewrite_report=report,
            check_report=check_report,
        )
        self._lowered[key] = low
        return low


# ---------------------------------------------------------------------------
# Module-level engine registry + one-call convenience
# ---------------------------------------------------------------------------

_ENGINES: "OrderedDict[Tuple[int, bool], RAEngine]" = OrderedDict()
_MAX_ENGINES = 256

#: ambient-mesh stack; a ContextVar so concurrent threads / tasks (e.g. a
#: serving worker pool) each see only their own mesh-context nesting.
_MESH_STACK: "contextvars.ContextVar[Tuple[Any, ...]]" = contextvars.ContextVar(
    "repro_engine_mesh_stack", default=()
)


@contextlib.contextmanager
def _use_mesh(mesh):
    """Internal ambient-mesh context (no deprecation warning): pushes
    ``mesh`` — a jax Mesh or a ``launch/mesh.resolve_mesh`` spec string —
    onto the stack ``default_mesh`` reads. ``Database.activate`` uses
    this to make the session's active mesh ambient for the relational
    operator layer."""
    if isinstance(mesh, str):
        from repro.launch.mesh import resolve_mesh

        mesh = resolve_mesh(mesh)
    token = _MESH_STACK.set(_MESH_STACK.get() + (mesh,))
    try:
        yield mesh
    finally:
        _MESH_STACK.reset(token)


def default_mesh():
    """The innermost ambient (``_use_mesh`` / session-activated) mesh,
    or None."""
    stack = _MESH_STACK.get()
    return stack[-1] if stack else None


def _committed_layouts(env: Env) -> Dict[str, P]:
    """PartitionSpec per relation whose arrays are *committed* to a
    NamedSharding layout (outputs of earlier compiled steps; explicitly
    device_put inputs) — the dict ``Lowered.compile(committed=...)``
    expects, so the planner charges device-layout rechunks instead of
    ``Compiled.__call__`` silently paying them. Uncommitted (freshly
    created) arrays place for free and are omitted."""
    out: Dict[str, P] = {}
    for name, rel in env.items():
        arr = rel.data if isinstance(rel, DenseRelation) else rel.values
        sh = getattr(arr, "sharding", None)
        if (
            getattr(arr, "committed", False)
            and isinstance(sh, NamedSharding)
        ):
            out[name] = sh.spec
    return out


def engine_for(program: Program, *, fuse_join_agg: bool = True) -> RAEngine:
    """Engine per (program identity, fuse flag), LRU-bounded. The engine
    holds a strong reference to the program, so the id key cannot be
    recycled while the entry lives. This is the internal registry the
    ``Database`` session steps through."""
    key = (id(program), fuse_join_agg)
    eng = _ENGINES.get(key)
    if eng is not None and eng.source is program:
        _ENGINES.move_to_end(key)
        return eng
    eng = RAEngine(program, fuse_join_agg=fuse_join_agg)
    _ENGINES[key] = eng
    while len(_ENGINES) > _MAX_ENGINES:
        _ENGINES.popitem(last=False)
    return eng


def _trace_clean() -> bool:
    """True outside any active jax trace. Meshes are only compiled
    against at top level: an outer jit/grad's in-flight shardings would
    fight the planner's, so sharding is left to propagate from the
    traced operands instead. The one place this probe lives — the
    session's mesh resolution reuses it."""
    try:
        return jax.core.trace_state_clean()
    except AttributeError:  # no trace-state probe on this jax:
        return False  # be safe, skip the ambient mesh


def _ambient_mesh():
    """The mesh a top-level staged execution should compile against: the
    innermost ambient mesh, or None under an active trace."""
    return default_mesh() if _trace_clean() else None


def _staged_execute(
    program: Program,
    env: Env,
    seed: Optional[AnyRel] = None,
    *,
    mesh=None,
    donate: Tuple[str, ...] = (),
    fuse_join_agg: bool = True,
    dispatch=None,
    stats: Optional[Dict[str, planner.RelationStats]] = None,
    mem_budget: float = planner.DEFAULT_MEM_BUDGET,
    rewrite=None,
):
    """lower → plan → compile → run in one call, with every stage cached:
    per-program engine, per-(signature, dispatch-table, rewrite-key)
    Lowered, per-mesh ``compile_auto`` record (committed layouts folded
    without plan-flapping). The internal staged hot path
    ``Database.execute`` and the relational operator layer step through;
    ``mesh=None`` picks up the ambient (session-activated) mesh outside
    traces; ``rewrite`` enables the cost-gated rewrite stage (anything
    ``rewrite.make_rules`` accepts)."""
    if mesh is None:
        mesh = _ambient_mesh()
    eng = engine_for(program, fuse_join_agg=fuse_join_agg)
    compiled = eng.lower(
        env, seed, dispatch=dispatch, stats=stats, rewrite=rewrite
    ).compile_auto(
        env, mesh=mesh, donate=donate, stats=stats, mem_budget=mem_budget
    )
    return compiled(env, seed)
