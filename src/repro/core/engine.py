"""Staged query engine: lower → plan → jit-compile.

The paper's systems claim (§1) is that a relational engine *automatically
distributes* differentiated queries: the optimizer picks a physical plan
per join, the execution engine inserts the implied collectives, and the
whole thing is compiled once and reused across training iterations. This
module is that pipeline, staged explicitly in the jax.stages idiom
(wrapped → lowered → compiled):

    RAEngine(program)             # FRA query / gradient program (wrapped)
        .lower(env)               # → Lowered: abstract-shape trace of the
                                  #   chunked lowering, cached per
                                  #   (graph, shapes/dtypes, dispatch
                                  #   table) signature
        .compile(mesh=...)        # → Compiled: planner.plan_query picks a
                                  #   JoinPlan per join — 2-D (data ×
                                  #   model) on a launch/mesh mesh — its
                                  #   PartitionSpecs become jax.jit
                                  #   in_shardings, XLA SPMD inserts the
                                  #   plan's collectives
    compiled(env)                 # jit-cached step: zero re-lowering

Kernel dispatch is part of the lowering: ``lower(env, dispatch=...)``
pins a kernels.DispatchTable (Pallas / interpret / ref / jnp tier per hot
op) into the lowering signature, so switching tiers re-lowers and jits a
distinct step — kernel choice can never alias a stale jit cache entry.
The decisions actually taken are recorded on ``Compiled.resolutions``.

``RAEngine.trace_count`` counts actual FRA-graph walks (lowerings). A
``Compiled`` step re-walks the graph only when jit retraces — i.e. never,
for a fixed environment signature; the engine-stage tests assert this.

Relations cross the jit boundary as pytrees (relation.py registers
``DenseRelation``/``CooRelation`` with key arity / extents as static aux
data), so a whole relation environment is one argument and every
relation's block axes can carry a planner-emitted sharding.

Eager mode (``RAEngine.eager`` / ``compiler.execute``) walks the graph on
every call — it is the un-staged path kept for debugging and for the
oracle cross-checks.
"""

from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import functools
from collections import OrderedDict
from typing import Any, Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from . import fra, kernels, planner
from .autodiff import GradientProgram
from .relation import CooRelation, DenseRelation

AnyRel = Union[DenseRelation, CooRelation]
Env = Dict[str, AnyRel]
Program = Union[fra.Query, fra.Node, GradientProgram]


# ---------------------------------------------------------------------------
# Environment signatures: the lowering-cache key
# ---------------------------------------------------------------------------


def _rel_signature(name: str, rel: AnyRel) -> Tuple:
    if isinstance(rel, DenseRelation):
        return (
            name,
            "dense",
            rel.key_arity,
            tuple(rel.data.shape),
            str(rel.data.dtype),
        )
    if isinstance(rel, CooRelation):
        return (
            name,
            "coo",
            tuple(rel.extents),
            tuple(rel.keys.shape),
            str(rel.keys.dtype),
            tuple(rel.values.shape),
            str(rel.values.dtype),
        )
    raise TypeError(f"env entry {name!r} is not a relation: {type(rel)}")


def env_signature(env: Env, seed: Optional[AnyRel] = None) -> Tuple:
    """Hashable (graph-independent) structure+shape+dtype key for an
    environment — the lowering cache is keyed on this per engine."""
    sig = tuple(_rel_signature(n, env[n]) for n in sorted(env))
    if seed is not None:
        sig += (_rel_signature("__seed_arg", seed),)
    return sig


def _abstract(rel):
    """Replace array leaves with ShapeDtypeStructs (relation containers and
    their static aux data survive — relations are pytrees)."""
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(tuple(x.shape), x.dtype), rel
    )


# ---------------------------------------------------------------------------
# Compiled: the jitted executable with planner-emitted shardings
# ---------------------------------------------------------------------------


class Compiled:
    """A jit-compiled, plan-annotated executable for one environment
    signature. Calling it with a same-signature environment hits the jit
    cache: the FRA graph is never re-walked.

    Cache-key semantics: a Compiled is cached on its parent ``Lowered``
    under ``(mesh, axis, donate, mem_budget, n_devices, geometry)`` where
    ``geometry`` is the planner's ``MeshGeometry`` read off the mesh; the
    Lowered itself is cached on the engine under ``(env signature,
    dispatch table)``. Everything that changes the traced computation —
    shapes, dtypes, relation layouts, kernel tiers, mesh shape — is
    therefore part of some cache key, and a Compiled can only ever be
    replayed on environments whose signature matches the one it was
    lowered for (``__call__`` re-checks and raises otherwise)."""

    def __init__(
        self,
        lowered: "Lowered",
        jitted,
        donate_names: Tuple[str, ...],
        plans: Dict[int, planner.JoinPlan],
        input_specs: Dict[str, P],
        mesh,
        geometry: Optional[planner.MeshGeometry] = None,
        in_shardings: Optional[Tuple[Dict, Dict]] = None,
    ):
        self.lowered = lowered
        self._jitted = jitted
        self.donate_names = donate_names
        #: planner.JoinPlan per Join node id — the chosen physical plans.
        self.plans = plans
        #: planner-emitted PartitionSpec per base relation (pre-padding).
        self.input_specs = input_specs
        self.mesh = mesh
        #: the (data × model) MeshGeometry this executable was planned for.
        self.geometry = geometry
        #: (donated, kept) relation-shaped sharding pytrees when a mesh
        #: was given; __call__ reshards inputs to the planned layout.
        self.in_shardings = in_shardings

    @property
    def dispatch(self) -> kernels.DispatchTable:
        """The kernel DispatchTable this executable was lowered under."""
        return self.lowered.dispatch

    @property
    def resolutions(self) -> Dict[str, str]:
        """``op[site] → tier`` record of every kernel-dispatch decision
        taken while lowering (e.g. ``segment_sum[E=320000,D=32,S=20000]``
        → ``'pallas'``)."""
        return dict(self.lowered.resolutions)

    @property
    def placements(self) -> Dict[str, Dict[str, Optional[int]]]:
        """``relation → {"data": dim, "model": dim}`` record of the 2-D
        placement of every base relation: which block axis carries the
        mesh's (folded) data axes and which carries the model axis
        (``None`` = replicated on that mesh axis). The distribution
        analogue of ``resolutions``. Compiled against a mesh, this reads
        the *effective* in_shardings (after non-divisible axes were
        dropped and COO relations replicated); without a mesh it reports
        the planner's intent from ``input_specs``."""
        geo = self.geometry
        model_axis = geo.model_axis if geo is not None else "model"
        data_axes = set(geo.data_axes) if geo is not None else set()

        def dims_of(spec) -> Dict[str, Optional[int]]:
            data_dim = model_dim = None
            for d, entry in enumerate(tuple(spec)):
                if entry is None:
                    continue
                axes = entry if isinstance(entry, tuple) else (entry,)
                if any(a in data_axes for a in axes):
                    data_dim = d
                if model_axis in axes:
                    model_dim = d
            return {"data": data_dim, "model": model_dim}

        if self.in_shardings is None:
            return {n: dims_of(s) for n, s in self.input_specs.items()}
        out: Dict[str, Dict[str, Optional[int]]] = {}
        for shards in self.in_shardings:
            for name, rel in shards.items():
                if isinstance(rel, DenseRelation):
                    out[name] = dims_of(rel.data.spec)
                else:  # CooRelation: kept replicated
                    out[name] = {"data": None, "model": None}
        return out

    def __call__(self, env: Env, seed: Optional[AnyRel] = None):
        sig = env_signature(env, seed)
        if sig != self.lowered.sig:
            raise ValueError(
                "environment signature does not match this Compiled's "
                "lowering; call RAEngine.lower(env) again for the new "
                f"shapes.\n  lowered: {self.lowered.sig}\n  got:     {sig}"
            )
        donated = {k: env[k] for k in self.donate_names}
        kept = {k: v for k, v in env.items() if k not in self.donate_names}
        if self.in_shardings is not None:
            # Reshard to the planned layout: inputs produced by an earlier
            # step may be committed to a different placement (e.g. a
            # gradient seed laid out by the forward's compiled output);
            # device_put inserts the re-blocking collective and is a
            # no-op when the layout already matches.
            sh_don, sh_kept = self.in_shardings
            donated = jax.device_put(donated, sh_don)
            kept = jax.device_put(kept, sh_kept)
        return self._jitted(donated, kept, seed)

    def lower_text(self, *, compiled: bool = True) -> str:
        """HLO of the jitted step (diagnostics). ``compiled=True`` returns
        post-SPMD-partitioning HLO — the text in which the plan's
        collectives (all-reduce/all-gather) are visible; ``compiled=False``
        returns the pre-partitioning StableHLO."""
        don = {k: self.lowered.abstract_env[k] for k in self.donate_names}
        kept = {
            k: v
            for k, v in self.lowered.abstract_env.items()
            if k not in self.donate_names
        }
        lowered = self._jitted.lower(don, kept, self.lowered.abstract_seed)
        if compiled:
            return lowered.compile().as_text()
        return lowered.as_text()


# ---------------------------------------------------------------------------
# Lowered: the shape-specialized lowering, pre-plan
# ---------------------------------------------------------------------------


class Lowered:
    """Abstract-shape lowering of an engine's program for one environment
    signature and one kernel DispatchTable. ``compile`` attaches a
    physical plan + jit.

    Cache-key semantics: the engine caches Lowereds under ``(sig,
    dispatch)`` where ``sig`` is ``env_signature(env, seed)`` — relation
    structure, key arities, shapes, dtypes — and ``dispatch`` is the
    (hashable) DispatchTable. Two environments with equal signatures share
    a Lowered; a different tier table never does."""

    def __init__(
        self,
        engine: "RAEngine",
        sig: Tuple,
        dispatch: kernels.DispatchTable,
        abstract_env: Env,
        abstract_seed,
        out_shape,
        resolutions: Dict[str, str],
    ):
        self.engine = engine
        self.sig = sig
        #: the kernel tier table this lowering resolved against.
        self.dispatch = dispatch
        self.abstract_env = abstract_env
        self.abstract_seed = abstract_seed
        #: pytree of ShapeDtypeStruct-leaved relations: the program output.
        self.out_shape = out_shape
        #: op[site] → tier decisions recorded during the lowering walk.
        self.resolutions = resolutions
        self._compiled: Dict[Tuple, Compiled] = {}

    def eager(self, env: Env, seed: Optional[AnyRel] = None):
        """Un-jitted execution (re-walks the graph; debugging only)."""
        return self.engine._execute(env, seed, dispatch=self.dispatch)

    def compile(
        self,
        mesh=None,
        *,
        axis: Optional[str] = None,
        donate: Tuple[str, ...] = (),
        mem_budget: float = planner.DEFAULT_MEM_BUDGET,
        n_devices: Optional[int] = None,
    ) -> Compiled:
        """plan_query → in_shardings → jax.jit.

        ``mesh``: a jax Mesh — ``launch/mesh.make_host_mesh`` and
        ``make_production_mesh`` are the canonical constructors. The
        planner reads the real (data × model) geometry off it
        (``planner.MeshGeometry.from_mesh``): a 1-axis mesh reproduces
        the historical 1-D model-axis plans, a 2-D mesh adds per-relation
        batch-dim sharding over the (folded) data axes. None compiles for
        the default (single-device) placement but still runs the planner
        (the plans are inspectable either way).
        ``axis`` overrides the name of the model axis (default: the
        mesh's ``"model"`` axis, or its sole axis).
        ``donate`` names env entries whose buffers jit may reuse
        (parameters / optimizer state on the training hot path).
        """
        donate = tuple(sorted(donate))
        geo = (
            planner.MeshGeometry.from_mesh(mesh, axis=axis)
            if mesh is not None
            else None
        )
        if n_devices is None:
            n_devices = geo.model_size if geo is not None else jax.device_count()
        elif geo is not None and n_devices != geo.model_size:
            # an explicit n_devices overrides the mesh-derived model-axis
            # size in the cost model (legacy contract)
            geo = dataclasses.replace(geo, model_size=n_devices)
        key = (mesh, axis, donate, mem_budget, n_devices, geo)
        hit = self._compiled.get(key)
        if hit is not None:
            return hit

        # --- plan: the distribution planner picks a JoinPlan per join ----
        # (planner._rel_bytes reads sizes off relations whose payloads are
        # ShapeDtypeStructs, so the abstract env is a valid stats source)
        fwd_query = self.engine.forward_query
        plans = planner.plan_query(
            fwd_query,
            self.abstract_env,
            n_devices,
            mem_budget=mem_budget,
            geometry=geo,
        )
        input_specs = planner.input_pspecs(fwd_query, plans)

        # --- jit: plans become in_shardings, XLA inserts the collectives -
        engine = self.engine
        table = self.dispatch

        def step(donated_env: Env, kept_env: Env, seed):
            env = dict(kept_env)
            env.update(donated_env)
            return engine._execute(env, seed, dispatch=table)

        jit_kwargs: Dict[str, Any] = {"donate_argnums": (0,)} if donate else {}
        shardings = None
        if mesh is not None:
            sh_don = {
                k: self._rel_sharding(self.abstract_env[k], input_specs.get(k), mesh)
                for k in donate
            }
            sh_kept = {
                k: self._rel_sharding(rel, input_specs.get(k), mesh)
                for k, rel in self.abstract_env.items()
                if k not in donate
            }
            jit_kwargs["in_shardings"] = (sh_don, sh_kept, None)
            shardings = (sh_don, sh_kept)

        compiled = Compiled(
            self,
            jax.jit(step, **jit_kwargs),
            donate,
            plans,
            input_specs,
            mesh,
            geo,
            shardings,
        )
        self._compiled[key] = compiled
        return compiled

    @staticmethod
    def _rel_sharding(rel: AnyRel, spec: Optional[P], mesh):
        """Relation-shaped sharding pytree: the planner's block-axis spec,
        padded over chunk axes and dropped on non-divisible extents; a
        2-D plan's folded data-axis tuples (("pod", "data")) divide by
        the axes' product. COO relations are kept replicated (their
        key/value rows have no block axes to co-partition statically)."""
        if isinstance(rel, CooRelation):
            rep = NamedSharding(mesh, P())
            return CooRelation(rep, rep, rel.extents)
        sizes = dict(mesh.shape)
        full = [None] * len(rel.data.shape)
        if spec is not None:
            for d, ax in enumerate(tuple(spec)):
                if ax is None or d >= rel.key_arity:
                    continue
                axes = ax if isinstance(ax, tuple) else (ax,)
                if any(a not in sizes for a in axes):
                    continue
                total = 1
                for a in axes:
                    total *= int(sizes[a])
                if rel.data.shape[d] % total == 0:
                    full[d] = ax
        return DenseRelation(NamedSharding(mesh, P(*full)), rel.key_arity)


# ---------------------------------------------------------------------------
# RAEngine: the wrapped program
# ---------------------------------------------------------------------------


class RAEngine:
    """Staged executor for an FRA query, bare gradient-graph root, or
    GradientProgram. Holds the lowering cache and the trace counter."""

    def __init__(self, program: Program, *, fuse_join_agg: bool = True):
        self.source = program
        self.fuse_join_agg = fuse_join_agg
        #: number of actual FRA-graph walks (eager calls + jit traces).
        self.trace_count = 0
        self._lowered: Dict[Tuple, Lowered] = {}

        if isinstance(program, GradientProgram):
            self.kind = "grad"
            self.program = program
        elif isinstance(program, fra.Query):
            self.kind = "query"
            self.program = program
        elif isinstance(program, fra.Node):
            self.kind = "query"
            inputs = tuple(sorted({s.name for s in program.table_scans()}))
            self.program = fra.Query(program, inputs)
        else:
            raise TypeError(f"cannot wrap program of type {type(program)}")

    @property
    def forward_query(self) -> fra.Query:
        return (
            self.program.forward if self.kind == "grad" else self.program
        )

    # -- execution body (runs eagerly or under trace) ----------------------
    def _execute(
        self,
        env: Env,
        seed: Optional[AnyRel] = None,
        dispatch: Optional[kernels.DispatchTable] = None,
        resolutions: Optional[Dict[str, str]] = None,
    ):
        from . import compiler

        self.trace_count += 1
        if self.kind == "query":
            if seed is not None:
                raise ValueError("seed is only meaningful for GradientPrograms")
            return compiler._execute_graph(
                self.program.root,
                env,
                fuse_join_agg=self.fuse_join_agg,
                dispatch=dispatch,
                resolutions=resolutions,
            )

        prog = self.program
        fwd_cache: Env = {}
        out = compiler._execute_graph(
            prog.forward.root,
            env,
            cache=fwd_cache,
            fuse_join_agg=self.fuse_join_agg,
            dispatch=dispatch,
            resolutions=resolutions,
        )
        if seed is None:
            if not (isinstance(out, DenseRelation) and out.key_arity == 0):
                raise ValueError("default seed requires a scalar-loss output")
            seed = DenseRelation(jnp.ones_like(out.data), key_arity=0)
        genv = dict(env)
        genv.update(fwd_cache)
        genv["__seed"] = seed
        # Gradient graphs fuse their own join-aggs regardless of how the
        # forward was executed (matches the historical grad_eval contract;
        # rjp_ablation relies on it).
        grads = {
            name: compiler._execute_graph(
                rootn, genv, dispatch=dispatch, resolutions=resolutions
            )
            for name, rootn in prog.grads.items()
        }
        return out, grads

    # -- the staged pipeline ----------------------------------------------
    def eager(
        self, env: Env, seed: Optional[AnyRel] = None, *, dispatch=None
    ):
        """Un-staged execution: walk the graph now, every call.
        ``dispatch`` takes anything ``kernels.make_table`` accepts."""
        table = kernels.make_table(dispatch)
        return self._execute(env, seed, dispatch=table)

    def lower(
        self, env: Env, seed: Optional[AnyRel] = None, *, dispatch=None
    ) -> Lowered:
        """Trace the chunked lowering at ``env``'s shapes under a kernel
        DispatchTable (``dispatch`` accepts anything ``kernels.make_table``
        does; None → backend default). Cached: a second call with an
        identical (signature, table) pair returns the same Lowered without
        re-walking the graph; switching tiers is a cache miss and
        re-lowers."""
        table = kernels.make_table(dispatch)
        sig = env_signature(env, seed)
        key = (sig, table)
        hit = self._lowered.get(key)
        if hit is not None:
            return hit
        abstract_env = {k: _abstract(v) for k, v in env.items()}
        abstract_seed = None if seed is None else _abstract(seed)
        resolutions: Dict[str, str] = {}
        out_shape = jax.eval_shape(
            functools.partial(
                self._execute, dispatch=table, resolutions=resolutions
            ),
            abstract_env,
            abstract_seed,
        )
        low = Lowered(
            self, sig, table, abstract_env, abstract_seed, out_shape, resolutions
        )
        self._lowered[key] = low
        return low


# ---------------------------------------------------------------------------
# Module-level engine registry + one-call convenience
# ---------------------------------------------------------------------------

_ENGINES: "OrderedDict[Tuple[int, bool], RAEngine]" = OrderedDict()
_MAX_ENGINES = 256

#: ambient-mesh stack; a ContextVar so concurrent threads / tasks (e.g. a
#: serving worker pool) each see only their own use_mesh nesting.
_MESH_STACK: "contextvars.ContextVar[Tuple[Any, ...]]" = contextvars.ContextVar(
    "repro_engine_mesh_stack", default=()
)


@contextlib.contextmanager
def use_mesh(mesh):
    """Make ``mesh`` the default mesh of every ``jit_execute`` call in the
    block — the canonical way to run the relational operator layer
    (``rel_matmul``, ``gcn_conv``, ``rel_embed``) distributed, since the
    ``custom_vjp`` wrappers expose no mesh argument of their own.

    ``mesh`` is a jax Mesh or a ``launch/mesh.resolve_mesh`` spec string
    (``"host"``, ``"host:<model>"``, ``"production"``,
    ``"production:multipod"``), so ``launch/mesh.make_host_mesh`` /
    ``make_production_mesh`` are the entry points either way::

        with use_mesh("host:2"):
            y = rel_matmul(x, w)      # planned + sharded on the host mesh
    """
    if isinstance(mesh, str):
        from repro.launch.mesh import resolve_mesh

        mesh = resolve_mesh(mesh)
    token = _MESH_STACK.set(_MESH_STACK.get() + (mesh,))
    try:
        yield mesh
    finally:
        _MESH_STACK.reset(token)


def default_mesh():
    """The innermost ``use_mesh`` mesh, or None."""
    stack = _MESH_STACK.get()
    return stack[-1] if stack else None


def engine_for(program: Program, *, fuse_join_agg: bool = True) -> RAEngine:
    """Engine per (program identity, fuse flag), LRU-bounded. The engine
    holds a strong reference to the program, so the id key cannot be
    recycled while the entry lives."""
    key = (id(program), fuse_join_agg)
    eng = _ENGINES.get(key)
    if eng is not None and eng.source is program:
        _ENGINES.move_to_end(key)
        return eng
    eng = RAEngine(program, fuse_join_agg=fuse_join_agg)
    _ENGINES[key] = eng
    while len(_ENGINES) > _MAX_ENGINES:
        _ENGINES.popitem(last=False)
    return eng


def jit_execute(
    program: Program,
    env: Env,
    seed: Optional[AnyRel] = None,
    *,
    mesh=None,
    donate: Tuple[str, ...] = (),
    fuse_join_agg: bool = True,
    dispatch=None,
):
    """lower → plan → compile → run in one call, with every stage cached:
    per-program engine, per-(signature, dispatch-table) Lowered, per-mesh
    Compiled. This is the staged hot path the relational operator layer
    steps through. ``dispatch`` steers the kernel tier (see
    ``kernels.make_table``); ``mesh=None`` picks up the ambient
    ``use_mesh`` mesh, so the wrappers distribute without new arguments.
    The ambient mesh only applies at top level: under an active trace
    (an outer jit / grad) the planner's in_shardings would fight the
    shardings already carried by the traced operands, so sharding is
    left to propagate from them instead."""
    if mesh is None:
        try:
            trace_clean = jax.core.trace_state_clean()
        except AttributeError:  # no trace-state probe on this jax:
            trace_clean = False  # be safe, skip the ambient mesh
        if trace_clean:
            mesh = default_mesh()
    eng = engine_for(program, fuse_join_agg=fuse_join_agg)
    compiled = eng.lower(env, seed, dispatch=dispatch).compile(
        mesh=mesh, donate=donate
    )
    return compiled(env, seed)
