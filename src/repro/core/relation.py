"""Chunked relation representations for the compiled path (Appendix A).

Two physical layouts, mirroring what a tensor-relational engine stores:

  DenseRelation — the key set is a full grid range(n₀)×…×range(n_{d-1});
      tuples are laid out as one jnp array of shape (n₀,…,n_{d-1}, *chunk).
      This is the layout for blocked matrices/tensors (paper §2.1 Fig 1).

  CooRelation — sparse key set: an int32 key array (nnz, d) plus a value
      array (nnz, *chunk) and per-column extents. This is the layout for
      graph edge relations (paper §1 GCN example).

Both carry ``chunk_rank`` — the number of trailing value ("chunk") dims —
so executors can separate block-key axes from within-chunk axes.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

#: sentinel key component marking padded COO rows (see ``pad_coo_nnz``):
#: every lowering that consumes COO keys drops out-of-range ids, so padded
#: rows contribute nothing to gathers or segment sums.
COO_PAD_KEY = -1


@dataclass
class DenseRelation:
    data: jnp.ndarray
    key_arity: int

    @property
    def chunk_rank(self) -> int:
        return self.data.ndim - self.key_arity

    @property
    def extents(self) -> Tuple[int, ...]:
        return tuple(self.data.shape[: self.key_arity])

    @property
    def chunk_shape(self) -> Tuple[int, ...]:
        return tuple(self.data.shape[self.key_arity:])

    def to_sparse(self) -> dict:
        """Materialize as dict for interpreter cross-checks (small inputs)."""
        out = {}
        arr = np.asarray(self.data)
        for key in np.ndindex(*self.extents):
            v = arr[key]
            out[tuple(int(i) for i in key)] = v if self.chunk_rank else float(v)
        return out


@dataclass
class CooRelation:
    """Sparse relation: ``keys`` (nnz, d) int32 + ``values`` (nnz, *chunk).

    The nnz dimension is the *physical* row axis the distribution planner
    shards over the mesh's data axes (core/planner.py). ``owner_dim`` /
    ``shard_offsets`` describe the optional **owner-partitioned layout**
    produced by ``owner_partition``: rows sorted by the key column
    ``owner_dim`` (the Σ's segment key, e.g. a GCN edge's dst) and padded
    to a shard multiple, with ``shard_offsets[s]`` recording the first
    owner key held by shard ``s``. The layout is what lets the planner
    cost the Σ-over-edges scatter at its edge-cut estimate instead of a
    full all-reduce. Both fields are static schema (pytree aux data) like
    ``extents``; ``None`` means unpartitioned.
    """

    keys: jnp.ndarray    # (nnz, key_arity) int32
    values: jnp.ndarray  # (nnz, *chunk)
    extents: Tuple[int, ...]
    owner_dim: Optional[int] = None
    shard_offsets: Optional[Tuple[int, ...]] = None

    @property
    def key_arity(self) -> int:
        return int(self.keys.shape[1])

    @property
    def nnz(self) -> int:
        return int(self.keys.shape[0])

    @property
    def chunk_rank(self) -> int:
        return self.values.ndim - 1

    @property
    def chunk_shape(self) -> Tuple[int, ...]:
        return tuple(self.values.shape[1:])

    def to_sparse(self) -> dict:
        out = {}
        keys = np.asarray(self.keys)
        vals = np.asarray(self.values)
        for i in range(keys.shape[0]):
            k = tuple(int(x) for x in keys[i])
            v = vals[i]
            out[k] = v if self.chunk_rank else float(v)
        return out


Relation = (DenseRelation, CooRelation)


# ---------------------------------------------------------------------------
# Pytree registration: relations cross jax.jit / shard boundaries as
# containers whose array payloads are leaves and whose relational schema
# (key arity, COO extents) is static aux data. This is what lets the staged
# engine (core/engine.py) jit a whole relation environment and attach
# planner-emitted shardings per relation.
# ---------------------------------------------------------------------------


def _dense_flatten(rel: DenseRelation):
    return (rel.data,), rel.key_arity


def _dense_unflatten(key_arity: int, children) -> DenseRelation:
    (data,) = children
    return DenseRelation(data, key_arity)


def _coo_flatten(rel: CooRelation):
    return (rel.keys, rel.values), (
        rel.extents,
        rel.owner_dim,
        rel.shard_offsets,
    )


def _coo_unflatten(aux, children) -> CooRelation:
    keys, values = children
    extents, owner_dim, shard_offsets = aux
    return CooRelation(keys, values, extents, owner_dim, shard_offsets)


jax.tree_util.register_pytree_node(
    DenseRelation, _dense_flatten, _dense_unflatten
)
jax.tree_util.register_pytree_node(CooRelation, _coo_flatten, _coo_unflatten)


def from_blocked(x, block_shape: Tuple[int, ...]) -> DenseRelation:
    """Split a dense array into a chunked DenseRelation (paper Fig 1)."""
    x = jnp.asarray(x)
    assert x.ndim == len(block_shape)
    grid = []
    for n, b in zip(x.shape, block_shape):
        assert n % b == 0, (n, b)
        grid.append(n // b)
    # (g0,b0,g1,b1,...) -> (g0,g1,...,b0,b1,...)
    shape = []
    for g, b in zip(grid, block_shape):
        shape += [g, b]
    y = x.reshape(shape)
    perm = list(range(0, 2 * len(grid), 2)) + list(range(1, 2 * len(grid), 2))
    return DenseRelation(jnp.transpose(y, perm), key_arity=len(grid))


def to_blocked(rel: DenseRelation):
    """Inverse of from_blocked: reassemble the dense array."""
    d = rel.key_arity
    grid = rel.extents
    block = rel.chunk_shape
    assert len(block) == d, "to_blocked requires chunk_rank == key_arity"
    perm = [None] * (2 * d)
    for i in range(d):
        perm[2 * i] = i
        perm[2 * i + 1] = d + i
    y = jnp.transpose(rel.data, perm)
    return y.reshape(tuple(g * b for g, b in zip(grid, block)))


def scalar_relation(value=1.0, dtype=jnp.float32) -> DenseRelation:
    """The one-tuple relation {(⟨⟩, value)} — loss outputs / gradient seeds."""
    return DenseRelation(jnp.asarray(value, dtype=dtype), key_arity=0)


# ---------------------------------------------------------------------------
# COO nnz-dimension layouts (the sharded-graph fast path)
# ---------------------------------------------------------------------------


def pad_coo_nnz(rel: CooRelation, target_nnz: int) -> CooRelation:
    """Pad the nnz axis up to ``target_nnz`` rows with ``COO_PAD_KEY`` keys
    and zero values — the pad-and-mask layout the engine emits when a
    planned nnz sharding does not divide the row count. Padded rows are
    inert: every key column is out of range, so gathers mask them to zero
    and segment sums drop them."""
    pad = target_nnz - rel.nnz
    if pad < 0:
        raise ValueError(
            f"pad_coo_nnz: target {target_nnz} < nnz {rel.nnz}"
        )
    if pad == 0:
        return rel
    keys = jnp.pad(rel.keys, ((0, pad), (0, 0)), constant_values=COO_PAD_KEY)
    values = jnp.pad(
        rel.values, ((0, pad),) + ((0, 0),) * rel.chunk_rank
    )
    return CooRelation(keys, values, rel.extents, rel.owner_dim, rel.shard_offsets)


def measure_stats(rel):
    """Measure a relation's key-domain statistics — the
    ``planner.RelationStats`` a ``Database`` catalog tracks per table and
    refreshes on ``put``.

    DenseRelation key sets are full grids, so every statistic is exact
    and free (distinct = extents, density = 1, and each histogram bucket
    holds its share of the uniform grid). CooRelation key columns are
    counted with ``np.unique`` / ``np.histogram`` over the live
    (non-padded) rows — a host-side pass over concrete key arrays, i.e.
    a data-loading step like ``owner_partition``, never a traced one."""
    from .planner import HIST_BUCKETS, RelationStats

    def column_hist(values, extent, per_value=1):
        """Equi-width tuple counts over ``[0, extent)``."""
        if extent <= 0:
            return tuple([0] * HIST_BUCKETS)
        counts, _ = np.histogram(
            values, bins=HIST_BUCKETS, range=(0, extent)
        )
        return tuple(int(c) * int(per_value) for c in counts)

    if isinstance(rel, DenseRelation):
        extents = rel.extents
        size = 1
        for e in extents:
            size *= int(e)
        hist = tuple(
            column_hist(
                np.arange(int(e)), int(e), size // int(e) if int(e) else 0
            )
            for e in extents
        )
        return RelationStats(
            distinct=tuple(int(e) for e in extents),
            extents=tuple(int(e) for e in extents),
            nnz=size,
            density=1.0,
            hist=hist,
        )
    if isinstance(rel, CooRelation):
        keys = np.asarray(rel.keys)
        live = keys[keys[:, 0] != COO_PAD_KEY] if keys.size else keys
        nnz = int(live.shape[0])
        distinct = tuple(
            int(np.unique(live[:, j]).size) if nnz else 0
            for j in range(rel.key_arity)
        )
        size = 1
        for e in rel.extents:
            size *= int(e)
        hist = tuple(
            column_hist(live[:, j], int(rel.extents[j]))
            for j in range(rel.key_arity)
        )
        return RelationStats(
            distinct=distinct,
            extents=tuple(int(e) for e in rel.extents),
            nnz=nnz,
            density=(nnz / size) if size else 0.0,
            hist=hist,
        )
    raise TypeError(f"measure_stats: not a relation: {type(rel)}")


# ---------------------------------------------------------------------------
# Chunk manifests: the host-resident blocked layout for out-of-core waves
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ChunkManifest:
    """Row-blocking of one relation for out-of-core execution.

    ``axis`` is the blocked dimension — a key dim for a DenseRelation, and
    always the physical nnz row axis for a CooRelation. ``boundaries`` is
    the monotone cut vector (num_chunks+1 entries, first 0, last the row
    count), so chunk ``w`` is rows ``[boundaries[w], boundaries[w+1])``.
    ``owner_aligned`` records that COO cuts were snapped to owner-run
    starts (see ``make_manifest``): no Σ segment then straddles a wave, so
    each wave's partial segment grid is exact where touched and the
    ⊕-unit elsewhere — what lets zero-preserving kernels stream."""

    axis: int
    boundaries: Tuple[int, ...]
    owner_aligned: bool = False

    @property
    def num_chunks(self) -> int:
        return len(self.boundaries) - 1

    def chunk_rows(self, w: int) -> int:
        return self.boundaries[w + 1] - self.boundaries[w]

    @property
    def max_rows(self) -> int:
        return max(
            self.boundaries[w + 1] - self.boundaries[w]
            for w in range(self.num_chunks)
        )


def make_manifest(rel, num_chunks: int, axis: int = 0) -> ChunkManifest:
    """Block ``rel`` into ``num_chunks`` row ranges.

    Dense relations split a key dim evenly (remainder spread over the
    leading chunks). COO relations split the nnz axis; when the relation
    is owner-partitioned, tentative even cuts are snapped *down* to the
    start of the owner run they fall into, so one Σ segment is never split
    across two waves (duplicate cuts collapse — heavy owners can reduce
    the chunk count)."""
    if num_chunks < 1:
        raise ValueError(f"make_manifest: num_chunks={num_chunks} must be >= 1")
    if isinstance(rel, DenseRelation):
        if not 0 <= axis < rel.key_arity:
            raise ValueError(
                f"make_manifest: axis {axis} out of range for key arity "
                f"{rel.key_arity}"
            )
        rows = int(rel.extents[axis])
    elif isinstance(rel, CooRelation):
        axis = 0
        rows = rel.nnz
    else:
        raise TypeError(f"make_manifest: not a relation: {type(rel)}")
    if num_chunks > max(rows, 1):
        raise ValueError(
            f"make_manifest: {num_chunks} chunks over {rows} rows"
        )
    base, rem = divmod(rows, num_chunks)
    cuts = [0]
    for w in range(num_chunks):
        cuts.append(cuts[-1] + base + (1 if w < rem else 0))
    owner_aligned = False
    if isinstance(rel, CooRelation) and rel.owner_dim is not None and rows:
        owners = np.asarray(rel.keys)[:, rel.owner_dim]
        # first row of each contiguous owner run; in the owner-sorted live
        # region runs ARE owner groups, and the trailing COO_PAD_KEY pad
        # rows form one final run of their own (splitting pads is harmless)
        starts = np.flatnonzero(np.r_[True, owners[1:] != owners[:-1]])
        snapped = [0]
        for t in cuts[1:-1]:
            s = int(starts[np.searchsorted(starts, t, side="right") - 1])
            if s > snapped[-1]:
                snapped.append(s)
        snapped.append(rows)
        cuts = snapped
        owner_aligned = True
    return ChunkManifest(axis, tuple(cuts), owner_aligned)


def split_chunks(rel, manifest: ChunkManifest):
    """Materialize the manifest's chunks as host-resident relations
    (numpy payloads — this is the spill step, not a traced one)."""
    out = []
    for w in range(manifest.num_chunks):
        lo, hi = manifest.boundaries[w], manifest.boundaries[w + 1]
        if isinstance(rel, DenseRelation):
            data = np.asarray(rel.data)
            sl = [slice(None)] * data.ndim
            sl[manifest.axis] = slice(lo, hi)
            out.append(DenseRelation(data[tuple(sl)], rel.key_arity))
        else:
            out.append(
                CooRelation(
                    np.asarray(rel.keys)[lo:hi],
                    np.asarray(rel.values)[lo:hi],
                    rel.extents,
                    rel.owner_dim,
                    None,
                )
            )
    return out


def assemble_chunks(chunks, manifest: ChunkManifest):
    """Inverse of ``split_chunks``: reassemble one relation."""
    if not chunks:
        raise ValueError("assemble_chunks: no chunks")
    first = chunks[0]
    if isinstance(first, DenseRelation):
        data = np.concatenate(
            [np.asarray(c.data) for c in chunks], axis=manifest.axis
        )
        return DenseRelation(data, first.key_arity)
    keys = np.concatenate([np.asarray(c.keys) for c in chunks], axis=0)
    values = np.concatenate([np.asarray(c.values) for c in chunks], axis=0)
    return CooRelation(keys, values, first.extents, first.owner_dim, None)


def rechunk(chunks, old: ChunkManifest, new: ChunkManifest):
    """Re-block a chunked relation from manifest ``old`` to ``new`` —
    the same all-to-all ``split ∘ assemble`` whether the target is a
    different grid or a different tier. Round-tripping A→B→A is
    bit-stable (pure row movement, no arithmetic)."""
    if old.boundaries[-1] != new.boundaries[-1]:
        raise ValueError(
            f"rechunk: row counts differ ({old.boundaries[-1]} vs "
            f"{new.boundaries[-1]})"
        )
    if old.axis != new.axis:
        raise ValueError(f"rechunk: axes differ ({old.axis} vs {new.axis})")
    return split_chunks(assemble_chunks(chunks, old), new)


def owner_partition(
    rel: CooRelation, num_shards: int, dim: int = -1
) -> CooRelation:
    """Owner-partitioned nnz layout: sort rows by the key column ``dim``
    (the Σ's segment key — a GCN edge's dst node), pad nnz to a multiple
    of ``num_shards``, and record per-shard segment offsets.

    Under an nnz sharding over ``num_shards`` devices, each equal shard of
    the sorted rows then holds a contiguous owner-key range
    (``shard_offsets[s]`` is the first owner key of shard ``s``; a shard
    whose rows are all padding owns no segments and records the
    one-past-the-end owner extent), so the Σ-by-owner scatter is local
    except at range boundaries — the layout the planner's edge-cut
    estimate (``planner.EDGE_CUT_LOCAL``) prices. Sorting happens on the
    host (numpy): this is a data-loading step, not a traced one."""
    if num_shards < 1:
        raise ValueError(f"owner_partition: num_shards={num_shards} must be >= 1")
    dim = dim % rel.key_arity
    keys = np.asarray(rel.keys)
    values = np.asarray(rel.values)
    order = np.argsort(keys[:, dim], kind="stable")
    sorted_rel = CooRelation(
        jnp.asarray(keys[order]),
        jnp.asarray(values[order]),
        rel.extents,
        owner_dim=dim,
    )
    padded_nnz = ((sorted_rel.nnz + num_shards - 1) // num_shards) * num_shards
    sorted_rel = pad_coo_nnz(sorted_rel, padded_nnz)
    per = padded_nnz // num_shards
    owners = keys[order][:, dim]
    end = int(rel.extents[dim])  # empty-shard sentinel: one past the last owner
    offsets = tuple(
        int(owners[s * per]) if s * per < len(owners) else end
        for s in range(num_shards)
    )
    return CooRelation(
        sorted_rel.keys,
        sorted_rel.values,
        rel.extents,
        owner_dim=dim,
        shard_offsets=offsets,
    )
