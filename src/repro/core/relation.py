"""Chunked relation representations for the compiled path (Appendix A).

Two physical layouts, mirroring what a tensor-relational engine stores:

  DenseRelation — the key set is a full grid range(n₀)×…×range(n_{d-1});
      tuples are laid out as one jnp array of shape (n₀,…,n_{d-1}, *chunk).
      This is the layout for blocked matrices/tensors (paper §2.1 Fig 1).

  CooRelation — sparse key set: an int32 key array (nnz, d) plus a value
      array (nnz, *chunk) and per-column extents. This is the layout for
      graph edge relations (paper §1 GCN example).

Both carry ``chunk_rank`` — the number of trailing value ("chunk") dims —
so executors can separate block-key axes from within-chunk axes.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class DenseRelation:
    data: jnp.ndarray
    key_arity: int

    @property
    def chunk_rank(self) -> int:
        return self.data.ndim - self.key_arity

    @property
    def extents(self) -> Tuple[int, ...]:
        return tuple(self.data.shape[: self.key_arity])

    @property
    def chunk_shape(self) -> Tuple[int, ...]:
        return tuple(self.data.shape[self.key_arity:])

    def to_sparse(self) -> dict:
        """Materialize as dict for interpreter cross-checks (small inputs)."""
        out = {}
        arr = np.asarray(self.data)
        for key in np.ndindex(*self.extents):
            v = arr[key]
            out[tuple(int(i) for i in key)] = v if self.chunk_rank else float(v)
        return out


@dataclass
class CooRelation:
    keys: jnp.ndarray    # (nnz, key_arity) int32
    values: jnp.ndarray  # (nnz, *chunk)
    extents: Tuple[int, ...]

    @property
    def key_arity(self) -> int:
        return int(self.keys.shape[1])

    @property
    def nnz(self) -> int:
        return int(self.keys.shape[0])

    @property
    def chunk_rank(self) -> int:
        return self.values.ndim - 1

    @property
    def chunk_shape(self) -> Tuple[int, ...]:
        return tuple(self.values.shape[1:])

    def to_sparse(self) -> dict:
        out = {}
        keys = np.asarray(self.keys)
        vals = np.asarray(self.values)
        for i in range(keys.shape[0]):
            k = tuple(int(x) for x in keys[i])
            v = vals[i]
            out[k] = v if self.chunk_rank else float(v)
        return out


Relation = (DenseRelation, CooRelation)


# ---------------------------------------------------------------------------
# Pytree registration: relations cross jax.jit / shard boundaries as
# containers whose array payloads are leaves and whose relational schema
# (key arity, COO extents) is static aux data. This is what lets the staged
# engine (core/engine.py) jit a whole relation environment and attach
# planner-emitted shardings per relation.
# ---------------------------------------------------------------------------


def _dense_flatten(rel: DenseRelation):
    return (rel.data,), rel.key_arity


def _dense_unflatten(key_arity: int, children) -> DenseRelation:
    (data,) = children
    return DenseRelation(data, key_arity)


def _coo_flatten(rel: CooRelation):
    return (rel.keys, rel.values), rel.extents


def _coo_unflatten(extents: Tuple[int, ...], children) -> CooRelation:
    keys, values = children
    return CooRelation(keys, values, extents)


jax.tree_util.register_pytree_node(
    DenseRelation, _dense_flatten, _dense_unflatten
)
jax.tree_util.register_pytree_node(CooRelation, _coo_flatten, _coo_unflatten)


def from_blocked(x, block_shape: Tuple[int, ...]) -> DenseRelation:
    """Split a dense array into a chunked DenseRelation (paper Fig 1)."""
    x = jnp.asarray(x)
    assert x.ndim == len(block_shape)
    grid = []
    for n, b in zip(x.shape, block_shape):
        assert n % b == 0, (n, b)
        grid.append(n // b)
    # (g0,b0,g1,b1,...) -> (g0,g1,...,b0,b1,...)
    shape = []
    for g, b in zip(grid, block_shape):
        shape += [g, b]
    y = x.reshape(shape)
    perm = list(range(0, 2 * len(grid), 2)) + list(range(1, 2 * len(grid), 2))
    return DenseRelation(jnp.transpose(y, perm), key_arity=len(grid))


def to_blocked(rel: DenseRelation):
    """Inverse of from_blocked: reassemble the dense array."""
    d = rel.key_arity
    grid = rel.extents
    block = rel.chunk_shape
    assert len(block) == d, "to_blocked requires chunk_rank == key_arity"
    perm = [None] * (2 * d)
    for i in range(d):
        perm[2 * i] = i
        perm[2 * i + 1] = d + i
    y = jnp.transpose(rel.data, perm)
    return y.reshape(tuple(g * b for g, b in zip(grid, block)))


def scalar_relation(value=1.0, dtype=jnp.float32) -> DenseRelation:
    """The one-tuple relation {(⟨⟩, value)} — loss outputs / gradient seeds."""
    return DenseRelation(jnp.asarray(value, dtype=dtype), key_arity=0)
