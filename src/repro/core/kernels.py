"""Kernel functions for the functional RA, with derivative registry.

The paper parameterizes RA operations with scalar kernel functions and, in
the chunked "tensor-relational" extension (Appendix A), with tensor kernels
(MatMul/MatAdd/...). RJP construction needs, for every kernel, its
derivative in VJP form:

  unary   ⊙ : V -> V          vjp(g, x)        =  (∂⊙(x)/∂x)ᵀ · g
  binary  ⊗ : V x V -> V      vjp_l(g, l, r)   =  (∂⊗/∂l)ᵀ · g
                              vjp_r(g, l, r)   =  (∂⊗/∂r)ᵀ · g
  agg     ⊕ : V x V -> V      commutative+associative; for ⊕ = add the
                              derivative is the identity map on g.

Kernels are looked up by name so query graphs stay picklable/hashable and
the compiler can pattern-match (e.g. ⊗ ∈ {mul, matmul} + ⊕ = add → einsum).
Per Appendix A, derivatives of *chunk* kernels may be produced by
conventional auto-diff (JAX) — that is where ``jax.grad``/``jax.vjp`` is
allowed; the relational layer above never calls it.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class UnaryKernel:
    name: str
    fn: Callable
    vjp: Callable  # vjp(g, x)

    def __repr__(self) -> str:
        return f"⊙{self.name}"


@dataclass(frozen=True)
class BinKernel:
    name: str
    fn: Callable
    vjp_l: Callable  # vjp_l(g, l, r)
    vjp_r: Callable  # vjp_r(g, l, r)
    # "multiplicative" kernels admit the paper's §4 ⋈_const-elimination:
    # ∂⊗/∂l depends only on (g, r) and ∂⊗/∂r only on (g, l).
    multiplicative: bool = False
    # einsum lowering hints for the chunked compiler:
    #   elementwise  — ⊗ multiplies chunks pointwise (broadcasting)
    #   chunk_spec   — (l, r, out) einsum letters over *chunk* dims
    #                  (e.g. matmul: ('mk', 'kn', 'mn')); lowercase reserved
    #                  for chunks, uppercase for block-key axes.
    elementwise: bool = False
    chunk_spec: Optional[tuple] = None

    def __repr__(self) -> str:
        return f"⊗{self.name}"


@dataclass(frozen=True)
class AggKernel:
    name: str
    fn: Callable  # fn(a, b), commutative + associative
    # unit for reductions over an empty/masked set, as a float
    unit: float = 0.0
    # is ⊕ == +? (enables the paper's constant-grp RJP simplification and
    # einsum lowering)
    is_add: bool = True

    def __repr__(self) -> str:
        return f"⊕{self.name}"


_UNARY: Dict[str, UnaryKernel] = {}
_BIN: Dict[str, BinKernel] = {}
_AGG: Dict[str, AggKernel] = {}


def register_unary(name: str, fn: Callable, vjp: Optional[Callable] = None) -> UnaryKernel:
    if vjp is None:
        # Appendix A: chunk-kernel derivatives via conventional auto-diff.
        def vjp(g, x, _fn=fn):
            _, pull = jax.vjp(_fn, x)
            return pull(g)[0]

    k = UnaryKernel(name, fn, vjp)
    _UNARY[name] = k
    return k


def register_bin(
    name: str,
    fn: Callable,
    vjp_l: Optional[Callable] = None,
    vjp_r: Optional[Callable] = None,
    multiplicative: bool = False,
    elementwise: bool = False,
    chunk_spec: Optional[tuple] = None,
) -> BinKernel:
    if vjp_l is None:
        def vjp_l(g, l, r, _fn=fn):
            _, pull = jax.vjp(_fn, l, r)
            return pull(g)[0]

    if vjp_r is None:
        def vjp_r(g, l, r, _fn=fn):
            _, pull = jax.vjp(_fn, l, r)
            return pull(g)[1]

    k = BinKernel(name, fn, vjp_l, vjp_r, multiplicative, elementwise, chunk_spec)
    _BIN[name] = k
    return k


def register_agg(name: str, fn: Callable, unit: float = 0.0, is_add: bool = True) -> AggKernel:
    k = AggKernel(name, fn, unit, is_add)
    _AGG[name] = k
    return k


def unary(name: str) -> UnaryKernel:
    return _UNARY[name]


def bin_kernel(name: str) -> BinKernel:
    return _BIN[name]


def agg(name: str) -> AggKernel:
    return _AGG[name]


# ---------------------------------------------------------------------------
# Standard kernels
# ---------------------------------------------------------------------------

# -- aggregation ⊕ ----------------------------------------------------------
ADD = register_agg("add", lambda a, b: a + b)           # scalars and chunks
MATADD = register_agg("matadd", lambda a, b: a + b)      # alias, paper's name
MAX = register_agg("max", jnp.maximum, unit=-jnp.inf, is_add=False)

# -- binary ⊗ ---------------------------------------------------------------
MUL = register_bin(
    "mul",
    lambda l, r: l * r,
    vjp_l=lambda g, l, r: g * r,
    vjp_r=lambda g, l, r: g * l,
    multiplicative=True,
    elementwise=True,
)

# Blocked matrix multiply over chunks. vjp_l/vjp_r are the paper's Fig. 4
# optimized RJP kernels: dL = g @ rᵀ, dR = lᵀ @ g.
MATMUL = register_bin(
    "matmul",
    lambda l, r: jnp.matmul(l, r),
    vjp_l=lambda g, l, r: jnp.matmul(g, jnp.swapaxes(r, -1, -2)),
    vjp_r=lambda g, l, r: jnp.matmul(jnp.swapaxes(l, -1, -2), g),
    multiplicative=True,
    chunk_spec=("mk", "kn", "mn"),
)

ADD2 = register_bin(
    "add2",
    lambda l, r: l + r,
    vjp_l=lambda g, l, r: g,
    vjp_r=lambda g, l, r: g,
)

SUB = register_bin(
    "sub",
    lambda l, r: l - r,
    vjp_l=lambda g, l, r: g,
    vjp_r=lambda g, l, r: -g,
)

# cross-entropy ⊗ for logistic regression (paper §2.3):
#   ⊗(yhat, y) = -y·log(yhat) + (y-1)·log(1-yhat)
XENT = register_bin(
    "xent",
    lambda yhat, y: -y * jnp.log(yhat) + (y - 1.0) * jnp.log1p(-yhat),
    vjp_l=lambda g, yhat, y: g * (-y / yhat - (y - 1.0) / (1.0 - yhat)),
    vjp_r=lambda g, yhat, y: g * (-jnp.log(yhat) + jnp.log1p(-yhat)),
)

# squared error ⊗(pred, target) = 0.5(pred-target)^2, for NNMF / KGE
SQERR = register_bin(
    "sqerr",
    lambda p, t: 0.5 * (p - t) ** 2,
    vjp_l=lambda g, p, t: g * (p - t),
    vjp_r=lambda g, p, t: g * (t - p),
)

# -- unary ⊙ ----------------------------------------------------------------
IDENT = register_unary("ident", lambda x: x, vjp=lambda g, x: g)
NEG = register_unary("neg", lambda x: -x, vjp=lambda g, x: -g)
LOGISTIC = register_unary(
    "logistic",
    jax.nn.sigmoid,
    vjp=lambda g, x: g * jax.nn.sigmoid(x) * (1.0 - jax.nn.sigmoid(x)),
)
RELU = register_unary("relu", jax.nn.relu, vjp=lambda g, x: g * (x > 0))
EXP = register_unary("exp", jnp.exp, vjp=lambda g, x: g * jnp.exp(x))
SQUARE = register_unary("square", lambda x: x * x, vjp=lambda g, x: 2.0 * g * x)
# Reduce a chunk to a scalar value (chunked losses). Chunk-local semantics:
# executors vmap kernels over block-key axes, so jnp.sum sees one chunk.
SUM_CHUNK = register_unary(
    "sum_chunk",
    lambda x: jnp.sum(x),
    vjp=lambda g, x: g * jnp.ones_like(x),
)
SCALE = {}


def scale_kernel(c: float) -> UnaryKernel:
    """⊙(x) = c·x — memoized per constant."""
    key = float(c)
    if key not in SCALE:
        SCALE[key] = register_unary(
            f"scale[{key}]", lambda x, _c=key: _c * x, vjp=lambda g, x, _c=key: _c * g
        )
    return SCALE[key]
