"""Kernel functions for the functional RA, with derivative registry, plus
the physical-kernel **dispatch registry** the chunked compiler routes hot
operators through.

The paper parameterizes RA operations with scalar kernel functions and, in
the chunked "tensor-relational" extension (Appendix A), with tensor kernels
(MatMul/MatAdd/...). RJP construction needs, for every kernel, its
derivative in VJP form:

  unary   ⊙ : V -> V          vjp(g, x)        =  (∂⊙(x)/∂x)ᵀ · g
  binary  ⊗ : V x V -> V      vjp_l(g, l, r)   =  (∂⊗/∂l)ᵀ · g
                              vjp_r(g, l, r)   =  (∂⊗/∂r)ᵀ · g
  agg     ⊕ : V x V -> V      commutative+associative; for ⊕ = add the
                              derivative is the identity map on g.

Kernels are looked up by name so query graphs stay picklable/hashable and
the compiler can pattern-match (e.g. ⊗ ∈ {mul, matmul} + ⊕ = add → einsum).
Per Appendix A, derivatives of *chunk* kernels may be produced by
conventional auto-diff (JAX) — that is where ``jax.grad``/``jax.vjp`` is
allowed; the relational layer above never calls it.

Separately from the *logical* kernels above, this module owns the
**dispatch registry** (``register_impl`` / ``resolve_impl`` /
``DispatchTable``): the mapping from the compiler's hot logical ops
(``segment_sum`` — the Σ over a CooRelation; ``blocked_matmul`` — the
matmul-shaped Σ∘⋈ einsum) to physical implementations, tiered per backend
(``pallas`` on TPU, ``interpret``/``ref`` on CPU, ``jnp`` as the default).
See docs/kernels.md for the authoring guide and the registry contract.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class UnaryKernel:
    name: str
    fn: Callable
    vjp: Callable  # vjp(g, x)
    # Streamability hints for out-of-core wave planning (core/planner.py):
    #   linear           — ⊙(a + b) = ⊙(a) + ⊙(b); safe after a Σ that has
    #                      only been partially accumulated across waves
    #   zero_preserving  — ⊙(0) = 0; safe on a segment grid whose untouched
    #                      segments are still the Σ unit (owner-aligned waves)
    linear: bool = False
    zero_preserving: bool = False

    def __repr__(self) -> str:
        return f"⊙{self.name}"


@dataclass(frozen=True)
class BinKernel:
    name: str
    fn: Callable
    vjp_l: Callable  # vjp_l(g, l, r)
    vjp_r: Callable  # vjp_r(g, l, r)
    # "multiplicative" kernels admit the paper's §4 ⋈_const-elimination:
    # ∂⊗/∂l depends only on (g, r) and ∂⊗/∂r only on (g, l).
    multiplicative: bool = False
    # einsum lowering hints for the chunked compiler:
    #   elementwise  — ⊗ multiplies chunks pointwise (broadcasting)
    #   chunk_spec   — (l, r, out) einsum letters over *chunk* dims
    #                  (e.g. matmul: ('mk', 'kn', 'mn')); lowercase reserved
    #                  for chunks, uppercase for block-key axes.
    elementwise: bool = False
    chunk_spec: Optional[tuple] = None

    def __repr__(self) -> str:
        return f"⊗{self.name}"


@dataclass(frozen=True)
class AggKernel:
    name: str
    fn: Callable  # fn(a, b), commutative + associative
    # unit for reductions over an empty/masked set, as a float
    unit: float = 0.0
    # is ⊕ == +? (enables the paper's constant-grp RJP simplification and
    # einsum lowering)
    is_add: bool = True

    def __repr__(self) -> str:
        return f"⊕{self.name}"


_UNARY: Dict[str, UnaryKernel] = {}
_BIN: Dict[str, BinKernel] = {}
_AGG: Dict[str, AggKernel] = {}


def register_unary(
    name: str,
    fn: Callable,
    vjp: Optional[Callable] = None,
    linear: bool = False,
    zero_preserving: bool = False,
) -> UnaryKernel:
    if vjp is None:
        # Appendix A: chunk-kernel derivatives via conventional auto-diff.
        def vjp(g, x, _fn=fn):  # type: ignore[no-redef]
            _, pull = jax.vjp(_fn, x)
            return pull(g)[0]

    k = UnaryKernel(name, fn, vjp, linear, zero_preserving)
    _UNARY[name] = k
    return k


def register_bin(
    name: str,
    fn: Callable,
    vjp_l: Optional[Callable] = None,
    vjp_r: Optional[Callable] = None,
    multiplicative: bool = False,
    elementwise: bool = False,
    chunk_spec: Optional[tuple] = None,
) -> BinKernel:
    if vjp_l is None:
        def vjp_l(g, l, r, _fn=fn):  # type: ignore[no-redef]
            _, pull = jax.vjp(_fn, l, r)
            return pull(g)[0]

    if vjp_r is None:
        def vjp_r(g, l, r, _fn=fn):  # type: ignore[no-redef]
            _, pull = jax.vjp(_fn, l, r)
            return pull(g)[1]

    k = BinKernel(name, fn, vjp_l, vjp_r, multiplicative, elementwise, chunk_spec)
    _BIN[name] = k
    return k


def register_agg(name: str, fn: Callable, unit: float = 0.0, is_add: bool = True) -> AggKernel:
    k = AggKernel(name, fn, unit, is_add)
    _AGG[name] = k
    return k


def unary(name: str) -> UnaryKernel:
    return _UNARY[name]


def bin_kernel(name: str) -> BinKernel:
    return _BIN[name]


def agg(name: str) -> AggKernel:
    return _AGG[name]


# ---------------------------------------------------------------------------
# Standard kernels
# ---------------------------------------------------------------------------

# -- aggregation ⊕ ----------------------------------------------------------
ADD = register_agg("add", lambda a, b: a + b)           # scalars and chunks
MATADD = register_agg("matadd", lambda a, b: a + b)      # alias, paper's name
MAX = register_agg("max", jnp.maximum, unit=-jnp.inf, is_add=False)

# -- binary ⊗ ---------------------------------------------------------------
MUL = register_bin(
    "mul",
    lambda l, r: l * r,
    vjp_l=lambda g, l, r: g * r,
    vjp_r=lambda g, l, r: g * l,
    multiplicative=True,
    elementwise=True,
)

# Blocked matrix multiply over chunks. vjp_l/vjp_r are the paper's Fig. 4
# optimized RJP kernels: dL = g @ rᵀ, dR = lᵀ @ g.
MATMUL = register_bin(
    "matmul",
    lambda l, r: jnp.matmul(l, r),
    vjp_l=lambda g, l, r: jnp.matmul(g, jnp.swapaxes(r, -1, -2)),
    vjp_r=lambda g, l, r: jnp.matmul(jnp.swapaxes(l, -1, -2), g),
    multiplicative=True,
    chunk_spec=("mk", "kn", "mn"),
)

ADD2 = register_bin(
    "add2",
    lambda l, r: l + r,
    vjp_l=lambda g, l, r: g,
    vjp_r=lambda g, l, r: g,
)

SUB = register_bin(
    "sub",
    lambda l, r: l - r,
    vjp_l=lambda g, l, r: g,
    vjp_r=lambda g, l, r: -g,
)

# cross-entropy ⊗ for logistic regression (paper §2.3):
#   ⊗(yhat, y) = -y·log(yhat) + (y-1)·log(1-yhat)
XENT = register_bin(
    "xent",
    lambda yhat, y: -y * jnp.log(yhat) + (y - 1.0) * jnp.log1p(-yhat),
    vjp_l=lambda g, yhat, y: g * (-y / yhat - (y - 1.0) / (1.0 - yhat)),
    vjp_r=lambda g, yhat, y: g * (-jnp.log(yhat) + jnp.log1p(-yhat)),
)

# squared error ⊗(pred, target) = 0.5(pred-target)^2, for NNMF / KGE
SQERR = register_bin(
    "sqerr",
    lambda p, t: 0.5 * (p - t) ** 2,
    vjp_l=lambda g, p, t: g * (p - t),
    vjp_r=lambda g, p, t: g * (t - p),
)

# -- unary ⊙ ----------------------------------------------------------------
IDENT = register_unary(
    "ident", lambda x: x, vjp=lambda g, x: g, linear=True, zero_preserving=True
)
NEG = register_unary(
    "neg", lambda x: -x, vjp=lambda g, x: -g, linear=True, zero_preserving=True
)
LOGISTIC = register_unary(
    "logistic",
    jax.nn.sigmoid,
    vjp=lambda g, x: g * jax.nn.sigmoid(x) * (1.0 - jax.nn.sigmoid(x)),
)
RELU = register_unary(
    "relu", jax.nn.relu, vjp=lambda g, x: g * (x > 0), zero_preserving=True
)
EXP = register_unary("exp", jnp.exp, vjp=lambda g, x: g * jnp.exp(x))
SQUARE = register_unary(
    "square", lambda x: x * x, vjp=lambda g, x: 2.0 * g * x, zero_preserving=True
)
# Reduce a chunk to a scalar value (chunked losses). Chunk-local semantics:
# executors vmap kernels over block-key axes, so jnp.sum sees one chunk.
SUM_CHUNK = register_unary(
    "sum_chunk",
    lambda x: jnp.sum(x),
    vjp=lambda g, x: g * jnp.ones_like(x),
    linear=True,
    zero_preserving=True,
)
SCALE: Dict[float, UnaryKernel] = {}


def scale_kernel(c: float) -> UnaryKernel:
    """⊙(x) = c·x — memoized per constant."""
    key = float(c)
    if key not in SCALE:
        SCALE[key] = register_unary(
            f"scale[{key}]",
            lambda x, _c=key: _c * x,
            vjp=lambda g, x, _c=key: _c * g,
            linear=True,
            zero_preserving=True,
        )
    return SCALE[key]


# ---------------------------------------------------------------------------
# Kernel dispatch registry: (logical op, backend, predicate) → implementation
#
# The chunked compiler (compiler.py) has two hardware hot-spots:
#
#   segment_sum     Σ over a CooRelation — fn(msg2d, seg, num_segments),
#                   msg2d: (E, D) float, seg: (E,) int32 (out-of-range ids
#                   are dropped), returns (num_segments, D).
#   blocked_matmul  the matmul-shaped Σ∘⋈ einsum — fn(x2d, y2d) → x @ y.
#   gather_join     the COO gather join (edge ⋈ node) and the restricted-
#                   join sparse-gradient gather — fn(table2d, rows),
#                   table2d: (N, D), rows: (E,) int32; out-of-range /
#                   negative ids (COO nnz padding) yield zero rows;
#                   returns (E, D).
#
# Instead of calling jax.ops.segment_sum / jnp.einsum directly, the
# compiler resolves each site against this registry at lowering time. A
# resolved choice is pinned by the DispatchTable the engine carries, so
# kernel selection is part of the lowering signature and hence of the jit
# cache key (core/engine.py). Tiers, from most to least specialized:
#
#   pallas     the hand-tiled TPU kernels (kernels/segsum, kernels/matmul)
#   interpret  the same Pallas kernels in interpreter mode — CPU
#              correctness tier for kernel logic, slow by construction
#   ref        the kernels' pure-jnp oracles (kernels/*/ref.py)
#   jnp        the compiler's original jnp lowering (einsum / segment_sum);
#              always registered, always applicable — the default tier
# ---------------------------------------------------------------------------

#: logical ops the compiler routes through the registry.
DISPATCH_OPS: Tuple[str, ...] = ("segment_sum", "blocked_matmul", "gather_join")

#: known tiers, in decreasing specialization order. ``sanitizer`` is the
#: instrumented cross-check tier: it replays the kernel's declared grid
#: model with out-of-bounds / write-race / uninitialized-accumulator
#: instrumentation (raising SanitizerError) and computes through the ref
#: oracle — never part of a default table, selected explicitly via
#: ``make_table("sanitizer")`` by CI and debugging sessions.
DISPATCH_TIERS: Tuple[str, ...] = ("pallas", "interpret", "sanitizer", "ref", "jnp")


class KernelDispatchError(LookupError):
    """No registered implementation matched (op, backend, predicate)."""


@dataclass(frozen=True)
class KernelImpl:
    """One registry entry.

    ``predicate(info)`` sees a dict of shape/dtype facts for the call site
    (segment_sum: nnz/dim/num_segments/dtype; blocked_matmul: m/k/n/dtype)
    and must be a pure function of it — resolution happens at lowering
    time and is replayed on retrace, so a flappy predicate would desync
    the lowering from its cache key.
    """

    op: str
    tier: str
    fn: Callable
    backends: Tuple[str, ...] = ()   # () = any jax platform
    priority: int = 0                # higher wins within a tier
    predicate: Optional[Callable] = None

    def __repr__(self) -> str:
        plats = ",".join(self.backends) or "any"
        return f"<{self.op}:{self.tier}@{plats}>"


_IMPLS: Dict[Tuple[str, str], List[KernelImpl]] = {}


def register_impl(
    op: str,
    tier: str,
    fn: Callable,
    *,
    backends: Tuple[str, ...] = (),
    priority: int = 0,
    predicate: Optional[Callable] = None,
) -> KernelImpl:
    """Register a physical implementation for a logical op under a tier.

    Entries within one (op, tier) bucket are tried in decreasing
    ``priority``; the first whose backend list admits the current platform
    and whose predicate accepts the site's shape/dtype info wins.
    """
    if tier not in DISPATCH_TIERS:
        raise ValueError(f"unknown tier {tier!r}; have {DISPATCH_TIERS}")
    impl = KernelImpl(op, tier, fn, tuple(backends), priority, predicate)
    bucket = _IMPLS.setdefault((op, tier), [])
    bucket.append(impl)
    bucket.sort(key=lambda i: -i.priority)
    return impl


@dataclass(frozen=True)
class DispatchTable:
    """Immutable (hashable) tier preference per logical op, pinned to one
    backend. This is the object the engine folds into the lowering
    signature: two tables that differ in any op's tier order produce
    distinct ``Lowered`` objects and therefore distinct jitted steps."""

    backend: str
    entries: Tuple[Tuple[str, Tuple[str, ...]], ...]  # sorted by op name

    def tiers(self, op: str) -> Tuple[str, ...]:
        for name, tiers in self.entries:
            if name == op:
                return tiers
        return ("jnp",)

    def describe(self) -> str:
        body = ", ".join(
            f"{op}→{'>'.join(tiers)}" for op, tiers in self.entries
        )
        return f"[{self.backend}] {body}"


def default_table(backend: Optional[str] = None) -> DispatchTable:
    """The default tier order for a backend: Pallas kernels (predicate-
    gated, jnp fallback) on TPU; the plain jnp lowerings elsewhere —
    CPU keeps its historical behaviour unless a tier is forced."""
    backend = backend or jax.default_backend()
    tiers = ("pallas", "jnp") if backend == "tpu" else ("jnp",)
    return DispatchTable(
        backend, tuple((op, tiers) for op in sorted(DISPATCH_OPS))
    )


def make_table(spec=None, backend: Optional[str] = None) -> DispatchTable:
    """Normalize a dispatch request into a DispatchTable.

    ``spec`` may be: None / ``"auto"`` (backend default), an existing
    DispatchTable, a tier name applied to every op (``"ref"``), a tuple of
    tier names tried in order, or a dict ``{op: tier | (tiers...)}`` —
    unmentioned ops keep their default tiers.
    """
    requested = backend
    backend = backend or jax.default_backend()
    if isinstance(spec, DispatchTable):
        if requested is not None and spec.backend != requested:
            raise ValueError(
                f"DispatchTable is pinned to backend {spec.backend!r} and "
                f"cannot be reinterpreted for {requested!r}; rebuild it "
                "with make_table(<tier spec>, backend=...)"
            )
        return spec
    if spec is None or spec == "auto":
        return default_table(backend)

    def norm(tiers) -> Tuple[str, ...]:
        if isinstance(tiers, str):
            tiers = (tiers,)
        tiers = tuple(tiers)
        bad = [t for t in tiers if t not in DISPATCH_TIERS]
        if bad:
            raise ValueError(f"unknown tier(s) {bad}; have {DISPATCH_TIERS}")
        return tiers

    if isinstance(spec, (str, tuple, list)):
        tiers = norm(spec)
        return DispatchTable(
            backend, tuple((op, tiers) for op in sorted(DISPATCH_OPS))
        )
    if isinstance(spec, dict):
        unknown = set(spec) - set(DISPATCH_OPS)
        if unknown:
            raise ValueError(f"unknown op(s) {sorted(unknown)}; have {DISPATCH_OPS}")
        base = dict(default_table(backend).entries)
        base.update({op: norm(t) for op, t in spec.items()})
        return DispatchTable(backend, tuple(sorted(base.items())))
    raise TypeError(f"cannot build a DispatchTable from {type(spec)}")


def resolve_impl(op: str, info: Dict, table: Optional[DispatchTable] = None) -> KernelImpl:
    """Walk the table's tier order for ``op`` and return the first
    implementation whose backend and predicate admit this site."""
    table = table or default_table()
    for tier in table.tiers(op):
        for impl in _IMPLS.get((op, tier), ()):
            if impl.backends and table.backend not in impl.backends:
                continue
            if impl.predicate is not None and not impl.predicate(info):
                continue
            return impl
    raise KernelDispatchError(
        f"no implementation of {op!r} for backend {table.backend!r} under "
        f"tiers {table.tiers(op)} with site info {info}"
    )


# ---------------------------------------------------------------------------
# Kernel contracts: the statically checkable shape of a Pallas kernel
#
# Every kernel package declares a ``CONTRACT`` (a KernelContract) next to
# its registration: the dtype domain its hardware tiers accept, the f32
# accumulator it carries, the masking obligations the wrapper discharges
# (COO_PAD_KEY rows, clamp-and-mask), which dispatch ops its custom VJP
# re-enters, and — the load-bearing part — a ``grid_model`` mapping a
# dispatch site-info dict to the exact ``grid`` + BlockSpec index maps the
# kernel would launch (padding mirrored from the ops.py wrapper).
#
# ``analysis.kernelcheck`` interprets the model abstractly (every output
# block stored by exactly one program instance, all index maps in-bounds,
# accumulator initialized before use); the ``sanitizer`` dispatch tier
# interprets the same model concretely at runtime. The vocabulary lives
# here, not in analysis/, so kernel packages never import the analysis
# layer.
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Interval:
    """Inclusive integer range for index-map coordinates that are only
    known as a range statically (scalar-prefetched row ids)."""

    lo: int
    hi: int

    def __repr__(self) -> str:
        return f"[{self.lo}..{self.hi}]"


#: an index-map coordinate: exact, or an inclusive range.
Coord = Union[int, Interval]


@dataclass(frozen=True)
class BlockModel:
    """One operand's BlockSpec, abstractly: the (padded) array shape the
    kernel addresses, the block shape, and the index map from grid
    coordinates to block indices (returning ``Coord`` per dim)."""

    name: str
    array_shape: Tuple[int, ...]
    block_shape: Tuple[int, ...]
    index_map: Callable[..., Tuple[Coord, ...]]

    def block_counts(self) -> Tuple[int, ...]:
        return tuple(
            -(-a // b) for a, b in zip(self.array_shape, self.block_shape)
        )


@dataclass(frozen=True)
class AccumModel:
    """A VMEM scratch accumulator carried across the ``axis`` grid
    dimension: zeroed when the axis coordinate equals ``init_at``, with
    the output block stored at the axis' last step (``store="last"``) or
    at every step (``store="every"``, the scan kernels)."""

    axis: int
    init_at: int = 0
    store: str = "last"  # "last" | "every"


@dataclass(frozen=True)
class GridModel:
    """The launch geometry of one kernel instantiation: grid extents,
    input/output block models, and the optional accumulator."""

    grid: Tuple[int, ...]
    inputs: Tuple[BlockModel, ...]
    output: BlockModel
    accumulator: Optional[AccumModel] = None


@dataclass(frozen=True)
class VjpPair:
    """One dispatch op the kernel's custom VJP re-enters at the forward's
    tier; ``info_map`` translates the forward site info into the backward
    site's info dict."""

    op: str
    info_map: Callable[[Dict], Dict]


@dataclass(frozen=True)
class KernelContract:
    """The statically checkable contract of one kernel package.

    ``dtypes`` is the domain of the hardware (pallas/interpret) tiers —
    ``"floating"`` or ``"any"``; ``accum_dtype`` names the accumulator
    dtype the grid model's AccumModel carries; ``masking`` lists the
    pad-and-mask obligations the ops.py wrapper discharges (prose,
    rendered in docs/kernels.md); ``vjp`` describes the backward;
    ``vjp_pairs`` are the dispatch ops it re-enters in-tier;
    ``grid_model(info, **concrete)`` builds the GridModel for a site
    (``None`` when the site degenerates, e.g. an empty gather) —
    ``concrete`` may carry runtime operands (the sanitizer passes actual
    row ids) to sharpen Interval coordinates into exact ones.
    """

    op: str
    dtypes: str
    accum_dtype: str
    masking: Tuple[str, ...]
    vjp: str
    vjp_pairs: Tuple[VjpPair, ...]
    grid_model: Callable[..., Optional[GridModel]]


#: kernel package module per contract-carrying op. ``ssm_scan`` carries a
#: contract but no registry entries (the models layer calls it directly).
_CONTRACT_MODULES: Dict[str, str] = {
    "segment_sum": "repro.kernels.segsum.ops",
    "blocked_matmul": "repro.kernels.matmul.ops",
    "gather_join": "repro.kernels.gather.ops",
    "ssm_scan": "repro.kernels.ssm_scan.ops",
}


def contract_ops() -> Tuple[str, ...]:
    """Ops with a declared KernelContract (dispatch ops + ssm_scan)."""
    return tuple(_CONTRACT_MODULES)


def kernel_contract(op: str) -> KernelContract:
    """The ``CONTRACT`` declared in ``op``'s kernel package (lazy import,
    matching the lazy impl wrappers below)."""
    import importlib

    mod = _CONTRACT_MODULES.get(op)
    if mod is None:
        raise KeyError(f"no kernel contract for op {op!r}; have {contract_ops()}")
    return importlib.import_module(mod).CONTRACT


# -- grid-model interpretation ----------------------------------------------
# Shared by the static certifier (analysis/kernelcheck.py wraps violations
# into node-path Diagnostics) and the sanitizer tier (raises
# SanitizerError). Index maps are affine in the grid coordinates (the only
# shape Pallas BlockSpecs take in this repo), which is what makes corner
# sampling sound for grids too large to enumerate.

#: grids at most this large are enumerated exhaustively (exact coverage /
#: race counts); larger grids are corner-sampled (bounds + race only).
GRID_ENUM_CAP: int = 32768


class SanitizerError(RuntimeError):
    """A sanitizer-tier instrumentation check failed. ``kind`` is the
    violation code, matching the static certifier's diagnostic codes."""

    def __init__(self, kind: str, detail: str):
        super().__init__(f"[{kind}] {detail}")
        self.kind = kind
        self.detail = detail


def _grid_coords(grid: Tuple[int, ...], cap: int) -> Tuple[List[Tuple[int, ...]], bool]:
    import itertools

    total = 1
    for s in grid:
        total *= s
    if total <= cap:
        pts = list(itertools.product(*(range(s) for s in grid)))
        return pts, True
    corners = [
        sorted({p for p in (0, 1, s - 2, s - 1) if 0 <= p < s}) for s in grid
    ]
    return list(itertools.product(*corners)), False


def _map_axis_deps(index_map: Callable, grid: Tuple[int, ...]) -> Tuple[int, ...]:
    """Grid axes the index map depends on, by probing unit moves from the
    origin (sound for affine maps)."""
    base = index_map(*(0,) * len(grid))
    deps = []
    for ax, size in enumerate(grid):
        if size <= 1:
            continue
        probe = [0] * len(grid)
        probe[ax] = size - 1
        if index_map(*probe) != base:
            deps.append(ax)
    return tuple(deps)


def _coord_range(v: Coord) -> Tuple[int, int]:
    if isinstance(v, Interval):
        return v.lo, v.hi
    return int(v), int(v)


def simulate_grid(
    model: GridModel, cap: int = GRID_ENUM_CAP
) -> List[Tuple[str, str]]:
    """Interpret a kernel's grid model and return ``(kind, detail)``
    violations (empty = sound). Kinds: ``grid-oob-index`` (an input or
    output block index leaves the padded array), ``grid-race`` (an output
    block stored by more than one program instance), ``grid-uncovered``
    (an output block never stored; exhaustive enumeration only),
    ``grid-reduction-order`` (revisit axes not innermost, so a VMEM
    accumulator would be clobbered between partial sums), and
    ``uninit-accumulator`` (accumulated before its zeroing step)."""
    viols: List[Tuple[str, str]] = []
    grid = model.grid
    if any(s <= 0 for s in grid):
        return viols
    coords, exhaustive = _grid_coords(grid, cap)
    acc = model.accumulator

    # revisit axes (grid axes the output map ignores — the reduction /
    # sweep axes) must be the innermost suffix: the TPU grid executes
    # sequentially with the last axis fastest, so only a trailing sweep
    # keeps one output block's partial sums adjacent in time.
    out_deps = set(_map_axis_deps(model.output.index_map, grid))
    revisit = [ax for ax in range(len(grid)) if ax not in out_deps and grid[ax] > 1]
    if revisit != list(range(len(grid) - len(revisit), len(grid))):
        viols.append((
            "grid-reduction-order",
            f"revisit axes {tuple(revisit)} of grid {grid} are not the "
            f"innermost suffix (output map depends on axes {tuple(sorted(out_deps))})",
        ))
    if acc is not None:
        if acc.init_at != 0:
            viols.append((
                "uninit-accumulator",
                f"accumulator on grid axis {acc.axis} is zeroed at step "
                f"{acc.init_at}, so steps 0..{acc.init_at - 1} accumulate "
                "into uninitialized VMEM",
            ))
        if not 0 <= acc.axis < len(grid):
            viols.append((
                "uninit-accumulator",
                f"accumulator axis {acc.axis} outside grid {grid}",
            ))
            acc = None

    oob_seen = set()
    stores: Dict[Tuple[int, ...], int] = {}
    out_counts = model.output.block_counts()
    for coord in coords:
        for bm in model.inputs + (model.output,):
            idx = bm.index_map(*coord)
            counts = bm.block_counts()
            if len(idx) != len(counts):
                if bm.name not in oob_seen:
                    oob_seen.add(bm.name)
                    viols.append((
                        "grid-oob-index",
                        f"{bm.name}: index map arity {len(idx)} != "
                        f"array rank {len(counts)}",
                    ))
                continue
            for d, (v, n) in enumerate(zip(idx, counts)):
                lo, hi = _coord_range(v)
                if lo < 0 or hi >= n:
                    key = (bm.name, d)
                    if key not in oob_seen:
                        oob_seen.add(key)
                        viols.append((
                            "grid-oob-index",
                            f"{bm.name} dim {d}: block index {v} at grid "
                            f"point {coord} outside [0, {n}) "
                            f"(array {bm.array_shape}, block {bm.block_shape})",
                        ))
        if acc is None or acc.store == "every":
            stored = True
        else:
            stored = coord[acc.axis] == grid[acc.axis] - 1
        if stored:
            oidx = model.output.index_map(*coord)
            if any(isinstance(v, Interval) for v in oidx):
                viols.append((
                    "grid-race",
                    f"output block index {oidx} at grid point {coord} is "
                    "not statically exact — cannot prove single-writer",
                ))
                continue
            oidx = tuple(int(v) for v in oidx)
            stores[oidx] = stores.get(oidx, 0) + 1

    races = sorted(idx for idx, c in stores.items() if c > 1)
    if races:
        viols.append((
            "grid-race",
            f"{len(races)} output block(s) stored by more than one program "
            f"instance, e.g. block {races[0]} stored {stores[races[0]]}x",
        ))
    if exhaustive:
        import itertools

        missing = [
            idx
            for idx in itertools.product(*(range(n) for n in out_counts))
            if idx not in stores
        ]
        if missing:
            viols.append((
                "grid-uncovered",
                f"{len(missing)} output block(s) never stored, e.g. "
                f"block {missing[0]} of {out_counts}",
            ))
    return viols


# -- dispatch-site resolution log --------------------------------------------


@dataclass(frozen=True)
class SiteRecord:
    """One dispatch decision with enough context to replay it: the
    ``op[site]`` key, the site-info dict (frozen as sorted items), and
    the tier that resolved. ``analysis.kernelcheck`` re-runs
    ``resolve_impl`` on the snapshot and flags any drift — the checked
    form of the flappy-predicate hazard on KernelImpl."""

    key: str
    op: str
    site: str
    tier: str
    info: Tuple[Tuple[str, object], ...]

    def info_dict(self) -> Dict:
        return dict(self.info)


class ResolutionLog(Dict[str, str]):
    """The ``op[site] → tier`` dict the engine exposes as
    ``Compiled.resolutions``, plus per-site SiteRecords for replay."""

    def __init__(self) -> None:
        super().__init__()
        self.sites: List[SiteRecord] = []

    def record(self, key: str, op: str, site: str, tier: str, info: Dict) -> None:
        self.sites.append(
            SiteRecord(key, op, site, tier, tuple(sorted(info.items())))
        )


# -- registered implementations ---------------------------------------------
# The pallas/interpret/ref fns import the kernel packages lazily so that
# importing repro.core stays cheap on machines that never leave the jnp
# tier.


def _is_float(info: Dict) -> bool:
    return jnp.issubdtype(jnp.dtype(info["dtype"]), jnp.floating)


def _segsum_jnp(msg: jnp.ndarray, seg: jnp.ndarray, num_segments: int) -> jnp.ndarray:
    return jax.ops.segment_sum(msg, seg, num_segments=num_segments)


def _segsum_ref(msg: jnp.ndarray, seg: jnp.ndarray, num_segments: int) -> jnp.ndarray:
    from repro.kernels.segsum.ref import segment_sum_ref

    return segment_sum_ref(msg, seg, num_segments)


def _segsum_pallas(msg: jnp.ndarray, seg: jnp.ndarray, num_segments: int) -> jnp.ndarray:
    from repro.kernels.segsum.ops import segment_sum

    return segment_sum(msg, seg, num_segments, interpret=False)


def _segsum_interpret(msg: jnp.ndarray, seg: jnp.ndarray, num_segments: int) -> jnp.ndarray:
    from repro.kernels.segsum.ops import segment_sum

    return segment_sum(msg, seg, num_segments, interpret=True)


def _matmul_jnp(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    return jnp.matmul(x, y)


def _matmul_ref(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    from repro.kernels.matmul.ref import matmul_ref

    return matmul_ref(x, y)


def _matmul_pallas(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    from repro.kernels.matmul.ops import blocked_matmul

    return blocked_matmul(x, y, interpret=False)


def _matmul_interpret(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    from repro.kernels.matmul.ops import blocked_matmul

    return blocked_matmul(x, y, interpret=True)


def _gather_jnp(table: jnp.ndarray, rows: jnp.ndarray) -> jnp.ndarray:
    # the default lowering IS the masked-gather oracle (one definition of
    # the COO pad-and-mask contract: out-of-range / negative ids gather
    # zero rows — see kernels/gather/ref.py)
    from repro.kernels.gather.ref import gather_rows_ref

    return gather_rows_ref(table, rows)


def _gather_ref(table: jnp.ndarray, rows: jnp.ndarray) -> jnp.ndarray:
    from repro.kernels.gather.ref import gather_rows_ref

    return gather_rows_ref(table, rows)


def _gather_pallas(table: jnp.ndarray, rows: jnp.ndarray) -> jnp.ndarray:
    from repro.kernels.gather.ops import gather_rows

    return gather_rows(table, rows, interpret=False)


def _gather_interpret(table: jnp.ndarray, rows: jnp.ndarray) -> jnp.ndarray:
    from repro.kernels.gather.ops import gather_rows

    return gather_rows(table, rows, interpret=True)


# -- sanitizer tier ----------------------------------------------------------
# Instrumented cross-check impls: on concrete (eager) inputs they replay
# the contract's grid model with out-of-bounds / write-race /
# uninitialized-accumulator instrumentation (raising SanitizerError with
# the same violation codes the static certifier reports) and compute the
# result through the ref oracle; under tracing (eval_shape / jit) the
# checks cannot observe values and the impl degrades to the plain oracle.


def _is_concrete(*xs: Any) -> bool:
    return not any(isinstance(x, jax.core.Tracer) for x in xs)


def _sanitize_site(op: str, info: Dict, **concrete: Any) -> None:
    contract = kernel_contract(op)
    if contract.dtypes == "floating" and not _is_float(info):
        raise SanitizerError(
            "dtype-domain",
            f"{op}: dtype {jnp.dtype(info['dtype'])} outside the "
            f"contract's floating domain at site {info}",
        )
    model = contract.grid_model(info, **concrete)
    if model is None:
        return
    viols = simulate_grid(model)
    if viols:
        kind, detail = viols[0]
        raise SanitizerError(kind, f"{op}: {detail} (site {info})")


def _segsum_sanitizer(msg: jnp.ndarray, seg: jnp.ndarray, num_segments: int) -> jnp.ndarray:
    from repro.kernels.segsum.ref import segment_sum_ref

    if _is_concrete(msg, seg):
        info = {
            "nnz": msg.shape[0], "dim": msg.shape[1],
            "num_segments": num_segments, "dtype": msg.dtype,
        }
        _sanitize_site("segment_sum", info)
    return segment_sum_ref(msg, seg, num_segments)


def _matmul_sanitizer(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    from repro.kernels.matmul.ref import matmul_ref

    if _is_concrete(x, y):
        info = {
            "m": x.shape[0], "k": x.shape[1], "n": y.shape[1],
            "dtype": jnp.result_type(x, y),
        }
        _sanitize_site("blocked_matmul", info)
    return matmul_ref(x, y)


def _gather_sanitizer(table: jnp.ndarray, rows: jnp.ndarray) -> jnp.ndarray:
    import numpy as np

    from repro.kernels.gather.ref import gather_rows_ref

    if _is_concrete(table, rows):
        info = {
            "rows": rows.shape[0], "num_rows": table.shape[0],
            "dim": table.shape[1], "dtype": table.dtype,
        }
        # concrete row ids sharpen the scalar-prefetch Interval into the
        # exact per-step indices the DMA pipeline would issue
        _sanitize_site("gather_join", info, rows=np.asarray(rows))
    return gather_rows_ref(table, rows)


# The hardware tiers require float inputs (the Pallas kernels accumulate in
# f32 and store the input dtype); the ref oracles accept anything their jnp
# twins accept; the jnp tier is the unconditional fallback.
register_impl(
    "segment_sum", "pallas", _segsum_pallas, backends=("tpu",), predicate=_is_float
)
register_impl("segment_sum", "interpret", _segsum_interpret, predicate=_is_float)
register_impl("segment_sum", "sanitizer", _segsum_sanitizer, predicate=_is_float)
register_impl("segment_sum", "ref", _segsum_ref)
register_impl("segment_sum", "jnp", _segsum_jnp)

register_impl(
    "blocked_matmul", "pallas", _matmul_pallas, backends=("tpu",), predicate=_is_float
)
register_impl("blocked_matmul", "interpret", _matmul_interpret, predicate=_is_float)
register_impl("blocked_matmul", "sanitizer", _matmul_sanitizer, predicate=_is_float)
register_impl("blocked_matmul", "ref", _matmul_ref)
register_impl("blocked_matmul", "jnp", _matmul_jnp)

# The gather DMA kernel's interpret tier is the CPU-tested path; the TPU
# hardware tier shares it behind the registry pending tile tuning on real
# devices (ROADMAP "tier predicates from measurements").
register_impl(
    "gather_join", "pallas", _gather_pallas, backends=("tpu",), predicate=_is_float
)
register_impl("gather_join", "interpret", _gather_interpret, predicate=_is_float)
register_impl("gather_join", "sanitizer", _gather_sanitizer, predicate=_is_float)
register_impl("gather_join", "ref", _gather_ref)
register_impl("gather_join", "jnp", _gather_jnp)
