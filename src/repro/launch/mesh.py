"""Production mesh construction — the canonical mesh entry points.

``make_host_mesh`` / ``make_production_mesh`` build the (data × model)
meshes the 2-D distribution planner (core/planner.py) reads its geometry
from; ``resolve_mesh`` turns the spec strings accepted by
``train.make_train_step`` / ``serving`` / ``repro.Database(mesh=...)``
into those meshes.

Defined as functions (never module-level constants) so importing this
module never touches jax device state — jax locks the device count on
first backend initialization, and only dryrun.py is allowed to force the
512-placeholder-device configuration.
"""

from __future__ import annotations

import jax

from repro.core.planner import DATA_AXIS_NAMES


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod (TPU v5e); multi-pod adds a leading
    pod=2 axis (2 pods = 512 chips) used for data parallelism."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Small (data × model) mesh over however many devices this host
    exposes (tests; 8 virtual CPU devices on the tier1-spmd CI lane give
    a 4×2 mesh at ``model=2``). With a single visible device this falls
    back to a 1-axis mesh — the planner then reproduces its 1-D plans —
    instead of a degenerate (1, 1) mesh."""
    if model < 1:
        raise ValueError(f"make_host_mesh: model={model} must be >= 1")
    n = len(jax.devices())
    if n == 1 and model == 1:
        return jax.make_mesh((1,), ("model",))
    if n % model != 0:
        raise ValueError(
            f"make_host_mesh: {n} visible device(s) not divisible by "
            f"model={model}"
        )
    return jax.make_mesh((n // model, model), ("data", "model"))


def resolve_mesh(spec):
    """Resolve a mesh spec to a jax Mesh: None and Mesh objects pass
    through; the strings ``"host"``, ``"host:<model>"``, ``"production"``
    and ``"production:multipod"`` name the standard meshes above."""
    if spec is None or not isinstance(spec, str):
        return spec
    name, _, arg = spec.partition(":")
    if name == "host":
        return make_host_mesh(model=int(arg) if arg else 1)
    if name == "production":
        if arg and arg not in ("multipod", "multi_pod", "2"):
            raise ValueError(
                f"unknown production mesh variant {arg!r}; use "
                "'production' or 'production:multipod'"
            )
        return make_production_mesh(multi_pod=bool(arg))
    raise ValueError(
        f"unknown mesh spec {spec!r}; use 'host[:<model>]' or "
        "'production[:multipod]'"
    )


def batch_axes(mesh) -> tuple:
    """Mesh axes used for data parallelism — the fold the 2-D planner
    (``core.planner.DATA_AXIS_NAMES``) puts on batch dimensions."""
    return tuple(a for a in DATA_AXIS_NAMES if a in mesh.axis_names)


def data_parallel_size(mesh) -> int:
    """Total data-parallel ways: the product of the batch axes' sizes."""
    n = 1
    for a in batch_axes(mesh):
        n *= int(dict(mesh.shape)[a])
    return n
