"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches jax device state — jax locks the device count on
first backend initialization, and only dryrun.py is allowed to force the
512-placeholder-device configuration.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod (TPU v5e); multi-pod adds a leading
    pod=2 axis (2 pods = 512 chips) used for data parallelism."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Small mesh over however many devices this host exposes (tests)."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))


def batch_axes(mesh) -> tuple:
    """Mesh axes used for data parallelism."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)
