import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (architecture × input-shape ×
mesh) combination with 512 placeholder host devices standing in for the
TPU v5e pods. No arrays are allocated — inputs are ShapeDtypeStructs — but
the SPMD partitioner runs for real: sharding mismatches, compile-time OOM
and unsupported collectives all surface here.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-405b \
      --shape train_4k [--multi-pod] [--out experiments/dryrun]
  PYTHONPATH=src python -m repro.launch.dryrun --all
"""

import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config
from repro.launch.mesh import data_parallel_size, make_production_mesh
from repro.launch.roofline import roofline_from_compiled
from repro.launch.sharding import (
    batch_pspecs,
    cache_pspecs,
    param_pspecs,
    to_shardings,
)
from repro.models import build_model
from repro.optim import adam_init
from repro.serving import init_cache
from repro.train import make_train_step

# (arch, shape) pairs skipped, with the DESIGN.md §long-context rationale.
SKIPS = {
    ("gemma2-9b", "long_500k"): "global layers are full attention (4k ctx)",
    ("deepseek-coder-33b", "long_500k"): "pure full attention",
    ("deepseek-v3-671b", "long_500k"): "full attention (MLA) — no windowed variant",
    ("llama3-405b", "long_500k"): "pure full attention",
    ("qwen2-vl-72b", "long_500k"): "pure full attention",
    ("olmoe-1b-7b", "long_500k"): "pure full attention",
    ("whisper-small", "long_500k"): "decoder is spec'd to ≤448 positions",
}


def input_specs(arch: str, shape_name: str, mesh) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this
    (arch, shape): weak-type-correct, shardable, no device allocation."""
    cfg = get_config(arch)
    shp = INPUT_SHAPES[shape_name]
    b, s = shp.global_batch, shp.seq_len
    sds = jax.ShapeDtypeStruct
    dt = jnp.dtype(cfg.dtype)

    if shp.kind == "train":
        batch = {
            "tokens": sds((b, s), jnp.int32),
            "labels": sds((b, s), jnp.int32),
        }
        if cfg.encoder_layers:
            batch["frames"] = sds((b, cfg.enc_seq, cfg.d_model), dt)
        if cfg.vis_seq:
            batch["patches"] = sds((b, cfg.vis_seq, cfg.d_model), dt)
        return {"batch": batch}
    if shp.kind == "prefill":
        batch = {"tokens": sds((b, s), jnp.int32)}
        if cfg.encoder_layers:
            batch["frames"] = sds((b, cfg.enc_seq, cfg.d_model), dt)
        if cfg.vis_seq:
            batch["patches"] = sds((b, cfg.vis_seq, cfg.d_model), dt)
        return {"batch": batch}
    # decode: one token + caches of capacity seq_len
    caches = jax.eval_shape(lambda: init_cache(cfg, b, s))
    out = {
        "token": sds((b, 1), jnp.int32),
        "caches": caches,
        "length": sds((), jnp.int32),
    }
    if cfg.encoder_layers:
        out["enc_out"] = sds((b, cfg.enc_seq, cfg.d_model), dt)
    return out


def build_step(
    arch: str,
    shape_name: str,
    mesh,
    *,
    unroll: bool = True,
    overrides: Optional[Dict[str, Any]] = None,
):
    """Returns (jitted_fn, example_args_as_SDS) ready to .lower().

    ``unroll`` fully unrolls the layer scan: compile is slower but
    cost_analysis then counts every layer (XLA reports while-loop bodies
    once, not ×trip-count) — required for faithful roofline terms.
    ``overrides`` replaces config fields (perf iteration, reduced-layer
    proxies).
    """
    from dataclasses import replace

    cfg = get_config(arch)
    if overrides:
        cfg = replace(cfg, **overrides)
    if unroll:
        cfg = replace(cfg, scan_unroll=1_000_000)
    shp = INPUT_SHAPES[shape_name]
    model = build_model(cfg)

    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pspec = param_pspecs(params_shape, mesh)
    pshard = to_shardings(pspec, mesh)
    params_sds = jax.tree.map(
        lambda l, sh: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=sh),
        params_shape, pshard,
    )
    specs = input_specs(arch, shape_name, mesh)

    if shp.kind == "train":
        opt_shape = jax.eval_shape(
            lambda p: adam_init(p, dtype=jnp.dtype(cfg.opt_state_dtype)),
            params_shape,
        )
        opt_spec = {
            "mu": param_pspecs(opt_shape["mu"], mesh),
            "nu": param_pspecs(opt_shape["nu"], mesh),
            "step": P(),
        }
        oshard = to_shardings(opt_spec, mesh)
        opt_sds = jax.tree.map(
            lambda l, sh: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=sh),
            opt_shape, oshard,
        )
        bspec = batch_pspecs(specs["batch"], mesh)
        bshard = to_shardings(bspec, mesh)
        batch_sds = jax.tree.map(
            lambda l, sh: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=sh),
            specs["batch"], bshard,
        )
        step = make_train_step(model, jit=False)
        fn = jax.jit(
            step,
            in_shardings=(pshard, oshard, bshard),
            out_shardings=(pshard, oshard, None),
        )
        return fn, (params_sds, opt_sds, batch_sds)

    if shp.kind == "prefill":
        bspec = batch_pspecs(specs["batch"], mesh)
        bshard = to_shardings(bspec, mesh)
        batch_sds = jax.tree.map(
            lambda l, sh: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=sh),
            specs["batch"], bshard,
        )
        cache_len = shp.seq_len + (cfg.vis_seq or 0)

        def prefill_step(params, batch):
            return model.prefill(params, batch, cache_len)

        fn = jax.jit(prefill_step, in_shardings=(pshard, bshard))
        return fn, (params_sds, batch_sds)

    # decode: data-parallel ways = the full ("pod","data") fold, so the
    # multi-pod mesh counts the pod axis toward batch parallelism too
    seq_sharded = shp.global_batch < data_parallel_size(mesh)
    cspec = cache_pspecs(
        specs["caches"], mesh, batch=shp.global_batch, seq_sharded=seq_sharded
    )
    cshard = to_shardings(cspec, mesh)
    cache_sds = jax.tree.map(
        lambda l, sh: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=sh),
        specs["caches"], cshard,
    )
    tok_spec = P("data") if not seq_sharded else P()
    tok_shard = to_shardings(tok_spec, mesh)
    tok_sds = jax.ShapeDtypeStruct(
        (shp.global_batch, 1), jnp.int32,
        sharding=to_shardings(P("data", None) if not seq_sharded else P(None, None), mesh),
    )
    len_sds = jax.ShapeDtypeStruct((), jnp.int32)
    args = [params_sds, tok_sds, cache_sds, len_sds]

    if cfg.encoder_layers:
        enc_sds = jax.ShapeDtypeStruct(
            (shp.global_batch, cfg.enc_seq, cfg.d_model), jnp.dtype(cfg.dtype),
            sharding=to_shardings(
                P("data", None, None) if not seq_sharded else P(None, None, None),
                mesh,
            ),
        )
        args.append(enc_sds)

        def decode_fn(params, token, caches, length, enc_out):
            return model.decode_step(params, token, caches, length, enc_out)
    else:

        def decode_fn(params, token, caches, length):
            return model.decode_step(params, token, caches, length)

    fn = jax.jit(decode_fn, in_shardings=(pshard,) + tuple([None] * (len(args) - 1)))
    return fn, tuple(args)


def dryrun_one(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    verbose: bool = True,
    unroll: bool = True,
    overrides: Optional[Dict[str, Any]] = None,
) -> Dict[str, Any]:
    """``unroll=False`` keeps the layer scan rolled: much faster compile,
    but cost_analysis counts the loop body once — use for lowering proofs
    (multi-pod pass), not for the roofline table."""
    if (arch, shape_name) in SKIPS:
        return {
            "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
            "status": "skipped", "reason": SKIPS[(arch, shape_name)],
        }
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    with jax.set_mesh(mesh):
        fn, args = build_step(
            arch, shape_name, mesh, unroll=unroll, overrides=overrides
        )
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        roof = roofline_from_compiled(
            compiled, mesh, arch=arch, shape=shape_name
        )
    rec = {
        "arch": arch,
        "shape": shape_name,
        "multi_pod": multi_pod,
        "unrolled": unroll,
        "overrides": overrides or {},
        "status": "ok",
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory_analysis": _mem_dict(mem),
        "flops": cost.get("flops") if cost else None,
        "bytes_accessed": cost.get("bytes accessed") if cost else None,
        "roofline": roof,
    }
    if verbose:
        print(json.dumps(rec, indent=2, default=str))
    return rec


def _mem_dict(mem) -> Optional[Dict[str, float]]:
    if mem is None:
        return None
    keys = (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "generated_code_size_in_bytes",
    )
    out = {}
    for k in keys:
        v = getattr(mem, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(INPUT_SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--no-unroll", action="store_true",
                    help="keep the layer scan rolled (fast lowering proof; "
                         "cost_analysis counts the loop body once)")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument(
        "--set", action="append", default=[], metavar="KEY=VALUE",
        help="config override (repeatable), e.g. --set ssm_chunk=512",
    )
    ap.add_argument("--tag", default="", help="suffix for the output file")
    args = ap.parse_args()

    overrides: Dict[str, Any] = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        try:
            overrides[k] = int(v)
        except ValueError:
            try:
                overrides[k] = float(v)
            except ValueError:
                overrides[k] = v

    os.makedirs(args.out, exist_ok=True)
    combos = []
    if args.all:
        for a in ARCH_IDS:
            for s in INPUT_SHAPES:
                combos.append((a, s, False))
                combos.append((a, s, True))
    else:
        assert args.arch and args.shape
        combos = [(args.arch, args.shape, args.multi_pod)]

    for a, s, mp in combos:
        tag = f"{a}_{s}_{'pod2' if mp else 'pod1'}"
        if args.tag:
            tag += f"_{args.tag}"
        try:
            rec = dryrun_one(
                a, s, multi_pod=mp, unroll=not args.no_unroll,
                overrides=overrides or None,
            )
        except Exception as e:  # noqa: BLE001 — record and continue
            rec = {
                "arch": a, "shape": s, "multi_pod": mp,
                "status": "error", "error": repr(e),
                "traceback": traceback.format_exc(),
            }
            print(f"[FAIL] {tag}: {e}")
        with open(os.path.join(args.out, tag + ".json"), "w") as f:
            json.dump(rec, f, indent=2, default=str)


if __name__ == "__main__":
    main()
