"""Roofline analysis from the compiled dry-run artifact (no real hardware).

Three terms per (arch × shape × mesh), all in seconds:

  compute     = HLO_FLOPs_per_device / peak_FLOP/s
  memory      = HLO_bytes_per_device / HBM_bw
  collective  = collective_bytes_per_device / ICI_bw

``compiled.cost_analysis()`` reports the *partitioned per-device* module,
so no further division by chip count is applied. Collective bytes are not
in cost_analysis: we parse the optimized HLO text and sum the result-shape
bytes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute (async -start variants counted once; -done skipped).

Hardware model (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (we charge one link — conservative; a 2D torus has more).
"""

from __future__ import annotations

import re
from typing import Any, Dict, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"\b(pred|[sufc]\d+|bf16)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, Any]:
    """Sum result-shape bytes per collective kind from optimized HLO."""
    per_kind = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.search(r"=\s*(?:\([^)]*\)|\S+)\s+([a-z\-]+)(?:-start)?\(", stripped)
        if not m:
            continue
        op = m.group(1)
        if op.endswith("-start"):
            op = op[: -len("-start")]
        if op.endswith("-done") or op not in _COLLECTIVES:
            continue
        shapes = _SHAPE_RE.findall(stripped.split("=", 1)[1].split(op)[0])
        if not shapes:
            shapes = _SHAPE_RE.findall(stripped)
        if not shapes:
            continue
        b = max(_shape_bytes(dt, dims) for dt, dims in shapes)
        per_kind[op] += b
        counts[op] += 1
    total = sum(per_kind.values())
    return {"total_bytes": total, "bytes_by_kind": per_kind, "counts": counts}


def model_flops(arch: str, shape_name: str) -> Optional[float]:
    """6·N·D (train) or 2·N·D (inference) with N = active params."""
    from repro.configs import INPUT_SHAPES, get_config

    cfg = get_config(arch)
    shp = INPUT_SHAPES[shape_name]
    n_active = active_params(cfg)
    if shp.kind == "train":
        tokens = shp.global_batch * shp.seq_len
        return 6.0 * n_active * tokens
    if shp.kind == "prefill":
        tokens = shp.global_batch * shp.seq_len
        return 2.0 * n_active * tokens
    tokens = shp.global_batch * 1
    return 2.0 * n_active * tokens


def active_params(cfg) -> float:
    """Analytic active-parameter count (MoE: top-k routed + shared)."""
    d, v = cfg.d_model, cfg.vocab
    hd = cfg.hd() if cfg.n_heads else 0
    total = v * d  # embed
    if not cfg.tie_embeddings:
        total += d * v

    def attn_params():
        return d * cfg.n_heads * hd + 2 * d * cfg.n_kv_heads * hd + cfg.n_heads * hd * d

    def mla_params():
        qh = cfg.nope_head_dim + cfg.rope_head_dim
        return (
            d * cfg.q_lora_rank
            + cfg.q_lora_rank * cfg.n_heads * qh
            + d * (cfg.kv_lora_rank + cfg.rope_head_dim)
            + cfg.kv_lora_rank * cfg.n_heads * (cfg.nope_head_dim + cfg.v_head_dim)
            + cfg.n_heads * cfg.v_head_dim * d
        )

    def mlp_params(ff):
        return 3 * d * ff

    def mamba1_params():
        c = cfg.ssm_expand * d
        dt_rank = max(1, d // 16)
        return d * 2 * c + 4 * c + c * (dt_rank + 2 * cfg.ssm_state) + dt_rank * c + c * d

    def mamba2_params():
        c = cfg.ssm_expand * d
        nh = c // cfg.ssm_head_dim
        return d * (2 * c + 2 * cfg.ssm_state + nh) + 4 * (c + 2 * cfg.ssm_state) + c * d

    from repro.models.model import stages_of

    for st in stages_of(cfg):
        kinds = list(st.pattern) * st.repeats + list(st.tail)
        for kind in kinds:
            if kind in ("attn", "local", "global"):
                total += attn_params() + mlp_params(cfg.d_ff)
            elif kind == "moe":
                ff = cfg.d_expert_ff or cfg.d_ff
                total += attn_params() + cfg.top_k * 3 * d * ff
                total += cfg.n_shared_experts * 3 * d * ff
            elif kind == "mla":
                total += mla_params() + mlp_params(cfg.d_ff)
            elif kind == "mla_moe":
                ff = cfg.d_expert_ff or cfg.d_ff
                total += mla_params() + cfg.top_k * 3 * d * ff
                total += cfg.n_shared_experts * 3 * d * ff
            elif kind == "mamba1":
                total += mamba1_params()
            elif kind in ("mamba2", "mamba2_attn"):
                total += mamba2_params()
                if kind == "mamba2_attn":
                    total += attn_params() + mlp_params(cfg.d_ff)
            elif kind == "dec":
                total += 2 * attn_params() + mlp_params(cfg.d_ff)
            elif kind == "enc":
                total += attn_params() + mlp_params(cfg.d_ff)
    if cfg.encoder_layers:
        total += cfg.encoder_layers * (attn_params() + mlp_params(cfg.d_ff))
    return float(total)


def roofline_from_compiled(compiled, mesh, *, arch: str, shape: str) -> Dict[str, Any]:
    cost = compiled.cost_analysis() or {}
    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))
    try:
        hlo = compiled.as_text()
    except Exception:  # pragma: no cover
        hlo = ""
    coll = collective_bytes_from_hlo(hlo)

    chips = 1
    for n in mesh.shape.values():
        chips *= n

    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_accessed / HBM_BW
    collective_s = coll["total_bytes"] / ICI_BW
    terms = {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
    }
    dominant = max(terms, key=terms.get)
    mf = model_flops(arch, shape)
    mf_per_device = mf / chips if mf else None
    return {
        **terms,
        "dominant": dominant,
        "hlo_flops_per_device": flops,
        "hlo_bytes_per_device": bytes_accessed,
        "collective": coll,
        "model_flops_per_device": mf_per_device,
        "useful_flops_ratio": (mf_per_device / flops) if (mf_per_device and flops) else None,
        "chips": chips,
    }
