"""Sharding assignment — the JAX analogue of the paper's "database query
optimizer distributes the computation".

The paper's engine decides per join between co-partitioning (tensor
parallelism) and broadcasting the small side (data parallelism) from
relation statistics. Statically we make the same decisions:

  * tensor-parallel ("model" axis): every parameter matrix's
    output-feature / expert / channel dimension, per the rule table below —
    this co-partitions the big join-aggregates (QKV/FFN matmuls) on their
    contraction keys, producing psum/reduce-scatter collectives;
  * fully-sharded data parallelism ("data", and "pod" when present):
    the remaining large dimension of every parameter ≥ 1 MiB is sharded
    over the batch axes (ZeRO-3-style), all-gathered per layer on use —
    the "broadcast the small side" plan, amortized;
  * batch axes carry activations; long_500k (batch=1) shards the KV-cache
    sequence dimension over "data" instead (ring-style decode attention).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


# name-based rules: which dimension gets the tensor-parallel axis.
# value = index of the dim to place on "model" (negative ok), or None.
_MODEL_DIM_RULES = (
    ("router", None),
    ("q_norm", None),
    ("k_norm", None),
    ("kv_norm", None),
    ("norm_scale", None),
    ("wq_a", 1),
    ("wq_b", 1),
    ("wkv_a", None),
    ("wk_b", 1),
    ("wv_b", 1),
    ("wi_gate", -1),
    ("wi_up", -1),
    ("wo", 0),        # row-parallel: contraction dim sharded -> psum
    ("wq", 1),
    ("wk", 1),
    ("wv", 1),
    ("in_proj", 1),
    ("out_proj", 0),
    ("x_proj", 0),
    ("dt_proj", 1),
    ("conv_w", 1),
    ("conv_b", 0),
    ("dt_bias", 0),
    ("a_log", 0),
    ("d_skip", 0),
    ("out_embed", 1),
    ("embed", 0),     # vocab-parallel embedding table
)

_MOE_3D = ("wi_gate", "wi_up", "wo")  # (E, ·, ·): experts on "model"


def _leaf_name(path) -> str:
    for p in reversed(path):
        if hasattr(p, "key"):
            return str(p.key)
        if hasattr(p, "name"):
            return str(p.name)
    return ""


def _path_str(path) -> str:
    return "/".join(
        str(getattr(p, "key", getattr(p, "name", getattr(p, "idx", "?"))))
        for p in path
    )


def param_pspec(
    path,
    shape: Tuple[int, ...],
    *,
    model_size: int,
    fsdp_axes: Tuple[str, ...],
    fsdp_size: int,
    min_fsdp_bytes: int = 1 << 20,
    stacked: bool,
) -> P:
    """PartitionSpec for one parameter leaf. ``stacked`` marks scanned
    stage parameters whose dim 0 is the layer axis (never sharded)."""
    name = _leaf_name(path)
    off = 1 if stacked else 0
    ndim = len(shape)
    spec: list = [None] * ndim

    model_dim = None
    is_moe = any(f"{m}" == name for m in _MOE_3D) and (ndim - off) == 3
    if is_moe:
        model_dim = off  # expert axis
    else:
        for key, rule in _MODEL_DIM_RULES:
            if name == key:
                if rule is not None:
                    model_dim = rule % ndim if rule >= 0 else ndim + rule
                    if rule >= 0:
                        model_dim = rule + off
                break
        else:
            model_dim = None
    if model_dim is not None and shape[model_dim] % model_size == 0:
        spec[model_dim] = "model"

    # FSDP: largest remaining divisible dim, if the leaf is big enough.
    nbytes = int(np.prod(shape)) * 4
    if fsdp_axes and nbytes >= min_fsdp_bytes:
        cands = [
            d for d in range(off, ndim)
            if spec[d] is None and shape[d] % fsdp_size == 0
        ]
        if cands:
            d = max(cands, key=lambda i: shape[i])
            spec[d] = fsdp_axes if len(fsdp_axes) > 1 else fsdp_axes[0]
    return P(*spec)


def param_pspecs(param_shapes, mesh, *, fsdp: bool = True) -> Any:
    """PartitionSpec tree for a params pytree of ShapeDtypeStructs.

    Scanned stage params (under "stages/*/scan") carry a leading layer
    axis which stays unsharded.
    """
    model_size = mesh.shape["model"]
    dp_axes = tuple(a for a in ("data",) if a in mesh.axis_names)
    fsdp_size = mesh.shape["data"] if fsdp else 1

    def assign(path, leaf):
        ps = _path_str(path)
        stacked = "/scan/" in f"/{ps}/"
        return param_pspec(
            path,
            leaf.shape,
            model_size=model_size,
            fsdp_axes=dp_axes if fsdp else (),
            fsdp_size=fsdp_size,
            stacked=stacked,
        )

    return jax.tree_util.tree_map_with_path(assign, param_shapes)


def coo_pspecs(rel, mesh) -> Any:
    """CooRelation-shaped PartitionSpec pytree for the nnz-sharded edge
    layout: keys/values row (nnz) dim over the mesh's batch axes — the
    same fold the 2-D relational planner emits for ``data:shard_nnz_*``
    plans. For manual ``device_put`` of edge relations (benchmarks,
    data loading); the engine derives the same layout from the plan."""
    from repro.core.planner import fold_axes
    from repro.core.relation import CooRelation

    from .mesh import batch_axes

    row = fold_axes(batch_axes(mesh))
    return CooRelation(
        P(row, None),
        P(row, *([None] * (rel.values.ndim - 1))),
        rel.extents,
        rel.owner_dim,
        rel.shard_offsets,
    )


def batch_pspecs(batch_shapes, mesh) -> Any:
    """Input batch: batch dimension over the mesh's data axes (the same
    ("pod","data") fold the 2-D relational planner emits — see
    ``launch.mesh.batch_axes``)."""
    from repro.core.planner import fold_axes

    from .mesh import batch_axes

    bspec = fold_axes(batch_axes(mesh))

    def assign(path, leaf):
        if leaf.ndim == 0:
            return P()
        return P(bspec, *([None] * (leaf.ndim - 1)))

    return jax.tree_util.tree_map_with_path(assign, batch_shapes)


def cache_pspecs(cache_shapes, mesh, *, batch: int, seq_sharded: bool) -> Any:
    """KV/SSM cache sharding for serving.

    batch ≥ data-axis: batch dim over "data", kv-heads/channels on "model".
    batch == 1 (long_500k): shard the cache *sequence* dim over "data"
    (decode attention's softmax reductions over the sharded key axis become
    all-reduces — ring-decode).  Cache layouts (leading stacked layer axis
    optional):
       k/v   (B, S, Hkv, hd)     c/r (B, S, dc)
       conv  (B, W-1, C)         ssm (B, C, N) | (B, H, N, P)
    """
    data = "data"

    def assign(path, leaf):
        name = _leaf_name(path)
        ps = _path_str(path)
        stacked = "/scan/" in f"/{ps}/"
        off = 1 if stacked else 0
        nd = leaf.ndim
        spec = [None] * nd
        if not seq_sharded:
            spec[off] = data        # batch dim
        if name in ("k", "v"):
            if seq_sharded and leaf.shape[off + 1] % 16 == 0:
                spec[off + 1] = data
            if leaf.shape[off + 2] % 16 == 0:
                spec[off + 2] = "model"
        elif name in ("c", "r"):
            if seq_sharded and leaf.shape[off + 1] % 16 == 0:
                spec[off + 1] = data
        elif name == "conv":
            if leaf.shape[off + 2] % 16 == 0:
                spec[off + 2] = "model"
        elif name == "ssm":
            if leaf.shape[off + 1] % 16 == 0:
                spec[off + 1] = "model"
        return P(*spec)

    return jax.tree_util.tree_map_with_path(assign, cache_shapes)


def hint(x, *spec):
    """Best-effort activation sharding constraint: applies
    with_sharding_constraint(P(*spec)) when an ambient mesh is set (the
    launcher/dry-run trace under ``jax.set_mesh``), else a no-op (CPU smoke
    tests). Axis names absent from the ambient mesh are dropped, and axes
    that do not divide the dimension are dropped (e.g. batch=1 decode)."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or not mesh.axis_names:
            return x
        axis_sizes = dict(mesh.shape)

        def keep(a, dim):
            if a is None:
                return None
            if isinstance(a, tuple):
                kept = tuple(x_ for x_ in a if x_ in mesh.axis_names)
                if not kept:
                    return None
                tot = 1
                for x_ in kept:
                    tot *= axis_sizes[x_]
                return kept if dim % tot == 0 else None
            if a not in mesh.axis_names:
                return None
            return a if dim % axis_sizes[a] == 0 else None

        cleaned = [keep(a, d) for a, d in zip(spec, x.shape)]
        return jax.lax.with_sharding_constraint(x, P(*cleaned))
    except Exception:  # pragma: no cover — never fail model code on hints
        return x


DP = ("pod", "data")  # batch axes superset; hint() drops absent names


def to_shardings(pspecs, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        pspecs,
        is_leaf=lambda x: isinstance(x, P),
    )


def catalog_shardings(db, mesh=None) -> Dict[str, NamedSharding]:
    """NamedSharding per ``repro.Database`` catalog relation whose layout
    a compiled plan committed to (``Database.layout``) — the dict to
    ``device_put`` freshly loaded inputs against so they arrive at the
    planned placement and the session's plan-stability record applies
    from the first step (``Compiled.counters["reshard"]`` stays flat at
    zero).
    ``mesh`` defaults to the session's active mesh; relations no plan has
    placed yet are omitted."""
    mesh = mesh if mesh is not None else db.mesh
    if mesh is None:
        return {}
    out: Dict[str, NamedSharding] = {}
    for name, entry in db.catalog.items():
        if entry.layout is not None:
            out[name] = NamedSharding(mesh, entry.layout)
    return out
