"""Shared model primitives: norms, activations, RoPE / M-RoPE, init."""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale + bias).astype(dt)


def softcap(x: jnp.ndarray, cap: Optional[float]) -> jnp.ndarray:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# Rotary embeddings
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float = 10000.0) -> jnp.ndarray:
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(
    x: jnp.ndarray,                 # (B, S, H, hd)
    positions: jnp.ndarray,         # (B, S) int32
    theta: float = 10000.0,
) -> jnp.ndarray:
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B,S,hd/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jnp.ndarray,                 # (B, S, H, hd)
    positions: jnp.ndarray,         # (B, 3, S) int32 — t/h/w position triplets
    sections: Tuple[int, int, int],  # rope dims (pairs) per axis, sums to hd/2
    theta: float = 1_000_000.0,
) -> jnp.ndarray:
    """Qwen2-VL multimodal RoPE: the rotary spectrum is split into three
    sections rotated by temporal/height/width positions respectively
    [arXiv:2409.12191]."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    # section id per frequency pair
    sec = jnp.repeat(
        jnp.arange(3), jnp.array(sections), total_repeat_length=hd // 2
    )
    # Gather per-frequency positions: posf[b, f, s] = positions[b, sec[f], s]
    posf = positions.astype(jnp.float32)[:, sec, :]     # (B, hd/2, S)
    ang = jnp.einsum("bfs,f->bsf", posf, freqs)         # (B,S,hd/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def dense_init(key, shape, in_axis: int = 0, dtype=jnp.float32) -> jnp.ndarray:
    fan_in = shape[in_axis]
    std = fan_in ** -0.5
    return (jax.random.normal(key, shape, dtype=jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32) -> jnp.ndarray:
    return (jax.random.normal(key, shape, dtype=jnp.float32)).astype(dtype)
