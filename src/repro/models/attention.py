"""Attention: GQA with RoPE / sliding window / logit softcap, MLA
(DeepSeek-V3 multi-head latent attention), KV caches, and a chunked
(online-softmax) attention that never materializes the S×S score matrix —
required to lower prefill_32k without O(S²) buffers.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .common import apply_rope, softcap

NEG_INF = -2.0e38


_PAD_POS = -(10**9)  # sentinel position for padded KV slots


def _mask_bias(
    q_pos: jnp.ndarray,   # (Sq,) absolute query positions
    k_pos: jnp.ndarray,   # (Sk,)
    causal: bool,
    window: Optional[int],
) -> jnp.ndarray:
    """(Sq, Sk) additive mask."""
    dq = q_pos[:, None]
    dk = k_pos[None, :]
    ok = dk > _PAD_POS // 2   # padded slots always masked
    if causal:
        ok &= dk <= dq
    if window is not None:
        ok &= dk > dq - window
    return jnp.where(ok, 0.0, NEG_INF)


def attention(
    q: jnp.ndarray,       # (B, Sq, Hq, hd)
    k: jnp.ndarray,       # (B, Sk, Hkv, hd)
    v: jnp.ndarray,       # (B, Sk, Hkv, hd)
    *,
    q_positions: jnp.ndarray,
    k_positions: jnp.ndarray,
    causal: bool = True,
    window: Optional[int] = None,
    logit_softcap: Optional[float] = None,
    chunk_size: Optional[int] = None,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    """GQA attention. With ``chunk_size`` set, keys/values are processed in
    blocks with an online softmax (flash-attention recurrence) under
    ``lax.scan`` — O(Sq·chunk) live memory instead of O(Sq·Sk)."""
    b, sq, hq, hd = q.shape
    _, sk, hkv, _ = k.shape
    assert hq % hkv == 0
    groups = hq // hkv
    scale = scale if scale is not None else hd ** -0.5
    qf = (q * scale).astype(jnp.float32).reshape(b, sq, hkv, groups, hd)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    if chunk_size is None or sk <= chunk_size:
        logits = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kf)
        logits = softcap(logits, logit_softcap)
        logits = logits + _mask_bias(q_positions, k_positions, causal, window)
        w = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bhgqk,bkhd->bqhgd", w, vf)
        return out.reshape(b, sq, hq, hd).astype(q.dtype)

    # --- chunked online-softmax path -------------------------------------
    pad = (-sk) % chunk_size
    if pad:
        kf = jnp.pad(kf, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_positions = jnp.pad(k_positions, (0, pad), constant_values=_PAD_POS)
        sk += pad
    nkc = sk // chunk_size
    kc = kf.reshape(b, nkc, chunk_size, hkv, hd).transpose(1, 0, 2, 3, 4)
    vc = vf.reshape(b, nkc, chunk_size, hkv, hd).transpose(1, 0, 2, 3, 4)
    kpos = k_positions.reshape(nkc, chunk_size)

    def step(carry, inp):
        m, l, acc = carry          # (b,hkv,g,sq), (b,hkv,g,sq), (b,hkv,g,sq,hd)
        kb, vb, kp = inp
        logits = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kb)
        logits = softcap(logits, logit_softcap)
        logits = logits + _mask_bias(q_positions, kp, causal, window)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        # guard fully-masked rows
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(logits - m_safe[..., None])
        p = jnp.where(jnp.isfinite(logits), p, 0.0)
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum("bhgqk,bkhd->bhgqd", p, vb)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hkv, groups, sq), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((b, hkv, groups, sq), dtype=jnp.float32)
    a0 = jnp.zeros((b, hkv, groups, sq, hd), dtype=jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kc, vc, kpos))
    out = acc / jnp.maximum(l, 1e-30)[..., None]       # (b,hkv,g,sq,hd)
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, hq, hd)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# KV cache (decode)
# ---------------------------------------------------------------------------


@dataclass
class KVCache:
    """Static-capacity ring-less cache: k/v (B, S_max, Hkv, hd), ``length``
    scalar int32 = tokens currently valid."""

    k: jnp.ndarray
    v: jnp.ndarray
    length: jnp.ndarray  # () int32


def cache_update(cache_k, cache_v, length, k_new, v_new):
    """Insert (B, 1, Hkv, hd) new entries at ``length``."""
    length = jnp.asarray(length, dtype=jnp.int32)
    zero = jnp.zeros((), dtype=jnp.int32)
    idx = (zero, length, zero, zero)
    ck = jax.lax.dynamic_update_slice(cache_k, k_new.astype(cache_k.dtype), idx)
    cv = jax.lax.dynamic_update_slice(cache_v, v_new.astype(cache_v.dtype), idx)
    return ck, cv


def decode_attention(
    q: jnp.ndarray,        # (B, 1, Hq, hd)
    cache_k: jnp.ndarray,  # (B, S, Hkv, hd) — S = full capacity
    cache_v: jnp.ndarray,
    length: jnp.ndarray,   # () int32 — number of valid positions
    *,
    window: Optional[int] = None,
    logit_softcap: Optional[float] = None,
    scale: Optional[float] = None,
    align: str = "left",   # "right": valid entries occupy the last slots
) -> jnp.ndarray:
    """Single-token decode against a cache; invalid/out-of-window positions
    are masked. O(S) compute/memory — sub-quadratic by nature. Sliding-
    window layers keep a right-aligned window-sized cache (align='right')."""
    b, _, hq, hd = q.shape
    _, s, hkv, _ = cache_k.shape
    groups = hq // hkv
    scale = scale if scale is not None else hd ** -0.5
    qf = (q * scale).astype(jnp.float32).reshape(b, hkv, groups, hd)
    logits = jnp.einsum("bhgd,bkhd->bhgk", qf, cache_k.astype(jnp.float32))
    logits = softcap(logits, logit_softcap)
    pos = jnp.arange(s)
    if align == "left":
        ok = pos[None, :] < length
        if window is not None:
            ok = ok & (pos[None, :] > length - 1 - window)
    else:
        ok = pos[None, :] >= s - length
    logits = jnp.where(ok[:, None, None, :], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", w, cache_v.astype(jnp.float32))
    return out.reshape(b, 1, hq, hd).astype(q.dtype)
