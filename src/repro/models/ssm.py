"""State-space blocks: Mamba-1 (falcon-mamba) and Mamba-2 (zamba2).

The selective scan is a linear recurrence h_t = a_t ⊙ h_{t-1} + b_t, which
we run with ``jax.lax.associative_scan`` — the TPU-native parallel-prefix
form (log-depth, bandwidth-bound) instead of the CUDA kernel the papers
ship. Decode keeps (conv window, ssm state) as carried state and advances
one step in O(1).

Arch-applicability (DESIGN.md): the recurrence is *not* a relational
join-aggregate, so the paper's auto-diff does not cover it — these blocks
use JAX AD for the scan itself, while their projections (in/out/gate/dt)
still go through the relational engine.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.relational import rel_linear

from .common import dense_init


def _assoc_scan(a, b):
    """h_t = a_t * h_{t-1} + b_t along axis 1 (time). a, b: (B, S, ...).
    Returns (cumulative a-product, h)."""

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    return jax.lax.associative_scan(combine, (a, b), axis=1)


def selective_scan(a, b, chunk: int = 0):
    """h_t = a_t ⊙ h_{t-1} + b_t along axis 1.

    ``chunk == 0`` runs one parallel prefix over the whole sequence:
    O(S·log₂S) HBM traffic in the (B,S,·) state tensors. ``chunk > 0``
    runs a *sequential* ``lax.scan`` over S/chunk chunks carrying the
    boundary state, with the parallel prefix only within each chunk:
    O(S·(log₂chunk + 2)) traffic — the Mamba-2/SSD blocking adapted to
    XLA (§Perf iteration 1). The carry enters each chunk through the
    cumulative a-product the within-chunk prefix already computes, so the
    extra cost per chunk is one multiply-add."""
    s = a.shape[1]
    if not chunk or s <= chunk or s % chunk:
        return _assoc_scan(a, b)[1]
    nc = s // chunk
    a_c = jnp.moveaxis(
        a.reshape((a.shape[0], nc, chunk) + a.shape[2:]), 1, 0
    )
    b_c = jnp.moveaxis(
        b.reshape((b.shape[0], nc, chunk) + b.shape[2:]), 1, 0
    )
    h0 = jnp.zeros(b.shape[:1] + b.shape[2:], dtype=b.dtype)

    def step(h, ab):
        ac, bc = ab
        pa, hl = _assoc_scan(ac, bc)
        hc = hl + pa * h[:, None]
        return hc[:, -1], hc

    # fully unrolled: few chunks (S/chunk ≤ ~64), no loop overhead, and
    # cost_analysis counts every chunk (honest roofline accounting)
    _, hs = jax.lax.scan(step, h0, (a_c, b_c), unroll=True)
    hs = jnp.moveaxis(hs, 0, 1)
    return hs.reshape((b.shape[0], s) + b.shape[2:])


# ---------------------------------------------------------------------------
# Mamba-1 (falcon-mamba, arXiv:2410.05355)
# ---------------------------------------------------------------------------


def mamba1_init(key, d_model: int, state: int = 16, expand: int = 2,
                conv_width: int = 4, dt_rank: Optional[int] = None,
                dtype=jnp.float32):
    d_inner = expand * d_model
    dt_rank = dt_rank or max(1, d_model // 16)
    ks = jax.random.split(key, 7)
    return {
        "in_proj": dense_init(ks[0], (d_model, 2 * d_inner), dtype=dtype),
        "conv_w": dense_init(ks[1], (conv_width, d_inner), dtype=dtype),
        "conv_b": jnp.zeros((d_inner,), dtype=dtype),
        "x_proj": dense_init(ks[2], (d_inner, dt_rank + 2 * state), dtype=dtype),
        "dt_proj": dense_init(ks[3], (dt_rank, d_inner), dtype=dtype),
        "dt_bias": jnp.zeros((d_inner,), dtype=dtype),
        "a_log": jnp.log(
            jnp.broadcast_to(jnp.arange(1, state + 1, dtype=jnp.float32), (d_inner, state))
        ),
        "d_skip": jnp.ones((d_inner,), dtype=jnp.float32),
        "out_proj": dense_init(ks[4], (d_inner, d_model), dtype=dtype),
    }


def _causal_conv(x, w, b, state: Optional[jnp.ndarray] = None):
    """x: (B,S,C), w: (W,C) depthwise. With ``state`` (B,W-1,C) prepends the
    carried window (decode); returns (y, new_state)."""
    width = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], width - 1, x.shape[2]), dtype=x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(width)
    )
    new_state = xp[:, xp.shape[1] - (width - 1):, :]
    return y + b[None, None, :], new_state


def mamba1_apply(
    p,
    x: jnp.ndarray,                      # (B, S, D)
    *,
    state: Optional[dict] = None,        # decode: {"conv": (B,W-1,C), "ssm": (B,C,N)}
    chunk: int = 0,                      # sequential chunking of the scan
    scan_dtype=jnp.float32,              # state dtype inside the scan
    use_pallas: bool = False,            # single-pass Pallas scan kernel
) -> Tuple[jnp.ndarray, Optional[dict]]:
    b, s, _ = x.shape
    d_inner = p["conv_w"].shape[1]
    n = p["a_log"].shape[1]

    xz = rel_linear(x, p["in_proj"])
    xin, z = jnp.split(xz, 2, axis=-1)

    conv_state = state["conv"] if state is not None else None
    xc, new_conv = _causal_conv(xin, p["conv_w"], p["conv_b"], conv_state)
    xc = jax.nn.silu(xc)

    dbl = rel_linear(xc, p["x_proj"])
    dt_rank = p["dt_proj"].shape[0]
    dt, bmat, cmat = jnp.split(dbl, [dt_rank, dt_rank + n], axis=-1)
    dt = jax.nn.softplus(rel_linear(dt, p["dt_proj"]) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])                            # (C, N)

    dt32 = dt.astype(jnp.float32)                        # (B,S,C)
    da = jnp.exp(dt32[..., None] * a[None, None])        # (B,S,C,N)
    db = dt32[..., None] * bmat.astype(jnp.float32)[:, :, None, :]  # (B,S,C,N)
    bx = db * xc.astype(jnp.float32)[..., None]

    if state is None:
        # da/bx are exp/products computed in f32; the scan itself may run
        # in a narrower state dtype (§Perf iteration 2).
        if use_pallas:
            from repro.kernels.ssm_scan import ssm_scan

            h = ssm_scan(
                da.astype(scan_dtype), bx.astype(scan_dtype),
                256, 8, jax.default_backend() != "tpu", True,
            )
        else:
            h = selective_scan(
                da.astype(scan_dtype), bx.astype(scan_dtype), chunk
            )                                            # (B,S,C,N)
        new_ssm = h[:, -1].astype(jnp.float32)
    else:
        h = da[:, 0] * state["ssm"] + bx[:, 0]           # (B,C,N)
        new_ssm = h
        h = h[:, None]

    y = jnp.einsum("bscn,bsn->bsc", h, cmat.astype(h.dtype))
    y = y.astype(jnp.float32)
    y = y + p["d_skip"][None, None] * xc.astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = rel_linear(y, p["out_proj"])
    return out, {"conv": new_conv, "ssm": new_ssm}


# ---------------------------------------------------------------------------
# Mamba-2 / SSD (zamba2, arXiv:2411.15242)
# ---------------------------------------------------------------------------


def mamba2_init(key, d_model: int, state: int = 64, expand: int = 2,
                n_heads: Optional[int] = None, head_dim: int = 64,
                conv_width: int = 4, dtype=jnp.float32):
    d_inner = expand * d_model
    n_heads = n_heads or d_inner // head_dim
    ks = jax.random.split(key, 6)
    # in_proj emits [z, x, B, C, dt]
    d_xbc = d_inner + 2 * state
    return {
        "in_proj": dense_init(
            ks[0], (d_model, 2 * d_inner + 2 * state + n_heads), dtype=dtype
        ),
        "conv_w": dense_init(ks[1], (conv_width, d_xbc), dtype=dtype),
        "conv_b": jnp.zeros((d_xbc,), dtype=dtype),
        "a_log": jnp.zeros((n_heads,), dtype=jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), dtype=jnp.float32),
        "d_skip": jnp.ones((n_heads,), dtype=jnp.float32),
        "norm_scale": jnp.zeros((d_inner,), dtype=dtype),
        "out_proj": dense_init(ks[2], (d_inner, d_model), dtype=dtype),
    }


def mamba2_apply(
    p,
    x: jnp.ndarray,                      # (B, S, D)
    *,
    head_dim: int = 64,
    state_dim: int = 64,
    state: Optional[dict] = None,
    chunk: int = 0,
    scan_dtype=jnp.float32,
    use_pallas: bool = False,
) -> Tuple[jnp.ndarray, dict]:
    from .common import rms_norm

    b, s, _ = x.shape
    nh = p["a_log"].shape[0]
    d_inner = nh * head_dim
    n = state_dim

    zxbcdt = rel_linear(x, p["in_proj"])
    z, xbc, dt = jnp.split(
        zxbcdt, [d_inner, d_inner + d_inner + 2 * n], axis=-1
    )
    conv_state = state["conv"] if state is not None else None
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state)
    xbc = jax.nn.silu(xbc)
    xin, bmat, cmat = jnp.split(xbc, [d_inner, d_inner + n], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    a = -jnp.exp(p["a_log"])                                      # (H,)
    da = jnp.exp(dt * a[None, None])                              # (B,S,H)

    xh = xin.reshape(b, s, nh, head_dim).astype(jnp.float32)
    bx = (
        dt[..., None, None]
        * bmat.astype(jnp.float32)[:, :, None, :, None]
        * xh[..., None, :]
    )  # (B,S,H,N,P)

    if state is None:
        if use_pallas:
            from repro.kernels.ssm_scan import ssm_scan

            hb, hs, hh = bx.shape[:3]
            da_full = jnp.broadcast_to(da[..., None, None], bx.shape)
            h = ssm_scan(
                da_full.reshape(hb, hs, hh, n * head_dim).astype(scan_dtype),
                bx.reshape(hb, hs, hh, n * head_dim).astype(scan_dtype),
                256, 8, jax.default_backend() != "tpu", True,
            ).reshape(bx.shape)
        else:
            h = selective_scan(
                da[..., None, None].astype(scan_dtype),
                bx.astype(scan_dtype),
                chunk,
            )                                                     # (B,S,H,N,P)
        new_ssm = h[:, -1].astype(jnp.float32)
    else:
        h = da[:, 0, :, None, None] * state["ssm"] + bx[:, 0]
        new_ssm = h
        h = h[:, None]

    y = jnp.einsum("bshnp,bsn->bshp", h, cmat.astype(h.dtype))
    y = y.astype(jnp.float32)
    y = y + p["d_skip"][None, None, :, None] * xh
    y = y.reshape(b, s, d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm_scale"])
    out = rel_linear(y, p["out_proj"])
    return out, {"conv": new_conv, "ssm": new_ssm}
