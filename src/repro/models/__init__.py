"""Model zoo: the 10 assigned architectures as config-selectable decoder /
encoder-decoder / SSM / hybrid / MoE language models, built from shared
pure-JAX blocks. Parameter-bearing contractions route through the
relational engine (repro.relational) so training gradients are the
RA-autodiff-generated queries."""

from .model import Model, build_model  # noqa: F401
