"""Model assembly: config → staged, scanned decoder (+ optional encoder).

Layers are grouped into *stages*; each stage is a repeating superblock
(cfg.pattern) whose parameters are stacked on a leading axis and executed
with ``jax.lax.scan`` — compile time is O(#distinct blocks), not O(depth),
which keeps the 512-device dry-run tractable for 126-layer models.
Remainder layers (n_layers % len(pattern)) run unscanned after the scan.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.launch.sharding import DP, hint
from repro.relational import rel_embed, rel_linear

from .blocks import block_apply, block_init, shared_attn_init
from .common import dense_init, embed_init, layer_norm, rms_norm, softcap


@dataclass(frozen=True)
class Stage:
    pattern: Tuple[str, ...]
    repeats: int
    tail: Tuple[str, ...] = ()


def stages_of(cfg) -> List[Stage]:
    if cfg.first_k_dense:
        # deepseek-v3: leading dense-FFN layers, then MoE layers
        return [
            Stage(("mla" if cfg.mla else "attn",), cfg.first_k_dense),
            Stage(
                ("mla_moe" if cfg.mla else "moe",),
                cfg.n_layers - cfg.first_k_dense,
            ),
        ]
    pat = cfg.pattern
    reps = cfg.n_layers // len(pat)
    tail = pat[: cfg.n_layers % len(pat)]
    return [Stage(pat, reps, tail)]


class Model:
    """Functional model: ``init`` → params pytree; ``train_logits`` /
    ``prefill`` / ``decode_step`` pure functions of (params, batch)."""

    def __init__(self, cfg):
        self.cfg = cfg
        self.stages = stages_of(cfg)

    # -- parameters ---------------------------------------------------------

    def init(self, key) -> Dict[str, Any]:
        cfg = self.cfg
        keys = jax.random.split(key, 8 + len(self.stages))
        dt = jnp.dtype(cfg.dtype)
        params: Dict[str, Any] = {
            "embed": embed_init(keys[0], (cfg.vocab, cfg.d_model), dtype=dt),
            "ln_f": jnp.zeros((cfg.d_model,), dt),
        }
        if not cfg.tie_embeddings:
            params["out_embed"] = dense_init(
                keys[1], (cfg.d_model, cfg.vocab), dtype=dt
            )
        if "mamba2_attn" in _all_kinds(self.stages):
            params["shared_attn"] = shared_attn_init(keys[2], cfg)
        if cfg.encoder_layers:
            ek = jax.random.split(keys[3], cfg.encoder_layers)
            params["encoder"] = jax.vmap(
                lambda k: block_init(k, "enc", cfg)
            )(ek)
            params["enc_ln_s"] = jnp.ones((cfg.d_model,), dt)
            params["enc_ln_b"] = jnp.zeros((cfg.d_model,), dt)

        def superblock_init(k, pattern):
            ks = jax.random.split(k, len(pattern))
            return {
                f"{i}:{kind}": block_init(ks[i], kind, cfg)
                for i, kind in enumerate(pattern)
            }

        params["stages"] = []
        for si, st in enumerate(self.stages):
            sk = jax.random.split(keys[4 + si], st.repeats)
            stacked = jax.vmap(lambda k: superblock_init(k, st.pattern))(sk)
            tailp = [
                block_init(jax.random.fold_in(keys[4 + si], 1000 + i), kind, cfg)
                for i, kind in enumerate(st.tail)
            ]
            params["stages"].append({"scan": stacked, "tail": tailp})
        return params

    # -- embedding / head ---------------------------------------------------

    def _embed(self, params, tokens):
        cfg = self.cfg
        x = rel_embed(params["embed"], tokens.reshape(-1)).reshape(
            *tokens.shape, cfg.d_model
        )
        if cfg.embed_scale:
            x = x * jnp.asarray(cfg.d_model**0.5, x.dtype)
        # The vocab-parallel gather leaves the result's sharding ambiguous;
        # pin activations to batch-sharded before the backbone.
        return hint(x, DP, None, None)

    def _head(self, params, x):
        cfg = self.cfg
        h = rms_norm(x, params["ln_f"], cfg.norm_eps)
        if cfg.tie_embeddings:
            logits = jnp.einsum("bsd,vd->bsv", h, params["embed"])
        else:
            logits = rel_linear(h, params["out_embed"])
        return softcap(logits.astype(jnp.float32), cfg.final_softcap)

    # -- backbone -----------------------------------------------------------

    def _run_stages(self, params, x, ctx, caches):
        """caches: None (train) or list matching stages:
        {"scan": stacked cache pytree or None, "tail": [entry,...]}.
        Returns (x, new_caches, aux)."""
        cfg = self.cfg
        mode = ctx["mode"]
        aux_total = jnp.zeros((), jnp.float32)
        new_caches = []

        for si, st in enumerate(self.stages):
            sp = params["stages"][si]

            def superblock(x, sparams, cache_entry):
                aux = jnp.zeros((), jnp.float32)
                new_entry = {}
                for i, kind in enumerate(st.pattern):
                    key = f"{i}:{kind}"
                    bctx = dict(ctx)
                    bctx["cache"] = (
                        cache_entry[key] if cache_entry is not None else None
                    )
                    x, c, a = block_apply(sparams[key], kind, x, bctx)
                    new_entry[key] = c
                    aux = aux + a
                return x, new_entry, aux

            sb = superblock
            if cfg.remat and mode == "train":
                # "dots" saves every matmul output (no backward recompute
                # of the big contractions); note dots_with_no_batch_dims
                # is a no-op for transformer blocks — everything here
                # carries a batch dim (measured: identical terms).
                policy = (
                    jax.checkpoint_policies.dots_saveable
                    if cfg.remat_policy == "dots"
                    else jax.checkpoint_policies.nothing_saveable
                )
                sb = jax.checkpoint(superblock, policy=policy)

            def scan_step(carry, xs):
                x, aux = carry
                sparams, cache_entry = xs
                x = hint(x, DP, None, None)
                x, new_entry, a = sb(x, sparams, cache_entry)
                return (x, aux + a), new_entry

            cache_xs = caches[si]["scan"] if caches is not None else None
            if cache_xs is None:
                cache_xs = _none_like_scan(sp["scan"], st)
            (x, aux_total), scan_cache = jax.lax.scan(
                scan_step,
                (x, aux_total),
                (sp["scan"], cache_xs),
                unroll=max(1, min(cfg.scan_unroll, st.repeats)),
            )

            tail_cache = []
            for i, kind in enumerate(st.tail):
                bctx = dict(ctx)
                bctx["cache"] = (
                    caches[si]["tail"][i] if caches is not None else None
                )
                x, c, a = block_apply(sp["tail"][i], kind, x, bctx)
                tail_cache.append(c)
                aux_total = aux_total + a
            new_caches.append({"scan": scan_cache, "tail": tail_cache})
        return x, new_caches, aux_total

    def _encode(self, params, frames):
        """Whisper encoder over stubbed frame embeddings (B, S_enc, D)."""
        cfg = self.cfg
        ctx = {"cfg": cfg, "mode": "train", "positions": None, "cache": None}

        def step(x, lp):
            x, _, _ = block_apply(lp, "enc", x, ctx)
            return x, None

        x, _ = jax.lax.scan(step, frames, params["encoder"])
        return layer_norm(x, params["enc_ln_s"], params["enc_ln_b"], cfg.norm_eps)

    def _positions(self, batch_shape, s, length=None, vis=0):
        cfg = self.cfg
        b = batch_shape
        if cfg.mrope_sections:
            if length is not None:
                p = jnp.broadcast_to(length, (b, 3, 1)).astype(jnp.int32)
                return p
            grid = max(1, int(round(vis**0.5))) if vis else 1
            idx = jnp.arange(vis)
            tpos = jnp.zeros((vis,), jnp.int32)
            hpos = (idx // grid).astype(jnp.int32)
            wpos = (idx % grid).astype(jnp.int32)
            start = jnp.asarray(max(grid, 1), jnp.int32)
            text = start + jnp.arange(s - vis, dtype=jnp.int32)
            pos = jnp.stack(
                [
                    jnp.concatenate([tpos, text]),
                    jnp.concatenate([hpos, text]),
                    jnp.concatenate([wpos, text]),
                ]
            )
            return jnp.broadcast_to(pos[None], (b, 3, s))
        if length is not None:
            return jnp.broadcast_to(length, (b, 1)).astype(jnp.int32)
        return jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    # -- entry points --------------------------------------------------------

    def train_logits(self, params, batch):
        """batch: tokens (B,S) [+ frames (B,S_enc,D) | patches (B,Sv,D)].
        Returns (logits (B,S,V), aux)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        b, s = tokens.shape
        x = self._embed(params, tokens)
        vis = 0
        if cfg.vis_seq and "patches" in batch:
            vis = batch["patches"].shape[1]
            x = jnp.concatenate([batch["patches"].astype(x.dtype), x], axis=1)
            s = s + vis
        ctx = {
            "cfg": cfg,
            "mode": "train",
            "positions": self._positions(b, s, vis=vis),
            "cache": None,
        }
        if cfg.encoder_layers:
            ctx["enc_out"] = self._encode(params, batch["frames"])
        if "shared_attn" in params:
            ctx["shared"] = params["shared_attn"]
        x, _, aux = self._run_stages(params, x, ctx, None)
        if vis:
            x = x[:, vis:]
        return self._head(params, x), aux

    def prefill(self, params, batch, cache_len: int):
        cfg = self.cfg
        tokens = batch["tokens"]
        b, s = tokens.shape
        x = self._embed(params, tokens)
        vis = 0
        if cfg.vis_seq and "patches" in batch:
            vis = batch["patches"].shape[1]
            x = jnp.concatenate([batch["patches"].astype(x.dtype), x], axis=1)
            s = s + vis
        ctx = {
            "cfg": cfg,
            "mode": "prefill",
            "positions": self._positions(b, s, vis=vis),
            "cache": None,
            "cache_len": cache_len,
        }
        if cfg.encoder_layers:
            ctx["enc_out"] = self._encode(params, batch["frames"])
        if "shared_attn" in params:
            ctx["shared"] = params["shared_attn"]
        x, caches, _ = self._run_stages(params, x, ctx, None)
        if vis:
            x = x[:, vis:]
        return self._head(params, x[:, -1:]), caches

    def decode_step(self, params, token, caches, length, enc_out=None):
        """token: (B, 1) int32; caches from prefill (or dry-run specs);
        length: () int32 count of valid cache entries."""
        cfg = self.cfg
        b = token.shape[0]
        x = self._embed(params, token)
        ctx = {
            "cfg": cfg,
            "mode": "decode",
            "positions": self._positions(b, 1, length=length),
            "length": length,
        }
        if cfg.encoder_layers:
            assert enc_out is not None
            ctx["enc_out"] = enc_out
        if "shared_attn" in params:
            ctx["shared"] = params["shared_attn"]
        x, caches, _ = self._run_stages(params, x, ctx, caches)
        return self._head(params, x), caches


def _all_kinds(stages: List[Stage]) -> set:
    out = set()
    for st in stages:
        out |= set(st.pattern) | set(st.tail)
    return out


def _none_like_scan(stacked_params, st: Stage):
    """Scan xs placeholder when no cache is threaded: a pytree of Nones is
    not scannable, so thread a zeros i32 per repeat instead and translate
    to None inside the superblock (block ctx uses `cache_entry is None`)."""
    return None


def build_model(cfg) -> Model:
    return Model(cfg)
