"""Layer blocks for all architecture families.

Kinds:
  attn / local / global — GQA decoder layer (full / sliding-window / full)
  moe                   — GQA attention + token-choice MoE   (olmoe)
  mla / mla_moe         — multi-head latent attention ± MoE  (deepseek-v3)
  mamba1 / mamba2       — SSM blocks                         (falcon-mamba, zamba2)
  mamba2_attn           — mamba2 + *shared* attention layer  (zamba2)
  enc / dec             — whisper encoder / decoder layers

Every block's apply has signature  (params, x, ctx) -> (x, cache_entry, aux)
where ctx = {mode: train|prefill|decode, positions, cache (entry or None),
length, enc_out, shared (zamba shared-attention params), cfg}.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.relational import rel_linear

from .attention import attention, cache_update, decode_attention
from .common import apply_mrope, apply_rope, dense_init, layer_norm, rms_norm
from .ffn import mlp_apply, mlp_init, moe_apply, moe_init
from .ssm import mamba1_apply, mamba1_init, mamba2_apply, mamba2_init

Ctx = Dict[str, Any]


def _dt(cfg):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# GQA attention sublayer
# ---------------------------------------------------------------------------


def gqa_init(key, cfg, causal: bool = True):
    hd = cfg.hd()
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (cfg.d_model, cfg.n_heads * hd), dtype=_dt(cfg)),
        "wk": dense_init(ks[1], (cfg.d_model, cfg.n_kv_heads * hd), dtype=_dt(cfg)),
        "wv": dense_init(ks[2], (cfg.d_model, cfg.n_kv_heads * hd), dtype=_dt(cfg)),
        "wo": dense_init(ks[3], (cfg.n_heads * hd, cfg.d_model), dtype=_dt(cfg)),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype=_dt(cfg))
        p["k_norm"] = jnp.zeros((hd,), dtype=_dt(cfg))
    return p


def gqa_apply(
    p,
    x: jnp.ndarray,
    ctx: Ctx,
    *,
    window: Optional[int] = None,
    causal: bool = True,
    rope: bool = True,
    kv_source: Optional[jnp.ndarray] = None,   # cross-attention
):
    cfg = ctx["cfg"]
    hd = cfg.hd()
    b, s, _ = x.shape
    mode = ctx["mode"]

    q = rel_linear(x, p["wq"]).reshape(b, s, cfg.n_heads, hd)
    src = kv_source if kv_source is not None else x
    k = rel_linear(src, p["wk"]).reshape(b, src.shape[1], cfg.n_kv_heads, hd)
    v = rel_linear(src, p["wv"]).reshape(b, src.shape[1], cfg.n_kv_heads, hd)

    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)

    if rope and kv_source is None:
        pos = ctx["positions"]
        if cfg.mrope_sections:
            q = apply_mrope(q, pos, cfg.mrope_sections, cfg.rope_theta)
            k = apply_mrope(k, pos, cfg.mrope_sections, cfg.rope_theta)
        else:
            q = apply_rope(q, pos, cfg.rope_theta)
            k = apply_rope(k, pos, cfg.rope_theta)

    new_cache = None
    if kv_source is not None:
        # cross-attention: no cache, bidirectional over encoder states
        out = attention(
            q, k, v,
            q_positions=jnp.arange(s), k_positions=jnp.arange(src.shape[1]),
            causal=False, window=None,
            logit_softcap=cfg.logit_softcap, chunk_size=cfg.attn_chunk,
        )
    elif mode == "decode":
        ck, cv, length = ctx["cache"]["k"], ctx["cache"]["v"], ctx["length"]
        if window is not None and ck.shape[1] <= window:
            # Sliding-window layers keep a window-sized, right-aligned
            # cache: shift left, append — O(window) per step, never O(S).
            ck = jnp.concatenate([ck[:, 1:], k.astype(ck.dtype)], axis=1)
            cv = jnp.concatenate([cv[:, 1:], v.astype(cv.dtype)], axis=1)
            out = decode_attention(
                q, ck, cv, jnp.minimum(length + 1, ck.shape[1]),
                logit_softcap=cfg.logit_softcap, align="right",
            )
        else:
            ck, cv = cache_update(ck, cv, length, k, v)
            out = decode_attention(
                q, ck, cv, length + 1,
                window=window, logit_softcap=cfg.logit_softcap,
            )
        new_cache = {"k": ck, "v": cv}
    else:
        qpos = jnp.arange(s)
        out = attention(
            q, k, v,
            q_positions=qpos, k_positions=qpos,
            causal=causal, window=window,
            logit_softcap=cfg.logit_softcap, chunk_size=cfg.attn_chunk,
        )
        if mode == "prefill":
            cap = ctx["cache_len"]
            if window is not None:
                # right-aligned window cache
                capw = min(cap, window)
                keep = min(s, capw)
                kk, vv = k[:, s - keep:], v[:, s - keep:]
                padl = capw - keep
                new_cache = {
                    "k": jnp.pad(kk, ((0, 0), (padl, 0), (0, 0), (0, 0))).astype(_dt(cfg)),
                    "v": jnp.pad(vv, ((0, 0), (padl, 0), (0, 0), (0, 0))).astype(_dt(cfg)),
                }
            else:
                pad = cap - s
                new_cache = {
                    "k": jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(_dt(cfg)),
                    "v": jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(_dt(cfg)),
                }
    y = rel_linear(out.reshape(b, s, cfg.n_heads * hd), p["wo"])
    return y, new_cache


# ---------------------------------------------------------------------------
# MLA attention sublayer (deepseek-v3, arXiv:2412.19437)
# ---------------------------------------------------------------------------


def mla_init(key, cfg):
    ks = jax.random.split(key, 6)
    qh = cfg.nope_head_dim + cfg.rope_head_dim
    p = {
        "wq_a": dense_init(ks[0], (cfg.d_model, cfg.q_lora_rank), dtype=_dt(cfg)),
        "q_norm": jnp.zeros((cfg.q_lora_rank,), dtype=_dt(cfg)),
        "wq_b": dense_init(ks[1], (cfg.q_lora_rank, cfg.n_heads * qh), dtype=_dt(cfg)),
        "wkv_a": dense_init(
            ks[2], (cfg.d_model, cfg.kv_lora_rank + cfg.rope_head_dim), dtype=_dt(cfg)
        ),
        "kv_norm": jnp.zeros((cfg.kv_lora_rank,), dtype=_dt(cfg)),
        "wk_b": dense_init(
            ks[3], (cfg.kv_lora_rank, cfg.n_heads * cfg.nope_head_dim), dtype=_dt(cfg)
        ),
        "wv_b": dense_init(
            ks[4], (cfg.kv_lora_rank, cfg.n_heads * cfg.v_head_dim), dtype=_dt(cfg)
        ),
        "wo": dense_init(
            ks[5], (cfg.n_heads * cfg.v_head_dim, cfg.d_model), dtype=_dt(cfg)
        ),
    }
    return p


def mla_apply(p, x, ctx):
    """MLA: queries/keys/values via low-rank compression; the decode cache
    stores only (c_kv, k_rope) per position — the paper's memory saving —
    and decode runs in the latent space with absorbed projections."""
    cfg = ctx["cfg"]
    b, s, _ = x.shape
    h, dn, dr, dv, dc = (
        cfg.n_heads, cfg.nope_head_dim, cfg.rope_head_dim,
        cfg.v_head_dim, cfg.kv_lora_rank,
    )
    mode = ctx["mode"]
    pos = ctx["positions"]

    q = rel_linear(rms_norm(rel_linear(x, p["wq_a"]), p["q_norm"], cfg.norm_eps), p["wq_b"])
    q = q.reshape(b, s, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, pos, cfg.rope_theta)

    kv = rel_linear(x, p["wkv_a"])
    c_kv, k_rope = kv[..., :dc], kv[..., dc:]
    c_kv = rms_norm(c_kv, p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], pos, cfg.rope_theta)  # (B,S,1,dr)

    wk_b = p["wk_b"].reshape(dc, h, dn)
    wv_b = p["wv_b"].reshape(dc, h, dv)
    scale = (dn + dr) ** -0.5

    new_cache = None
    if mode == "decode":
        cc, cr, length = ctx["cache"]["c"], ctx["cache"]["r"], ctx["length"]
        zero = jnp.zeros((), jnp.int32)
        idx = (zero, jnp.asarray(length, jnp.int32), zero)
        cc = jax.lax.dynamic_update_slice(cc, c_kv.astype(cc.dtype), idx)
        cr = jax.lax.dynamic_update_slice(
            cr, k_rope[:, :, 0, :].astype(cr.dtype), idx
        )
        new_cache = {"c": cc, "r": cr}
        # absorbed decode: score = (q_nope·W_k c) + (q_rope·k_rope)
        q_lat = jnp.einsum("bshd,chd->bshc", q_nope, wk_b)       # (B,1,H,dc)
        sc = jnp.einsum("bshc,btc->bhst", q_lat.astype(jnp.float32), cc.astype(jnp.float32))
        sc += jnp.einsum("bshd,btd->bhst", q_rope.astype(jnp.float32), cr.astype(jnp.float32))
        sc *= scale
        t = cc.shape[1]
        ok = jnp.arange(t)[None, :] < (length + 1)
        sc = jnp.where(ok[:, None, None, :], sc, -2.0e38)
        w = jax.nn.softmax(sc, axis=-1)
        o_lat = jnp.einsum("bhst,btc->bshc", w, cc.astype(jnp.float32))  # (B,1,H,dc)
        out = jnp.einsum("bshc,chd->bshd", o_lat, wv_b.astype(jnp.float32))
        out = out.astype(x.dtype)
    else:
        k_nope = jnp.einsum("btc,chd->bthd", c_kv, wk_b)
        v = jnp.einsum("btc,chd->bthd", c_kv, wv_b)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope, (b, s, h, dr))], axis=-1
        )
        qfull = jnp.concatenate([q_nope, q_rope], axis=-1)
        qpos = jnp.arange(s)
        # pad v to qk head dim for the shared attention helper, then slice
        out = attention(
            qfull, k, jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, dn + dr - dv))),
            q_positions=qpos, k_positions=qpos, causal=True,
            chunk_size=cfg.attn_chunk, scale=scale,
        )[..., :dv]
        if mode == "prefill":
            cap = ctx["cache_len"]
            pad = cap - s
            new_cache = {
                "c": jnp.pad(c_kv, ((0, 0), (0, pad), (0, 0))).astype(_dt(cfg)),
                "r": jnp.pad(k_rope[:, :, 0, :], ((0, 0), (0, pad), (0, 0))).astype(_dt(cfg)),
            }
    y = rel_linear(out.reshape(b, s, h * dv), p["wo"])
    return y, new_cache


# ---------------------------------------------------------------------------
# Full layer blocks
# ---------------------------------------------------------------------------


def _ffn_dims(cfg, kind: str) -> int:
    if kind in ("moe", "mla_moe"):
        return cfg.d_expert_ff or cfg.d_ff
    return cfg.d_ff


def block_init(key, kind: str, cfg):
    ks = jax.random.split(key, 4)
    dt = _dt(cfg)
    if kind in ("attn", "local", "global", "moe"):
        p = {
            "ln1": jnp.zeros((cfg.d_model,), dt),
            "attn": gqa_init(ks[0], cfg),
            "ln2": jnp.zeros((cfg.d_model,), dt),
        }
        if kind == "moe":
            p["moe"] = moe_init(
                ks[1], cfg.d_model, _ffn_dims(cfg, kind), cfg.n_experts,
                cfg.n_shared_experts, dtype=dt,
            )
        else:
            p["mlp"] = mlp_init(ks[1], cfg.d_model, cfg.d_ff, dtype=dt)
        return p
    if kind in ("mla", "mla_moe"):
        p = {
            "ln1": jnp.zeros((cfg.d_model,), dt),
            "attn": mla_init(ks[0], cfg),
            "ln2": jnp.zeros((cfg.d_model,), dt),
        }
        if kind == "mla_moe":
            p["moe"] = moe_init(
                ks[1], cfg.d_model, _ffn_dims(cfg, kind), cfg.n_experts,
                cfg.n_shared_experts, dtype=dt,
            )
        else:
            p["mlp"] = mlp_init(ks[1], cfg.d_model, cfg.d_ff, dtype=dt)
        return p
    if kind == "mamba1":
        return {
            "ln": jnp.zeros((cfg.d_model,), dt),
            "ssm": mamba1_init(
                key, cfg.d_model, cfg.ssm_state, cfg.ssm_expand,
                cfg.conv_width, dtype=dt,
            ),
        }
    if kind in ("mamba2", "mamba2_attn"):
        return {
            "ln": jnp.zeros((cfg.d_model,), dt),
            "ssm": mamba2_init(
                key, cfg.d_model, cfg.ssm_state, cfg.ssm_expand,
                n_heads=(cfg.ssm_expand * cfg.d_model) // cfg.ssm_head_dim,
                head_dim=cfg.ssm_head_dim, conv_width=cfg.conv_width, dtype=dt,
            ),
        }
    if kind == "enc":
        return {
            "ln1_s": jnp.ones((cfg.d_model,), dt), "ln1_b": jnp.zeros((cfg.d_model,), dt),
            "attn": gqa_init(ks[0], cfg),
            "ln2_s": jnp.ones((cfg.d_model,), dt), "ln2_b": jnp.zeros((cfg.d_model,), dt),
            "mlp": mlp_init(ks[1], cfg.d_model, cfg.d_ff, dtype=dt),
        }
    if kind == "dec":
        return {
            "ln1_s": jnp.ones((cfg.d_model,), dt), "ln1_b": jnp.zeros((cfg.d_model,), dt),
            "attn": gqa_init(ks[0], cfg),
            "lnx_s": jnp.ones((cfg.d_model,), dt), "lnx_b": jnp.zeros((cfg.d_model,), dt),
            "xattn": gqa_init(ks[1], cfg),
            "ln2_s": jnp.ones((cfg.d_model,), dt), "ln2_b": jnp.zeros((cfg.d_model,), dt),
            "mlp": mlp_init(ks[2], cfg.d_model, cfg.d_ff, dtype=dt),
        }
    raise ValueError(f"unknown block kind {kind}")


def shared_attn_init(key, cfg):
    """zamba2 shared attention+MLP block: ONE param set reused at every
    mamba2_attn position (arXiv:2411.15242)."""
    ks = jax.random.split(key, 2)
    dt = _dt(cfg)
    return {
        "ln1": jnp.zeros((cfg.d_model,), dt),
        "attn": gqa_init(ks[0], cfg),
        "ln2": jnp.zeros((cfg.d_model,), dt),
        "mlp": mlp_init(ks[1], cfg.d_model, cfg.d_ff, dtype=dt),
    }


def block_apply(p, kind: str, x, ctx: Ctx):
    cfg = ctx["cfg"]
    aux = jnp.zeros((), jnp.float32)
    cache = {}

    if kind in ("attn", "local", "global", "moe", "mla", "mla_moe"):
        window = cfg.window if kind == "local" else None
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        actx = dict(ctx)
        actx["cache"] = ctx["cache"]["kv"] if ctx.get("cache") else None
        if kind in ("mla", "mla_moe"):
            a, kv = mla_apply(p["attn"], h, actx)
        else:
            a, kv = gqa_apply(p["attn"], h, actx, window=window)
        x = x + a
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        if kind in ("moe", "mla_moe"):
            f, aux = moe_apply(
                p["moe"], h, top_k=cfg.top_k,
                capacity_factor=cfg.capacity_factor,
                shard_experts=cfg.moe_shard_experts,
            )
        else:
            f = mlp_apply(p["mlp"], h)
        x = x + f
        if kv is not None:
            cache["kv"] = kv
        return x, cache, aux

    if kind == "mamba1":
        h = rms_norm(x, p["ln"], cfg.norm_eps)
        y, st = mamba1_apply(
            p["ssm"], h,
            state=ctx.get("cache", {}).get("ssm1") if ctx.get("cache") else None,
            chunk=cfg.ssm_chunk, scan_dtype=jnp.dtype(cfg.ssm_scan_dtype),
            use_pallas=cfg.ssm_pallas,
        )
        cache["ssm1"] = st
        return x + y, cache, aux

    if kind in ("mamba2", "mamba2_attn"):
        h = rms_norm(x, p["ln"], cfg.norm_eps)
        y, st = mamba2_apply(
            p["ssm"], h,
            head_dim=cfg.ssm_head_dim, state_dim=cfg.ssm_state,
            state=ctx.get("cache", {}).get("ssm2") if ctx.get("cache") else None,
            chunk=cfg.ssm_chunk, scan_dtype=jnp.dtype(cfg.ssm_scan_dtype),
            use_pallas=cfg.ssm_pallas,
        )
        cache["ssm2"] = st
        x = x + y
        if kind == "mamba2_attn":
            sp = ctx["shared"]
            sctx = dict(ctx)
            sctx["cache"] = ctx["cache"]["shared_kv"] if ctx.get("cache") else None
            h = rms_norm(x, sp["ln1"], cfg.norm_eps)
            a, kv = gqa_apply(sp["attn"], h, sctx)
            x = x + a
            x = x + mlp_apply(sp["mlp"], rms_norm(x, sp["ln2"], cfg.norm_eps))
            if kv is not None:
                cache["shared_kv"] = kv
        return x, cache, aux

    if kind == "enc":
        h = layer_norm(x, p["ln1_s"], p["ln1_b"], cfg.norm_eps)
        a, _ = gqa_apply(p["attn"], h, ctx, causal=False, rope=False)
        x = x + a
        h = layer_norm(x, p["ln2_s"], p["ln2_b"], cfg.norm_eps)
        return x + mlp_apply(p["mlp"], h, activation=jax.nn.gelu), cache, aux

    if kind == "dec":
        sctx = dict(ctx)
        sctx["cache"] = ctx["cache"]["kv"] if ctx.get("cache") else None
        h = layer_norm(x, p["ln1_s"], p["ln1_b"], cfg.norm_eps)
        a, kv = gqa_apply(p["attn"], h, sctx)
        x = x + a
        h = layer_norm(x, p["lnx_s"], p["lnx_b"], cfg.norm_eps)
        a, _ = gqa_apply(p["xattn"], h, ctx, kv_source=ctx["enc_out"], rope=False)
        x = x + a
        h = layer_norm(x, p["ln2_s"], p["ln2_b"], cfg.norm_eps)
        x = x + mlp_apply(p["mlp"], h, activation=jax.nn.gelu)
        if kv is not None:
            cache["kv"] = kv
        return x, cache, aux

    raise ValueError(f"unknown block kind {kind}")
