"""Feed-forward blocks: gated MLP and token-choice MoE.

The MoE dispatch is the relational view the paper takes of conditional
computation: routing is a token⋈expert join on the routed key, the combine
is the Σ. The jit lowering uses the sort-by-expert + capacity layout so all
shapes are static; experts are sharded on the ``model`` mesh axis (expert
parallelism) and the gather/scatter become all-to-alls under SPMD.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.launch.sharding import DP, hint
from repro.relational import rel_linear

from .common import dense_init


def mlp_init(key, d_model: int, d_ff: int, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi_gate": dense_init(k1, (d_model, d_ff), dtype=dtype),
        "wi_up": dense_init(k2, (d_model, d_ff), dtype=dtype),
        "wo": dense_init(k3, (d_ff, d_model), dtype=dtype),
    }


def mlp_apply(p, x, activation=jax.nn.silu):
    g = rel_linear(x, p["wi_gate"])
    u = rel_linear(x, p["wi_up"])
    return rel_linear(activation(g) * u, p["wo"])


# ---------------------------------------------------------------------------
# Mixture of Experts
# ---------------------------------------------------------------------------


def moe_init(
    key,
    d_model: int,
    d_ff: int,
    n_experts: int,
    n_shared: int = 0,
    dtype=jnp.float32,
):
    keys = jax.random.split(key, 5)
    p = {
        "router": dense_init(keys[0], (d_model, n_experts), dtype=jnp.float32),
        "wi_gate": dense_init(keys[1], (n_experts, d_model, d_ff), in_axis=1, dtype=dtype),
        "wi_up": dense_init(keys[2], (n_experts, d_model, d_ff), in_axis=1, dtype=dtype),
        "wo": dense_init(keys[3], (n_experts, d_ff, d_model), in_axis=1, dtype=dtype),
    }
    if n_shared:
        p["shared"] = mlp_init(keys[4], d_model, d_ff * n_shared, dtype=dtype)
    return p


def _dispatch_group(xt, router, *, top_k, capacity, e):
    """Routing + dispatch for ONE token group (T_g, D) → expert buffers.

    vmapped over groups (= batch rows): the sort / slot / gather / scatter
    are all group-local, so under SPMD they never cross the data axis.
    Returns (xe (E, C, D), combine metadata, aux loss)."""
    t, d = xt.shape
    logits = xt.astype(jnp.float32) @ router             # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)    # (T, k)

    # auxiliary load-balance loss (Switch-style)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(gate_idx[:, 0], e, dtype=jnp.float32), axis=0)
    aux = e * jnp.sum(me * ce)

    # Sort assignments by expert; position within expert = slot.
    flat_expert = gate_idx.reshape(-1).astype(jnp.int32)  # (T*k,)
    flat_token = jnp.repeat(jnp.arange(t, dtype=jnp.int32), top_k)
    flat_gate = gate_vals.reshape(-1)
    order = jnp.argsort(flat_expert, stable=True)
    se, st, sg = flat_expert[order], flat_token[order], flat_gate[order]
    same = jnp.cumsum(jax.nn.one_hot(se, e, dtype=jnp.int32), axis=0)
    slot = same[jnp.arange(se.shape[0]), se] - 1         # (T*k,)
    keep = slot < capacity
    dest = (se * capacity + jnp.where(keep, slot, capacity - 1)).astype(jnp.int32)

    buf_tok = jnp.zeros((e * capacity,), dtype=jnp.int32).at[dest].set(
        jnp.where(keep, st, 0), mode="drop"
    )
    buf_used = jnp.zeros((e * capacity,), dtype=bool).at[dest].set(
        keep, mode="drop"
    )
    xe = xt[buf_tok] * buf_used[:, None].astype(xt.dtype)
    xe = xe.reshape(e, capacity, d)
    return xe, (dest, st, sg, keep), aux


def _combine_group(ye, meta, *, t, dtype):
    """Scatter expert outputs (E·C, D) back to token order for one group."""
    dest, st, sg, keep = meta
    # combine in the activation dtype: the gate factor is f32 (softmax),
    # but promoting the (T·k, D) contrib tensor to f32 doubles the bytes
    # of the layer's biggest reshard.
    contrib = ye[dest] * (sg * keep)[:, None].astype(ye.dtype)
    return jnp.zeros((t, ye.shape[-1]), dtype=dtype).at[st].add(
        contrib.astype(dtype)
    )


def moe_apply(
    p,
    x: jnp.ndarray,               # (B, S, D)
    *,
    top_k: int,
    capacity_factor: float = 1.25,
    shard_experts: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Token-choice top-k routing with static per-group capacity.

    Groups = batch rows (data-sharded); tokens beyond an expert's capacity
    within their group are dropped (combine weight zero) — the standard
    static-shape TPU formulation, kept shard-local per group.
    """
    b, s, d = x.shape
    e = p["router"].shape[1]
    capacity = max(int(capacity_factor * s * top_k / e), top_k)

    # Dispatch (group-local, vmapped over batch rows).
    xe, meta, aux = jax.vmap(
        functools.partial(_dispatch_group, top_k=top_k, capacity=capacity, e=e),
        in_axes=(0, None),
    )(x, p["router"])                                    # xe: (B, E, C, D)
    aux = jnp.mean(aux)

    # Expert FFN OUTSIDE the vmap so the partitioner sees both the batch
    # and expert dims: tokens stay data-sharded, experts model-sharded —
    # the GSPMD MoE layout. (Inside a vmap the batch dim is invisible to
    # sharding constraints and the partitioner replicated the full global
    # batch through this segment — §Perf olmoe iterations.)
    if shard_experts:
        xe = hint(xe, DP, "model", None, None)
    g = jnp.einsum("becd,edf->becf", xe, p["wi_gate"])
    u = jnp.einsum("becd,edf->becf", xe, p["wi_up"])
    ye = jnp.einsum("becf,efd->becd", jax.nn.silu(g) * u, p["wo"])
    if shard_experts:
        ye = hint(ye, DP, "model", None, None)
    ye = ye.reshape(b, e * capacity, d)

    # Combine (group-local, vmapped).
    out = jax.vmap(
        functools.partial(_combine_group, t=s, dtype=x.dtype)
    )(ye, meta)

    if "shared" in p:
        out = out + mlp_apply(p["shared"], x).astype(out.dtype)
    return out, aux
