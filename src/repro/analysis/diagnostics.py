"""Structured diagnostics shared by the typed FRA checker, the SQL
front end, and ``Database.explain``.

A :class:`Diagnostic` pins one finding to a *node path* — a stable,
structural address inside the query (``Σ/⋈/L:τ(edges)``) or the SQL
script (``stmt[0]/FROM``) — so tooling can point at the offending
operator rather than a trace-time stack frame. This module deliberately
imports nothing from the rest of ``repro`` so that any layer (including
``core.sql``) can depend on it without cycles.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

#: Severity levels, most severe first. ``error`` means the compiled
#: path is guaranteed to reject the query; ``warning`` marks hazards
#: (silent dtype promotion, empty selections, replication fallbacks,
#: partial-RJP gradients) that execute but deserve attention.
SEVERITIES: Tuple[str, ...] = ("error", "warning", "info")


@dataclass(frozen=True)
class Diagnostic:
    """One finding: where (``node_path``), what (``message``), how bad
    (``severity``), which rule (``code``), and how to fix it (``hint``)."""

    severity: str
    code: str
    node_path: str
    message: str
    hint: str = ""

    def render(self) -> str:
        """Multi-line human rendering (the form ``CheckReport.render``
        and ``Database.explain`` emit)."""
        out = f"{self.severity}[{self.code}] {self.node_path}: {self.message}"
        if self.hint:
            out += f"\n    hint: {self.hint}"
        return out

    def render_inline(self) -> str:
        """Single-line rendering (used for exception messages)."""
        out = f"{self.node_path}: {self.message}"
        if self.hint:
            out += f" (hint: {self.hint})"
        return out


@dataclass(frozen=True)
class CheckReport:
    """Ordered collection of diagnostics from one check pass."""

    diagnostics: Tuple[Diagnostic, ...]

    @property
    def errors(self) -> Tuple[Diagnostic, ...]:
        return tuple(d for d in self.diagnostics if d.severity == "error")

    @property
    def warnings(self) -> Tuple[Diagnostic, ...]:
        return tuple(d for d in self.diagnostics if d.severity == "warning")

    @property
    def ok(self) -> bool:
        """True when no *error*-severity diagnostic was produced (the
        compiled path is not statically doomed; warnings may remain)."""
        return not self.errors

    def codes(self) -> Tuple[str, ...]:
        return tuple(d.code for d in self.diagnostics)

    def render(self) -> str:
        if not self.diagnostics:
            return "ok (no diagnostics)"
        head = "ok" if self.ok else "rejected"
        lines = [
            f"{head}: {len(self.errors)} error(s), "
            f"{len(self.warnings)} warning(s)"
        ]
        lines += [d.render() for d in self.diagnostics]
        return "\n".join(lines)
