"""Static plan certification: *prove* plan properties from the compile
records instead of observing them at runtime.

``certify(compiled, env, ...)`` inspects a ``Compiled`` (or
``StreamedCompiled``) together with the environment it will run over and
emits a :class:`Certificate` asserting, section by section:

- ``reshard``: zero-unplanned-reshard execution — every committed input
  layout either equals the planned spec, or the move was recorded in the
  plan's rechunk stage (``Compiled.rechunks``, priced at plan time). The
  proof re-derives the committed-vs-planned comparison that
  ``Compiled.__call__`` performs dynamically (and warns about), so a CI
  lane can assert it *before* paying an execution.
- ``divisibility``: every sharded block dim of the effective input
  shardings divides by the mesh axes placed on it, and COO nnz padding
  targets are exactly the next shard multiple. Planner intents the
  sharding stage had to drop (replication fallbacks) are reported.
- ``coo``: owner-partition soundness of COO inputs — ``shard_offsets``
  monotone and consistent with the owner-key column (each shard's first
  real owner key matches its recorded offset).
- ``waves`` (streamed plans): re-derives ``plan_waves``' soundness as an
  independent cross-check — boundary monotonicity/coverage, owner-run
  alignment of COO wave cuts, and the resident+one-wave ≤ budget sizing.
- ``grad`` (when an FRA query + wrt names are given): RJP derivability
  per join side, ahead of compiling the gradient — ``full_rjp`` is False
  when some wrt input sits below a join whose side key is not solvable
  from its output key (the general partial-RJP fallback).
- ``kernels``: kernel-contract certification of every dispatch site the
  plan resolved (``certify_kernels`` — grid/write-race soundness, VJP
  pairing, predicate determinism; see ``analysis.kernelcheck``), cached
  on the underlying ``Lowered``.

The certificate is machine-readable (``to_dict``) and human-renderable
(``render``); the tier1-spmd / tier1-oocore CI lanes assert ``ok``.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import numpy as np

from ..core import fra
from ..core.keys import solve_left_key
from ..core.relation import COO_PAD_KEY, CooRelation, DenseRelation
from .typecheck import _mirror_join


@dataclass
class Certificate:
    """Machine-readable proof record for one compiled plan."""

    kind: str  # "in-core" | "streamed"
    reshard: Dict[str, object] = field(default_factory=dict)
    divisibility: Dict[str, object] = field(default_factory=dict)
    coo: Dict[str, object] = field(default_factory=dict)
    waves: Optional[Dict[str, object]] = None
    grad: Optional[Dict[str, object]] = None
    kernels: Optional[Dict[str, object]] = None

    @property
    def zero_unplanned_reshard(self) -> bool:
        return bool(self.reshard.get("proven_zero_unplanned", True))

    @property
    def ok(self) -> bool:
        parts = [
            self.zero_unplanned_reshard,
            self.divisibility.get("ok", True),
            self.coo.get("ok", True),
        ]
        if self.waves is not None:
            parts.append(self.waves.get("ok", False))
        if self.kernels is not None:
            parts.append(self.kernels.get("ok", True))
        return all(bool(p) for p in parts)

    def to_dict(self) -> Dict[str, object]:
        return {
            "kind": self.kind,
            "ok": self.ok,
            "reshard": self.reshard,
            "divisibility": self.divisibility,
            "coo": self.coo,
            "waves": self.waves,
            "grad": self.grad,
            "kernels": self.kernels,
        }

    def render(self) -> str:
        lines = [f"certificate ({self.kind}): {'OK' if self.ok else 'FAILED'}"]
        lines.append(
            "  zero-unplanned-reshard: "
            + ("proven" if self.zero_unplanned_reshard else "VIOLATED")
        )
        for name, rec in sorted(self.reshard.get("relations", {}).items()):
            lines.append(
                f"    {name}: {rec['status']} "
                f"(planned={rec['planned']}, committed={rec['committed']})"
            )
        lines.append(
            "  divisibility: "
            + ("ok" if self.divisibility.get("ok", True) else "VIOLATED")
        )
        for item in self.divisibility.get("fallbacks", []):
            lines.append(f"    fallback: {item}")
        lines.append("  coo: " + ("ok" if self.coo.get("ok", True) else "VIOLATED"))
        if self.waves is not None:
            w = self.waves
            lines.append(
                f"  waves: {'ok' if w.get('ok') else 'VIOLATED'} "
                f"(num_waves={w.get('num_waves')}, "
                f"max_wave_bytes={w.get('max_wave_bytes')}, "
                f"budget={w.get('budget')})"
            )
        if self.grad is not None:
            lines.append(
                "  grad: "
                + ("full RJP" if self.grad.get("full_rjp") else "partial RJP")
            )
            for jp, rec in sorted(self.grad.get("joins", {}).items()):
                lines.append(f"    {jp}: {rec}")
        if self.kernels is not None:
            k = self.kernels
            lines.append(
                f"  kernels: {'ok' if k.get('ok') else 'VIOLATED'} "
                f"({k.get('sites', 0)} dispatch site(s), "
                f"{k.get('errors', 0)} error(s))"
            )
            for code in k.get("codes", []):
                lines.append(f"    {code}")
        return "\n".join(lines)


def _spec_str(spec) -> Optional[str]:
    return None if spec is None else str(tuple(spec))


def _norm(spec):
    """Trailing-None-insensitive spec comparison key (mirrors
    ``engine._norm_spec`` independently)."""
    if spec is None:
        return ()
    t = tuple(spec)
    while t and t[-1] is None:
        t = t[:-1]
    return t


def _axes_total(mesh, ax) -> Optional[int]:
    sizes = dict(mesh.shape)
    axes = ax if isinstance(ax, tuple) else (ax,)
    total = 1
    for a in axes:
        if a not in sizes:
            return None
        total *= int(sizes[a])
    return total


def _certify_reshard(compiled, committed: Dict[str, object]) -> Dict[str, object]:
    relations: Dict[str, Dict[str, object]] = {}
    proven = True
    for name in sorted(compiled.input_specs):
        planned = compiled.planned_spec(name)
        have = committed.get(name)
        if have is None:
            status = "uncommitted"  # places for free; no bytes move
        elif _norm(have) == _norm(planned):
            status = "aligned"
        elif name in getattr(compiled, "rechunks", {}):
            status = "planned-rechunk"  # costed by the plan's rechunk stage
        else:
            status = "unplanned"
            proven = False
        relations[name] = {
            "planned": _spec_str(planned),
            "committed": _spec_str(have),
            "status": status,
        }
    return {"proven_zero_unplanned": proven, "relations": relations}


def _certify_divisibility(compiled, env) -> Dict[str, object]:
    mesh = compiled.mesh
    out: Dict[str, object] = {"ok": True, "relations": {}, "fallbacks": []}
    if mesh is None:
        return out
    for name, rel in env.items():
        planned = compiled.planned_spec(name)
        intent = compiled.input_specs.get(name)
        items = []
        if isinstance(rel, CooRelation):
            total = None
            if planned is not None and tuple(planned):
                total = _axes_total(mesh, tuple(planned)[0])
            if total and total > 1:
                nnz = int(rel.keys.shape[0])
                target = compiled.pad_nnz.get(name)
                padded = target if target is not None else nnz
                ok = padded % total == 0 and padded >= nnz
                if target is not None:
                    # padding must be the *next* shard multiple, no more
                    ok = ok and target == ((nnz + total - 1) // total) * total
                items.append(
                    {"dim": "nnz", "extent": nnz, "padded": padded,
                     "divisor": total, "ok": ok}
                )
                if not ok:
                    out["ok"] = False
        elif isinstance(rel, DenseRelation):
            eff = tuple(planned) if planned is not None else ()
            for d, ax in enumerate(eff):
                if ax is None or d >= rel.key_arity:
                    continue
                total = _axes_total(mesh, ax)
                if total is None or total <= 1:
                    continue
                extent = int(rel.data.shape[d])
                ok = extent % total == 0
                items.append(
                    {"dim": d, "axis": str(ax), "extent": extent,
                     "divisor": total, "ok": ok}
                )
                if not ok:
                    out["ok"] = False
            # intents the sharding stage dropped (replication fallback)
            for d, ax in enumerate(_norm(intent)):
                if ax is None or d >= rel.key_arity:
                    continue
                if d >= len(eff) or eff[d] != ax:
                    total = _axes_total(mesh, ax)
                    if total and total > 1:
                        out["fallbacks"].append(
                            f"{name} dim {d}: planner intent {ax!r} dropped "
                            f"(extent {int(rel.data.shape[d])} not divisible "
                            f"by {total}); replicated instead"
                        )
        if items:
            out["relations"][name] = items
    return out


def _certify_coo(env) -> Dict[str, object]:
    out: Dict[str, object] = {"ok": True, "relations": {}}
    for name, rel in env.items():
        if not isinstance(rel, CooRelation) or rel.shard_offsets is None:
            continue
        offs = np.asarray(rel.shard_offsets)
        owners = np.asarray(rel.keys)[:, rel.owner_dim]
        nnz = owners.shape[0]
        num = len(offs)
        rec = {"owner_dim": int(rel.owner_dim), "num_shards": num}
        rec["offsets_monotone"] = bool(np.all(np.diff(offs) >= 0))
        consistent = nnz % num == 0
        if consistent:
            per = nnz // num
            extent = int(rel.extents[rel.owner_dim])
            for s in range(num):
                first = owners[s * per]
                want = int(offs[s])
                if first == COO_PAD_KEY:
                    # all-pad shard: sentinel offset = owner extent
                    if want != extent:
                        consistent = False
                        break
                elif int(first) != want:
                    consistent = False
                    break
                # rows must be owner-sorted within/across shards
            real = owners[owners != COO_PAD_KEY]
            if consistent and real.size:
                consistent = bool(np.all(np.diff(real) >= 0))
        rec["offsets_consistent"] = bool(consistent)
        rec["ok"] = rec["offsets_monotone"] and rec["offsets_consistent"]
        if not rec["ok"]:
            out["ok"] = False
        out["relations"][name] = rec
    return out


def _certify_waves(streamed, env) -> Dict[str, object]:
    from ..core.planner import _rel_bytes

    plan = streamed.plan
    sizes = {name: _rel_bytes(rel) for name, rel in env.items()}
    streamed_names = set(plan.streamed_names)
    resident = sum(b for n, b in sizes.items() if n not in streamed_names)

    srel = env[plan.stream]
    rows = (
        int(srel.nnz)
        if isinstance(srel, CooRelation)
        else int(srel.extents[0])
    )
    b = tuple(plan.boundaries)
    boundaries_ok = (
        len(b) == plan.num_waves + 1
        and b[0] == 0
        and b[-1] == rows
        and all(b[i] < b[i + 1] for i in range(len(b) - 1))
    )

    # owner-run alignment: no COO Σ-segment may straddle a wave cut
    owner_aligned_ok = True
    if plan.owner_aligned and isinstance(srel, CooRelation):
        owners = np.asarray(srel.keys)[:, srel.owner_dim]
        for cut in b[1:-1]:
            if owners[cut - 1] == owners[cut] != COO_PAD_KEY:
                owner_aligned_ok = False
                break

    # independent sizing check, re-deriving plan_waves' invariant: the
    # moving bytes split across num_waves waves must fit the headroom
    # left by the resident relations (owner-aligned snapping can skew an
    # individual wave past the average — max_wave_bytes reports the
    # actual worst wave; co-streams slice by the stream's row fractions)
    moving = sum(sizes.get(n, 0.0) for n in plan.streamed_names)
    max_wave = 0.0
    for w in range(plan.num_waves):
        frac = (b[w + 1] - b[w]) / rows if rows else 0.0
        max_wave = max(max_wave, moving * frac)
    budget_ok = (
        plan.num_waves >= 2
        and resident + moving / plan.num_waves <= plan.budget + 1e-9
    )

    ok = boundaries_ok and owner_aligned_ok and budget_ok
    return {
        "ok": ok,
        "num_waves": int(plan.num_waves),
        "boundaries_ok": boundaries_ok,
        "owner_aligned_ok": owner_aligned_ok,
        "budget_ok": budget_ok,
        "resident_bytes": float(resident),
        "max_wave_bytes": float(max_wave),
        "budget": float(plan.budget),
    }


def certify_grad(query, wrt: Tuple[str, ...]) -> Dict[str, object]:
    """RJP grad-derivability report for ``wrt`` inputs of an FRA query,
    computable before any compile: per join (identified by a structural
    path), whether each side's input key is solvable from the output key
    (``solvable``) or needs the general partial-RJP fallback
    (``partial``). ``full_rjp`` is True iff no wrt input needs the
    fallback."""
    root = query.root if isinstance(query, fra.Query) else query
    wrt_set = set(wrt)
    joins: Dict[str, Dict[str, str]] = {}
    full = True

    def walk(n: fra.Node, prefix: str):
        label = {
            fra.TableScan: lambda: f"τ({n.name})",
            fra.Const: lambda: f"const({n.ref})",
            fra.Select: lambda: "σ",
            fra.Agg: lambda: "Σ",
            fra.Join: lambda: "⋈",
            fra.AddOp: lambda: "+",
        }.get(type(n), lambda: "restrict")()
        sep = "" if not prefix or prefix.endswith(":") else "/"
        path = prefix + sep + label
        if isinstance(n, fra.Join):
            nonlocal full
            la, ra = n.left.key_arity, n.right.key_arity
            mpred, mproj = _mirror_join(n.pred, n.proj)
            rec = {}
            for side, child, pred, proj, sa, oa in (
                ("left", n.left, n.pred, n.proj, la, ra),
                ("right", n.right, mpred, mproj, ra, la),
            ):
                below = {s.name for s in child.table_scans()} & wrt_set
                if not below:
                    rec[side] = "n/a"
                    continue
                solvable = solve_left_key(pred, proj, sa, oa) is not None
                rec[side] = "solvable" if solvable else "partial"
                if not solvable:
                    full = False
            joins[path] = rec
            walk(n.left, path + "/L:")
            walk(n.right, path + "/R:")
        else:
            for i, c in enumerate(n.children):
                p = path + ("/L:" if i == 0 else "/R:") if len(n.children) > 1 else path
                walk(c, p)

    walk(root, "")
    return {"full_rjp": full, "joins": joins}


def certify_kernels(compiled, *, recheck: bool = False):
    """Kernel-contract certification of the dispatch sites one compiled
    plan resolved (re-exported from :mod:`repro.analysis.kernelcheck`):
    grid/write-race soundness at the recorded shapes, VJP pairing,
    predicate determinism + resolution replay. Returns a
    :class:`~repro.analysis.diagnostics.CheckReport`."""
    from .kernelcheck import certify_kernels as _ck

    return _ck(compiled, recheck=recheck)


def _kernels_section(compiled) -> Dict[str, object]:
    from .kernelcheck import _lowered_of
    from .kernelcheck import certify_kernels as _ck

    report = _ck(compiled)
    resolutions = getattr(_lowered_of(compiled), "resolutions", {})
    return {
        "ok": report.ok,
        "sites": len(getattr(resolutions, "sites", ())),
        "errors": len(report.errors),
        "warnings": len(report.warnings),
        "codes": sorted(set(report.codes())),
    }


def certify(
    compiled,
    env: Dict[str, object],
    *,
    committed: Optional[Dict[str, object]] = None,
    query=None,
    wrt: Tuple[str, ...] = (),
) -> Certificate:
    """Certify a compiled plan against the environment it will execute.

    ``compiled`` is a ``Compiled`` or ``StreamedCompiled``; ``committed``
    optionally overrides the committed layouts (default: probed from
    ``env``'s arrays, exactly as ``compile_auto`` does); ``query``/``wrt``
    additionally attach the grad-derivability section."""
    from ..core.engine import Compiled, StreamedCompiled, _committed_layouts

    grad = None
    if query is not None:
        grad = certify_grad(query, wrt or getattr(query, "inputs", ()))
    kernels_section = _kernels_section(compiled)

    if isinstance(compiled, StreamedCompiled):
        cert = Certificate(kind="streamed", grad=grad, kernels=kernels_section)
        cert.waves = _certify_waves(compiled, env)
        cert.coo = _certify_coo(env)
        inner = getattr(compiled, "_inner", None)
        if inner is not None:
            # per-wave inner plan: streamed relations have no single
            # placement; certify the resident relations' shardings
            resident_env = {
                n: r for n, r in env.items()
                if n not in set(compiled.plan.streamed_names)
            }
            if inner.mesh is not None:
                have = committed
                if have is None:
                    have = _committed_layouts(resident_env)
                cert.reshard = _certify_reshard(inner, have)
                cert.divisibility = _certify_divisibility(inner, resident_env)
        return cert

    if not isinstance(compiled, Compiled):
        raise TypeError(f"cannot certify {type(compiled).__name__}")

    cert = Certificate(kind="in-core", grad=grad, kernels=kernels_section)
    if compiled.mesh is not None:
        have = committed if committed is not None else _committed_layouts(env)
        cert.reshard = _certify_reshard(compiled, have)
        cert.divisibility = _certify_divisibility(compiled, env)
    else:
        cert.reshard = {
            "proven_zero_unplanned": True,
            "relations": {},
            "reason": "mesh-less plan: no device_put stage, nothing can move",
        }
    cert.coo = _certify_coo(env)
    return cert
