"""Static analysis over FRA programs and compiled plans.

Three layers, all ahead of (or independent of) execution:

- ``diagnostics``: the shared :class:`Diagnostic` / :class:`CheckReport`
  record types (severity, node path, message, fix hint) used by the
  typed checker, the SQL front end, and ``Database.explain``.
- ``typecheck``: bottom-up schema/shape/dtype inference over an FRA
  graph — ``check_query`` returns a :class:`CheckReport`; the engine
  runs it as a mandatory validate stage between ``RAEngine.lower`` and
  the rewrite stage, and ``db.check(q)`` exposes it directly.
- ``certify``: static certificates over a ``Compiled`` /
  ``StreamedCompiled`` plan — zero-unplanned-reshard, sharded-dim
  divisibility, COO owner-partition soundness, wave soundness, and
  partial-RJP grad derivability, proven from the plan records rather
  than observed from runtime counters.
- ``kernelcheck``: static certification of the kernel dispatch registry
  against the packages' declared ``KernelContract``s — grid/write-race
  soundness of the Pallas BlockSpecs, VJP tier pairing, and dispatch-
  predicate determinism; ``certify_kernels`` proves exactly the sites a
  compiled plan resolved, ``certify_registry`` sweeps the whole registry
  (the CI lint lane runs ``python -m repro.analysis.kernelcheck``).
"""

from .diagnostics import CheckReport, Diagnostic
from .typecheck import ValidationError, check_query
from .certify import Certificate, certify, certify_kernels
from .kernelcheck import certify_registry

__all__ = [
    "CheckReport",
    "Diagnostic",
    "ValidationError",
    "check_query",
    "Certificate",
    "certify",
    "certify_kernels",
    "certify_registry",
]
