"""Static certification of the kernel dispatch registry.

``core/kernels.py`` routes the engine's hardware hot spots (Σ-over-COO
segment-sum, gather-join, blocked matmul) through registered
``KernelImpl`` tiers, and every kernel package declares a
:class:`~repro.core.kernels.KernelContract` — dtype domain, masking
obligations, accumulator dtype, the dispatch ops its VJP re-enters, and a
``grid_model`` mapping a dispatch site to the exact Pallas launch
geometry. This module *proves* the registry sound against those
contracts, per impl and shape class, before anything runs:

- **grid/write-race soundness** — abstract interpretation of the grid +
  BlockSpec index maps: every output block is stored by exactly one
  program instance (``grid-race`` / ``grid-uncovered``), all index maps
  stay inside the padded arrays (``grid-oob-index``), reduction axes are
  the innermost grid suffix (``grid-reduction-order``), and VMEM
  accumulators are zeroed before first use (``uninit-accumulator``) —
  including the ``COO_PAD_KEY`` padded rows and non-divisible extents,
  because the models mirror the ops.py wrappers' padding.
- **VJP pairing** — every hardware forward tier re-enters its declared
  backward ops at the *same* tier, and that backward has a registered
  impl whose backend/predicate domain covers the forward's
  (``unpaired-vjp`` / ``vjp-domain-gap``): no site where the gradient
  silently falls to a different tier than ``Compiled.resolutions``
  recorded.
- **predicate determinism** — dispatch predicates are pure functions of
  the site-info dict (``flappy-predicate``); ``certify_kernels``
  additionally replays every recorded ``SiteRecord`` through
  ``resolve_impl`` and flags resolution drift, turning the retrace-desync
  hazard documented on ``KernelImpl`` into a checked invariant.

Two entry points: :func:`certify_registry` sweeps the whole registry over
representative shape classes (the CI lint lane runs ``python -m
repro.analysis.kernelcheck``); :func:`certify_kernels` certifies exactly
the sites one ``Compiled``/``Lowered`` resolved, at their recorded
site-info dicts, and caches the report on the ``Lowered`` (which the
engine already caches per ``(sig, dispatch, rewrite)`` key) so repeated
``db.explain``/``certify`` calls — and the hot path itself — pay nothing.

The dynamic twin is the ``sanitizer`` dispatch tier (core/kernels.py):
the same grid models interpreted concretely at runtime, raising
``SanitizerError`` with these diagnostic codes as ``kind``.
"""

from __future__ import annotations

from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

import jax.numpy as jnp

from repro.core import kernels as K

from .diagnostics import CheckReport, Diagnostic

__all__ = [
    "certify_kernels",
    "certify_registry",
    "check_contract_grid",
    "check_impl",
    "default_shape_classes",
    "main",
]

#: tiers whose custom VJP re-enters dispatch ops physically (the jnp/ref
#: tiers differentiate through plain jnp and need no pairing proof).
_HARDWARE_TIERS: Tuple[str, ...] = ("pallas", "interpret", "sanitizer")

#: backends a backend-unrestricted impl is certified under.
_BACKENDS: Tuple[str, ...] = ("cpu", "tpu")


def default_shape_classes(op: str) -> Tuple[Dict[str, Any], ...]:
    """Representative site-info dicts per op: tile-exact shapes, ragged
    shapes that exercise the pad-and-mask path (``COO_PAD_KEY`` rows,
    non-divisible extents), a single-tile degenerate, and an integer
    dtype (admitted by the jnp/ref tiers only)."""
    f32, i32 = jnp.dtype("float32"), jnp.dtype("int32")
    if op == "segment_sum":
        return (
            {"nnz": 512, "dim": 128, "num_segments": 128, "dtype": f32},
            {"nnz": 1000, "dim": 96, "num_segments": 300, "dtype": f32},
            {"nnz": 7, "dim": 3, "num_segments": 5, "dtype": f32},
            {"nnz": 1024, "dim": 64, "num_segments": 256, "dtype": i32},
        )
    if op == "blocked_matmul":
        return (
            {"m": 128, "k": 128, "n": 128, "dtype": f32},
            {"m": 200, "k": 384, "n": 72, "dtype": f32},
            {"m": 7, "k": 5, "n": 3, "dtype": f32},
            {"m": 64, "k": 64, "n": 64, "dtype": i32},
        )
    if op == "gather_join":
        return (
            {"rows": 512, "num_rows": 128, "dim": 64, "dtype": f32},
            {"rows": 1000, "num_rows": 300, "dim": 96, "dtype": f32},
            {"rows": 7, "num_rows": 5, "dim": 3, "dtype": f32},
            {"rows": 256, "num_rows": 64, "dim": 32, "dtype": i32},
        )
    if op == "ssm_scan":
        return (
            {"batch": 2, "seq": 512, "channels": 16, "state": 4, "dtype": f32},
            {"batch": 1, "seq": 12, "channels": 6, "state": 4, "dtype": f32},
            {"batch": 3, "seq": 7, "channels": 5, "state": 2, "dtype": f32},
        )
    return ()


def _site_label(op: str, info: Dict[str, Any]) -> str:
    """The compiler's site label for an info dict (compiler._note)."""
    if op == "segment_sum":
        return f"E={info['nnz']},D={info['dim']},S={info['num_segments']}"
    if op == "blocked_matmul":
        return f"m={info['m']},k={info['k']},n={info['n']}"
    if op == "gather_join":
        return f"E={info['rows']},N={info['num_rows']},D={info['dim']}"
    return ",".join(f"{k}={v}" for k, v in sorted(info.items()) if k != "dtype")


_HINTS = {
    "grid-race": "make the output index map injective over the non-reduction "
    "grid axes, or store from an accumulator at the reduction axis' last step",
    "grid-uncovered": "the output index map must reach every "
    "ceil(shape/block) block of the (padded) output array",
    "grid-oob-index": "pad the operand to a block multiple in the ops.py "
    "wrapper (and mirror the padding in the contract's grid_model)",
    "grid-reduction-order": "move the reduction/sweep axes to the end of the "
    "grid tuple — the TPU grid runs sequentially with the last axis fastest",
    "uninit-accumulator": "zero the VMEM scratch at the reduction axis' step "
    "0 (pl.when(pl.program_id(axis) == 0))",
}


def _grid_diags(
    op: str, model: Optional[K.GridModel], node_path: str
) -> List[Diagnostic]:
    if model is None:
        return []
    return [
        Diagnostic(
            severity="error",
            code=kind,
            node_path=node_path,
            message=detail,
            hint=_HINTS.get(kind, ""),
        )
        for kind, detail in K.simulate_grid(model)
    ]


def check_contract_grid(
    op: str,
    contract: K.KernelContract,
    infos: Sequence[Dict[str, Any]],
    node_path: Optional[str] = None,
) -> List[Diagnostic]:
    """Grid/write-race soundness of ``contract.grid_model`` over the
    given shape classes (floating classes only — the hardware tiers'
    domain, which is what the model describes)."""
    diags: List[Diagnostic] = []
    for info in infos:
        if contract.dtypes == "floating" and not K._is_float(info):
            continue
        path = node_path or f"registry:{op}[{_site_label(op, info)}]"
        diags += _grid_diags(op, contract.grid_model(dict(info)), path)
    return diags


def _predicate_diags(
    impl: K.KernelImpl, infos: Sequence[Dict[str, Any]], node_path: str
) -> List[Diagnostic]:
    """Predicate determinism: two evaluations on independently built but
    equal info dicts must agree — a stateful (call-counting, clock- or
    RNG-reading) predicate flips somewhere across the double sweep."""
    if impl.predicate is None:
        return []
    try:
        first = [bool(impl.predicate(dict(info))) for info in infos]
        second = [bool(impl.predicate(dict(info))) for info in infos]
    except Exception as exc:  # a raising predicate can never be replayed
        return [
            Diagnostic(
                severity="error",
                code="flappy-predicate",
                node_path=node_path,
                message=f"predicate raised {type(exc).__name__}: {exc}",
                hint="dispatch predicates must be total pure functions of "
                "the site-info dict",
            )
        ]
    diags = []
    for info, a, b in zip(infos, first, second):
        if a != b:
            diags.append(
                Diagnostic(
                    severity="error",
                    code="flappy-predicate",
                    node_path=node_path,
                    message=(
                        f"predicate is not a pure function of the site info: "
                        f"two evaluations at {_site_label(impl.op, info)} "
                        f"returned {a} then {b} — resolution would desync "
                        "from the lowering cache key on retrace"
                    ),
                    hint="derive the decision only from the info dict "
                    "(shapes/dtype); hoist any state into the DispatchTable",
                )
            )
    return diags


def _vjp_diags(
    impl: K.KernelImpl,
    contract: K.KernelContract,
    infos: Sequence[Dict[str, Any]],
    node_path: str,
) -> List[Diagnostic]:
    """VJP pairing: each declared backward op must have a registered impl
    *at the forward's tier* whose backend + predicate domain covers every
    site the forward accepts."""
    if impl.tier not in _HARDWARE_TIERS or not contract.vjp_pairs:
        return []
    backends = impl.backends or _BACKENDS
    diags: List[Diagnostic] = []
    for pair in contract.vjp_pairs:
        bucket = K._IMPLS.get((pair.op, impl.tier), ())
        if not bucket:
            diags.append(
                Diagnostic(
                    severity="error",
                    code="unpaired-vjp",
                    node_path=node_path,
                    message=(
                        f"backward re-enters {pair.op!r} at tier "
                        f"{impl.tier!r} but no impl is registered there"
                    ),
                    hint=f"register_impl({pair.op!r}, {impl.tier!r}, ...) "
                    "or change the contract's vjp_pairs",
                )
            )
            continue
        for info in infos:
            if impl.predicate is not None and not impl.predicate(dict(info)):
                continue  # the forward never fires here
            binfo = pair.info_map(dict(info))
            for backend in backends:
                covered = any(
                    (not b.backends or backend in b.backends)
                    and (b.predicate is None or b.predicate(dict(binfo)))
                    for b in bucket
                )
                if not covered:
                    diags.append(
                        Diagnostic(
                            severity="error",
                            code="vjp-domain-gap",
                            node_path=node_path,
                            message=(
                                f"forward accepts "
                                f"{_site_label(impl.op, info)} on "
                                f"{backend} but its backward "
                                f"{pair.op!r}@{impl.tier} rejects the "
                                f"cotangent site "
                                f"{_site_label(pair.op, binfo)} — the "
                                "gradient would fall to a different tier "
                                "than Compiled.resolutions recorded"
                            ),
                            hint="widen the backward impl's predicate/"
                            "backends to cover the forward's domain",
                        )
                    )
                    break  # one gap per (pair, info) is enough
    return diags


def _dtype_diags(
    impl: K.KernelImpl,
    contract: K.KernelContract,
    infos: Sequence[Dict[str, Any]],
    node_path: str,
) -> List[Diagnostic]:
    """Hardware tiers must not accept sites outside the contract's dtype
    domain (the kernels accumulate in f32 and store the input dtype —
    integer inputs would round-trip through float silently)."""
    if impl.tier not in _HARDWARE_TIERS or contract.dtypes != "floating":
        return []
    diags = []
    for info in infos:
        if K._is_float(info):
            continue
        if impl.predicate is None or impl.predicate(dict(info)):
            diags.append(
                Diagnostic(
                    severity="error",
                    code="dtype-domain",
                    node_path=node_path,
                    message=(
                        f"tier {impl.tier!r} admits dtype "
                        f"{jnp.dtype(info['dtype'])} at "
                        f"{_site_label(impl.op, info)} but the contract's "
                        "domain is floating (f32 accumulate + store-input-"
                        "dtype would silently round-trip integers)"
                    ),
                    hint="gate the impl with a floating predicate "
                    "(kernels._is_float) or widen the contract",
                )
            )
            break
    return diags


def check_impl(
    impl: K.KernelImpl,
    contract: K.KernelContract,
    infos: Sequence[Dict[str, Any]],
) -> List[Diagnostic]:
    """All per-impl checks: predicate determinism, dtype domain, VJP
    pairing."""
    node_path = f"registry:{impl.op}:{impl.tier}"
    return (
        _predicate_diags(impl, infos, node_path)
        + _dtype_diags(impl, contract, infos, node_path)
        + _vjp_diags(impl, contract, infos, node_path)
    )


def _missing_contract(op: str) -> Diagnostic:
    return Diagnostic(
        severity="error",
        code="missing-contract",
        node_path=f"registry:{op}",
        message=f"dispatch op {op!r} has no KernelContract",
        hint="declare CONTRACT next to the registration in the kernel "
        "package's ops.py and map it in kernels._CONTRACT_MODULES",
    )


def certify_registry(
    ops: Optional[Iterable[str]] = None,
    shape_classes: Optional[Dict[str, Sequence[Dict[str, Any]]]] = None,
) -> CheckReport:
    """Certify the full registry (or ``ops``) over representative shape
    classes: contract grid soundness once per (op, class), then every
    registered impl's determinism / dtype-domain / VJP-pairing checks."""
    diags: List[Diagnostic] = []
    for op in ops if ops is not None else K.DISPATCH_OPS:
        try:
            contract = K.kernel_contract(op)
        except KeyError:
            diags.append(_missing_contract(op))
            continue
        infos = tuple(
            (shape_classes or {}).get(op) or default_shape_classes(op)
        )
        diags += check_contract_grid(op, contract, infos)
        for tier in K.DISPATCH_TIERS:
            for impl in K._IMPLS.get((op, tier), ()):
                diags += check_impl(impl, contract, infos)
    # contract-only kernels (ssm_scan): grid proof without registry entries
    for op in set(K.contract_ops()) - set(K.DISPATCH_OPS):
        if ops is not None and op not in ops:
            continue
        diags += check_contract_grid(op, K.kernel_contract(op), default_shape_classes(op))
    return CheckReport(tuple(diags))


def _lowered_of(compiled: Any):
    """Accept a Compiled, StreamedCompiled, or Lowered."""
    inner = getattr(compiled, "_inner", None)
    if inner is not None:  # StreamedCompiled wraps a per-wave Compiled
        compiled = inner
    return getattr(compiled, "lowered", compiled)


def certify_kernels(compiled: Any, *, recheck: bool = False) -> CheckReport:
    """Certify exactly the kernels one compiled plan resolved.

    For every ``SiteRecord`` the lowering walk logged (op, site-info
    snapshot, chosen tier) this (1) replays ``resolve_impl`` on the
    snapshot against the plan's DispatchTable and flags any drift from
    the recorded tier (``flappy-predicate`` — the retrace-desync hazard,
    now checked), (2) proves the contract's grid model sound *at the
    site's actual shapes*, and (3) runs the per-impl dtype/determinism/
    VJP-pairing checks for every op the plan touched. The report is
    cached on the ``Lowered`` (itself cached per ``(sig, dispatch,
    rewrite)``), so certification adds zero hot-path cost; ``recheck``
    forces a fresh pass (tests that mutate contracts underneath).
    """
    lowered = _lowered_of(compiled)
    cached = getattr(lowered, "_kernel_report", None)
    if cached is not None and not recheck:
        return cached
    table = getattr(lowered, "dispatch", None) or K.default_table()
    resolutions = getattr(lowered, "resolutions", {})
    sites: Sequence[K.SiteRecord] = getattr(resolutions, "sites", ())

    diags: List[Diagnostic] = []
    infos_by_op: Dict[str, List[Dict[str, Any]]] = {}
    for rec in sites:
        info = rec.info_dict()
        infos_by_op.setdefault(rec.op, []).append(info)
        node_path = f"dispatch:{rec.key}"
        try:
            replayed = K.resolve_impl(rec.op, dict(info), table)
        except K.KernelDispatchError as exc:
            diags.append(
                Diagnostic(
                    severity="error",
                    code="flappy-predicate",
                    node_path=node_path,
                    message=f"recorded tier {rec.tier!r} no longer resolves: {exc}",
                    hint="dispatch predicates must be pure functions of the "
                    "site-info dict",
                )
            )
            continue
        if replayed.tier != rec.tier:
            diags.append(
                Diagnostic(
                    severity="error",
                    code="flappy-predicate",
                    node_path=node_path,
                    message=(
                        f"lowering resolved tier {rec.tier!r} but replaying "
                        f"the recorded site info resolves {replayed.tier!r} "
                        "— a stateful predicate desyncs retraces from the "
                        "lowering cache key"
                    ),
                    hint="derive the decision only from the info dict; "
                    "hoist any state into the DispatchTable",
                )
            )
        try:
            contract = K.kernel_contract(rec.op)
        except KeyError:
            diags.append(_missing_contract(rec.op))
            continue
        diags += check_contract_grid(rec.op, contract, [info], node_path=node_path)

    for op, infos in sorted(infos_by_op.items()):
        try:
            contract = K.kernel_contract(op)
        except KeyError:
            continue  # already reported per site
        for tier in table.tiers(op):
            for impl in K._IMPLS.get((op, tier), ()):
                diags += check_impl(impl, contract, infos)

    report = CheckReport(tuple(diags))
    if getattr(lowered, "dispatch", None) is not None:
        # cache only on a real Lowered — a StreamedCompiled whose inner
        # plan has not materialized yet must not pin an empty report
        lowered._kernel_report = report
    return report


def main(argv: Optional[Sequence[str]] = None) -> int:
    """CLI for the CI lint lane: certify the full registry, print the
    report, exit non-zero on any error-severity diagnostic."""
    import argparse

    parser = argparse.ArgumentParser(
        prog="repro.analysis.kernelcheck",
        description="statically certify the kernel dispatch registry",
    )
    parser.add_argument(
        "ops", nargs="*", help="ops to certify (default: the full registry)"
    )
    ns = parser.parse_args(argv)
    report = certify_registry(ns.ops or None)
    n_impls = sum(
        len(K._IMPLS.get((op, tier), ()))
        for op in K.DISPATCH_OPS
        for tier in K.DISPATCH_TIERS
    )
    print(
        f"kernelcheck: {len(K.DISPATCH_OPS)} dispatch op(s), "
        f"{n_impls} registered impl(s), "
        f"{len(K.contract_ops())} contract(s)"
    )
    print(report.render())
    return 0 if report.ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
