"""Typed checker for FRA queries (bottom-up schema/shape/dtype inference).

``check_query`` walks the graph leaves-first, inferring for every node a
:class:`RelType` — layout kind (dense/COO), key arity, per-component
extents and provenance labels, value dtype — and emitting
:class:`~repro.analysis.diagnostics.Diagnostic` records along the way.

Severity contract: an ``error`` diagnostic means the chunked compiler is
*guaranteed* to reject (or crash on) the query — every error rule
mirrors a concrete raise site in ``core/compiler.py`` (the rule codes
below cite them). ``warning`` marks executable hazards: implicit dtype
promotion (f32→f64), statically empty selections, stale catalog
statistics, non-divisible sharded extents, and joins whose gradient
falls back to the general partial-RJP path.

The engine runs this as a mandatory validate stage between
``RAEngine.lower`` and the rewrite stage (raising :class:`ValidationError`
on errors); ``db.check(q)`` / ``QueryHandle.check()`` expose the full
report, and ``Database.explain`` renders it.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..core import fra
from ..core.keys import (
    In,
    JoinPred,
    JoinProj,
    L,
    Lit,
    R,
    join_equiv_classes,
    solve_left_key,
)
from ..core.relation import CooRelation, DenseRelation
from .diagnostics import CheckReport, Diagnostic


class ValidationError(ValueError):
    """Raised by the engine's validate stage when the typed checker
    produces error-severity diagnostics. Carries the full report."""

    def __init__(self, report: CheckReport):
        self.report = report
        super().__init__(
            "query rejected by the validate stage:\n" + report.render()
        )


@dataclass
class RelType:
    """Inferred relation type for one node: layout kind, key arity,
    per-component extents (None = unknown), provenance labels (where each
    key component originated, e.g. ``edges[0]``), and value dtype."""

    kind: str  # "dense" | "coo" | "unknown"
    key_arity: int
    extents: Tuple[Optional[int], ...]
    labels: Tuple[str, ...]
    dtype: Optional[np.dtype]


def _label(name: str, i: int, schema) -> str:
    attrs = (schema or {}).get(name)
    if attrs is not None and i < len(attrs):
        return f"{name}.{attrs[i]}"
    return f"{name}[{i}]"


def _unknown(arity: int) -> RelType:
    return RelType(
        "unknown", arity, (None,) * arity, tuple(f"?[{i}]" for i in range(arity)), None
    )


def _mirror_join(pred: JoinPred, proj: JoinProj) -> Tuple[JoinPred, JoinProj]:
    """Swap the L/R sides of a join's key functions (for solving the
    *right* input's RJP key with ``solve_left_key``)."""

    def sw(c):
        if isinstance(c, L):
            return R(c.idx)
        if isinstance(c, R):
            return L(c.idx)
        return c

    return (
        JoinPred(tuple((sw(a), sw(b)) for a, b in pred.eqs)),
        JoinProj(tuple(sw(c) for c in proj.comps)),
    )


def check_query(
    query,
    env: Optional[Dict[str, object]] = None,
    *,
    stats: Optional[Dict[str, object]] = None,
    schema: Optional[Dict[str, Tuple[str, ...]]] = None,
    geometry=None,
    wrt: Tuple[str, ...] = (),
    fuse_join_agg: bool = True,
) -> CheckReport:
    """Statically check an FRA query (``fra.Query`` or bare ``fra.Node``).

    ``env`` maps relation names to concrete or abstract relations (shapes
    and dtypes; ``jax.ShapeDtypeStruct`` leaves are fine); ``stats`` is a
    catalog ``RelationStats`` snapshot for key-domain soundness;
    ``schema`` maps relation names to key-attribute-name tuples (SQL
    catalogs) for readable provenance labels; ``geometry`` is a planner
    ``MeshGeometry`` for sharded-extent divisibility warnings; ``wrt``
    names gradient inputs for partial-RJP derivability warnings (the
    query's own ``inputs`` are used when it is a ``fra.Query``).
    ``fuse_join_agg`` mirrors the engine flag (a Σ directly over a ⋈ is
    checked as the fused form)."""
    root = query.root if isinstance(query, fra.Query) else query
    if isinstance(query, fra.Query) and not wrt:
        wrt = query.inputs
    wrt_set = set(wrt)
    diags: List[Diagnostic] = []
    memo: Dict[int, RelType] = {}

    def emit(severity, code, path, message, hint=""):
        diags.append(Diagnostic(severity, code, path, message, hint))

    def err(code, path, message, hint=""):
        emit("error", code, path, message, hint)

    def warn(code, path, message, hint=""):
        emit("warning", code, path, message, hint)

    def _dtype_of(rel):
        arr = rel.values if isinstance(rel, CooRelation) else getattr(rel, "data", None)
        try:
            return np.dtype(arr.dtype) if arr is not None else None
        except TypeError:
            return None

    def _promotion(lt: RelType, rt: RelType, path: str, what: str):
        if lt.dtype is None or rt.dtype is None or lt.dtype == rt.dtype:
            return lt.dtype or rt.dtype
        out = np.promote_types(lt.dtype, rt.dtype)
        f32_to_f64 = out == np.float64 and np.float32 in (lt.dtype, rt.dtype)
        warn(
            "dtype-promotion",
            path,
            f"{what} mixes {lt.dtype} and {rt.dtype}; the result silently "
            f"promotes to {out}" + (" (f32→f64 upcast)" if f32_to_f64 else ""),
            "cast the wider operand down (e.g. .astype(np.float32)) or "
            "accept the promotion explicitly",
        )
        return out

    def _scan(name: str, node: fra.Node, path: str) -> RelType:
        if name.startswith("__"):  # cached forward intermediates (grad graphs)
            return _unknown(node.key_arity)
        labels = tuple(_label(name, i, schema) for i in range(node.key_arity))
        if env is None or name not in env:
            if env is not None:
                err(
                    "unknown-relation",
                    path,
                    f"relation {name!r} is not defined in the environment",
                    "db.put(...) the relation (or declare it) before "
                    "checking/lowering the query",
                )
            t = _unknown(node.key_arity)
            return RelType(t.kind, t.key_arity, t.extents, labels, None)
        rel = env[name]
        arity = getattr(rel, "key_arity", node.key_arity)
        if arity != node.key_arity:
            err(
                "arity-mismatch",
                path,
                f"scan declares key arity {node.key_arity} but relation "
                f"{name!r} has key arity {arity}",
                "match the scan's arity to the stored relation",
            )
            return RelType("unknown", node.key_arity, (None,) * node.key_arity, labels, None)
        rel_ext = getattr(rel, "extents", None)
        if rel_ext is None:
            return RelType("unknown", arity, (None,) * arity, labels, _dtype_of(rel))
        extents = tuple(int(e) for e in rel_ext[:arity])
        if stats and name in stats:
            st_ext = tuple(int(e) for e in stats[name].extents[:arity])
            if st_ext != extents:
                warn(
                    "stale-stats",
                    path,
                    f"catalog statistics for {name!r} record extents "
                    f"{st_ext} but the relation has {extents}",
                    "refresh with db.put (stats are re-measured on put) "
                    "before planning against them",
                )
        kind = "coo" if isinstance(rel, CooRelation) else "dense"
        return RelType(kind, arity, extents, labels, _dtype_of(rel))

    def _select(n: fra.Select, path: str) -> RelType:
        ct = visit(n.child, path)
        a = ct.key_arity

        def comp_ok(c, what) -> bool:
            if isinstance(c, Lit):
                return True
            if not (0 <= c.idx < a):
                err(
                    "bad-key-index",
                    path,
                    f"{what} references key component {c.idx} but the "
                    f"input has arity {a}",
                    "key components are 0-indexed over the child's key",
                )
                return False
            return True

        for i, v in n.pred.eqs:
            if not (0 <= i < a):
                err(
                    "bad-key-index",
                    path,
                    f"σ predicate fixes key component {i} but the input "
                    f"has arity {a}",
                    "key components are 0-indexed over the child's key",
                )
            elif ct.extents[i] is not None and not (0 <= v < ct.extents[i]):
                warn(
                    "empty-selection",
                    path,
                    f"σ fixes {ct.labels[i]} == {v} but its domain is "
                    f"[0, {ct.extents[i]}); the selection is statically empty",
                    "check the literal against the relation's key domain",
                )
        if ct.kind == "coo":
            if not n.pred.always_true:
                err(
                    "coo-predicate",
                    path,
                    "predicated σ over a COO relation is not compilable "
                    "(compiler: 'predicated σ over COO not supported')",
                    "materialize the relation densely or filter at load time",
                )
            for c in n.proj.comps:
                if isinstance(c, Lit):
                    err(
                        "literal-projection",
                        path,
                        "Lit component in a σ projection over COO is not "
                        "compilable",
                        "project only existing key columns over COO",
                    )
                else:
                    comp_ok(c, "σ projection")
        elif ct.kind == "dense":
            if n.pred.custom is not None:
                err(
                    "custom-predicate",
                    path,
                    "custom σ predicates are interpreter-only "
                    "(compiler: 'custom σ predicate not compilable')",
                    "express the predicate as key equalities "
                    "(SelPred(eqs=...)) or run via the interpreter",
                )
            fixed = {i for i, _ in n.pred.eqs}
            proj_idx = []
            for c in n.proj.comps:
                if isinstance(c, Lit):
                    err(
                        "literal-projection",
                        path,
                        "Lit component in a σ projection over dense is not "
                        "compilable",
                        "introduce literal key components via a join "
                        "projection under a Σ instead",
                    )
                    continue
                if not comp_ok(c, "σ projection"):
                    continue
                if c.idx in fixed:
                    err(
                        "projects-fixed",
                        path,
                        f"σ projects key component {c.idx} which the "
                        "predicate fixes to a literal (the compiler slices "
                        "fixed components away)",
                        "drop the fixed component from the projection",
                    )
                    continue
                proj_idx.append(c.idx)
            remaining = [i for i in range(a) if i not in fixed]
            if sorted(proj_idx) != remaining:
                err(
                    "non-permutation",
                    path,
                    f"σ projection keeps components {sorted(proj_idx)} but "
                    f"must permute exactly the surviving components "
                    f"{remaining} (dense σ cannot drop or duplicate keys)",
                    "aggregate (Σ) to drop key components; permutations "
                    "only in σ",
                )
        out_ext, out_lab = [], []
        for c in n.proj.comps:
            if isinstance(c, Lit) or not (0 <= c.idx < a):
                out_ext.append(None)
                out_lab.append("lit" if isinstance(c, Lit) else "?")
            else:
                out_ext.append(ct.extents[c.idx])
                out_lab.append(ct.labels[c.idx])
        return RelType(ct.kind, n.key_arity, tuple(out_ext), tuple(out_lab), ct.dtype)

    def _agg(n: fra.Agg, path: str) -> RelType:
        fused = isinstance(n.child, fra.Join) and fuse_join_agg
        if fused:
            ct = _join(n.child, path + "/⋈", grp=n.grp)
        else:
            ct = visit(n.child, path)
        if not n.kernel.is_add:
            err(
                "non-additive-agg",
                path,
                f"Σ kernel ⊕{n.kernel.name} is not additive; the compiler "
                "supports only additive aggregation "
                "(compiler: 'non-additive Σ not supported')",
                "use the interpreter for max-style aggregates, or rewrite "
                "as additive Σ",
            )
        a = ct.key_arity
        comps = n.grp.comps
        lits = [c for c in comps if isinstance(c, Lit)]
        if lits:
            err(
                "literal-group",
                path,
                "Lit components in a Σ grouping are not compilable "
                "(compiler: 'mixed Lit grp' / 'Lit grp over COO')",
                "group by existing key components; a full reduce is "
                "grp=KeyFn(())",
            )
        idxs = [c.idx for c in comps if isinstance(c, In)]
        for i in idxs:
            if not (0 <= i < a):
                err(
                    "bad-key-index",
                    path,
                    f"Σ grouping references key component {i} but the "
                    f"input has arity {a}",
                    "key components are 0-indexed over the child's key",
                )
        if ct.kind != "coo" and len(set(idxs)) != len(idxs):
            err(
                "duplicate-group",
                path,
                "duplicate Σ grouping components over a dense input "
                "(compiler: 'duplicate grp components over dense')",
                "group by each key component at most once; duplicates "
                "are only meaningful over COO inputs",
            )
        out_ext = tuple(
            ct.extents[c.idx] if isinstance(c, In) and 0 <= c.idx < a else None
            for c in comps
        )
        out_lab = tuple(
            ct.labels[c.idx] if isinstance(c, In) and 0 <= c.idx < a else "lit"
            for c in comps
        )
        return RelType("dense", n.key_arity, out_ext, out_lab, ct.dtype)

    def _join(n: fra.Join, path: str, grp=None) -> RelType:
        lt = visit(n.left, path + "/L:")
        rt = visit(n.right, path + "/R:")
        la, ra = n.left.key_arity, n.right.key_arity
        coo_side = "coo" in (lt.kind, rt.kind)
        if lt.kind == "coo" and rt.kind == "coo":
            err(
                "coo-coo-join",
                path,
                "COO ⋈ COO is not compilable "
                "(compiler: 'COO ⋈ COO not supported')",
                "densify one operand, or restructure so each join has at "
                "most one sparse side",
            )

        def side_t(c):
            return lt if isinstance(c, L) else rt

        def comp_ok(c, what) -> bool:
            if isinstance(c, Lit):
                return True
            arity = la if isinstance(c, L) else ra
            if not (0 <= c.idx < arity):
                err(
                    "bad-key-index",
                    path,
                    f"{what} references {'left' if isinstance(c, L) else 'right'} "
                    f"key component {c.idx} but that side has arity {arity}",
                    "key components are 0-indexed per join side",
                )
                return False
            return True

        has_lit_pred = False
        same_side_pairs = False
        for a, b in n.pred.eqs:
            comp_ok(a, "⋈ predicate")
            comp_ok(b, "⋈ predicate")
            if isinstance(a, Lit) or isinstance(b, Lit):
                has_lit_pred = True
                lit, other = (a, b) if isinstance(a, Lit) else (b, a)
                if not isinstance(other, Lit):
                    t = side_t(other)
                    if (
                        0 <= other.idx < t.key_arity
                        and t.extents[other.idx] is not None
                        and not (0 <= lit.val < t.extents[other.idx])
                    ):
                        warn(
                            "empty-selection",
                            path,
                            f"⋈ predicate fixes {t.labels[other.idx]} == "
                            f"{lit.val} outside its domain "
                            f"[0, {t.extents[other.idx]}); the join is "
                            "statically empty",
                            "check the literal against the key domain",
                        )
            elif type(a) is type(b):
                same_side_pairs = True
        if has_lit_pred:
            if coo_side:
                err(
                    "literal-join-pred",
                    path,
                    "literal ⋈ predicates over a COO operand are not "
                    "compilable (compiler: 'literal predicates on COO "
                    "joins not supported')",
                    "σ-select the dense side before joining instead",
                )
            else:
                emit(
                    "info",
                    "literal-join-pred",
                    path,
                    "literal ⋈ predicate over dense operands falls off the "
                    "einsum fast path (aligned/broadcast fallback)",
                    "σ-select before joining to stay on the einsum path",
                )
        if same_side_pairs and coo_side:
            err(
                "same-side-equality",
                path,
                "an L-L / R-R equality (diagonal) is not compilable over a "
                "COO operand",
                "pre-apply the diagonal with a σ on the dense side",
            )

        # join-key compatibility: members of one equivalence class must
        # agree on their key domains (einsum binds them to one letter)
        uf = join_equiv_classes(n.pred, la, ra)
        for members in uf.classes().values():
            known = []
            for c in members:
                if isinstance(c, Lit):
                    continue
                t = side_t(c)
                if 0 <= c.idx < t.key_arity and t.extents[c.idx] is not None:
                    known.append((t.labels[c.idx], t.extents[c.idx]))
            exts = {e for _, e in known}
            if len(exts) > 1:
                parts = ", ".join(f"{lab} (extent {e})" for lab, e in known)
                err(
                    "join-extent-mismatch",
                    path,
                    f"⋈ equates key components with different domains: {parts}",
                    "joined key components must range over the same domain; "
                    "check the join predicate's column pairing",
                )

        # COO gather contract: every dense key component must be matched
        if coo_side and not (lt.kind == "coo" and rt.kind == "coo"):
            dense_t, dense_cls = (rt, R) if lt.kind == "coo" else (lt, L)
            matched = set()
            for a, b in n.pred.eqs:
                for c in (a, b):
                    if isinstance(c, dense_cls):
                        matched.add(c.idx)
            if not has_lit_pred and len(matched) < dense_t.key_arity:
                err(
                    "coo-unmatched-dense-key",
                    path,
                    f"COO ⋈ dense requires every dense key component "
                    f"matched by the predicate (matched {sorted(matched)} "
                    f"of arity {dense_t.key_arity}) "
                    "(compiler: gather needs a full index)",
                    "add predicate equalities covering all dense key "
                    "components",
                )

        for c in n.proj.comps:
            comp_ok(c, "⋈ projection")
            if isinstance(c, Lit) and coo_side is False and grp is not None:
                emit(
                    "info",
                    "literal-projection",
                    path,
                    "Lit component in a Σ-fused ⋈ projection falls off the "
                    "einsum fast path",
                    "",
                )

        # a bare dense⋈dense must keep every key class in its output
        # (classes pinned to a literal by the predicate are selection-like
        # and may legitimately be dropped on the fallback paths)
        if grp is None and not coo_side and lt.kind == "dense" and rt.kind == "dense":
            out_roots = {
                uf.find(c) for c in n.proj.comps if not isinstance(c, Lit)
            }
            lit_roots = {
                uf.find(c)
                for pair in n.pred.eqs
                for c in pair
                if isinstance(c, Lit)
            }
            in_roots = {uf.find(L(i)) for i in range(la)} | {
                uf.find(R(j)) for j in range(ra)
            }
            if not (in_roots - lit_roots) <= out_roots:
                err(
                    "join-drops-class",
                    path,
                    "bare ⋈ drops a key class (would implicitly aggregate "
                    "duplicate keys) "
                    "(compiler: 'bare join drops a key class; wrap in Σ')",
                    "wrap the join in a Σ that sums over the dropped "
                    "components",
                )

        # partial-RJP grad derivability: a wrt input below this join whose
        # side key is not solvable from the output key gets the general
        # (slower) partial-RJP gradient fallback
        if wrt_set:
            sides = [("left", n.left, n.pred, n.proj, la, ra)]
            mpred, mproj = _mirror_join(n.pred, n.proj)
            sides.append(("right", n.right, mpred, mproj, ra, la))
            for side, child, pred, proj, sa, oa in sides:
                below = sorted(
                    {s.name for s in child.table_scans()} & wrt_set
                )
                if below and solve_left_key(pred, proj, sa, oa) is None:
                    warn(
                        "partial-rjp",
                        path,
                        f"the {side} input key of this ⋈ is not solvable "
                        f"from its output key; gradients for {below} fall "
                        "back to the general partial-RJP path",
                        "keep the joined key components in the join/Σ "
                        "output, or accept the slower general RJP",
                    )

        dtype = _promotion(lt, rt, path, f"⋈ kernel ⊗{n.kernel.name}")

        def comp_info(c):
            if isinstance(c, Lit):
                return None, "lit"
            t = side_t(c)
            if not (0 <= c.idx < t.key_arity):
                return None, "?"
            return t.extents[c.idx], t.labels[c.idx]

        ext, lab = zip(*[comp_info(c) for c in n.proj.comps]) if n.proj.comps else ((), ())
        kind = "coo" if coo_side else "dense"
        return RelType(kind, n.key_arity, tuple(ext), tuple(lab), dtype)

    def _add(n: fra.AddOp, path: str) -> RelType:
        lt = visit(n.left, path + "/L:")
        rt = visit(n.right, path + "/R:")
        if lt.kind == "coo" and rt.kind == "coo":
            err(
                "coo-coo-add",
                path,
                "COO + COO is not compilable "
                "(compiler: 'COO + COO add not supported')",
                "densify one operand before adding",
            )
        for i in range(min(lt.key_arity, rt.key_arity)):
            le, re = lt.extents[i], rt.extents[i]
            if le is None or re is None or le == re:
                continue
            if 1 in (le, re):
                warn(
                    "broadcast-add",
                    path,
                    f"add over mismatched extents {lt.labels[i]} ({le}) vs "
                    f"{rt.labels[i]} ({re}) silently broadcasts",
                    "make the key domains equal if broadcasting is not "
                    "intended",
                )
            else:
                err(
                    "add-extent-mismatch",
                    path,
                    f"add requires equal key domains: {lt.labels[i]} has "
                    f"extent {le} but {rt.labels[i]} has {re}",
                    "align the operands' key domains before adding",
                )
        dtype = _promotion(lt, rt, path, "add")
        base = lt if lt.kind != "unknown" else rt
        return RelType(base.kind, n.key_arity, base.extents, base.labels, dtype)

    def _restrict(n: fra.Restrict, path: str) -> RelType:
        ct = visit(n.child, path + "/L:")
        ft = visit(n.ref, path + "/R:")
        if ft.kind == "coo" and isinstance(n.child, fra.Join):
            jt_l = memo.get(n.child.left.id)
            jt_r = memo.get(n.child.right.id)
            if (
                jt_l is not None
                and jt_r is not None
                and jt_l.kind == "dense"
                and jt_r.kind == "dense"
            ):
                from ..core.compiler import _solve_side_from_output

                solved = _solve_side_from_output(
                    n.child.pred,
                    n.child.proj,
                    n.child.left.key_arity,
                    n.child.right.key_arity,
                )
                if solved is None:
                    err(
                        "restricted-join-underdetermined",
                        path,
                        "restrict-to-COO over this ⋈ cannot reconstruct "
                        "both input keys from the output key "
                        "(compiler: 'restricted join underdetermined')",
                        "aggregate (Σ) the join before restricting",
                    )
        return RelType(
            ft.kind if ft.kind != "unknown" else ct.kind,
            n.key_arity,
            ct.extents,
            ct.labels,
            ct.dtype,
        )

    def visit(n: fra.Node, prefix: str) -> RelType:
        if isinstance(n, fra.TableScan):
            label = f"τ({n.name})"
        elif isinstance(n, fra.Const):
            label = f"const({n.ref})"
        elif isinstance(n, fra.Select):
            label = "σ"
        elif isinstance(n, fra.Agg):
            label = "Σ"
        elif isinstance(n, fra.Join):
            label = "⋈"
        elif isinstance(n, fra.AddOp):
            label = "+"
        else:
            label = "restrict"
        sep = "" if not prefix or prefix.endswith(":") else "/"
        path = prefix + sep + label
        if n.id in memo:  # shared subgraph: first path's diagnostics win
            return memo[n.id]
        if isinstance(n, fra.TableScan):
            t = _scan(n.name, n, path)
        elif isinstance(n, fra.Const):
            t = _scan(n.ref, n, path)
        elif isinstance(n, fra.Select):
            t = _select(n, path)
        elif isinstance(n, fra.Agg):
            t = _agg(n, path)
        elif isinstance(n, fra.Join):
            t = _join(n, path)
        elif isinstance(n, fra.AddOp):
            t = _add(n, path)
        else:
            t = _restrict(n, path)
        memo[n.id] = t
        return t

    visit(root, "")

    # -- sharded-extent divisibility against the mesh geometry --------------
    if geometry is not None and getattr(geometry, "model_size", 1) > 1 and env:
        m = int(geometry.model_size)
        for s in root.topo():
            if not isinstance(s, (fra.TableScan, fra.Const)):
                continue
            name = s.name if isinstance(s, fra.TableScan) else s.ref
            rel = (env or {}).get(name)
            if not isinstance(rel, DenseRelation):
                continue
            exts = [int(e) for e in rel.extents[: rel.key_arity]]
            if not exts or not any(e >= m for e in exts):
                continue
            if not any(e % m == 0 for e in exts):
                warn(
                    "non-divisible-shard",
                    f"τ({name})" if isinstance(s, fra.TableScan) else f"const({name})",
                    f"no key extent of {name!r} {tuple(exts)} divides the "
                    f"mesh model axis ({m} devices); the planner will fall "
                    "back to replicating it",
                    "pad the relation to a multiple of the model-axis size "
                    "to shard it",
                )

    # drop duplicate diagnostics (shared subgraphs), preserving order
    seen = set()
    uniq = []
    for d in diags:
        if d not in seen:
            seen.add(d)
            uniq.append(d)
    return CheckReport(tuple(uniq))
