from .losses import lm_loss  # noqa: F401
from .trainer import make_train_step, TrainState  # noqa: F401
