"""Training step factory.

``make_train_step(model)`` returns a pure (params, opt_state, batch) →
(params, opt_state, metrics) function. Gradients flow through the
relational custom_vjp ops, i.e. the backward pass executes the
RA-autodiff-generated queries.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from repro.optim import adam_init, adam_update

from .losses import lm_loss


@dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: int = 0


def make_train_step(
    model,
    *,
    lr: float = 3e-4,
    aux_weight: float = 0.01,
    grad_clip: float = 1.0,
) -> Callable:
    cfg = model.cfg

    def loss_fn(params, batch):
        logits, aux = model.train_logits(params, batch)
        loss = lm_loss(logits, batch["labels"])
        total = loss + aux_weight * aux
        return total, {"loss": loss, "aux": aux}

    def train_step(params, opt_state, batch):
        (total, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch
        )
        params, opt_state = adam_update(
            params, grads, opt_state,
            lr=lr, grad_clip=grad_clip,
        )
        metrics = dict(metrics, total=total)
        return params, opt_state, metrics

    return train_step


def init_train_state(model, key, dtype=None) -> TrainState:
    params = model.init(key)
    opt_dtype = jnp.dtype(dtype or model.cfg.opt_state_dtype)
    return TrainState(params, adam_init(params, dtype=opt_dtype))
