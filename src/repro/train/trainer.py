"""Training step factory.

``make_train_step(model)`` returns a pure (params, opt_state, batch) →
(params, opt_state, metrics) function. Gradients flow through the
relational custom_vjp ops, i.e. the backward pass executes the
RA-autodiff-generated queries — which themselves step through the staged
engine (core/engine.py), so the FRA graphs are lowered once and reused
across steps.

The step itself is staged the same way: constructed once, jit-compiled
once (donating the parameter and optimizer buffers so XLA updates them
in place), and optionally sharded over a mesh — the planner-style
PartitionSpec assignment from launch/sharding.py is applied as sharding
constraints inside the compiled step.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.optim import adam_init, adam_update

from .losses import lm_loss


@dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: int = 0


def make_train_step(
    model,
    *,
    lr: float = 3e-4,
    aux_weight: float = 0.01,
    grad_clip: float = 1.0,
    jit: bool = True,
    donate: bool = False,
    mesh=None,
    database=None,
) -> Callable:
    """Build the train step once; the returned callable is the compiled
    executable reused every iteration.

    ``jit=False`` returns the eager step (debugging). ``donate=True``
    donates the params/opt_state buffers to the compiled step — use it
    when the caller rebinds both from the step's outputs (donation under
    an *outer* jit wrapper is ignored by JAX, so legacy callers that
    re-wrap the step in jax.jit are unaffected).
    ``database`` threads a ``repro.Database`` session through the step:
    the mesh defaults to the session's active mesh and every returned
    step runs inside ``database.activate()``, so the relational ops in
    the model plan/dispatch through that session — the one front door.
    ``mesh`` (when given, or inherited from the session) applies the
    distribution planner's parameter layout (launch/sharding.py) inside
    the compiled step via sharding constraints, so XLA SPMD places each
    matmul's collective. It takes a jax Mesh or a
    ``launch/mesh.resolve_mesh`` spec string (``"host"``,
    ``"host:<model>"``, ``"production"``, ``"production:multipod"``) —
    ``launch.mesh.make_host_mesh`` / ``make_production_mesh`` are the
    canonical constructors either way.
    """
    cfg = model.cfg

    if database is not None and mesh is None:
        mesh = database.mesh

    if isinstance(mesh, str):
        from repro.launch.mesh import resolve_mesh

        mesh = resolve_mesh(mesh)

    if mesh is not None:
        from jax.sharding import NamedSharding

        from repro.launch.sharding import param_pspecs

        def constrain(params):
            # FSDP needs a "data" axis; a model-only mesh still gets the
            # tensor-parallel rules.
            specs = param_pspecs(params, mesh, fsdp="data" in mesh.axis_names)
            return jax.tree_util.tree_map(
                lambda x, s: jax.lax.with_sharding_constraint(
                    x, NamedSharding(mesh, s)
                ),
                params,
                specs,
            )
    else:
        def constrain(params):
            return params

    def loss_fn(params, batch):
        logits, aux = model.train_logits(params, batch)
        loss = lm_loss(logits, batch["labels"])
        total = loss + aux_weight * aux
        return total, {"loss": loss, "aux": aux}

    def train_step(params, opt_state, batch):
        params = constrain(params)
        (total, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch
        )
        params, opt_state = adam_update(
            params, grads, opt_state,
            lr=lr, grad_clip=grad_clip,
        )
        params = constrain(params)
        metrics = dict(metrics, total=total)
        return params, opt_state, metrics

    if jit:
        donate_argnums = (0, 1) if donate else ()
        stepped = jax.jit(train_step, donate_argnums=donate_argnums)
    else:
        stepped = train_step
    if database is None:
        return stepped

    def sessioned_step(params, opt_state, batch):
        with database.activate():
            return stepped(params, opt_state, batch)

    return sessioned_step


def init_train_state(model, key, dtype=None) -> TrainState:
    params = model.init(key)
    opt_dtype = jnp.dtype(dtype or model.cfg.opt_state_dtype)
    return TrainState(params, adam_init(params, dtype=opt_dtype))
