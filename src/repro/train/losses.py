"""Losses. Cross entropy computed in f32 with label masking (-100)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def lm_loss(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """logits (B,S,V) f32; labels (B,S) int32, -100 = ignore.

    The gold logit is extracted with a masked reduction over V rather than
    take_along_axis: with vocab-parallel logits the reduction stays sharded
    (partial sum + all-reduce) instead of forcing an all-gather of the
    full logits tensor."""
    valid = labels >= 0
    safe = jnp.where(valid, labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    vocab = logits.shape[-1]
    onehot = safe[..., None] == jax.lax.broadcasted_iota(
        jnp.int32, (1, 1, vocab), 2
    )
    gold = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    nll = (logz - gold) * valid
    return jnp.sum(nll) / jnp.maximum(jnp.sum(valid), 1)
