"""SGD (paper Appendix B/C use SGD with η=0.1 / 0.5)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def sgd_init(params, momentum: float = 0.0, dtype=jnp.float32):
    if momentum:
        return {
            "mom": jax.tree.map(lambda p: jnp.zeros(p.shape, dtype=dtype), params)
        }
    return {}


def sgd_update(params, grads, state, *, lr: float = 0.1, momentum: float = 0.0):
    if momentum:
        new_mom = jax.tree.map(
            lambda m, g: momentum * m + g.astype(m.dtype), state["mom"], grads
        )
        new_p = jax.tree.map(
            lambda p, m: (p.astype(jnp.float32) - lr * m.astype(jnp.float32)).astype(p.dtype),
            params,
            new_mom,
        )
        return new_p, {"mom": new_mom}
    new_p = jax.tree.map(
        lambda p, g: (p.astype(jnp.float32) - lr * g.astype(jnp.float32)).astype(p.dtype),
        params,
        grads,
    )
    return new_p, state
