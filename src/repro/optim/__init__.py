from .adam import adam_init, adam_update  # noqa: F401
from .sgd import sgd_init, sgd_update  # noqa: F401
