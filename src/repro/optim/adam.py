"""Adam/AdamW — paper §6 uses Adam (η=0.1) for the GCN experiments.

State dtype is configurable: the largest assigned configs (llama3-405b,
deepseek-v3) keep moments in bf16 so params+state fit the single-pod HBM
budget (see DESIGN.md §hardware-adaptation)."""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


def adam_init(params, dtype=jnp.float32) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, dtype=dtype)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adam_update(
    params,
    grads,
    state,
    *,
    lr: float = 1e-3,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    grad_clip: float = 0.0,
) -> Tuple[Any, Dict[str, Any]]:
    step = state["step"] + 1

    if grad_clip:
        gnorm = jnp.sqrt(
            sum(
                jnp.sum(jnp.square(g.astype(jnp.float32)))
                for g in jax.tree.leaves(grads)
            )
        )
        scale = jnp.minimum(1.0, grad_clip / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m1 = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        v1 = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        mh = m1 / (1 - b1 ** step)
        vh = v1 / (1 - b2 ** step)
        delta = mh / (jnp.sqrt(vh) + eps)
        if weight_decay:
            delta = delta + weight_decay * p.astype(jnp.float32)
        return (
            (p.astype(jnp.float32) - lr * delta).astype(p.dtype),
            m1.astype(m.dtype),
            v1.astype(v.dtype),
        )

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["mu"])
    flat_v = tdef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"mu": new_m, "nu": new_v, "step": step}
