"""The async serving front door: continuous batching over a Database.

The paper's systems pitch is that the relational engine *is* the ML
system — so the ``repro.Database`` session front door must also be the
serving front door. ``Endpoint`` (built with ``db.endpoint(...)`` /
``repro.serve(db, ...)``) is that service layer:

  * an **admission queue** (bounded at ``max_queue``; overflow requests
    are shed with ``Overloaded``, counted under
    ``db.counters()["serve"]["shed_queue_full"]``),
  * **continuous batching**: a scheduler task coalesces whatever
    requests are in flight — grouped by (model version, prompt length) —
    into the session's (batch, seq) **bucketed prefill executables**
    (serve.py's ``BucketedPrefill``), so N concurrent single-row
    requests cost ~N/bucket compiled steps, not N,
  * **decode-step bucketing with slot reuse**: decode runs at a small
    set of batch buckets (compiled once per bucket, never per exact
    batch); a finished request releases its slot immediately — its
    future resolves mid-group — and when enough slots free up the group
    compacts down to a smaller bucket (``decode/rebuckets``),
  * **per-tenant model versions** resolved through the catalog's model
    registry (``db.register_model``): requests address models as
    ``name@version`` or through the endpoint's tenant map, and
    re-registering a version hot-swaps the served parameters,
  * **deadline shedding**: a request whose deadline passes while queued
    is rejected at batch formation (``DeadlineExceeded``,
    ``serve/shed_deadline``) instead of wasting a slot.

Every counter lives in the session's unified telemetry tree next to the
cache/reshard/spill counters::

    db.counters()["serve"]   # requests, batches, sheds, prefill/decode

Quickstart (see docs/serving.md)::

    db = repro.Database()
    db.register_model("lm", model, params)          # → lm@v1
    ep = db.endpoint("lm", cache_len=48,
                     buckets=[(1, 16), (4, 16), (8, 16)])
    ep.warmup()                                     # compile before traffic

    async def client(prompt):
        out = await ep.submit(prompt, max_new_tokens=8)
        return out.token_ids

The sequence dim is never padded (see ``BucketedPrefill``): prompts must
arrive at a bucketed length. Tokens are decoded greedily (argmax); the
decode step threads encoder output for encoder-decoder configs when the
batch carries ``frames``.
"""

from __future__ import annotations

import asyncio
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .serve import BucketedPrefill, make_decode_step


class ServingError(RuntimeError):
    """Base class of the serving front door's structured failures."""


class Overloaded(ServingError):
    """The admission queue is at ``max_queue``: the request was shed at
    submit time (``serve/shed_queue_full``). Back off and retry."""


class DeadlineExceeded(ServingError):
    """The request's deadline passed before service started: it was shed
    at batch formation (``serve/shed_deadline``)."""


class EndpointClosed(ServingError):
    """The endpoint was closed; in-queue requests fail with this."""


@dataclass
class Completion:
    """One served request's result."""

    #: greedily decoded token ids, ``(n_generated,)`` int32.
    token_ids: np.ndarray
    #: prompt length the request arrived with.
    prompt_len: int
    #: the catalog coordinate that served it, ``"name@version"``.
    model: str
    #: submit → completion wall time in seconds (event-loop clock).
    latency: float


@dataclass
class _Request:
    tokens: np.ndarray
    entry_key: Tuple[str, str]
    model_id: str
    seq: int
    max_new: int
    deadline: Optional[float]
    t_submit: float
    future: "asyncio.Future"
    generated: List[int] = field(default_factory=list)


# -- cache-pytree batch-dim surgery (decode slot pool) ----------------------
#
# The batch axis follows the repo's cache layout (serve.init_cache): axis 1
# under a stacked ``scan`` subtree (axis 0 is the layer axis), axis 0
# elsewhere; leaves without the expected extent at that axis pass through.


def _cache_batch_axis(path) -> int:
    return 1 if any(getattr(p, "key", None) == "scan" for p in path) else 0


def _pad_cache_batch(caches, bsz: int, bucket_b: int):
    """Zero-pad the cache pytree's batch axis from ``bsz`` to the decode
    bucket ``bucket_b`` (the padded rows are dead slots)."""
    if bsz == bucket_b:
        return caches

    def pad(path, leaf):
        if not hasattr(leaf, "ndim"):
            return leaf
        axis = _cache_batch_axis(path)
        if leaf.ndim > axis and leaf.shape[axis] == bsz:
            widths = [(0, 0)] * leaf.ndim
            widths[axis] = (0, bucket_b - bsz)
            return jnp.pad(leaf, widths)
        return leaf

    return jax.tree_util.tree_map_with_path(pad, caches)


def _take_cache_batch(caches, idx: Sequence[int], bucket_b: int):
    """Gather cache rows ``idx`` out of a ``bucket_b``-batch cache pytree
    — the slot-compaction move when a decode group re-buckets down."""
    idxa = jnp.asarray(list(idx), jnp.int32)

    def take(path, leaf):
        if not hasattr(leaf, "ndim"):
            return leaf
        axis = _cache_batch_axis(path)
        if leaf.ndim > axis and leaf.shape[axis] == bucket_b:
            return jnp.take(leaf, idxa, axis=axis)
        return leaf

    return jax.tree_util.tree_map_with_path(take, caches)


def _pad_rows(x, bucket_b: int):
    """Pad a leading batch axis with zero rows up to ``bucket_b``."""
    if x.shape[0] == bucket_b:
        return x
    widths = [(0, 0)] * x.ndim
    widths[0] = (0, bucket_b - x.shape[0])
    return jnp.pad(x, widths)


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


class Endpoint:
    """An async serving endpoint over a ``repro.Database`` session.

    Construct through ``db.endpoint(model, ...)`` (or ``repro.serve``).
    ``model`` is a registered model name (``"lm"`` / ``"lm@v2"``), a
    Model instance (auto-registered under ``name=`` with ``params=``), or
    None (every request must then pass ``model=`` / ``tenant=``).

    Parameters
    ----------
    cache_len:
        KV/state cache length decode runs against (prompt + generation
        budget; one compiled decode shape class per batch bucket).
    buckets:
        (batch, seq) prefill buckets, as in ``BucketedPrefill``. None
        compiles per exact shape (coalescing still happens, bucketing
        does not).
    decode_buckets:
        batch buckets decode compiles at. Default: powers of two up to
        the largest prefill bucket batch; None (with ``buckets=None``)
        decodes at exact batch.
    tenants:
        tenant → ``"name[@version]"`` model-registry coordinates;
        ``submit(tenant=...)`` resolves through this map, so tenants pin
        model versions without clients knowing the mapping.
    max_queue:
        admission queue bound; a full queue sheds with ``Overloaded``.
        None = unbounded (no queue-full shedding).
    gather_window:
        seconds the scheduler waits after the first queued request for
        more to coalesce with. 0 (default) batches only what is already
        in flight — under sustained load that is plenty.
    max_new_tokens:
        per-request default generation budget.
    eos_token:
        optional end-of-sequence token id: a slot whose latest generated
        token equals it is released immediately (counted on
        ``counters["serve"]["decode"]["eos_stops"]``) instead of
        decoding to its ``max_new_tokens`` budget. None (default)
        disables early stop.
    make_batch:
        optional ``tokens (B, S) → batch dict`` hook for models whose
        prefill reads more than ``{"tokens": ...}`` (vision/encoder
        configs).
    """

    def __init__(
        self,
        db,
        model=None,
        *,
        cache_len: int,
        params=None,
        version: Optional[str] = None,
        name: Optional[str] = None,
        buckets: Optional[Sequence[Tuple[int, int]]] = None,
        decode_buckets: Optional[Sequence[int]] = None,
        tenants: Optional[Dict[str, str]] = None,
        max_queue: Optional[int] = 64,
        gather_window: float = 0.0,
        max_new_tokens: int = 16,
        eos_token: Optional[int] = None,
        make_batch: Optional[Callable[[Any], Dict[str, Any]]] = None,
    ):
        self.db = db
        self.cache_len = int(cache_len)
        self._buckets = (
            sorted({(int(b), int(s)) for b, s in buckets}) if buckets else None
        )
        if decode_buckets is not None:
            self.decode_buckets: Optional[List[int]] = sorted(
                {int(b) for b in decode_buckets}
            )
        elif self._buckets:
            top = _next_pow2(max(b for b, _ in self._buckets))
            self.decode_buckets = [
                2 ** i for i in range(top.bit_length()) if 2 ** i <= top
            ]
        else:
            self.decode_buckets = None
        self._tenants = dict(tenants or {})
        self._max_queue = max_queue
        self._gather_window = float(gather_window)
        self._max_new_tokens = int(max_new_tokens)
        self._eos_token = None if eos_token is None else int(eos_token)
        self._make_batch = make_batch

        self._default: Optional[Tuple[str, Optional[str]]] = None
        if model is None:
            pass
        elif isinstance(model, str):
            entry = db.model(model, version)  # validates registration
            # "lm@v2" / version= pins that version; a bare name follows
            # the latest registration (hot-swap on re-register)
            pinned = version is not None or "@" in model
            self._default = (entry.name, entry.version if pinned else None)
        else:
            if params is None:
                raise ValueError(
                    "db.endpoint(model_instance) needs params=; or "
                    "db.register_model(name, model, params) first and "
                    "pass the name"
                )
            entry = db.register_model(
                name or "default", model, params, version=version
            )
            self._default = (entry.name, None)  # follows re-registrations

        #: one bucketing engine per (model name, version) served.
        self._prefills: Dict[Tuple[str, str], BucketedPrefill] = {}
        self._serve = db._counters["serve"]
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._task: Optional[asyncio.Task] = None
        self._queue: Optional[asyncio.Queue] = None
        self._closed = False

    # -- model resolution (through the catalog) ----------------------------

    def _resolve(self, *, tenant=None, model=None, version=None):
        if tenant is not None:
            if model is not None:
                raise ValueError("pass tenant= or model=, not both")
            try:
                spec = self._tenants[tenant]
            except KeyError:
                raise ValueError(
                    f"tenant {tenant!r} has no model mapping on this "
                    f"endpoint (tenants: {sorted(self._tenants)})"
                ) from None
            return self.db.model(spec)
        if model is not None:
            return self.db.model(model, version)
        if self._default is None:
            raise ValueError(
                "endpoint has no default model; pass model= (or tenant=) "
                "to submit, or model= to db.endpoint(...)"
            )
        return self.db.model(*self._default)

    def _prefill_for(self, entry) -> BucketedPrefill:
        pre = self._prefills.get(entry.key)
        if pre is None or pre.model is not entry.model:
            counters = self._serve["prefill"]

            def on_compile():
                counters["compiles"] += 1

            pre = BucketedPrefill(
                entry.model,
                self.cache_len,
                db=self.db,
                buckets=self._buckets,
                on_compile=on_compile,
            )
            self._prefills[entry.key] = pre
        return pre

    def _decode_exec(self, entry, bucket: int):
        dec = self._serve["decode"]
        key = ("decode", entry.key, id(entry.model), self.cache_len, bucket)

        def build():
            dec["compiles"] += 1

            def on_trace():
                dec["traces"] += 1

            fn = make_decode_step(entry.model, db=self.db, on_trace=on_trace)
            # a mesh-less session gets the raw step back: jit it so
            # decode is compiled per bucket, never interpreted per call
            return fn if self.db.mesh is not None else jax.jit(fn)

        return self.db.cached_executable(key, build)

    def _decode_bucket(self, k: int) -> int:
        if not self.decode_buckets:
            return k
        fitting = [b for b in self.decode_buckets if b >= k]
        return min(fitting) if fitting else k

    # -- the request path ---------------------------------------------------

    async def submit(
        self,
        tokens,
        *,
        tenant: Optional[str] = None,
        model: Optional[str] = None,
        version: Optional[str] = None,
        max_new_tokens: Optional[int] = None,
        deadline: Optional[float] = None,
    ) -> Completion:
        """Serve one prompt (1-D int token ids) and return its
        ``Completion`` — admission, batching, prefill and decode all
        happen behind the await. ``deadline`` (seconds from now) sheds
        the request with ``DeadlineExceeded`` if service has not started
        in time; a full admission queue sheds immediately with
        ``Overloaded``."""
        if self._closed:
            raise EndpointClosed("endpoint is closed")
        c = self._serve
        c["requests"] += 1
        arr = np.asarray(tokens)
        if arr.ndim != 1:
            raise ValueError(
                f"submit takes one prompt of 1-D token ids; got shape "
                f"{arr.shape} (batching is the endpoint's job)"
            )
        seq = int(arr.shape[0])
        if seq == 0:
            raise ValueError(
                "zero-length prompt: prefill needs at least one token — "
                "pad prompts to a configured bucket length upstream"
            )
        max_new = int(
            self._max_new_tokens if max_new_tokens is None else max_new_tokens
        )
        if max_new < 1:
            raise ValueError(f"max_new_tokens must be >= 1, got {max_new}")
        entry = self._resolve(tenant=tenant, model=model, version=version)
        # reject unservable shapes before they occupy a queue slot
        self._prefill_for(entry).bucket_for(1, seq)
        self._ensure_started()
        loop = self._loop
        req = _Request(
            tokens=arr.astype(np.int32),
            entry_key=entry.key,
            model_id=str(entry),
            seq=seq,
            max_new=max_new,
            deadline=None if deadline is None else loop.time() + deadline,
            t_submit=loop.time(),
            future=loop.create_future(),
        )
        try:
            self._queue.put_nowait(req)
        except asyncio.QueueFull:
            c["shed_queue_full"] += 1
            raise Overloaded(
                f"admission queue full (max_queue={self._max_queue}); "
                f"request shed — back off and retry"
            ) from None
        c["admitted"] += 1
        c["queue_peak"] = max(c["queue_peak"], self._queue.qsize())
        return await req.future

    def _ensure_started(self) -> None:
        loop = asyncio.get_running_loop()
        if self._loop is not loop or self._task is None or self._task.done():
            # (re)bind to the current event loop: endpoints survive
            # consecutive asyncio.run() blocks (each run tears its loop
            # — and the scheduler task — down with it)
            self._loop = loop
            self._queue = (
                asyncio.Queue(maxsize=self._max_queue)
                if self._max_queue
                else asyncio.Queue()
            )
            self._task = loop.create_task(
                self._run(), name="repro-endpoint-scheduler"
            )

    async def _run(self) -> None:
        while True:
            req = await self._queue.get()
            if self._gather_window > 0:
                # let concurrent submitters land in the queue so the
                # batch coalesces them (continuous batching under load
                # happens anyway: requests queue while a batch decodes)
                await asyncio.sleep(self._gather_window)
            batch = [req]
            while True:
                try:
                    batch.append(self._queue.get_nowait())
                except asyncio.QueueEmpty:
                    break
            await self._dispatch(batch)

    async def _dispatch(self, batch: List[_Request]) -> None:
        c = self._serve
        now = self._loop.time()
        groups: Dict[Tuple[Tuple[str, str], int], List[_Request]] = {}
        for r in batch:
            if r.deadline is not None and now >= r.deadline:
                c["shed_deadline"] += 1
                if not r.future.done():
                    r.future.set_exception(
                        DeadlineExceeded(
                            f"deadline passed before service started "
                            f"(queued {now - r.t_submit:.3f}s)"
                        )
                    )
                continue
            groups.setdefault((r.entry_key, r.seq), []).append(r)
        for (entry_key, seq), reqs in groups.items():
            try:
                entry = self.db.model(*entry_key)  # fresh params (hot-swap)
                pre = self._prefill_for(entry)
                cap = pre.max_batch(seq) or len(reqs)
                chunks = [
                    reqs[i : i + cap] for i in range(0, len(reqs), cap)
                ]
            except Exception as e:  # keep the scheduler alive
                for r in reqs:
                    if not r.future.done():
                        c["failed"] += 1
                        r.future.set_exception(e)
                continue
            for chunk in chunks:
                try:
                    await self._serve_group(entry, pre, chunk, seq)
                except Exception as e:  # keep serving the other groups
                    for r in chunk:
                        if not r.future.done():
                            c["failed"] += 1
                            r.future.set_exception(e)

    async def _serve_group(
        self, entry, pre: BucketedPrefill, reqs: List[_Request], seq: int
    ) -> None:
        """Prefill one coalesced batch, then decode it as a slot pool:
        bucket-shaped caches, per-request completion the step a request
        finishes, compaction to a smaller bucket when slots free up."""
        c = self._serve
        params = entry.params
        model = entry.model
        k = len(reqs)
        tokens = jnp.asarray(np.stack([r.tokens for r in reqs]))
        batch = (
            self._make_batch(tokens)
            if self._make_batch is not None
            else {"tokens": tokens}
        )
        logits, caches = pre.prefill(params, batch)
        c["batches"] += 1
        c["prefill"]["steps"] += 1
        if k > 1:
            c["batched_requests"] += k
        # the repo's models emit last-position-only prefill logits
        # (B, 1, V); [:, -1:] also tolerates per-token stand-ins
        first = np.asarray(
            jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        )
        for i, r in enumerate(reqs):
            r.generated.append(int(first[i, 0]))

        cfg = getattr(model, "cfg", None)
        enc_out = None
        if (
            cfg is not None
            and getattr(cfg, "encoder_layers", 0)
            and "frames" in batch
        ):
            enc_out = model._encode(params, batch["frames"])
        vis = int(getattr(cfg, "vis_seq", 0) or 0) if cfg is not None else 0
        length = seq + vis

        bucket = self._decode_bucket(k)
        tok = _pad_rows(jnp.asarray(first), bucket)
        caches = _pad_cache_batch(caches, k, bucket)
        if enc_out is not None:
            enc_out = jax.tree_util.tree_map(
                lambda x: _pad_rows(x, bucket), enc_out
            )
        slots: List[Optional[_Request]] = list(reqs) + [None] * (bucket - k)

        eos = self._eos_token
        while True:
            for i, r in enumerate(slots):
                if r is None:
                    continue
                # EOS early stop: the model ended the sequence, so the
                # slot frees now (and may trigger a rebucket below)
                # instead of burning decode steps to the max_new budget
                eos_hit = (
                    eos is not None
                    and r.generated
                    and r.generated[-1] == eos
                    and len(r.generated) < r.max_new
                )
                if eos_hit or len(r.generated) >= r.max_new:
                    self._complete(r)
                    slots[i] = None
                    c["decode"]["slot_releases"] += 1
                    if eos_hit:
                        c["decode"]["eos_stops"] += 1
            active = [i for i, r in enumerate(slots) if r is not None]
            if not active:
                return
            nb = self._decode_bucket(len(active))
            if nb < bucket:
                # compact live slots to the front and drop to the
                # smaller bucket's executable (compiled once, reused)
                idx = active + [active[0]] * (nb - len(active))
                tok = jnp.take(tok, jnp.asarray(idx[:nb]), axis=0)
                caches = _take_cache_batch(caches, idx[:nb], bucket)
                if enc_out is not None:
                    enc_out = jax.tree_util.tree_map(
                        lambda x: jnp.take(
                            x, jnp.asarray(idx[:nb]), axis=0
                        ),
                        enc_out,
                    )
                slots = [slots[i] for i in active] + [None] * (
                    nb - len(active)
                )
                bucket = nb
                c["decode"]["rebuckets"] += 1
            # yield: concurrent submits land in the admission queue and
            # coalesce into the next batch while this group decodes
            await asyncio.sleep(0)
            step = self._decode_exec(entry, bucket)
            length_arr = jnp.asarray(length, jnp.int32)
            if enc_out is not None:
                logits, caches = step(params, tok, caches, length_arr, enc_out)
            else:
                logits, caches = step(params, tok, caches, length_arr)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            row = np.asarray(tok)
            for i, r in enumerate(slots):
                if r is not None:
                    r.generated.append(int(row[i, 0]))
            length += 1
            c["decode"]["steps"] += 1

    def _complete(self, req: _Request) -> None:
        if req.future.done():
            return
        self._serve["completed"] += 1
        req.future.set_result(
            Completion(
                token_ids=np.asarray(req.generated, np.int32),
                prompt_len=req.seq,
                model=req.model_id,
                latency=self._loop.time() - req.t_submit,
            )
        )

    # -- warmup + lifecycle -------------------------------------------------

    def warmup(
        self,
        *,
        tenant: Optional[str] = None,
        model: Optional[str] = None,
        version: Optional[str] = None,
        buckets: Optional[Sequence[Tuple[int, int]]] = None,
        decode: bool = True,
        batch_fn: Optional[Callable[[int, int], Dict[str, Any]]] = None,
    ) -> None:
        """Compile the prefill buckets and (``decode=True``) every decode
        bucket before traffic arrives, so a warmed endpoint never
        compiles on the request path — ``db.counters()["serve"]`` shows
        flat prefill/decode compile counts under traffic afterwards."""
        entry = self._resolve(tenant=tenant, model=model, version=version)
        pre = self._prefill_for(entry)
        todo = [
            (int(b), int(s))
            for b, s in (buckets if buckets is not None else (pre.buckets or ()))
        ]
        if not todo:
            return
        pre.warmup(entry.params, buckets=todo, batch_fn=batch_fn)
        if not decode:
            return
        b0, s0 = todo[0]
        ex = (
            batch_fn(b0, s0)
            if batch_fn is not None
            else {"tokens": jnp.zeros((b0, s0), jnp.int32)}
        )
        _, caches = pre.prefill(entry.params, ex)
        cfg = getattr(entry.model, "cfg", None)
        enc_out = None
        if (
            cfg is not None
            and getattr(cfg, "encoder_layers", 0)
            and "frames" in ex
        ):
            enc_out = entry.model._encode(entry.params, ex["frames"])
        vis = int(getattr(cfg, "vis_seq", 0) or 0) if cfg is not None else 0
        length = jnp.asarray(s0 + vis, jnp.int32)
        for db_ in self.decode_buckets or [b0]:
            if db_ >= b0:
                cb = _pad_cache_batch(caches, b0, db_)
                eb = (
                    None
                    if enc_out is None
                    else jax.tree_util.tree_map(
                        lambda x: _pad_rows(x, db_), enc_out
                    )
                )
            else:
                cb = _take_cache_batch(caches, list(range(db_)), b0)
                eb = (
                    None
                    if enc_out is None
                    else jax.tree_util.tree_map(lambda x: x[:db_], enc_out)
                )
            tok = jnp.zeros((db_, 1), jnp.int32)
            step = self._decode_exec(entry, db_)
            out = (
                step(entry.params, tok, cb, length)
                if eb is None
                else step(entry.params, tok, cb, length, eb)
            )
            jax.block_until_ready(out)

    async def aclose(self) -> None:
        """Stop the scheduler and fail queued requests with
        ``EndpointClosed``; further submits are rejected."""
        self._closed = True
        if self._task is not None:
            self._task.cancel()
            try:
                await self._task
            except asyncio.CancelledError:
                pass
            self._task = None
        while self._queue is not None and not self._queue.empty():
            r = self._queue.get_nowait()
            if not r.future.done():
                r.future.set_exception(EndpointClosed("endpoint closed"))

    async def __aenter__(self) -> "Endpoint":
        return self

    async def __aexit__(self, *exc) -> None:
        await self.aclose()


def serve(db, model=None, **kwargs) -> Endpoint:
    """``repro.serve(db, "lm", cache_len=..., buckets=...)`` — the
    one-call serving front door; equivalent to ``db.endpoint(...)``."""
    return db.endpoint(model, **kwargs)
