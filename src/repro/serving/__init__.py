from .serve import make_prefill_step, make_decode_step, init_cache  # noqa: F401
from .serve import BatchServer  # noqa: F401
